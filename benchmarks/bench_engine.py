"""Continuous-batching engine throughput vs the one-shot lockstep loop
at **equal HBM budget** (same slot count, same KV capacity).

Workload: R requests, equal prompts, *skewed* generation lengths — the
regime continuous batching exists for.  The one-shot loop must serve the
requests in fixed batches of ``n_slots`` and run each batch until its
longest member finishes (early-finished rows keep burning decode steps);
the engine refills a slot the step after it frees.

Rows (``engine_throughput_*`` / ``one_shot_throughput_*``, consumed by
tests/test_bench_accounting.py):

* ``us_per_call``: wall time of serving the whole workload;
* derived: useful tokens/s for engine and one-shot, the ratio, mean slot
  occupancy, mean/peak page-pool utilization, and the HBM-budget line
  (slots × pages × page_size KV tokens; weight layout + B/weight).

``engine_throughput_kvq{2,4,8}`` rows re-run the engine with
codebook-quantized KV pages at the slot count each width affords in the
dense baseline's KV HBM (``engine.kvcache.equal_hbm_slots``); their
derived strings carry the slot-capacity ratio the accounting test pins
(≥1.5× at 4-bit on this geometry).

CPU caveat (recorded in the row): the jnp reference decode gathers KV
through the page table per layer, so the *per-step* engine cost exceeds
the one-shot contiguous-cache step; the engine wins on workload wall
time by keeping slots occupied.  ``REPRO_BENCH_FAST=1`` shrinks the
workload (accounting strings unchanged in form).
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionPlan, compression
from repro.engine import (Engine, FaultPlan, Request,
                          ServeSupervisorConfig, greedy_generate,
                          supervised_serve)
from repro.models.transformer import (LayerKind, ModelConfig, MoESpec,
                                      SSMSpec, StackSpec, init_params)

FAST = bool(os.environ.get("REPRO_BENCH_FAST"))


def _cfg() -> ModelConfig:
    return ModelConfig(
        name="bench-engine", family="hybrid", d_model=48, n_heads=4,
        n_kv=2, head_dim=12, d_ff=96, vocab=160,
        stacks=(StackSpec(pattern=(LayerKind("gqa", "dense"),
                                   LayerKind("ssm", "none")), groups=2),
                StackSpec(pattern=(LayerKind("gqa", "moe"),), groups=1)),
        tie_embeddings=True,
        moe=MoESpec(n_experts=4, top_k=2, n_shared=1, d_ff_expert=24,
                    capacity_factor=4.0),
        ssm=SSMSpec(d_inner=96, head_p=16, state_n=12, conv_w=4, chunk=8),
        q_chunk=8, kv_chunk=8, remat=False)


def _pack(params, k):
    plan = CompressionPlan.parse(f"adaptive:{k}")
    qspec = plan.build_qspec(params)
    state = plan.init(jax.random.PRNGKey(1), params, qspec)
    return plan.pack(params, state, qspec)


def _workload(cfg, n_req, prompt_len, gen_max):
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(7), (n_req, prompt_len), 0, cfg.vocab))
    # skewed gen lengths: a few long requests among many short ones
    gens = [gen_max if r % 4 == 0 else max(gen_max // 4, 1)
            for r in range(n_req)]
    reqs = [Request(rid=r, prompt=prompts[r], max_new_tokens=gens[r])
            for r in range(n_req)]
    return prompts, gens, reqs


def _one_shot_serve(params, cfg, prompts, gens, n_slots):
    """Fixed batches of n_slots in arrival order; each batch decodes in
    lockstep until its longest request finishes."""
    useful = 0
    for lo in range(0, len(gens), n_slots):
        hi = min(lo + n_slots, len(gens))
        batch_gen = max(gens[lo:hi])
        toks, _ = greedy_generate(params, cfg,
                                  jnp.asarray(prompts[lo:hi]), batch_gen)
        jax.block_until_ready(toks)
        useful += sum(gens[lo:hi])
    return useful


def _bench_cell(name, params, cfg, weight_note):
    n_req = 6 if FAST else 16
    prompt_len, gen_max = 16, (8 if FAST else 24)
    n_slots, page_size = 4, 8
    prompts, gens, reqs = _workload(cfg, n_req, prompt_len, gen_max)
    max_seq = prompt_len + gen_max
    pages_per_slot = -(-max_seq // page_size)
    n_pages = n_slots * pages_per_slot          # == one-shot KV capacity

    def engine_run():
        eng = Engine(params, cfg, n_slots=n_slots, page_size=page_size,
                     max_seq=max_seq, n_pages=n_pages,
                     token_budget=n_slots + prompt_len)
        outs = eng.run([Request(rid=r.rid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs])
        return eng, sum(len(v) for v in outs.values())

    # warm the compile caches outside the timed region with the FULL
    # workload on both paths (a ragged final one-shot batch would
    # otherwise compile its [R mod slots]-row prefill inside the timer)
    engine_run()
    _one_shot_serve(params, cfg, prompts, gens, n_slots)

    t0 = time.perf_counter()
    eng, useful_e = engine_run()
    dt_e = time.perf_counter() - t0
    t0 = time.perf_counter()
    useful_o = _one_shot_serve(params, cfg, prompts, gens, n_slots)
    dt_o = time.perf_counter() - t0

    s = eng.stats.summary()
    tps_e, tps_o = useful_e / dt_e, useful_o / dt_o
    kv_tokens = n_pages * page_size
    derived = (f"tok/s={tps_e:.1f} one_shot={tps_o:.1f} "
               f"(x{tps_e / tps_o:.2f}); occupancy={s['slot_occupancy']:.2f} "
               f"page_util={s['page_utilization']:.2f} "
               f"peak={s['page_utilization_max']:.2f}; "
               f"equal-HBM: slots={n_slots} pages={n_pages}x{page_size} "
               f"({kv_tokens} KV tokens, == one-shot {n_slots}x{max_seq}); "
               f"{weight_note}; R={n_req} gen {max(gens)}/{min(gens)} skew")
    return (name, dt_e * 1e6, derived), tps_e


def _bench_cell_kvq(params, cfg, kv_bits, dense_tps):
    """Quantized-KV engine cell at the **equal-HBM slot count**: the
    slots that ``kv_bits``-wide pages afford in the HBM the dense-KV
    baseline's 4 slots occupy (``engine.kvcache.equal_hbm_slots`` —
    word pools + per-page codebooks, so kvq8's codebook overhead can
    honestly erase the win at this tiny page geometry).  Throughput is
    quoted vs the dense engine cell; the slot-capacity ratio is the
    accounting claim tests/test_bench_accounting.py enforces."""
    from repro.engine import equal_hbm_slots
    from repro.engine.kvcache import kv_page_footprint

    n_req = 6 if FAST else 16
    prompt_len, gen_max = 16, (8 if FAST else 24)
    n_slots, page_size = 4, 8
    prompts, gens, reqs = _workload(cfg, n_req, prompt_len, gen_max)
    max_seq = prompt_len + gen_max
    pages_per_slot = -(-max_seq // page_size)

    slots_cap = equal_hbm_slots(n_slots, page_size, cfg.n_kv,
                                cfg.head_dim, kv_bits, "page")
    run_slots = min(slots_cap, 16)      # bound the CPU decode batch
    n_pages = run_slots * pages_per_slot
    dense_fp = kv_page_footprint(page_size, cfg.n_kv, cfg.head_dim, 0)
    quant_fp = kv_page_footprint(page_size, cfg.n_kv, cfg.head_dim,
                                 kv_bits, "page")

    def engine_run():
        eng = Engine(params, cfg, n_slots=run_slots, page_size=page_size,
                     max_seq=max_seq, n_pages=n_pages,
                     token_budget=run_slots + prompt_len,
                     kv_bits=kv_bits, kv_cb_mode="page")
        outs = eng.run([Request(rid=r.rid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs])
        return eng, sum(len(v) for v in outs.values())

    engine_run()                                    # warm compiles
    t0 = time.perf_counter()
    eng, useful = engine_run()
    dt = time.perf_counter() - t0
    s = eng.stats.summary()
    tps = useful / dt
    derived = (f"tok/s={tps:.1f} dense={dense_tps:.1f} "
               f"(x{tps / dense_tps:.2f}); "
               f"occupancy={s['slot_occupancy']:.2f} "
               f"page_util={s['page_utilization']:.2f} "
               f"peak={s['page_utilization_max']:.2f}; "
               f"equal-HBM: kv_bits={kv_bits} slots={slots_cap}/{n_slots} "
               f"(x{slots_cap / n_slots:.2f} capacity; running "
               f"{run_slots}) page_bytes={quant_fp} dense={dense_fp} "
               f"cb_mode=page; R={n_req} gen {max(gens)}/{min(gens)} skew")
    return (f"engine_throughput_kvq{kv_bits}", dt * 1e6, derived)


def _bench_cell_faulted(name, params, cfg, weight_note):
    """The throughput cell again, under ~2% injected faults served via
    ``supervised_serve`` — measures what fault tolerance costs: snapshot
    cadence, restore replay, and quarantined work, against the same
    one-shot baseline at the same HBM budget."""
    n_req = 6 if FAST else 16
    prompt_len, gen_max = 16, (8 if FAST else 24)
    n_slots, page_size = 4, 8
    prompts, gens, reqs = _workload(cfg, n_req, prompt_len, gen_max)
    max_seq = prompt_len + gen_max
    pages_per_slot = -(-max_seq // page_size)
    n_pages = n_slots * pages_per_slot

    def build():
        return Engine(params, cfg, n_slots=n_slots, page_size=page_size,
                      max_seq=max_seq, n_pages=n_pages,
                      token_budget=n_slots + prompt_len)

    # clean warmup run: compiles everything and measures the fault-free
    # step count the 2% fault rate is calibrated against
    clean = build()
    clean.run([Request(rid=r.rid, prompt=r.prompt,
                       max_new_tokens=r.max_new_tokens) for r in reqs])
    total_steps = clean.stats.steps
    n_faults = max(5, total_steps * 2 // 100)   # ≥1 of each kind

    def faulted_run():
        plan = FaultPlan.generate(17, horizon=max(total_steps - 4, 8),
                                  n_slots=n_slots, n_events=n_faults)
        with tempfile.TemporaryDirectory() as td:
            sup = ServeSupervisorConfig(
                snapshot_dir=td,
                snapshot_every=max(total_steps // 4, 4),
                max_restarts=2 * n_faults,
                max_steps=50 * max(total_steps, 10))
            outputs, _, report = supervised_serve(
                build, [Request(rid=r.rid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs], sup, injector=plan)
        return outputs, report

    faulted_run()                                   # warm
    _one_shot_serve(params, cfg, prompts, gens, n_slots)

    t0 = time.perf_counter()
    outputs, report = faulted_run()
    dt_e = time.perf_counter() - t0
    useful_e = sum(len(v) for v in outputs.values())
    t0 = time.perf_counter()
    useful_o = _one_shot_serve(params, cfg, prompts, gens, n_slots)
    dt_o = time.perf_counter() - t0

    s = report.final_stats
    tps_e, tps_o = useful_e / dt_e, useful_o / dt_o
    kv_tokens = n_pages * page_size
    derived = (f"tok/s={tps_e:.1f} one_shot={tps_o:.1f} "
               f"(x{tps_e / tps_o:.2f}); occupancy={s['slot_occupancy']:.2f} "
               f"page_util={s['page_utilization']:.2f} "
               f"peak={s['page_utilization_max']:.2f}; "
               f"equal-HBM: slots={n_slots} pages={n_pages}x{page_size} "
               f"({kv_tokens} KV tokens, == one-shot {n_slots}x{max_seq}); "
               f"{weight_note}; R={n_req} gen {max(gens)}/{min(gens)} skew; "
               f"faults={n_faults}/{total_steps} steps (~2%): "
               f"{report.restarts} restarts {report.kill_restores} kills "
               f"{report.snapshots} snapshots, finished "
               f"{len(outputs)}/{n_req}")
    return (name, dt_e * 1e6, derived)


def _bench_cell_long_prompt(params, cfg):
    """Blockwise-prefill scaling row: per-chunk step latency and
    per-chunk kernel VMEM across growing prompt lengths at a fixed
    ``prefill_chunk``.  Both must be ~flat in prompt length — the old
    engine re-ran the *whole* prompt through one ``jit_prefill`` at
    commit, so this row would have scaled linearly (and its peak
    activation footprint with it).  Geometry (``max_seq``, page count)
    is held at the longest prompt for every length so the per-chunk
    attend view is identical and only the prompt length varies."""
    from repro.analysis.vmem import estimate_prefill_vmem_bytes
    from repro.kernels.dispatch import prefill_token_tile

    chunk, page_size, gen = 16, 8, 2
    lens = (32, 64) if FAST else (32, 128)
    max_seq = max(lens) + gen
    pages_per_slot = -(-max_seq // page_size)

    def timed(prompt_len):
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(13), (prompt_len,), 0, cfg.vocab))
        n_chunks = -(-prompt_len // chunk)

        def drive():
            eng = Engine(params, cfg, n_slots=1, page_size=page_size,
                         max_seq=max_seq, n_pages=pages_per_slot,
                         prefill_chunk=chunk, token_budget=chunk)
            eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=gen))
            t0 = time.perf_counter()
            for _ in range(n_chunks):
                eng.step()
            dt = time.perf_counter() - t0
            assert eng.stats.prefill_calls == n_chunks, \
                (eng.stats.prefill_calls, n_chunks)
            while eng.sched.has_work():
                eng.step()
            return dt / n_chunks

        drive()                                     # warm compiles
        return drive(), n_chunks

    cells = [(s,) + timed(s) for s in lens]
    tile = prefill_token_tile("dense", cfg.head_dim)
    vmem_b = estimate_prefill_vmem_bytes("dense", cfg.head_dim, tile)
    (s0, us0, _), (s1, us1, _) = cells[0], cells[-1]
    derived = ("us/chunk " +
               " ".join(f"S={s}->{u * 1e6:.0f} ({n} chunks)"
                        for s, u, n in cells) +
               f" (x{us1 / us0:.2f} across x{s1 // s0} prompt len); "
               f"chunk={chunk} vmem/chunk={vmem_b} B (dense tile={tile}, "
               f"flat in S); budget bounds compute: no step forwards "
               f"more than {chunk} prompt tokens")
    return ("engine_prefill_long_prompt", cells[-1][1] * 1e6, derived)


def run():
    rows = []
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    row, dense_tps = _bench_cell("engine_throughput_dense", params, cfg,
                                 "weights dense f32 (4 B/weight)")
    rows.append(row)
    sp16 = None
    for k in (2, 16):
        packed = _pack(params, k)
        sp = packed.serving_params(packed=True)
        if k == 16:
            sp16 = sp
        bits = compression.bits_per_index(k)
        row, _ = _bench_cell(
            f"engine_throughput_K{k}_packed", sp, cfg,
            f"weights bit-packed K={k} ({bits / 8:g} B/weight idx)")
        rows.append(row)
    rows.append(_bench_cell_faulted(
        "engine_throughput_faulted", sp16, cfg,
        "weights bit-packed K=16 (0.5 B/weight idx)"))
    # codebook-quantized KV pages at the equal-HBM slot count each
    # width affords (vs the dense-KV 4-slot baseline)
    for kv_bits in (2, 4, 8):
        rows.append(_bench_cell_kvq(params, cfg, kv_bits, dense_tps))
    # blockwise-prefill scaling: per-chunk latency/VMEM flat in prompt len
    rows.append(_bench_cell_long_prompt(params, cfg))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
