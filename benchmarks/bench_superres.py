"""Paper §5.2 / Fig. 7: super-resolution linear regression with a
clustered, non-Gaussian weight distribution.  Exact closed-form L step ⇒
this is the controlled setting where the paper *proves* its point:

  * DC and iDC are identical to each other and stall after iteration 1;
  * LC reaches a much lower loss at K ∈ {2, 4};
  * warm-started k-means converges in ~1 iteration after the first C step
    (fig. 10's claim, measured via KMeansResult.iters_run).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LCConfig, c_step, default_qspec, finalize, lc_init,
                        make_scheme)
from repro.data.synthetic import superres_data
from repro.models.paper_nets import superres_l_step_closed_form, superres_loss


def _fit_reference(x, y):
    n, din = x.shape
    xm, ym = jnp.mean(x, 0), jnp.mean(y, 0)
    xc, yc = x - xm, y - ym
    w = jnp.linalg.solve(xc.T @ xc + 1e-6 * jnp.eye(din), xc.T @ yc).T
    b = ym - w @ xm
    return w, b


def run_case(k: int, num_iters: int = 30):
    x, y = superres_data(0, n=1000, hi_side=20, factor=2, noise=0.05)
    w_ref, b_ref = _fit_reference(x, y)
    ref_loss = float(superres_loss(w_ref, b_ref, x, y))

    params = {"w": w_ref}
    qspec = default_qspec(params)
    scheme = make_scheme(f"adaptive:{k}", init_method="kmeans++")
    key = jax.random.PRNGKey(0)

    # --- DC / iDC ---------------------------------------------------------
    cfg0 = LCConfig(mu0=0.0, mu_growth=1.0, use_lagrangian=False)
    st = lc_init(key, params, scheme, qspec, cfg0)
    dc = finalize(params, st, qspec)
    dc_loss = float(superres_loss(dc["w"], b_ref, x, y))

    idc_params, idc_st = dict(params), st
    idc_losses = []
    for _ in range(num_iters):
        # retrain exactly from the quantized point (μ = 0 → plain L step)
        w_new, b_new = superres_l_step_closed_form(
            x, y, mu=0.0, wc=idc_st.w_c["w"], lam=jnp.zeros_like(w_ref))
        idc_params = {"w": w_new}
        idc_st = c_step(idc_params, idc_st._replace(
            mu=jnp.asarray(0.0, jnp.float32)), scheme, qspec, cfg0)
        q = finalize(idc_params, idc_st, qspec)
        idc_losses.append(float(superres_loss(q["w"], b_new, x, y)))
    idc_loss = idc_losses[-1]

    # --- LC (augmented Lagrangian, closed-form L step) ---------------------
    cfg = LCConfig(mu0=10.0, mu_growth=1.1, num_lc_iters=num_iters)
    st = lc_init(key, params, scheme, qspec, cfg)
    p = params
    kmeans_iters = []
    for _ in range(num_iters):
        mu = float(st.mu)
        w_new, b_new = superres_l_step_closed_form(
            x, y, mu=mu, wc=st.w_c["w"], lam=st.lam["w"])
        p = {"w": w_new}
        st = c_step(p, st, scheme, qspec, cfg)
        kmeans_iters.append(int(st.theta["['w']"]["kmeans_iters"]))
    lc = finalize(p, st, qspec)
    lc_loss = float(superres_loss(lc["w"], b_new, x, y))

    centroids = np.asarray(np.unique(np.asarray(lc["w"])))
    return {
        "ref_loss": ref_loss, "dc_loss": dc_loss, "idc_loss": idc_loss,
        "lc_loss": lc_loss, "centroids": centroids.tolist(),
        "kmeans_iters_first": kmeans_iters[0],
        "kmeans_iters_late": kmeans_iters[-1],
        "idc_stalled": bool(abs(idc_losses[0] - idc_losses[-1])
                            < 1e-3 * abs(idc_losses[0]) + 1e-9),
    }


def run():
    rows = []
    for k in (2, 4):
        t0 = time.perf_counter()
        r = run_case(k)
        us = (time.perf_counter() - t0) * 1e6
        derived = (f"ref={r['ref_loss']:.4f} dc={r['dc_loss']:.4f} "
                   f"idc={r['idc_loss']:.4f} lc={r['lc_loss']:.4f} "
                   f"lc/dc={r['lc_loss'] / r['dc_loss']:.3f} "
                   f"idc_stalled={r['idc_stalled']} "
                   f"km_first={r['kmeans_iters_first']} "
                   f"km_late={r['kmeans_iters_late']}")
        rows.append((f"superres_fig7_K{k}", us, derived))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
