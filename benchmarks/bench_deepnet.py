"""Paper §5.4: deep conv net quantization (reduced: LeNet5-style conv net
on synthetic 28×28 data; the paper's 14M-param VGG is CPU-prohibitive).

Scale caveat, measured and reported: at this reduced width (8/16 filters)
K=2 with per-layer codebooks exceeds the net's capacity — DC lands at 29%
error and LC falls to a *worse* local optimum (the problem is NP-complete;
LC guarantees feasibility + local optimality, not global).  The paper's
14M-param net has the redundancy that makes K=2 benign.  The working
point here is K=4, where the paper's claim shows clearly: LC ~60× lower
loss than DC with zero error degradation."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LCConfig, default_qspec, make_scheme
from repro.data.synthetic import mnist_like
from repro.models.paper_nets import (classification_error, cross_entropy,
                                     lenet5_init, lenet5_logits)
from repro.train.trainer import (LCTrainer, TrainerConfig, init_train_state,
                                 make_train_step)


def run():
    from repro.core import baselines
    t0 = time.perf_counter()
    X, Y = mnist_like(0, 2048, noise=0.8)
    Ximg = X.reshape(-1, 28, 28, 1)
    params = lenet5_init(jax.random.PRNGKey(0), c1=8, c2=16, fc=64)

    def loss_fn(p, batch):
        return cross_entropy(lenet5_logits(p, batch[0]), batch[1])

    def batches():
        i = 0
        while True:
            k = jax.random.fold_in(jax.random.PRNGKey(1), i)
            idx = jax.random.randint(k, (128,), 0, Ximg.shape[0])
            yield (Ximg[idx], Y[idx])
            i += 1

    tc = TrainerConfig(lr=0.02, steps_per_l=30)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(loss_fn, tc))
    it = batches()
    for _ in range(400):
        state, m = step(state, next(it))
    ref = state.params
    ref_loss = float(loss_fn(ref, (Ximg, Y)))

    qspec = default_qspec(ref, grouped_min_ndim=5)   # conv kernels: 1 codebook
    rows = []
    for k in (2, 4):
        scheme = make_scheme(f"adaptive:{k}")
        dc, _ = baselines.direct_compression(jax.random.PRNGKey(0), ref,
                                             scheme, qspec)
        dc_loss = float(loss_fn(dc, (Ximg, Y)))
        tr = LCTrainer(loss_fn, scheme, qspec,
                       LCConfig(mu0=1e-3, mu_growth=1.25, num_lc_iters=30),
                       tc)
        st = tr.init(jax.random.PRNGKey(0), ref)
        it = batches()                      # fresh stream per K: runs are
        for _ in range(400):                # independent & reproducible
            next(it)
        st = tr.run(st, it)
        q = tr.finalize(st)
        lc_loss = float(loss_fn(q, (Ximg, Y)))
        err = float(classification_error(lenet5_logits(q, Ximg), Y))
        uniq = max(len(np.unique(np.asarray(l)))
                   for l in [q["conv0"]["w"], q["fc0"]["w"]])
        us = (time.perf_counter() - t0) * 1e6
        note = " (capacity-infeasible regime, see docstring)" if k == 2 else ""
        rows.append((f"deepnet_sec54_K{k}", us,
                     f"ref={ref_loss:.4f} dc={dc_loss:.4f} lc={lc_loss:.4f} "
                     f"err={err:.3f} max_unique={uniq}{note}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
