"""Benchmark harness — one module per paper table/figure + systems benches.

Prints ``name,us_per_call,derived`` CSV.  Select with --only <substring>;
``--json <path>`` additionally writes a machine-readable
``{name: {"us_per_call": float, "derived": str}}`` dump (e.g.
``BENCH_kernels.json``) so the perf trajectory is tracked across PRs —
CI runs ``--only kernels --json BENCH_kernels.json`` and uploads it.
"""
import argparse
import importlib
import json
import sys
import traceback

MODULES = [
    "benchmarks.bench_superres",    # §5.2 / fig. 7
    "benchmarks.bench_lenet",       # §5.3 / figs. 8-9
    "benchmarks.bench_binarize",    # table 2
    "benchmarks.bench_tradeoff",    # §5.1 / fig. 6
    "benchmarks.bench_deepnet",     # §5.4
    "benchmarks.bench_al_vs_qp",    # §5 AL-vs-QP + §4.2 fn.2 prune+quant
    "benchmarks.bench_cstep",       # systems: C-step throughput, fig. 10
    "benchmarks.bench_kernels",     # systems: kernel micro
    "benchmarks.bench_engine",      # systems: continuous-batching serving
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run modules whose name contains one of these "
                         "comma-separated substrings")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON "
                         "(name → us_per_call + derived)")
    args = ap.parse_args()

    only = args.only.split(",") if args.only else None
    if only:
        # A typo'd group used to select nothing and exit green — CI then
        # "passed" while benchmarking nothing.  Every token must match
        # at least one module.
        groups = [m.rsplit(".bench_", 1)[-1] for m in MODULES]
        bad = [t for t in only
               if not any(t and t in m for m in MODULES)]
        if bad:
            print(f"error: --only {','.join(bad)!r} matches no benchmark "
                  f"module; valid groups: {', '.join(groups)}",
                  file=sys.stderr)
            sys.exit(2)

    print("name,us_per_call,derived")
    results = {}
    failures = 0
    for modname in MODULES:
        if only and not any(tok and tok in modname for tok in only):
            continue
        try:
            mod = importlib.import_module(modname)
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
                results[name] = {"us_per_call": round(float(us), 1),
                                 "derived": str(derived)}
        except Exception:                          # noqa: BLE001
            failures += 1
            err = traceback.format_exc(limit=3)
            print(f"{modname},ERROR,{err!r}", flush=True)
            results[modname] = {"us_per_call": None, "derived": f"ERROR: {err}"}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
        print(f"# wrote {len(results)} rows to {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
