"""Benchmark harness — one module per paper table/figure + systems benches.

Prints ``name,us_per_call,derived`` CSV.  Select with --only <substring>.
"""
import argparse
import importlib
import sys
import traceback

MODULES = [
    "benchmarks.bench_superres",    # §5.2 / fig. 7
    "benchmarks.bench_lenet",       # §5.3 / figs. 8-9
    "benchmarks.bench_binarize",    # table 2
    "benchmarks.bench_tradeoff",    # §5.1 / fig. 6
    "benchmarks.bench_deepnet",     # §5.4
    "benchmarks.bench_al_vs_qp",    # §5 AL-vs-QP + §4.2 fn.2 prune+quant
    "benchmarks.bench_cstep",       # systems: C-step throughput, fig. 10
    "benchmarks.bench_kernels",     # systems: kernel micro
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run modules whose name contains this substring")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        try:
            mod = importlib.import_module(modname)
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:                          # noqa: BLE001
            failures += 1
            print(f"{modname},ERROR,{traceback.format_exc(limit=3)!r}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
