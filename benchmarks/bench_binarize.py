"""Paper Table 2: binarization — LC(adaptive K=2) vs BinaryConnect vs
fixed {-1,+1} and {-a,+a} schemes.  Claims validated:
  * LC with a learned 2-entry codebook beats BinaryConnect;
  * the learned codebook values differ per layer and are far from ±1.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import mnist_batches, train_reference
from repro.core import (LCConfig, baselines, default_qspec, make_scheme)
from repro.data.synthetic import mnist_like
from repro.models.paper_nets import (classification_error, cross_entropy,
                                     init_mlp_classifier, mlp_logits)
from repro.train.trainer import LCTrainer, TrainerConfig


def binaryconnect(loss_fn, ref, it, qspec, steps=1200, lr=0.02):
    vg = jax.jit(baselines.make_binaryconnect_grad(loss_fn, qspec))
    params = ref
    for _ in range(steps):
        loss, g = vg(params, next(it))
        params = jax.tree_util.tree_map(lambda p, gi: p - lr * gi, params, g)
        params = baselines.binaryconnect_clip(params, qspec)
    return baselines.binaryconnect_forward_params(params, qspec), float(loss)


def run():
    from repro.data.synthetic import mnist_like_split
    (X, Y), (Xt, Yt) = mnist_like_split(0, 4096, 1024, noise=1.0)
    params0 = init_mlp_classifier(jax.random.PRNGKey(0), [784, 8, 10])

    def loss_fn(p, batch):
        return cross_entropy(mlp_logits(p, batch[0]), batch[1])

    it = mnist_batches(X, Y, 256)
    ref, _ = train_reference(loss_fn, params0, it, steps=500)
    qspec = default_qspec(ref)
    err = lambda p: float(classification_error(mlp_logits(p, Xt), Yt))

    t0 = time.perf_counter()
    rows = []

    bc_params, _ = binaryconnect(loss_fn, ref, it, qspec)
    bc_loss = float(loss_fn(bc_params, (X, Y)))

    results = {"binaryconnect": (bc_loss, err(bc_params), "{-1,+1}")}
    for spec in ("adaptive:2", "binary", "binary_scale"):
        scheme = make_scheme(spec)
        tr = LCTrainer(loss_fn, scheme, qspec,
                       LCConfig(mu0=1e-3, mu_growth=1.25, num_lc_iters=30),
                       TrainerConfig(lr=0.1, steps_per_l=40))
        st = tr.init(jax.random.PRNGKey(0), ref)
        st = tr.run(st, it)
        q = tr.finalize(st)
        if spec == "adaptive:2":
            cb0 = np.asarray(st.lc_state.theta["['fc0']['w']"]["codebook"])
            cbs = np.round(cb0, 4).tolist()
        else:
            cbs = spec
        results[f"lc_{spec}"] = (float(loss_fn(q, (X, Y))), err(q), cbs)

    us = (time.perf_counter() - t0) * 1e6
    derived = " ".join(f"{k}={v[0]:.4f}/{v[1]:.3f}({v[2]})"
                       for k, v in results.items())
    rows.append(("binarize_table2", us, derived))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
