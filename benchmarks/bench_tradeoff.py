"""Paper §5.1 / Fig. 6: loss × model size × codebook size tradeoff.

Train reference nets of H ∈ {2,4,8,16} hidden units, LC-compress each at
log2 K ∈ {1,2,4}, and report the (K, H) grid of losses + model sizes
C(K,H).  Claim validated: for loose loss targets the optimal operating
point is "largest H, smallest K" (train big, compress max).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import mnist_batches, train_reference
from repro.core import (LCConfig, compression, default_qspec, make_scheme,
                        param_counts)
from repro.data.synthetic import mnist_like
from repro.models.paper_nets import (cross_entropy, init_mlp_classifier,
                                     mlp_logits)
from repro.train.trainer import LCTrainer, TrainerConfig


def run():
    X, Y = mnist_like(0, 4096, noise=1.0)

    def loss_fn(p, batch):
        return cross_entropy(mlp_logits(p, batch[0]), batch[1])

    rows = []
    t0 = time.perf_counter()
    grid = {}
    for h in (2, 4, 8, 16):
        params0 = init_mlp_classifier(jax.random.PRNGKey(h), [784, h, 10])
        it = mnist_batches(X, Y, 256, seed=h)
        ref, _ = train_reference(loss_fn, params0, it, steps=400)
        qspec = default_qspec(ref)
        p1, p0 = param_counts(ref, qspec)
        grid[(h, "inf")] = (float(loss_fn(ref, (X, Y))), (p1 + p0) * 32)
        for k in (2, 4, 16):
            scheme = make_scheme(f"adaptive:{k}")
            tr = LCTrainer(loss_fn, scheme, qspec,
                           LCConfig(mu0=1e-3, mu_growth=1.35,
                                    num_lc_iters=20),
                           TrainerConfig(lr=0.1, steps_per_l=30))
            st = tr.init(jax.random.PRNGKey(0), ref)
            st = tr.run(st, it)
            q = tr.finalize(st)
            bits = compression.quantized_bytes(p1, p0, k, 2 * k) * 8
            grid[(h, k)] = (float(loss_fn(q, (X, Y))), bits)

    # best operating point for a loose target: max compression viable?
    target = 2.0 * grid[(16, "inf")][0]
    feasible = [(bits, h, k) for (h, k), (l, bits) in grid.items()
                if l <= target]
    best = min(feasible) if feasible else None
    us = (time.perf_counter() - t0) * 1e6
    cells = " ".join(f"H{h}K{k}:{l:.4f}/{b // 8}B"
                     for (h, k), (l, b) in sorted(grid.items(),
                                                  key=lambda x: str(x)))
    rows.append(("tradeoff_fig6", us,
                 f"best_point={best} target={target:.4f} | {cells}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
