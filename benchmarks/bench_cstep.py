"""C-step systems benchmarks: throughput of the quantization path
(weights/second), paper fig. 10's warm-start iteration counts, and the
kernel-vs-jnp C-step comparison."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core.kmeans import kmeans_fit, kmeans_plus_plus_init, quantile_init
from repro.kernels import ops as kops


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    for p in (1 << 20, 1 << 23):          # 1M / 8M weights
        w = jax.random.normal(key, (p,))
        cb = quantile_init(w, 16)

        fit = jax.jit(lambda w, cb: kmeans_fit(w, cb, iters=5).codebook)
        us = time_call(fit, w, cb, warmup=1, iters=5)
        rows.append((f"cstep_kmeans5_P{p}", us,
                     f"{p / (us * 1e-6) / 1e6:.1f}Mw/s"))

        us = time_call(lambda w, cb: kops.kmeans_assign(w, cb)[1], w, cb,
                       warmup=1, iters=5)
        rows.append((f"cstep_kernel_assign_P{p}", us,
                     f"{p / (us * 1e-6) / 1e6:.1f}Mw/s (interpret mode)"))

        us = time_call(lambda w: kops.fixed_quant(w, "ternary"), w,
                       warmup=1, iters=5)
        rows.append((f"cstep_kernel_ternary_P{p}", us,
                     f"{p / (us * 1e-6) / 1e6:.1f}Mw/s (interpret mode)"))

    # fig. 10: k-means iterations — cold (k-means++) vs warm (previous C step)
    w = jax.random.normal(key, (1 << 20,))
    cold = kmeans_fit(w, kmeans_plus_plus_init(key, w, 4), iters=60)
    w2 = w + 0.003 * jax.random.normal(jax.random.fold_in(key, 1), w.shape)
    warm = kmeans_fit(w2, cold.codebook, iters=60)
    rows.append(("cstep_fig10_warmstart", 0.0,
                 f"cold_iters={int(cold.iters_run)} "
                 f"warm_iters={int(warm.iters_run)}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
