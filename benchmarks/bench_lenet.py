"""Paper §5.3 / Figs. 8-9: LeNet300-style classification, K ∈ {2,...,64},
LC vs DC vs iDC (reduced scale: capacity-tight MLP on the synthetic
MNIST-like set — same tensor shapes, CPU-sized optimization budget).

Validated paper claims:
  * large K: DC ≈ iDC ≈ LC (all close to the reference);
  * small K (1-2 bits): LC ≪ iDC ≪ DC in loss;
  * compression ratios ρ(K) follow eq. 14.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import mnist_batches, train_reference
from repro.core import (LCConfig, baselines, compression, default_qspec,
                        make_scheme, param_counts)
from repro.data.synthetic import mnist_like
from repro.models.paper_nets import (classification_error, cross_entropy,
                                     init_mlp_classifier, mlp_logits)
from repro.train.trainer import LCTrainer, TrainerConfig

HIDDEN = [784, 8, 10]        # capacity-tight (see tests/test_system.py)


def setup():
    from repro.data.synthetic import mnist_like_split
    (X, Y), (Xt, Yt) = mnist_like_split(0, 4096, 1024, noise=1.0)
    params = init_mlp_classifier(jax.random.PRNGKey(0), HIDDEN)

    def loss_fn(p, batch):
        return cross_entropy(mlp_logits(p, batch[0]), batch[1])

    it = mnist_batches(X, Y, 256)
    ref, _ = train_reference(loss_fn, params, it, steps=500)
    return X, Y, Xt, Yt, ref, loss_fn, it


def idc(loss_fn, ref, it, scheme, qspec, rounds=15, steps=40):
    """Han et al. 2015-style trained quantization: retrain → re-quantize."""
    from repro.train.trainer import init_train_state, make_train_step
    q, state = baselines.direct_compression(jax.random.PRNGKey(0), ref,
                                            scheme, qspec)
    params = q
    tc = TrainerConfig(lr=0.1, steps_per_l=steps)
    step = jax.jit(make_train_step(loss_fn, tc))
    for _ in range(rounds):
        ts = init_train_state(params, tc)
        for _ in range(steps):
            ts, _ = step(ts, next(it))
        q, state = baselines.idc_round(ts.params, state, scheme, qspec)
        params = q
    return q


def run():
    X, Y, Xt, Yt, ref, loss_fn, it = setup()
    ref_loss = float(loss_fn(ref, (X, Y)))
    ref_err = float(classification_error(mlp_logits(ref, Xt), Yt))
    qspec = default_qspec(ref)
    p1, p0 = param_counts(ref, qspec)

    rows = []
    for k in (2, 4, 16, 64):
        t0 = time.perf_counter()
        scheme = make_scheme(f"adaptive:{k}")
        dc, _ = baselines.direct_compression(jax.random.PRNGKey(0), ref,
                                             scheme, qspec)
        dc_loss = float(loss_fn(dc, (X, Y)))
        idc_q = idc(loss_fn, ref, it, scheme, qspec)
        idc_loss = float(loss_fn(idc_q, (X, Y)))
        tr = LCTrainer(loss_fn, scheme, qspec,
                       LCConfig(mu0=1e-3, mu_growth=1.25, num_lc_iters=30),
                       TrainerConfig(lr=0.1, steps_per_l=40))
        st = tr.init(jax.random.PRNGKey(0), ref)
        st = tr.run(st, it)
        lc = tr.finalize(st)
        lc_loss = float(loss_fn(lc, (X, Y)))
        lc_err = float(classification_error(mlp_logits(lc, Xt), Yt))
        rho = compression.compression_ratio(p1, p0, k, 2 * k)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"lenet_fig9_K{k}", us,
            f"rho={rho:.1f} ref={ref_loss:.4f}/{ref_err:.3f} "
            f"dc={dc_loss:.4f} idc={idc_loss:.4f} lc={lc_loss:.4f} "
            f"lc_test_err={lc_err:.3f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
