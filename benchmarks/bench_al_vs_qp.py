"""Paper §5 methodology claim: the augmented-Lagrangian LC variant is
more robust than the quadratic-penalty variant (λ ≡ 0) under the same μ
schedule — and the zero-pinned codebook (paper §4.2 footnote 2) prunes +
quantizes jointly.

Controlled setting: the §5.2 super-resolution regression with exact
closed-form L steps, K = 4."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LCConfig, c_step, default_qspec, finalize, lc_init,
                        make_scheme)
from repro.data.synthetic import superres_data
from repro.models.paper_nets import superres_l_step_closed_form, superres_loss


def _run(scheme_spec: str, use_lagrangian: bool, num_iters: int = 30):
    x, y = superres_data(0, n=1000, hi_side=20, factor=2, noise=0.05)
    n, din = x.shape
    xm, ym = jnp.mean(x, 0), jnp.mean(y, 0)
    xc, yc = x - xm, y - ym
    w_ref = jnp.linalg.solve(xc.T @ xc + 1e-6 * jnp.eye(din), xc.T @ yc).T
    b_ref = ym - w_ref @ xm

    params = {"w": w_ref}
    qspec = default_qspec(params)
    scheme = make_scheme(scheme_spec)
    cfg = LCConfig(mu0=10.0, mu_growth=1.1, num_lc_iters=num_iters,
                   use_lagrangian=use_lagrangian)
    st = lc_init(jax.random.PRNGKey(0), params, scheme, qspec, cfg)
    p = params
    b_new = b_ref
    for _ in range(num_iters):
        w_new, b_new = superres_l_step_closed_form(
            x, y, mu=float(st.mu), wc=st.w_c["w"], lam=st.lam["w"])
        p = {"w": w_new}
        st = c_step(p, st, scheme, qspec, cfg)
    q = finalize(p, st, qspec)
    loss = float(superres_loss(q["w"], b_new, x, y))
    gap = float(jnp.sqrt(jnp.mean((p["w"] - q["w"]) ** 2)))
    sparsity = float(jnp.mean((q["w"] == 0).astype(jnp.float32)))
    return loss, gap, sparsity


def run():
    rows = []
    t0 = time.perf_counter()
    al_loss, al_gap, _ = _run("adaptive:4", True)
    qp_loss, qp_gap, _ = _run("adaptive:4", False)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("al_vs_qp_superres_K4", us,
                 f"AL loss={al_loss:.4f} gap={al_gap:.2e} | "
                 f"QP loss={qp_loss:.4f} gap={qp_gap:.2e} | "
                 f"AL_better={al_loss <= qp_loss}"))

    t0 = time.perf_counter()
    z_loss, z_gap, z_sp = _run("adaptive_zero:4", True)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("prune_quant_zero_centroid_K4", us,
                 f"loss={z_loss:.4f} gap={z_gap:.2e} sparsity={z_sp:.3f} "
                 f"(paper §4.2 fn.2: joint prune+quantize)"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
