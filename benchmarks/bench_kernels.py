"""Kernel microbenchmarks: Pallas (interpret mode — correctness-grade
timing only on CPU) vs the jnp reference, plus serving-path byte
accounting (the roofline story of codebook_matmul)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels import ops, ref


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    m, kd, n, k = 256, 2048, 512, 16
    x = jax.random.normal(key, (m, kd), jnp.float32)
    idx = jax.random.randint(key, (kd, n), 0, k).astype(jnp.uint8)
    cb = jax.random.normal(key, (k,))

    us_ref = time_call(jax.jit(ref.codebook_matmul_ref), x, idx, cb,
                       warmup=2, iters=5)
    bytes_bf16 = kd * n * 2
    bytes_packed = kd * n * 4 // 8 + k * 4      # 4-bit packing for K=16
    rows.append((
        "codebook_matmul_ref_jit", us_ref,
        f"weight_bytes bf16={bytes_bf16} packed={bytes_packed} "
        f"(x{bytes_bf16 / bytes_packed:.1f} HBM reduction at decode)"))

    us_pal = time_call(lambda *a: ops.codebook_matmul(*a, bm=128, bn=128,
                                                      bk=512), x, idx, cb,
                       warmup=1, iters=2)
    rows.append(("codebook_matmul_pallas_interpret", us_pal,
                 "interpret-mode (correctness only; TPU target)"))

    p = 1 << 20
    w = jax.random.normal(key, (p,))
    cbk = jnp.sort(jax.random.normal(key, (16,)))
    us = time_call(jax.jit(lambda w, c: ref.kmeans_assign_ref(w, c)[1]),
                   w, cbk, warmup=2, iters=5)
    rows.append(("kmeans_assign_ref_jit_1M", us, f"{p/(us*1e-6)/1e6:.0f}Mw/s"))
    us = time_call(lambda w, c: ops.kmeans_assign(w, c)[1], w, cbk,
                   warmup=1, iters=2)
    rows.append(("kmeans_assign_pallas_interpret_1M", us,
                 "interpret-mode (correctness only; TPU target)"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
