"""Kernel microbenchmarks: Pallas (interpret mode — correctness-grade
timing only on CPU) vs the jnp reference, plus serving-path byte
accounting (the roofline story of codebook_matmul).

Byte accounting uses ``compression.bits_per_index(k)`` — the eq.-14 index
width — so the roofline row is correct for any K, and the packed-route
rows report the *actual* HBM bytes of the uint32 word operand
(``pidx.nbytes``), which must equal bits/8 per weight (+ codebook).
Gather rows report the *gathered traffic* per weight (one packed word
row per token on the ``pack_rows`` serving layout — bits/8; the pre-PR-4
accounting quoted resident word bytes while the jnp column-layout route
actually read 4 B/word per gathered column).  Every such row is enforced
by tests/test_bench_accounting.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import compression, kvquant
from repro.kernels import dispatch, ops, ref


def _accounting(kd: int, n: int, k: int) -> str:
    bits = compression.bits_per_index(k)
    lanes = 32 // bits
    bytes_bf16 = kd * n * 2
    # Actual pack_indices_2d word-layout bytes (not the entropy formula):
    # lane counts that don't divide 32 waste the word's top bits.
    bytes_packed = -(-kd // lanes) * n * 4 + k * 4
    return (f"weight_bytes bf16={bytes_bf16} packed={bytes_packed} "
            f"({bits}-bit; x{bytes_bf16 / bytes_packed:.1f} HBM reduction "
            f"at decode)")


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # -- roofline accounting row (prefill-ish shape, K=16) -------------------
    m, kd, n, k = 256, 2048, 512, 16
    x = jax.random.normal(key, (m, kd), jnp.float32)
    idx = jax.random.randint(key, (kd, n), 0, k).astype(jnp.uint8)
    cb = jax.random.normal(key, (k,))

    us_ref = time_call(jax.jit(ref.codebook_matmul_ref), x, idx, cb,
                       warmup=2, iters=5)
    rows.append(("codebook_matmul_ref_jit", us_ref, _accounting(kd, n, k)))

    us_pal = time_call(lambda *a: ops.codebook_matmul(*a, bm=128, bn=128,
                                                      bk=512), x, idx, cb,
                       warmup=1, iters=2)
    rows.append(("codebook_matmul_pallas_interpret", us_pal,
                 "interpret-mode (correctness only; TPU target)"))

    # -- packed vs uint8 vs ref across the serving K range -------------------
    # kd2 is a multiple of 32 so every lane count packs without a ragged
    # tail and pidx.nbytes is exactly bits/8 per weight.
    m2, kd2, n2 = 64, 1024, 256
    x2 = jax.random.normal(key, (m2, kd2), jnp.float32)
    rng = np.random.RandomState(0)
    for k in (2, 4, 16, 256):
        bits = compression.bits_per_index(k)
        idx_np = rng.randint(0, k, size=(kd2, n2))
        idx2 = jnp.asarray(idx_np.astype(np.uint8))
        pidx = jnp.asarray(compression.pack_indices_2d(idx_np, k))
        cb2 = jax.random.normal(jax.random.fold_in(key, k), (k,))
        bm, bn, bk = dispatch.packed_block_sizes(m2, kd2, n2, bits)

        us = time_call(jax.jit(ref.codebook_matmul_ref), x2, idx2, cb2,
                       warmup=2, iters=5)
        rows.append((f"codebook_matmul_ref_K{k}", us,
                     f"dense-gather oracle ({bits}-bit indices)"))

        us = time_call(lambda *a: ops.codebook_matmul(*a, bm=bm, bn=bn,
                                                      bk=bk),
                       x2, idx2, cb2, warmup=1, iters=2)
        rows.append((f"codebook_matmul_uint8_interp_K{k}", us,
                     "idx_bytes/weight=1.0 (uint8 HBM layout)"))

        us = time_call(lambda *a: ops.packed_codebook_matmul(
            *a, bm=bm, bn=bn, bk=bk), x2, pidx, cb2, warmup=1, iters=2)
        bpw = pidx.size * 4 / (kd2 * n2)
        expect = bits / 8
        flag = "" if abs(bpw - expect) < 1e-9 else " MISMATCH"
        rows.append((
            f"codebook_matmul_packed_interp_K{k}", us,
            f"idx_bytes/weight={bpw:.4f} (== bits_per_index/8 = "
            f"{expect:.4f}{flag}; +{k * 4} B codebook; "
            f"blocks bm={bm} bn={bn} bk={bk})"))

    # -- attention-projection packed route (full-model qleaf serving) --------
    # q/k/v/o-ish shape: d_model → n_heads·head_dim at a prefill batch.
    m3, kd3, n3 = 128, 512, 512
    x3 = jax.random.normal(key, (m3, kd3), jnp.float32)
    for k in (4, 16):
        bits = compression.bits_per_index(k)
        idx_np = rng.randint(0, k, size=(kd3, n3))
        pidx = jnp.asarray(compression.pack_indices_2d(idx_np, k))
        cb3 = jax.random.normal(jax.random.fold_in(key, 100 + k), (k,))
        bm, bn, bk = dispatch.packed_block_sizes(m3, kd3, n3, bits)
        us = time_call(lambda *a: ops.packed_codebook_matmul(
            *a, bm=bm, bn=bn, bk=bk), x3, pidx, cb3, warmup=1, iters=2)
        bpw = pidx.size * 4 / (kd3 * n3)
        expect = bits / 8
        flag = "" if abs(bpw - expect) < 1e-9 else " MISMATCH"
        rows.append((
            f"codebook_matmul_packed_attn_K{k}", us,
            f"idx_bytes/weight={bpw:.4f} (== bits_per_index/8 = "
            f"{expect:.4f}{flag}; +{k * 4} B codebook; qkv-proj shape "
            f"{m3}x{kd3}x{n3}; blocks bm={bm} bn={bn} bk={bk})"))

    # -- embedding dequant-on-gather (packed table, no dense [V, D]) ---------
    # The serving layout is row-packed (pack_rows): a token's lookup reads
    # its contiguous word row — ⌈D/lanes⌉·4 B per token, i.e. exactly
    # bits/8 *index bytes per gathered weight* (d4 is a multiple of 32 so
    # every lane count divides).  The pre-row-pack jnp fallback gathered
    # one full uint32 word per embedding column: 4 B/weight.
    v4, d4 = 4096, 256
    toks = jnp.asarray(rng.randint(0, v4, size=(8, 32)), jnp.int32)
    toks_m = toks[:2]              # 64 tokens: interpret-mode grid is 1/row
    for k in (2, 16, 256):
        bits = compression.bits_per_index(k)
        idx_np = rng.randint(0, k, size=(v4, d4))
        pidx_r = jnp.asarray(compression.pack_rows(idx_np, k))
        cb4 = jax.random.normal(jax.random.fold_in(key, 200 + k), (k,))
        layout = compression.PackedLayout.make(v4, d4, k, order="row")
        # Gathered HBM index bytes per gathered weight (the serve-path
        # traffic — NOT the resident word-array bytes per table weight),
        # measured from the actual packed operand's row width so a
        # pack_rows layout regression trips the MISMATCH flag.
        bpw = pidx_r.shape[1] * 4 / d4
        expect = bits / 8
        flag = "" if abs(bpw - expect) < 1e-9 else " MISMATCH"
        note = (f"idx_bytes/weight={bpw:.4f} (== bits_per_index/8 = "
                f"{expect:.4f}{flag}; +{k * 4} B codebook; "
                f"table {v4}x{d4})")

        gather = jax.jit(lambda t, w, c: dispatch.quantized_gather(
            t, w, c, layout=layout, backend="ref"))
        us = time_call(gather, toks, pidx_r, cb4, warmup=2, iters=5)
        dense_tbl = jnp.asarray(cb4)[jnp.asarray(idx_np)]
        us_d = time_call(jax.jit(lambda t, w: w[t]), toks, dense_tbl,
                         warmup=2, iters=5)
        rows.append((
            f"quantized_gather_embed_K{k}", us,
            f"{note[:-1]}; 256 tokens, jnp row-gather reference; dense "
            f"f32 gather {us_d:.1f}us / {v4 * d4 * 4} B resident)"))

        us = time_call(lambda t, w, c: ops.quantized_gather(
            t, w, c, d4), toks_m.reshape(-1), pidx_r, cb4,
            warmup=1, iters=2)
        rows.append((
            f"quantized_gather_mosaic_K{k}", us,
            f"{note[:-1]}; 64 tokens, scalar-prefetch row DMA, "
            f"interpret-mode)"))

    # -- fused transposed LM head (tied embedding; packed words stay HBM) ----
    # y[M, V] = x[M, D]·W.T over the row-packed [V, ⌈D/lanes⌉] serving
    # operand — the route that replaces dequant-then-dot for the tied head.
    m5, d5, v5 = 8, 256, 1024
    x5 = jax.random.normal(key, (m5, d5), jnp.float32)
    for k in (2, 16, 256):
        bits = compression.bits_per_index(k)
        idx_np = rng.randint(0, k, size=(v5, d5))
        pidx_r = jnp.asarray(compression.pack_rows(idx_np, k))
        cb5 = jax.random.normal(jax.random.fold_in(key, 300 + k), (k,))
        bm, bn, bk = dispatch.packed_block_sizes_t(m5, d5, v5, bits, "row")
        us = time_call(lambda *a: ops.packed_codebook_matmul_t(
            *a, v5, order="row", bm=bm, bn=bn, bk=bk), x5, pidx_r, cb5,
            warmup=1, iters=2)
        bpw = pidx_r.size * 4 / (v5 * d5)
        expect = bits / 8
        flag = "" if abs(bpw - expect) < 1e-9 else " MISMATCH"
        rows.append((
            f"codebook_matmul_packed_t_K{k}", us,
            f"idx_bytes/weight={bpw:.4f} (== bits_per_index/8 = "
            f"{expect:.4f}{flag}; +{k * 4} B codebook; LM-head shape "
            f"{m5}x{d5}x{v5}; blocks bm={bm} bn={bn} bk={bk})"))

    # -- paged-attention decode (dense + codebook-quantized KV pages) --------
    # The KV B/token note is measured from the materialized pool arrays
    # (word bytes per cached token per tensor — codebooks amortize per
    # page and are quoted separately), and must equal kv_bits/8 ·
    # head_dim · n_kv — the eq.-14 activation accounting
    # tests/test_bench_accounting.py enforces on every such row.  Dense
    # rows report the same identity at kv_bits=32 (4 B/scalar).  head_dim
    # is a multiple of every lane count so rows pack with no ragged tail;
    # token tiles come from dispatch._PAGED_BLOCK_TABLE (the committed
    # winners this bench measures).
    def _kv_note(actual_bpt, bits_eff, hd, nkv, page, tile, cb_b):
        expect = bits_eff / 8 * hd * nkv
        flag = "" if abs(actual_bpt - expect) < 1e-9 else " MISMATCH"
        return (f"kv_bytes/token={actual_bpt:g} (== kv_bits/8*head_dim*"
                f"n_kv = {expect:g}{flag}; kv_bits={bits_eff} "
                f"head_dim={hd} n_kv={nkv}; +{cb_b} B codebook/page; "
                f"page={page} tile={tile})")

    bq, hq, kvh, hd6, page6, npg6 = 4, 4, 2, 32, 8, 3
    pp1 = bq * npg6 + 1                         # pool pages incl. trash
    kp = jax.random.normal(jax.random.fold_in(key, 400),
                           (pp1, page6, kvh, hd6), jnp.float32)
    vp = jax.random.normal(jax.random.fold_in(key, 401), kp.shape)
    q6 = jax.random.normal(jax.random.fold_in(key, 402),
                           (bq, 1, hq, hd6), jnp.float32)
    tbl6 = jnp.asarray(rng.permutation(np.arange(1, pp1)
                                       ).reshape(bq, npg6), jnp.int32)
    pos6 = jnp.asarray([20, 13, 7, 2], jnp.int32)
    alive6 = jnp.asarray([True, True, True, False])
    scale6 = hd6 ** -0.5

    tile = dispatch.paged_token_tile("gqa", kvh * hd6, page6, 0)
    note = _kv_note(kvh * hd6 * 4, 32, hd6, kvh, page6, tile, 0)
    us = time_call(jax.jit(lambda *a: ref.paged_attention_ref(
        *a, softcap=None, scale=scale6)), q6, kp, vp, tbl6, pos6, alive6,
        warmup=2, iters=5)
    rows.append(("paged_attention_gqa_ref_dense", us,
                 f"{note}; jnp gather+softmax oracle"))
    us = time_call(lambda *a: ops.paged_attention(
        *a, softcap=None, scale=scale6, token_tile=tile, interpret=True),
        q6, kp, vp, tbl6, pos6, alive6, warmup=1, iters=2)
    rows.append(("paged_attention_gqa_interp_dense", us,
                 f"{note}; scalar-prefetch fused kernel, interpret-mode"))

    def _quantize_pool(pool, bits):
        grp = pool.reshape(pool.shape[0], 1, -1)
        cb = kvquant.fit_codebooks(grp, bits)
        idx = kvquant.assign_codebook(grp, cb).reshape(pool.shape)
        return kvquant.pack_rows_jnp(idx, bits), cb

    for bits in (2, 4, 8):
        kw, kcb = _quantize_pool(kp, bits)
        vw, vcb = _quantize_pool(vp, bits)
        tile = dispatch.paged_token_tile("gqa", kvh * hd6, page6, bits)
        bpt = kw[0].nbytes / page6               # words/token/tensor
        cb_b = kcb[0].nbytes
        note = _kv_note(bpt, bits, hd6, kvh, page6, tile, cb_b)
        if bits == 4:
            us = time_call(jax.jit(lambda *a: ref.paged_attention_quant_ref(
                *a, bits=4, head_dim=hd6, softcap=None, scale=scale6)),
                q6, kw, vw, kcb, vcb, tbl6, pos6, alive6,
                warmup=2, iters=5)
            rows.append(("paged_attention_gqa_ref_kvq4", us,
                         f"{note}; dequant-pages oracle"))
        us = time_call(lambda *a: ops.paged_attention_quant(
            *a, bits=bits, head_dim=hd6, softcap=None, scale=scale6,
            token_tile=tile, interpret=True),
            q6, kw, vw, kcb, vcb, tbl6, pos6, alive6, warmup=1, iters=2)
        rows.append((f"paged_attention_gqa_interp_kvq{bits}", us,
                     f"{note}; in-kernel shift+mask dequant, "
                     f"interpret-mode"))

    # absorbed-MLA latent pages: one "head" of kv_lora + rope_dim feats
    lat7, rd7 = 32, 16
    cp = jax.random.normal(jax.random.fold_in(key, 410),
                           (pp1, page6, lat7), jnp.float32)
    rp = jax.random.normal(jax.random.fold_in(key, 411),
                           (pp1, page6, rd7), jnp.float32)
    qe = jax.random.normal(jax.random.fold_in(key, 412),
                           (bq, 1, hq, lat7), jnp.float32)
    qr = jax.random.normal(jax.random.fold_in(key, 413),
                           (bq, 1, hq, rd7), jnp.float32)
    scale7 = (lat7 + rd7) ** -0.5
    tile = dispatch.paged_token_tile("mla", lat7 + rd7, page6, 0)
    note = _kv_note((lat7 + rd7) * 4, 32, lat7 + rd7, 1, page6, tile, 0)
    us = time_call(lambda *a: ops.mla_paged_attention(
        *a, scale=scale7, token_tile=tile, interpret=True),
        qe, qr, cp, rp, tbl6, pos6, alive6, warmup=1, iters=2)
    rows.append(("paged_attention_mla_interp_dense", us,
                 f"{note}; latent pages, interpret-mode"))

    cw, ccb = _quantize_pool(cp, 4)
    rw, rcb = _quantize_pool(rp, 4)
    tile = dispatch.paged_token_tile("mla", lat7 + rd7, page6, 4)
    bpt = (cw[0].nbytes + rw[0].nbytes) / page6
    note = _kv_note(bpt, 4, lat7 + rd7, 1, page6, tile,
                    ccb[0].nbytes + rcb[0].nbytes)
    us = time_call(lambda *a: ops.mla_paged_attention_quant(
        *a, bits=4, kv_lora=lat7, rope_dim=rd7, scale=scale7,
        token_tile=tile, interpret=True),
        qe, qr, cw, rw, ccb, rcb, tbl6, pos6, alive6, warmup=1, iters=2)
    rows.append(("paged_attention_mla_interp_kvq4", us,
                 f"{note}; quantized latent pages, interpret-mode"))

    # standalone page gather (the non-fused slot view)
    us = time_call(jax.jit(ref.gather_pages_ref), kp, tbl6, alive6,
                   warmup=2, iters=5)
    rows.append(("page_gather_ref_dense", us,
                 f"alive-masked table gather; pool {pp1}x{page6}x"
                 f"{kvh}x{hd6}"))
    us = time_call(lambda *a: ops.page_gather(*a, interpret=True),
                   kp, tbl6, alive6, warmup=1, iters=2)
    rows.append(("page_gather_interp_dense", us,
                 "scalar-prefetch page DMA, interpret-mode"))

    # -- kmeans assign -------------------------------------------------------
    p = 1 << 20
    w = jax.random.normal(key, (p,))
    cbk = jnp.sort(jax.random.normal(key, (16,)))
    us = time_call(jax.jit(lambda w, c: ref.kmeans_assign_ref(w, c)[1]),
                   w, cbk, warmup=2, iters=5)
    rows.append(("kmeans_assign_ref_jit_1M", us, f"{p/(us*1e-6)/1e6:.0f}Mw/s"))
    us = time_call(lambda w, c: ops.kmeans_assign(w, c)[1], w, cbk,
                   warmup=1, iters=2)
    rows.append(("kmeans_assign_pallas_interpret_1M", us,
                 "interpret-mode (correctness only; TPU target)"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(map(str, row)))
