"""Shared benchmark utilities: timing, CSV rows, tiny training loops."""
from __future__ import annotations

import os
import time
from typing import Callable, Iterator, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]      # (name, us_per_call, derived)


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in µs (blocks on jax outputs).

    ``REPRO_BENCH_FAST=1`` collapses to warmup=0/iters=1 — the timings
    become meaningless but every row's *derived* accounting string is
    still produced, which is what the byte-accounting invariant test
    (tests/test_bench_accounting.py) consumes.
    """
    if os.environ.get("REPRO_BENCH_FAST"):
        warmup, iters = 0, 1
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def mnist_batches(X, Y, batch: int, seed: int = 1) -> Iterator:
    i = 0
    while True:
        k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        idx = jax.random.randint(k, (batch,), 0, X.shape[0])
        yield (X[idx], Y[idx])
        i += 1


def train_reference(loss_fn, params, batches, steps: int, lr: float = 0.1):
    from repro.train.trainer import (TrainerConfig, init_train_state,
                                     make_train_step)
    tc = TrainerConfig(lr=lr, steps_per_l=40)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(loss_fn, tc))
    for _ in range(steps):
        state, m = step(state, next(batches))
    return state.params, float(m["loss"])
