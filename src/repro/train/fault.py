"""Fault tolerance: supervised step loop with checkpoint/restart.

On a real cluster the failure signals are coordinator heartbeats /
preemption notices; in this container we exercise the identical control
flow with injected failures, which is what the restart logic actually has
to survive:

* ``FailureInjector`` raises ``SimulatedNodeFailure`` at configured steps
  (tests also inject at *checkpoint-write* time to verify atomicity);
* ``supervised_run`` catches failures, restores the last checkpoint
  (params/opt/LC state + data cursor) and resumes, with bounded restarts
  and exponential backoff;
* ``PreemptionSignal`` triggers a save-and-exit (SIGTERM-style handling).

Straggler mitigation is structural (DESIGN §9): prefetch depth ≥ 2,
C step fused into the jitted program, pod-axis gradient compression.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Set

from repro.train import checkpoint as ckpt


class SimulatedNodeFailure(RuntimeError):
    pass


class PreemptionSignal(Exception):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: Set[int] = dataclasses.field(default_factory=set)
    preempt_at: Optional[int] = None
    _fired: Set[int] = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedNodeFailure(f"injected failure at step {step}")
        if self.preempt_at is not None and step == self.preempt_at:
            self.preempt_at = None
            raise PreemptionSignal(f"preempted at step {step}")


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    max_restarts: int = 5
    backoff_s: float = 0.0            # 0 in tests; seconds on real clusters
    keep: int = 3


def supervised_run(
    *,
    state: Any,
    make_batches: Callable[[int], Iterator],   # start_step → batch iterator
    step_fn: Callable[[Any, Any], Any],        # (state, batch) → (state, metrics)
    num_steps: int,
    cfg: SupervisorConfig,
    injector: Optional[FailureInjector] = None,
    extra_state: Optional[Dict] = None,
) -> Any:
    """Run ``num_steps`` with checkpoint/restart supervision.

    ``state`` must be a pytree (TrainState works).  The data iterator is
    re-created from the restored step so the stream resumes exactly.
    Returns the final state.
    """
    restarts = 0
    step = int(getattr(state, "step", 0))
    start_state = state

    while True:
        try:
            batches = make_batches(step)
            while step < num_steps:
                if injector is not None:
                    injector.check(step)
                state, metrics = step_fn(state, next(batches))
                step += 1
                if step % cfg.ckpt_every == 0 or step == num_steps:
                    ckpt.save_checkpoint(
                        cfg.ckpt_dir, step, state,
                        extra={"data_step": step, **(extra_state or {})},
                        keep=cfg.keep)
            return state

        except PreemptionSignal:
            ckpt.save_checkpoint(cfg.ckpt_dir, step, state,
                                 extra={"data_step": step,
                                        **(extra_state or {})},
                                 keep=cfg.keep)
            raise

        except SimulatedNodeFailure:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            if cfg.backoff_s:
                time.sleep(min(cfg.backoff_s * 2 ** (restarts - 1), 60.0))
            last = ckpt.latest_step(cfg.ckpt_dir)
            if last is None:
                # no checkpoint yet — restart from scratch
                state, step = start_state, int(getattr(start_state, "step", 0))
                continue
            state, extra, step = ckpt.restore_checkpoint(
                cfg.ckpt_dir, like=state)
            step = int(extra.get("data_step", step))
