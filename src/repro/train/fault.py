"""Training-side fault tolerance: supervised step loop with
checkpoint/restart.

The supervisor primitives (:class:`SimulatedNodeFailure`,
:class:`PreemptionSignal`, :class:`FailureInjector`, backoff) are shared
with the serving engine and live in :mod:`repro.fault`; this module owns
the *training* recovery loop:

* ``supervised_run`` catches failures, restores the last checkpoint
  (params/opt/LC state + data cursor) and resumes, with bounded restarts
  and exponential backoff;
* ``PreemptionSignal`` triggers a save-and-exit (SIGTERM-style handling).

The serving analogue — engine snapshot/restore with typed request
outcomes — is ``repro.engine.snapshot.supervised_serve``.

Straggler mitigation is structural (DESIGN §9): prefetch depth ≥ 2,
C step fused into the jitted program, pod-axis gradient compression.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

from repro.fault import (FailureInjector, PreemptionSignal,  # noqa: F401
                         SimulatedNodeFailure, backoff_delay)
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    max_restarts: int = 5
    backoff_s: float = 0.0            # 0 in tests; seconds on real clusters
    keep: int = 3


def supervised_run(
    *,
    state: Any,
    make_batches: Callable[[int], Iterator],   # start_step → batch iterator
    step_fn: Callable[[Any, Any], Any],        # (state, batch) → (state, metrics)
    num_steps: int,
    cfg: SupervisorConfig,
    injector: Optional[FailureInjector] = None,
    extra_state: Optional[Dict] = None,
) -> Any:
    """Run ``num_steps`` with checkpoint/restart supervision.

    ``state`` must be a pytree (TrainState works).  The data iterator is
    re-created from the restored step so the stream resumes exactly.
    Returns the final state.
    """
    restarts = 0
    step = int(getattr(state, "step", 0))
    start_state = state

    while True:
        try:
            batches = make_batches(step)
            while step < num_steps:
                if injector is not None:
                    injector.check(step)
                state, metrics = step_fn(state, next(batches))
                step += 1
                if step % cfg.ckpt_every == 0 or step == num_steps:
                    ckpt.save_checkpoint(
                        cfg.ckpt_dir, step, state,
                        extra={"data_step": step, **(extra_state or {})},
                        keep=cfg.keep)
            return state

        except PreemptionSignal:
            ckpt.save_checkpoint(cfg.ckpt_dir, step, state,
                                 extra={"data_step": step,
                                        **(extra_state or {})},
                                 keep=cfg.keep)
            raise

        except SimulatedNodeFailure:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            delay = backoff_delay(restarts, cfg.backoff_s)
            if delay:
                time.sleep(delay)
            last = ckpt.latest_step(cfg.ckpt_dir)
            if last is None:
                # no checkpoint yet — restart from scratch
                state, step = start_state, int(getattr(start_state, "step", 0))
                continue
            state, extra, step = ckpt.restore_checkpoint(
                cfg.ckpt_dir, like=state)
            step = int(extra.get("data_step", step))
