"""Training runtime: the L step as ordinary (distributed) training.

``make_train_step`` builds a jittable step:
    grads = ∇L(w)  (+ LC penalty gradient μ(w - w_C) - λ, elementwise)
    w ← optimizer(w, grads, lr)         lr = min(η_t, 1/μ)  (clipped rule)

``LCTrainer`` owns the outer LC loop: run `steps_per_l` train steps (the
L step, eq. 4), then the C step (eq. 5) + multiplier/μ update — matching
the paper's pseudocode (figs. 2-4) with warm-started k-means.  The C step
is also jitted; both steps carry the same shardings, so under pjit the
whole LC iteration runs without host round-trips beyond the loop itself.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lc as lc_mod
from repro.core.schemes import Scheme, as_scheme
from repro.optim import schedules as sched
from repro.optim import sgd as opt_mod

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt_state: Any
    lc_state: Optional[lc_mod.LCState]
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    optimizer: str = "sgd"            # sgd | adamw
    lr: float = 0.05
    momentum: float = 0.95
    nesterov: bool = True
    weight_decay: float = 0.0
    grad_clip: Optional[float] = None
    steps_per_l: int = 200            # SGD steps per L step
    schedule: str = "constant"        # constant | exponential | cosine | wsd
    schedule_kwargs: tuple = ()
    total_steps: int = 10000


def _base_schedule(tc: TrainerConfig):
    kw = dict(tc.schedule_kwargs)
    if tc.schedule == "constant":
        return sched.constant(tc.lr)
    if tc.schedule == "exponential":
        return sched.exponential(tc.lr, kw.get("decay", 0.99),
                                 kw.get("steps_per_decay", tc.steps_per_l))
    if tc.schedule == "cosine":
        return sched.cosine(tc.lr, tc.total_steps, kw.get("warmup", 0))
    if tc.schedule == "wsd":
        return sched.wsd(tc.lr, tc.total_steps)
    raise ValueError(tc.schedule)


def _global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.vdot(g, g).real
                        for g in jax.tree_util.tree_leaves(tree)))


def make_train_step(
    loss_fn: Callable[[PyTree, Any], jax.Array],
    tc: TrainerConfig,
    qspec: Optional[PyTree] = None,
) -> Callable[[TrainState, Any], Tuple[TrainState, Dict[str, jax.Array]]]:
    """Build the jittable train step (the inner loop of the L step).

    ``loss_fn(params, batch) -> scalar``.  When the state carries an
    LCState, the penalty gradient is added (zero communication: it is
    elementwise on the weight shards).
    """
    base = _base_schedule(tc)
    clipped = sched.lc_clip(base)

    def step_fn(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        metrics = {"loss": loss}

        if state.lc_state is not None:
            pg = lc_mod.penalty_grad(state.params, state.lc_state, qspec)
            grads = jax.tree_util.tree_map(jnp.add, grads, pg)
            lr = clipped(state.step, state.lc_state.mu)
            metrics["mu"] = state.lc_state.mu
        else:
            lr = base(state.step)
        metrics["lr"] = lr

        if tc.grad_clip is not None:
            gn = _global_norm(grads)
            scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gn, 1e-12))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
            metrics["grad_norm"] = gn

        if tc.optimizer == "sgd":
            params, opt_state = opt_mod.sgd_update(
                state.params, grads, state.opt_state, lr,
                momentum=tc.momentum, nesterov=tc.nesterov,
                weight_decay=tc.weight_decay)
        else:
            params, opt_state = opt_mod.adamw_update(
                state.params, grads, state.opt_state, lr,
                weight_decay=tc.weight_decay)

        return TrainState(params, opt_state, state.lc_state,
                          state.step + 1), metrics

    return step_fn


def init_train_state(params: PyTree, tc: TrainerConfig,
                     lc_state: Optional[lc_mod.LCState] = None) -> TrainState:
    opt_state = (opt_mod.sgd_init(params) if tc.optimizer == "sgd"
                 else opt_mod.adamw_init(params))
    return TrainState(params=params, opt_state=opt_state, lc_state=lc_state,
                      step=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# LC outer loop (host-side driver)
# ---------------------------------------------------------------------------

class LCTrainer:
    """Paper figs. 2-4: alternate L steps (SGD epochs) with C steps.

    ``sharded_c=True`` + a ``mesh`` routes the C step through
    ``repro.dist.cstep.lc_c_step_sharded`` (shard_map over ``shard_axis``)
    so production LC solves Π(w) where the weight shards live — the plan
    flag ``CompressionPlan(sharded_c_step=True)`` sets this through
    :meth:`from_plan`.
    """

    def __init__(self, loss_fn, scheme: Scheme, qspec, lc_cfg: lc_mod.LCConfig,
                 tc: TrainerConfig, jit: bool = True, mesh=None,
                 shard_axis: str = "model", sharded_c: bool = False):
        scheme = as_scheme(scheme)                   # accept a plan too
        self.loss_fn = loss_fn
        self.scheme = scheme
        self.qspec = qspec
        self.lc_cfg = lc_cfg
        self.tc = tc
        self._train_step = make_train_step(loss_fn, tc, qspec)
        if sharded_c:
            if mesh is None:
                raise ValueError("sharded_c requires a mesh (pass mesh= "
                                 "to LCTrainer / from_plan)")
            from repro.dist.cstep import lc_c_step_sharded
            self._c_step = functools.partial(
                lc_c_step_sharded, scheme=scheme, qspec=qspec,
                config=lc_cfg, mesh=mesh, axis=shard_axis)
        else:
            self._c_step = functools.partial(
                lc_mod.c_step, scheme=scheme, qspec=qspec, config=lc_cfg)
        if jit:
            self._train_step = jax.jit(self._train_step)
            self._c_step = jax.jit(self._c_step,
                                   static_argnames=("advance_mu",))

    @classmethod
    def from_plan(cls, loss_fn, plan, params, tc: TrainerConfig,
                  jit: bool = True, mesh=None,
                  shard_axis: str = "model") -> "LCTrainer":
        """Build a trainer straight from a CompressionPlan: the plan's
        qspec policy is applied to ``params``, its scheme and LC config
        drive the L/C alternation; ``plan.sharded_c_step`` + ``mesh``
        enable the shard-local C step."""
        return cls(loss_fn, plan.scheme, plan.build_qspec(params), plan.lc,
                   tc, jit=jit, mesh=mesh, shard_axis=shard_axis,
                   sharded_c=getattr(plan, "sharded_c_step", False))

    def init(self, key, params) -> TrainState:
        lc_state = lc_mod.lc_init(key, params, self.scheme, self.qspec,
                                  self.lc_cfg)
        return init_train_state(params, self.tc, lc_state)

    def run(self, state: TrainState, batches, log_every: int = 0,
            callback: Optional[Callable] = None) -> TrainState:
        """Full LC optimization: num_lc_iters × (L step; C step)."""
        for j in range(self.lc_cfg.num_lc_iters):
            for inner in range(max(1, self.lc_cfg.inner_alternations)):
                for _ in range(self.tc.steps_per_l):
                    state, metrics = self._train_step(state, next(batches))
                advance = inner == self.lc_cfg.inner_alternations - 1
                new_lc = self._c_step(state.params, state.lc_state,
                                      advance_mu=advance)
                state = state._replace(lc_state=new_lc)
            gap = lc_mod.feasibility_gap(state.params, state.lc_state,
                                         self.qspec)
            if callback is not None:
                callback(j, state, float(metrics["loss"]), float(gap))
            if log_every and j % log_every == 0:
                print(f"[LC {j:03d}] loss={float(metrics['loss']):.5f} "
                      f"mu={float(state.lc_state.mu):.4g} gap={float(gap):.3e}")
            if float(gap) < self.lc_cfg.tol:
                break
        return state

    def finalize(self, state: TrainState) -> PyTree:
        return lc_mod.finalize(state.params, state.lc_state, self.qspec)
