"""Checkpointing: atomic, step-numbered, elastic-reshard-on-load.

Layout::

    <dir>/step_000123.tmp/    → written fully, then os.rename →
    <dir>/step_000123/
        manifest.msgpack      # treedef, shapes/dtypes, LC μ/iter, pipeline
        arrays.npz            # flat leaves, logically-global values
    <dir>/LATEST              # written last (atomic pointer)

Design points for 1000+ nodes (DESIGN §9):
* atomic rename + LATEST-last ordering ⇒ a crash mid-write never corrupts
  the restore path;
* arrays are saved *logically global* (fully addressable here; on real
  multi-host this is a `jax.experimental.multihost_utils` gather or an
  Orbax-style per-shard layout — interface kept identical);
* restore re-shards to the **current** mesh (elastic rescale: save on N
  devices, resume on M);
* LC state (μ, λ, codebooks) is part of the checkpoint — restarting
  without it would silently degrade the augmented Lagrangian to the
  quadratic-penalty method;
* the data-pipeline cursor rides along, so the token stream resumes
  exactly.
"""
from __future__ import annotations

import contextlib
import hashlib
import io
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any


def file_sha256(path: str) -> str:
    """Streaming SHA-256 of a file (integrity gate for checkpoint and
    engine-snapshot artifacts)."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


@contextlib.contextmanager
def atomic_dir(final: str):
    """Write a directory atomically: yields ``<final>.tmp`` to fill,
    then os.rename's it over ``final`` (atomic on POSIX) — a crash
    mid-write never leaves a half-written directory at ``final``.
    Shared by training checkpoints and engine snapshots."""
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    yield tmp
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


def write_pointer(directory: str, pointer: str, value: str):
    """Atomically update ``<directory>/<pointer>`` to ``value`` (written
    last, after the data it names — the restore path never sees a
    pointer to a half-written artifact)."""
    tmp = os.path.join(directory, pointer + ".tmp")
    with open(tmp, "w") as f:
        f.write(value)
    os.replace(tmp, os.path.join(directory, pointer))


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Atomically persist ``tree`` (+ JSON-serializable ``extra``)."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(directory, name)

    with atomic_dir(final) as tmp:
        flat, treedef = _flatten_with_paths(tree)
        arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(flat)}
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **arrays)

        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(flat),
            "dtypes": [str(np.asarray(x).dtype) for x in flat],
            "shapes": [list(np.asarray(x).shape) for x in flat],
            "sha256": file_sha256(npz_path),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))

    write_pointer(directory, "LATEST", name)
    _gc_old(directory, keep)
    return final


def _gc_old(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip().split("_")[1])


def restore_checkpoint(directory: str, like: PyTree,
                       step: Optional[int] = None,
                       shardings: Optional[PyTree] = None
                       ) -> Tuple[PyTree, Dict, int]:
    """Restore into the structure of ``like``; optionally placing each leaf
    with ``shardings`` (elastic re-shard: the saved arrays are logically
    global, so any current mesh works).

    Returns (tree, extra, step).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    npz_path = os.path.join(path, "arrays.npz")
    if file_sha256(npz_path) != manifest["sha256"]:
        raise IOError(f"checkpoint {path} failed integrity check")

    data = np.load(npz_path)
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(flat_like) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(flat_like)} — structure mismatch")
    flat = [data[f"leaf_{i}"] for i in range(len(flat_like))]

    if shardings is not None:
        flat_sh = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        flat = [jax.device_put(x, s) for x, s in zip(flat, flat_sh)]
    else:
        flat = [jnp.asarray(x) for x in flat]

    return treedef.unflatten(flat), manifest["extra"], step
