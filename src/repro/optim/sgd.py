"""Optimizers: SGD(+momentum/Nesterov) and AdamW — tiny optax-free
implementations (pure pytrees, pjit-shardable like params).

``init → (update, state)`` convention; ``update`` returns (new_params,
new_state).  Learning rate is passed per-step (schedules live in
repro/optim/schedules.py so the LC clipped-LR rule can wrap any of them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class SGDState(NamedTuple):
    momentum: PyTree
    step: jax.Array


def sgd_init(params: PyTree) -> SGDState:
    return SGDState(momentum=jax.tree_util.tree_map(jnp.zeros_like, params),
                    step=jnp.zeros((), jnp.int32))


def sgd_update(params: PyTree, grads: PyTree, state: SGDState, lr,
               momentum: float = 0.9, nesterov: bool = True,
               weight_decay: float = 0.0) -> Tuple[PyTree, SGDState]:
    tm = jax.tree_util.tree_map
    if weight_decay:
        grads = tm(lambda g, p: g + weight_decay * p, grads, params)
    new_m = tm(lambda m, g: momentum * m + g, state.momentum, grads)
    if nesterov:
        new_p = tm(lambda p, g, m: p - lr * (g + momentum * m),
                   params, grads, new_m)
    else:
        new_p = tm(lambda p, m: p - lr * m, params, new_m)
    return new_p, SGDState(momentum=new_m, step=state.step + 1)


class AdamWState(NamedTuple):
    m: PyTree
    v: PyTree
    step: jax.Array


def adamw_init(params: PyTree) -> AdamWState:
    z = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamWState(m=z(), v=z(), step=jnp.zeros((), jnp.int32))


def adamw_update(params: PyTree, grads: PyTree, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0) -> Tuple[PyTree, AdamWState]:
    tm = jax.tree_util.tree_map
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = tm(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = tm(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)
    new_p = tm(
        lambda p, m, v: p - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                                  + weight_decay * p),
        params, new_m, new_v)
    return new_p, AdamWState(m=new_m, v=new_v, step=step)
