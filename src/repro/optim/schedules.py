"""LR schedules, including the paper's clipped LC rule and MiniCPM's WSD.

The LC clipped schedule (paper §3.3): η′_t = min(η_t, 1/μ).  As μ grows
the permissible step shrinks, which keeps the L step stable against the
μ(w - w_C) penalty gradient (our core smoke study reproduced the
divergence without it).  ``lc_clip`` wraps *any* base schedule.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[..., jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential(lr0: float, decay: float, steps_per_decay: int) -> Schedule:
    """Paper §5.3 style: α · γ^j with j advanced every ``steps_per_decay``."""
    def f(step):
        j = jnp.asarray(step) // steps_per_decay
        return jnp.asarray(lr0, jnp.float32) * decay ** j.astype(jnp.float32)
    return f


def cosine(lr0: float, total_steps: int, warmup: int = 0,
           final_frac: float = 0.1) -> Schedule:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr0 * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = final_frac * lr0 + (1 - final_frac) * lr0 * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return f


def wsd(lr0: float, total_steps: int, warmup_frac: float = 0.01,
        decay_frac: float = 0.1) -> Schedule:
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395)."""
    warm = max(1, int(total_steps * warmup_frac))
    decay_start = int(total_steps * (1 - decay_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        w = lr0 * step / warm
        d = lr0 * 0.5 ** ((step - decay_start) /
                          jnp.maximum(total_steps - decay_start, 1) * 6.0)
        return jnp.where(step < warm, w,
                         jnp.where(step < decay_start, lr0, d))
    return f


def lc_clip(base: Schedule) -> Callable:
    """η′_t = min(η_t, 1/μ) — the paper's clipped LC schedule (§3.3)."""
    def f(step, mu):
        return jnp.minimum(base(step), 1.0 / jnp.maximum(mu, 1e-30))
    return f
