"""Architecture registry: ``get_config(arch_id)`` / ``reduce_config(cfg)``.

Full configs are exercised only by the dry-run (ShapeDtypeStructs, no
allocation); smoke tests instantiate the reduced versions on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.transformer import (
    MLASpec, ModelConfig, MoESpec, RGLRUSpec, SSMSpec, StackSpec)

from repro.configs import (          # noqa: E402
    deepseek_v2_lite_16b,
    gemma2_9b,
    granite_moe_1b_a400m,
    internvl2_26b,
    mamba2_1p3b,
    minicpm_2b,
    musicgen_large,
    nemotron_4_340b,
    qwen1p5_0p5b,
    recurrentgemma_2b,
)

_REGISTRY = {
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b.config,
    "granite-moe-1b-a400m": granite_moe_1b_a400m.config,
    "internvl2-26b": internvl2_26b.config,
    "mamba2-1.3b": mamba2_1p3b.config,
    "nemotron-4-340b": nemotron_4_340b.config,
    "qwen1.5-0.5b": qwen1p5_0p5b.config,
    "gemma2-9b": gemma2_9b.config,
    "minicpm-2b": minicpm_2b.config,
    "musicgen-large": musicgen_large.config,
    "recurrentgemma-2b": recurrentgemma_2b.config,
}


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; choose from {list_archs()}")
    return _REGISTRY[arch]()


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Same-family tiny config for CPU smoke tests: small width, few
    groups, tiny vocab — the *structure* (patterns, mixer kinds, MoE/MLA/
    SSM machinery) is preserved exactly."""
    heads = 4
    kv = min(cfg.n_kv, heads) if cfg.n_kv < cfg.n_heads else heads
    kv = max(1, kv if cfg.n_kv > 1 else 1)
    upd: Dict = dict(
        d_model=64,
        n_heads=heads,
        n_kv=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=512,
        stacks=tuple(
            dataclasses.replace(s, groups=min(s.groups, 2))
            for s in cfg.stacks),
        q_chunk=32,
        kv_chunk=32,
        remat=False,
    )
    if cfg.window is not None:
        upd["window"] = 64
    if cfg.emb_scale is not None:
        upd["emb_scale"] = 8.0
    if cfg.query_scale is not None:
        upd["query_scale"] = 16.0 ** -0.5
    if cfg.moe is not None:
        # capacity_factor ≥ E/top_k ⇒ per-row capacity ≥ S: no token drops,
        # so teacher-forced decode matches the full forward exactly
        # (capacity dropping is a train-time approximation; serving uses
        # drop-free capacity)
        upd["moe"] = MoESpec(n_experts=4, top_k=2,
                             n_shared=min(1, cfg.moe.n_shared),
                             d_ff_expert=32, capacity_factor=4.0)
    if cfg.mla is not None:
        upd["mla"] = MLASpec(kv_lora=32, rope_dim=8, nope_dim=16, v_dim=16)
    if cfg.ssm is not None:
        upd["ssm"] = SSMSpec(d_inner=128, head_p=16, state_n=16, conv_w=4,
                             chunk=16)
    if cfg.rglru is not None:
        upd["rglru"] = RGLRUSpec(width=64, conv_w=4)
    if cfg.vlm_patches:
        upd["vlm_patches"] = 8
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **upd)
