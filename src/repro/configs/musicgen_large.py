"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192,
decoder-only over EnCodec tokens, vocab=2048, sinusoidal positions,
non-gated GELU MLP.  [arXiv:2306.05284]
The EnCodec frontend is a STUB — inputs are token ids over the EnCodec
codebook (the backbone's native interface per the assignment).
"""
from repro.models.transformer import LayerKind, ModelConfig, uniform_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        d_model=2048,
        n_heads=32,
        n_kv=32,
        head_dim=64,
        d_ff=8192,
        vocab=2048,
        stacks=uniform_stack(LayerKind("gqa", "dense"), 48),
        mlp_act="gelu",
        gated_mlp=False,
        pos_embed="sinusoidal",
    )
