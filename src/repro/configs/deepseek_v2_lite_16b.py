"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 2 shared / 64 routed
top-6 MoE.  27L d_model=2048 16H d_ff_expert=1408 vocab=102400.
[arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite]
First layer uses a dense FFN (intermediate 10944), layers 2..27 are MoE —
expressed as two stacks.
"""
from repro.models.transformer import (
    LayerKind, MLASpec, ModelConfig, MoESpec, StackSpec)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        d_model=2048,
        n_heads=16,
        n_kv=16,
        head_dim=192,            # MLA: nope 128 + rope 64
        d_ff=10944,              # dense FFN of layer 1
        vocab=102400,
        stacks=(
            StackSpec(pattern=(LayerKind("mla", "dense"),), groups=1),
            StackSpec(pattern=(LayerKind("mla", "moe"),), groups=26),
        ),
        mlp_act="silu",
        gated_mlp=True,
        moe=MoESpec(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408),
        mla=MLASpec(kv_lora=512, rope_dim=64, nope_dim=128, v_dim=128),
        rope_theta=10000.0,
    )
