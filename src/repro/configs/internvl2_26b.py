"""internvl2-26b [vlm] — InternLM2-20B language backbone: 48L d_model=6144
48H (GQA kv=8) d_ff=16384 vocab=92553.  [arXiv:2404.16821; hf]
The InternViT-6B frontend is a STUB: ``input_specs`` supplies 256
precomputed patch embeddings per sample (DESIGN.md §5).
"""
from repro.models.transformer import LayerKind, ModelConfig, uniform_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        d_model=6144,
        n_heads=48,
        n_kv=8,
        head_dim=128,
        d_ff=16384,
        vocab=92553,
        stacks=uniform_stack(LayerKind("gqa", "dense"), 48),
        mlp_act="silu",
        gated_mlp=True,
        vlm_patches=256,
        rope_theta=1000000.0,
    )
