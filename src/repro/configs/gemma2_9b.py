"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8, head 256)
d_ff=14336, local(4096)/global alternating attention, attn softcap 50,
final logit softcap 30, post-norms, vocab=256000.  [arXiv:2408.00118]
"""
import math

from repro.models.transformer import LayerKind, ModelConfig, StackSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        d_model=3584,
        n_heads=16,
        n_kv=8,
        head_dim=256,
        d_ff=14336,
        vocab=256000,
        stacks=(StackSpec(pattern=(LayerKind("gqa_local", "dense"),
                                   LayerKind("gqa", "dense")), groups=21),),
        mlp_act="gelu",
        gated_mlp=True,
        tie_embeddings=True,
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        query_scale=256.0 ** -0.5,      # query_pre_attn_scalar = head_dim
        post_norms=True,
        emb_scale=math.sqrt(3584.0),
        rope_theta=10000.0,
    )
