"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (kv=16) d_ff=2816,
QKV bias, tied embeddings, vocab=151936.  [hf:Qwen/Qwen1.5-0.5B]
"""
from repro.models.transformer import LayerKind, ModelConfig, uniform_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        d_model=1024,
        n_heads=16,
        n_kv=16,
        head_dim=64,
        d_ff=2816,
        vocab=151936,
        stacks=uniform_stack(LayerKind("gqa", "dense"), 24),
        mlp_act="silu",
        gated_mlp=True,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1000000.0,
    )
