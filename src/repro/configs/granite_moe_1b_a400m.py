"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) expert
d_ff=512, 32 experts top-8, vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.models.transformer import (
    LayerKind, ModelConfig, MoESpec, uniform_stack)


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        d_model=1024,
        n_heads=16,
        n_kv=8,
        head_dim=64,
        d_ff=512,
        vocab=49155,
        stacks=uniform_stack(LayerKind("gqa", "moe"), 24),
        mlp_act="silu",
        gated_mlp=True,
        tie_embeddings=True,
        moe=MoESpec(n_experts=32, top_k=8, n_shared=0, d_ff_expert=512),
        rope_theta=10000.0,
    )
