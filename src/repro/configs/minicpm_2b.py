"""minicpm-2b [dense] — 40L d_model=2304 36H (kv=36) d_ff=5760, llama-like
with μP-style embedding scaling, tied embeddings, vocab=122753.
Trained with a WSD schedule (provided in repro/optim/schedules.py).
[arXiv:2404.06395]
"""
from repro.models.transformer import LayerKind, ModelConfig, uniform_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        d_model=2304,
        n_heads=36,
        n_kv=36,
        head_dim=64,
        d_ff=5760,
        vocab=122753,
        stacks=uniform_stack(LayerKind("gqa", "dense"), 40),
        mlp_act="silu",
        gated_mlp=True,
        tie_embeddings=True,
        emb_scale=12.0,
        rope_theta=10000.0,
    )
