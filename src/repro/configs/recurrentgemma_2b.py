"""recurrentgemma-2b [hybrid] — 26L d_model=2560, RG-LRU + local attention
(window 2048, MQA kv=1) at 2:1 ratio, d_ff=7680, vocab=256000.
[arXiv:2402.19427 (Griffin)]
26 = 8×(rec,rec,attn) + (rec,rec) — two stacks.  Sub-quadratic ⇒ runs
long_500k (RG-LRU state + 2048-token ring buffer).
"""
import math

from repro.models.transformer import (
    LayerKind, ModelConfig, RGLRUSpec, StackSpec)


def config() -> ModelConfig:
    rec = LayerKind("rglru", "dense")
    att = LayerKind("gqa_local", "dense")
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        d_model=2560,
        n_heads=10,
        n_kv=1,
        head_dim=256,
        d_ff=7680,
        vocab=256000,
        stacks=(
            StackSpec(pattern=(rec, rec, att), groups=8),
            StackSpec(pattern=(rec, rec), groups=1),
        ),
        mlp_act="gelu",
        gated_mlp=True,
        tie_embeddings=True,
        window=2048,
        emb_scale=math.sqrt(2560.0),
        rglru=RGLRUSpec(width=2560, conv_w=4),
        subquadratic=True,
    )
