"""Assigned input-shape cells and ShapeDtypeStruct input specs.

Four cells per LM architecture (40 cells total):
  train_4k     seq 4096   × global_batch 256   → train_step
  prefill_32k  seq 32768  × global_batch 32    → prefill
  decode_32k   seq 32768  × global_batch 128   → serve_step (1 new token)
  long_500k    seq 524288 × global_batch 1     → serve_step; requires
               sub-quadratic decode state (SSM / hybrid only — see
               DESIGN.md §5 for the documented skips).

``input_specs`` returns weak-type-correct ShapeDtypeStructs — no device
allocation; the dry-run lowers/compiles against them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, init_cache


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


CELLS = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

CELLS_BY_NAME = {c.name: c for c in CELLS}


def applicable(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    """None if the (arch, cell) pair runs; else the documented skip reason."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return "skipped (full attention — O(S) KV decode state at 524k)"
    return None


def input_specs(cfg: ModelConfig, cell: ShapeCell,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = cell.global_batch, cell.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)

    if cell.kind == "train":
        spec = {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.vlm_patches:
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vlm_patches, cfg.d_model), dtype)
        return spec

    if cell.kind == "prefill":
        spec = {"tokens": tok}
        if cfg.vlm_patches:
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vlm_patches, cfg.d_model), dtype)
        return spec

    # decode: one new token against a cache of capacity seq_len
    caches = jax.eval_shape(
        lambda: init_cache(cfg, b, s, dtype))
    return {
        "tokens_t": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": caches,
    }
