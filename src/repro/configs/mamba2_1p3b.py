"""mamba2-1.3b [ssm] — 48L d_model=2048, attention-free SSD blocks,
ssm_state=128, vocab=50280.  [arXiv:2405.21060]
Sub-quadratic ⇒ runs the long_500k cell (O(1) decode state).
"""
from repro.models.transformer import (
    LayerKind, ModelConfig, SSMSpec, uniform_stack)


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        d_model=2048,
        n_heads=1, n_kv=1, head_dim=1,       # unused (attention-free)
        d_ff=0,
        vocab=50280,
        stacks=uniform_stack(LayerKind("ssm", "none"), 48),
        ssm=SSMSpec(d_inner=4096, head_p=64, state_n=128, conv_w=4, chunk=256),
        tie_embeddings=True,
        subquadratic=True,
    )
