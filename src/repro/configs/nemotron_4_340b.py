"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8, head 192)
d_ff=73728, squared-ReLU MLP (non-gated), vocab=256000.
[arXiv:2402.16819]
The 340B scale case: uses ZeRO-style state sharding in the launcher.
"""
from repro.models.transformer import LayerKind, ModelConfig, uniform_stack


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        family="dense",
        d_model=18432,
        n_heads=96,
        n_kv=8,
        head_dim=192,
        d_ff=73728,
        vocab=256000,
        stacks=uniform_stack(LayerKind("gqa", "dense"), 96),
        mlp_act="sqrelu",
        gated_mlp=False,
        rope_theta=10000.0,
    )
