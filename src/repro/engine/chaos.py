"""Deterministic chaos harness for the serving engine.

A :class:`FaultPlan` is a seeded schedule of injected faults that
:func:`~repro.engine.snapshot.supervised_serve` consults before every
engine step:

* ``decode_fail``  — raise :class:`~repro.fault.SimulatedNodeFailure`
  (the supervisor restores the last snapshot and replays);
* ``poison``       — NaN-poison one slot's logits for one step (the
  engine must quarantine exactly that slot);
* ``pressure``     — seize free pages for ``duration`` steps (a
  simulated neighbor hogging the pool; the engine stalls/waits, never
  preempts on borrowed starvation);
* ``kill_restore`` — snapshot → tear the engine down → restore, mid
  stream (the bit-exactness acceptance gate);
* ``preempt``      — raise :class:`~repro.fault.PreemptionSignal`
  (save-and-exit, then in-process resume);
* ``prefill_kill`` — a ``kill_restore`` that waits until some slot is
  *mid-prefill* (0 < progress < prompt_len), so the snapshot must
  round-trip partially-written KV pages and the per-layer block-carry
  state of an in-flight blockwise prefill.

Every event fires **at most once** per plan object (the ``_fired`` set
lives on the plan, which outlives engine restarts) — a restored run
replaying through an event's step must not re-suffer it, mirroring
``repro.fault.FailureInjector``.  Event times are engine steps and the
schedule comes from ``np.random.RandomState(seed)``, so a plan is fully
reproducible: the acceptance oracle (``engine/oneshot.py``'s lockstep
loop) must match every FINISHED stream bit-for-bit under any seed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.fault import PreemptionSignal, SimulatedNodeFailure

KINDS = ("decode_fail", "poison", "pressure", "kill_restore", "preempt",
         "prefill_kill")


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault.  ``step`` is the earliest engine step the
    event may fire at (it fires on the first supervisor poll with
    ``step >= event.step``); ``slot``/``pages``/``duration`` parametrize
    the kind that uses them."""

    step: int
    kind: str
    slot: int = 0
    pages: int = 1
    duration: int = 2

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.step < 0:
            raise ValueError("fault step must be >= 0")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FaultPlan:
    """A deterministic fault schedule (the ``injector`` protocol of
    :func:`~repro.engine.snapshot.supervised_serve`)."""

    events: List[FaultEvent] = dataclasses.field(default_factory=list)
    seed: Optional[int] = None
    _fired: set = dataclasses.field(default_factory=set)
    _pending_release: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)          # (release_step, n_pages)

    @classmethod
    def generate(cls, seed: int, *, horizon: int = 48, n_slots: int = 4,
                 kinds: Sequence[str] = KINDS,
                 n_events: Optional[int] = None) -> "FaultPlan":
        """A seeded random plan with ≥ 1 event of every requested kind
        (the acceptance criterion's minimum fault mix), spread over
        ``horizon`` steps."""
        rng = np.random.RandomState(seed)
        kinds = list(kinds)
        if n_events is None:
            n_events = len(kinds) + int(rng.randint(0, 3))
        picks = kinds + [kinds[int(rng.randint(len(kinds)))]
                         for _ in range(max(n_events - len(kinds), 0))]
        events = []
        for kind in picks:
            events.append(FaultEvent(
                # step >= 2 so the first prefill commits before chaos
                step=2 + int(rng.randint(max(horizon - 2, 1))),
                kind=kind,
                slot=int(rng.randint(n_slots)),
                pages=1 + int(rng.randint(3)),
                duration=1 + int(rng.randint(4))))
        events.sort(key=lambda e: (e.step, KINDS.index(e.kind), e.slot))
        return cls(events=events, seed=seed)

    def counts(self) -> dict:
        return {k: sum(e.kind == k for e in self.events) for k in KINDS}

    def to_json(self) -> dict:
        return {"seed": self.seed,
                "events": [e.to_json() for e in self.events]}

    # -- injector protocol --------------------------------------------------

    def apply(self, eng, step: int) -> Optional[str]:
        """Fire every due, unfired event.  May mutate ``eng``, raise a
        fault exception, or return ``"kill_restore"``; called by the
        supervisor before each engine step."""
        # scheduled pressure releases first (so a seize's own release
        # isn't blocked by an exception from a later event this step)
        still = []
        for when, n in self._pending_release:
            if step >= when:
                eng.pool.release(n)
            else:
                still.append((when, n))
        self._pending_release = still

        for idx, ev in enumerate(self.events):
            if idx in self._fired or step < ev.step:
                continue
            if ev.kind == "poison":
                # needs a decoding slot to poison; stays pending until
                # one exists (deterministic: state at a step is a pure
                # function of the seed and the schedule)
                running = eng.sched.running_ids()
                if not running:
                    continue
                self._fired.add(idx)
                eng.poison_slot(running[ev.slot % len(running)])
            elif ev.kind == "pressure":
                self._fired.add(idx)
                taken = eng.pool.seize(ev.pages)
                if taken:
                    self._pending_release.append(
                        (step + max(ev.duration, 1), taken))
            elif ev.kind == "kill_restore":
                # hand control back immediately: later due events fire
                # on the next poll, against the restored engine
                self._fired.add(idx)
                return "kill_restore"
            elif ev.kind == "prefill_kill":
                # stays pending until a slot is partway through its
                # block sequence (short prompts may never get there —
                # the event then simply never fires)
                if not any(s is not None and not s.prefilled
                           and 0 < s.prefill_progress
                           for s in eng.sched.slots):
                    continue
                self._fired.add(idx)
                return "kill_restore"
            elif ev.kind == "decode_fail":
                self._fired.add(idx)
                raise SimulatedNodeFailure(
                    f"injected decode failure at step {step}")
            elif ev.kind == "preempt":
                self._fired.add(idx)
                raise PreemptionSignal(
                    f"injected preemption at step {step}")
        return None
