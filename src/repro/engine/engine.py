"""The continuous-batching step loop.

Each :meth:`Engine.step` mixes, under a per-step token budget:

1. **decode** — one token for every running slot, fused into a single
   ``decode_step_slots`` call (fixed shapes: dead slots are masked, so
   admission/eviction never recompiles);
2. **admission** — queued requests move into free slots once their
   prompt's pages can be reserved from the pool;
3. **chunked prefill** — admitted prompts consume leftover budget in
   chunks across steps; when a prompt is fully scheduled, one batch-1
   ``prefill`` call runs and its KV is scattered into the slot's pages.
   (The compute is a single full-prompt call — the same call the
   one-shot oracle makes — so engine token streams are exactly the
   one-shot streams; the budget governs *scheduling*, i.e. how much
   prompt work each step admits next to ongoing decodes.)

A finished slot's pages return to the pool immediately (a queued short
request reuses a long one's pages without waiting for the batch).  If
every running slot is page-starved and nothing else can progress, the
youngest stalled request is preempted back to the queue head and
restarts from scratch — deterministic sampling keys make the replayed
stream identical.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import sampling
from repro.engine.kvcache import PagePool
from repro.engine.oneshot import jit_prefill
from repro.engine.scheduler import Request, SlotScheduler
from repro.models.transformer import (ModelConfig, decode_step_slots,
                                      init_paged_cache,
                                      write_prefill_to_slot)


def _decode_and_sample(params, cfg, caches, page_table, tokens_t, pos,
                       alive, temps, top_ks, keys):
    """One fused device call per engine step: decode + per-slot sample."""
    logits, caches = decode_step_slots(params, cfg, caches, page_table,
                                       tokens_t, pos, alive)
    nxt = sampling.sample_tokens(logits[:, 0], temps, top_ks, keys)
    return nxt, caches


# module-level jits shared by every Engine instance: constructing an
# engine (or several, as the bench does) never recompiles a step that a
# previous instance with the same config/shapes already compiled.
# Prefill is oneshot.jit_prefill — one cache for the oracle AND the
# engine (their prefill calls must be the same computation anyway for
# stream parity).
_DECODE = jax.jit(_decode_and_sample, static_argnums=1)
_SAMPLE = jax.jit(sampling.sample_tokens)
# slot stays traced (it is only an index), so admitting into slot 63
# reuses slot 0's compiled scatter
_COMMIT = jax.jit(write_prefill_to_slot, static_argnums=(0, 5))


def _activation_dtype(params):
    """The model's residual-stream dtype, read off the embedding leaf in
    any serving layout (dense table, or the codebook / layout metadata
    of the quantized layouts — both carry the original leaf dtype)."""
    if "embed_tok" in params:
        return params["embed_tok"].dtype
    if "embed_tok_layout" in params:
        return jnp.dtype(params["embed_tok_layout"].dtype)
    if "embed_tok_cb" in params:
        return params["embed_tok_cb"].dtype
    return jnp.float32


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0        # prompt tokens scheduled (chunked)
    prefill_calls: int = 0
    admitted: int = 0
    finished: int = 0
    delivered_tokens: int = 0      # tokens in finished outputs (excludes
    #                                work discarded by preemption)
    stall_events: int = 0
    preemptions: int = 0
    occupancy_sum: float = 0.0
    page_util_sum: float = 0.0
    page_util_max: float = 0.0
    wall_s: float = 0.0

    @property
    def generated_tokens(self) -> int:
        """Tokens *computed* (every prefill call emits the request's
        first token) — exceeds delivered_tokens when preemptions
        discarded work."""
        return self.decode_tokens + self.prefill_calls

    def summary(self) -> dict:
        steps = max(self.steps, 1)
        wall = max(self.wall_s, 1e-9)
        return {
            "steps": self.steps,
            "generated_tokens": self.generated_tokens,
            "delivered_tokens": self.delivered_tokens,
            "prefill_tokens": self.prefill_tokens,
            "tokens_per_s": self.delivered_tokens / wall,
            "slot_occupancy": self.occupancy_sum / steps,
            "page_utilization": self.page_util_sum / steps,
            "page_utilization_max": self.page_util_max,
            "finished": self.finished,
            "preemptions": self.preemptions,
            "stall_events": self.stall_events,
            "wall_s": self.wall_s,
        }


class Engine:
    """Continuous-batching serving engine over (possibly packed) params.

    ``params`` may be any serving layout — dense, uint8-oracle, or the
    bit-packed ``serving_params(packed=True)`` tree: every weight fetch
    inside the step goes through ``repro.models.qleaf``.

    HBM sizing: the page pool holds ``n_pages`` pages of ``page_size``
    tokens for every global-attention layer; ``max_seq`` bounds one
    request's prompt + generation.  Defaults give every slot its full
    ``max_seq`` worth of pages (no contention); pass a smaller
    ``n_pages`` to oversubscribe (short/long request mixes reuse pages).

    ``dtype`` is the KV-pool element type and must match the model's
    activation dtype (bf16 for bf16 models): the one-shot oracle's
    caches inherit the prefill dtype, so a mismatched pool would round
    differently and break stream parity.  The default infers it from
    the params' embedding leaf (any serving layout).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 page_size: int = 16, max_seq: int = 256,
                 n_pages: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 prefill_chunk: int = 64, dtype=None, mesh=None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.page_size = page_size
        max_pages_per_slot = -(-max_seq // page_size)
        self.max_seq = max_pages_per_slot * page_size
        if n_pages is None:
            n_pages = n_slots * max_pages_per_slot
        self.pool = PagePool(n_pages, page_size, n_slots, max_pages_per_slot)
        self.sched = SlotScheduler(n_slots)
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.token_budget = (int(token_budget) if token_budget is not None
                             else n_slots + self.prefill_chunk)
        if self.token_budget < 1:
            raise ValueError("token_budget must be >= 1")
        if dtype is None:
            dtype = _activation_dtype(params)
        self.caches = init_paged_cache(cfg, n_slots, n_pages, page_size,
                                       dtype)
        if mesh is not None:
            from repro.dist import sharding as shard_rules
            sh = shard_rules.engine_cache_shardings(self.caches, mesh,
                                                    n_slots=n_slots,
                                                    n_pages=n_pages)
            self.caches = jax.tree_util.tree_map(jax.device_put,
                                                 self.caches, sh)
        self._decode = _DECODE
        self._prefill = jit_prefill
        self._sample = _SAMPLE
        self._zero_key = np.zeros((2,), np.uint32)
        self._table_cache = (-1, None)     # (pool.version, device table)
        self.outputs: Dict[int, np.ndarray] = {}
        self.stats = EngineStats()

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        total = req.prompt_len + req.max_new_tokens
        if total > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + max_new "
                f"{req.max_new_tokens} exceeds max_seq {self.max_seq}")
        if self.pool.pages_for_len(total) > self.pool.n_pages:
            # would stall at the same position on every replay — reject
            # up front instead of preempt-cycling until max_steps
            raise ValueError(
                f"request {req.rid}: needs {self.pool.pages_for_len(total)}"
                f" pages to finish, pool has {self.pool.n_pages}")
        self.sched.submit(req)

    def decode_compile_count(self) -> int:
        """Number of compiled decode-step variants in the shared jit
        cache (one per distinct config/shape — admission/eviction within
        one engine must never add another)."""
        return int(self._decode._cache_size())

    def trace_counts(self) -> Dict[str, int]:
        """Jit-cache entry counts for every device call the step loop
        makes.  These are the module-level shared jits, so the counts are
        process-wide; ``repro.analysis.recompile.RecompileAuditor``
        snapshots them around a scenario to prove admission / completion
        / preemption never trigger a retrace."""
        return {
            "decode": int(self._decode._cache_size()),
            "prefill": int(self._prefill._cache_size()),
            "sample": int(self._sample._cache_size()),
            "commit": int(_COMMIT._cache_size()),
        }

    def run(self, requests: Optional[List[Request]] = None,
            max_steps: int = 100_000) -> Dict[int, np.ndarray]:
        """Drive steps until queue and slots drain; returns rid → tokens."""
        for r in requests or ():
            self.submit(r)
        t0 = time.perf_counter()
        while self.sched.has_work():
            self.step()
            if self.stats.steps > max_steps:
                raise RuntimeError("engine exceeded max_steps")
        self.stats.wall_s += time.perf_counter() - t0
        return dict(self.outputs)

    # -- one step -----------------------------------------------------------

    def step(self) -> dict:
        st = self.stats
        st.steps += 1
        st.occupancy_sum += self.sched.occupancy()
        info = {"decoded": 0, "prefill_tokens": 0, "admitted": 0,
                "finished": 0, "stalled": 0, "preempted": 0}
        budget = self.token_budget

        # 1) decode every running slot whose next page is available
        running = self.sched.running_ids()
        ready, stalled = [], []
        for i in running:
            s = self.sched.slots[i]
            (ready if self.pool.ensure(i, s.write_pos)
             else stalled).append(i)
        if stalled:
            st.stall_events += len(stalled)
            info["stalled"] = len(stalled)
        if ready:
            self._decode_ready(ready, info)
            budget -= len(ready)
            st.decode_tokens += len(ready)

        # 2) admit queued requests into free slots (reserve prompt pages)
        for i in self.sched.free_ids():
            if not self.sched.queue:
                break
            req = self.sched.queue[0]
            if not self.pool.alloc(i, self.pool.pages_for_len(
                    req.prompt_len)):
                break
            self.sched.queue.popleft()
            self.sched.admit(i, req)
            st.admitted += 1
            info["admitted"] += 1

        # 3) chunked prefill under the leftover budget
        for i in self.sched.prefilling_ids():
            if budget <= 0:
                break
            s = self.sched.slots[i]
            chunk = min(budget, self.prefill_chunk,
                        s.req.prompt_len - s.prefill_progress)
            s.prefill_progress += chunk
            budget -= chunk
            st.prefill_tokens += chunk
            info["prefill_tokens"] += chunk
            if s.prefill_progress >= s.req.prompt_len:
                self._commit_prefill(i, s)
                if s.finished():
                    self._finish(i, info)

        util = self.pool.utilization()
        st.page_util_sum += util
        st.page_util_max = max(st.page_util_max, util)

        if not (info["decoded"] or info["prefill_tokens"]
                or info["admitted"]):
            self._resolve_no_progress(stalled, info)
        return info

    # -- internals ----------------------------------------------------------

    def _page_table(self):
        if self._table_cache[0] != self.pool.version:
            self._table_cache = (self.pool.version,
                                 jnp.asarray(self.pool.table))
        return self._table_cache[1]

    def _decode_ready(self, ready, info):
        b = self.n_slots
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        alive = np.zeros((b,), bool)
        temps = np.zeros((b,), np.float32)
        top_ks = np.zeros((b,), np.int32)
        keys = np.zeros((b, 2), np.uint32)
        for i in ready:
            s = self.sched.slots[i]
            tokens[i, 0] = s.out[-1]
            pos[i] = s.write_pos
            alive[i] = True
            temps[i] = s.req.temperature
            top_ks[i] = s.req.top_k
            keys[i] = (np.asarray(sampling.slot_key(s.req.seed,
                                                    s.n_generated))
                       if s.req.temperature > 0 else self._zero_key)
        nxt, self.caches = self._decode(
            self.params, self.cfg, self.caches, self._page_table(),
            jnp.asarray(tokens), jnp.asarray(pos), jnp.asarray(alive),
            jnp.asarray(temps), jnp.asarray(top_ks), jnp.asarray(keys))
        nxt = np.asarray(nxt)
        for i in ready:
            s = self.sched.slots[i]
            s.out.append(int(nxt[i]))
            info["decoded"] += 1
            if s.finished():
                self._finish(i, info)

    def _commit_prefill(self, i, s):
        """The bit-exact full-prompt prefill call + page scatter."""
        prompt = jnp.asarray(s.req.prompt[None, :], jnp.int32)
        logits, pcaches = self._prefill(self.params, self.cfg, prompt,
                                        last_logits_only=True)
        pages = jnp.asarray(self.pool.pages_of(i), jnp.int32)
        self.caches = _COMMIT(self.cfg, self.caches, pcaches, i, pages,
                              self.page_size)
        key = (np.asarray(sampling.slot_key(s.req.seed, 0))
               if s.req.temperature > 0 else self._zero_key)
        tok = np.asarray(self._sample(
            logits[:, -1], jnp.asarray([s.req.temperature], jnp.float32),
            jnp.asarray([s.req.top_k], jnp.int32),
            jnp.asarray(key[None, :])))
        s.out.append(int(tok[0]))
        s.prefilled = True
        self.stats.prefill_calls += 1

    def _finish(self, i, info):
        s = self.sched.evict(i)
        self.pool.free_slot(i)
        self.outputs[s.req.rid] = np.asarray(s.out, np.int32)
        self.stats.finished += 1
        self.stats.delivered_tokens += len(s.out)
        info["finished"] += 1

    def _resolve_no_progress(self, stalled, info):
        if stalled:
            # every runnable slot is page-starved and no admission or
            # prefill could proceed: preempt the youngest, replay later
            j = max(stalled, key=lambda i: self.sched.slots[i].admit_seq)
            s = self.sched.evict(j)
            self.pool.free_slot(j)
            # Request is immutable (progress lives on SlotState): the
            # replay reuses it as-is and regenerates the same stream
            self.sched.requeue_front(s.req)
            self.stats.preemptions += 1
            info["preempted"] = 1
        elif self.sched.queue:
            req = self.sched.queue[0]
            raise RuntimeError(
                f"page pool too small for request {req.rid}: prompt needs "
                f"{self.pool.pages_for_len(req.prompt_len)} pages, pool has "
                f"{self.pool.n_pages}")
