"""The continuous-batching step loop.

Each :meth:`Engine.step` mixes, under a per-step token budget:

1. **decode** — one token for every running slot, fused into a single
   ``decode_step_slots`` call (fixed shapes: dead slots are masked, so
   admission/eviction never recompiles);
2. **admission** — queued requests move into free slots once their
   prompt's pages can be reserved from the pool;
3. **blockwise prefill** — each admitted prompt advances at most ONE
   block of ≤ ``effective_chunk`` new tokens per step, paid out of the
   leftover budget.  The block *is* the compute: an incremental forward
   over just those tokens whose K/V lands directly in the slot's pages
   (quantized when ``kv_bits > 0``) with per-layer recurrent / window
   carries riding in the slot's cache rows — so the budget bounds
   device work, and no engine step runs a forward over more than
   ``effective_chunk`` prompt tokens.  The one-shot oracle runs the
   same blockwise computation (``transformer.prefill`` with the same
   block), so engine token streams are exactly the one-shot streams.

A finished slot's pages return to the pool immediately (a queued short
request reuses a long one's pages without waiting for the batch).  If
every running slot is page-starved and nothing else can progress, the
youngest stalled request is preempted back to the queue head and
restarts from scratch — deterministic sampling keys make the replayed
stream identical.

**Failure isolation** (PR 7): one request's fate never corrupts a
neighbor.  Every request ends in exactly one typed
:class:`~repro.engine.outcomes.Outcome` in :attr:`Engine.results` —
an unservable prompt is *rejected* before reserving a page
(``REJECTED_TOO_LARGE``; a full bounded queue gives
``REJECTED_BACKPRESSURE``), per-request deadlines expire to
``DEADLINE_EXCEEDED`` with pages freed immediately, :meth:`cancel`
frees mid-stream, a per-request preemption budget converts page-starved
livelock into a typed ``FAILED``, and a non-finite logit row
quarantines only the poisoned slot while batch mates keep decoding.
The engine itself no longer raises out of :meth:`run`: exceeding
``max_steps`` fails the stragglers and returns every completed stream.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kvquant
from repro.engine import sampling
from repro.engine.kvcache import PagePool
from repro.engine.outcomes import Outcome, RequestResult
from repro.engine.scheduler import Request, SlotScheduler
from repro.models.transformer import (ModelConfig, decode_step_slots,
                                      init_paged_cache,
                                      prefill_chunk_slots)


def _decode_and_sample(params, cfg, caches, page_table, tokens_t, pos,
                       alive, temps, top_ks, keys, poison):
    """One fused device call per engine step: decode + per-slot sample.

    ``poison`` [B] bool overwrites a slot's logits row with NaN *after*
    the model ran — the chaos harness's injection point for numerically
    poisoned slots (``engine/chaos.py``); all-False in production.  The
    returned ``bad`` flags rows with any non-finite logit (injected or
    genuine) so the engine can quarantine exactly that slot.
    """
    logits, caches = decode_step_slots(params, cfg, caches, page_table,
                                       tokens_t, pos, alive)
    row = logits[:, 0]
    row = jnp.where(poison[:, None], jnp.full_like(row, jnp.nan), row)
    nxt, bad = sampling.sample_and_flag(row, temps, top_ks, keys)
    return nxt, bad, caches


# module-level jits shared by every Engine instance: constructing an
# engine (or several, as the bench does) never recompiles a step that a
# previous instance with the same config/shapes already compiled.
_DECODE = jax.jit(_decode_and_sample, static_argnums=1)
_SAMPLE = jax.jit(sampling.sample_and_flag)
# slot and start stay traced (they are only indices), so block 7 of a
# long prompt in slot 63 reuses the compile of block 0 in slot 0; only
# distinct block widths (the full chunk plus each prompt's remainder)
# trace anew
_CHUNK = jax.jit(prefill_chunk_slots, static_argnums=1)


def _activation_dtype(params):
    """The model's residual-stream dtype, read off the embedding leaf in
    any serving layout (dense table, or the codebook / layout metadata
    of the quantized layouts — both carry the original leaf dtype)."""
    if "embed_tok" in params:
        return params["embed_tok"].dtype
    if "embed_tok_layout" in params:
        return jnp.dtype(params["embed_tok_layout"].dtype)
    if "embed_tok_cb" in params:
        return params["embed_tok_cb"].dtype
    return jnp.float32


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0        # prompt tokens actually computed
    prefill_calls: int = 0         # block forwards run (>= 1 per prompt)
    prefill_samples: int = 0       # first tokens sampled at final blocks
    admitted: int = 0
    finished: int = 0
    delivered_tokens: int = 0      # tokens in finished outputs (excludes
    #                                work discarded by preemption)
    stall_events: int = 0
    preemptions: int = 0
    rejected: int = 0              # TOO_LARGE + BACKPRESSURE at submit
    cancelled: int = 0
    deadline_expired: int = 0
    quarantined: int = 0           # non-finite logit rows isolated
    failed: int = 0                # FAILED outcomes (incl. quarantines)
    occupancy_sum: float = 0.0
    page_util_sum: float = 0.0
    page_util_max: float = 0.0
    wall_s: float = 0.0

    @property
    def generated_tokens(self) -> int:
        """Tokens *sampled*: decode steps plus the first token each
        completed prefill emits.  A multi-block prefill samples exactly
        once, so this never double-counts block forwards; it exceeds
        delivered_tokens when preemptions discarded work."""
        return self.decode_tokens + self.prefill_samples

    def summary(self) -> dict:
        steps = max(self.steps, 1)
        wall = max(self.wall_s, 1e-9)
        return {
            "steps": self.steps,
            "generated_tokens": self.generated_tokens,
            "delivered_tokens": self.delivered_tokens,
            "prefill_tokens": self.prefill_tokens,
            "tokens_per_s": self.delivered_tokens / wall,
            "slot_occupancy": self.occupancy_sum / steps,
            "page_utilization": self.page_util_sum / steps,
            "page_utilization_max": self.page_util_max,
            "finished": self.finished,
            "preemptions": self.preemptions,
            "stall_events": self.stall_events,
            "rejected": self.rejected,
            "cancelled": self.cancelled,
            "deadline_expired": self.deadline_expired,
            "quarantined": self.quarantined,
            "failed": self.failed,
            "wall_s": self.wall_s,
        }


class Engine:
    """Continuous-batching serving engine over (possibly packed) params.

    ``params`` may be any serving layout — dense, uint8-oracle, or the
    bit-packed ``serving_params(packed=True)`` tree: every weight fetch
    inside the step goes through ``repro.models.qleaf``.

    HBM sizing: the page pool holds ``n_pages`` pages of ``page_size``
    tokens for every global-attention layer; ``max_seq`` bounds one
    request's prompt + generation.  Defaults give every slot its full
    ``max_seq`` worth of pages (no contention); pass a smaller
    ``n_pages`` to oversubscribe (short/long request mixes reuse pages).

    ``dtype`` is the KV-pool element type and must match the model's
    activation dtype (bf16 for bf16 models): the one-shot oracle's
    caches inherit the prefill dtype, so a mismatched pool would round
    differently and break stream parity.  The default infers it from
    the params' embedding leaf (any serving layout).

    Admission control: ``queue_limit`` bounds the request queue —
    :meth:`submit` beyond it records ``REJECTED_BACKPRESSURE`` instead
    of growing without bound (the backpressure signal a front end
    propagates to clients).  ``max_preemptions`` bounds how many times
    one request may be preempted for page pressure before it fails
    typed (two page-starved giants otherwise ping-pong the
    no-progress resolver forever).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 page_size: int = 16, max_seq: int = 256,
                 n_pages: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 prefill_chunk: int = 64, dtype=None, mesh=None,
                 queue_limit: Optional[int] = None,
                 max_preemptions: int = 8, kv_bits: int = 0,
                 kv_cb_mode: str = "page"):
        self.params = params
        if kv_bits:
            kvquant.check_kv_bits(kv_bits)
            if kv_cb_mode not in ("page", "head"):
                raise ValueError(f"kv_cb_mode={kv_cb_mode!r}; "
                                 f"choose 'page' or 'head'")
            # ride the knobs on the (static, hashable) config so the
            # shared decode jit keys on them; kv_bits == 0 leaves cfg
            # untouched and the default jit cache entries intact
            cfg = dataclasses.replace(cfg, kv_bits=kv_bits,
                                      kv_cb_mode=kv_cb_mode)
        self.kv_bits = kv_bits
        self.kv_cb_mode = kv_cb_mode
        self.cfg = cfg
        self.n_slots = n_slots
        self.page_size = page_size
        max_pages_per_slot = -(-max_seq // page_size)
        self.max_seq = max_pages_per_slot * page_size
        if n_pages is None:
            n_pages = n_slots * max_pages_per_slot
        self.pool = PagePool(n_pages, page_size, n_slots, max_pages_per_slot)
        self.sched = SlotScheduler(n_slots)
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.token_budget = (int(token_budget) if token_budget is not None
                             else n_slots + self.prefill_chunk)
        if self.token_budget < 1:
            raise ValueError("token_budget must be >= 1")
        # the block size every prefill forward actually uses: the fixed
        # partition must fit inside a fresh step's budget, or a long
        # prompt could never schedule its first block
        self.effective_chunk = max(1, min(self.prefill_chunk,
                                          self.token_budget))
        self.queue_limit = (None if queue_limit is None
                            else max(int(queue_limit), 1))
        self.max_preemptions = int(max_preemptions)
        if dtype is None:
            dtype = _activation_dtype(params)
        self.dtype = jnp.dtype(dtype)
        self.caches = init_paged_cache(cfg, n_slots, n_pages, page_size,
                                       dtype)
        if mesh is not None:
            from repro.dist import sharding as shard_rules
            sh = shard_rules.engine_cache_shardings(self.caches, mesh,
                                                    n_slots=n_slots,
                                                    n_pages=n_pages)
            self.caches = jax.tree_util.tree_map(jax.device_put,
                                                 self.caches, sh)
        self._decode = _DECODE
        self._chunk = _CHUNK
        self._sample = _SAMPLE
        self._zero_key = np.zeros((2,), np.uint32)
        self._no_poison = np.zeros((n_slots,), bool)
        self._poison_mask: Optional[np.ndarray] = None
        self._table_cache = (-1, None)     # (pool.version, device table)
        self.outputs: Dict[int, np.ndarray] = {}
        self.results: Dict[int, RequestResult] = {}
        self._submit_step: Dict[int, int] = {}
        self._preempt_counts: Dict[int, int] = {}
        self.stats = EngineStats()

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request) -> Optional[Outcome]:
        """Admission control.  Returns ``None`` when the request is
        queued, or the typed rejection outcome (also recorded in
        :attr:`results`) — never raises, never reserves a page for an
        unservable request, never disturbs in-flight neighbors."""
        total = req.prompt_len + req.max_new_tokens
        if total > self.max_seq:
            return self._reject(
                req, Outcome.REJECTED_TOO_LARGE,
                f"prompt {req.prompt_len} + max_new {req.max_new_tokens} "
                f"exceeds max_seq {self.max_seq}")
        if self.pool.pages_for_len(total) > self.pool.n_pages:
            # would stall at the same position on every replay — reject
            # up front instead of preempt-cycling until max_steps
            return self._reject(
                req, Outcome.REJECTED_TOO_LARGE,
                f"needs {self.pool.pages_for_len(total)} pages to finish, "
                f"pool has {self.pool.n_pages}")
        if (self.queue_limit is not None
                and len(self.sched.queue) >= self.queue_limit):
            return self._reject(
                req, Outcome.REJECTED_BACKPRESSURE,
                f"queue full ({self.queue_limit}); retry after drain")
        self._submit_step.setdefault(req.rid, self.stats.steps)
        self.sched.submit(req)
        return None

    def cancel(self, rid: int, detail: str = "client cancel") -> bool:
        """Cancel a queued or running request: its pages free
        immediately, its partial tokens ride in the typed result, and
        batch mates never notice.  Returns False for unknown/finished
        rids."""
        if self.sched.remove_queued(rid) is not None:
            self._record(rid, Outcome.CANCELLED, detail=detail)
            self.stats.cancelled += 1
            return True
        slot = self.sched.slot_of(rid)
        if slot is None:
            return False
        s = self.sched.evict(slot)
        self.pool.free_slot(slot)
        self._record(rid, Outcome.CANCELLED, tokens=s.out, detail=detail)
        self.stats.cancelled += 1
        return True

    def poison_slot(self, slot: int):
        """Chaos-harness injection point: NaN-poison ``slot``'s logits
        row on the *next* decode step (one step only).  The quarantine
        path must isolate exactly that slot."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        if self._poison_mask is None:
            self._poison_mask = np.zeros((self.n_slots,), bool)
        self._poison_mask[slot] = True

    def abort_remaining(self, detail: str):
        """Terminate every queued and in-flight request with a typed
        ``FAILED`` carrying its partial tokens (used on ``max_steps``
        overrun and supervisor give-up — completed outputs survive)."""
        while self.sched.queue:
            req = self.sched.queue.popleft()
            self._record(req.rid, Outcome.FAILED, detail=detail)
            self.stats.failed += 1
        for i, s in enumerate(self.sched.slots):
            if s is None:
                continue
            self.sched.evict(i)
            self.pool.free_slot(i)
            self._record(s.req.rid, Outcome.FAILED, tokens=s.out,
                         detail=detail)
            self.stats.failed += 1

    def decode_compile_count(self) -> int:
        """Number of compiled decode-step variants in the shared jit
        cache (one per distinct config/shape — admission/eviction within
        one engine must never add another)."""
        return int(self._decode._cache_size())

    def trace_counts(self) -> Dict[str, int]:
        """Jit-cache entry counts for every device call the step loop
        makes.  These are the module-level shared jits, so the counts are
        process-wide; ``repro.analysis.recompile.RecompileAuditor``
        snapshots them around a scenario to prove admission / completion
        / preemption never trigger a retrace."""
        return {
            "decode": int(self._decode._cache_size()),
            "prefill_chunk": int(self._chunk._cache_size()),
            "sample": int(self._sample._cache_size()),
        }

    def run(self, requests: Optional[List[Request]] = None,
            max_steps: int = 100_000) -> Dict[int, np.ndarray]:
        """Drive steps until queue and slots drain; returns rid → tokens
        for every ``FINISHED`` request.  Never raises: rejected /
        expired / failed requests carry typed outcomes in
        :attr:`results`, and a ``max_steps`` overrun fails the
        stragglers instead of discarding the completed streams."""
        for r in requests or ():
            self.submit(r)
        t0 = time.perf_counter()
        while self.sched.has_work():
            self.step()
            if self.stats.steps > max_steps:
                self.abort_remaining(f"engine exceeded max_steps "
                                     f"({max_steps})")
                break
        self.stats.wall_s += time.perf_counter() - t0
        return dict(self.outputs)

    # -- one step -----------------------------------------------------------

    def step(self) -> dict:
        st = self.stats
        st.steps += 1
        st.occupancy_sum += self.sched.occupancy()
        info = {"decoded": 0, "prefill_tokens": 0, "admitted": 0,
                "finished": 0, "stalled": 0, "preempted": 0, "expired": 0,
                "quarantined": 0}
        budget = self.token_budget

        # 0) deadline sweep: expired requests (queued or in-flight) free
        #    their slot/pages before any work is scheduled this step
        self._expire_deadlines(info)

        # 1) decode every running slot whose next page is available
        running = self.sched.running_ids()
        ready, stalled = [], []
        for i in running:
            s = self.sched.slots[i]
            (ready if self.pool.ensure(i, s.write_pos)
             else stalled).append(i)
        if stalled:
            st.stall_events += len(stalled)
            info["stalled"] = len(stalled)
        if ready:
            self._decode_ready(ready, info)
            budget -= len(ready)
            st.decode_tokens += len(ready)

        # 2) admit queued requests into free slots (reserve prompt pages)
        for i in self.sched.free_ids():
            if not self.sched.queue:
                break
            req = self.sched.queue[0]
            if not self.pool.alloc(i, self.pool.pages_for_len(
                    req.prompt_len)):
                break
            self.sched.queue.popleft()
            self.sched.admit(i, req)
            st.admitted += 1
            info["admitted"] += 1

        # 3) blockwise prefill under the leftover budget: each prefilling
        #    slot advances at most one block per step, and only when the
        #    leftover budget covers the whole block.  Block boundaries
        #    depend only on (prompt_len, effective_chunk) — never on this
        #    step's leftover — so a preempted or restored request replays
        #    the exact same block sequence (and jit cache entries).
        for i in self.sched.prefilling_ids():
            s = self.sched.slots[i]
            blk = min(self.effective_chunk,
                      s.req.prompt_len - s.prefill_progress)
            if blk > budget:
                continue
            self._prefill_block(i, s, blk, info)
            budget -= blk

        util = self.pool.utilization()
        st.page_util_sum += util
        st.page_util_max = max(st.page_util_max, util)

        if not (info["decoded"] or info["prefill_tokens"]
                or info["admitted"] or info["expired"]
                or info["quarantined"]):
            self._resolve_no_progress(stalled, info)
        return info

    # -- internals ----------------------------------------------------------

    def _record(self, rid: int, outcome: Outcome, tokens=None,
                detail: str = ""):
        self.results[rid] = RequestResult(
            rid=rid, outcome=outcome,
            tokens=np.asarray(tokens if tokens is not None else [],
                              np.int32),
            detail=detail,
            n_preemptions=self._preempt_counts.get(rid, 0))

    def _reject(self, req: Request, outcome: Outcome,
                detail: str) -> Outcome:
        self._record(req.rid, outcome, detail=f"request {req.rid}: {detail}")
        self.stats.rejected += 1
        return outcome

    def _expire_deadlines(self, info):
        expired = []
        for req in list(self.sched.queue):
            if self._deadline_hit(req):
                self.sched.remove_queued(req.rid)
                self._record(req.rid, Outcome.DEADLINE_EXCEEDED,
                             detail=self._deadline_detail(req))
                expired.append(req.rid)
        for i, s in enumerate(self.sched.slots):
            if s is None or not self._deadline_hit(s.req):
                continue
            self.sched.evict(i)
            self.pool.free_slot(i)
            self._record(s.req.rid, Outcome.DEADLINE_EXCEEDED,
                         tokens=s.out,
                         detail=self._deadline_detail(s.req))
            expired.append(s.req.rid)
        if expired:
            self.stats.deadline_expired += len(expired)
            info["expired"] = len(expired)

    def _deadline_hit(self, req: Request) -> bool:
        if req.deadline_steps is None:
            return False
        born = self._submit_step.get(req.rid, 0)
        return self.stats.steps - born > req.deadline_steps

    def _deadline_detail(self, req: Request) -> str:
        return (f"deadline of {req.deadline_steps} steps exceeded "
                f"(submitted at step {self._submit_step.get(req.rid, 0)})")

    def _page_table(self):
        if self._table_cache[0] != self.pool.version:
            self._table_cache = (self.pool.version,
                                 jnp.asarray(self.pool.table))
        return self._table_cache[1]

    def _decode_ready(self, ready, info):
        b = self.n_slots
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        alive = np.zeros((b,), bool)
        temps = np.zeros((b,), np.float32)
        top_ks = np.zeros((b,), np.int32)
        keys = np.zeros((b, 2), np.uint32)
        for i in ready:
            s = self.sched.slots[i]
            tokens[i, 0] = s.out[-1]
            pos[i] = s.write_pos
            alive[i] = True
            temps[i] = s.req.temperature
            top_ks[i] = s.req.top_k
            keys[i] = (np.asarray(sampling.slot_key(s.req.seed,
                                                    s.n_generated))
                       if s.req.temperature > 0 else self._zero_key)
        poison = (self._poison_mask if self._poison_mask is not None
                  else self._no_poison)
        self._poison_mask = None           # one-shot injection
        nxt, bad, self.caches = self._decode(
            self.params, self.cfg, self.caches, self._page_table(),
            jnp.asarray(tokens), jnp.asarray(pos), jnp.asarray(alive),
            jnp.asarray(temps), jnp.asarray(top_ks), jnp.asarray(keys),
            jnp.asarray(poison))
        nxt, bad = np.asarray(nxt), np.asarray(bad)
        for i in ready:
            if bad[i]:
                self._quarantine(i, info)
                continue
            s = self.sched.slots[i]
            s.out.append(int(nxt[i]))
            info["decoded"] += 1
            if s.finished():
                self._finish(i, info)

    def _quarantine(self, i, info):
        """Isolate a slot whose logits went non-finite: typed ``FAILED``
        with the partial stream, pages freed, neighbors untouched (their
        lanes sampled from their own finite rows this very step)."""
        s = self.sched.evict(i)
        self.pool.free_slot(i)
        self._record(s.req.rid, Outcome.FAILED, tokens=s.out,
                     detail="non-finite logits: slot quarantined")
        self.stats.quarantined += 1
        self.stats.failed += 1
        info["quarantined"] += 1

    def _prefill_block(self, i, s, blk, info):
        """One incremental forward over the slot's next ``blk`` prompt
        tokens: the block's K/V lands in the slot's pages inside the
        call (quantized when ``kv_bits > 0``) and per-layer recurrent /
        window carries ride in the slot's cache rows.  On the final
        block the request's first token is sampled from the block's
        last-position logits — the same row the one-shot oracle's
        blockwise prefill produces, so streams stay bit-exact."""
        start = s.prefill_progress
        tok = jnp.asarray(s.req.prompt[None, start:start + blk], jnp.int32)
        logits, self.caches = self._chunk(
            self.params, self.cfg, self.caches, self._page_table(), tok,
            jnp.asarray(i, jnp.int32), jnp.asarray(start, jnp.int32))
        s.prefill_progress += blk
        self.stats.prefill_calls += 1
        self.stats.prefill_tokens += blk
        info["prefill_tokens"] += blk
        if s.prefill_progress < s.req.prompt_len:
            return
        key = (np.asarray(sampling.slot_key(s.req.seed, 0))
               if s.req.temperature > 0 else self._zero_key)
        tok0, bad = self._sample(
            logits[:, -1], jnp.asarray([s.req.temperature], jnp.float32),
            jnp.asarray([s.req.top_k], jnp.int32),
            jnp.asarray(key[None, :]))
        self.stats.prefill_samples += 1
        s.prefilled = True
        if bool(np.asarray(bad)[0]):
            self._quarantine(i, info)
            return
        s.out.append(int(np.asarray(tok0)[0]))
        if s.finished():
            self._finish(i, info)

    def _finish(self, i, info):
        s = self.sched.evict(i)
        self.pool.free_slot(i)
        self.outputs[s.req.rid] = np.asarray(s.out, np.int32)
        self._record(s.req.rid, Outcome.FINISHED, tokens=s.out)
        self.stats.finished += 1
        self.stats.delivered_tokens += len(s.out)
        info["finished"] += 1

    def _resolve_no_progress(self, stalled, info):
        if stalled:
            # every runnable slot is page-starved and no admission or
            # prefill could proceed: preempt the youngest, replay later.
            # Injected pressure spikes (seized pages) are transient by
            # construction — wait them out instead of burning a
            # request's preemption budget on borrowed starvation.
            if self.pool.seized:
                return
            j = max(stalled, key=lambda i: self.sched.slots[i].admit_seq)
            s = self.sched.evict(j)
            self.pool.free_slot(j)
            rid = s.req.rid
            n = self._preempt_counts.get(rid, 0) + 1
            self._preempt_counts[rid] = n
            self.stats.preemptions += 1
            info["preempted"] = 1
            if n > self.max_preemptions:
                # livelock breaker: two page-starved giants would
                # otherwise ping-pong this resolver forever
                self._record(rid, Outcome.FAILED, tokens=s.out,
                             detail=f"preemption budget exhausted "
                                    f"({n - 1} > {self.max_preemptions} "
                                    f"would never converge)")
                self.stats.failed += 1
                return
            # Request is immutable (progress lives on SlotState): the
            # replay reuses it as-is and regenerates the same stream
            self.sched.requeue_front(s.req)
        elif self.sched.queue:
            if self.pool.seized or self.pool.used_pages:
                # pages will free (pressure release / neighbor finish);
                # the queue head retries admission next step
                self.stats.stall_events += 1
                return
            # defensive: submit() guards total-size up front, so an
            # unadmittable head with an idle pool is a logic error —
            # fail that request typed instead of killing the batch
            req = self.sched.queue.popleft()
            self._record(
                req.rid, Outcome.FAILED,
                detail=f"prompt needs "
                       f"{self.pool.pages_for_len(req.prompt_len)} pages, "
                       f"pool has {self.pool.n_pages} — unadmittable")
            self.stats.failed += 1
