"""Request queue + slot scheduler for the continuous-batching engine.

Requests wait in a FIFO queue until a batch slot frees; an admitted
request occupies its slot through (chunked) prefill and decode, tracking
its own position, generated tokens, and completion (EOS or max-new-
tokens).  The slot set is fixed-size: admission and eviction only flip
host-side state, never the compiled step's shapes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request.

    ``temperature == 0`` is greedy (the differential-oracle setting);
    ``top_k <= 0`` samples the full vocabulary.  ``seed`` drives the
    per-request sampling stream — a request's tokens depend only on its
    own (prompt, seed), never on batch mates or admission timing.
    ``deadline_steps`` bounds how many *engine steps* after submission
    the request may stay unfinished (steps, not wall time, so chaos
    replays are deterministic); expiry yields a typed
    ``DEADLINE_EXCEEDED`` outcome and frees the slot/pages immediately.
    """
    rid: int
    prompt: np.ndarray
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    deadline_steps: Optional[int] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.deadline_steps is not None and self.deadline_steps < 1:
            raise ValueError("deadline_steps must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    def to_json(self) -> dict:
        """Snapshot record (the prompt array rides in the npz)."""
        return {"rid": int(self.rid),
                "max_new_tokens": int(self.max_new_tokens),
                "eos_id": None if self.eos_id is None else int(self.eos_id),
                "temperature": float(self.temperature),
                "top_k": int(self.top_k), "seed": int(self.seed),
                "deadline_steps": (None if self.deadline_steps is None
                                   else int(self.deadline_steps))}

    @classmethod
    def from_json(cls, rec: dict, prompt: np.ndarray) -> "Request":
        return cls(rid=int(rec["rid"]), prompt=prompt,
                   max_new_tokens=int(rec["max_new_tokens"]),
                   eos_id=rec["eos_id"], temperature=rec["temperature"],
                   top_k=int(rec["top_k"]), seed=int(rec["seed"]),
                   deadline_steps=rec.get("deadline_steps"))


class SlotState:
    """Runtime state of one occupied batch slot."""

    __slots__ = ("req", "admit_seq", "prefill_progress", "prefilled", "out")

    def __init__(self, req: Request, admit_seq: int):
        self.req = req
        self.admit_seq = admit_seq
        self.prefill_progress = 0      # prompt tokens computed so far
        self.prefilled = False
        self.out: List[int] = []       # generated tokens (first from prefill)

    @property
    def write_pos(self) -> int:
        """Cache position the next decode step writes (the position of
        the last generated token, which the step feeds back in)."""
        return self.req.prompt_len + len(self.out) - 1

    @property
    def n_generated(self) -> int:
        return len(self.out)

    def finished(self) -> bool:
        if not self.out:
            return False
        if len(self.out) >= self.req.max_new_tokens:
            return True
        return (self.req.eos_id is not None
                and self.out[-1] == self.req.eos_id)

    def to_json(self) -> dict:
        """Snapshot record; the request rides separately (by rid)."""
        return {"rid": int(self.req.rid), "admit_seq": int(self.admit_seq),
                "prefill_progress": int(self.prefill_progress),
                "prefilled": bool(self.prefilled),
                "out": [int(t) for t in self.out]}

    @classmethod
    def from_json(cls, rec: dict, req: Request) -> "SlotState":
        st = cls(req, int(rec["admit_seq"]))
        st.prefill_progress = int(rec["prefill_progress"])
        st.prefilled = bool(rec["prefilled"])
        st.out = [int(t) for t in rec["out"]]
        return st


class SlotScheduler:
    """Admit/evict requests over a fixed set of ``n_slots`` batch slots."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.queue: deque = deque()
        self.slots: List[Optional[SlotState]] = [None] * n_slots
        self._admit_seq = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def requeue_front(self, req: Request) -> None:
        self.queue.appendleft(req)

    def free_ids(self):
        return [i for i, s in enumerate(self.slots) if s is None]

    def running_ids(self):
        """Slots with committed prefill, decoding."""
        return [i for i, s in enumerate(self.slots)
                if s is not None and s.prefilled]

    def prefilling_ids(self):
        return [i for i, s in enumerate(self.slots)
                if s is not None and not s.prefilled]

    def occupancy(self) -> float:
        return sum(s is not None for s in self.slots) / self.n_slots

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def admit(self, slot: int, req: Request) -> SlotState:
        assert self.slots[slot] is None, slot
        st = SlotState(req, self._admit_seq)
        self._admit_seq += 1
        self.slots[slot] = st
        return st

    def evict(self, slot: int) -> SlotState:
        st = self.slots[slot]
        assert st is not None, slot
        self.slots[slot] = None
        return st

    def slot_of(self, rid: int) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is not None and s.req.rid == rid:
                return i
        return None

    def remove_queued(self, rid: int) -> Optional[Request]:
        """Drop a still-queued request (cancellation / deadline expiry
        before admission)."""
        for req in self.queue:
            if req.rid == rid:
                self.queue.remove(req)
                return req
        return None
