"""The one-shot lockstep greedy loop — the engine's reference oracle.

This is the pre-engine serving path (a fixed batch, lockstep prefill,
greedy decode until the longest request finishes) that used to be
duplicated in ``launch/serve.py`` and ``scripts/smoke_serve_packed.py``.
The continuous-batching engine must reproduce each request's greedy
token stream from this loop exactly (``tests/test_engine.py``), so it
lives here as the single shared implementation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelConfig, decode_step, prefill

Array = jax.Array

# module-level jits: every greedy_generate call (and bench iteration)
# shares one trace/compile cache per (config, shape) instead of
# recompiling per invocation.  ``block`` picks the blockwise-prefill
# partition — engine differential tests pass the engine's effective
# prefill chunk so oracle and engine run the same block sequence.
jit_prefill = jax.jit(prefill, static_argnums=1,
                      static_argnames=("last_logits_only", "block"))
_STEP = jax.jit(decode_step, static_argnums=1)


def grow_caches(caches, prompt_len: int, gen_len: int):
    """Pad prefill caches (capacity = prompt_len on the sequence axis) to
    capacity prompt_len + gen_len for the decode loop."""
    def grow(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == prompt_len:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, gen_len)
            return jnp.pad(leaf, pad)
        return leaf

    return jax.tree_util.tree_map(grow, caches)


def greedy_generate(params, cfg: ModelConfig, prompts: Array, gen_len: int,
                    collect_logits: bool = False,
                    block: Optional[int] = None
                    ) -> Tuple[Array, Optional[Array]]:
    """Lockstep greedy generation for a same-length prompt batch.

    prompts [B, S] int32 → (tokens [B, gen_len] int32, and — when
    ``collect_logits`` — the per-step last-position logits
    [B, gen_len, V] f32).  Token 0 comes from the prefill logits; each
    decode step feeds the previous token at position S + t.  ``block``:
    blockwise-prefill partition (see ``transformer.prefill``).
    """
    b, prompt_len = prompts.shape
    logits0, caches = jit_prefill(params, cfg, prompts,
                                  last_logits_only=True, block=block)
    caches = grow_caches(caches, prompt_len, gen_len)
    tok = jnp.argmax(logits0[:, -1], -1)[:, None].astype(jnp.int32)
    toks = [tok]
    logs = [logits0[:, -1:]] if collect_logits else None
    for t in range(gen_len - 1):
        logits, caches = _STEP(params, cfg, caches, tok,
                               jnp.asarray(prompt_len + t, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(tok)
        if collect_logits:
            logs.append(logits[:, -1:])
    tokens = jnp.concatenate(toks, axis=1)
    return tokens, (jnp.concatenate(logs, axis=1) if collect_logits
                    else None)


def truncate_at_eos(tokens, eos_id: Optional[int]) -> np.ndarray:
    """Cut one request's stream after the first EOS (inclusive)."""
    tokens = np.asarray(tokens).reshape(-1)
    if eos_id is None:
        return tokens
    hits = np.nonzero(tokens == eos_id)[0]
    return tokens[:hits[0] + 1] if hits.size else tokens
