"""Paged KV-cache bookkeeping: a fixed pool of fixed-size pages with a
per-slot page table.

The device-side pools live in the model cache tree
(``transformer.init_paged_cache``); this module owns the *host-side*
allocation state: the free list, per-slot ownership, and the int32 page
table the fused decode step consumes.  Physical page 0 is reserved as
the **trash page** — dead slots' writes and unallocated table entries
point at it, so the decode step's shapes never depend on which slots are
live.  Freeing a finished slot returns its pages to the free list
immediately (LIFO, so a queued request reuses the hottest pages first).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import kvquant


def kv_page_footprint(page_size: int, n_kv: int, head_dim: int,
                      kv_bits: int = 0, kv_cb_mode: str = "page",
                      itemsize: int = 4) -> int:
    """Stored HBM bytes of ONE page of ONE cached tensor (K or V).

    Dense pages store ``page·n_kv·head_dim`` scalars; quantized pages
    store bit-packed uint32 words (one row per (token, kv-head)) plus
    the per-page codebooks — the eq.-14 byte accounting with KV bits as
    the free variable.  ``bench_engine``'s equal-HBM rows and
    ``launch/report.py`` both quote this function.
    """
    if not kv_bits:
        return n_kv * kvquant.dense_page_bytes(page_size, head_dim,
                                               itemsize)
    kvquant.check_kv_bits(kv_bits)
    n_cb = n_kv if kv_cb_mode == "head" else 1
    word_bytes = page_size * n_kv * kvquant.words_per(head_dim,
                                                      kv_bits) * 4
    return word_bytes + n_cb * kvquant.kv_entries(kv_bits) * itemsize


def mla_page_footprint(page_size: int, kv_lora: int, rope_dim: int,
                       kv_bits: int = 0, itemsize: int = 4) -> int:
    """Stored HBM bytes of ONE latent page (c_kv + k_rope tensors)."""
    if not kv_bits:
        return (kvquant.dense_page_bytes(page_size, kv_lora, itemsize)
                + kvquant.dense_page_bytes(page_size, rope_dim, itemsize))
    kvquant.check_kv_bits(kv_bits)
    return (kvquant.quant_page_bytes(page_size, kv_lora, kv_bits, 1,
                                     itemsize)
            + kvquant.quant_page_bytes(page_size, rope_dim, kv_bits, 1,
                                       itemsize))


def equal_hbm_slots(n_slots: int, page_size: int, n_kv: int, head_dim: int,
                    kv_bits: int, kv_cb_mode: str = "page",
                    itemsize: int = 4) -> int:
    """How many slots fit in the HBM that ``n_slots`` dense-KV slots
    occupy, once pages quantize to ``kv_bits`` (slots scale with the
    page-byte ratio; pages per slot are geometry-fixed)."""
    dense = kv_page_footprint(page_size, n_kv, head_dim, 0,
                              itemsize=itemsize)
    quant = kv_page_footprint(page_size, n_kv, head_dim, kv_bits,
                              kv_cb_mode, itemsize)
    return max(n_slots, n_slots * dense // quant)


class PagePool:
    """Host-side page allocator for ``n_slots`` batch slots.

    Usable physical pages are 1..n_pages (0 is the trash page); each
    slot may own at most ``max_pages_per_slot`` (== ceil(max_seq /
    page_size)).
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages_per_slot: int):
        if n_pages < 1:
            raise ValueError("need at least one usable page")
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.max_pages_per_slot = max_pages_per_slot
        self._free = list(range(n_pages, 0, -1))     # LIFO reuse
        self._owned = [[] for _ in range(n_slots)]
        self._seized = []         # pages withheld by pressure injection
        self.table = np.zeros((n_slots, max_pages_per_slot), np.int32)
        self.version = 0          # bumped on any table change (host cache
        #                           of the device-side table keys on it)

    # -- accounting ---------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Pages owned by live slots.  Seized pages are *withheld*, not
        used — they report via :attr:`seized`, so a pressure spike never
        inflates utilization into looking like real KV residency."""
        return self.n_pages - len(self._free) - len(self._seized)

    def utilization(self) -> float:
        """Fraction of the pool owned by live slots (excludes seized)."""
        return self.used_pages / max(self.n_pages, 1)

    def pages_of(self, slot: int):
        return list(self._owned[slot])

    def pages_for_len(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    # -- alloc / free -------------------------------------------------------

    def alloc(self, slot: int, n: int = 1) -> bool:
        """Append ``n`` pages to ``slot``; all-or-nothing."""
        if (len(self._free) < n
                or len(self._owned[slot]) + n > self.max_pages_per_slot):
            return False
        for _ in range(n):
            pg = self._free.pop()
            self.table[slot, len(self._owned[slot])] = pg
            self._owned[slot].append(pg)
        self.version += 1
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Slot's pages cover logical position ``pos`` (alloc on demand).

        Returns False when the pool is exhausted (the engine then masks
        the slot for this step — a *stall*, resolved when another slot
        frees pages or by preemption)."""
        need = pos // self.page_size + 1
        if need > self.max_pages_per_slot:
            return False
        while len(self._owned[slot]) < need:
            if not self.alloc(slot, 1):
                return False
        return True

    def free_slot(self, slot: int) -> int:
        """Release every page of ``slot`` back to the pool."""
        n = len(self._owned[slot])
        while self._owned[slot]:
            self._free.append(self._owned[slot].pop())
        self.table[slot, :] = 0
        if n:
            self.version += 1
        return n

    # -- pressure injection (chaos harness) ---------------------------------

    @property
    def seized(self) -> int:
        """Pages currently withheld from the free list by an injected
        pressure spike (``engine/chaos.py``)."""
        return len(self._seized)

    def seize(self, n: int) -> int:
        """Withhold up to ``n`` free pages (a simulated pressure spike:
        the allocator behaves exactly as if neighbors held them).  Never
        touches owned pages — live requests' KV is untouchable.  Returns
        how many were actually seized."""
        taken = 0
        while taken < n and self._free:
            self._seized.append(self._free.pop())
            taken += 1
        return taken

    def release(self, n: Optional[int] = None) -> int:
        """Return ``n`` seized pages (default: all) to the free list.
        Tolerates over-release — a restored snapshot may predate the
        matching :meth:`seize`."""
        if n is None:
            n = len(self._seized)
        given = 0
        while given < n and self._seized:
            self._free.append(self._seized.pop())
            given += 1
        return given

    # -- snapshot (engine/snapshot.py) --------------------------------------

    def state_dict(self) -> dict:
        """Full host-side allocator state, JSON-serializable except the
        table (which rides in the snapshot's npz).

        Seized pages are recorded as *free*: a pressure spike is
        injected, transient state — the simulated page-hogging neighbor
        dies with the process, so a restored engine must not inherit
        the starvation (its injector may no longer hold the matching
        release)."""
        return {"free": list(self._free) + list(self._seized),
                "owned": [list(o) for o in self._owned],
                "seized": [],
                "version": int(self.version)}

    def load_state_dict(self, state: dict, table: np.ndarray):
        got = (len(state["free"]) + len(state["seized"])
               + sum(len(o) for o in state["owned"]))
        if got != self.n_pages or len(state["owned"]) != self.n_slots:
            raise ValueError(
                f"pool snapshot geometry mismatch: {got} pages / "
                f"{len(state['owned'])} slots vs pool {self.n_pages} / "
                f"{self.n_slots}")
        self._free = [int(p) for p in state["free"]]
        self._owned = [[int(p) for p in o] for o in state["owned"]]
        self._seized = [int(p) for p in state["seized"]]
        self.table = np.asarray(table, np.int32).reshape(self.table.shape)
        self.version = int(state["version"]) + 1   # force device re-upload
