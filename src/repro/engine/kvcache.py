"""Paged KV-cache bookkeeping: a fixed pool of fixed-size pages with a
per-slot page table.

The device-side pools live in the model cache tree
(``transformer.init_paged_cache``); this module owns the *host-side*
allocation state: the free list, per-slot ownership, and the int32 page
table the fused decode step consumes.  Physical page 0 is reserved as
the **trash page** — dead slots' writes and unallocated table entries
point at it, so the decode step's shapes never depend on which slots are
live.  Freeing a finished slot returns its pages to the free list
immediately (LIFO, so a queued request reuses the hottest pages first).
"""
from __future__ import annotations

import numpy as np


class PagePool:
    """Host-side page allocator for ``n_slots`` batch slots.

    Usable physical pages are 1..n_pages (0 is the trash page); each
    slot may own at most ``max_pages_per_slot`` (== ceil(max_seq /
    page_size)).
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages_per_slot: int):
        if n_pages < 1:
            raise ValueError("need at least one usable page")
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.max_pages_per_slot = max_pages_per_slot
        self._free = list(range(n_pages, 0, -1))     # LIFO reuse
        self._owned = [[] for _ in range(n_slots)]
        self.table = np.zeros((n_slots, max_pages_per_slot), np.int32)
        self.version = 0          # bumped on any table change (host cache
        #                           of the device-side table keys on it)

    # -- accounting ---------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / max(self.n_pages, 1)

    def pages_of(self, slot: int):
        return list(self._owned[slot])

    def pages_for_len(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    # -- alloc / free -------------------------------------------------------

    def alloc(self, slot: int, n: int = 1) -> bool:
        """Append ``n`` pages to ``slot``; all-or-nothing."""
        if (len(self._free) < n
                or len(self._owned[slot]) + n > self.max_pages_per_slot):
            return False
        for _ in range(n):
            pg = self._free.pop()
            self.table[slot, len(self._owned[slot])] = pg
            self._owned[slot].append(pg)
        self.version += 1
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Slot's pages cover logical position ``pos`` (alloc on demand).

        Returns False when the pool is exhausted (the engine then masks
        the slot for this step — a *stall*, resolved when another slot
        frees pages or by preemption)."""
        need = pos // self.page_size + 1
        if need > self.max_pages_per_slot:
            return False
        while len(self._owned[slot]) < need:
            if not self.alloc(slot, 1):
                return False
        return True

    def free_slot(self, slot: int) -> int:
        """Release every page of ``slot`` back to the pool."""
        n = len(self._owned[slot])
        while self._owned[slot]:
            self._free.append(self._owned[slot].pop())
        self.table[slot, :] = 0
        if n:
            self.version += 1
        return n
