"""Per-slot sampling: greedy + temperature / top-k with per-request keys.

A slot's next token depends only on (its logits row, its request's seed,
its step index) — never on batch mates — so streams are reproducible
across admission orders, slot assignments, and engine restarts.
``temperature == 0`` rows take the exact ``argmax`` the one-shot oracle
uses, keeping the engine-vs-one-shot differential bit-for-bit on greedy
requests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def slot_key(seed: int, n_generated: int) -> Array:
    """The sampling key for a request's ``n_generated``-th token."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), n_generated)


def _sample_one(logits: Array, temp: Array, top_k: Array, key: Array):
    v = logits.shape[-1]
    t = jnp.maximum(temp, 1e-6)
    k = jnp.where(top_k <= 0, v, jnp.clip(top_k, 1, v))
    # rank by (logit desc, vocab index asc) — argsort is stable, so ties
    # at the cutoff break toward the lower token id and exactly k
    # candidates survive; a `logits >= cutoff` mask would keep every
    # token tied with the k-th and silently widen the nucleus
    order = jnp.argsort(-logits)
    ranks = jnp.zeros((v,), jnp.int32).at[order].set(
        jnp.arange(v, dtype=jnp.int32))
    masked = jnp.where(ranks < k, logits, -jnp.inf)
    return jax.random.categorical(key, masked / t).astype(jnp.int32)


def sample_tokens(logits: Array, temps: Array, top_ks: Array,
                  keys: Array) -> Array:
    """logits [B, V] f32; temps [B] (0 → greedy); top_ks [B] int32
    (<= 0 → full vocab); keys [B, 2] uint32 (``slot_key`` data).
    Returns [B] int32."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    sampled = jax.vmap(_sample_one)(logits, temps, top_ks, keys)
    return jnp.where(temps <= 0.0, greedy, sampled)


def sample_and_flag(logits: Array, temps: Array, top_ks: Array,
                    keys: Array):
    """:func:`sample_tokens` plus a per-row poison flag.

    ``bad[i]`` is True when row ``i`` contains any non-finite logit
    (NaN/inf — a numerically poisoned slot).  The engine quarantines
    flagged slots (typed ``FAILED`` outcome, pages freed) instead of
    streaming garbage; sampling runs on a zeroed copy of bad rows so a
    neighbor's lane never sees the NaN.  Returns (tokens [B] int32,
    bad [B] bool)."""
    bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
    safe = jnp.where(bad[:, None], jnp.zeros_like(logits), logits)
    return sample_tokens(safe, temps, top_ks, keys), bad
