"""Typed per-request outcomes — the engine's failure-isolation contract.

Every request submitted to the engine ends in exactly one
:class:`Outcome`, recorded as a :class:`RequestResult` in
``Engine.results``.  Nothing about one request's fate may corrupt a
neighbor: an unservable prompt is *rejected* before any page is
reserved, a poisoned slot is *quarantined* while the rest of the batch
keeps decoding, and a supervisor restart replays deterministic streams
so every request that reaches ``FINISHED`` is bit-exact to the one-shot
oracle (``tests/test_chaos.py`` asserts exactly this under seeded fault
schedules).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np


class Outcome(enum.Enum):
    """Terminal state of one request."""

    FINISHED = "finished"                    # full stream delivered
    REJECTED_TOO_LARGE = "rejected_too_large"    # can never fit max_seq/pool
    REJECTED_BACKPRESSURE = "rejected_backpressure"  # bounded queue full
    CANCELLED = "cancelled"                  # client cancel; pages freed
    DEADLINE_EXCEEDED = "deadline_exceeded"  # per-request deadline expired
    FAILED = "failed"                        # quarantined / budget exhausted

    @property
    def ok(self) -> bool:
        return self is Outcome.FINISHED


@dataclasses.dataclass
class RequestResult:
    """One request's terminal record.

    ``tokens`` holds the delivered stream for ``FINISHED`` and whatever
    partial prefix existed at termination otherwise (empty for
    rejections).  ``detail`` is the human-readable reason for every
    non-``FINISHED`` outcome.
    """

    rid: int
    outcome: Outcome
    tokens: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int32))
    detail: str = ""
    n_preemptions: int = 0

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)

    @property
    def ok(self) -> bool:
        return self.outcome.ok

    def to_json(self) -> dict:
        """JSON-serializable record (CHAOS_report.json / snapshot
        manifests); tokens ride separately as arrays."""
        return {"rid": int(self.rid), "outcome": self.outcome.value,
                "detail": self.detail,
                "n_preemptions": int(self.n_preemptions),
                "n_tokens": int(self.tokens.size)}

    @classmethod
    def from_json(cls, rec: dict,
                  tokens: Optional[np.ndarray] = None) -> "RequestResult":
        return cls(rid=int(rec["rid"]), outcome=Outcome(rec["outcome"]),
                   tokens=(tokens if tokens is not None
                           else np.zeros((0,), np.int32)),
                   detail=rec.get("detail", ""),
                   n_preemptions=int(rec.get("n_preemptions", 0)))
