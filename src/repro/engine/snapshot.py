"""Bit-exact engine snapshot / restore + the serving supervisor loop.

A snapshot captures *everything* the step loop depends on — scheduler
queue and slot states, the page allocator (free list / ownership /
seized pages / table), live KV pages, per-request bookkeeping, typed
results, and stats — so a restored engine's next step is byte-identical
to the step the killed engine would have taken.  Sampling keys need no
serialization: a slot's key is ``slot_key(seed, n_generated)``, both
already in the snapshot.

Layout (shared atomic-write discipline with ``train/checkpoint.py``)::

    <dir>/snap_00000042.tmp/  → written fully, then os.rename →
    <dir>/snap_00000042/
        arrays.npz            # KV cache leaves, page table, prompts, tokens
        manifest.json         # geometry, scheduler/pool state, npz sha256
    <dir>/LATEST              # written last (atomic pointer)

:func:`supervised_serve` wraps an engine in the restart loop the
training side uses (``repro.fault``): periodic snapshots, restore on
:class:`~repro.fault.SimulatedNodeFailure` (bounded restarts,
exponential backoff), save-then-resume on
:class:`~repro.fault.PreemptionSignal`, and controlled
kill-and-restore.  It **never raises**: when the restart budget is
exhausted it fails the remaining requests typed and returns every
completed stream.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.outcomes import Outcome, RequestResult
from repro.engine.scheduler import Request, SlotState
from repro.fault import (PreemptionSignal, SimulatedNodeFailure,
                         backoff_delay)
from repro.train.checkpoint import atomic_dir, file_sha256, write_pointer

SNAPSHOT_VERSION = 1

_BYTE_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


class SnapshotError(RuntimeError):
    """A snapshot artifact is missing, corrupt, or geometry-incompatible
    with the engine being restored.  The supervisor treats it as 'no
    usable snapshot' (fresh start), never as a crash."""


def _pack_leaf(arr) -> Tuple[np.ndarray, str]:
    """npz-safe encoding: extension dtypes (bf16 etc.) ride as unsigned
    words of the same width, with the true dtype recorded."""
    arr = np.asarray(arr)
    name = str(arr.dtype)
    if arr.dtype.kind in "biufc":
        return arr, name
    return arr.view(_BYTE_VIEW[arr.dtype.itemsize]), name


def _unpack_leaf(arr: np.ndarray, name: str) -> np.ndarray:
    if str(arr.dtype) == name:
        return arr
    try:
        dt = np.dtype(name)
    except TypeError:
        dt = np.dtype(getattr(jnp, name))
    return arr.view(dt)


def _live_requests(eng) -> List[Request]:
    reqs = list(eng.sched.queue)
    for s in eng.sched.slots:
        if s is not None:
            reqs.append(s.req)
    return reqs


def save_snapshot(eng, directory: str, keep: int = 2) -> str:
    """Atomically persist the engine's full serving state; returns the
    snapshot path.  Crash-safe: a kill mid-write leaves the previous
    ``LATEST`` target intact."""
    os.makedirs(directory, exist_ok=True)
    name = f"snap_{eng.stats.steps:08d}"
    final = os.path.join(directory, name)

    arrays: Dict[str, np.ndarray] = {"table": eng.pool.table}
    for req in _live_requests(eng):
        arrays[f"req{req.rid}_prompt"] = req.prompt
    for rid, toks in eng.outputs.items():
        arrays[f"out{rid}"] = np.asarray(toks, np.int32)
    for rid, res in eng.results.items():
        arrays[f"res{rid}_tokens"] = res.tokens
    flat, _ = jax.tree_util.tree_flatten(eng.caches)
    dtypes, shapes = [], []
    for i, leaf in enumerate(flat):
        enc, dt = _pack_leaf(leaf)
        arrays[f"cache{i}"] = enc
        dtypes.append(dt)
        shapes.append(list(np.asarray(leaf).shape))

    manifest = {
        "format": "engine-snapshot",
        "version": SNAPSHOT_VERSION,
        "step": int(eng.stats.steps),
        "geometry": {
            "n_slots": eng.n_slots, "page_size": eng.page_size,
            "max_seq": eng.max_seq, "n_pages": eng.pool.n_pages,
            "token_budget": eng.token_budget,
            "prefill_chunk": eng.prefill_chunk,
            "dtype": str(eng.dtype),
            "kv_bits": eng.kv_bits, "kv_cb_mode": eng.kv_cb_mode,
        },
        "stats": dataclasses.asdict(eng.stats),
        "admit_seq": int(eng.sched._admit_seq),
        "queue": [int(r.rid) for r in eng.sched.queue],
        "requests": [r.to_json() for r in _live_requests(eng)],
        "slots": [None if s is None else s.to_json()
                  for s in eng.sched.slots],
        "pool": eng.pool.state_dict(),
        "outputs": sorted(int(r) for r in eng.outputs),
        "results": [eng.results[rid].to_json()
                    for rid in sorted(eng.results)],
        "submit_step": {str(k): int(v)
                        for k, v in eng._submit_step.items()},
        "preempt_counts": {str(k): int(v)
                           for k, v in eng._preempt_counts.items()},
        "cache_leaves": len(flat),
        "cache_dtypes": dtypes,
        "cache_shapes": shapes,
    }

    with atomic_dir(final) as tmp:
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **arrays)
        manifest["npz_sha256"] = file_sha256(npz_path)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)

    write_pointer(directory, "LATEST", name)
    snaps = sorted(d for d in os.listdir(directory)
                   if d.startswith("snap_") and not d.endswith(".tmp"))
    for d in snaps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    return final


def latest_snapshot(directory: str) -> Optional[str]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return os.path.join(directory, f.read().strip())


def restore_into(eng, directory: str) -> int:
    """Restore the ``LATEST`` snapshot under ``directory`` into a
    freshly constructed engine of identical geometry; returns the
    snapshot's step.  Raises :class:`SnapshotError` on any missing,
    corrupt, or mismatched artifact (never a partial restore: the
    engine is only mutated after every piece validates)."""
    path = latest_snapshot(directory)
    if path is None or not os.path.isdir(path):
        raise SnapshotError(f"no snapshot under {directory}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise SnapshotError(f"unreadable snapshot manifest at {path}: {e}")
    if manifest.get("format") != "engine-snapshot":
        raise SnapshotError(f"{path} is not an engine snapshot")
    if manifest.get("version") != SNAPSHOT_VERSION:
        raise SnapshotError(
            f"snapshot version {manifest.get('version')} != "
            f"{SNAPSHOT_VERSION}")

    npz_path = os.path.join(path, "arrays.npz")
    if not os.path.exists(npz_path):
        raise SnapshotError(f"{path} missing arrays.npz")
    got = file_sha256(npz_path)
    if got != manifest["npz_sha256"]:
        raise SnapshotError(
            f"snapshot {path} failed integrity check: arrays.npz sha256 "
            f"{got[:12]}… != manifest {manifest['npz_sha256'][:12]}…")

    geo = manifest["geometry"]
    mine = {"n_slots": eng.n_slots, "page_size": eng.page_size,
            "max_seq": eng.max_seq, "n_pages": eng.pool.n_pages,
            "token_budget": eng.token_budget,
            "prefill_chunk": eng.prefill_chunk, "dtype": str(eng.dtype),
            "kv_bits": eng.kv_bits, "kv_cb_mode": eng.kv_cb_mode}
    if geo != mine:
        diff = {k: (geo.get(k), mine[k]) for k in mine
                if geo.get(k) != mine[k]}
        raise SnapshotError(f"snapshot geometry mismatch: {diff}")

    try:
        data = np.load(npz_path)
        flat, treedef = jax.tree_util.tree_flatten(eng.caches)
        n = manifest["cache_leaves"]
        if n != len(flat):
            raise SnapshotError(
                f"snapshot has {n} cache leaves, engine has {len(flat)}")
        new_flat = []
        for i, leaf in enumerate(flat):
            arr = _unpack_leaf(data[f"cache{i}"],
                               manifest["cache_dtypes"][i])
            want = np.asarray(leaf)
            if list(arr.shape) != list(want.shape):
                raise SnapshotError(
                    f"cache leaf {i} shape {list(arr.shape)} != engine "
                    f"{list(want.shape)}")
            new_flat.append(jnp.asarray(arr))

        reqs: Dict[int, Request] = {}
        for rec in manifest["requests"]:
            rid = int(rec["rid"])
            reqs[rid] = Request.from_json(rec, data[f"req{rid}_prompt"])

        eng.caches = treedef.unflatten(new_flat)
        eng.pool.load_state_dict(manifest["pool"], data["table"])
        eng.sched.queue.clear()
        for rid in manifest["queue"]:
            eng.sched.queue.append(reqs[int(rid)])
        for i, rec in enumerate(manifest["slots"]):
            eng.sched.slots[i] = (None if rec is None else
                                  SlotState.from_json(rec,
                                                      reqs[int(rec["rid"])]))
        eng.sched._admit_seq = int(manifest["admit_seq"])
        eng.outputs = {int(rid): np.asarray(data[f"out{rid}"], np.int32)
                       for rid in manifest["outputs"]}
        eng.results = {}
        for rec in manifest["results"]:
            rid = int(rec["rid"])
            key = f"res{rid}_tokens"
            toks = data[key] if key in data else None
            eng.results[rid] = RequestResult.from_json(rec, toks)
        eng._submit_step = {int(k): int(v)
                            for k, v in manifest["submit_step"].items()}
        eng._preempt_counts = {int(k): int(v)
                               for k, v in
                               manifest["preempt_counts"].items()}
        for k, v in manifest["stats"].items():
            setattr(eng.stats, k, v)
    except SnapshotError:
        raise
    except (KeyError, ValueError, OSError) as e:
        raise SnapshotError(f"corrupt snapshot at {path}: {e!r}")
    return int(manifest["step"])


@dataclasses.dataclass
class ServeSupervisorConfig:
    """Knobs for :func:`supervised_serve` (mirrors
    ``train.fault.SupervisorConfig``)."""

    snapshot_dir: str
    snapshot_every: int = 8        # steps between periodic snapshots
    max_restarts: int = 4          # failure-restart budget
    backoff_s: float = 0.0         # base restart delay (0 in tests)
    max_steps: int = 100_000       # hard overrun bound per incarnation


@dataclasses.dataclass
class ServeReport:
    """What the supervisor did — ``tests/test_chaos.py`` and
    ``scripts/smoke_chaos.py`` assert on these counters."""

    restarts: int = 0
    snapshots: int = 0
    restores: int = 0
    kill_restores: int = 0
    preemptions_signalled: int = 0
    fresh_starts: int = 0
    aborted: bool = False
    final_stats: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def supervised_serve(make_engine: Callable[[], object],
                     requests: List[Request],
                     cfg: ServeSupervisorConfig,
                     injector=None):
    """Serve ``requests`` to completion under a restart supervisor.

    ``make_engine`` builds a fresh engine (same params/geometry every
    call).  ``injector`` (e.g. ``chaos.FaultPlan``) is consulted before
    every step: it may mutate the engine (poison a slot, seize pages),
    raise :class:`SimulatedNodeFailure` / :class:`PreemptionSignal`, or
    return ``"kill_restore"`` to demand an immediate snapshot → teardown
    → restore round trip.

    Returns ``(outputs, results, report)`` — outputs is rid → tokens for
    FINISHED requests, results maps *every* submitted rid to its typed
    :class:`~repro.engine.outcomes.RequestResult`.  Never raises on
    injected faults: an exhausted restart budget fails the remaining
    requests typed and returns what completed.
    """
    report = ServeReport()

    def fresh() -> object:
        eng = make_engine()
        for r in requests:
            eng.submit(r)
        report.fresh_starts += 1
        return eng

    def revive() -> object:
        """Restore from the latest snapshot, or start fresh when none is
        usable (rejections re-record deterministically on resubmit)."""
        eng = make_engine()
        try:
            restore_into(eng, cfg.snapshot_dir)
            report.restores += 1
            return eng
        except SnapshotError:
            return fresh()

    eng = fresh()
    while True:
        try:
            while eng.sched.has_work():
                if eng.stats.steps >= cfg.max_steps:
                    eng.abort_remaining(
                        f"supervisor exceeded max_steps ({cfg.max_steps})")
                    report.aborted = True
                    break
                step = eng.stats.steps
                action = injector.apply(eng, step) if injector else None
                if action == "kill_restore":
                    save_snapshot(eng, cfg.snapshot_dir)
                    report.snapshots += 1
                    report.kill_restores += 1
                    eng = revive()
                    continue
                if (cfg.snapshot_every and step > 0
                        and step % cfg.snapshot_every == 0):
                    save_snapshot(eng, cfg.snapshot_dir)
                    report.snapshots += 1
                eng.step()
            break
        except PreemptionSignal:
            # save-and-exit; in-process we immediately resume from the
            # snapshot we just wrote, exercising the full round trip
            report.preemptions_signalled += 1
            save_snapshot(eng, cfg.snapshot_dir)
            report.snapshots += 1
            eng = revive()
        except SimulatedNodeFailure:
            report.restarts += 1
            if report.restarts > cfg.max_restarts:
                eng.abort_remaining("restart budget exhausted")
                report.aborted = True
                break
            delay = backoff_delay(report.restarts, cfg.backoff_s)
            if delay:
                time.sleep(delay)
            eng = revive()
    report.final_stats = eng.stats.summary()
    return dict(eng.outputs), dict(eng.results), report
