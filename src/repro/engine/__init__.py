"""Continuous-batching serving engine over the packed qleaf model.

The freed HBM of the eq.-14 packed layout is cashed in as serving
capacity: a fixed set of batch slots decodes in lockstep from a paged KV
cache while a scheduler admits queued requests into slots as they free —
no recompile on admission, short requests' pages immediately reusable by
queued ones.

* :mod:`repro.engine.scheduler` — request queue + slot scheduler;
* :mod:`repro.engine.kvcache`   — fixed-size page pool + per-slot tables;
* :mod:`repro.engine.sampling`  — per-slot greedy / temperature / top-k;
* :mod:`repro.engine.engine`    — the step loop (chunked prefill +
  decode under a per-step token budget);
* :mod:`repro.engine.oneshot`   — the lockstep one-shot greedy loop, the
  engine's reference oracle (formerly duplicated in launch/serve.py and
  scripts/smoke_serve_packed.py);
* :mod:`repro.engine.outcomes`  — typed per-request terminal outcomes
  (the failure-isolation contract);
* :mod:`repro.engine.snapshot`  — bit-exact snapshot/restore + the
  ``supervised_serve`` restart loop;
* :mod:`repro.engine.chaos`     — seeded deterministic fault injection.
"""
from repro.engine.chaos import FaultEvent, FaultPlan
from repro.engine.engine import Engine, EngineStats
from repro.engine.kvcache import (PagePool, equal_hbm_slots,
                                  kv_page_footprint, mla_page_footprint)
from repro.engine.oneshot import greedy_generate, truncate_at_eos
from repro.engine.outcomes import Outcome, RequestResult
from repro.engine.sampling import sample_tokens, slot_key
from repro.engine.scheduler import Request, SlotScheduler
from repro.engine.snapshot import (ServeReport, ServeSupervisorConfig,
                                   SnapshotError, restore_into,
                                   save_snapshot, supervised_serve)

__all__ = ["Engine", "EngineStats", "PagePool", "Request", "SlotScheduler",
           "greedy_generate", "truncate_at_eos", "sample_tokens",
           "slot_key", "Outcome", "RequestResult", "FaultEvent",
           "FaultPlan", "SnapshotError", "ServeReport",
           "ServeSupervisorConfig", "save_snapshot", "restore_into",
           "supervised_serve", "kv_page_footprint", "mla_page_footprint",
           "equal_hbm_slots"]
