"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON
records in experiments/dryrun (and the §Perf deltas from experiments/perf).

    PYTHONPATH=src python -m repro.launch.report > experiments/tables.md

``--packed <dir>`` instead prints the eq.-14 whole-model compression
report for a PackedModel artifact: the compression rate ρ(K) over *all*
params (the paper's headline number — valid now that serving executes the
packed layout for every quantized leaf, not just MLP), plus the per-leaf
coverage table (which param paths quantize, which stay dense and why).
"""
import argparse
import glob
import json
import os
import sys


def load(d):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 1e9:.2f}"


def dryrun_table(recs, mesh):
    rows = ["| arch | cell | status | peak GB/dev | args GB/dev | "
            "HLO TF/chip | coll GB/chip | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["cell"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['cell']} | {r['status']}: "
                        f"{r.get('reason', '')[:40]} | - | - | - | - | - |")
            continue
        rows.append(
            f"| {r['arch']} | {r['cell']} | ok "
            f"| {fmt_bytes(r['memory']['peak_bytes'])} "
            f"| {fmt_bytes(r['memory']['argument_bytes'])} "
            f"| {r['hlo']['dot_flops_per_chip'] / 1e12:.2f} "
            f"| {r['hlo']['collective_bytes_per_chip'] / 1e9:.2f} "
            f"| {r.get('compile_s', 0):.1f} |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | cell | compute s | memory s | collective s | "
            "dominant | MODEL/HLO | roofline frac | next lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    lever = {
        "collective": "less TP / DP layout, compressed collectives",
        "memory": "quantized weight streaming (codebook_matmul)",
        "compute": "remat policy, causal scheduling, capacity factor",
    }
    for r in sorted(recs, key=lambda r: (r["arch"], r["cell"])):
        if r["mesh"] != "16x16" or r["status"] != "ok":
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['cell']} "
            f"| {rf['compute_term_s']:.4f} | {rf['memory_term_s']:.4f} "
            f"| {rf['collective_term_s']:.4f} | {rf['dominant']} "
            f"| {rf['useful_flops_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.3f} "
            f"| {lever[rf['dominant']]} |")
    return "\n".join(rows)


def packed_report(directory: str) -> None:
    """Eq.-14 whole-model compression rate + leaf-coverage table."""
    from repro.core import PackedModel
    pm = PackedModel.load(directory)
    s = pm.summary()
    print(f"## §Compression — eq. 14, whole model ({s['scheme']})\n")
    print(f"ρ(K={s['k']}) = {s['ratio']:.2f}  "
          f"[{s['bits_per_weight']} bit/weight indices; "
          f"P1={s['p1']} quantized, P0={s['p0']} dense, "
          f"{s['codebook_entries']} codebook floats; "
          f"b={pm.bits_ref}-bit reference: "
          f"{s['ref_bytes']} B → {s['packed_bytes']} B]\n")
    rows = pm.leaf_coverage()
    n_q = sum(r["quantized"] for r in rows)
    print(f"### Leaf coverage — {n_q}/{len(rows)} param paths served "
          f"quantized\n")
    print("(serve route: `qmatmul` = packed codebook matmul; "
          "`qembed+qmatmul_t` = row-packed fused gather + transposed "
          "LM head — every route reads bits_per_index(K)/8 B/weight of "
          "HBM index traffic)\n")
    print("| path | shape | quantized | bits | B/weight | route "
          "| why dense |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        shape = "×".join(map(str, r["shape"]))
        bpw = (f"{r['bytes_per_weight']:g}" if r["quantized"] else "-")
        print(f"| `{r['path']}` | {shape} "
              f"| {'yes' if r['quantized'] else 'no'} "
              f"| {r['bits'] if r['quantized'] else '-'} "
              f"| {bpw} | {r['route'] or '-'} "
              f"| {r['reason'] or '-'} |")


def kv_report(page_size: int, n_kv: int, head_dim: int, n_slots: int,
              max_seq: int, cb_mode: str = "page") -> None:
    """Eq.-14 byte accounting extended to activations: page-pool sizing
    with KV bits as the free variable (what ``--kv-bits`` on
    ``launch/serve.py`` buys at fixed HBM)."""
    from repro.core import kvquant
    from repro.engine.kvcache import equal_hbm_slots, kv_page_footprint

    pages_per_slot = -(-max_seq // page_size)
    print(f"## §KV quantization — eq. 14 on activations "
          f"(page={page_size}, n_kv={n_kv}, head_dim={head_dim}, "
          f"cb_mode={cb_mode})\n")
    print("| kv_bits | B/page (K or V) | B/token/tensor | ratio | "
          f"slots @ equal HBM (dense={n_slots}) |")
    print("|---|---|---|---|---|")
    dense_fp = kv_page_footprint(page_size, n_kv, head_dim, 0)
    for bits in (0,) + kvquant.KV_BITS_CHOICES:
        fp = kv_page_footprint(page_size, n_kv, head_dim, bits, cb_mode)
        bpt = (kvquant.kv_bytes_per_token(bits, head_dim, n_kv) if bits
               else 4.0 * head_dim * n_kv)
        slots = (equal_hbm_slots(n_slots, page_size, n_kv, head_dim,
                                 bits, cb_mode) if bits else n_slots)
        print(f"| {bits or 'dense'} | {fp} | {bpt:g} "
              f"| {dense_fp / fp:.2f}x | {slots} |")
    print(f"\n(pages/slot = ceil(max_seq/page) = {pages_per_slot}; "
          "quantized pages carry packed uint32 index words + per-page "
          "codebooks, so the ratio is below the raw 32/bits bound — "
          "codebook overhead amortizes with page_size·head_dim)")


def audit_table(report: dict) -> str:
    """Human rendering of an ``repro.analysis.audit`` report (the
    AUDIT.json payload, or a path to one)."""
    if isinstance(report, str):
        with open(report) as fh:
            report = json.load(fh)
    lines = [f"## §Static audit — {report['artifact']} "
             f"(config {report['config']})\n"]
    hbm = report.get("checks", {}).get("hbm", {})
    if hbm:
        lines.append("### HBM bytes per weight (compiled-HLO entry "
                     "parameters; eq.-14 exact = bits/8)\n")
        lines.append("| leaf | entry | K | bits | HLO operand | B/weight "
                     "| exact | uses |")
        lines.append("|---|---|---|---|---|---|---|---|")
        for entry, res in sorted(hbm.items()):
            for r in res["rows"]:
                shape = "×".join(map(str, r["hlo_shape"]))
                flag = ("" if r["bytes_per_weight"]
                        == r["expected_bytes_per_weight"] else " ⚠")
                lines.append(
                    f"| `{r['path']}` | {entry} | {r['k']} | {r['bits']} "
                    f"| {r['hlo_dtype']}[{shape}] "
                    f"| {r['bytes_per_weight']:g}{flag} "
                    f"| {r['expected_bytes_per_weight']:g} "
                    f"| {r['uses']} |")
        lines.append("")
    rc = report.get("checks", {}).get("recompile")
    if isinstance(rc, dict) and "events" in rc:
        ev, ct = rc["events"], rc["counts"]
        lines.append(f"### Recompile gate — {ev['steps']} steps, "
                     f"{ev['admitted']} admitted, {ev['finished']} "
                     f"finished, {ev['preemptions']} preempted: "
                     f"0 new jit entries "
                     + "("
                     + ", ".join(f"{k}={v}" for k, v in sorted(ct.items()))
                     + ")\n")
    vm = report.get("checks", {}).get("vmem")
    if vm:
        lines.append(f"### VMEM / block lint — {vm['configs_checked']} "
                     f"configs checked, {len(vm['warnings'])} warnings\n")
    allowed = report.get("allowed_violations", [])
    if allowed:
        lines.append(f"### Allowlisted exceptions ({len(allowed)})\n")
        for v in allowed:
            lines.append(f"- `{v['subject']}` [{v['check']}]: "
                         f"{v['allowed_reason']}")
        lines.append("")
    active = report.get("violations", [])
    if active:
        lines.append(f"### VIOLATIONS ({len(active)}) — audit FAILED\n")
        for v in active:
            lines.append(f"- `{v['subject']}` [{v['check']}]: "
                         f"{v['detail']}")
    else:
        lines.append("**Audit passed** — 0 violations "
                     f"({len(allowed)} documented exceptions).")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--packed", default=None, metavar="DIR",
                    help="print the eq.-14 report for this PackedModel "
                         "artifact instead of the dry-run tables")
    ap.add_argument("--audit", default=None, metavar="AUDIT_JSON",
                    help="render the human table for an AUDIT.json "
                         "written by `python -m repro.analysis.audit`")
    ap.add_argument("--kv", action="store_true",
                    help="print the KV-quantization page-pool sizing "
                         "table (eq. 14 on activation bytes)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-kv", type=int, default=8)
    ap.add_argument("--head-dim", type=int, default=128)
    ap.add_argument("--n-slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=4096)
    ap.add_argument("--kv-cb", choices=("page", "head"), default="page")
    args = ap.parse_args()
    if args.kv:
        kv_report(args.page_size, args.n_kv, args.head_dim, args.n_slots,
                  args.max_seq, args.kv_cb)
        return
    if args.audit:
        print(audit_table(args.audit))
        return
    if args.packed:
        packed_report(args.packed)
        return
    recs = load("experiments/dryrun")
    ok = sum(r["status"] == "ok" for r in recs)
    sk = sum(r["status"] == "skipped" for r in recs)
    print(f"## §Dry-run — {ok} ok / {sk} documented skips "
          f"(of {len(recs)} cells × meshes)\n")
    print("### Single pod (16×16 = 256 chips)\n")
    print(dryrun_table(recs, "16x16"))
    print("\n### Multi-pod (2×16×16 = 512 chips)\n")
    print(dryrun_table(recs, "2x16x16"))
    print("\n## §Roofline — per-cell terms (single pod, v5e constants)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
