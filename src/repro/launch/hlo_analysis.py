"""Static analysis of compiled (SPMD-partitioned) HLO text.

Extracts, with while-loop trip multipliers (from XLA's
``backend_config={"known_trip_count":{"n":...}}``, falling back to the
loop condition's compare constant):

* per-chip collective bytes by op type (all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute);
* per-chip dot FLOPs (cross-check against compiled.cost_analysis()).

Shapes in the post-partitioning module are per-device, so all byte counts
are per-chip (roofline divides by per-link bandwidth directly; global =
×chips).

Byte conventions per collective (ring-traffic approximations using the
spec's "operand sizes"):
  all-reduce          output bytes
  all-gather          output bytes
  reduce-scatter      operand bytes
  all-to-all          output bytes
  collective-permute  output bytes
"""
from __future__ import annotations

import re
import warnings
from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

# Bytes per element.  Sub-byte and FP8 types matter here: the packed
# serving artifacts feed u32 words today, but quantized KV caches and
# entropy-coded artifacts (ROADMAP) will surface u4/f8 operands — and an
# audit that silently counts them as 0 bytes under-reports HBM traffic.
_DTYPE_BYTES: Dict[str, float] = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f4e2m1fn": 0.5,
    "f8e3m4": 1, "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1,
    "f8e4m3fnuz": 1, "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e8m0fnu": 1,
    "c64": 8, "c128": 16,
}
# Shape-like tokens that legitimately carry no byte count.
_BYTELESS_TYPES = {"token", "opaque"}

# Full dtype token (letters+digits, e.g. ``f8e4m3fn``) directly before
# ``[dims]``.  The pre-fix pattern ``[a-z]+\d*`` stopped at the first
# letter-digit alternation, so ``f8e4m3fn[...]`` parsed as dtype ``fn``
# → unknown → silently 0 bytes.
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# %name = <type> opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+?)(?:\.\d+)?\(")


def _shape_bytes(dtype: str, dims: str,
                 unknown: Optional[Set[str]] = None) -> float:
    if dtype not in _DTYPE_BYTES:
        if unknown is not None and dtype not in _BYTELESS_TYPES:
            unknown.add(dtype)
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _all_shape_bytes(s: str, unknown: Optional[Set[str]] = None
                     ) -> List[float]:
    return [_shape_bytes(d, dims, unknown)
            for d, dims in _SHAPE_RE.findall(s)]


def _resolve_unknown(unknown: Set[str], on_unknown: str) -> None:
    """Unknown dtypes must not silently count as 0 bytes: ``"raise"``
    for audits (under-counting voids the eq.-14 proof), ``"warn"``
    (default for :func:`analyze`) for exploratory use."""
    if not unknown:
        return
    msg = (f"unrecognized HLO dtypes counted as 0 bytes: "
           f"{sorted(unknown)} — extend hlo_analysis._DTYPE_BYTES")
    if on_unknown == "raise":
        raise ValueError(msg)
    if on_unknown == "warn":
        warnings.warn(msg, stacklevel=3)
    elif on_unknown != "ignore":
        raise ValueError(f"on_unknown={on_unknown!r}; "
                         f"choose raise|warn|ignore")


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"[)\]}]\s+([a-z][\w\-]*?)(?:\.\d+)?\(")


def _instr_opcode(line: str):
    """(name, opcode, paren_index) or None.  Robust to tuple types with
    /*index=N*/ comments: the opcode follows the type's closing )/]/}."""
    md = _DEF_RE.match(line)
    if not md:
        return None
    mo = _OPCODE_RE.search(line, md.end() - 1)
    if not mo:
        return None
    return md.group(1), mo.group(1), line.index("(", mo.end() - 1)


def _dot_flops(line: str, paren: int, symtab: Dict[str, str]) -> float:
    """2 × (out elems) × (contracted size); lhs shape via symbol table."""
    outs = _SHAPE_RE.findall(line[:paren])
    if not outs:
        return 0.0
    out_elems = 1
    for d in (outs[0][1].split(",") if outs[0][1] else []):
        out_elems *= int(d)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if not mc:
        return 0.0
    mop = re.search(r"\(\s*(%[\w.\-]+)", line[paren:])
    if not mop:
        return 0.0
    lhs_type = symtab.get(mop.group(1), "")
    lhs_shapes = _SHAPE_RE.findall(lhs_type)
    if not lhs_shapes or not lhs_shapes[0][1]:
        return 0.0
    lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",")]
    contract = 1
    for i in (int(x) for x in mc.group(1).split(",") if x):
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _split_computations(text: str) -> Dict[str, List[str]]:
    """computation name → instruction lines (brace-balanced)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$", line)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        comps[cur].append(line.strip())
    return comps


def _while_edges(comps: Dict[str, List[str]]):
    """computation → [(body_comp, trip_count)] from while instructions."""
    edges: Dict[str, list] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            if " while(" not in line and not re.search(r"=\s*\([^=]*\)\s*while\(", line):
                if "while(" not in line:
                    continue
            mb = re.search(r"body=%?([\w.\-]+)", line)
            if not mb:
                continue
            trip = 1
            mt = re.search(r'known_trip_count[^}]*"n":"(\d+)"', line)
            if mt:
                trip = int(mt.group(1))
            else:
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                if mc:
                    cond_lines = comps.get(mc.group(1), [])
                    for cl in cond_lines:
                        cc = re.search(r"constant\((\d+)\)", cl)
                        if cc and "compare" in cl:
                            trip = int(cc.group(1))
                            break
                    else:
                        # compare references a named constant — resolve it
                        for cl in cond_lines:
                            cc = re.search(
                                r"=\s*s32\[\]\s*constant\((\d+)\)", cl)
                            if cc:
                                trip = int(cc.group(1))
                                break
            edges[name].append((mb.group(1), trip))
    return edges


def _call_edges(comps: Dict[str, List[str]]):
    edges: Dict[str, list] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            for m in re.finditer(r"(?:calls|to_apply|condition)=%?([\w.\-]+)", line):
                edges[name].append((m.group(1), 1))
    return edges


_PARAM_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+parameter\((\d+)\)")


def entry_parameters(text: str, *, on_unknown: str = "raise") -> List[Dict]:
    """Parse the ENTRY computation's ``parameter(i)`` instructions.

    Returns, sorted by parameter index, one dict per parameter:
    ``{"index", "name", "dtype", "shape", "bytes", "uses"}`` — ``uses``
    counts references to the parameter by the rest of the ENTRY body (0
    means the input is dead at the top level).  Only the ENTRY block is
    scanned: subcomputations declare their own ``parameter`` instructions
    which do not correspond to HBM inputs.  jax jit entries are untupled,
    so entry parameter *i* is flat argument leaf *i*.
    """
    entry_lines: List[str] = []
    in_entry = False
    depth = 0
    for raw in text.splitlines():
        line = raw.rstrip()
        if not in_entry:
            if re.match(r"^ENTRY\s", line):
                in_entry = True
                depth = line.count("{") - line.count("}")
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0 and "{" not in line:
            break
        entry_lines.append(line.strip())
    if not entry_lines:
        raise ValueError("no ENTRY computation found in HLO text")

    unknown: Set[str] = set()
    params: List[Dict] = []
    body: List[str] = []
    for line in entry_lines:
        m = _PARAM_RE.match(line)
        if m is None:
            body.append(line)
            continue
        name, ty, index = m.group(1), m.group(2), int(m.group(3))
        if ty.startswith("("):
            raise ValueError(
                f"parameter({index}) is tuple-typed ({ty}); the audit "
                f"needs untupled entry parameters (jax jit default)")
        shapes = _SHAPE_RE.findall(ty)
        if len(shapes) != 1:
            raise ValueError(
                f"parameter({index}): cannot parse array type {ty!r}")
        dtype, dims = shapes[0]
        params.append({
            "index": index, "name": name, "dtype": dtype,
            "shape": tuple(int(d) for d in dims.split(",") if d),
            "bytes": _shape_bytes(dtype, dims, unknown), "uses": 0,
        })
    _resolve_unknown(unknown, on_unknown)
    indices = [p["index"] for p in params]
    if len(set(indices)) != len(indices):
        raise ValueError("duplicate parameter indices in ENTRY")

    body_text = "\n".join(body)
    for p in params:
        p["uses"] = len(re.findall(
            r"(?<![\w.])%?" + re.escape(p["name"]) + r"(?![\w.])",
            body_text))
    return sorted(params, key=lambda p: p["index"])


def analyze(text: str, *, on_unknown: str = "warn") -> Dict:
    """Returns {collective_bytes, collective_breakdown, dot_flops}."""
    unknown: Set[str] = set()
    comps = _split_computations(text)
    wedges = _while_edges(comps)
    cedges = _call_edges(comps)

    coll_per_comp: Dict[str, list] = defaultdict(list)
    flops_per_comp: Dict[str, float] = defaultdict(float)
    bytes_per_comp: Dict[str, float] = defaultdict(float)
    # fusion-internal / reducer computations don't touch HBM directly
    _internal = re.compile(r"(fused_computation|_computation|region_\d+\.\d+$)")
    _no_hbm_ops = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "conditional", "iota", "broadcast"}
    for name, lines in comps.items():
        symtab: Dict[str, str] = {}
        parsed = []
        for line in lines:
            info = _instr_opcode(line)
            if info is None:
                continue
            iname, op, paren = info
            symtab[iname] = line[:paren]
            parsed.append((line, op, paren))
        is_internal = bool(_internal.search(name)) and "region" not in name
        for line, op, paren in parsed:
            if not is_internal and op not in _no_hbm_ops:
                bytes_per_comp[name] += float(
                    sum(_all_shape_bytes(line[:paren], unknown)))
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in _COLLECTIVES:
                out_b = float(sum(_all_shape_bytes(line[:paren], unknown)))
                if base_op == "reduce-scatter":
                    mop = re.search(r"\(\s*(%[\w.\-]+)", line[paren:])
                    opnd_b = (float(sum(_all_shape_bytes(
                        symtab.get(mop.group(1), ""), unknown))) if mop
                              else 0.0)
                    size = opnd_b or out_b
                else:
                    size = out_b
                coll_per_comp[name].append((base_op, size))
            elif base_op == "dot":
                flops_per_comp[name] += _dot_flops(line, paren, symtab)

    totals: Dict[str, float] = defaultdict(float)
    dot_total = [0.0]
    bytes_total = [0.0]

    children = {c for lst in list(wedges.values()) + list(cedges.values())
                for c, _ in lst}
    roots = [n for n in comps if n not in children]

    def walk(comp: str, mult: float, stack):
        if comp in stack:
            return
        stack = stack + [comp]
        for op, b in coll_per_comp.get(comp, []):
            totals[op] += b * mult
        dot_total[0] += flops_per_comp.get(comp, 0.0) * mult
        bytes_total[0] += bytes_per_comp.get(comp, 0.0) * mult
        for child, trip in wedges.get(comp, []):
            walk(child, mult * trip, stack)
        for child, _ in cedges.get(comp, []):
            if child not in {b for b, _ in wedges.get(comp, [])}:
                walk(child, mult, stack)

    for r in roots:
        walk(r, 1.0, [])

    _resolve_unknown(unknown, on_unknown)
    return {
        "collective_bytes": sum(totals.values()),
        "collective_breakdown": dict(totals),
        "dot_flops": dot_total[0],
        # ×2: instruction outputs counted once ≈ HBM writes; reads ≈ writes
        "hbm_bytes_proxy": bytes_total[0] * 2.0,
    }


def collective_bytes(text: str) -> Tuple[float, Dict[str, float]]:
    res = analyze(text)
    return res["collective_bytes"], res["collective_breakdown"]
