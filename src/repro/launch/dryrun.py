import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the realistic step program —
  train:   L-step train step (loss + LC quadratic-penalty gradient + SGD
           momentum update; the paper's technique is part of the program)
  prefill: full-sequence forward emitting KV/state caches
  decode:  one-token serve_step against a seq_len cache
— with production shardings, runs ``jit(...).lower().compile()`` on the
16×16 (or 2×16×16) mesh of host devices, and records:

  * memory_analysis()       (bytes/device — proves it fits)
  * cost_analysis()         (per-device HLO FLOPs / bytes)
  * per-chip collective bytes parsed from the optimized HLO
    (repro.launch.hlo_analysis — while-loop trip counts included)
  * roofline terms (repro.launch.roofline)

Results land in experiments/dryrun/<arch>__<cell>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run/§Roofline.  Cached: existing JSONs are skipped
unless --force.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --cell train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.configs.shapes import CELLS, CELLS_BY_NAME, applicable, input_specs
from repro.dist import sharding as shard_rules
from repro.launch import hlo_analysis, roofline
from repro.launch.mesh import make_production_mesh
from repro.models import sharding_ctx
from repro.models import transformer as tfm

# archs needing ZeRO-style (data-axis) param sharding to fit HBM
ZERO_ARCHS = {"nemotron-4-340b", "internvl2-26b"}

DTYPE = jnp.bfloat16


def _mask_qspec(params_shapes):
    """Quantization mask (which leaves carry penalty terms)."""
    from repro.core.lc import default_qspec
    return default_qspec(params_shapes)


def make_train_step_dp8(cfg, mesh):
    """Pure-DP train step with int8-compressed gradient all-reduce.

    shard_map over every mesh axis: params replicated per rank, batch
    sharded; grads sync via repro.dist.cstep.compressed_psum (shared-scale
    int8 payload — the paper's codebook-with-scale idea applied to the
    collective).  Wire bytes: 1 B/grad value vs 2 B bf16 / 4 B f32.
    """
    import functools
    from jax.experimental.shard_map import shard_map
    from repro.dist.cstep import compressed_psum

    axes = tuple(mesh.axis_names)
    nshards = mesh.size

    def train_step(params, mom, w_c, lam, mu, batch):
        def loss(p):
            return tfm.loss_fn(p, cfg, batch)

        lval, g = jax.value_and_grad(loss)(params)
        g = jax.tree_util.tree_map(
            lambda x: (compressed_psum(x.astype(jnp.float32), axes)
                       / nshards).astype(x.dtype) if x.ndim else x, g)
        lval = jax.lax.pmean(lval, axes)

        qspec = _mask_qspec(params)
        g = jax.tree_util.tree_map_with_path(
            lambda path, spec, gi, w, qc, lm:
                (gi.astype(jnp.float32) + mu * (w - qc).astype(jnp.float32)
                 - lm.astype(jnp.float32)).astype(gi.dtype)
                if spec.quantize else gi,
            qspec, g, params, w_c, lam,
            is_leaf=lambda x: hasattr(x, "quantize"))

        lr = jnp.minimum(jnp.asarray(0.05, jnp.float32),
                         1.0 / jnp.maximum(mu, 1e-30))
        new_mom = jax.tree_util.tree_map(
            lambda m, gi: (0.95 * m.astype(jnp.float32)
                           + gi.astype(jnp.float32)).astype(m.dtype), mom, g)
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32)
                          - lr * m.astype(jnp.float32)).astype(p.dtype),
            params, new_mom)
        return new_params, new_mom, lval

    def rep_specs(tree):
        return jax.tree_util.tree_map(lambda _: jax.sharding.PartitionSpec(),
                                      tree)

    def wrapped(params, mom, w_c, lam, mu, batch):
        bspec = jax.tree_util.tree_map(
            lambda leaf: jax.sharding.PartitionSpec(
                axes, *([None] * (leaf.ndim - 1))), batch)
        fn = shard_map(
            train_step, mesh=mesh,
            in_specs=(rep_specs(params), rep_specs(mom), rep_specs(w_c),
                      rep_specs(lam), jax.sharding.PartitionSpec(), bspec),
            out_specs=(rep_specs(params), rep_specs(mom),
                       jax.sharding.PartitionSpec()),
            check_rep=False)
        return fn(params, mom, w_c, lam, mu, batch)

    return wrapped


def make_train_step(cfg):
    """L-step train step: CE loss + LC penalty grad + SGD momentum."""
    def train_step(params, mom, w_c, lam, mu, batch):
        def loss(p):
            return tfm.loss_fn(p, cfg, batch)

        lval, g = jax.value_and_grad(loss)(params)
        qspec = _mask_qspec(params)

        def add_penalty(path, spec, gi, w, qc, lm):
            if spec.quantize:
                return (gi.astype(jnp.float32) + mu * (w - qc).astype(jnp.float32)
                        - lm.astype(jnp.float32)).astype(gi.dtype)
            return gi

        g = jax.tree_util.tree_map_with_path(
            lambda path, spec, gi, w, qc, lm: add_penalty(path, spec, gi, w, qc, lm),
            qspec, g, params, w_c, lam,
            is_leaf=lambda x: hasattr(x, "quantize"))

        lr = jnp.minimum(jnp.asarray(0.05, jnp.float32), 1.0 / jnp.maximum(mu, 1e-30))
        new_mom = jax.tree_util.tree_map(
            lambda m, gi: (0.95 * m.astype(jnp.float32)
                           + gi.astype(jnp.float32)).astype(m.dtype), mom, g)
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32)
                          - lr * m.astype(jnp.float32)).astype(p.dtype),
            params, new_mom)
        return new_params, new_mom, lval

    return train_step


def _quantize_param_shapes(params_sh):
    """Replace dense MLP weight ShapeDtypeStructs with the packed LC
    serving layout: uint8 assignment indices + a [16] bf16 codebook per
    stacked group (K=16 ⇒ 4-bit information; stored at byte granularity
    here, 2× under the bit-packed deploy format)."""
    def visit(d):
        if isinstance(d, dict):
            out = {}
            for k, v in d.items():
                if k in ("w_in", "w_gate", "w_out") and hasattr(v, "shape") \
                        and v.ndim >= 2:
                    out[k + "_idx"] = jax.ShapeDtypeStruct(v.shape, jnp.uint8)
                    out[k + "_cb"] = jax.ShapeDtypeStruct(
                        (v.shape[0], 16) if v.ndim == 3 else (16,), DTYPE)
                else:
                    out[k] = visit(v)
            return out
        if isinstance(d, tuple):
            return tuple(visit(x) for x in d)
        return d

    return visit(params_sh)


def build_cell(arch: str, cell_name: str, mesh, zero: bool,
               policy_mode: str = "tp"):
    """Returns (fn, arg_shapes, in_shardings, out_shardings)."""
    cfg = get_config(arch)
    cell = CELLS_BY_NAME[cell_name]
    skip = applicable(cfg, cell)
    if skip:
        return None, skip, None, None

    params_sh = jax.eval_shape(
        lambda k: tfm.init_params(k, cfg, DTYPE),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    if policy_mode.endswith("_quant"):
        # LC-quantized serving: MLP weights → uint8 idx + [16] codebook
        policy_mode = policy_mode[:-6]
        params_sh = _quantize_param_shapes(params_sh)
    if policy_mode in ("dp", "dp8"):
        # pure data parallelism: params replicated, batch over every axis
        p_shard = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), params_sh)
    else:
        p_shard = shard_rules.param_shardings(
            params_sh, mesh, zero=zero,
            zero_cols=policy_mode == "tp_zcols")
    specs = input_specs(cfg, cell, DTYPE)

    def bshard(leaf):
        axes = shard_rules.batch_axes(mesh)
        if policy_mode in ("dp", "dp8"):
            axes = axes + ("model",)
        nshard = 1
        for a in axes:
            nshard *= mesh.shape[a]
        if leaf.ndim == 0 or leaf.shape[0] % max(nshard, 1):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(axes, *([None] * (leaf.ndim - 1))))

    if cell.kind == "train":
        fn = (make_train_step_dp8(cfg, mesh) if policy_mode == "dp8"
              else make_train_step(cfg))
        batch = {k: v for k, v in specs.items()}
        args = (params_sh, params_sh, params_sh, params_sh,
                jax.ShapeDtypeStruct((), jnp.float32), batch)
        in_sh = (p_shard, p_shard, p_shard, p_shard,
                 NamedSharding(mesh, P()),
                 jax.tree_util.tree_map(bshard, batch))
        out_sh = (p_shard, p_shard, NamedSharding(mesh, P()))
        return (fn, args, in_sh, out_sh)

    if cell.kind == "prefill":
        def fn(params, batch):
            return tfm.prefill(params, cfg, batch["tokens"],
                               batch.get("patch_embeds"),
                               last_logits_only=True)
        batch = {k: v for k, v in specs.items()}
        args = (params_sh, batch)
        cache_sh = jax.eval_shape(
            lambda p, b: tfm.prefill(p, cfg, b["tokens"],
                                     b.get("patch_embeds"),
                                     last_logits_only=True),
            params_sh, batch)[1]
        in_sh = (p_shard, jax.tree_util.tree_map(bshard, batch))
        out_sh = (bshard(jax.ShapeDtypeStruct(
            (cell.global_batch, 1, cfg.vocab), jnp.float32)),
            shard_rules.cache_shardings(cache_sh, mesh))
        return (fn, args, in_sh, out_sh)

    # decode
    def fn(params, caches, tokens_t, pos):
        return tfm.decode_step(params, cfg, caches, tokens_t, pos)

    caches = specs["caches"]
    args = (params_sh, caches, specs["tokens_t"], specs["pos"])
    c_shard = shard_rules.cache_shardings(caches, mesh)
    in_sh = (p_shard, c_shard, bshard(specs["tokens_t"]),
             NamedSharding(mesh, P()))
    logits_sh = bshard(jax.ShapeDtypeStruct(
        (cell.global_batch, 1, cfg.vocab), jnp.float32))
    out_sh = (logits_sh, c_shard)
    return (fn, args, in_sh, out_sh)


def run_cell(arch: str, cell_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, label: str = "baseline",
             policy_mode: str = "tp") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{cell_name}__{mesh_name}"
    if label != "baseline":
        tag += f"__{label}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=multi_pod)
    zero = arch in ZERO_ARCHS
    record = {"arch": arch, "cell": cell_name, "mesh": mesh_name,
              "zero": zero, "label": label, "chips": mesh.size,
              "policy": policy_mode}
    t0 = time.time()
    try:
        # dp8 runs the whole step inside shard_map: constraints must be off
        base_mode = policy_mode[:-6] if policy_mode.endswith("_quant") \
            else policy_mode
        act_mode = {"dp8": "none", "tp_zcols": "tp2d"}.get(base_mode,
                                                           base_mode)
        policy = sharding_ctx.Policy(mesh, mode=act_mode)
        sharding_ctx.set_policy(policy)
        built, *rest = build_cell(arch, cell_name, mesh, zero, policy_mode)
        if built is None:
            record["status"] = "skipped"
            record["reason"] = rest[0]
        else:
            fn, args, in_sh, out_sh = built, *rest
            with mesh:
                jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
                lowered = jitted.lower(*args)
                t_lower = time.time()
                compiled = lowered.compile()
                t_compile = time.time()

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            text = compiled.as_text()
            hlo = hlo_analysis.analyze(text)

            record.update({
                "status": "ok",
                "lower_s": round(t_lower - t0, 2),
                "compile_s": round(t_compile - t_lower, 2),
                "memory": {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                    "output_bytes": getattr(mem, "output_size_in_bytes", None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                    "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                },
                # cost_analysis counts while bodies ONCE (verified) —
                # kept for reference; roofline uses the trip-multiplied
                # static analysis below.
                "cost_body_once": {
                    "flops": cost.get("flops"),
                    "bytes_accessed": cost.get("bytes accessed"),
                    "transcendentals": cost.get("transcendentals"),
                },
                "hlo": {
                    "dot_flops_per_chip": hlo["dot_flops"],
                    "hbm_bytes_per_chip": hlo["hbm_bytes_proxy"],
                    "collective_bytes_per_chip": hlo["collective_bytes"],
                    "collective_breakdown": hlo["collective_breakdown"],
                },
            })
            cfg = get_config(arch)
            record["roofline"] = roofline.terms(
                cfg, CELLS_BY_NAME[cell_name], mesh.size, record)
    except Exception as e:                      # noqa: BLE001
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    finally:
        sharding_ctx.set_policy(None)
    record["wall_s"] = round(time.time() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=str)
    status = record["status"]
    extra = record.get("reason") or record.get("error", "")
    print(f"[{status:7s}] {tag} ({record['wall_s']}s) {extra[:120]}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = [args.cell] if args.cell else [c.name for c in CELLS]
    archs = [args.arch] if args.arch else list_archs()
    if not (args.arch or args.all):
        ap.error("pass --arch or --all")

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for mp in meshes:
        for arch in archs:
            for cell in cells:
                rec = run_cell(arch, cell, mp, args.out, force=args.force)
                failures += rec["status"] == "error"
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
