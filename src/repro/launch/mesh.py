"""Production mesh builders.  FUNCTIONS ONLY — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any import).

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — "pod" is
pure data parallelism across the cross-pod (DCN-class) links, which is
where the int8 gradient-compression collective (repro/dist/compress.py)
applies.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2,
                    pods: int = 0) -> jax.sharding.Mesh:
    """Small mesh for subprocess tests (XLA_FLAGS device count permitting)."""
    if pods:
        return jax.make_mesh((pods, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh):
    """The batch-sharding axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
