"""Roofline terms from the compiled dry-run artifact (TPU v5e targets).

Per (arch × shape × mesh):
  compute_term_s    = HLO_FLOPs_per_chip / peak_FLOPs        (197 TF/s bf16)
  memory_term_s     = HLO_bytes_per_chip / HBM_bw            (819 GB/s)
  collective_term_s = collective_bytes_per_chip / link_bw    (50 GB/s/link)

(cost_analysis of the SPMD-partitioned module reports per-device numbers;
the spec's global formulation divides global totals by `chips ×`, which is
identical.)

MODEL_FLOPS (the "useful" compute):
  train:   6 · N_active · tokens   (fwd+bwd)
  prefill: 2 · N_active · tokens
  decode:  2 · N_active · tokens (+ attention KV term, reported separately)
The MODEL_FLOPS / HLO_FLOPs ratio exposes remat/causal-masking/capacity
waste — the §Perf hillclimb watches it.
"""
from __future__ import annotations

from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def model_flops(cfg, cell) -> float:
    """6·N_active·tokens (train) / 2·N_active (fwd), with the input
    embedding excluded from N (a gather, not a matmul); tied embeddings
    still count once via the LM-head matmul."""
    n_active = cfg.active_param_count()
    embed = cfg.vocab * cfg.d_model
    n_mat = n_active - embed if not cfg.tie_embeddings else n_active
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    if cell.kind == "train":
        return 6.0 * n_mat * tokens
    return 2.0 * n_mat * tokens


def attention_flops(cfg, cell) -> float:
    """Useful causal-attention matmul FLOPs (not in 6ND), global."""
    if cfg.ssm is not None and cfg.n_heads == 1:
        return 0.0
    s, b = cell.seq_len, cell.global_batch
    h, hd = cfg.n_heads, cfg.head_dim
    n_attn = sum(sum(1 for k in st.pattern if "gqa" in k.mixer or k.mixer == "mla")
                 * st.groups for st in cfg.stacks)
    if cell.kind == "decode":
        per_layer = 2 * 2 * b * 1 * s * h * hd
        mult = 1.0
    else:
        per_layer = 2 * 2 * b * s * s * h * hd / 2     # causal half
        mult = 3.0 if cell.kind == "train" else 1.0
    return n_attn * per_layer * mult


def analytic_memory_bytes(cfg, cell, chips: int, model_par: int,
                          zero: bool) -> float:
    """See _analytic_memory_impl; model_par=1 means pure DP (replicated
    params, batch over every axis)."""
    return _analytic_memory_impl(cfg, cell, chips, model_par, zero)


def _analytic_memory_impl(cfg, cell, chips: int, model_par: int,
                          zero: bool) -> float:
    """Per-chip HBM-traffic floor for a TPU compile (fusion-optimal).

    Counts: optimizer/LC state streams (params r/w, momentum r/w,
    w_C + λ reads — all bf16, sharded), major activation tensors per layer
    (remat ⇒ ~3 forward-equivalent passes in training), logits, and for
    decode/prefill the KV/state caches.  This is the *floor*; the HLO
    proxy (CPU fusion granularity, f32-upcast) is the upper bound.
    """
    n = cfg.param_count()
    bp = 2.0
    par = model_par * (chips // model_par if zero else 1)
    params_chip = n * bp / par
    tokens_chip = cell.global_batch * cell.seq_len / max(chips // model_par, 1)

    d_loc = cfg.d_model                       # residual stream: replicated
    f_loc = max(cfg.d_ff, 1) / model_par
    if cfg.moe:
        f_loc = cfg.moe.top_k * cfg.moe.d_ff_expert / model_par * 3
    if cfg.ssm:
        f_loc = cfg.ssm.d_inner * 2 / model_par
    if cfg.rglru:
        f_loc = max(f_loc, cfg.rglru.width * 2 / model_par)
    per_layer_act = (4 * tokens_chip * f_loc + 8 * tokens_chip * d_loc) * bp
    n_layers = cfg.n_layers
    vocab_loc = cfg.vocab / model_par

    import jax
    from repro.configs.shapes import input_specs
    cache_bytes = 0.0
    if cell.kind in ("decode", "prefill"):
        try:
            import jax.numpy as jnp
            specs = input_specs(cfg, cell, jnp.bfloat16)
            caches = specs.get("caches")
            if caches is None:
                from repro.models.transformer import init_cache
                caches = jax.eval_shape(
                    lambda: init_cache(cfg, cell.global_batch, cell.seq_len,
                                       jnp.bfloat16))
            cache_bytes = sum(
                int(x.size) * x.dtype.itemsize
                for x in jax.tree_util.tree_leaves(caches)) / chips
        except Exception:
            cache_bytes = 0.0

    if cell.kind == "train":
        state = 6.0 * params_chip            # p r/w, m r/w, w_C + λ reads
        acts = 3.0 * per_layer_act * n_layers
        logits = 4.0 * tokens_chip * vocab_loc * 4.0
        return state + acts + logits
    if cell.kind == "prefill":
        return params_chip + per_layer_act * n_layers + cache_bytes \
            + tokens_chip * vocab_loc * 4.0
    # decode: stream weights + read cache once
    return params_chip + cache_bytes


def terms(cfg, cell, chips: int, record: Dict) -> Dict:
    hlo = record["hlo"]
    flops_chip = hlo["dot_flops_per_chip"] or 0.0
    bytes_chip_hlo = hlo["hbm_bytes_per_chip"] or 0.0
    coll_chip = hlo["collective_bytes_per_chip"] or 0.0

    policy = record.get("policy", "tp")
    model_par = 1 if policy in ("dp", "dp8") else 16
    bytes_floor = analytic_memory_bytes(cfg, cell, chips, model_par,
                                        record.get("zero", False))
    if policy.endswith("_quant"):
        # LC-quantized MLP weights: uint8 idx (1 B) instead of bf16 (2 B)
        # for ~85-95% of params at decode — ÷1.8 on the weight stream
        # (4-bit packing would give ÷3.6; kernels/codebook_matmul.py)
        bytes_floor = bytes_floor / 1.8

    compute_s = flops_chip / PEAK_FLOPS
    memory_s = bytes_floor / HBM_BW
    memory_hlo_s = bytes_chip_hlo / HBM_BW
    # CPU HLO upcasts bf16 collectives to f32; TPU moves them at bf16.
    collective_s = 0.5 * coll_chip / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]

    mf = model_flops(cfg, cell)
    af = attention_flops(cfg, cell)
    hlo_global = flops_chip * chips
    useful_ratio = (mf + af) / hlo_global if hlo_global else None
    bound_s = max(compute_s, memory_s, collective_s)
    # fraction of roofline: useful work at peak vs actual bound time
    roofline_frac = ((mf + af) / chips / PEAK_FLOPS) / bound_s if bound_s else None

    return {
        "compute_term_s": compute_s,
        "memory_term_s": memory_s,
        "memory_term_hlo_upper_s": memory_hlo_s,
        "collective_term_s": collective_s,
        "dominant": dominant,
        "model_flops_global": mf,
        "attention_flops_global": af,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": roofline_frac,
    }
