"""Serving launcher: a thin client of the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --host-devices 4 --mesh 2x2 --requests 8 --slots 4

By default requests flow through ``repro.engine.Engine``: a request
queue feeding a fixed set of batch slots, a paged KV cache (fixed-size
pages + per-slot page table, finished requests' pages immediately
reusable), chunked prefill mixed with decode under a per-step token
budget, and per-slot sampling (greedy, or ``--temperature``/``--top-k``
with per-request seeds).  ``--no-engine`` restores the pre-engine
one-shot path — one fixed batch, lockstep prefill, greedy decode until
the longest request finishes (``repro.engine.oneshot``, the engine's
differential oracle).

``--packed <dir>`` serves straight from a PackedModel artifact (the
output of ``launch.train --lc`` / ``CompressionPlan.pack``): **every**
quantized leaf — attention q/k/v/o, embedding table / LM head, MoE
experts, SSM/RG-LRU projections, MLP — stays quantized in HBM and routes
through ``repro.models.qleaf`` → ``repro.kernels.dispatch`` (Mosaic
codebook-matmul / dequant-on-gather on TPU, jnp reference on CPU).
``--serve-layout packed`` (default) keeps the bit-packed uint32 word
operand HBM-resident (bits_per_index(K)/8 bytes/weight — the eq.-14
footprint); ``--serve-layout uint8`` is the legacy 1 B/weight oracle;
``--serve-leaves mlp`` restricts coverage to the pre-qleaf MLP-only
set.  The freed weight HBM is what the engine turns into serving
capacity: more slots × longer pages on the same device (see README
"Serving engine" for the sizing math).
"""
import argparse
import os
import sys


def _preparse_devices():
    if "--host-devices" in sys.argv:
        i = sys.argv.index("--host-devices")
        n = int(sys.argv[i + 1])
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count={n}")


_preparse_devices()

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402

from repro.configs import get_config, list_archs, reduce_config  # noqa: E402
from repro.dist import sharding as shard_rules                   # noqa: E402
from repro.engine import Engine, Request, greedy_generate        # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.models import sharding_ctx                            # noqa: E402
from repro.models.transformer import init_params                 # noqa: E402
from repro.train import checkpoint as ckpt                       # noqa: E402


def _load_params(args, cfg):
    if args.packed:
        from repro.core import ArtifactError, PackedModel
        try:
            packed = PackedModel.load(args.packed)
        except ArtifactError as e:
            # integrity gate: a truncated/corrupt artifact must fail the
            # launch cleanly, never half-serve
            sys.exit(f"refusing to serve {args.packed}: {e}")
        quant_names = (None if args.serve_leaves == "all"
                       else ("w_in", "w_gate", "w_out"))
        params = packed.serving_params(
            quant_names=quant_names, packed=args.serve_layout == "packed")
        s = packed.summary()
        idx_bytes = (s["bits_per_weight"] / 8
                     if args.serve_layout == "packed" else 1.0)
        cov = packed.leaf_coverage()
        n_q = sum(r["quantized"] for r in cov)
        # row-packed fused routes only exist on the bit-packed layout
        # with full coverage (uint8/MLP-only serving never emits them)
        n_row = (sum(r["quantized"] and "pack_rows" in (r["route"] or "")
                     for r in cov)
                 if args.serve_layout == "packed"
                 and args.serve_leaves == "all" else 0)
        row_note = (f", {n_row} row-packed for fused gather + transposed "
                    f"head" if n_row else "")
        print(f"serving packed artifact: {s['scheme']} "
              f"({s['bits_per_weight']} bit/weight, ×{s['ratio']:.1f}, "
              f"{args.serve_layout} layout: {idx_bytes:g} B/weight HBM "
              f"index traffic; {args.serve_leaves} leaves — "
              f"{n_q}/{len(cov)} param paths quantized{row_note})")
        return params
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        params, _, _ = ckpt.restore_checkpoint(args.ckpt_dir, like=params)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--host-devices", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4,
                    help="one-shot batch size / engine slot count alias")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--packed", default=None,
                    help="PackedModel artifact dir: serve quantized")
    ap.add_argument("--serve-layout", default="packed",
                    choices=("packed", "uint8"),
                    help="quantized HBM layout: bit-packed uint32 words "
                         "(bits/8 B/weight) or legacy uint8 indices "
                         "(1 B/weight oracle)")
    ap.add_argument("--serve-leaves", default="all", choices=("all", "mlp"),
                    help="which leaves serve quantized: the whole model "
                         "(attention/embed/MoE/SSM/MLP) or the legacy "
                         "MLP-only set")
    # engine knobs
    ap.add_argument("--no-engine", action="store_true",
                    help="one-shot lockstep loop (the engine's oracle)")
    ap.add_argument("--requests", type=int, default=None,
                    help="number of requests (default: --batch)")
    ap.add_argument("--slots", type=int, default=None,
                    help="engine batch slots (default: --batch)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size in tokens")
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size (default: slots × max pages)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step token budget (decode + chunked prefill)")
    ap.add_argument("--kv-bits", type=int, default=0,
                    choices=(0, 2, 4, 8),
                    help="codebook-quantize KV pages to this many bits "
                         "(0 = dense pages); kv_bits/8 B per cached "
                         "scalar of decode HBM traffic")
    ap.add_argument("--kv-cb", default="page", choices=("page", "head"),
                    help="KV codebook grouping: one per page, or one per "
                         "(page, kv-head) — finer fit, n_kv× metadata")
    ap.add_argument("--vary-gen", action="store_true",
                    help="stagger request gen lengths (engine mode)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    # fault tolerance (engine mode)
    ap.add_argument("--deadline", type=int, default=None,
                    help="per-request deadline in engine steps "
                         "(DEADLINE_EXCEEDED past it)")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bound the request queue; submissions beyond it "
                         "get REJECTED_BACKPRESSURE")
    ap.add_argument("--snapshot-dir", default=None,
                    help="serve under the restart supervisor with "
                         "periodic snapshots to this directory")
    ap.add_argument("--snapshot-every", type=int, default=32,
                    help="steps between snapshots (with --snapshot-dir)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(dims, names)
    else:
        mesh = make_production_mesh()
    sharding_ctx.set_policy(sharding_ctx.Policy(mesh, mode="tp"))

    params = _load_params(args, cfg)
    p_shard = shard_rules.param_shardings(params, mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, p_shard)

    key = jax.random.PRNGKey(7)
    n_req = args.requests if args.requests is not None else args.batch
    prompts = jax.random.randint(key, (n_req, args.prompt_len), 0,
                                 cfg.vocab)

    if args.no_engine:
        n_b = min(args.batch, n_req)
        with mesh:
            gen, _ = greedy_generate(params, cfg, prompts[:n_b],
                                     args.gen_len)
        for r in range(n_b):
            print(f"req{r}: {np.asarray(gen)[r]}")
        return

    n_slots = args.slots if args.slots is not None else args.batch
    rng = np.random.RandomState(args.seed)
    reqs = []
    for r in range(n_req):
        gen_len = (int(rng.randint(max(args.gen_len // 4, 1),
                                   args.gen_len + 1))
                   if args.vary_gen else args.gen_len)
        reqs.append(Request(rid=r, prompt=np.asarray(prompts[r]),
                            max_new_tokens=gen_len,
                            temperature=args.temperature,
                            top_k=args.top_k, seed=args.seed + r,
                            deadline_steps=args.deadline))

    def build():
        return Engine(params, cfg, n_slots=n_slots,
                      page_size=args.page_size,
                      max_seq=args.prompt_len + args.gen_len,
                      n_pages=args.pages, token_budget=args.token_budget,
                      mesh=mesh, queue_limit=args.queue_limit,
                      kv_bits=args.kv_bits, kv_cb_mode=args.kv_cb)

    with mesh:
        if args.snapshot_dir:
            from repro.engine import (ServeSupervisorConfig,
                                      supervised_serve)
            sup = ServeSupervisorConfig(snapshot_dir=args.snapshot_dir,
                                        snapshot_every=args.snapshot_every)
            outs, results, report = supervised_serve(build, reqs, sup)
            eng = None
            print(f"supervisor: {report.snapshots} snapshots, "
                  f"{report.restores} restores, {report.restarts} restarts")
        else:
            eng = build()
            outs = eng.run(reqs)
            results = eng.results
    for r in sorted(results):
        res = results[r]
        if res.ok:
            print(f"req{r}: {res.tokens}")
        else:
            print(f"req{r}: {res.outcome.value} ({res.detail}; "
                  f"{res.tokens.size} partial tokens)")
    n_bad = sum(not res.ok for res in results.values())
    if n_bad:
        print(f"outcomes: {len(results) - n_bad}/{len(results)} finished")
    if eng is not None:
        s = eng.stats.summary()
        print(f"engine: {s['delivered_tokens']} tokens in {s['steps']} "
              f"steps ({s['tokens_per_s']:.1f} tok/s, occupancy "
              f"{s['slot_occupancy']:.2f}, page util "
              f"{s['page_utilization']:.2f}"
              f" peak {s['page_utilization_max']:.2f}, "
              f"{s['preemptions']} preemptions, decode compiled "
              f"{eng.decode_compile_count()}x)")


if __name__ == "__main__":
    main()
