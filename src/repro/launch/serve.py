"""Serving launcher: batched prefill + decode on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --host-devices 4 --mesh 2x2 --batch 4

Loads (or initializes) params, shards them with the production rules,
prefills a batch of prompts and runs a greedy decode loop — the same
``decode_step`` the dry-run lowers for the decode_32k/long_500k cells.

``--packed <dir>`` serves straight from a PackedModel artifact (the
output of ``launch.train --lc`` / ``CompressionPlan.pack``): **every**
quantized leaf — attention q/k/v/o, embedding table / LM head, MoE
experts, SSM/RG-LRU projections, MLP — stays quantized in HBM and routes
through ``repro.models.qleaf`` → ``repro.kernels.dispatch`` (Mosaic
codebook-matmul / dequant-on-gather on TPU, jnp reference on CPU).
``--serve-layout packed`` (default) keeps the bit-packed uint32 word
operand HBM-resident (bits_per_index(K)/8 bytes/weight — the eq.-14
footprint): matmul leaves in the ``pack_indices_2d`` layout (fused
codebook matmul), the embedding table row-packed (``pack_rows``) so both
the Mosaic dequant-on-gather and the fused transposed tied-LM-head
kernel read bits/8 B/weight without ever inflating the dense [V, D]
table.  ``--serve-layout uint8`` is the legacy 1 B/weight uint8-index
layout kept as the fallback/oracle.  ``--serve-leaves mlp`` restricts
coverage to the pre-qleaf MLP-only set (the PR-2 behaviour).  The
arch/config must match the one the artifact was packed from.
"""
import argparse
import os
import sys


def _preparse_devices():
    if "--host-devices" in sys.argv:
        i = sys.argv.index("--host-devices")
        n = int(sys.argv[i + 1])
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count={n}")


_preparse_devices()

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402

from repro.configs import get_config, list_archs, reduce_config  # noqa: E402
from repro.dist import sharding as shard_rules                   # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.models import sharding_ctx                            # noqa: E402
from repro.models.transformer import (decode_step, init_params,  # noqa: E402
                                      prefill)
from repro.train import checkpoint as ckpt                       # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--host-devices", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--packed", default=None,
                    help="PackedModel artifact dir: serve quantized")
    ap.add_argument("--serve-layout", default="packed",
                    choices=("packed", "uint8"),
                    help="quantized HBM layout: bit-packed uint32 words "
                         "(bits/8 B/weight) or legacy uint8 indices "
                         "(1 B/weight oracle)")
    ap.add_argument("--serve-leaves", default="all", choices=("all", "mlp"),
                    help="which leaves serve quantized: the whole model "
                         "(attention/embed/MoE/SSM/MLP) or the legacy "
                         "MLP-only set")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(dims, names)
    else:
        mesh = make_production_mesh()
    sharding_ctx.set_policy(sharding_ctx.Policy(mesh, mode="tp"))

    if args.packed:
        from repro.core import PackedModel
        packed = PackedModel.load(args.packed)
        quant_names = (None if args.serve_leaves == "all"
                       else ("w_in", "w_gate", "w_out"))
        params = packed.serving_params(
            quant_names=quant_names, packed=args.serve_layout == "packed")
        s = packed.summary()
        idx_bytes = (s["bits_per_weight"] / 8
                     if args.serve_layout == "packed" else 1.0)
        cov = packed.leaf_coverage()
        n_q = sum(r["quantized"] for r in cov)
        # row-packed fused routes only exist on the bit-packed layout
        # with full coverage (uint8/MLP-only serving never emits them)
        n_row = (sum(r["quantized"] and "pack_rows" in (r["route"] or "")
                     for r in cov)
                 if args.serve_layout == "packed"
                 and args.serve_leaves == "all" else 0)
        row_note = (f", {n_row} row-packed for fused gather + transposed "
                    f"head" if n_row else "")
        print(f"serving packed artifact: {s['scheme']} "
              f"({s['bits_per_weight']} bit/weight, ×{s['ratio']:.1f}, "
              f"{args.serve_layout} layout: {idx_bytes:g} B/weight HBM "
              f"index traffic; {args.serve_leaves} leaves — "
              f"{n_q}/{len(cov)} param paths quantized{row_note})")
    else:
        params = init_params(jax.random.PRNGKey(0), cfg)
        if args.ckpt_dir:
            params, _, _ = ckpt.restore_checkpoint(args.ckpt_dir, like=params)
    p_shard = shard_rules.param_shardings(params, mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, p_shard)

    key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    capacity = args.prompt_len + args.gen_len

    with mesh:
        logits, caches = prefill(params, cfg, prompts,
                                 last_logits_only=True)

        def grow(leaf):
            if leaf.ndim >= 3 and leaf.shape[2] == args.prompt_len:
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, args.gen_len)
                return jnp.pad(leaf, pad)
            return leaf

        caches = jax.tree_util.tree_map(grow, caches)
        step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out = [tok]
        for t in range(args.gen_len - 1):
            logits, caches = step(caches, tok,
                                  jnp.asarray(args.prompt_len + t, jnp.int32))
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            out.append(tok)
        gen = np.asarray(jnp.concatenate(out, axis=1))
    for r in range(args.batch):
        print(f"req{r}: {gen[r]}")


if __name__ == "__main__":
    main()
