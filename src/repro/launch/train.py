"""Production training launcher: mesh + sharded LC training + supervision.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --mesh 2x2 --steps 40 --lc

On a real TPU slice the same entry point runs with the production mesh
(--mesh 16x16 / 2x16x16 after jax.distributed.initialize); in this
container use --reduced with a small mesh via
XLA_FLAGS=--xla_force_host_platform_device_count=N (set by --host-devices
N *before* jax import).

What it wires together:
  * make_mesh + param/batch sharding rules (repro.dist.sharding)
  * activation-sharding policy (repro.models.sharding_ctx)
  * LC trainer (L steps jitted on the mesh; C steps psum-exact)
  * checkpoint/restart supervision with the LC state included
  * optional int8 gradient compression on the pod axis (--compress-grads)
"""
import argparse
import os
import sys


def _preparse_devices():
    if "--host-devices" in sys.argv:
        i = sys.argv.index("--host-devices")
        n = int(sys.argv[i + 1])
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count={n}")


_preparse_devices()

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402

from repro.configs import get_config, list_archs, reduce_config  # noqa: E402
from repro.core import CompressionPlan, LCConfig                 # noqa: E402
from repro.data.pipeline import LMTokenPipeline, shard_batch     # noqa: E402
from repro.dist import sharding as shard_rules                   # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.models import sharding_ctx                            # noqa: E402
from repro.models.transformer import init_params, loss_fn        # noqa: E402
from repro.train import checkpoint as ckpt                       # noqa: E402
from repro.train.trainer import (LCTrainer, TrainerConfig)       # noqa: E402


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 3:
        return jax.make_mesh(dims, ("pod", "data", "model"))
    return jax.make_mesh(dims, ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 or 2x2x2")
    ap.add_argument("--host-devices", type=int, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--lc", action="store_true", help="enable LC quantization")
    ap.add_argument("--scheme", default=None,
                    help="scheme spec (default adaptive:<k>), e.g. ternary_scale")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--lc-iters", type=int, default=5)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--zero", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    mesh = (parse_mesh(args.mesh) if args.mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    print(f"mesh: {dict(mesh.shape)}; model: {cfg.name}")

    sharding_ctx.set_policy(sharding_ctx.Policy(mesh, mode="tp"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    p_shard = shard_rules.param_shardings(params, mesh, zero=args.zero)
    params = jax.tree_util.tree_map(jax.device_put, params, p_shard)

    pipe = LMTokenPipeline(seed=0, batch=args.batch, seq_len=args.seq,
                           vocab=cfg.vocab)

    def loss(p, batch):
        return loss_fn(p, cfg, batch)

    def batches():
        while True:
            yield shard_batch(pipe.next(), mesh)

    with mesh:
        if args.lc:
            plan = CompressionPlan.parse(
                args.scheme or f"adaptive:{args.k}",
                lc=LCConfig(mu0=1e-2, mu_growth=1.4,
                            num_lc_iters=args.lc_iters))
            tr = LCTrainer.from_plan(
                loss, plan, params,
                TrainerConfig(optimizer="adamw", lr=2e-3,
                              steps_per_l=max(1, args.steps // args.lc_iters)))
            state = tr.init(jax.random.PRNGKey(1), params)
            state = tr.run(state, batches(), log_every=1)
            ckpt.save_checkpoint(args.ckpt_dir, int(state.step), state,
                                 extra={"data_step": pipe.state.step})
            packed = plan.pack(state.params, state.lc_state, tr.qspec)
            art = os.path.join(args.ckpt_dir, "packed")
            packed.save(art)
            s = packed.summary()
            print(f"LC training done; checkpoint in {args.ckpt_dir}; "
                  f"PackedModel artifact in {art} "
                  f"(×{s['ratio']:.1f}, {s['packed_bytes']} B) — serve with "
                  f"launch.serve --packed")
        else:
            from repro.train.trainer import init_train_state, make_train_step
            tc = TrainerConfig(optimizer="adamw", lr=2e-3)
            state = init_train_state(params, tc)
            step = jax.jit(make_train_step(loss, tc))
            it = batches()
            for i in range(args.steps):
                state, m = step(state, next(it))
                if i % 10 == 0:
                    print(f"[{i:4d}] loss={float(m['loss']):.4f}")
            ckpt.save_checkpoint(args.ckpt_dir, args.steps, state,
                                 extra={"data_step": pipe.state.step})
            print("done; checkpoint saved to", args.ckpt_dir)


if __name__ == "__main__":
    main()
