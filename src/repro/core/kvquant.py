"""Codebook quantization of KV-cache pages (paper eq. 14 on activations).

The paper's C-step machinery is agnostic to *which* tensor it
compresses: a KV page is just another weight matrix whose distortion-
vs-bytes trade-off eq. 14 accounts for.  This module holds the pure-jnp
primitives the paged serving stack shares:

* **fit** — per-group adaptive codebooks learned at page-write time by
  the in-tree exact 1-D k-means (``core.kmeans``), quantile-seeded so
  the fit is deterministic (no RNG in the serving path);
* **assign/dequant** — eq.-11 nearest-codebook assignment
  (``quant_ops.fixed_codebook_assign``) and its LUT inverse;
* **pack** — a jit-friendly twin of ``compression.pack_rows`` so the
  engine can bit-pack indices *inside* the decode step (the host numpy
  packer only serves artifact build time);
* **byte accounting** — eq.-14 page/token byte math with KV bits as a
  free variable (what ``bench_engine`` and ``launch/report.py`` quote).

Grouping modes (``kv_cb_mode``):

* ``"page"`` — one codebook per page per tensor (K and V separate):
  cheapest metadata, coarsest fit;
* ``"head"`` — one codebook per (page, kv-head): n_kv× the metadata,
  tracks per-head scale differences (GQA K heads after RoPE span very
  different ranges than V heads).

Layout contract: indices pack along the trailing feature axis in the
``pack_rows`` little-endian no-straddle layout, so the in-kernel unpack
is the shared ``kernels/unpack.py`` shift+mask and the jnp inverse is
``compression.unpack_rows`` — the same micro-library the weight path
uses.  ``bits ∈ {2, 4, 8}`` (divisors of 32; K = 2**bits codebook
entries, ``bits == bits_per_index(K)`` exactly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans_fit, quantile_init

Array = jax.Array

KV_BITS_CHOICES = (2, 4, 8)
# k-means iterations per page-write fit.  Pages are tiny (≤ a few
# hundred scalars) and quantile seeding is already near-optimal in 1-D,
# so a short budget converges; the fit runs inside the jitted decode
# step, so this is a static trip count.
KV_FIT_ITERS = 8


def check_kv_bits(bits: int) -> int:
    if bits not in KV_BITS_CHOICES:
        raise ValueError(f"kv_bits={bits}; choose one of {KV_BITS_CHOICES} "
                         f"(0 disables KV quantization)")
    return bits


def kv_entries(bits: int) -> int:
    return 1 << bits


def kv_lanes(bits: int) -> int:
    return 32 // bits


def words_per(d: int, bits: int) -> int:
    """uint32 words per packed feature row of true width ``d``."""
    return -(-d // kv_lanes(bits))


def pack_rows_jnp(idx: Array, bits: int) -> Array:
    """jnp twin of ``compression.pack_rows`` over the trailing axis.

    [..., D] int assignments (< 2**bits) → [..., ⌈D/lanes⌉] uint32,
    lane l of word w holding index w·lanes+l at bit offset l·bits —
    bit-identical to the host packer, invertible by
    ``compression.unpack_rows`` / ``kernels.unpack.unpack_words_axis1``.
    """
    lanes = kv_lanes(bits)
    d = idx.shape[-1]
    pad = (-d) % lanes
    w = idx.astype(jnp.uint32)
    if pad:
        w = jnp.pad(w, [(0, 0)] * (w.ndim - 1) + [(0, pad)])
    w = w.reshape(w.shape[:-1] + (-1, lanes))
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * jnp.uint32(bits)
    # lanes occupy disjoint bit fields, so the sum is exactly the OR
    return jnp.sum(w << shifts, axis=-1, dtype=jnp.uint32)


def fit_codebooks(vals: Array, bits: int, iters: int = KV_FIT_ITERS
                  ) -> Array:
    """[..., G, N] values → [..., G, K] sorted f32 codebooks.

    Deterministic: quantile seeding + exact 1-D k-means (no RNG).
    K > N is fine — empty clusters keep their centroids (the decode
    first-write fit sees one token row per group).
    """
    k = kv_entries(check_kv_bits(bits))
    lead = vals.shape[:-1]
    flat = vals.reshape((-1, vals.shape[-1])).astype(jnp.float32)

    def fit_one(row):
        return kmeans_fit(row, quantile_init(row, k), iters=iters).codebook

    cbs = jax.vmap(fit_one)(flat)
    return cbs.reshape(lead + (k,))


def assign_codebook(vals: Array, cbs: Array) -> Array:
    """[..., G, N] values + [..., G, K] sorted codebooks → int32 indices.

    Eq.-11 midpoint assignment in f32 — the same rule the stored pages
    are reconstructed against, so storage is idempotent:
    ``assign(dequant(assign(v)))) == assign(v)``.
    """
    mids = 0.5 * (cbs[..., 1:] + cbs[..., :-1]).astype(jnp.float32)
    v = vals.astype(jnp.float32)

    def one(row, m):
        return jnp.searchsorted(m, row, side="right").astype(jnp.int32)

    lead = vals.shape[:-1]
    flat_v = v.reshape((-1, v.shape[-1]))
    flat_m = jnp.broadcast_to(mids, lead + mids.shape[-1:]).reshape(
        (-1, mids.shape[-1]))
    idx = jax.vmap(one)(flat_v, flat_m)
    return idx.reshape(vals.shape)


def dequant_codebook(idx: Array, cbs: Array) -> Array:
    """int32 indices [..., G, N] + codebooks [..., G, K] → values.

    Pure LUT gather; output dtype is the codebook's.
    """
    cb_b = jnp.broadcast_to(cbs, idx.shape[:-1] + cbs.shape[-1:])
    return jnp.take_along_axis(cb_b, idx, axis=-1)


# ---------------------------------------------------------------------------
# eq.-14 byte accounting with KV bits as the free variable


def kv_bytes_per_token(bits: int, head_dim: int, n_kv: int) -> float:
    """HBM bytes per token per cached tensor (K or V) at ``bits``.

    The invariant ``bench_kernels`` rows quote and
    ``test_bench_accounting`` asserts: bits/8 × head_dim × n_kv.
    """
    return bits / 8.0 * head_dim * n_kv


def quant_page_bytes(page_size: int, feat: int, bits: int, n_cb: int,
                     itemsize: int = 4) -> int:
    """Stored bytes of one quantized page of ``feat`` features/token:
    packed words + ``n_cb`` per-page codebooks of K = 2**bits entries."""
    check_kv_bits(bits)
    word_bytes = page_size * words_per(feat, bits) * 4
    cb_bytes = n_cb * kv_entries(bits) * itemsize
    return word_bytes + cb_bytes


def dense_page_bytes(page_size: int, feat: int, itemsize: int = 4) -> int:
    return page_size * feat * itemsize
