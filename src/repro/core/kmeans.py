"""Exact 1-D k-means for adaptive-codebook quantization (paper §4.1).

The C step with an adaptive codebook is the quadratic-distortion problem
(eq. 9), solved by k-means.  For scalar weights each iteration is exact in
O(P log K): sort the K centroids once, then a weight belongs to centroid k
iff it falls between the midpoints of (c_{k-1},c_k) and (c_k,c_{k+1})
— a ``searchsorted`` over K-1 midpoints (paper §4.1, eq. 11 geometry).

Supports:
* weighted points (used by the histogram-compressed distributed C step);
* warm start (LC C steps re-use the previous codebook: paper Fig. 10 shows
  ~1 iteration suffices after the first);
* k-means++ initialization for the first C step (paper §3.3);
* an optional mesh ``axis_name`` — inside ``shard_map`` the per-centroid
  statistics are psum'd, giving the exact *global* k-means update while the
  weight shards never leave their chips (2·K floats of traffic/iteration).
* vmapped per-group fits via ``jax.vmap`` (stacked-layer codebooks).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.quant_ops import fixed_codebook_assign

Array = jax.Array


class KMeansResult(NamedTuple):
    codebook: Array      # [K] ascending
    assignments: Array   # same shape as input points, int32
    distortion: Array    # scalar Σ n_i (w_i - c_{κ(i)})²
    iters_run: Array     # scalar int32 — iterations until assignment fixpoint


def kmeans_plus_plus_init(key: Array, w: Array, k: int) -> Array:
    """k-means++ seeding (Arthur & Vassilvitskii 2007) on scalar points."""
    flat = w.ravel()
    p = flat.size
    k0, key = jax.random.split(key)
    first = flat[jax.random.randint(k0, (), 0, p)]
    cents = jnp.full((k,), first, flat.dtype)
    d2 = (flat - first) ** 2

    def body(i, carry):
        cents, d2, key = carry
        key, sub = jax.random.split(key)
        # D² sampling; degenerate (all-zero) distances fall back to uniform.
        total = jnp.sum(d2)
        probs = jnp.where(total > 0, d2 / total, jnp.full_like(d2, 1.0 / p))
        idx = jax.random.choice(sub, p, p=probs)
        c_new = flat[idx]
        cents = cents.at[i].set(c_new)
        d2 = jnp.minimum(d2, (flat - c_new) ** 2)
        return cents, d2, key

    cents, _, _ = jax.lax.fori_loop(1, k, body, (cents, d2, key))
    return jnp.sort(cents)


def quantile_init(w: Array, k: int) -> Array:
    """Deterministic quantile seeding — the distributed-friendly default.

    Exact on a single device; under sharding callers pass a (histogram-)
    approximated quantile vector instead.
    """
    qs = (jnp.arange(k, dtype=jnp.float32) + 0.5) / k
    return jnp.quantile(w.ravel().astype(jnp.float32), qs).astype(w.dtype)


def kmeans_fit(
    w: Array,
    init_codebook: Array,
    iters: int = 30,
    point_weights: Optional[Array] = None,
    axis_name: Optional[Union[str, Sequence[str]]] = None,
    tol: float = 1e-4,
) -> KMeansResult:
    """Run ≤ ``iters`` exact 1-D k-means iterations from ``init_codebook``.

    Iterations after convergence are no-ops (pure-jnp loops must have static
    trip counts); ``iters_run`` reports when convergence was reached — the
    paper's Fig. 10 warm-start claim is measured with it.  Convergence is
    either the assignment fixpoint or a distortion plateau: relative
    improvement ≤ ``tol`` per iteration.  The plateau stop is what makes
    warm starts cheap — near the optimum, boundary points can keep flipping
    between adjacent cells for many iterations while the distortion is
    already flat.

    Empty clusters keep their previous centroid (can re-acquire points later).
    """
    flat = w.ravel()
    nw = jnp.ones_like(flat) if point_weights is None else point_weights.ravel()
    k = init_codebook.shape[0]

    def psum(x):
        return jax.lax.psum(x, axis_name) if axis_name is not None else x

    def step(carry, _):
        c, prev_assign, prev_dist, done, n_run = carry
        assign = fixed_codebook_assign(flat, c)
        sums = psum(jax.ops.segment_sum(flat * nw, assign, num_segments=k))
        counts = psum(jax.ops.segment_sum(nw, assign, num_segments=k))
        c_new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), c)
        c_new = jnp.sort(c_new)
        # Convergence must be GLOBAL: a shard whose local assignments are
        # already stable must keep iterating with the others, else the
        # replicated codebooks diverge across shards.  (The distortion is
        # psum'd, so the plateau criterion is global too.)
        changed = jnp.any(assign != prev_assign).astype(jnp.float32)
        changed = psum(changed) > 0
        # f32 accumulation: the carry slot is f32, and bf16 would both
        # break the scan carry-type match and swamp the plateau test.
        resid = (flat - c[assign]).astype(jnp.float32)
        dist = psum(jnp.sum(nw.astype(jnp.float32) * resid * resid))
        plateau = (prev_dist - dist) <= tol * jnp.abs(dist)
        # Freeze once converged so iters_run is the true fixpoint index.
        c_out = jnp.where(done, c, c_new)
        n_run = n_run + jnp.where(done, 0, 1)
        done = done | ~changed | plateau
        return (c_out, assign, dist, done, n_run), None

    c0 = jnp.sort(init_codebook.astype(flat.dtype))
    init = (c0, jnp.full(flat.shape, -1, jnp.int32),
            jnp.asarray(jnp.inf, jnp.float32), jnp.asarray(False),
            jnp.asarray(0, jnp.int32))
    (c, _, _, _, n_run), _ = jax.lax.scan(step, init, None, length=iters)

    assign = fixed_codebook_assign(flat, c)
    resid = (flat - c[assign]).astype(jnp.float32)
    dist = psum(jnp.sum(nw.astype(jnp.float32) * resid * resid))
    return KMeansResult(c, assign.reshape(w.shape), dist, n_run)


def kmeans_quantize(
    w: Array,
    codebook: Array,
) -> Array:
    """Δ(Θ): decompress — map each weight to its assigned codebook entry."""
    c = jnp.sort(codebook)
    return c[fixed_codebook_assign(w, c)].astype(w.dtype)


# Per-group (stacked-layer) variants: codebooks [G, K], weights [G, ...].
kmeans_fit_grouped = jax.vmap(
    lambda w, c, iters: kmeans_fit(w, c, iters=iters),
    in_axes=(0, 0, None),
)


def quantile_init_grouped(w: Array, k: int) -> Array:
    """[G, ...] weights → [G, K] quantile codebooks."""
    return jax.vmap(lambda x: quantile_init(x, k))(w)
