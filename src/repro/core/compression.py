"""Compression accounting (paper eq. 14) and index bit-packing.

ratio ρ(K) = #bits(reference) / #bits(quantized)
  #bits(reference) = (P1 + P0)·b
  #bits(quantized) = P1·⌈log2 K⌉ + (P0 + E)·b
where P1 = quantized (multiplicative) weights, P0 = non-quantized params
(biases etc.), E = stored float entries (codebook size K for adaptive, 1
for a learned scale, 0 for fixed values), b = float bit width (32 unless
stated — the paper is explicit that b must be quoted).

Bit-packing stores ⌈log2 K⌉-bit assignment indices in uint32 words, the
on-disk / serving format consumed by the codebook-matmul kernel.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def bits_per_index(k: int) -> int:
    return max(1, math.ceil(math.log2(k)))


def compression_ratio(
    p1: int, p0: int, k: int, codebook_entries: int, b: int = 32
) -> float:
    """Paper eq. (14).  ``codebook_entries``: floats stored with the model."""
    ref_bits = (p1 + p0) * b
    quant_bits = p1 * bits_per_index(k) + (p0 + codebook_entries) * b
    return ref_bits / quant_bits


def pack_indices(assign: np.ndarray, k: int) -> Tuple[np.ndarray, int]:
    """Pack integer assignments (< k) into a uint32 word stream.

    Indices are laid out little-endian within each word at a fixed
    ``bits_per_index(k)`` width (no straddling: ``floor(32/bits)`` lanes per
    word) so the unpack is a shift+mask — TPU/VPU friendly.
    Returns (words, lanes_per_word).
    """
    bits = bits_per_index(k)
    lanes = 32 // bits
    flat = np.asarray(assign, dtype=np.uint32).ravel()
    pad = (-flat.size) % lanes
    flat = np.pad(flat, (0, pad))
    flat = flat.reshape(-1, lanes)
    words = np.zeros(flat.shape[0], dtype=np.uint32)
    for lane in range(lanes):
        words |= flat[:, lane] << np.uint32(lane * bits)
    return words, lanes


def unpack_indices(words: Array, n: int, k: int) -> Array:
    """Inverse of :func:`pack_indices` (jnp; usable on device)."""
    bits = bits_per_index(k)
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * bits
    out = (words[:, None] >> shifts[None, :]) & mask
    return out.ravel()[:n].astype(jnp.int32)


def quantized_bytes(p1: int, p0: int, k: int, codebook_entries: int,
                    b: int = 32) -> int:
    """Absolute storage in bytes of the packed model (for bench tables)."""
    return (p1 * bits_per_index(k) + (p0 + codebook_entries) * b + 7) // 8
