"""Compression accounting (paper eq. 14), index bit-packing, and the
:class:`PackedModel` artifact.

ratio ρ(K) = #bits(reference) / #bits(quantized)
  #bits(reference) = (P1 + P0)·b
  #bits(quantized) = P1·⌈log2 K⌉ + (P0 + E)·b
where P1 = quantized (multiplicative) weights, P0 = non-quantized params
(biases etc.), E = stored float entries (codebook size K for adaptive, 1
for a learned scale, 0 for fixed values), b = float bit width (32 unless
stated — the paper is explicit that b must be quoted).

Bit-packing stores ⌈log2 K⌉-bit assignment indices in uint32 words, the
on-disk / serving format consumed by the codebook-matmul kernel.

``PackedModel`` is the deployable artifact of a finished LC run: per-leaf
packed assignment words + effective decode codebooks for every quantized
leaf, dense storage for the rest, with eq.-14 accounting attached.  It is
what ``CompressionPlan.pack`` emits, what ``save``/``load`` round-trips,
and what the serving path (``repro.kernels.dispatch`` + ``launch/serve.py
--packed``) consumes instead of dense params.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import re
import warnings
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


class ArtifactError(RuntimeError):
    """A :class:`PackedModel` artifact is missing, truncated, or fails
    integrity verification.  The message names the offending leaf/key so
    an operator knows *which* array is bad, and the serving entry points
    (``launch/serve.py``, ``repro.analysis.audit``) surface it as a
    clean load failure instead of a deep numpy traceback — a corrupt
    artifact must never be half-served."""


def _array_sha256(arr: np.ndarray) -> str:
    """Content hash of one array (dtype/shape are recorded separately in
    the manifest, so the hash covers exactly the element bytes)."""
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def bits_per_index(k: int) -> int:
    return max(1, math.ceil(math.log2(k)))


def compression_ratio(
    p1: int, p0: int, k: int, codebook_entries: int, b: int = 32
) -> float:
    """Paper eq. (14).  ``codebook_entries``: floats stored with the model."""
    ref_bits = (p1 + p0) * b
    quant_bits = p1 * bits_per_index(k) + (p0 + codebook_entries) * b
    return ref_bits / quant_bits


def pack_indices(assign: np.ndarray, k: int) -> Tuple[np.ndarray, int]:
    """Pack integer assignments (< k) into a uint32 word stream.

    Indices are laid out little-endian within each word at a fixed
    ``bits_per_index(k)`` width (no straddling: ``floor(32/bits)`` lanes per
    word) so the unpack is a shift+mask — TPU/VPU friendly.
    Returns (words, lanes_per_word).
    """
    bits = bits_per_index(k)
    lanes = 32 // bits
    flat = np.asarray(assign, dtype=np.uint32).ravel()
    pad = (-flat.size) % lanes
    flat = np.pad(flat, (0, pad))
    flat = flat.reshape(-1, lanes)
    words = np.zeros(flat.shape[0], dtype=np.uint32)
    for lane in range(lanes):
        words |= flat[:, lane] << np.uint32(lane * bits)
    return words, lanes


def unpack_indices(words: Array, n: int, k: int) -> Array:
    """Inverse of :func:`pack_indices` (jnp; usable on device)."""
    bits = bits_per_index(k)
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * bits
    out = (words[:, None] >> shifts[None, :]) & mask
    return out.ravel()[:n].astype(jnp.int32)


def pack_indices_2d(idx: np.ndarray, k: int) -> np.ndarray:
    """Column-preserving pack for the serve-path matmul operand.

    ``idx`` [Kd, N] → uint32 words [⌈Kd/lanes⌉, N]: word (w, n) holds the
    ``lanes`` consecutive *reduction-axis* indices idx[w·lanes+l, n] at bit
    offset l·bits (same little-endian no-straddle layout as
    :func:`pack_indices`, applied per output column).  This is the HBM
    layout ``kernels.codebook_matmul_packed`` consumes: one [bkw, bn] word
    tile unpacks in VMEM to a [bkw·lanes, bn] index tile with a shift+mask.
    """
    bits = bits_per_index(k)
    lanes = 32 // bits
    idx = np.asarray(idx, dtype=np.uint32)
    kd, n = idx.shape
    pad = (-kd) % lanes
    idx = np.pad(idx, ((0, pad), (0, 0)))
    idx = idx.reshape(-1, lanes, n)
    words = np.zeros((idx.shape[0], n), dtype=np.uint32)
    for lane in range(lanes):
        words |= idx[:, lane, :] << np.uint32(lane * bits)
    return words


def unpack_indices_2d(words: Array, kd: int, k: int) -> Array:
    """Inverse of :func:`pack_indices_2d` (jnp; usable on device / in-jit)."""
    bits = bits_per_index(k)
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * bits
    out = (words[:, None, :] >> shifts[None, :, None]) & mask
    return out.reshape(-1, words.shape[-1])[:kd].astype(jnp.int32)


def pack_rows(idx: np.ndarray, k: int) -> np.ndarray:
    """Row-major pack for *gather-accessed* operands (embedding tables).

    ``idx`` [V, D] → uint32 words [V, ⌈D/lanes⌉]: word (v, w) holds the
    ``lanes`` consecutive *feature-axis* indices idx[v, w·lanes+l] at bit
    offset l·bits — each vocab row is a contiguous packed run, so a token
    gather reads exactly ``⌈D/lanes⌉`` words = ``bits_per_index(k)/8``
    bytes per gathered weight.  This is the layout
    ``kernels.quantized_gather`` (fused row gather) and
    ``kernels.codebook_matmul_packed_t`` with ``order="row"`` (fused tied
    LM head — D is the contraction axis) both consume, so one stored
    operand serves both access patterns of a tied embedding.
    """
    bits = bits_per_index(k)
    lanes = 32 // bits
    idx = np.asarray(idx, dtype=np.uint32)
    v, d = idx.shape
    pad = (-d) % lanes
    idx = np.pad(idx, ((0, 0), (0, pad)))
    idx = idx.reshape(v, -1, lanes)
    words = np.zeros(idx.shape[:2], dtype=np.uint32)
    for lane in range(lanes):
        words |= idx[:, :, lane] << np.uint32(lane * bits)
    return words


def unpack_rows(words: Array, d: int, k: int) -> Array:
    """Inverse of :func:`pack_rows` over the trailing axis (jnp; arbitrary
    leading dims — usable on a gathered [..., ⌈D/lanes⌉] word batch)."""
    bits = bits_per_index(k)
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * bits
    out = (words[..., :, None] >> shifts) & mask
    out = out.reshape(words.shape[:-1] + (-1,))
    return out[..., :d].astype(jnp.int32)


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Static lane metadata of one packed-index matmul operand.

    Registered static so it rides inside a params pytree (including through
    ``jax.lax.scan`` over stacked layers) without becoming a traced leaf —
    the kernel needs these as Python ints at trace time.
    """

    kd: int        # true reduction dim (rows of the unpacked idx)
    n: int         # output dim (columns)
    k: int         # index-space size (codebook entries)
    bits: int      # bits per index = bits_per_index(k)
    lanes: int     # indices per uint32 word = 32 // bits
    # Original (per-group) dense shape when it is not the packed (kd, n)
    # matrix view — e.g. MoE expert stacks [E, D, F] pack as (E·D, F).
    # None means the leaf is the plain 2-D matrix (kd, n).
    shape: Optional[Tuple[int, ...]] = None
    # Original leaf dtype (string).  Codebooks are stored f32 for kernel
    # precision; dequantized weights / gathered embedding rows cast back
    # to this so a bf16 model's packed serve matches its dense layout
    # (the embedding is the dtype anchor of the residual stream).
    dtype: Optional[str] = None
    # Word orientation: "kd" = pack_indices_2d (words run down the
    # reduction axis: pidx [⌈kd/lanes⌉, n] — the matmul operand layout);
    # "row" = pack_rows (words run along each row: pidx [kd, ⌈n/lanes⌉] —
    # the gather / transposed-matmul layout for embedding tables).
    order: str = "kd"

    @classmethod
    def make(cls, kd: int, n: int, k: int,
             shape: Optional[Tuple[int, ...]] = None,
             dtype: Optional[str] = None,
             order: str = "kd") -> "PackedLayout":
        if order not in ("kd", "row"):
            raise ValueError(f"order={order!r}; choose kd|row")
        bits = bits_per_index(k)
        return cls(kd=kd, n=n, k=k, bits=bits, lanes=32 // bits,
                   shape=None if shape is None else tuple(shape),
                   dtype=dtype, order=order)

    @property
    def words(self) -> int:
        """Rows of the packed word array: ⌈kd/lanes⌉ ("kd") or kd ("row")."""
        return -(-self.kd // self.lanes) if self.order == "kd" else self.kd

    @property
    def word_shape(self) -> Tuple[int, int]:
        """Shape of the packed uint32 word array for this layout."""
        if self.order == "kd":
            return (-(-self.kd // self.lanes), self.n)
        return (self.kd, -(-self.n // self.lanes))


def quantized_bytes(p1: int, p0: int, k: int, codebook_entries: int,
                    b: int = 32) -> int:
    """Absolute storage in bytes of the packed model (for bench tables)."""
    return (p1 * bits_per_index(k) + (p0 + codebook_entries) * b + 7) // 8


# ---------------------------------------------------------------------------
# Path-keyed pytree (de)construction
# ---------------------------------------------------------------------------

PathToken = Union[str, int]
_PATH_RE = re.compile(r"\['([^']*)'\]|\[(\d+)\]")


def path_tokens(path: str) -> Tuple[PathToken, ...]:
    """``"['stacks'][0]['mlp']['w_in']"`` → ``("stacks", 0, "mlp", "w_in")``
    (the inverse of ``jax.tree_util.keystr`` on dict/sequence trees)."""
    tokens: List[PathToken] = []
    pos = 0
    for m in _PATH_RE.finditer(path):
        if m.start() != pos:
            raise ValueError(f"unparseable tree path {path!r}")
        pos = m.end()
        tokens.append(m.group(1) if m.group(1) is not None
                      else int(m.group(2)))
    if pos != len(path) or not tokens:
        raise ValueError(f"unparseable tree path {path!r}")
    return tuple(tokens)


def unflatten_paths(entries: Dict[Tuple[PathToken, ...], Any]) -> PyTree:
    """Rebuild a nested dict/tuple tree from token-path-keyed leaves.
    Integer-keyed levels become tuples (the params convention)."""
    root: dict = {}
    for tokens, val in entries.items():
        node = root
        for t in tokens[:-1]:
            node = node.setdefault(t, {})
        node[tokens[-1]] = val

    def finish(node):
        if not isinstance(node, dict):
            return node
        if node and all(isinstance(k, int) for k in node):
            if sorted(node) != list(range(len(node))):
                raise ValueError(f"non-contiguous sequence keys {sorted(node)}")
            return tuple(finish(node[i]) for i in range(len(node)))
        return {k: finish(v) for k, v in node.items()}

    return finish(root)


# ---------------------------------------------------------------------------
# PackedModel — the deployable artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedLeaf:
    """One quantized leaf: bit-packed assignment words + effective decode
    codebook.  Grouped (stacked-layer) leaves carry a leading G axis on
    both ``words`` [G, W] and ``codebook`` [G, K]."""

    words: np.ndarray        # uint32, [W] or [G, W]
    codebook: np.ndarray     # float32, [K] or [G, K]
    shape: Tuple[int, ...]   # original leaf shape
    k: int                   # index-space size (≤ codebook.shape[-1])
    dtype: str               # original leaf dtype

    @property
    def grouped(self) -> bool:
        return self.words.ndim == 2

    @property
    def bits(self) -> int:
        return bits_per_index(self.k)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))

    def indices(self) -> Array:
        """Unpacked int32 assignment indices in the original leaf shape."""
        words = jnp.asarray(self.words)
        if self.grouped:
            n = int(np.prod(self.shape[1:]))
            idx = jax.vmap(lambda w: unpack_indices(w, n, self.k))(words)
        else:
            idx = unpack_indices(words, self.size, self.k)
        return idx.reshape(self.shape)

    def decode(self) -> Array:
        """Δ(Θ): codebook gather — bit-exact vs the LC ``finalize`` leaf."""
        idx = self.indices()
        cb = jnp.asarray(self.codebook)
        if self.grouped:
            dec = jax.vmap(lambda i, c: c[i])(idx.reshape(idx.shape[0], -1), cb)
        else:
            dec = cb[idx.reshape(-1)]
        return dec.reshape(self.shape).astype(self.dtype)


def _pack_assignments(assign: np.ndarray, k: int) -> np.ndarray:
    words, _ = pack_indices(assign.ravel(), k)
    return words


@dataclasses.dataclass
class PackedModel:
    """Deployable quantized-model artifact (pack → save/load → serve).

    ``packed``: keystr path → PackedLeaf for every quantized leaf;
    ``dense``: keystr path → raw array for everything else (biases, norms);
    eq.-14 accounting (``summary``) rides along.
    """

    packed: Dict[str, PackedLeaf]
    dense: Dict[str, np.ndarray]
    scheme_spec: str
    k: int
    codebook_entries: int
    bits_ref: int = 32

    # -- construction -------------------------------------------------------

    @classmethod
    def pack(cls, params: PyTree, state, plan, qspec: Optional[PyTree] = None,
             bits_ref: int = 32) -> "PackedModel":
        """Pack a finished LC run: ``state`` is the LCState whose Θ defines
        the codebooks; ``plan`` a CompressionPlan (or bare Scheme)."""
        from repro.core import lc as lc_mod

        from repro.core.schemes import as_scheme

        scheme = as_scheme(plan)
        if qspec is None:
            qspec = (plan.build_qspec(params) if hasattr(plan, "build_qspec")
                     else lc_mod.default_qspec(params))
        w_c = lc_mod.finalize(params, state, qspec)
        grouped = lc_mod._grouped_lookup(qspec)
        quant_paths = set(lc_mod.quant_leaf_paths(qspec))
        k = scheme.index_entries

        packed: Dict[str, PackedLeaf] = {}
        dense: Dict[str, np.ndarray] = {}
        flat = jax.tree_util.tree_flatten_with_path(w_c)[0]
        for path, leaf in flat:
            ks = jax.tree_util.keystr(path)
            if ks not in quant_paths:
                dense[ks] = np.asarray(leaf)
                continue
            th = state.theta[ks]
            if grouped[ks]:
                assign = jax.vmap(scheme.assignments)(leaf, th)
                cb = jax.vmap(lambda t: scheme.decode(jnp.arange(k), t))(th)
                assign_np = np.asarray(assign)
                words = np.stack([_pack_assignments(a, k) for a in assign_np])
            else:
                assign = scheme.assignments(leaf, th)
                cb = scheme.decode(jnp.arange(k), th)
                words = _pack_assignments(np.asarray(assign), k)
            packed[ks] = PackedLeaf(
                words=words, codebook=np.asarray(cb, np.float32),
                shape=tuple(leaf.shape), k=k, dtype=str(leaf.dtype))
        return cls(packed=packed, dense=dense, scheme_spec=scheme.spec, k=k,
                   codebook_entries=lc_mod.codebook_entry_count(state, scheme),
                   bits_ref=bits_ref)

    # -- consumption --------------------------------------------------------

    def decode(self) -> PyTree:
        """Full dense params pytree — bit-exact vs ``lc.finalize``."""
        entries: Dict[Tuple[PathToken, ...], Any] = {}
        for ks, leaf in self.packed.items():
            entries[path_tokens(ks)] = leaf.decode()
        for ks, arr in self.dense.items():
            entries[path_tokens(ks)] = jnp.asarray(arr)
        return unflatten_paths(entries)

    def _serves_quantized(self, ks: str, leaf: "PackedLeaf"
                          ) -> Tuple[bool, str]:
        """Shared eligibility rule for :meth:`serving_params` (full
        coverage) and :meth:`leaf_coverage` — (serves_quantized, reason).

        Leaves whose path matches ``DEFAULT_EXCLUDE`` decode dense even
        if an artifact packed them (e.g. pre-d_skip-fix artifacts, or a
        custom qspec): model code reads policy-excluded leaves raw, not
        through qleaf, so serving them renamed would crash."""
        from repro.core.lc import DEFAULT_EXCLUDE
        tokens = path_tokens(ks)
        if not isinstance(tokens[-1], str):
            return False, "non-string leaf key: dense-decoded"
        mshape = leaf.shape[1:] if leaf.grouped else leaf.shape
        if leaf.k > 256:
            return False, f"K={leaf.k} > 256: dense-decoded"
        if len(mshape) < 2:
            return False, "per-group ndim < 2: dense-decoded"
        m = DEFAULT_EXCLUDE.search(ks)
        if m:
            return False, (f"policy exclude /{m.group(0)}/: model reads "
                           "this leaf raw — dense-decoded")
        return True, ""

    # Leaves accessed by *row gather* at serve time (embedding tables,
    # which double as the tied LM head): packed per-row along the feature
    # axis (``pack_rows``) so a token gather reads bits/8 B/weight and the
    # fused transposed head contracts the packed axis directly.
    GATHER_NAMES: Tuple[str, ...] = ("embed_tok",)

    def serving_params(
        self, quant_names: Optional[Tuple[str, ...]] = None,
        packed: bool = False,
        gather_names: Optional[Tuple[str, ...]] = None,
    ) -> PyTree:
        """Params pytree for quantized serving.

        ``quant_names=None`` (default, full-model coverage): **every**
        packed leaf stays quantized — attention q/k/v/o, the embedding
        table / LM head, MoE expert stacks, SSM/RG-LRU projections as well
        as the MLP leaves.  (Which leaves were packed in the first place
        is the qspec policy — ``DEFAULT_EXCLUDE`` keeps biases, norms,
        routers, recurrence dynamics dense.)  Pass an explicit tuple —
        e.g. the pre-qleaf MLP set ``("w_in", "w_gate", "w_out")`` — to
        restrict coverage; everything else decodes dense.

        ``packed=False`` (legacy/oracle layout): ``<name>_idx`` uint8
        indices + ``<name>_cb`` codebook — 1 B/weight of HBM index traffic.

        ``packed=True`` (the bit-packed serve layout): ``<name>_pidx``
        uint32 words from :func:`pack_indices_2d` ([⌈Kd/lanes⌉, N], with a
        leading G axis on grouped leaves), ``<name>_cb``, and
        ``<name>_layout`` (static :class:`PackedLayout` lane metadata) —
        exactly ``bits_per_index(k)/8`` bytes/weight of HBM index traffic,
        consumed directly by ``kernels.dispatch.packed_codebook_matmul``
        / ``quantized_gather``.  Leaves whose per-group shape is not a
        2-D matrix (MoE expert stacks [E, D, F]) pack the flattened
        (∏lead, last) view and record the dense shape on the layout.
        Leaves named in ``gather_names`` (default :attr:`GATHER_NAMES` —
        embedding tables, row-gathered at serve time and doubling as the
        tied LM head) pack per-row instead (:func:`pack_rows`,
        ``layout.order == "row"``) so both the fused gather and the fused
        transposed-head kernel read bits/8 B/weight.
        No uint8 (or wider) index array is ever materialized.
        """
        if gather_names is None:
            gather_names = self.GATHER_NAMES
        entries: Dict[Tuple[PathToken, ...], Any] = {}
        for ks, leaf in self.packed.items():
            tokens = path_tokens(ks)
            name = tokens[-1]
            eligible, _ = self._serves_quantized(ks, leaf)
            if not (eligible
                    and (quant_names is None or name in quant_names)):
                entries[tokens] = leaf.decode()
                continue
            mshape = leaf.shape[1:] if leaf.grouped else leaf.shape
            if packed:
                # f32 codebook: the kernels dequant in f32 and cast the
                # result; the layout carries the original leaf dtype.
                cb = jnp.asarray(leaf.codebook, jnp.float32)
                kd = int(np.prod(mshape[:-1]))
                n = int(mshape[-1])
                idx = np.asarray(leaf.indices())
                row_packed = (name in gather_names and not leaf.grouped
                              and len(mshape) == 2)
                if row_packed:
                    words = pack_rows(idx.reshape(kd, n), leaf.k)
                elif leaf.grouped:
                    words = np.stack([pack_indices_2d(g.reshape(kd, n),
                                                      leaf.k) for g in idx])
                else:
                    words = pack_indices_2d(idx.reshape(kd, n), leaf.k)
                entries[tokens[:-1] + (f"{name}_pidx",)] = jnp.asarray(words)
                entries[tokens[:-1] + (f"{name}_layout",)] = (
                    PackedLayout.make(kd, n, leaf.k,
                                      shape=mshape if len(mshape) != 2
                                      else None,
                                      dtype=leaf.dtype,
                                      order="row" if row_packed else "kd"))
            else:
                # uint8 oracle layout has no static layout node to carry
                # the dtype: store the codebook in the leaf's original
                # dtype instead, so cb[idx] == decode() bitwise (the
                # oracle property) for bf16 models too.
                cb = jnp.asarray(leaf.codebook, jnp.float32).astype(
                    leaf.dtype)
                entries[tokens[:-1] + (f"{name}_idx",)] = (
                    leaf.indices().astype(jnp.uint8))
            entries[tokens[:-1] + (f"{name}_cb",)] = cb
        for ks, arr in self.dense.items():
            entries[path_tokens(ks)] = jnp.asarray(arr)
        return unflatten_paths(entries)

    def leaf_coverage(self, gather_names: Optional[Tuple[str, ...]] = None
                      ) -> List[Dict[str, Any]]:
        """Per-leaf coverage rows for the eq.-14 report: every param path
        with its shape, whether it **serves** quantized (the same
        eligibility rule as :meth:`serving_params` with full coverage —
        packed leaves with K > 256 or a sub-matrix per-group shape decode
        dense at serve time), the serve route (``gather_names`` must
        match what was passed to :meth:`serving_params`; default
        :attr:`GATHER_NAMES`), and why dense leaves are dense."""
        from repro.core.lc import DEFAULT_EXCLUDE
        if gather_names is None:
            gather_names = self.GATHER_NAMES
        rows: List[Dict[str, Any]] = []
        for ks, leaf in sorted(self.packed.items()):
            served, reason = self._serves_quantized(ks, leaf)
            name = path_tokens(ks)[-1]
            mshape = leaf.shape[1:] if leaf.grouped else leaf.shape
            # mirror serving_params' row_packed condition exactly
            row_packed = (name in gather_names and not leaf.grouped
                          and len(mshape) == 2)
            if not served:
                route = None
            elif row_packed:
                route = "qembed+qmatmul_t (pack_rows)"
            else:
                route = "qmatmul (pack_indices_2d)"
            rows.append({"path": ks, "shape": tuple(leaf.shape),
                         "quantized": served, "k": leaf.k,
                         "bits": leaf.bits if served else None,
                         "bytes_per_weight": leaf.bits / 8 if served
                         else None,
                         "route": route,
                         "reason": reason})
        for ks, arr in sorted(self.dense.items()):
            m = DEFAULT_EXCLUDE.search(ks)
            if m:
                reason = f"policy exclude: /{m.group(0)}/"
            elif np.ndim(arr) < 2:
                reason = f"ndim {np.ndim(arr)} < 2"
            else:
                reason = "excluded by qspec policy"
            rows.append({"path": ks, "shape": tuple(np.shape(arr)),
                         "quantized": False, "k": None, "bits": None,
                         "bytes_per_weight": None, "route": None,
                         "reason": reason})
        return rows

    # -- accounting (paper eq. 14) ------------------------------------------

    @property
    def p1(self) -> int:
        return sum(leaf.size for leaf in self.packed.values())

    @property
    def p0(self) -> int:
        return sum(int(a.size) for a in self.dense.values())

    def ratio(self) -> float:
        return compression_ratio(self.p1, self.p0, self.k,
                                 self.codebook_entries, b=self.bits_ref)

    def summary(self) -> Dict[str, Any]:
        return {
            "scheme": self.scheme_spec,
            "k": self.k,
            "bits_per_weight": bits_per_index(self.k),
            "p1": self.p1,
            "p0": self.p0,
            "codebook_entries": self.codebook_entries,
            "ref_bytes": (self.p1 + self.p0) * self.bits_ref // 8,
            "packed_bytes": quantized_bytes(self.p1, self.p0, self.k,
                                            self.codebook_entries,
                                            b=self.bits_ref),
            "ratio": self.ratio(),
        }

    # -- persistence --------------------------------------------------------

    def save(self, directory: str) -> str:
        """Write ``manifest.json`` + ``arrays.npz`` under ``directory``.

        Manifest **version 2**: every npz key carries its SHA-256 (over
        element bytes), dtype, and shape, plus artifact-wide totals —
        :meth:`load` verifies all of it, so a truncated download or a
        flipped bit fails loudly (``ArtifactError`` naming the leaf)
        instead of serving garbage logits."""
        os.makedirs(directory, exist_ok=True)
        arrays: Dict[str, np.ndarray] = {}
        integrity: Dict[str, Dict[str, Any]] = {}

        def add(key: str, arr: np.ndarray):
            arrays[key] = arr
            integrity[key] = {"sha256": _array_sha256(arr),
                              "dtype": str(np.asarray(arr).dtype),
                              "shape": list(np.shape(arr))}

        manifest: Dict[str, Any] = {
            "version": 2, "scheme": self.scheme_spec, "k": self.k,
            "codebook_entries": self.codebook_entries,
            "bits_ref": self.bits_ref, "packed": [], "dense": [],
        }
        for i, (ks, leaf) in enumerate(sorted(self.packed.items())):
            add(f"p{i}_words", leaf.words)
            add(f"p{i}_cb", leaf.codebook)
            manifest["packed"].append({"path": ks, "shape": list(leaf.shape),
                                       "k": leaf.k, "dtype": leaf.dtype})
        for j, (ks, arr) in enumerate(sorted(self.dense.items())):
            add(f"d{j}", arr)
            manifest["dense"].append({"path": ks})
        manifest["arrays"] = integrity
        manifest["n_arrays"] = len(arrays)
        manifest["total_elements"] = int(sum(int(np.asarray(a).size)
                                             for a in arrays.values()))
        np.savez(os.path.join(directory, "arrays.npz"), **arrays)
        with open(os.path.join(directory, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        return directory

    @classmethod
    def load(cls, directory: str) -> "PackedModel":
        """Load and verify an artifact.  Version-2 manifests are fully
        integrity-checked per array; version-1 (pre-integrity) artifacts
        still load, with a warning.  Any missing/corrupt piece raises
        :class:`ArtifactError` naming the bad leaf."""
        man_path = os.path.join(directory, "manifest.json")
        npz_path = os.path.join(directory, "arrays.npz")
        try:
            with open(man_path) as f:
                manifest = json.load(f)
        except FileNotFoundError:
            raise ArtifactError(f"no PackedModel manifest at {man_path}")
        except ValueError as e:
            raise ArtifactError(f"unparseable manifest {man_path}: {e}")
        version = int(manifest.get("version", 1))
        if version > 2:
            raise ArtifactError(
                f"{directory}: manifest version {version} is newer than "
                f"this reader (knows <= 2)")
        try:
            data = np.load(npz_path)
        except FileNotFoundError:
            raise ArtifactError(f"no PackedModel arrays at {npz_path}")
        except Exception as e:   # zipfile.BadZipFile, OSError, ...
            raise ArtifactError(f"unreadable arrays.npz at {npz_path}: "
                                f"{e!r}")

        def fetch(key: str, owner: str) -> np.ndarray:
            if key not in data.files:
                raise ArtifactError(
                    f"{directory}: arrays.npz is missing {key!r} "
                    f"(leaf {owner!r}) — truncated artifact?")
            try:
                arr = data[key]
            except Exception as e:
                raise ArtifactError(
                    f"{directory}: cannot decode {key!r} (leaf "
                    f"{owner!r}): {e!r}")
            if version >= 2:
                rec = manifest["arrays"].get(key)
                if rec is None:
                    raise ArtifactError(
                        f"{directory}: manifest has no integrity record "
                        f"for {key!r} (leaf {owner!r})")
                if (str(arr.dtype) != rec["dtype"]
                        or list(arr.shape) != list(rec["shape"])):
                    raise ArtifactError(
                        f"{directory}: {key!r} (leaf {owner!r}) is "
                        f"{arr.dtype}{list(arr.shape)}, manifest says "
                        f"{rec['dtype']}{rec['shape']}")
                got = _array_sha256(arr)
                if got != rec["sha256"]:
                    raise ArtifactError(
                        f"{directory}: {key!r} (leaf {owner!r}) failed "
                        f"integrity check: sha256 {got[:12]}… != manifest "
                        f"{rec['sha256'][:12]}…")
            return arr

        if version < 2:
            warnings.warn(
                f"PackedModel at {directory} has a version-{version} "
                f"manifest (no per-array integrity data); loading "
                f"unverified — re-save to upgrade", stacklevel=2)
        elif int(manifest.get("n_arrays", -1)) != len(data.files):
            raise ArtifactError(
                f"{directory}: arrays.npz holds {len(data.files)} arrays, "
                f"manifest expects {manifest.get('n_arrays')}")

        packed = {}
        for i, rec in enumerate(manifest["packed"]):
            packed[rec["path"]] = PackedLeaf(
                words=fetch(f"p{i}_words", rec["path"]),
                codebook=fetch(f"p{i}_cb", rec["path"]),
                shape=tuple(rec["shape"]), k=int(rec["k"]),
                dtype=rec["dtype"])
        dense = {rec["path"]: fetch(f"d{j}", rec["path"])
                 for j, rec in enumerate(manifest["dense"])}
        return cls(packed=packed, dense=dense,
                   scheme_spec=manifest["scheme"], k=int(manifest["k"]),
                   codebook_entries=int(manifest["codebook_entries"]),
                   bits_ref=int(manifest["bits_ref"]))
