"""LC-Quant core: the paper's contribution as a composable JAX module.

Public API (new code goes plan-first)::

    from repro.core import CompressionPlan, PackedModel

    plan = CompressionPlan.parse("adaptive:16")     # scheme+qspec+LC config
    ...LC fit...                                    # trainer / plan.c_step
    packed = plan.pack(params, state)               # deployable artifact
    packed.save(dir); PackedModel.load(dir)         # → serving path

Lower-level pieces (LCConfig, lc_init/c_step, make_scheme, …) stay
exported for the existing call sites and for string-spec compatibility.
"""
from repro.core.lc import (          # noqa: F401
    LCConfig,
    LCState,
    LeafSpec,
    c_step,
    codebook_entry_count,
    default_qspec,
    feasibility_gap,
    finalize,
    lc_init,
    param_counts,
    penalty_grad,
    penalty_value,
    quant_leaf_paths,
)
from repro.core.schemes import (     # noqa: F401
    AdaptiveScheme,
    FixedScheme,
    ScaledFixedScheme,
    Scheme,
    make_scheme,
    parse_spec,
    register_scheme,
    registered_schemes,
)
from repro.core.compression import (  # noqa: F401
    ArtifactError,
    PackedLayout,
    PackedLeaf,
    PackedModel,
)
from repro.core.plan import CompressionPlan, QSpecPolicy    # noqa: F401
from repro.core import baselines, compression, kmeans, quant_ops  # noqa: F401
