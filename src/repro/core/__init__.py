"""LC-Quant core: the paper's contribution as a composable JAX module.

Public API::

    from repro.core import (
        LCConfig, LCState, lc_init, c_step, penalty_grad, penalty_value,
        feasibility_gap, finalize, default_qspec, make_scheme,
    )
"""
from repro.core.lc import (          # noqa: F401
    LCConfig,
    LCState,
    LeafSpec,
    c_step,
    codebook_entry_count,
    default_qspec,
    feasibility_gap,
    finalize,
    lc_init,
    param_counts,
    penalty_grad,
    penalty_value,
    quant_leaf_paths,
)
from repro.core.schemes import (     # noqa: F401
    AdaptiveScheme,
    FixedScheme,
    ScaledFixedScheme,
    Scheme,
    make_scheme,
)
from repro.core import baselines, compression, kmeans, quant_ops  # noqa: F401
