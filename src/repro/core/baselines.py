"""Baselines the paper compares against (§2, §5).

* **DC** — direct compression (Gong et al. 2015): quantize a trained
  reference net once, loss-blind.  Equals the LC path at μ→0⁺ (§3.4).
* **iDC** — iterated DC (Han et al. 2015 "trained quantization"): alternate
  (train from the quantized point) / (re-quantize), *without* the penalty
  term or multipliers.  The paper shows it oscillates and does not converge
  to a feasible local optimum.
* **BinaryConnect** (Courbariaux et al. 2015): straight-through binarization
  — forward/gradients at sign(w) (optionally scaled), update applied to the
  real-valued weights, weights clipped to [-1, 1].

All three reuse the same scheme/C-step machinery as LC, so benchmark
comparisons differ only in the *algorithm*, exactly as in the paper.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lc as lc_mod
from repro.core.schemes import Scheme

Array = jax.Array
PyTree = Any


def direct_compression(
    key: Array, params: PyTree, scheme: Any, qspec: Optional[PyTree] = None,
) -> Tuple[PyTree, lc_mod.LCState]:
    """DC: Θ = Π(w̄), w_DC = Δ(Θ).  Returns (quantized params, state).

    ``scheme`` may be a bare Scheme (then ``qspec`` is required) or a
    CompressionPlan (then ``qspec`` defaults to the plan's policy).
    """
    if qspec is None:
        if not hasattr(scheme, "build_qspec"):
            raise TypeError("qspec required when passing a bare Scheme")
        qspec = scheme.build_qspec(params)
    cfg = getattr(scheme, "lc", None) or lc_mod.LCConfig()
    state = lc_mod.lc_init(key, params, scheme, qspec, cfg)
    return lc_mod.finalize(params, state, qspec), state


def idc_round(
    params: PyTree, state: lc_mod.LCState, scheme: Scheme, qspec: PyTree,
) -> Tuple[PyTree, lc_mod.LCState]:
    """One iDC compression round: re-quantize current weights (no λ, no μ).

    The caller alternates: ``params = train(start_from=quantized)`` then
    ``quantized, state = idc_round(params, ...)``.
    """
    cfg = lc_mod.LCConfig(use_lagrangian=False, mu0=0.0, mu_growth=1.0)
    # iDC quantizes w directly (no shift): reuse c_step with λ=0, μ=0.
    zero_lam = jax.tree_util.tree_map(jnp.zeros_like, state.lam)
    st = state._replace(lam=zero_lam, mu=jnp.asarray(0.0, jnp.float32))
    st = lc_mod.c_step(params, st, scheme, qspec, cfg)
    return lc_mod.finalize(params, st, qspec), st


# ---------------------------------------------------------------------------
# BinaryConnect
# ---------------------------------------------------------------------------

def binaryconnect_forward_params(
    params: PyTree, qspec: PyTree, scale: bool = False,
) -> PyTree:
    """Binarize quantized leaves for the forward pass (straight-through)."""
    def q(path, w):
        b = jnp.where(w >= 0, 1.0, -1.0).astype(w.dtype)
        if scale:
            b = b * jnp.mean(jnp.abs(w))
        return b

    return lc_mod._map_quant(q, qspec, params)


def binaryconnect_clip(params: PyTree, qspec: PyTree) -> PyTree:
    """Clip real-valued weights to [-1, 1] after the update (BC recipe)."""
    return lc_mod._map_quant(
        lambda path, w: jnp.clip(w, -1.0, 1.0), qspec, params)


def make_binaryconnect_grad(
    loss_fn: Callable[[PyTree, Any], Array], qspec: PyTree,
    scale: bool = False,
) -> Callable[[PyTree, Any], Tuple[Array, PyTree]]:
    """Gradient evaluated at binarized weights, applied to real weights.

    ``loss_fn(params, batch) -> scalar``.  Returns ``(loss, grads)`` — the
    straight-through estimator: g = ∂L/∂w |_{w=sign(w)}.
    """
    def val_grad(params: PyTree, batch: Any):
        bparams = binaryconnect_forward_params(params, qspec, scale=scale)
        return jax.value_and_grad(loss_fn)(bparams, batch)

    return val_grad
