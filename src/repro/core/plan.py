"""CompressionPlan — the declarative front door of the quantization
pipeline (Part I's framing: one constrained-optimization pipeline from
reference net to deployable compressed net).

A plan bundles the three policy decisions every caller used to wire by
hand:

* **scheme** — which Δ(Θ)/Π(w) pair (resolved through the
  ``repro.core.schemes`` registry);
* **qspec policy** — which leaves are quantized, and which get per-layer
  (grouped) codebooks (paper §5: multiplicative weights only);
* **lc** — the LC/augmented-Lagrangian hyperparameters (μ schedule etc.).

The same plan object drives every stage end to end::

    plan = CompressionPlan.parse("adaptive:16")
    qspec = plan.build_qspec(params)
    state = plan.init(key, params, qspec)           # DC point (Θ = Π(w̄))
    ...L steps (trainer)... state = plan.c_step(params, state, qspec)
    packed = plan.pack(params, state, qspec)        # → PackedModel artifact
    packed.save(path)                               # → serve (dispatch)

and the distributed C step (``repro.dist.cstep.sharded_c_step``) takes the
identical plan, so nothing downstream ever inspects scheme strings again.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax

from repro.core import lc as lc_mod
from repro.core.compression import PackedModel
from repro.core.lc import DEFAULT_EXCLUDE, LCConfig, LCState
from repro.core.schemes import Scheme, make_scheme

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class QSpecPolicy:
    """Which leaves quantize: path-regex exclusion + ndim thresholds."""

    exclude: str = DEFAULT_EXCLUDE.pattern
    min_ndim: int = 2
    grouped_min_ndim: int = 3

    def build(self, params: PyTree) -> PyTree:
        return lc_mod.default_qspec(
            params, exclude=re.compile(self.exclude, re.IGNORECASE),
            grouped_min_ndim=self.grouped_min_ndim, min_ndim=self.min_ndim)


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    scheme: Scheme
    qspec: QSpecPolicy = QSpecPolicy()
    lc: LCConfig = LCConfig()
    bits_ref: int = 32          # b of eq. 14 — quote it with every ratio
    # Run the C step shard-local via repro.dist.cstep.lc_c_step_sharded
    # (requires a mesh at the trainer: LCTrainer.from_plan(..., mesh=m)).
    sharded_c_step: bool = False

    # -- construction -------------------------------------------------------

    @classmethod
    def parse(cls, spec: str, *, lc: Optional[LCConfig] = None,
              qspec: Optional[QSpecPolicy] = None, bits_ref: int = 32,
              sharded_c_step: bool = False,
              **scheme_kw: Any) -> "CompressionPlan":
        """Build a plan from a scheme spec string (``adaptive:4`` …) —
        the CLI/config entry point; validation happens in the registry."""
        return cls(scheme=make_scheme(spec, **scheme_kw),
                   lc=lc or LCConfig(), qspec=qspec or QSpecPolicy(),
                   bits_ref=bits_ref, sharded_c_step=sharded_c_step)

    # -- pipeline stages ----------------------------------------------------

    def build_qspec(self, params: PyTree) -> PyTree:
        return self.qspec.build(params)

    def init(self, key: Array, params: PyTree,
             qspec: Optional[PyTree] = None) -> LCState:
        """LC init at the direct-compression point."""
        qspec = self.build_qspec(params) if qspec is None else qspec
        return lc_mod.lc_init(key, params, self.scheme, qspec, self.lc)

    def c_step(self, params: PyTree, state: LCState, qspec: PyTree,
               advance_mu: bool = True) -> LCState:
        return lc_mod.c_step(params, state, self.scheme, qspec, self.lc,
                             advance_mu=advance_mu)

    def finalize(self, params: PyTree, state: LCState,
                 qspec: PyTree) -> PyTree:
        return lc_mod.finalize(params, state, qspec)

    def pack(self, params: PyTree, state: LCState,
             qspec: Optional[PyTree] = None) -> PackedModel:
        """Finished LC run → deployable PackedModel artifact."""
        return PackedModel.pack(params, state, self,
                                qspec=qspec, bits_ref=self.bits_ref)

    # -- accounting ---------------------------------------------------------

    def summary(self, params: PyTree, state: LCState,
                qspec: Optional[PyTree] = None) -> Dict[str, Any]:
        """Eq.-14 accounting without materializing the packed artifact."""
        from repro.core import compression as C

        qspec = self.build_qspec(params) if qspec is None else qspec
        p1, p0 = lc_mod.param_counts(params, qspec)
        entries = lc_mod.codebook_entry_count(state, self.scheme)
        k = self.scheme.index_entries
        return {
            "scheme": self.scheme.spec,
            "k": k,
            "bits_per_weight": self.scheme.bits_per_weight,
            "p1": p1, "p0": p0, "codebook_entries": entries,
            "ratio": C.compression_ratio(p1, p0, k, entries, b=self.bits_ref),
            "packed_bytes": C.quantized_bytes(p1, p0, k, entries,
                                              b=self.bits_ref),
        }
