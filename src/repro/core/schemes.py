"""Quantization scheme registry — pluggable C-step solvers.

A *scheme* bundles the decompression form Δ(Θ) with its optimal C-step
solver Π(w) (paper §4).  Every scheme exposes the same tiny functional
interface so the LC driver, the baselines (DC/iDC), and the serving path
are scheme-agnostic:

    state = scheme.init(key, w)            # Θ-side state (codebook/scale)
    q, state = scheme.c_step(w, state, first=bool)   # solve eq. (8)
    scheme.bits_per_weight                  # storage accounting

``w`` here is one *quantization group* (a flat view of one layer's
multiplicative weights, or a [G, ...] stack — see ``grouped``).  Biases &
co. are excluded at the qspec level (paper §5: only multiplicative weights
are quantized).

Schemes register themselves under a spec name with :func:`register_scheme`;
``make_scheme("adaptive:4")`` resolves through that registry (structured
``name[:arg]`` parse + per-factory validation), so downstream packages can
add schemes without touching this module.  :class:`repro.core.plan
.CompressionPlan` is the preferred entry point and carries a Scheme built
here.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant_ops
from repro.core.kmeans import (
    kmeans_fit,
    kmeans_plus_plus_init,
    kmeans_quantize,
    quantile_init,
)

Array = jax.Array
SchemeState = Dict[str, Array]


@dataclasses.dataclass(frozen=True)
class Scheme:
    """Base class; concrete schemes override the four methods below."""

    name: str = "base"

    # -- storage accounting ------------------------------------------------
    @property
    def bits_per_weight(self) -> int:
        raise NotImplementedError

    @property
    def codebook_entries(self) -> int:
        """Float entries stored alongside the indices (K, or 1 for a scale)."""
        raise NotImplementedError

    @property
    def index_entries(self) -> int:
        """Size of the assignment index space (the K of pack_indices)."""
        raise NotImplementedError

    @property
    def spec(self) -> str:
        """Canonical ``make_scheme`` spec string (artifact manifests)."""
        return self.name

    # -- algorithm ----------------------------------------------------------
    def init(self, key: Array, w: Array) -> SchemeState:
        raise NotImplementedError

    def c_step(
        self, w: Array, state: SchemeState, first: bool = False
    ) -> Tuple[Array, SchemeState]:
        """Solve Π(w): return (quantized weights, new Θ state)."""
        raise NotImplementedError

    def assignments(self, w: Array, state: SchemeState) -> Array:
        """Codebook indices for packing/serving."""
        raise NotImplementedError

    def decode(self, assign: Array, state: SchemeState) -> Array:
        """Δ(Θ): indices → quantized weights."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class AdaptiveScheme(Scheme):
    """Adaptive codebook of size K — C step is exact 1-D k-means (§4.1)."""

    k: int = 4
    iters_first: int = 50
    iters_warm: int = 5
    init_method: str = "kmeans++"   # or "quantile" (deterministic/distributed)
    name: str = "adaptive"

    @property
    def bits_per_weight(self) -> int:
        return max(1, math.ceil(math.log2(self.k)))

    @property
    def codebook_entries(self) -> int:
        return self.k

    @property
    def index_entries(self) -> int:
        return self.k

    @property
    def spec(self) -> str:
        return f"{self.name}:{self.k}"

    def init(self, key: Array, w: Array) -> SchemeState:
        if self.init_method == "kmeans++":
            cb = kmeans_plus_plus_init(key, w, self.k)
        else:
            cb = quantile_init(w, self.k)
        # "kmeans_iters" present from init so the state pytree structure is
        # stable across init/c_step (required for jitted LC loops).
        return {"codebook": cb, "kmeans_iters": jnp.asarray(0, jnp.int32)}

    def c_step(self, w, state, first=False):
        iters = self.iters_first if first else self.iters_warm
        res = kmeans_fit(w, state["codebook"], iters=iters)
        q = res.codebook[res.assignments]
        return q.astype(w.dtype), {"codebook": res.codebook,
                                   "kmeans_iters": res.iters_run}

    def assignments(self, w, state):
        return quant_ops.fixed_codebook_assign(w, state["codebook"])

    def decode(self, assign, state):
        return state["codebook"][assign]


@dataclasses.dataclass(frozen=True)
class AdaptiveZeroScheme(AdaptiveScheme):
    """Adaptive codebook with one centroid PINNED at 0 — quantization +
    pruning jointly (the paper's §4.2 footnote 2: "we can also achieve
    pruning together with quantization by having one centroid be fixed to
    zero").

    C step: k-means over the K-1 free centroids with the zero entry
    participating in assignments (weights nearest 0 are pruned); the
    centroid update simply skips index of the zero entry (we re-pin it
    after each iteration — equivalent to a constrained centroid step).
    """

    name: str = "adaptive_zero"

    def init(self, key: Array, w: Array) -> SchemeState:
        st = super().init(key, w)
        cb = st["codebook"]
        zi = jnp.argmin(jnp.abs(cb))
        st["codebook"] = jnp.sort(cb.at[zi].set(0.0))
        return st

    def c_step(self, w, state, first=False):
        iters = self.iters_first if first else self.iters_warm
        cb = state["codebook"]

        def body(c, _):
            assign = quant_ops.fixed_codebook_assign(w.ravel(), c)
            sums = jax.ops.segment_sum(w.ravel(), assign, num_segments=self.k)
            counts = jax.ops.segment_sum(jnp.ones(w.size), assign,
                                         num_segments=self.k)
            c_new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), c)
            zi = jnp.argmin(jnp.abs(c_new))
            return jnp.sort(c_new.at[zi].set(0.0)), None

        cb, _ = jax.lax.scan(body, cb, None, length=iters)
        assign = quant_ops.fixed_codebook_assign(w.ravel(), cb)
        q = cb[assign].reshape(w.shape)
        return q.astype(w.dtype), {"codebook": cb,
                                   "kmeans_iters": jnp.asarray(iters, jnp.int32)}

    def sparsity(self, w: Array, state: SchemeState) -> Array:
        """Fraction of weights pruned (assigned to the zero centroid)."""
        q = state["codebook"][self.assignments(w, state)]
        return jnp.mean((q == 0.0).astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class FixedScheme(Scheme):
    """Parameter-free fixed codebook: binary / ternary / pow2(C) (§4.2)."""

    kind: str = "binary"          # binary | ternary | pow2
    pow2_c: int = 4
    name: str = "fixed"

    def _codebook(self, dtype) -> Array:
        if self.kind == "binary":
            return jnp.asarray([-1.0, 1.0], dtype)
        if self.kind == "ternary":
            return jnp.asarray([-1.0, 0.0, 1.0], dtype)
        if self.kind == "pow2":
            mags = [0.0] + [2.0 ** (-c) for c in range(self.pow2_c + 1)]
            vals = sorted({s * m for m in mags for s in (-1.0, 1.0)})
            return jnp.asarray(vals, dtype)
        raise ValueError(self.kind)

    @property
    def _k(self) -> int:
        return {"binary": 2, "ternary": 3}.get(self.kind, 2 * (self.pow2_c + 1) + 1)

    @property
    def bits_per_weight(self) -> int:
        return max(1, math.ceil(math.log2(self._k)))

    @property
    def codebook_entries(self) -> int:
        return 0  # fixed values: nothing stored

    @property
    def index_entries(self) -> int:
        return self._k

    @property
    def spec(self) -> str:
        return f"pow2:{self.pow2_c}" if self.kind == "pow2" else self.kind

    def init(self, key, w):
        return {"codebook": self._codebook(jnp.float32)}

    def c_step(self, w, state, first=False):
        if self.kind == "binary":
            return quant_ops.binarize(w), state
        if self.kind == "ternary":
            return quant_ops.ternarize(w), state
        return quant_ops.pow2_quantize(w, self.pow2_c), state

    def assignments(self, w, state):
        return quant_ops.fixed_codebook_assign(w, state["codebook"].astype(w.dtype))

    def decode(self, assign, state):
        return state["codebook"][assign]


@dataclasses.dataclass(frozen=True)
class ScaledFixedScheme(Scheme):
    """Fixed codebook with a learned global scale a (§4.2.1, Thms A.2/A.3)."""

    kind: str = "binary_scale"    # binary_scale | ternary_scale
    name: str = "scaled_fixed"

    @property
    def _k(self) -> int:
        return 2 if self.kind == "binary_scale" else 3

    @property
    def bits_per_weight(self) -> int:
        return 1 if self.kind == "binary_scale" else 2

    @property
    def codebook_entries(self) -> int:
        return 1  # the scale

    @property
    def index_entries(self) -> int:
        return self._k

    @property
    def spec(self) -> str:
        return self.kind

    def init(self, key, w):
        return {"scale": jnp.mean(jnp.abs(w))}

    def c_step(self, w, state, first=False):
        if self.kind == "binary_scale":
            q, a = quant_ops.binarize_scale(w)
        else:
            q, a = quant_ops.ternarize_scale(w)
        return q, {"scale": a}

    def assignments(self, w, state):
        a = state["scale"]
        base = jnp.asarray([-1.0, 1.0] if self.kind == "binary_scale"
                           else [-1.0, 0.0, 1.0], w.dtype)
        return quant_ops.fixed_codebook_assign(w, a * base)

    def decode(self, assign, state):
        a = state["scale"]
        base = jnp.asarray([-1.0, 1.0] if self.kind == "binary_scale"
                           else [-1.0, 0.0, 1.0], jnp.float32)
        return a * base[assign]


def as_scheme(obj: Any) -> Scheme:
    """Normalize a plan-or-scheme argument: anything carrying a Scheme
    under ``.scheme`` (a CompressionPlan) unwraps; a Scheme passes
    through.  Every plan-aware entry point calls this once at its
    boundary."""
    scheme = getattr(obj, "scheme", obj)
    if not isinstance(scheme, Scheme):
        raise TypeError(f"expected a Scheme or CompressionPlan, got {obj!r}")
    return scheme


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SchemeFactory = Callable[..., Scheme]
_REGISTRY: Dict[str, SchemeFactory] = {}


def register_scheme(name: str, *aliases: str):
    """Decorator registering ``factory(arg: Optional[str], **kw) -> Scheme``
    under ``name`` (+ aliases).  ``arg`` is the text after the first ``:``
    in a spec like ``adaptive:4`` (None when absent); the factory owns its
    validation and raises ``ValueError`` on a malformed arg."""
    def deco(factory: SchemeFactory) -> SchemeFactory:
        for n in (name,) + aliases:
            if n in _REGISTRY:
                raise ValueError(f"scheme {n!r} registered twice")
            _REGISTRY[n] = factory
        return factory
    return deco


def registered_schemes() -> List[str]:
    return sorted(_REGISTRY)


def parse_spec(spec: str) -> Tuple[str, Optional[str]]:
    """``"adaptive:4"`` → ``("adaptive", "4")``; ``"binary"`` → ``("binary",
    None)``.  Validates the name against the registry."""
    name, _, arg = spec.partition(":")
    name = name.strip()
    if name not in _REGISTRY:
        raise ValueError(f"unknown scheme spec {spec!r}; registered: "
                         f"{registered_schemes()}")
    return name, (arg.strip() or None) if arg else None


def _int_arg(name: str, arg: Optional[str], default: int, lo: int) -> int:
    if arg is None:
        return default
    try:
        val = int(arg)
    except ValueError as e:
        raise ValueError(f"scheme {name!r}: arg {arg!r} is not an int") from e
    if val < lo:
        raise ValueError(f"scheme {name!r}: arg must be ≥ {lo}, got {val}")
    return val


@register_scheme("adaptive")
def _make_adaptive(arg: Optional[str] = None, **kw: Any) -> Scheme:
    k = _int_arg("adaptive", arg, kw.pop("k", 4), lo=2)
    return AdaptiveScheme(k=k, **kw)


@register_scheme("adaptive_zero")
def _make_adaptive_zero(arg: Optional[str] = None, **kw: Any) -> Scheme:
    k = _int_arg("adaptive_zero", arg, kw.pop("k", 4), lo=2)
    return AdaptiveZeroScheme(k=k, **kw)


@register_scheme("pow2")
def _make_pow2(arg: Optional[str] = None, **kw: Any) -> Scheme:
    c = _int_arg("pow2", arg, kw.pop("pow2_c", 4), lo=0)
    return FixedScheme(kind="pow2", pow2_c=c, **kw)


def _register_parameter_free(kind: str, cls) -> None:
    @register_scheme(kind)
    def factory(arg: Optional[str] = None, **kw: Any) -> Scheme:
        if arg is not None:
            raise ValueError(f"scheme {kind!r} takes no arg, got {arg!r}")
        kw.setdefault("kind", kind)
        return cls(**kw)


for _kind in ("binary", "ternary"):
    _register_parameter_free(_kind, FixedScheme)
for _kind in ("binary_scale", "ternary_scale"):
    _register_parameter_free(_kind, ScaledFixedScheme)


def make_scheme(spec: str, **kw: Any) -> Scheme:
    """Resolve a spec string (``adaptive:4``, ``binary``, ``ternary_scale``,
    ``pow2:4``) through the registry — the CLI / config / shim entry point.
    Prefer ``CompressionPlan.parse`` in new code."""
    name, arg = parse_spec(spec)
    return _REGISTRY[name](arg, **kw)
