"""The LC (learning-compression) algorithm driver (paper §3).

Augmented-Lagrangian alternation over a parameter pytree:

    L step:  w   ← argmin_w  L(w) + μ/2 ||w - w_C - λ/μ||²      (SGD)
    C step:  Θ   ← Π(w - λ/μ)   per quantization group           (exact)
             w_C ← Δ(Θ)
    λ ← λ - μ (w - w_C)
    μ ← μ₀ aʲ

This module owns the *algorithm state* and the pytree plumbing; the L step
itself lives in :mod:`repro.train.trainer` (it is ordinary training with
:func:`penalty_grad` added to the loss gradient — that separation is the
paper's central point: the data-dependent part never sees the codebooks).

Representation
--------------
* ``w_c`` / ``lam`` are full pytrees congruent with ``params``: on leaves
  that are *not* quantized they hold the raw weight / zeros and are masked
  out of every computation (keeps tree_map structure trivial and makes the
  whole state jit/pjit-shardable with the same sharding rules as params).
* ``theta`` is a flat ``{leaf-path: scheme-state}`` dict — scheme states
  (codebooks/scales) have different shapes per leaf, so they do not live
  inside the param tree.
* ``grouped`` leaves carry a leading stacked-layer axis G and get
  **per-layer codebooks** via ``vmap`` (paper §5.3: one codebook/layer).

Biases, norms, router logits, recurrence gates are excluded by the default
policy (paper §5: only multiplicative weights are quantized).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.schemes import Scheme, as_scheme

Array = jax.Array
PyTree = Any

# Param-name patterns never quantized (dynamics/precision-sensitive, tiny).
# ``d_skip`` is the Mamba-2 per-head skip gain — listed as non-quantized in
# models/ssm.py (dynamics-sensitive, tiny) but previously missed by this
# pattern; stacked it is a 2-D [G, H] leaf, not a multiplicative matrix.
DEFAULT_EXCLUDE = re.compile(
    r"(bias|scale|norm|router|gate_logit|a_log|a_param|dt_|conv1d|embed_pos"
    r"|d_skip)",
    re.IGNORECASE,
)


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    quantize: bool
    grouped: bool = False   # leading axis = per-layer codebook groups


def _is_spec(x) -> bool:
    return isinstance(x, LeafSpec)


@dataclasses.dataclass(frozen=True)
class LCConfig:
    mu0: float = 1e-3
    mu_growth: float = 1.1          # μ_j = μ0 · growth^j (paper §3.3)
    num_lc_iters: int = 30
    inner_alternations: int = 1     # (L,C) alternations per μ (see c_step)
    tol: float = 1e-6               # stop when RMS(w - w_C) < tol
    use_lagrangian: bool = True     # False → quadratic-penalty method (λ≡0)


class LCState(NamedTuple):
    w_c: PyTree        # Δ(Θ); raw weights on unquantized leaves (masked)
    lam: PyTree        # Lagrange multipliers; zeros on unquantized leaves
    theta: Dict[str, Any]   # leaf-path → scheme state (codebook/scale)
    mu: Array          # current penalty weight
    lc_iter: Array     # outer iteration j


# ---------------------------------------------------------------------------
# QuantSpec construction
# ---------------------------------------------------------------------------

def default_qspec(
    params: PyTree,
    exclude: re.Pattern = DEFAULT_EXCLUDE,
    grouped_min_ndim: int = 3,
    min_ndim: int = 2,
) -> PyTree:
    """Quantize every leaf with ndim ≥ ``min_ndim`` whose path avoids
    ``exclude``.

    Leaves with ndim ≥ ``grouped_min_ndim`` are assumed to be stacked-layer
    tensors ([G, ...]) and get per-layer codebooks.
    """
    def make(path, leaf):
        name = jax.tree_util.keystr(path)
        if leaf.ndim < min_ndim or exclude.search(name):
            return LeafSpec(quantize=False)
        return LeafSpec(quantize=True, grouped=leaf.ndim >= grouped_min_ndim)

    return jax.tree_util.tree_map_with_path(make, params)


def quant_leaf_paths(qspec: PyTree) -> List[str]:
    """Stable ordered list of quantized-leaf path strings (theta keys)."""
    out: List[str] = []

    def visit(path, spec):
        if spec.quantize:
            out.append(jax.tree_util.keystr(path))
        return spec

    jax.tree_util.tree_map_with_path(visit, qspec, is_leaf=_is_spec)
    return out


def _map_quant(fn: Callable, qspec: PyTree, params: PyTree, *rest: PyTree,
               default: Callable = lambda path, w, *r: w) -> PyTree:
    """tree_map over paths; ``fn(path, spec, w, *rest)`` on quantized leaves,
    ``default`` elsewhere.  All trees congruent with ``params``."""
    def go(path, spec, w, *r):
        if spec.quantize:
            return fn(jax.tree_util.keystr(path), w, *r)
        return default(jax.tree_util.keystr(path), w, *r)

    return jax.tree_util.tree_map_with_path(go, qspec, params, *rest,
                                            is_leaf=_is_spec)


def _grouped_lookup(qspec: PyTree) -> Dict[str, bool]:
    table: Dict[str, bool] = {}

    def visit(path, spec):
        table[jax.tree_util.keystr(path)] = spec.grouped
        return spec

    jax.tree_util.tree_map_with_path(visit, qspec, is_leaf=_is_spec)
    return table


# ---------------------------------------------------------------------------
# Algorithm steps
# ---------------------------------------------------------------------------

def lc_init(
    key: Array, params: PyTree, scheme: Scheme, qspec: PyTree,
    config: LCConfig,
) -> LCState:
    """Initialize at the direct-compression point (μ→0⁺, λ=0): Θ = Π(w̄).

    ``scheme`` may be a bare Scheme or anything carrying one under
    ``.scheme`` (a CompressionPlan) — the LC driver is plan-agnostic.
    """
    scheme = as_scheme(scheme)
    grouped = _grouped_lookup(qspec)
    paths = quant_leaf_paths(qspec)
    keys = dict(zip(paths, jax.random.split(jax.random.fold_in(key, 0),
                                            max(1, len(paths)))))
    theta: Dict[str, Any] = {}

    def init_leaf(path, w):
        k = keys[path]
        if grouped[path]:
            th = jax.vmap(scheme.init)(jax.random.split(k, w.shape[0]), w)
            q, th = jax.vmap(lambda wi, ti: scheme.c_step(wi, ti, first=True))(w, th)
        else:
            th = scheme.init(k, w)
            q, th = scheme.c_step(w, th, first=True)
        theta[path] = th
        return q.astype(w.dtype)

    w_c = _map_quant(init_leaf, qspec, params)
    lam = jax.tree_util.tree_map(jnp.zeros_like, params)
    return LCState(w_c=w_c, lam=lam, theta=theta,
                   mu=jnp.asarray(config.mu0, jnp.float32),
                   lc_iter=jnp.asarray(0, jnp.int32))


def c_step(
    params: PyTree, state: LCState, scheme: Scheme, qspec: PyTree,
    config: LCConfig, advance_mu: bool = True,
) -> LCState:
    """One C step + multiplier + μ update (paper figs. 2/3/4 loop body).

    ``advance_mu=False`` holds μ constant — used for inner (L,C)
    alternations per μ value.  Theorem 5.1 of Part I requires optimizing the
    penalty function "accurately enough for each μ"; a single alternation
    per μ (the paper's pseudocode) under an aggressive μ schedule freezes
    the path early.  Our toy KKT study (tests/test_lc_algorithm.py)
    shows 2–3 inner alternations recover the loss-optimal codebook where
    one alternation lands measurably off-stationary.
    """
    scheme = as_scheme(scheme)
    mu = state.mu
    grouped = _grouped_lookup(qspec)
    new_theta: Dict[str, Any] = {}

    def do_c(path, w, lam):
        ws = w - lam / jnp.maximum(mu, 1e-30)     # w - λ/μ (λ=0 ⇒ just w)
        th = state.theta[path]
        if grouped[path]:
            q, th = jax.vmap(lambda wi, ti: scheme.c_step(wi, ti, first=False))(ws, th)
        else:
            q, th = scheme.c_step(ws, th, first=False)
        new_theta[path] = th
        return q.astype(w.dtype)

    w_c = _map_quant(do_c, qspec, params, state.lam)

    if config.use_lagrangian:
        lam = _map_quant(
            lambda path, lam, w, q: lam - mu * (w - q),
            qspec, state.lam, params, w_c,
            default=lambda path, lam, w, q: lam)
    else:
        lam = state.lam

    return LCState(
        w_c=w_c, lam=lam, theta=new_theta,
        mu=mu * config.mu_growth if advance_mu else mu,
        lc_iter=state.lc_iter + 1,
    )


def penalty_grad(params: PyTree, state: LCState, qspec: PyTree) -> PyTree:
    """∇_w of μ/2||w - w_C - λ/μ||² = μ(w - w_C) - λ.

    Elementwise on each shard — adds **zero** communication to the L step.
    Returns a pytree congruent with ``params``, zeros on unquantized leaves.
    """
    return _map_quant(
        lambda path, w, q, lam: state.mu * (w - q) - lam,
        qspec, params, state.w_c, state.lam,
        default=lambda path, w, q, lam: jnp.zeros_like(w))


def penalty_value(params: PyTree, state: LCState, qspec: PyTree) -> Array:
    """μ/2 ||w - w_C - λ/μ||² (for logging the true L-step objective)."""
    mu = jnp.maximum(state.mu, 1e-30)
    sq = _map_quant(
        lambda path, w, q, lam: jnp.vdot(w - q - lam / mu, w - q - lam / mu),
        qspec, params, state.w_c, state.lam,
        default=lambda path, w, q, lam: jnp.zeros((), w.dtype))
    return 0.5 * state.mu * sum(jax.tree_util.tree_leaves(sq))


def feasibility_gap(params: PyTree, state: LCState, qspec: PyTree) -> Array:
    """RMS of (w - w_C) over quantized elements — the stopping criterion."""
    sq = _map_quant(
        lambda path, w, q: jnp.vdot(w - q, w - q),
        qspec, params, state.w_c,
        default=lambda path, w, q: jnp.zeros((), jnp.float32))
    p1, _ = param_counts(params, qspec)
    total = sum(jax.tree_util.tree_leaves(sq))
    return jnp.sqrt(total / max(p1, 1))


def finalize(params: PyTree, state: LCState, qspec: PyTree) -> PyTree:
    """Return the feasible (quantized) model: quantized leaves ← Δ(Θ)."""
    return _map_quant(lambda path, w, q: q, qspec, params, state.w_c,
                      default=lambda path, w, q: w)


def param_counts(params: PyTree, qspec: PyTree) -> Tuple[int, int]:
    """(P1, P0): quantized vs non-quantized element counts (for eq. 14)."""
    p1 = p0 = 0
    flat_spec = jax.tree_util.tree_leaves(qspec, is_leaf=_is_spec)
    flat_w = jax.tree_util.tree_leaves(params)
    for spec, w in zip(flat_spec, flat_w):
        if spec.quantize:
            p1 += w.size
        else:
            p0 += w.size
    return p1, p0


def codebook_entry_count(state: LCState, scheme: Scheme) -> int:
    """Total stored float entries across per-group codebooks (for eq. 14)."""
    scheme = as_scheme(scheme)
    n = 0
    for th in state.theta.values():
        first = next(iter(th.values()))
        groups = first.shape[0] if first.ndim > 0 and scheme.codebook_entries else 1
        # grouped states are vmapped: leading dim = G; scalar states → 1.
        if first.ndim == 0:
            groups = 1
        elif scheme.codebook_entries <= 1:
            groups = first.shape[0] if first.ndim >= 1 else 1
        else:   # adaptive: codebook is [K] or [G, K]
            cb = th["codebook"]
            groups = cb.shape[0] if cb.ndim == 2 else 1
        n += groups * scheme.codebook_entries
    return n
