"""Optimal scalar quantization operators (paper §4.2, Theorems A.1-A.3).

These solve the C step ``min_Θ ||w - Δ(Θ)||²`` in closed form for fixed
codebooks, with or without a learned global scale.  Every operator is pure
jnp, jit/vmap/grad-safe (piecewise-constant ⇒ zero gradient, which is what
the LC algorithm wants: the C step is *not* differentiated through).

Conventions
-----------
* ``sgn(0) = +1`` (paper eq. 12).
* Ties at Voronoi boundaries round toward the *larger* codebook index
  (paper eq. 11: ``(c_{k-1}+c_k)/2 <= t < (c_k+c_{k+1})/2``).
* All operators take/return arrays of any shape; scale-solving operators
  reduce over *all* elements (callers flatten per quantization group, or
  vmap over a leading group axis).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def sgn(t: Array) -> Array:
    """Sign with sgn(0) = +1 (paper eq. 12)."""
    return jnp.where(t >= 0, 1.0, -1.0).astype(t.dtype)


# ---------------------------------------------------------------------------
# Fixed codebook, no scale (paper eq. 11 particular cases)
# ---------------------------------------------------------------------------

def binarize(t: Array) -> Array:
    """q(t) for codebook {-1,+1}: q = sgn(t)."""
    return sgn(t)


def ternarize(t: Array) -> Array:
    """q(t) for codebook {-1,0,+1}: q = sgn(t)·1[|t| ≥ 1/2]."""
    return sgn(t) * (jnp.abs(t) >= 0.5).astype(t.dtype)


def pow2_quantize(t: Array, C: int) -> Array:
    """q(t) for codebook {0, ±1, ±2^-1, ..., ±2^-C} (Theorem A.1).

    α(t) = 0              if f > C+1
           1              if f ≤ 0
           2^-C           if f ∈ (C, C+1]
           2^-⌊f+log2(3/2)⌋ otherwise,      f = -log2|t|.
    """
    if C < 0:
        raise ValueError(f"pow2 codebook needs C >= 0, got {C}")
    at = jnp.abs(t)
    # Guard log2(0): where at==0 we force the f > C+1 branch.
    safe = jnp.where(at > 0, at, 1.0)
    f = -jnp.log2(safe)
    f = jnp.where(at > 0, f, jnp.inf)
    mid_exp = jnp.floor(f + jnp.log2(1.5))
    alpha = jnp.where(
        f > C + 1,
        0.0,
        jnp.where(
            f <= 0,
            1.0,
            jnp.where(f > C, 2.0 ** (-float(C)), 2.0 ** (-mid_exp)),
        ),
    )
    return (alpha * sgn(t)).astype(t.dtype)


def fixed_codebook_quantize(t: Array, codebook: Array) -> Array:
    """q(t) for an arbitrary fixed scalar codebook (paper eq. 11).

    ``codebook`` is a 1-D array; it need not be sorted (we sort internally).
    Returns the quantized values (same shape as ``t``).
    """
    c = jnp.sort(codebook)
    return c[fixed_codebook_assign(t, c)]


def fixed_codebook_assign(t: Array, sorted_codebook: Array) -> Array:
    """Voronoi assignment indices into an ascending-sorted codebook.

    Ties at midpoints go to the larger index (paper eq. 11).
    """
    mids = 0.5 * (sorted_codebook[1:] + sorted_codebook[:-1])
    # side='right': t == midpoint → larger index, matching eq. (11).
    return jnp.searchsorted(mids, t, side="right").astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fixed codebook with learned global scale (Theorems A.2, A.3)
# ---------------------------------------------------------------------------

def binarize_scale(w: Array) -> Tuple[Array, Array]:
    """Codebook {-a,+a}, optimal a (Theorem A.2).

    Returns (q, a) with a* = mean(|w|), q = a·sgn(w).
    """
    a = jnp.mean(jnp.abs(w))
    return a * sgn(w), a


def ternarize_scale(w: Array) -> Tuple[Array, Array]:
    """Codebook {-a,0,+a}, exact optimal a (Theorem A.3).

    j* = argmax_j (1/√j) Σ_{i≤j} |w|_(i)  over |w| sorted descending,
    a* = (1/j*) Σ_{i≤j*} |w|_(i),   q_i = sgn(w_i)·a·1[|w_i| ≥ a/2].

    Exact (sort-based) — O(P log P).  For the distributed variant see
    :mod:`repro.dist.cstep` (histogram-CDF reformulation).
    """
    flat = jnp.abs(w).ravel()
    s = jnp.sort(flat)[::-1]                       # descending magnitudes
    csum = jnp.cumsum(s)
    j = jnp.arange(1, flat.size + 1, dtype=csum.dtype)
    obj = csum / jnp.sqrt(j)
    jstar = jnp.argmax(obj)
    a = csum[jstar] / (jstar + 1).astype(csum.dtype)
    q = sgn(w) * a * (jnp.abs(w) >= 0.5 * a).astype(w.dtype)
    return q.astype(w.dtype), a


def fixed_scale_fit(
    w: Array,
    codebook: Array,
    iters: int = 20,
) -> Tuple[Array, Array, Array]:
    """General fixed codebook with adaptive scale (paper eq. 13).

    Alternates assignment and the closed-form scale step
    ``a = Σ z_ik w_i c_k / Σ z_ik c_k²`` until ``iters`` iterations.
    Returns (q, a, assignments).  Used for codebooks without a closed form
    (e.g. scaled powers-of-two); binarize/ternarize have exact solutions
    above and are preferred.
    """
    flat = w.ravel()
    c = jnp.sort(codebook.astype(flat.dtype))
    csq = c * c

    def body(a, _):
        assign = fixed_codebook_assign(flat, a * c)
        ck = c[assign]
        num = jnp.sum(flat * ck)
        den = jnp.sum(csq[assign])
        a_new = jnp.where(den > 0, num / den, a)
        return a_new, None

    a0 = jnp.maximum(jnp.mean(jnp.abs(flat)), jnp.finfo(flat.dtype).tiny)
    a, _ = jax.lax.scan(body, a0, None, length=iters)
    assign = fixed_codebook_assign(flat, a * c)
    q = (a * c[assign]).reshape(w.shape)
    return q, a, assign.reshape(w.shape)


# ---------------------------------------------------------------------------
# Distortion helper (used by tests / benchmarks)
# ---------------------------------------------------------------------------

def distortion(w: Array, q: Array) -> Array:
    """Squared error ||w - q||² — the C-step objective."""
    d = (w - q).ravel()
    return jnp.dot(d, d)


# Named registry of parameter-free operators (bench/test sweeps).
FIXED_OPS = {
    "binary": binarize,
    "ternary": ternarize,
    "pow2_c4": functools.partial(pow2_quantize, C=4),
    "pow2_c7": functools.partial(pow2_quantize, C=7),
}
