"""Shared fault-tolerance primitives for training AND serving.

The supervisor control flow is the same on both sides of the system: a
step loop that may be interrupted by node failures (restore the last
durable state and resume, with bounded restarts and exponential
backoff) or by a preemption notice (save-and-exit).  On a real cluster
the signals are coordinator heartbeats / SIGTERM; in this container the
identical control flow is exercised with injected failures.

* :class:`SimulatedNodeFailure` — an unrecoverable step failure; the
  supervisor restores the last checkpoint/snapshot and replays;
* :class:`PreemptionSignal` — a scheduled eviction notice; the
  supervisor saves durable state first, then exits (or, in-process,
  restores and continues — the serving chaos harness does this to
  exercise the full save→restore round trip);
* :class:`FailureInjector` — raises the above at configured steps, each
  at most once (a restored run replaying past the step must not re-die);
* :func:`backoff_delay` — the shared bounded-exponential restart delay.

``repro.train.fault`` builds ``supervised_run`` (training: checkpoint/
restart over a TrainState) and ``repro.engine.snapshot`` builds
``supervised_serve`` (serving: engine snapshot/restore with typed
request outcomes) on these primitives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Set


class SimulatedNodeFailure(RuntimeError):
    """An injected (or real) node failure: state since the last durable
    checkpoint/snapshot is lost; the supervisor restores and replays."""


class PreemptionSignal(Exception):
    """A scheduled eviction notice (SIGTERM-style): save durable state,
    then exit — the replacement process resumes from it."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure injection at configured step indices.

    Each failure step fires at most once: a supervisor that restores to
    an earlier step and replays through the same index must not hit the
    same injected failure again (the real-world analogue: the node that
    died was replaced).
    """

    fail_at_steps: Set[int] = dataclasses.field(default_factory=set)
    preempt_at: Optional[int] = None
    _fired: Set[int] = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedNodeFailure(f"injected failure at step {step}")
        if self.preempt_at is not None and step == self.preempt_at:
            self.preempt_at = None
            raise PreemptionSignal(f"preempted at step {step}")


def backoff_delay(restarts: int, base_s: float, cap_s: float = 60.0) -> float:
    """Bounded exponential backoff: ``base · 2^(restarts-1)``, capped.
    ``restarts`` is 1 on the first restart; 0 seconds when ``base_s`` is
    0 (the test configuration)."""
    if base_s <= 0 or restarts < 1:
        return 0.0
    return min(base_s * 2 ** (restarts - 1), cap_s)
