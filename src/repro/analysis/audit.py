"""The audit CLI: ``python -m repro.analysis.audit --packed <artifact>``.

Runs every static check in this package against a ``PackedModel``
artifact directory and emits machine-readable ``AUDIT.json`` plus a
human table (``launch.report.audit_table``):

1. **dense-inflation** — trace ``forward`` / ``prefill`` /
   ``decode_step_slots`` / the engine's fused decode+sample step / the
   engine's blockwise prefill chunk (``prefill_chunk_slots``) with the
   *pallas* kernel backend pinned (tracing is abstract eval — no Mosaic,
   runs on CPU) and walk the jaxpr for codebook gathers that rebuild a
   packed leaf's dense weight;
2. **hbm-bytes / hbm-padding / hbm-dead-operand / dense-weight-input** —
   compile the same entries (ref backend: parameter identity is
   backend-independent, and CI has no TPU) and assert each packed leaf's
   only HBM input is its uint32 word operand at ``bits_per_index(K)/8``
   bytes/weight;
3. **kv-operand-missing / kv-dead-operand / kv-dense-input** — compile
   the quantized-KV engine's fused decode (``kv_bits=4``) and assert the
   KV pages reach it as live uint32 word pools with no dense-width float
   KV parameter riding along (eq. 14 extended to activation bytes);
4. **recompile** — drive a fresh engine through admission / chunked
   prefill / completion / page-pressure preemption after a warmup run
   and assert zero jit-cache growth;
5. **vmem-blocks** — lint every block config reachable from the
   autotune surface (VMEM footprint, lane divisibility) without Mosaic —
   the packed-matmul tables *and* every committed
   ``_PAGED_BLOCK_TABLE`` / ``_PREFILL_BLOCK_TABLE`` token tile.

Violations matching ``allowlist.json`` (packaged default, or
``--allowlist``) are reported but don't fail the gate; anything else
exits 1.  ``scripts/verify.sh`` and CI run this over the committed
golden fixtures.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Any, Dict, List, Optional

import numpy as np

_DEFAULT_ALLOWLIST = os.path.join(os.path.dirname(__file__),
                                  "allowlist.json")


def _glob(pattern: str, value: str) -> bool:
    """Glob where ONLY ``*`` is special (leaf paths are full of ``[``/
    ``]``, which fnmatch would read as character classes)."""
    rx = ".*".join(re.escape(part) for part in pattern.split("*"))
    return re.fullmatch(rx, value) is not None


def load_allowlist(path: Optional[str] = None) -> List[Dict[str, str]]:
    """Entries ``{"check", "subject", "reason"}``; ``subject`` is a
    ``*``-glob over the violation's subject (a leaf path or block
    source).  Every entry must carry a non-empty reason — the allowlist
    documents exceptions, it doesn't hide them."""
    with open(path or _DEFAULT_ALLOWLIST) as fh:
        data = json.load(fh)
    entries = data["entries"] if isinstance(data, dict) else data
    for e in entries:
        if not e.get("reason"):
            raise ValueError(f"allowlist entry {e} has no reason — "
                             f"document the exception or remove it")
        if not re.search(r"\b(PR|ISSUE)[ -]?\d+\b", e["reason"]):
            raise ValueError(
                f"allowlist entry for {e.get('subject')!r}: the reason "
                f"must name the PR/issue that blessed the exception "
                f"(e.g. 'PR 6'), got: {e['reason']!r}")
    return entries


def split_allowed(violations: List[Dict[str, str]],
                  allowlist: List[Dict[str, str]]):
    """(active, allowed) — a violation is allowed iff an entry matches
    both its check name and its subject glob."""
    active, allowed = [], []
    for v in violations:
        match = next(
            (e for e in allowlist
             if _glob(e["check"], v.get("check", ""))
             and _glob(e["subject"], v.get("subject", ""))),
            None)
        if match is not None:
            allowed.append({**v, "allowed_reason": match["reason"]})
        else:
            active.append(v)
    return active, allowed


def _serve_entries(sp, cfg):
    """name → (fn, args) for every real serve entry point.  ``cfg`` is
    closed over (it is a static argument everywhere)."""
    import jax.numpy as jnp

    from repro.engine.engine import Engine, _decode_and_sample
    from repro.models import transformer as T

    toks = jnp.zeros((1, 8), jnp.int32)
    entries = {
        "forward": (lambda p, t: T.forward(p, cfg, t), (sp, toks)),
        "prefill": (lambda p, t: T.prefill(p, cfg, t,
                                           last_logits_only=True),
                    (sp, toks)),
    }
    eng = Engine(sp, cfg, n_slots=2, page_size=8, max_seq=32)
    caches = eng.caches
    table = jnp.asarray(eng.pool.table)
    b = eng.n_slots
    dec = (caches, table, jnp.zeros((b, 1), jnp.int32),
           jnp.zeros((b,), jnp.int32), jnp.zeros((b,), bool))
    entries["decode_step_slots"] = (
        lambda p, c, pt, t, pos, al: T.decode_step_slots(
            p, cfg, c, pt, t, pos, al),
        (sp,) + dec)
    sample = (jnp.zeros((b,), jnp.float32), jnp.zeros((b,), jnp.int32),
              jnp.zeros((b, 2), jnp.uint32), jnp.zeros((b,), bool))
    entries["engine_decode_sample"] = (
        lambda p, c, pt, t, pos, al, tm, tk, ky, po: _decode_and_sample(
            p, cfg, c, pt, t, pos, al, tm, tk, ky, po),
        (sp,) + dec + sample)
    # the engine's blockwise-prefill device call: one chunk of new
    # prompt tokens forwarded into one slot's pages + carry rows
    entries["engine_prefill_chunk"] = (
        lambda p, c, pt, t, sl, st0: T.prefill_chunk_slots(
            p, cfg, c, pt, t, sl, st0),
        (sp, caches, table, toks, jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32)))
    return entries


def _kvq_entry(sp, cfg, kv_bits: int = 4):
    """(name, (fn, args), kv_cfg) for the quantized-KV engine's fused
    decode+sample entry — the graph the KV-page operand check compiles.
    ``kv_cfg`` is the engine's config with ``kv_bits`` applied."""
    import jax.numpy as jnp

    from repro.engine.engine import Engine, _decode_and_sample

    eng = Engine(sp, cfg, n_slots=2, page_size=8, max_seq=32,
                 kv_bits=kv_bits)
    kcfg = eng.cfg
    b = eng.n_slots
    args = (sp, eng.caches, jnp.asarray(eng.pool.table),
            jnp.zeros((b, 1), jnp.int32), jnp.zeros((b,), jnp.int32),
            jnp.zeros((b,), bool), jnp.zeros((b,), jnp.float32),
            jnp.zeros((b,), jnp.int32), jnp.zeros((b, 2), jnp.uint32),
            jnp.zeros((b,), bool))
    fn = (lambda p, c, pt, t, pos, al, tm, tk, ky, po: _decode_and_sample(
        p, kcfg, c, pt, t, pos, al, tm, tk, ky, po))
    return f"engine_decode_sample_kvq{kv_bits}", (fn, args), kcfg


def run_audit(packed_dir: str, config: Optional[str] = None,
              allowlist_path: Optional[str] = None,
              skip: Optional[List[str]] = None) -> Dict[str, Any]:
    """All checks over one artifact; returns the AUDIT.json payload."""
    from repro.analysis import graph as G
    from repro.analysis import hbm as H
    from repro.analysis import recompile as R
    from repro.analysis import vmem as V
    from repro.analysis.zoo import infer_config
    from repro.core.compression import PackedModel

    skip = skip or []
    pm = PackedModel.load(packed_dir)
    cfg_name, cfg = infer_config(pm, config)
    sp = pm.serving_params(packed=True)
    prot = G.protected_leaves(sp)

    report: Dict[str, Any] = {
        "artifact": os.path.abspath(packed_dir),
        "config": cfg_name,
        "protected_leaves": sorted(prot),
        "checks": {},
        "violations": [],
    }
    violations: List[Dict[str, str]] = []

    if "graph" not in skip:
        per_entry: Dict[str, List[str]] = {}
        with G.trace_backend("pallas"):
            for name, (fn, args) in _serve_entries(sp, cfg).items():
                hits = G.find_dense_inflations(fn, args, prot)
                per_entry[name] = [h.describe() for h in hits]
                for h in hits:
                    violations.append({
                        "check": "dense-inflation", "subject": h.leaf,
                        "detail": f"{name}: {h.describe()}"})
        report["checks"]["graph"] = per_entry

    if "hbm" not in skip:
        hbm_entries: Dict[str, Any] = {}
        with G.trace_backend("ref"):
            for name, (fn, args) in _serve_entries(sp, cfg).items():
                res = H.audit_entry_hbm(fn, args, prot, entry=name)
                hbm_entries[name] = {
                    "rows": res["rows"],
                    "packed_input_bytes": res["packed_input_bytes"],
                    "float_input_bytes": res["float_input_bytes"],
                }
                violations.extend(res["violations"])
            # KV pages at kv_bits width: the quantized-KV engine's decode
            # must keep every packed *weight* leaf AND read the KV pools
            # as live uint32 words with no dense-width float KV input.
            name, (fn, args), kcfg = _kvq_entry(sp, cfg)
            res = H.audit_entry_hbm(fn, args, prot, entry=name)
            kv = H.audit_kv_page_operands(fn, args, kcfg, entry=name)
            hbm_entries[name] = {
                "rows": res["rows"],
                "packed_input_bytes": res["packed_input_bytes"],
                "float_input_bytes": res["float_input_bytes"],
                "kv_rows": kv["rows"],
                "kv_word_input_bytes": kv["kv_word_input_bytes"],
            }
            violations.extend(res["violations"])
            violations.extend(kv["violations"])
        report["checks"]["hbm"] = hbm_entries

    if "recompile" not in skip:
        try:
            report["checks"]["recompile"] = R.audit_engine_recompiles(
                sp, cfg)
        except R.RecompileViolation as e:
            violations.append({"check": "recompile",
                               "subject": "engine-step-loop",
                               "detail": str(e)})
            report["checks"]["recompile"] = {"error": str(e)}

    if "vmem" not in skip:
        res = V.audit_block_space(prot)
        pres = V.audit_paged_block_space()
        fres = V.audit_prefill_block_space()
        all_rows = res["rows"] + pres["rows"] + fres["rows"]
        report["checks"]["vmem"] = {
            "configs_checked": len(all_rows),
            "paged_configs_checked": len(pres["rows"]),
            "prefill_configs_checked": len(fres["rows"]),
            "warnings": [w for r in all_rows for w in r["warnings"]],
        }
        violations.extend(res["violations"])
        violations.extend(pres["violations"])
        violations.extend(fres["violations"])

    active, allowed = split_allowed(violations,
                                    load_allowlist(allowlist_path))
    report["violations"] = active
    report["allowed_violations"] = allowed
    report["ok"] = not active
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="Static serving-graph audit over a PackedModel "
                    "artifact (compile-time eq.-14 proof).")
    ap.add_argument("--packed", required=True,
                    help="PackedModel artifact directory")
    ap.add_argument("--config", default=None,
                    help="model-zoo config name (default: inferred from "
                         "the artifact's leaf paths)")
    ap.add_argument("--out", default=None,
                    help="write AUDIT.json here (default: stdout only)")
    ap.add_argument("--allowlist", default=None,
                    help="allowlist JSON (default: packaged "
                         "allowlist.json)")
    ap.add_argument("--skip", action="append", default=[],
                    choices=["graph", "hbm", "recompile", "vmem"],
                    help="skip a check (repeatable; for debugging)")
    args = ap.parse_args(argv)

    from repro.core.compression import ArtifactError
    try:
        report = run_audit(args.packed, config=args.config,
                           allowlist_path=args.allowlist, skip=args.skip)
    except ArtifactError as e:
        # a corrupt artifact is an audit *failure*, not a crash
        print(f"artifact rejected: {e}", file=sys.stderr)
        return 1
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2, default=_json_default)
        print(f"wrote {args.out}")

    from repro.launch.report import audit_table
    print(audit_table(report))
    return 0 if report["ok"] else 1


def _json_default(obj):
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, tuple):
        return list(obj)
    raise TypeError(f"not JSON serializable: {type(obj)}")


if __name__ == "__main__":
    sys.exit(main())
