"""Static serving-graph auditor — compile-time proofs of the eq.-14
serving invariants (ISSUE 6).

The serving story of PRs 2–5 is a *dynamic* story: bench byte assertions
and differential tests catch a regression only if a covered row happens
to execute it.  This package proves the same invariants statically,
without running the model, over the real serve entry points (``forward``
/ ``prefill`` / ``decode_step`` / ``decode_step_slots`` / the engine's
fused decode+sample step):

* :mod:`repro.analysis.graph`     — dense-inflation detection: walk the
  traced jaxpr for codebook gathers that materialize a packed leaf's
  full dense weight (the exact LM-head failure PR 4 fixed);
* :mod:`repro.analysis.hbm`       — per-parameter HBM byte audit over
  compiled HLO: every packed leaf's graph input must read exactly
  ``bits_per_index(K)/8`` B/weight (eq.-14 checked from what executes,
  not from bench timers);
* :mod:`repro.analysis.recompile` — trace-count auditor: admission /
  completion / preemption in the engine step loop must never create new
  jit cache entries;
* :mod:`repro.analysis.vmem`      — Pallas kernel static checks: VMEM
  footprint estimates and grid/lane-divisibility validation for every
  block config reachable from the autotune tables, so a bad entry fails
  lint on CPU instead of failing Mosaic compile on TPU;
* :mod:`repro.analysis.audit`     — the CLI driver
  (``python -m repro.analysis.audit --packed <artifact>``) emitting
  ``AUDIT.json`` + a human table, wired into ``scripts/verify.sh`` and
  CI as a hard gate over the committed golden fixtures.
"""
from repro.analysis.graph import (DenseInflation, find_dense_inflations,
                                  protected_leaves)
from repro.analysis.hbm import audit_entry_hbm
from repro.analysis.recompile import RecompileAuditor, RecompileViolation
from repro.analysis.vmem import (audit_block_space, estimate_vmem_bytes,
                                 validate_block_config)

__all__ = [
    "DenseInflation", "find_dense_inflations", "protected_leaves",
    "audit_entry_hbm",
    "RecompileAuditor", "RecompileViolation",
    "audit_block_space", "estimate_vmem_bytes", "validate_block_config",
]
