"""Pallas kernel static checks: VMEM footprint + grid/divisibility lint.

A bad (bm, bn, bk) entry in ``dispatch._PACKED_BLOCK_TABLE`` (or a bad
``REPRO_PACKED_BLOCKS`` override) fails at Mosaic *compile* time on a
TPU — which CI doesn't have.  This module re-derives, from the same
BlockSpecs the kernels declare, what Mosaic would be asked to fit:

* per-grid-step VMEM bytes — DMA'd blocks ×2 for the pipeline's double
  buffering, plus the unpack/dequant intermediates the kernel body
  creates — checked against a conservative budget (TPU VMEM is ~16 MB
  per core; see the Pallas guide's memory-space table);
* lane-divisibility: the word-packed axis's block must be a multiple of
  ``lanes = 32 // bits`` so uint32 words never straddle a block boundary
  (``bk`` for the forward kernel and the row-order transposed kernel,
  ``bn`` for the kd-order transposed kernel — exactly the ValueErrors
  the kernels raise, surfaced without tracing);
* tiling hygiene: ``bm % 8`` (f32 sublane), last-dim ``% 128`` (lane)
  misalignment — warnings, not errors, since Mosaic pads.

Everything here is integer arithmetic over static shapes: it runs on
CPU, no Mosaic, no TPU.  :func:`audit_block_space` sweeps every block
config reachable from the autotune surface — each ``_PACKED_BLOCK_TABLE``
entry plus ``packed_block_sizes``/``packed_block_sizes_t`` evaluated at
representative serve M values for every packed leaf of an artifact.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core import kvquant
from repro.core.compression import PackedLayout, bits_per_index
from repro.kernels import dispatch

VMEM_BYTES = 16 * 1024 * 1024          # per TPU core (Pallas guide)
# Leave headroom for Mosaic's own staging + the K-entry LUT replication.
VMEM_BUDGET = int(0.75 * VMEM_BYTES)

# Decode micro-batch / prefill M values the serve paths actually emit.
SERVE_M = (1, 8, 64, 256)

KINDS = ("packed_matmul", "packed_matmul_t", "gather")

PAGED_KINDS = ("gqa", "mla", "gather")

# Upper bound on the query-side floats resident per grid step of a paged
# decode kernel: q block + out block + the m/l/acc online-softmax
# scratch.  Covers ≤128 query heads × ≤512 per-head features (hd for
# gqa, kv_lora for absorbed MLA) — far beyond the committed configs, and
# still <2 MiB against the budget.
PAGED_Q_SIDE_FLOATS = 128 * 512


def estimate_vmem_bytes(kind: str, bm: int, bn: int, bk: int, bits: int,
                        k: int, *, order: str = "kd",
                        dequant: str = "lut") -> int:
    """Per-grid-step VMEM bytes a kernel asks Mosaic to resident-fit.

    Mirrors the BlockSpecs in ``kernels/codebook_matmul_packed{,_t}.py``
    and ``kernels/quantized_gather.py``: DMA'd input/output blocks count
    ×2 (pipeline double buffering); the in-kernel unpack index tile and
    dequantized weight tile count once (``dequant="onehot"`` adds the
    [*, K] one-hot instead of the LUT result).
    """
    lanes = 32 // bits
    f32, u32, i32 = 4, 4, 4
    if kind == "packed_matmul":
        # x[bm,bk] · unpack(pidx[bk//lanes, bn]) with cb[1,K] → out[bm,bn]
        dma = bm * bk * f32 + (bk // lanes) * bn * u32 + k * f32 \
            + bm * bn * f32
        tile = (bk, bn)
    elif kind == "packed_matmul_t":
        # x[bm,bk] · unpack(pidx).T; word block is [bn//lanes, bk] (kd:
        # V packed) or [bn, bk//lanes] (row: D packed) — same byte count.
        dma = bm * bk * f32 + (bn * bk // lanes) * u32 + k * f32 \
            + bm * bn * f32
        tile = (bn, bk)
    elif kind == "gather":
        # One packed word row [1, bk//lanes] → out row [1, bk]; bm/bn
        # unused (the grid is one step per token).
        dma = (bk // lanes) * u32 + k * f32 + bk * f32
        tile = (1, bk)
    else:
        raise ValueError(f"kind={kind!r}; choose from {KINDS}")
    body = tile[0] * tile[1] * i32                       # unpacked indices
    if dequant == "onehot":
        body += tile[0] * tile[1] * k * f32              # one-hot tensor
    else:
        body += tile[0] * tile[1] * f32                  # LUT result tile
    return 2 * dma + body


def validate_block_config(kind: str, bm: int, bn: int, bk: int, bits: int,
                          k: int, *, order: str = "kd",
                          dequant: str = "lut",
                          budget: int = VMEM_BUDGET) -> Dict[str, Any]:
    """Statically lint one block config; returns
    ``{"ok", "errors", "warnings", "vmem_bytes"}``.  ``errors`` are
    conditions the kernels reject (lane straddling) or Mosaic cannot fit
    (VMEM over budget); ``warnings`` are padding inefficiencies.
    """
    errors: List[str] = []
    warnings: List[str] = []
    lanes = 32 // bits
    if min(bm, bn, bk) < 1:
        errors.append(f"non-positive block ({bm},{bn},{bk})")
    if kind == "packed_matmul" and bk % lanes:
        errors.append(f"bk={bk} not a multiple of lanes={lanes} "
                      f"(bits={bits}): words straddle the k-block edge")
    if kind == "packed_matmul_t":
        if order == "kd" and bn % lanes:
            errors.append(f"bn={bn} not a multiple of lanes={lanes} "
                          f"(bits={bits}): V is the word-packed axis")
        if order == "row" and bk % lanes:
            errors.append(f"bk={bk} not a multiple of lanes={lanes} "
                          f"(bits={bits}): D is the word-packed axis")
    if kind == "gather" and bk % lanes:
        errors.append(f"word row of {bk} features not a multiple of "
                      f"lanes={lanes}")
    if kind != "gather":
        if bm % 8:
            warnings.append(f"bm={bm} not a multiple of the f32 sublane "
                            f"tile (8) — Mosaic pads the activation block")
        for name, v in (("bn", bn), ("bk", bk)):
            if v % 128:
                warnings.append(f"{name}={v} not 128-lane aligned — "
                                f"padded tiles waste VPU/MXU width")
    vmem = estimate_vmem_bytes(kind, bm, bn, bk, bits, k, order=order,
                               dequant=dequant)
    if vmem > budget:
        errors.append(f"~{vmem / 2**20:.1f} MiB/step exceeds the "
                      f"{budget / 2**20:.1f} MiB VMEM budget "
                      f"(core has {VMEM_BYTES / 2**20:.0f} MiB)")
    elif vmem > 0.8 * budget:
        warnings.append(f"~{vmem / 2**20:.1f} MiB/step is within 20% of "
                        f"the {budget / 2**20:.1f} MiB VMEM budget")
    return {"ok": not errors, "errors": errors, "warnings": warnings,
            "vmem_bytes": vmem}


def _leaf_block_configs(leaf: str, lay: PackedLayout
                        ) -> Iterable[Dict[str, Any]]:
    """Every (kind, blocks) the dispatch layer could pick for this leaf
    at the serve M values."""
    if lay.shape is not None:
        return                       # dequant-then-dot route — no kernel
    if lay.order == "row":
        # Embedding serving layout: fused gather (whole packed row per
        # token) + the row-order transposed LM-head route (tied models).
        yield {"kind": "gather", "blocks": (1, 1, lay.n),
               "m": 1, "order": "row"}
        for m in SERVE_M:
            bm, bn, bk = dispatch.packed_block_sizes_t(
                m, lay.n, lay.kd, lay.bits, "row")
            yield {"kind": "packed_matmul_t", "blocks": (bm, bn, bk),
                   "m": m, "order": "row"}
    else:
        for m in SERVE_M:
            bm, bn, bk = dispatch.packed_block_sizes(m, lay.kd, lay.n,
                                                     lay.bits)
            yield {"kind": "packed_matmul", "blocks": (bm, bn, bk),
                   "m": m, "order": "kd"}


def audit_block_space(protected: Dict[str, dict],
                      dequant: str = "lut") -> Dict[str, Any]:
    """Sweep every block config reachable from the autotune surface.

    ``protected`` is :func:`repro.analysis.graph.protected_leaves`
    output.  Covers (a) each autotune-table entry verbatim (both the
    forward and transposed interpretations it serves) and (b) the
    heuristic's picks for every packed leaf at the serve M values.
    Returns ``{"rows", "violations"}``; a violation is any config with
    ``errors`` — a table entry or heuristic output the kernels would
    reject or Mosaic could not fit.
    """
    jobs: List[Dict[str, Any]] = []
    for (m, kd, n, bits), blocks in dispatch.packed_block_table().items():
        jobs.append({"kind": "packed_matmul", "blocks": blocks, "m": m,
                     "order": "kd", "bits": bits, "k": 1 << bits,
                     "source": f"table[{m},{kd},{n},{bits}]"})
        lanes = 32 // bits
        bm, bn, bk = blocks
        bn_t = max(lanes, bn // lanes * lanes)   # packed_block_sizes_t
        jobs.append({"kind": "packed_matmul_t", "blocks": (bm, bn_t, bk),
                     "m": m, "order": "kd", "bits": bits, "k": 1 << bits,
                     "source": f"table[{m},{kd},{n},{bits}]:t"})
    for leaf, info in sorted(protected.items()):
        lay = info["layout"]
        for job in _leaf_block_configs(leaf, lay):
            job.update(bits=lay.bits, k=lay.k, source=leaf)
            jobs.append(job)

    rows: List[Dict[str, Any]] = []
    violations: List[Dict[str, str]] = []
    for job in jobs:
        bm, bn, bk = job["blocks"]
        res = validate_block_config(job["kind"], bm, bn, bk, job["bits"],
                                    job["k"], order=job["order"],
                                    dequant=dequant)
        rows.append({**job, **res})
        for err in res["errors"]:
            violations.append({
                "check": "vmem-blocks", "subject": job["source"],
                "detail": f"{job['kind']} blocks ({bm},{bn},{bk}) at "
                          f"M={job['m']}: {err}"})
    return {"rows": rows, "violations": violations}


def block_table_entries() -> Dict[Tuple[int, int, int, int],
                                  Tuple[int, int, int]]:
    """Re-export of the dispatch autotune table (audit CLI convenience)."""
    return dispatch.packed_block_table()


# ---------------------------------------------------------------------------
# Paged-attention / page-gather route (dispatch._PAGED_BLOCK_TABLE)
# ---------------------------------------------------------------------------

def estimate_paged_vmem_bytes(kind: str, feat: int, page_size: int,
                              token_tile: int, bits: int, *,
                              dequant: str = "lut") -> int:
    """Per-grid-step VMEM bytes a paged kernel asks Mosaic to fit.

    Mirrors the BlockSpecs in ``kernels/paged_attention.py``: per step
    one ``token_tile``-token KV tile per cached tensor is DMA'd (×2 for
    double buffering) — dense f32 rows, or packed uint32 words plus the
    per-page codebooks when ``bits`` — and the quant kernel bodies
    create the unpacked index tile + the dequantized f32 tile (``lut``)
    or the [*, K] one-hot (``onehot``).  The query side (q/out blocks +
    m/l/acc online-softmax scratch) is bounded by
    :data:`PAGED_Q_SIDE_FLOATS` rather than threaded per-config — it is
    token-tile independent and small against the budget.
    """
    f32 = u32 = i32 = 4
    bt = token_tile
    n_tensors = 1 if kind == "gather" else 2      # gather: one pool
    if bits:
        lanes = kvquant.kv_lanes(bits)
        k = kvquant.kv_entries(bits)
        # per-(token, head) rows pack independently; ceil over the whole
        # feature row is a faithful upper bound for the committed shapes
        words = -(-feat // lanes)
        kv_tile = bt * words * u32 + k * f32      # word tile + codebook
        body = n_tensors * (bt * feat * i32       # unpacked index tile
                            + bt * feat * f32)    # dequantized KV tile
        if dequant == "onehot":
            body += n_tensors * bt * feat * k * f32
    else:
        kv_tile = bt * feat * f32
        body = 0
    dma = n_tensors * kv_tile
    if kind == "gather":
        dma += page_size * feat * f32             # whole-page out block
        q_side = 0
    else:
        # logits + probs tiles ([heads, bt], heads ≤ 128) and the
        # query-side blocks/scratch upper bound
        body += 2 * 128 * bt * f32
        q_side = 7 * PAGED_Q_SIDE_FLOATS * f32    # q, out (×2 ea) + m/l/acc
    return 2 * dma + body + q_side


def validate_paged_block_config(kind: str, feat: int, page_size: int,
                                token_tile: int, bits: int, *,
                                dequant: str = "lut",
                                budget: int = VMEM_BUDGET
                                ) -> Dict[str, Any]:
    """Statically lint one paged-route token-tile config; same contract
    as :func:`validate_block_config` — ``errors`` are what the ops layer
    rejects (non-divisor tiles) or Mosaic cannot fit."""
    errors: List[str] = []
    warnings: List[str] = []
    if kind not in PAGED_KINDS:
        errors.append(f"kind={kind!r}; choose from {PAGED_KINDS}")
        return {"ok": False, "errors": errors, "warnings": warnings,
                "vmem_bytes": 0}
    if bits and bits not in kvquant.KV_BITS_CHOICES:
        errors.append(f"kv_bits={bits} not in {kvquant.KV_BITS_CHOICES}")
        return {"ok": False, "errors": errors, "warnings": warnings,
                "vmem_bytes": 0}
    if token_tile < 1:
        errors.append(f"non-positive token_tile {token_tile}")
    elif page_size % token_tile:
        errors.append(f"token_tile={token_tile} does not divide "
                      f"page_size={page_size} — the kernels' grid "
                      f"(pages × tiles/page) would drop tokens")
    if feat % 128:
        warnings.append(f"feat={feat} not 128-lane aligned — Mosaic pads "
                        f"the KV tile's trailing dim")
    vmem = estimate_paged_vmem_bytes(kind, feat, page_size,
                                     max(token_tile, 1), bits,
                                     dequant=dequant)
    if vmem > budget:
        errors.append(f"~{vmem / 2**20:.1f} MiB/step exceeds the "
                      f"{budget / 2**20:.1f} MiB VMEM budget "
                      f"(core has {VMEM_BYTES / 2**20:.0f} MiB)")
    elif vmem > 0.8 * budget:
        warnings.append(f"~{vmem / 2**20:.1f} MiB/step is within 20% of "
                        f"the {budget / 2**20:.1f} MiB VMEM budget")
    return {"ok": not errors, "errors": errors, "warnings": warnings,
            "vmem_bytes": vmem}


def audit_paged_block_space(dequant: str = "lut") -> Dict[str, Any]:
    """Sweep every committed ``dispatch._PAGED_BLOCK_TABLE`` entry — the
    paged-route analogue of :func:`audit_block_space`.  A bad token tile
    otherwise only fails at Mosaic compile time on a TPU."""
    rows: List[Dict[str, Any]] = []
    violations: List[Dict[str, str]] = []
    for (kind, feat, page, bits), tile in sorted(
            dispatch.paged_block_table().items()):
        source = f"paged_table[{kind},{feat},{page},{bits}]"
        res = validate_paged_block_config(kind, feat, page, tile, bits,
                                          dequant=dequant)
        rows.append({"kind": kind, "feat": feat, "page_size": page,
                     "bits": bits, "token_tile": tile, "source": source,
                     **res})
        for err in res["errors"]:
            violations.append({
                "check": "vmem-blocks", "subject": source,
                "detail": f"paged {kind} token_tile={tile}: {err}"})
    return {"rows": rows, "violations": violations}


# ---------------------------------------------------------------------------
# Blockwise-prefill route (dispatch._PREFILL_BLOCK_TABLE)
# ---------------------------------------------------------------------------

PREFILL_KINDS = ("dense", "quant")

# Bounds on the chunk-side operands of the blockwise-prefill kernel:
# every committed path partitions prompts into blocks of ≤ 64 new tokens
# (``transformer.DEFAULT_PREFILL_BLOCK`` and the engine's default
# ``prefill_chunk``), and the committed configs stay ≤ 32 query heads.
# The q/out blocks and the online-softmax scratch scale with these, not
# with the prompt length — that flatness is the kernel's whole point,
# and the estimate below proves it per table entry.
PREFILL_CHUNK_BOUND = 64
PREFILL_HEADS_BOUND = 32


def estimate_prefill_vmem_bytes(kind: str, feat: int, token_tile: int,
                                bits: int = 0, *,
                                dequant: str = "lut") -> int:
    """Per-grid-step VMEM bytes a blockwise-prefill kernel asks Mosaic
    to fit.

    Mirrors the BlockSpecs in ``kernels/blockwise_prefill.py``: per step
    one ``token_tile`` K tile and one V tile are DMA'd (×2 double
    buffering) — dense rows, or packed uint32 words plus a single page's
    codebooks when ``bits`` — while the q/out blocks and the m/l/acc
    flash scratch are chunk-sized and bounded by
    :data:`PREFILL_CHUNK_BOUND` × :data:`PREFILL_HEADS_BOUND` (worst
    case ``rep == 1``: every query head has its own KV head).  Prompt
    length never appears: the footprint is flat in S.
    """
    f32 = u32 = i32 = 4
    bt = token_tile
    c, h = PREFILL_CHUNK_BOUND, PREFILL_HEADS_BOUND
    kv = h
    if kind == "quant" and bits:
        lanes = kvquant.kv_lanes(bits)
        kent = kvquant.kv_entries(bits)
        words = -(-feat // lanes)
        kv_tile = bt * kv * words * u32 + kent * f32
        # unpack index tile + dequantized f32 tile, for K and for V
        body = 2 * bt * kv * feat * (i32 + f32)
        if dequant == "onehot":
            body += 2 * bt * kv * feat * kent * f32
    else:
        kv_tile = bt * kv * feat * f32
        body = 0
    dma = 2 * kv_tile                        # K tile + V tile
    q_out = 2 * 2 * c * h * feat * f32       # q and out blocks, ×2 buffered
    scratch = h * c * (2 + feat) * f32       # m/l/acc online-softmax carry
    return 2 * dma + body + q_out + scratch


def validate_prefill_block_config(kind: str, feat: int, token_tile: int,
                                  bits: int = 0, *, dequant: str = "lut",
                                  budget: int = VMEM_BUDGET
                                  ) -> Dict[str, Any]:
    """Statically lint one blockwise-prefill token-tile entry; same
    contract as :func:`validate_paged_block_config`.  The quant route
    clamps tiles to page-size divisors at dispatch time, so divisibility
    is not an error here — only footprint and basic hygiene are."""
    errors: List[str] = []
    warnings: List[str] = []
    if kind not in PREFILL_KINDS:
        errors.append(f"kind={kind!r}; choose from {PREFILL_KINDS}")
        return {"ok": False, "errors": errors, "warnings": warnings,
                "vmem_bytes": 0}
    if bits and bits not in kvquant.KV_BITS_CHOICES:
        errors.append(f"kv_bits={bits} not in {kvquant.KV_BITS_CHOICES}")
        return {"ok": False, "errors": errors, "warnings": warnings,
                "vmem_bytes": 0}
    if token_tile < 1:
        errors.append(f"non-positive token_tile {token_tile}")
    elif token_tile % 8:
        warnings.append(f"token_tile={token_tile} not a multiple of the "
                        f"f32 sublane tile (8) — Mosaic pads the KV tile")
    if feat % 128:
        warnings.append(f"feat={feat} not 128-lane aligned — Mosaic pads "
                        f"the KV tile's trailing dim")
    vmem = estimate_prefill_vmem_bytes(kind, feat, max(token_tile, 1),
                                       bits, dequant=dequant)
    if vmem > budget:
        errors.append(f"~{vmem / 2**20:.1f} MiB/step exceeds the "
                      f"{budget / 2**20:.1f} MiB VMEM budget "
                      f"(core has {VMEM_BYTES / 2**20:.0f} MiB)")
    elif vmem > 0.8 * budget:
        warnings.append(f"~{vmem / 2**20:.1f} MiB/step is within 20% of "
                        f"the {budget / 2**20:.1f} MiB VMEM budget")
    return {"ok": not errors, "errors": errors, "warnings": warnings,
            "vmem_bytes": vmem}


def audit_prefill_block_space(dequant: str = "lut") -> Dict[str, Any]:
    """Sweep every committed ``dispatch._PREFILL_BLOCK_TABLE`` entry —
    quant entries at every supported ``kv_bits`` (the table doesn't key
    on bits; the worst case must still fit)."""
    rows: List[Dict[str, Any]] = []
    violations: List[Dict[str, str]] = []
    for (kind, feat), tile in sorted(dispatch.prefill_block_table().items()):
        source = f"prefill_table[{kind},{feat}]"
        sweep = kvquant.KV_BITS_CHOICES if kind == "quant" else (0,)
        for bits in sweep:
            res = validate_prefill_block_config(kind, feat, tile, bits,
                                                dequant=dequant)
            rows.append({"kind": kind, "feat": feat, "bits": bits,
                         "token_tile": tile, "source": source, **res})
            for err in res["errors"]:
                violations.append({
                    "check": "vmem-blocks", "subject": source,
                    "detail": f"prefill {kind} token_tile={tile} "
                              f"(bits={bits}): {err}"})
    return {"rows": rows, "violations": violations}
