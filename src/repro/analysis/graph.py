"""Dense-inflation detection over traced serve graphs.

THE invariant (PR 2→4): a quantized leaf's HBM-resident form is the
bit-packed uint32 word operand; its full dense ``[Kd, N]`` (or ``[V, D]``)
float weight must never be materialized inside a decode/serve graph.  The
exact historical failure: the tied LM head used to dequant-then-dot,
inflating the whole ``[V, D]`` embedding matrix every decode step — PR 4
replaced it with the fused transposed kernel, but nothing *prevents* a
regression except a bench row happening to cover the path.

This module proves the invariant statically: trace a serve entry point to
its jaxpr (with the ``pallas`` kernel backend, so the fused routes appear
as opaque ``pallas_call`` eqns whose operands stay packed) and walk every
equation — including ``pjit`` / ``scan`` / ``while`` / ``cond`` bodies,
but *not* Pallas kernel bodies, whose in-VMEM tile dequant is the blessed
mechanism — for codebook-gather ops whose output is a registered leaf's
dense shape.  A hit means the graph rebuilt the dense weight (the
dequant-then-dot pattern); whether it feeds a ``dot_general`` is reported
alongside.

Known, documented exceptions (e.g. MoE expert stacks, which are einsum
operands dequantized in-jit — ``PackedLayout.shape`` is set) are handled
by the audit allowlist, not here: this module reports every
materialization it finds.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax

# Primitives that materialize a dequantized tensor from a (small)
# codebook: jnp indexing / jnp.take lower to gather.
_GATHER_PRIMS = {"gather", "take", "dynamic_gather"}

# Pass-through ops a materialized weight may flow through before the
# contraction (used only for the feeds-dot annotation).
_PASSTHROUGH = {"convert_element_type", "reshape", "transpose",
                "broadcast_in_dim", "squeeze", "slice", "copy",
                "stop_gradient", "mul", "add", "sub", "div"}


@dataclasses.dataclass(frozen=True)
class DenseInflation:
    """One dense-weight materialization found in a traced graph."""

    leaf: str              # serving-tree path of the packed leaf
    shape: Tuple[int, ...]  # the materialized dense shape
    primitive: str         # the materializing primitive (gather family)
    feeds_dot: bool        # flows into a dot_general in the same subjaxpr

    def describe(self) -> str:
        dot = "feeds dot_general" if self.feeds_dot else "dot feed unproven"
        return (f"{self.leaf}: dense {'×'.join(map(str, self.shape))} "
                f"materialized by `{self.primitive}` ({dot})")


def _walk_tree(tree: Any, path: str, out: Dict[str, dict]) -> None:
    if isinstance(tree, dict):
        for key, val in tree.items():
            if isinstance(key, str) and key.endswith("_layout") \
                    and f"{key[:-7]}_pidx" in tree:
                name = key[:-7]
                out[f"{path}['{name}']"] = {
                    "layout": val,
                    "pidx_shape": tuple(tree[f"{name}_pidx"].shape),
                }
            elif isinstance(val, (dict, tuple, list)):
                _walk_tree(val, f"{path}['{key}']", out)
    elif isinstance(tree, (tuple, list)):
        for i, val in enumerate(tree):
            _walk_tree(val, f"{path}[{i}]", out)


def protected_leaves(serving_params: Any) -> Dict[str, dict]:
    """Packed leaves of a ``serving_params(packed=True)`` tree and the
    dense shapes their decode would materialize.

    Returns leaf path → {"layout", "pidx_shape", "dense_shapes"} where
    ``dense_shapes`` covers the 2-D packed view ``(kd, n)``, the
    per-group original shape (``layout.shape``, e.g. MoE ``[E, D, F]``),
    and their grouped variants with the leading stacked-layer axis.
    """
    found: Dict[str, dict] = {}
    _walk_tree(serving_params, "", found)
    for info in found.values():
        lay = info["layout"]
        shapes = {(lay.kd, lay.n)}
        if lay.shape is not None:
            shapes.add(tuple(lay.shape))
        if len(info["pidx_shape"]) == 3:        # grouped (stacked layers)
            g = info["pidx_shape"][0]
            for s in list(shapes):
                shapes.add((g,) + s)
        info["dense_shapes"] = shapes
    return found


def _sub_jaxprs(eqn) -> List[Any]:
    """Inner jaxprs of an equation — pjit/scan/while/cond/custom calls.
    Pallas kernel bodies are deliberately excluded: their in-VMEM tile
    dequant is the blessed fused mechanism, not an inflation."""
    if eqn.primitive.name == "pallas_call":
        return []
    subs: List[Any] = []
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if hasattr(v, "jaxpr"):             # ClosedJaxpr
                subs.append(v.jaxpr)
            elif hasattr(v, "eqns"):            # raw Jaxpr
                subs.append(v)
    return subs


def _taint_of(taint: Dict[int, set], vars_) -> set:
    out: set = set()
    for v in vars_:
        if hasattr(v, "val"):                   # Literal
            continue
        out |= taint.get(id(v), set())
    return out


def _seed_taint(jaxpr, args: Sequence[Any],
                protected: Dict[str, dict]) -> Dict[int, set]:
    """Top-jaxpr invar → {leaf} for every protected leaf's ``_pidx`` /
    ``_cb`` argument array.  Taint flows through equations (and into
    scan/pjit bodies positionally), so a codebook gather deep inside the
    stack is attributed to the leaf whose arrays actually feed it —
    shape-only attribution collides (e.g. a flattened MoE expert stack
    dequants to the same [96, 48] as a dense MLP's ``w_out``)."""
    flat = jax.tree_util.tree_flatten_with_path(tuple(args))[0]
    paths = [jax.tree_util.keystr(p) for p, _ in flat]
    taint: Dict[int, set] = {}
    if len(paths) != len(jaxpr.invars):
        return taint                             # fall back to shape-only
    suffix_to_leaf = {}
    for leaf in protected:
        head, name = leaf.rsplit("['", 1)
        for kind in ("_pidx", "_cb"):
            suffix_to_leaf[f"{head}['{name[:-2]}{kind}']"] = leaf
    for i, path in enumerate(paths):
        for suffix, leaf in suffix_to_leaf.items():
            if path.endswith(suffix):
                taint.setdefault(id(jaxpr.invars[i]), set()).add(leaf)
    return taint


def _feeds_dot(jaxpr, start_var) -> bool:
    """True if ``start_var`` flows into a dot_general / pallas_call in
    this subjaxpr, possibly through pass-through elementwise ops."""
    frontier = {id(start_var)}
    seen = set()
    changed = True
    while changed:
        changed = False
        for eqn in jaxpr.eqns:
            if id(eqn) in seen:
                continue
            if any(id(v) in frontier for v in eqn.invars
                   if not hasattr(v, "val")):      # skip Literals
                if eqn.primitive.name in ("dot_general", "pallas_call"):
                    return True
                seen.add(id(eqn))
                if eqn.primitive.name in _PASSTHROUGH:
                    frontier.update(id(v) for v in eqn.outvars)
                    changed = True
    return False


def _scan_jaxpr(jaxpr, shape_index: Dict[Tuple[int, ...], List[str]],
                hits: List[DenseInflation],
                taint: Dict[int, set]) -> None:
    for eqn in jaxpr.eqns:
        in_taint = _taint_of(taint, eqn.invars)
        if eqn.primitive.name in _GATHER_PRIMS:
            for outvar in eqn.outvars:
                aval = outvar.aval
                shape = tuple(getattr(aval, "shape", ()))
                dtype = getattr(aval, "dtype", None)
                # Only float materializations count — an int array of the
                # leaf shape is the unpack intermediate (4 B/weight index
                # inflation is caught by the HBM parameter audit instead).
                if dtype is None or dtype.kind != "f":
                    continue
                candidates = shape_index.get(shape, ())
                if not candidates:
                    continue
                # Taint disambiguates same-shape leaves; an untainted hit
                # (fallback) charges every shape candidate.
                attributed = [l for l in candidates if l in in_taint] \
                    or list(candidates)
                for leaf in attributed:
                    hits.append(DenseInflation(
                        leaf=leaf, shape=shape,
                        primitive=eqn.primitive.name,
                        feeds_dot=_feeds_dot(jaxpr, outvar)))
        for sub in _sub_jaxprs(eqn):
            inner: Dict[int, set] = {}
            # scan/pjit sub-jaxpr invars align with eqn invars
            # (consts+carry+xs); on a length mismatch (e.g. while's
            # cond/body consts) align the shared tail (the carry).
            pairs = (zip(sub.invars, eqn.invars)
                     if len(sub.invars) == len(eqn.invars)
                     else zip(reversed(sub.invars), reversed(eqn.invars)))
            for iv, ov in pairs:
                t = _taint_of(taint, [ov])
                if t:
                    inner[id(iv)] = t
            _scan_jaxpr(sub, shape_index, hits, inner)
        if in_taint:
            for ov in eqn.outvars:
                taint[id(ov)] = taint.get(id(ov), set()) | in_taint


def find_dense_inflations(fn: Callable, args: Sequence[Any],
                          protected: Dict[str, dict]
                          ) -> List[DenseInflation]:
    """Trace ``fn(*args)`` and report every dense materialization of a
    protected leaf.  ``protected`` is :func:`protected_leaves` output."""
    shape_index: Dict[Tuple[int, ...], List[str]] = {}
    for leaf, info in protected.items():
        for shape in info["dense_shapes"]:
            shape_index.setdefault(tuple(shape), []).append(leaf)
    jaxpr = jax.make_jaxpr(fn)(*args)
    hits: List[DenseInflation] = []
    taint = _seed_taint(jaxpr.jaxpr, args, protected)
    _scan_jaxpr(jaxpr.jaxpr, shape_index, hits, taint)
    # de-dup (scan bodies repeat per stack; one report per leaf+shape+prim)
    uniq: Dict[Tuple, DenseInflation] = {}
    for h in hits:
        key = (h.leaf, h.shape, h.primitive)
        if key not in uniq or (h.feeds_dot and not uniq[key].feeds_dot):
            uniq[key] = h
    return sorted(uniq.values(), key=lambda h: (h.leaf, h.shape))


def trace_backend(backend: str = "pallas"):
    """Context manager pinning ``REPRO_KERNEL_BACKEND`` while tracing —
    the auditor traces the *production* kernel routes (fused Pallas
    calls) even on CPU; tracing never compiles Mosaic, so this is safe
    off-TPU."""
    import contextlib

    @contextlib.contextmanager
    def _ctx():
        prev = os.environ.get("REPRO_KERNEL_BACKEND")
        os.environ["REPRO_KERNEL_BACKEND"] = backend
        try:
            yield
        finally:
            if prev is None:
                os.environ.pop("REPRO_KERNEL_BACKEND", None)
            else:
                os.environ["REPRO_KERNEL_BACKEND"] = prev
    return _ctx()
