"""The tiny model zoo the committed golden fixtures were generated from.

These configs used to live in ``tests/helpers.py``; the static auditor
needs them importable from ``src`` (the audit CLI reconstructs the model
a ``PackedModel`` artifact serves in order to trace its graphs), so they
live here and the test helpers re-export them.  Changing a config here
invalidates the fixtures under ``tests/fixtures/`` — regenerate with
``scripts/make_golden_fixtures.py`` and say so in the commit message.
"""
from __future__ import annotations

from typing import Optional

from repro.core.compression import PackedModel
from repro.models.transformer import (LayerKind, ModelConfig, MoESpec,
                                      SSMSpec, StackSpec)


def tiny_cfg(tie: bool = True) -> ModelConfig:
    """Smallest stack that still exercises every packed route: GQA +
    dense MLP, tied embeddings (row-packed table → fused gather AND fused
    transposed LM head)."""
    return ModelConfig(
        name="tiny-diff", family="dense", d_model=32, n_heads=4, n_kv=2,
        head_dim=8, d_ff=64, vocab=96,
        stacks=(StackSpec(pattern=(LayerKind("gqa", "dense"),), groups=2),),
        tie_embeddings=tie, q_chunk=8, kv_chunk=8, remat=False)


def mixed_cfg(tie: bool) -> ModelConfig:
    """Tiny mixed stack: gqa+dense-MLP, ssm (no MLP), gqa+MoE — every
    mixer/MLP kind the full-model qleaf layout must cover on CPU."""
    return ModelConfig(
        name="mixed-qleaf", family="hybrid", d_model=48, n_heads=4, n_kv=2,
        head_dim=12, d_ff=96, vocab=160,
        stacks=(StackSpec(pattern=(LayerKind("gqa", "dense"),
                                   LayerKind("ssm", "none")), groups=2),
                StackSpec(pattern=(LayerKind("gqa", "moe"),), groups=1)),
        tie_embeddings=tie,
        moe=MoESpec(n_experts=4, top_k=2, n_shared=1, d_ff_expert=24,
                    capacity_factor=4.0),
        ssm=SSMSpec(d_inner=96, head_p=16, state_n=12, conv_w=4, chunk=8),
        q_chunk=8, kv_chunk=8, remat=False)


CONFIGS = {
    "tiny": lambda: tiny_cfg(tie=True),
    "tiny-untied": lambda: tiny_cfg(tie=False),
    "mixed": lambda: mixed_cfg(tie=False),
    "mixed-tied": lambda: mixed_cfg(tie=True),
}


def infer_config(pm: PackedModel, name: Optional[str] = None
                 ) -> tuple[str, ModelConfig]:
    """(config name, ModelConfig) for an artifact.

    ``name`` (a :data:`CONFIGS` key) overrides; otherwise the choice is
    read off the artifact's leaf paths — the mixed stack has SSM leaves
    at ``pos1``, an untied model stores ``head_w``.  This covers every
    committed fixture; artifacts from other configs must pass
    ``--config`` explicitly.
    """
    if name is not None:
        if name not in CONFIGS:
            raise ValueError(f"unknown config {name!r}; "
                             f"choose from {sorted(CONFIGS)}")
        return name, CONFIGS[name]()
    paths = list(pm.packed) + list(pm.dense)
    mixed = any("'pos1'" in p for p in paths)
    tied = not any("'head_w'" in p for p in paths)
    if mixed:
        key = "mixed-tied" if tied else "mixed"
    else:
        key = "tiny" if tied else "tiny-untied"
    return key, CONFIGS[key]()
