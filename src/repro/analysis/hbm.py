"""Per-parameter HBM byte audit over compiled HLO — eq. 14 checked
against what actually executes.

``launch/hlo_analysis.py`` historically only attributed collective bytes;
its :func:`~repro.launch.hlo_analysis.entry_parameters` extension (this
PR) parses the ENTRY computation's ``parameter(i)`` instructions out of a
compiled module.  This module maps those parameters back to serving-tree
leaves (jax flattens jit arguments in ``tree_flatten`` order, so entry
parameter *i* IS flat leaf *i*) and proves, per packed leaf:

* the leaf's **only** HBM-resident form is the uint32 word operand —
  exactly ``prod(word_shape) · 4`` bytes, i.e. ``bits_per_index(K)/8``
  bytes per weight (plus lane padding when the packed axis is not a
  multiple of ``lanes``; zero on the committed fixtures);
* the word operand is **live** (read by the computation) — a dead packed
  input means the graph got the weight some other way;
* **no float parameter** of the leaf's dense shape exists — the dense
  weight is never an HBM input (the regression ``serving_params`` could
  reintroduce by emitting both layouts).

The compile runs on the CPU (ref-backend) graph: parameter identity and
layout are backend-independent — the packed tree is the same HBM input
set the TPU graph consumes — and CI has no TPU.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import numpy as np

from repro.core.compression import bits_per_index
from repro.launch import hlo_analysis


def _leaf_paths(args: Sequence[Any]) -> List[str]:
    flat = jax.tree_util.tree_flatten_with_path(tuple(args))[0]
    return [jax.tree_util.keystr(path) for path, _ in flat]


def _pidx_suffix(leaf_path: str) -> str:
    """Protected-leaf path → the keystr suffix of its ``_pidx`` leaf.
    ``"['stacks'][0]['mixer']['wk']"`` → ``"['stacks'][0]['mixer']['wk_pidx']"``."""
    head, name = leaf_path.rsplit("['", 1)
    return f"{head}['{name[:-2]}_pidx']"


def audit_entry_hbm(fn, args: Sequence[Any], protected: Dict[str, dict],
                    *, entry: str = "entry") -> Dict[str, Any]:
    """Compile ``fn(*args)`` and audit its entry parameters.

    ``protected`` is :func:`repro.analysis.graph.protected_leaves` output
    for the serving tree inside ``args``.  Returns ``{"entry", "rows",
    "violations", "packed_input_bytes", "float_input_bytes"}`` where each
    row is one packed leaf's byte accounting and each violation is a
    ``{"check", "subject", "detail"}`` dict.
    """
    text = jax.jit(fn).lower(*args).compile().as_text()
    params = hlo_analysis.entry_parameters(text, on_unknown="raise")
    paths = _leaf_paths(args)
    if len(params) != len(paths):
        raise RuntimeError(
            f"{entry}: HLO entry has {len(params)} parameters but the "
            f"argument tree has {len(paths)} leaves — parameter "
            f"attribution would be wrong")
    by_index = {p["index"]: p for p in params}

    dense_shapes: Dict[tuple, str] = {}
    for leaf, info in protected.items():
        for shape in info["dense_shapes"]:
            dense_shapes[tuple(shape)] = leaf

    rows: List[Dict[str, Any]] = []
    violations: List[Dict[str, str]] = []
    packed_bytes = 0.0
    for leaf, info in sorted(protected.items()):
        suffix = _pidx_suffix(leaf)
        idxs = [i for i, p in enumerate(paths) if p.endswith(suffix)]
        if len(idxs) != 1:
            violations.append({
                "check": "hbm-bytes", "subject": leaf,
                "detail": f"{entry}: expected exactly one {suffix} "
                          f"argument leaf, found {len(idxs)}"})
            continue
        prm = by_index[idxs[0]]
        lay = info["layout"]
        groups = (info["pidx_shape"][0]
                  if len(info["pidx_shape"]) == 3 else 1)
        weights = groups * lay.kd * lay.n
        expected = 4 * groups * int(np.prod(lay.word_shape))
        bpw = prm["bytes"] / weights
        exact = bits_per_index(lay.k) / 8
        row = {"path": leaf, "entry": entry, "param_index": prm["index"],
               "hlo_dtype": prm["dtype"], "hlo_shape": prm["shape"],
               "hbm_bytes": prm["bytes"], "weights": weights,
               "bytes_per_weight": bpw, "expected_bytes_per_weight": exact,
               "uses": prm["uses"], "k": lay.k, "bits": lay.bits}
        rows.append(row)
        packed_bytes += prm["bytes"]
        if prm["dtype"] != "u32" or prm["bytes"] != expected:
            violations.append({
                "check": "hbm-bytes", "subject": leaf,
                "detail": f"{entry}: packed operand is "
                          f"{prm['dtype']}{list(prm['shape'])} = "
                          f"{prm['bytes']:.0f} B; layout implies u32 "
                          f"words = {expected} B"})
        elif bpw != exact:
            violations.append({
                "check": "hbm-padding", "subject": leaf,
                "detail": f"{entry}: {bpw:.4f} B/weight from lane "
                          f"padding (eq.-14 exact is {exact:.4f}); pad "
                          f"the leaf or allowlist it"})
        if prm["uses"] == 0:
            violations.append({
                "check": "hbm-dead-operand", "subject": leaf,
                "detail": f"{entry}: packed word operand is an unused "
                          f"entry parameter — the graph is not reading "
                          f"the packed layout"})

    float_bytes = 0.0
    for i, prm in enumerate(params):
        if not prm["dtype"].startswith(("f", "bf")):
            continue
        float_bytes += prm["bytes"]
        hit = dense_shapes.get(tuple(prm["shape"]))
        if hit is not None:
            violations.append({
                "check": "dense-weight-input", "subject": hit,
                "detail": f"{entry}: float parameter {prm['index']} "
                          f"{prm['dtype']}{list(prm['shape'])} matches "
                          f"this packed leaf's dense shape — the dense "
                          f"weight is HBM-resident ({paths[i]})"})
    return {"entry": entry, "rows": rows, "violations": violations,
            "packed_input_bytes": packed_bytes,
            "float_input_bytes": float_bytes}
