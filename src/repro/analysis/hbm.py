"""Per-parameter HBM byte audit over compiled HLO — eq. 14 checked
against what actually executes.

``launch/hlo_analysis.py`` historically only attributed collective bytes;
its :func:`~repro.launch.hlo_analysis.entry_parameters` extension (this
PR) parses the ENTRY computation's ``parameter(i)`` instructions out of a
compiled module.  This module maps those parameters back to serving-tree
leaves (jax flattens jit arguments in ``tree_flatten`` order, so entry
parameter *i* IS flat leaf *i*) and proves, per packed leaf:

* the leaf's **only** HBM-resident form is the uint32 word operand —
  exactly ``prod(word_shape) · 4`` bytes, i.e. ``bits_per_index(K)/8``
  bytes per weight (plus lane padding when the packed axis is not a
  multiple of ``lanes``; zero on the committed fixtures);
* the word operand is **live** (read by the computation) — a dead packed
  input means the graph got the weight some other way;
* **no float parameter** of the leaf's dense shape exists — the dense
  weight is never an HBM input (the regression ``serving_params`` could
  reintroduce by emitting both layouts).

The compile runs on the CPU (ref-backend) graph: parameter identity and
layout are backend-independent — the packed tree is the same HBM input
set the TPU graph consumes — and CI has no TPU.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import numpy as np

from repro.core import kvquant
from repro.core.compression import bits_per_index
from repro.launch import hlo_analysis


def _leaf_paths(args: Sequence[Any]) -> List[str]:
    flat = jax.tree_util.tree_flatten_with_path(tuple(args))[0]
    return [jax.tree_util.keystr(path) for path, _ in flat]


def _pidx_suffix(leaf_path: str) -> str:
    """Protected-leaf path → the keystr suffix of its ``_pidx`` leaf.
    ``"['stacks'][0]['mixer']['wk']"`` → ``"['stacks'][0]['mixer']['wk_pidx']"``."""
    head, name = leaf_path.rsplit("['", 1)
    return f"{head}['{name[:-2]}_pidx']"


def audit_entry_hbm(fn, args: Sequence[Any], protected: Dict[str, dict],
                    *, entry: str = "entry") -> Dict[str, Any]:
    """Compile ``fn(*args)`` and audit its entry parameters.

    ``protected`` is :func:`repro.analysis.graph.protected_leaves` output
    for the serving tree inside ``args``.  Returns ``{"entry", "rows",
    "violations", "packed_input_bytes", "float_input_bytes"}`` where each
    row is one packed leaf's byte accounting and each violation is a
    ``{"check", "subject", "detail"}`` dict.
    """
    text = jax.jit(fn).lower(*args).compile().as_text()
    params = hlo_analysis.entry_parameters(text, on_unknown="raise")
    paths = _leaf_paths(args)
    if len(params) != len(paths):
        raise RuntimeError(
            f"{entry}: HLO entry has {len(params)} parameters but the "
            f"argument tree has {len(paths)} leaves — parameter "
            f"attribution would be wrong")
    by_index = {p["index"]: p for p in params}

    dense_shapes: Dict[tuple, str] = {}
    for leaf, info in protected.items():
        for shape in info["dense_shapes"]:
            dense_shapes[tuple(shape)] = leaf

    rows: List[Dict[str, Any]] = []
    violations: List[Dict[str, str]] = []
    packed_bytes = 0.0
    for leaf, info in sorted(protected.items()):
        suffix = _pidx_suffix(leaf)
        idxs = [i for i, p in enumerate(paths) if p.endswith(suffix)]
        if len(idxs) != 1:
            violations.append({
                "check": "hbm-bytes", "subject": leaf,
                "detail": f"{entry}: expected exactly one {suffix} "
                          f"argument leaf, found {len(idxs)}"})
            continue
        prm = by_index[idxs[0]]
        lay = info["layout"]
        groups = (info["pidx_shape"][0]
                  if len(info["pidx_shape"]) == 3 else 1)
        weights = groups * lay.kd * lay.n
        expected = 4 * groups * int(np.prod(lay.word_shape))
        bpw = prm["bytes"] / weights
        exact = bits_per_index(lay.k) / 8
        row = {"path": leaf, "entry": entry, "param_index": prm["index"],
               "hlo_dtype": prm["dtype"], "hlo_shape": prm["shape"],
               "hbm_bytes": prm["bytes"], "weights": weights,
               "bytes_per_weight": bpw, "expected_bytes_per_weight": exact,
               "uses": prm["uses"], "k": lay.k, "bits": lay.bits}
        rows.append(row)
        packed_bytes += prm["bytes"]
        if prm["dtype"] != "u32" or prm["bytes"] != expected:
            violations.append({
                "check": "hbm-bytes", "subject": leaf,
                "detail": f"{entry}: packed operand is "
                          f"{prm['dtype']}{list(prm['shape'])} = "
                          f"{prm['bytes']:.0f} B; layout implies u32 "
                          f"words = {expected} B"})
        elif bpw != exact:
            violations.append({
                "check": "hbm-padding", "subject": leaf,
                "detail": f"{entry}: {bpw:.4f} B/weight from lane "
                          f"padding (eq.-14 exact is {exact:.4f}); pad "
                          f"the leaf or allowlist it"})
        if prm["uses"] == 0:
            violations.append({
                "check": "hbm-dead-operand", "subject": leaf,
                "detail": f"{entry}: packed word operand is an unused "
                          f"entry parameter — the graph is not reading "
                          f"the packed layout"})

    float_bytes = 0.0
    for i, prm in enumerate(params):
        if not prm["dtype"].startswith(("f", "bf")):
            continue
        float_bytes += prm["bytes"]
        hit = dense_shapes.get(tuple(prm["shape"]))
        if hit is not None:
            violations.append({
                "check": "dense-weight-input", "subject": hit,
                "detail": f"{entry}: float parameter {prm['index']} "
                          f"{prm['dtype']}{list(prm['shape'])} matches "
                          f"this packed leaf's dense shape — the dense "
                          f"weight is HBM-resident ({paths[i]})"})
    return {"entry": entry, "rows": rows, "violations": violations,
            "packed_input_bytes": packed_bytes,
            "float_input_bytes": float_bytes}


def _kv_dense_shapes(shape, cfg):
    """Dense-widened shape(s) a uint32 KV word pool stands in for.

    GQA word pools are ``[P+1, page, KV, Wd]`` → dense ``[..., head_dim]``;
    MLA latent pools are ``[P+1, page, Wd]`` where ``Wd`` identifies the
    tensor (``words_per(kv_lora)`` vs ``words_per(rope_dim)``).
    """
    bits = cfg.kv_bits
    if len(shape) == 4:
        return [tuple(shape[:3]) + (cfg.head_dim,)]
    wd = shape[-1]
    mla_dims = ((cfg.mla.kv_lora, cfg.mla.rope_dim) if cfg.mla is not None
                else ())
    outs = [tuple(shape[:2]) + (d,) for d in mla_dims
            if d and kvquant.words_per(d, bits) == wd]
    return outs or [tuple(shape[:2]) + (wd * kvquant.kv_lanes(bits),)]


def audit_kv_page_operands(fn, args: Sequence[Any], cfg, *,
                           entry: str = "entry") -> Dict[str, Any]:
    """Eq.-14 on activations: prove the compiled decode entry reads KV
    pages at ``kv_bits``-width.

    With ``cfg.kv_bits > 0`` the cache tree's KV pools are bit-packed
    uint32 word tensors (``[P+1, page, KV, Wd]`` for GQA, ``[P+1, page,
    Wd]`` for MLA latents — ndim ≥ 3, which disambiguates them from the
    uint32 ``[B, 2]`` sampling keys).  Per word pool this asserts:

    * the entry parameter is **live** (a dead word operand means the
      graph sourced KV some other way);
    * **no float parameter** of the pool's dense-widened shape exists —
      the regression where a dense KV pool rides along at full width.

    Zero word pools in the argument tree while ``kv_bits`` is set is
    itself a violation (the engine silently fell back to dense pages).
    """
    text = jax.jit(fn).lower(*args).compile().as_text()
    params = hlo_analysis.entry_parameters(text, on_unknown="raise")
    paths = _leaf_paths(args)
    if len(params) != len(paths):
        raise RuntimeError(
            f"{entry}: HLO entry has {len(params)} parameters but the "
            f"argument tree has {len(paths)} leaves")
    flat = jax.tree_util.tree_flatten(tuple(args))[0]

    rows: List[Dict[str, Any]] = []
    violations: List[Dict[str, str]] = []
    dense_shapes: Dict[tuple, str] = {}
    word_bytes = 0.0
    for i, (leaf, prm) in enumerate(zip(flat, params)):
        if (getattr(leaf, "dtype", None) != np.uint32
                or getattr(leaf, "ndim", 0) < 3):
            continue
        for ds in _kv_dense_shapes(leaf.shape, cfg):
            dense_shapes[ds] = paths[i]
        dense_b = int(np.prod(leaf.shape[:-1])) * (
            leaf.shape[-1] * kvquant.kv_lanes(cfg.kv_bits)
            if leaf.ndim == 3 else cfg.head_dim) * 4
        rows.append({"path": paths[i], "entry": entry,
                     "param_index": prm["index"],
                     "hlo_dtype": prm["dtype"], "hlo_shape": prm["shape"],
                     "hbm_bytes": prm["bytes"], "dense_bytes": dense_b,
                     "bits": cfg.kv_bits, "uses": prm["uses"]})
        word_bytes += prm["bytes"]
        if prm["uses"] == 0:
            violations.append({
                "check": "kv-dead-operand", "subject": paths[i],
                "detail": f"{entry}: uint32 KV word pool is an unused "
                          f"entry parameter — the graph is not reading "
                          f"the quantized pages"})
    if cfg.kv_bits and not rows:
        violations.append({
            "check": "kv-operand-missing", "subject": entry,
            "detail": f"{entry}: kv_bits={cfg.kv_bits} but no uint32 KV "
                      f"word pool reaches the compiled entry — dense "
                      f"pages are serving instead"})
    for i, prm in enumerate(params):
        if not prm["dtype"].startswith(("f", "bf")):
            continue
        hit = dense_shapes.get(tuple(prm["shape"]))
        if hit is not None:
            violations.append({
                "check": "kv-dense-input", "subject": hit,
                "detail": f"{entry}: float parameter {prm['index']} "
                          f"{prm['dtype']}{list(prm['shape'])} matches "
                          f"this word pool's dense KV shape — a "
                          f"full-width KV read rides along ({paths[i]})"})
    return {"entry": entry, "rows": rows, "violations": violations,
            "kv_word_input_bytes": word_bytes}
