"""Recompile gate: jit-cache growth auditing around the engine step loop.

The engine's fixed-shape contract (PR 5) says admission, completion and
preemption never retrace a device call — dead slots are masked, slot
indices stay traced, prefill shapes depend only on the prompt length.
``test_engine`` used to assert this ad hoc on the decode cache alone;
this module promotes it into a reusable analyzer covering **every**
device call the step loop makes (decode+sample, blockwise prefill
chunks, prefill-sample) and ships a canned scenario —
:func:`audit_engine_recompiles` — that the audit CLI runs against an
artifact: warm up the shared jit caches, then drive a fresh engine
through admission, chunked prefill, completion AND page-pressure
preemption while asserting zero cache growth.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, Mapping, Optional, Union

import numpy as np


class RecompileViolation(AssertionError):
    """A watched jit cache grew beyond its budget during a scenario."""


def _as_counts_fn(source: Any) -> Callable[[], Dict[str, int]]:
    """Normalize a counts source: a zero-arg callable returning
    ``{name: count}`` (e.g. ``Engine.trace_counts``), or a mapping of
    name → jitted function / zero-arg int callable."""
    if isinstance(source, Mapping):
        probes = {}
        for name, fn in source.items():
            if hasattr(fn, "_cache_size"):
                probes[name] = fn._cache_size
            elif callable(fn):
                probes[name] = fn
            else:
                raise TypeError(f"{name}: not a jitted fn or callable")
        return lambda: {n: int(p()) for n, p in probes.items()}
    if hasattr(source, "_cache_size"):      # a single jitted function
        return lambda: {"jit": int(source._cache_size())}
    if callable(source):
        return lambda: {k: int(v) for k, v in source().items()}
    raise TypeError("counts source must be a callable, mapping, or jit fn")


class RecompileAuditor:
    """Snapshot jit-cache entry counts, run a scenario, assert no growth.

    ::

        aud = RecompileAuditor(engine.trace_counts)
        with aud.frozen("steady-state decode"):
            engine.run(requests)

    ``budget`` (per check) allows bounded growth — e.g. ``{"decode": 1}``
    for a scenario that legitimately compiles the step once.  Growth in
    any *other* watched cache still raises.
    """

    def __init__(self, counts: Any):
        self._counts = _as_counts_fn(counts)
        self._base: Optional[Dict[str, int]] = None

    def snapshot(self) -> Dict[str, int]:
        self._base = dict(self._counts())
        return dict(self._base)

    def delta(self) -> Dict[str, int]:
        if self._base is None:
            raise RuntimeError("snapshot() before delta()")
        now = self._counts()
        return {k: now[k] - self._base.get(k, 0) for k in now}

    def check(self, label: str = "scenario",
              budget: Union[int, Mapping[str, int], None] = None
              ) -> Dict[str, int]:
        """Raise :class:`RecompileViolation` if any watched cache grew
        beyond its budget (default 0); returns the delta otherwise."""
        delta = self.delta()
        if isinstance(budget, Mapping):
            allowed = lambda k: int(budget.get(k, 0))  # noqa: E731
        else:
            allowed = lambda k: int(budget or 0)       # noqa: E731
        grew = {k: d for k, d in delta.items() if d > allowed(k)}
        if grew:
            detail = ", ".join(f"{k}: +{d} (budget {allowed(k)})"
                               for k, d in sorted(grew.items()))
            raise RecompileViolation(
                f"{label}: jit caches grew during the scenario — {detail}. "
                f"The step loop retraced; check for shape- or "
                f"dtype-varying arguments.")
        return delta

    @contextlib.contextmanager
    def frozen(self, label: str = "scenario",
               budget: Union[int, Mapping[str, int], None] = None):
        self.snapshot()
        yield self
        self.check(label, budget)


def audit_engine_recompiles(params, cfg, *, n_slots: int = 2,
                            page_size: int = 8, max_seq: int = 64,
                            vocab: Optional[int] = None) -> Dict[str, Any]:
    """Prove the engine step loop never retraces, on a scenario that
    actually exercises admission, chunked prefill, completion and
    page-pressure preemption.

    Two passes with identical request shapes: a warmup engine populates
    the shared jit caches, then a **fresh** engine replays the scenario
    under a frozen :class:`RecompileAuditor` — any cache growth means a
    step-loop code path (not a new shape) triggered a retrace.  Raises
    :class:`RecompileViolation` on growth; returns the evidence dict
    ``{"counts", "delta", "events"}`` and asserts the scenario really
    contained admissions, completions and ≥1 preemption (an audit that
    never preempted proves nothing about preemption).
    """
    from repro.engine.engine import Engine
    from repro.engine.scheduler import Request

    if vocab is None:
        vocab = cfg.vocab
    rng = np.random.default_rng(0)
    pages_per_slot = -(-max_seq // page_size)
    # Pool sized so each request fits alone but two running slots
    # collide mid-generation → guaranteed stall → preemption.
    n_pages = pages_per_slot
    long_total = max_seq - page_size // 2

    def scenario():
        prompt_len = 2 * page_size
        new = long_total - prompt_len
        return [Request(rid=r,
                        prompt=rng.integers(0, vocab, prompt_len,
                                            dtype=np.int64).astype(np.int32),
                        max_new_tokens=new,
                        temperature=0.7 if r % 2 else 0.0,
                        top_k=8 if r % 2 else 0, seed=r)
                for r in range(3)]

    def drive(engine):
        return engine.run(scenario())

    mk = lambda: Engine(params, cfg, n_slots=n_slots,  # noqa: E731
                        page_size=page_size, max_seq=max_seq,
                        n_pages=n_pages)
    warm = mk()
    drive(warm)

    fresh = mk()
    auditor = RecompileAuditor(fresh.trace_counts)
    with auditor.frozen("engine admission/completion/preemption loop"):
        drive(fresh)
    st = fresh.stats
    events = {"admitted": st.admitted, "finished": st.finished,
              "preemptions": st.preemptions, "steps": st.steps}
    if not (st.admitted >= 3 and st.finished >= 3 and st.preemptions >= 1):
        raise RuntimeError(
            f"recompile-audit scenario too weak to prove anything: "
            f"{events} (needs admissions, completions and a preemption)")
    return {"counts": fresh.trace_counts(), "delta": auditor.delta(),
            "events": events}
