"""Deterministic synthetic datasets (the container is offline — no MNIST/
CIFAR downloads).  Each generator is seeded and pure, so the pipeline
cursor (seed, step) fully determines the batch — that is what makes
checkpoint/restart exactly reproducible.

* LM tokens: order-1 Markov chains with class-dependent transition
  matrices → next-token CE is genuinely learnable (loss decreases well
  below log V).
* MNIST-like classification: 10 class templates (random smooth blobs) +
  per-sample noise, 28×28 — same tensor shapes as the paper's §5.3.
* Super-resolution regression (§5.2): high-res "images" are smooth random
  fields; the low-res input is an average-pool (a linear map, exactly the
  paper's setting) + Gaussian noise.  The optimal W recovers clustered,
  non-Gaussian weights — reproducing the paper's fig. 7 structure.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _markov_logits(seed: Array, vocab: int, rank: int = 16,
                   temp: float = 0.7) -> Array:
    """Low-rank transition logits [V, V] — structured, learnable."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed) if seed.ndim == 0
                              else seed)
    a = jax.random.normal(k1, (vocab, rank))
    b = jax.random.normal(k2, (rank, vocab))
    return (a @ b) / (temp * jnp.sqrt(rank))


def lm_batch(seed: int, step: int, batch: int, seq_len: int,
             vocab: int) -> Dict[str, Array]:
    """Deterministic (seed, step) → {tokens, labels} with Markov structure."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    logits = _markov_logits(jnp.asarray(seed, jnp.uint32), min(vocab, 512))

    def sample_seq(k):
        k0, k = jax.random.split(k)
        first = jax.random.randint(k0, (), 0, min(vocab, 512))

        def body(tok, kk):
            nxt = jax.random.categorical(kk, logits[tok])
            return nxt, nxt

        _, toks = jax.lax.scan(body, first, jax.random.split(k, seq_len))
        return jnp.concatenate([first[None], toks[:-1]])

    keys = jax.random.split(key, batch)
    tokens = jax.vmap(sample_seq)(keys) % vocab
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens.astype(jnp.int32),
            "labels": labels.astype(jnp.int32)}


# ---------------------------------------------------------------------------
# MNIST-like classification
# ---------------------------------------------------------------------------

def _class_templates(seed: int, num_classes: int = 10,
                     side: int = 28) -> Array:
    """Smooth random blobs per class (fixed by seed)."""
    key = jax.random.PRNGKey(seed)
    raw = jax.random.normal(key, (num_classes, side, side))
    # cheap smoothing: two 3x3 box blurs
    for _ in range(2):
        raw = (raw +
               jnp.roll(raw, 1, 1) + jnp.roll(raw, -1, 1) +
               jnp.roll(raw, 1, 2) + jnp.roll(raw, -1, 2)) / 5.0
    raw = raw / jnp.std(raw, axis=(1, 2), keepdims=True)
    return raw


def mnist_like(seed: int, n: int, noise: float = 0.6,
               num_classes: int = 10, side: int = 28) -> Tuple[Array, Array]:
    """Returns (images [N, side*side], labels [N]) — separable, non-trivial."""
    templates = _class_templates(seed, num_classes, side)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 1)
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (n,), 0, num_classes)
    imgs = templates[labels] + noise * jax.random.normal(k2, (n, side, side))
    imgs = imgs - jnp.mean(imgs)
    return imgs.reshape(n, side * side), labels.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Super-resolution regression (§5.2)
# ---------------------------------------------------------------------------

def mnist_like_split(seed: int, n_train: int, n_test: int,
                     noise: float = 0.6):
    """Train/test split drawn from the SAME class templates (a held-out
    set from a different seed is a different distribution entirely)."""
    x, y = mnist_like(seed, n_train + n_test, noise=noise)
    return (x[:n_train], y[:n_train]), (x[n_train:], y[n_train:])


def superres_data(seed: int, n: int = 1000, hi_side: int = 28,
                  factor: int = 2, noise: float = 0.05
                  ) -> Tuple[Array, Array]:
    """(x low-res [N, (hi/f)²], y high-res [N, hi²]).

    y are smooth random images; x = avgpool(y) + ε.  The least-squares
    recovery matrix W* = A⁺ has rows with a few equal nonzero entries ⇒
    the clustered, far-from-Gaussian weight distribution of the paper's
    fig. 7 (a large cluster at 0 plus small positive clusters).
    """
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    y = jax.random.normal(k1, (n, hi_side, hi_side))
    for _ in range(3):
        y = (y + jnp.roll(y, 1, 1) + jnp.roll(y, -1, 1)
             + jnp.roll(y, 1, 2) + jnp.roll(y, -1, 2)) / 5.0
    y = y / jnp.std(y)
    lo = hi_side // factor
    x = y.reshape(n, lo, factor, lo, factor).mean(axis=(2, 4))
    x = x + noise * jax.random.normal(k2, (n, lo, lo))
    return x.reshape(n, lo * lo), y.reshape(n, hi_side * hi_side)
