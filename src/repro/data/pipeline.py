"""Host data pipeline: deterministic, sharded, prefetching, checkpointable.

The pipeline cursor is just ``(seed, step)`` — synthetic generators are
pure functions of it, so restoring a checkpoint resumes the *exact* token
stream (no data loss/duplication on restart).  ``shard_batch`` places the
global batch on the mesh's data axes; with multi-host DP each host would
generate only its addressable slice (same interface, sliced by
``process_index`` — single-process here).

Prefetch: a depth-``k`` iterator that overlaps host generation with device
steps — the straggler-mitigation lever (a) of DESIGN §9.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic

Array = jax.Array


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class LMTokenPipeline:
    """Markov LM batches keyed by (seed, step)."""

    def __init__(self, seed: int, batch: int, seq_len: int, vocab: int,
                 start_step: int = 0):
        self.state = PipelineState(seed=seed, step=start_step)
        self.batch, self.seq_len, self.vocab = batch, seq_len, vocab

    def next(self) -> Dict[str, Array]:
        b = synthetic.lm_batch(self.state.seed, self.state.step,
                               self.batch, self.seq_len, self.vocab)
        self.state.step += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, Array]]:
        while True:
            yield self.next()


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetch of ``depth`` batches."""
    q: collections.deque = collections.deque()
    lock = threading.Condition()
    done = []

    def worker():
        try:
            for item in it:
                with lock:
                    while len(q) >= depth:
                        lock.wait()
                    q.append(item)
                    lock.notify_all()
        finally:
            with lock:
                done.append(True)
                lock.notify_all()

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        with lock:
            while not q and not done:
                lock.wait()
            if q:
                item = q.popleft()
                lock.notify_all()
            else:
                return
        yield item


def shard_batch(batch: Dict[str, Array], mesh: jax.sharding.Mesh,
                batch_axes=("pod", "data")) -> Dict[str, Array]:
    """Place a host-global batch with batch-dim sharded over data axes."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    spec = jax.sharding.PartitionSpec(axes)

    def place(x):
        pspec = jax.sharding.PartitionSpec(
            axes, *([None] * (x.ndim - 1))) if x.ndim else jax.sharding.PartitionSpec()
        return jax.device_put(x, jax.sharding.NamedSharding(mesh, pspec))

    return jax.tree_util.tree_map(place, batch)
