"""Parameter / batch / cache sharding rules for the production meshes.

Megatron-style tensor parallelism by leaf name:

* column-parallel (output dim over "model"): ``wq wk wv w_in w_gate`` and
  every other ≥2-D multiplicative weight by default — the *last* axis;
* row-parallel (input dim over "model"): ``wo w_out out_proj_w`` — the
  second-to-last axis, so the TP pair (col-parallel up, row-parallel down)
  needs a single all-reduce per block;
* ``embed_tok`` shards the vocab axis, ``head_w`` the vocab (last) axis.

Divisibility is validated per leaf: a dim that does not divide the mesh
axis size **drops** that axis (replicates) instead of erroring — e.g. an
odd vocab like 50281 on a 4-way model axis.  This is the rule
``tests/test_dist.py::test_param_sharding_rules_divisibility`` pins down.

``zero=True`` additionally shards a remaining axis over the data axes
(ZeRO-style param partitioning, for the archs that do not fit replicated);
``zero_cols=True`` shards the matmul dim *orthogonal* to the model axis
over the data axes (the "tp_zcols" dry-run policy).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# Leaves whose *input* dim is model-sharded (row-parallel in Megatron terms).
ROW_PARALLEL = ("wo", "w_out", "out_proj_w")
# Embedding-style leaves: shard the vocab/first axis.
VOCAB_FIRST = ("embed_tok",)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch-sharding (pure data parallel) axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _model_dim(name: str, ndim: int) -> Optional[int]:
    """Which dim the model axis shards for this leaf (None: replicate)."""
    if name.endswith("_cb"):
        return None             # codebooks are tiny: replicate
    if name.endswith("_pidx"):
        name = name[:-5]        # bit-packed indices shard like their weight
    elif name.endswith("_idx"):
        name = name[:-4]        # quantized leaves shard like their weight
    if ndim < 2:
        return None
    if name in VOCAB_FIRST:
        return 0
    if name in ROW_PARALLEL:
        return ndim - 2
    return ndim - 1


def param_shardings(params: PyTree, mesh: Mesh, zero: bool = False,
                    zero_cols: bool = False) -> PyTree:
    """NamedSharding tree congruent with ``params`` (arrays or
    ShapeDtypeStructs — only ``.shape`` is read)."""
    daxes = batch_axes(mesh)
    model = mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1
    dsize = _axis_size(mesh, daxes)

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        parts: list = [None] * len(shape)
        mdim = _model_dim(name, len(shape))
        if mdim is not None and model > 1 and shape[mdim] % model == 0:
            parts[mdim] = "model"
        else:
            mdim = None
        if zero_cols and mdim is not None and dsize > 1:
            # rows over data, cols over model (or vice versa for row-par)
            other = len(shape) - 1 if mdim != len(shape) - 1 else len(shape) - 2
            if parts[other] is None and shape[other] % dsize == 0:
                parts[other] = daxes
        elif zero and dsize > 1 and len(shape) >= 2:
            for d in range(len(shape)):
                if parts[d] is None and shape[d] % dsize == 0:
                    parts[d] = daxes
                    break
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(rule, params)


def batch_shardings(batch: PyTree, mesh: Mesh) -> PyTree:
    """Shard the leading (global-batch) dim over the data axes."""
    daxes = batch_axes(mesh)
    dsize = _axis_size(mesh, daxes)

    def rule(leaf):
        if leaf.ndim == 0 or dsize <= 1 or leaf.shape[0] % dsize:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(daxes, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(rule, batch)


def engine_cache_shardings(caches: PyTree, mesh: Mesh, *, n_slots: int,
                           n_pages: Optional[int] = None) -> PyTree:
    """Shardings for the engine's paged/slot caches (``init_paged_cache``).

    Two leaf families, told apart by the second axis:

    * page pools (``[G, n_pages + 1, page, ...]``) — the page axis
      **replicates** over the data axes: any slot's page-table entry may
      point at any physical page, so pages cannot be partitioned by
      batch.  The kv-head axis of 5-D pools shards over ``model`` (the
      head's pages live with its projection shard); MLA latent pools
      (4-D) replicate;
    * per-slot state (``shape[1] == n_slots``: SSM/RG-LRU state, conv
      tails, sliding-window ring buffers) — the slot axis shards over
      the data axes exactly like a decode batch, and 5-D KV-style leaves
      keep their kv-head axis on ``model``.

    Pass ``n_pages`` so the pool check wins when ``n_pages + 1 ==
    n_slots`` (an oversubscribed pool could otherwise be mistaken for
    slot state and have its pages data-sharded; replication is the
    always-correct fallback).
    """
    daxes = batch_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    model = mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1

    def rule(leaf):
        parts: list = [None] * leaf.ndim
        is_pool = (n_pages is not None and leaf.ndim >= 3
                   and leaf.shape[1] == n_pages + 1)
        is_slot = (not is_pool and leaf.ndim >= 2
                   and leaf.shape[1] == n_slots)
        if is_slot and dsize > 1 and n_slots % dsize == 0:
            parts[1] = daxes
        if leaf.ndim >= 5 and model > 1 and leaf.shape[3] % model == 0:
            parts[3] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map(rule, caches)


def cache_shardings(caches: PyTree, mesh: Mesh) -> PyTree:
    """Decode/prefill cache shardings.  Stacked cache leaves are
    [G, B, ...]: batch over the data axes; for KV-style leaves
    [G, B, S, n_kv, hd] the kv-head axis goes over "model" (TP attention
    keeps each head's cache where its projection shard lives)."""
    daxes = batch_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    model = mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1

    def rule(leaf):
        parts: list = [None] * leaf.ndim
        if leaf.ndim >= 2 and dsize > 1 and leaf.shape[1] % dsize == 0:
            parts[1] = daxes
        if leaf.ndim >= 5 and model > 1 and leaf.shape[3] % model == 0:
            parts[3] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map(rule, caches)
