"""Distributed layer: param/batch/cache sharding rules and the sharded
C-step primitives (paper §4 solved under a mesh decomposition).

Everything here is mesh-agnostic: the rules take any ``jax.sharding.Mesh``
with some subset of the ("pod", "data", "model") axes, and the C-step
primitives take an ``axis_name`` so the same code runs inside any
``shard_map``.  The scheme dispatch (which primitive solves which scheme's
C step) goes through :func:`repro.dist.cstep.sharded_c_step`, keyed by the
same :class:`repro.core.plan.CompressionPlan` the single-device path uses.
"""
from repro.dist import cstep, sharding  # noqa: F401
