"""Sharded C-step primitives (paper §4 under a mesh decomposition).

The C step ``min_Θ ||w - Δ(Θ)||²`` touches every weight, so at production
scale it must run where the weight shards live.  Four primitives cover
every registered scheme:

* :func:`sharded_kmeans` — the adaptive-codebook C step (§4.1): each shard
  computes local per-centroid (Σw, count) statistics and a ``psum`` merges
  them — the *exact* global k-means update with 2·K floats of traffic per
  iteration (the weights never leave their chips).
* :func:`adaptive_zero_kmeans_psum` — the same statistics merge with one
  centroid re-pinned at 0 each iteration (§4.2 footnote 2: quantization +
  pruning jointly) — the ``adaptive_zero`` C step no longer falls back to
  the local solver.
* :func:`ternary_scale_histogram` — the ternary-with-scale C step
  (Theorem A.3).  The exact solution needs a global sort of |w|; the
  distributed reformulation bins |w| into a psum'd histogram and optimizes
  the prefix objective over bin boundaries — per-bin Σ|w| is accumulated
  exactly, so the only approximation is restricting the threshold to bin
  edges (rel. error ~1e-4 at 4k bins).
* :func:`compressed_psum` — int8-compressed all-reduce: each shard ships
  ⌈1 byte/value⌉ (own max-abs scale, symmetric round-to-nearest int8)
  instead of 4-byte floats — the paper's codebook-with-scale idea applied
  to the gradient collective on the slow (cross-pod) axis.

:func:`sharded_c_step` dispatches a scheme (or a
:class:`~repro.core.plan.CompressionPlan`) to these primitives, so the
distributed C step is driven by exactly the same plan object as the
single-device path.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import quant_ops
from repro.core.kmeans import kmeans_fit
from repro.core.schemes import (AdaptiveScheme, AdaptiveZeroScheme,
                                FixedScheme, ScaledFixedScheme, Scheme,
                                as_scheme)

Array = jax.Array
AxisName = Union[str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# Adaptive codebook: psum-exact k-means
# ---------------------------------------------------------------------------

def sharded_kmeans(w: Array, init_codebook: Array, mesh: Mesh,
                   iters: int = 20, axis: str = "model",
                   tol: float = 1e-4) -> Tuple[Array, Array, Array]:
    """Global k-means over ``w`` sharded on ``mesh`` axis ``axis``.

    Returns (codebook [K] replicated, assignments sharded like ``w``,
    distortion scalar).  Bit-for-bit the same update as
    :func:`repro.core.kmeans.kmeans_fit` — the per-centroid statistics are
    merged with a psum before the centroid step, and the convergence /
    plateau tests are global, so every shard walks the identical codebook
    trajectory.
    """
    def body(ws, cb):
        res = kmeans_fit(ws, cb, iters=iters, axis_name=axis, tol=tol)
        return res.codebook, res.assignments, res.distortion

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                   out_specs=(P(), P(axis), P()), check_rep=False)
    return fn(w, init_codebook)


# ---------------------------------------------------------------------------
# Ternary scale: histogram-CDF reformulation of Theorem A.3
# ---------------------------------------------------------------------------

def ternary_scale_histogram(w: Array, axis_name: Optional[AxisName],
                            bins: int = 4096) -> Array:
    """Optimal ternary scale  a* = max_j (1/j)Σ_{i≤j}|w|_(i)  s.t.
    j* = argmax_j (1/√j)Σ_{i≤j}|w|_(i)  — evaluated over a global
    |w|-histogram instead of a global sort.

    Call inside ``shard_map`` with the local weight shard; ``axis_name``
    merges max/histogram across shards (pass None for single-device use).
    Per-bin Σ|w| is accumulated exactly; only the candidate thresholds are
    discretized to bin edges.
    """
    aw = jnp.abs(w.ravel()).astype(jnp.float32)

    def pmerge(x):
        return jax.lax.psum(x, axis_name) if axis_name is not None else x

    gmax = jnp.max(aw, initial=0.0)
    if axis_name is not None:
        gmax = jax.lax.pmax(gmax, axis_name)
    scale = jnp.maximum(gmax, jnp.finfo(jnp.float32).tiny)
    idx = jnp.clip((aw / scale * bins).astype(jnp.int32), 0, bins - 1)
    counts = pmerge(jax.ops.segment_sum(jnp.ones_like(aw), idx,
                                        num_segments=bins))
    sums = pmerge(jax.ops.segment_sum(aw, idx, num_segments=bins))

    # Descending-magnitude prefixes = suffix-cumsum over ascending bins.
    n_desc = jnp.cumsum(counts[::-1])
    s_desc = jnp.cumsum(sums[::-1])
    obj = jnp.where(n_desc > 0, s_desc / jnp.sqrt(jnp.maximum(n_desc, 1.0)),
                    -jnp.inf)
    jstar = jnp.argmax(obj)
    return s_desc[jstar] / jnp.maximum(n_desc[jstar], 1.0)


def adaptive_zero_kmeans_psum(w: Array, codebook: Array, k: int,
                              axis_name: Optional[AxisName],
                              iters: int) -> Tuple[Array, Array]:
    """Pinned-zero k-means (§4.2 footnote 2: quantization + pruning
    jointly) under sharding — the sharded primitive for
    ``AdaptiveZeroScheme``: per-centroid (Σw, count) statistics are
    psum-merged before the centroid step (2·K floats of traffic per
    iteration, the weights never leave their chips), then the zero
    centroid is re-pinned exactly as the local
    ``AdaptiveZeroScheme.c_step`` does — every shard walks the identical
    codebook trajectory.  Returns (codebook, quantized local shard).
    """
    flat = w.ravel()

    def body(c, _):
        assign = quant_ops.fixed_codebook_assign(flat, c)
        sums = jax.ops.segment_sum(flat, assign, num_segments=k)
        counts = jax.ops.segment_sum(jnp.ones(flat.size), assign,
                                     num_segments=k)
        if axis_name is not None:
            sums = jax.lax.psum(sums, axis_name)
            counts = jax.lax.psum(counts, axis_name)
        c_new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), c)
        zi = jnp.argmin(jnp.abs(c_new))
        return jnp.sort(c_new.at[zi].set(0.0)), None

    cb, _ = jax.lax.scan(body, codebook, None, length=iters)
    assign = quant_ops.fixed_codebook_assign(flat, cb)
    return cb, cb[assign].reshape(w.shape)


def binary_scale_psum(w: Array, axis_name: Optional[AxisName]) -> Array:
    """Optimal binary scale a* = mean|w| (Theorem A.2) — *exact* under
    sharding: a single psum of (Σ|w|, count)."""
    s = jnp.sum(jnp.abs(w))
    n = jnp.asarray(w.size, jnp.float32)
    if axis_name is not None:
        s = jax.lax.psum(s, axis_name)
        n = jax.lax.psum(n, axis_name)
    return s / n


# ---------------------------------------------------------------------------
# int8-compressed all-reduce
# ---------------------------------------------------------------------------

def compressed_psum(x: Array, axis_name: AxisName) -> Array:
    """psum(x) over ``axis_name`` shipping int8 payloads + one f32 scale
    per shard (per-shard symmetric max-abs quantization).

    Wire bytes: 1 B/value (+4 B) vs 4 B f32 — the collective the multi-pod
    "pod" axis uses for gradient sync.  Heterogeneous per-shard scales are
    handled exactly: each shard's payload is dequantized with *its own*
    scale before the sum, so a small-gradient shard is not crushed by a
    large-gradient one.
    """
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    qg = jax.lax.all_gather(q, axis_name)          # [n_shards, ...] int8 wire
    sg = jax.lax.all_gather(scale, axis_name)      # [n_shards] f32
    sg = sg.reshape((-1,) + (1,) * x.ndim)
    return jnp.sum(qg.astype(jnp.float32) * sg, axis=0).astype(x.dtype)


# ---------------------------------------------------------------------------
# Plan-driven dispatch
# ---------------------------------------------------------------------------

def sharded_c_step(plan_or_scheme, w: Array, axis_name: Optional[AxisName],
                   codebook: Optional[Array] = None,
                   iters: Optional[int] = None,
                   ) -> Tuple[Array, dict]:
    """Solve Π(w) for one sharded quantization group, *inside* shard_map.

    ``plan_or_scheme``: a CompressionPlan or bare Scheme — the same object
    that drives the single-device C step, so launch code is scheme- and
    mesh-agnostic.  Returns (quantized local shard, new Θ state).

    Adaptive schemes with ``codebook=None`` take the **first-C-step path**:
    the codebook warm-starts from :func:`histogram_quantiles` (the
    distributed analogue of ``kmeans.quantile_init`` — a psum'd global
    histogram CDF inverse; the weights never leave their chips) and
    k-means runs ``scheme.iters_first`` iterations instead of
    ``iters_warm``.  On a 1-device mesh this is exactly the local
    quantile-init first C step (the histogram discretization is the only
    approximation, and at 4k bins it vanishes under the k-means
    refinement — pinned by ``tests/test_dist.py``).
    """
    scheme: Scheme = as_scheme(plan_or_scheme)
    if isinstance(scheme, AdaptiveZeroScheme):
        # Pinned-zero variant first (it subclasses AdaptiveScheme): the
        # constrained centroid step runs via adaptive_zero_kmeans_psum.
        first = codebook is None
        if first:
            codebook = histogram_quantiles(w, scheme.k, axis_name)
            zi = jnp.argmin(jnp.abs(codebook))
            codebook = jnp.sort(codebook.at[zi].set(0.0))
        if iters is None:
            iters = scheme.iters_first if first else scheme.iters_warm
        cb, q = adaptive_zero_kmeans_psum(w, codebook, scheme.k, axis_name,
                                          iters)
        return q.astype(w.dtype), {
            "codebook": cb, "kmeans_iters": jnp.asarray(iters, jnp.int32)}
    if isinstance(scheme, AdaptiveScheme):
        first = codebook is None
        if first:
            codebook = histogram_quantiles(w, scheme.k, axis_name)
        if iters is None:
            iters = scheme.iters_first if first else scheme.iters_warm
        res = kmeans_fit(w, codebook, iters=iters, axis_name=axis_name)
        q = res.codebook[res.assignments]
        return q.astype(w.dtype), {"codebook": res.codebook,
                                   "kmeans_iters": res.iters_run}
    if isinstance(scheme, ScaledFixedScheme):
        if scheme.kind == "binary_scale":
            a = binary_scale_psum(w, axis_name)
            return (a * quant_ops.sgn(w)).astype(w.dtype), {"scale": a}
        a = ternary_scale_histogram(w, axis_name)
        q = quant_ops.sgn(w) * a * (jnp.abs(w) >= 0.5 * a).astype(w.dtype)
        return q.astype(w.dtype), {"scale": a}
    if isinstance(scheme, FixedScheme):
        # Parameter-free codebooks are elementwise: zero communication.
        q, state = scheme.c_step(w, scheme.init(jax.random.PRNGKey(0), w))
        return q, state
    raise TypeError(f"no sharded C step for scheme {scheme!r}")


def lc_c_step_sharded(params, state, *, scheme, qspec, config, mesh: Mesh,
                      axis: str = "model", advance_mu: bool = True):
    """Drop-in for :func:`repro.core.lc.c_step` that solves each quantized
    group shard-local on ``mesh`` (the ROADMAP "wire sharded_c_step into
    LCTrainer" item): same (Θ, w_C, λ, μ) update, but every leaf's Π(w)
    runs inside ``shard_map`` over ``axis`` via :func:`sharded_c_step`, so
    the weights never leave their chips — the only C-step traffic is the
    per-centroid psum statistics (adaptive) or the scale psum/histogram
    (scaled-fixed).

    Exactness: adaptive leaves walk the bit-identical k-means trajectory
    (psum-exact statistics); ``adaptive_zero`` leaves use the pinned-zero
    psum primitive (:func:`adaptive_zero_kmeans_psum` — same statistics
    merge, the zero centroid re-pinned each iteration exactly like the
    local solver); ``ternary_scale`` is the histogram reformulation
    (rel. error ~1e-4 at 4k bins).  The remaining fallback boundary: a
    leaf whose per-shard element count does not divide the mesh axis
    falls back to the local solver (replicated math, still correct —
    just not shard-local); pinned by tests/test_dist.py.

    Enabled from a plan via ``CompressionPlan(sharded_c_step=True)`` +
    ``LCTrainer.from_plan(..., mesh=...)``.
    """
    from repro.core import lc as lc_mod

    scheme = as_scheme(scheme)
    grouped = lc_mod._grouped_lookup(qspec)
    mu = state.mu
    nshards = mesh.shape[axis]
    adaptive = isinstance(scheme, AdaptiveScheme)
    iters = getattr(scheme, "iters_warm", 5)
    new_theta = {}

    def rep_specs(tree):
        return jax.tree_util.tree_map(lambda _: P(), tree)

    def solve_one(wsh, th):
        cb = th["codebook"] if adaptive else None
        return sharded_c_step(scheme, wsh, axis, codebook=cb, iters=iters)

    def do_c(path, w, lam):
        ws = w - lam / jnp.maximum(mu, 1e-30)
        th = state.theta[path]
        if grouped[path]:
            flat = ws.reshape(ws.shape[0], -1)
            shardable = flat.shape[1] % nshards == 0
        else:
            flat = ws.ravel()
            shardable = flat.size % nshards == 0
        if not shardable:
            if grouped[path]:
                q, nth = jax.vmap(
                    lambda wi, ti: scheme.c_step(wi, ti, first=False))(ws, th)
            else:
                q, nth = scheme.c_step(ws, th, first=False)
            new_theta[path] = nth
            return q.astype(w.dtype)

        if grouped[path]:
            # Per-layer codebooks: vmap over G inside the shard_map body —
            # collectives batch, so each group's statistics psum is exact.
            def body(wsh, thx):
                return jax.vmap(solve_one)(wsh, thx)
            w_spec = P(None, axis)
        else:
            def body(wsh, thx):
                return solve_one(wsh, thx)
            w_spec = P(axis)
        # Every sharded_c_step branch returns a Θ dict with the same
        # structure as the incoming state (adaptive: codebook+iters;
        # fixed: codebook; scaled: scale), so the replicated out_specs
        # mirror the in_specs.
        fn = shard_map(body, mesh=mesh,
                       in_specs=(w_spec, rep_specs(th)),
                       out_specs=(w_spec, rep_specs(th)),
                       check_rep=False)
        q, nth = fn(flat, th)
        new_theta[path] = nth
        return q.reshape(ws.shape).astype(w.dtype)

    w_c = lc_mod._map_quant(do_c, qspec, params, state.lam)

    if config.use_lagrangian:
        lam = lc_mod._map_quant(
            lambda path, lam, w, q: lam - mu * (w - q),
            qspec, state.lam, params, w_c,
            default=lambda path, lam, w, q: lam)
    else:
        lam = state.lam

    return lc_mod.LCState(
        w_c=w_c, lam=lam, theta=new_theta,
        mu=mu * config.mu_growth if advance_mu else mu,
        lc_iter=state.lc_iter + 1,
    )


def histogram_quantiles(w: Array, k: int, axis_name: Optional[AxisName],
                        bins: int = 4096) -> Array:
    """Distributed quantile codebook init (the sharded analogue of
    :func:`repro.core.kmeans.quantile_init`): global-histogram CDF inverse
    at the k mid-quantiles."""
    flat = w.ravel().astype(jnp.float32)
    lo, hi = jnp.min(flat), jnp.max(flat)
    if axis_name is not None:
        lo = -jax.lax.pmax(-lo, axis_name)
        hi = jax.lax.pmax(hi, axis_name)
    span = jnp.maximum(hi - lo, jnp.finfo(jnp.float32).tiny)
    idx = jnp.clip(((flat - lo) / span * bins).astype(jnp.int32), 0, bins - 1)
    counts = jax.ops.segment_sum(jnp.ones_like(flat), idx, num_segments=bins)
    if axis_name is not None:
        counts = jax.lax.psum(counts, axis_name)
    cdf = jnp.cumsum(counts)
    total = cdf[-1]
    qs = (jnp.arange(k, dtype=jnp.float32) + 0.5) / k * total
    bidx = jnp.searchsorted(cdf, qs, side="left")
    centers = lo + (bidx.astype(jnp.float32) + 0.5) / bins * span
    return jnp.sort(centers)
