"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant_ops


def kmeans_assign_ref(w: jax.Array, codebook: jax.Array):
    """Reference for kernels.kmeans_assign: brute-force argmin + segment sums.

    Note: argmin tie-breaking (lowest index) matches the kernel.
    """
    flat = w.reshape(-1).astype(jnp.float32)
    c = codebook.astype(jnp.float32)
    d = (flat[:, None] - c[None, :]) ** 2
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    sums = jax.ops.segment_sum(flat, assign, num_segments=c.shape[0])
    counts = jax.ops.segment_sum(jnp.ones_like(flat), assign,
                                 num_segments=c.shape[0])
    return assign, sums, counts


def codebook_matmul_ref(x: jax.Array, idx: jax.Array, codebook: jax.Array):
    """Reference for kernels.codebook_matmul: dequantize fully, then dot."""
    w = codebook.astype(jnp.float32)[idx.astype(jnp.int32)]
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def packed_codebook_matmul_ref(x: jax.Array, pidx: jax.Array,
                               codebook: jax.Array):
    """Reference for kernels.codebook_matmul_packed: unpack the uint32 word
    operand (``compression.pack_indices_2d`` layout), then gather + dot.
    Also the CPU serving path — the unpack is an in-jit temporary, so the
    HBM-resident operand stays bit-packed here too."""
    from repro.core.compression import unpack_indices_2d

    idx = unpack_indices_2d(pidx, x.shape[-1], codebook.shape[0])
    return codebook_matmul_ref(x, idx, codebook)


def packed_codebook_matmul_t_ref(x: jax.Array, pidx: jax.Array,
                                 codebook: jax.Array, n_out: int,
                                 order: str = "kd"):
    """Reference for kernels.codebook_matmul_packed_t: unpack the word
    operand (either orientation), gather, then the transposed dot."""
    from repro.core.compression import unpack_indices_2d, unpack_rows

    if order == "row":
        idx = unpack_rows(pidx, x.shape[-1], codebook.shape[0])   # [V, D]
    else:
        idx = unpack_indices_2d(pidx, n_out, codebook.shape[0])   # [V, D]
    w = codebook.astype(jnp.float32)[idx]
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)


def quantized_gather_ref(tokens: jax.Array, pidx: jax.Array,
                         codebook: jax.Array, d: int):
    """Reference for kernels.quantized_gather: gather the packed word row,
    unpack its lanes, LUT through the codebook — a pure gather, so it is
    bit-exact vs the kernel and vs the dense-table row gather."""
    from repro.core.compression import unpack_rows

    words = pidx[tokens.astype(jnp.int32)]           # [..., ⌈d/lanes⌉]
    idx = unpack_rows(words, d, codebook.shape[0])
    return codebook[idx]


def fixed_quant_ref(w: jax.Array, mode: str, pow2_c: int = 4,
                    scale: float = 1.0):
    """Reference for kernels.fixed_quant via repro.core.quant_ops."""
    ws = w.astype(jnp.float32) / scale
    if mode == "binary":
        q = quant_ops.binarize(ws)
    elif mode == "ternary":
        q = quant_ops.ternarize(ws)
    else:
        q = quant_ops.pow2_quantize(ws, pow2_c)
    return (q * scale).astype(w.dtype)
