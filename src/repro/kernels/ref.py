"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant_ops


def kmeans_assign_ref(w: jax.Array, codebook: jax.Array):
    """Reference for kernels.kmeans_assign: brute-force argmin + segment sums.

    Note: argmin tie-breaking (lowest index) matches the kernel.
    """
    flat = w.reshape(-1).astype(jnp.float32)
    c = codebook.astype(jnp.float32)
    d = (flat[:, None] - c[None, :]) ** 2
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    sums = jax.ops.segment_sum(flat, assign, num_segments=c.shape[0])
    counts = jax.ops.segment_sum(jnp.ones_like(flat), assign,
                                 num_segments=c.shape[0])
    return assign, sums, counts


def codebook_matmul_ref(x: jax.Array, idx: jax.Array, codebook: jax.Array):
    """Reference for kernels.codebook_matmul: dequantize fully, then dot."""
    w = codebook.astype(jnp.float32)[idx.astype(jnp.int32)]
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def packed_codebook_matmul_ref(x: jax.Array, pidx: jax.Array,
                               codebook: jax.Array):
    """Reference for kernels.codebook_matmul_packed: unpack the uint32 word
    operand (``compression.pack_indices_2d`` layout), then gather + dot.
    Also the CPU serving path — the unpack is an in-jit temporary, so the
    HBM-resident operand stays bit-packed here too."""
    from repro.core.compression import unpack_indices_2d

    idx = unpack_indices_2d(pidx, x.shape[-1], codebook.shape[0])
    return codebook_matmul_ref(x, idx, codebook)


def packed_codebook_matmul_t_ref(x: jax.Array, pidx: jax.Array,
                                 codebook: jax.Array, n_out: int,
                                 order: str = "kd"):
    """Reference for kernels.codebook_matmul_packed_t: unpack the word
    operand (either orientation), gather, then the transposed dot."""
    from repro.core.compression import unpack_indices_2d, unpack_rows

    if order == "row":
        idx = unpack_rows(pidx, x.shape[-1], codebook.shape[0])   # [V, D]
    else:
        idx = unpack_indices_2d(pidx, n_out, codebook.shape[0])   # [V, D]
    w = codebook.astype(jnp.float32)[idx]
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)


def quantized_gather_ref(tokens: jax.Array, pidx: jax.Array,
                         codebook: jax.Array, d: int):
    """Reference for kernels.quantized_gather: gather the packed word row,
    unpack its lanes, LUT through the codebook — a pure gather, so it is
    bit-exact vs the kernel and vs the dense-table row gather."""
    from repro.core.compression import unpack_rows

    words = pidx[tokens.astype(jnp.int32)]           # [..., ⌈d/lanes⌉]
    idx = unpack_rows(words, d, codebook.shape[0])
    return codebook[idx]


def fixed_quant_ref(w: jax.Array, mode: str, pow2_c: int = 4,
                    scale: float = 1.0):
    """Reference for kernels.fixed_quant via repro.core.quant_ops."""
    ws = w.astype(jnp.float32) / scale
    if mode == "binary":
        q = quant_ops.binarize(ws)
    elif mode == "ternary":
        q = quant_ops.ternarize(ws)
    else:
        q = quant_ops.pow2_quantize(ws, pow2_c)
    return (q * scale).astype(w.dtype)


# ---------------------------------------------------------------------------
# Paged-attention decode family (engine KV path).
#
# These are verbatim moves of the jnp math that used to live inline in
# ``models.attention`` (``_gather_slots`` / ``_slot_attention`` / the MLA
# absorbed-decode einsums) — models now reaches them through
# ``kernels.dispatch`` so the Pallas route and this CPU route share one
# call site.  The einsum strings / dtypes / op order must not change:
# the engine's bit-exact streams and the golden fixtures pin them.

NEG_INF = -1e30


def _softcap(x, cap):
    # local twin of models.layers.softcap — kernels must not import models
    if cap is None:
        return x
    return (cap * jnp.tanh(x / cap)).astype(x.dtype)


def gather_pages_ref(pool: jax.Array, page_table: jax.Array,
                     alive: jax.Array) -> jax.Array:
    """[P+1, page, ...] pool → per-slot logical view [B, max_pages·page, ...].

    Dead slots' table rows are masked to the trash page (page 0) *before*
    the gather, so a stalled/empty slot contributes one repeated page to
    the gather footprint instead of max_pages arbitrary live pages.
    """
    b, npg = page_table.shape
    table = jnp.where(alive[:, None], page_table, 0)
    g = pool[table]                            # [B, max_pages, page, ...]
    return g.reshape((b, npg * pool.shape[1]) + pool.shape[2:])


def _paged_softmax_gqa(q, ck, cv, valid, *, softcap, scale):
    """q [B,1,H,hd]; ck/cv [B,cap,KV,hd]; valid [B,cap] → [B,1,H·hd]."""
    b, _, h, hd = q.shape
    kv = ck.shape[2]
    rep = h // kv
    qg = q.reshape(b, 1, kv, rep, hd)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, softcap)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bkrqd", attn.astype(cv.dtype), cv)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, h * hd)


def paged_attention_ref(q, k_pool, v_pool, page_table, pos, alive, *,
                        softcap=None, scale):
    """Dense-KV paged GQA decode: gather through the page table, mask,
    softmax-attend.  q [B,1,H,hd]; pools [P+1, page, KV, hd]."""
    gk = gather_pages_ref(k_pool, page_table, alive)
    gv = gather_pages_ref(v_pool, page_table, alive)
    cap = gk.shape[1]
    valid = (jnp.arange(cap)[None, :] <= pos[:, None]) & alive[:, None]
    return _paged_softmax_gqa(q, gk, gv, valid, softcap=softcap, scale=scale)


def dequant_pages_ref(words, cbs, page_table, alive, d: int, bits: int):
    """Gather + dequantize quantized pages to the dense logical view.

    words [P+1, page, ..., Wd] uint32 (pack_rows layout over the trailing
    feature axis); cbs [P+1, Gcb, K] with Gcb ∈ {1, group-axis size};
    returns [B, max_pages·page, ..., d] in the codebook dtype.
    """
    from repro.core.compression import unpack_rows

    b, npg = page_table.shape
    table = jnp.where(alive[:, None], page_table, 0)
    w = words[table]                           # [B, npg, page, ..., Wd]
    idx = unpack_rows(w, d, 1 << bits)         # [B, npg, page, ..., d]
    cb = cbs[table]                            # [B, npg, Gcb, K]
    # broadcast the per-page codebooks over the page axis (and over the
    # group axis when Gcb == 1 — the "page" grouping mode)
    cb = cb.reshape(cb.shape[:2] + (1,) * (idx.ndim - cb.ndim)
                    + cb.shape[2:])
    cb_b = jnp.broadcast_to(cb, idx.shape[:-1] + cb.shape[-1:])
    vals = jnp.take_along_axis(cb_b, idx, axis=-1)
    return vals.reshape((b, npg * words.shape[1]) + vals.shape[3:])


def paged_attention_quant_ref(q, k_words, v_words, k_cb, v_cb, page_table,
                              pos, alive, *, bits, head_dim,
                              softcap=None, scale):
    """Quantized-KV paged GQA decode: the gathered pages dequantize
    through their stored per-page codebooks, then the attention math is
    identical to the dense route (so at matching dequantized values the
    two are bit-exact)."""
    gk = dequant_pages_ref(k_words, k_cb, page_table, alive, head_dim, bits)
    gv = dequant_pages_ref(v_words, v_cb, page_table, alive, head_dim, bits)
    cap = gk.shape[1]
    valid = (jnp.arange(cap)[None, :] <= pos[:, None]) & alive[:, None]
    return _paged_softmax_gqa(q, gk, gv, valid, softcap=softcap, scale=scale)


def _paged_softmax_mla(q_eff, q_rope, gkv, grope, valid, *, scale):
    """q_eff [B,1,H,l]; q_rope [B,1,H,r]; gkv [B,cap,l]; grope [B,cap,r]."""
    logits = (jnp.einsum("bqhl,bsl->bhqs", q_eff, gkv) +
              jnp.einsum("bqhd,bsd->bhqs", q_rope, grope))
    logits = logits.astype(jnp.float32) * scale
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqs,bsl->bqhl", attn.astype(gkv.dtype), gkv)


def mla_paged_attention_ref(q_eff, q_rope, c_pool, r_pool, page_table, pos,
                            alive, *, scale):
    """Dense absorbed-MLA paged decode → latent context [B,1,H,kv_lora]."""
    gkv = gather_pages_ref(c_pool, page_table, alive)
    grope = gather_pages_ref(r_pool, page_table, alive)
    cap = gkv.shape[1]
    valid = (jnp.arange(cap)[None, :] <= pos[:, None]) & alive[:, None]
    return _paged_softmax_mla(q_eff, q_rope, gkv, grope, valid, scale=scale)


def mla_paged_attention_quant_ref(q_eff, q_rope, c_words, r_words, c_cb,
                                  r_cb, page_table, pos, alive, *, bits,
                                  kv_lora, rope_dim, scale):
    """Quantized absorbed-MLA paged decode (per-page codebooks)."""
    gkv = dequant_pages_ref(c_words, c_cb, page_table, alive, kv_lora, bits)
    grope = dequant_pages_ref(r_words, r_cb, page_table, alive, rope_dim,
                              bits)
    cap = gkv.shape[1]
    valid = (jnp.arange(cap)[None, :] <= pos[:, None]) & alive[:, None]
    return _paged_softmax_mla(q_eff, q_rope, gkv, grope, valid, scale=scale)


# ---------------------------------------------------------------------------
# Blockwise prefill family (chunked-prompt path, PR 9).
#
# One prompt chunk of C query tokens attends over a stored K/V view of S
# rows (paged-pool gather on the engine side, the growing prefill buffer
# on the one-shot oracle side) with an online-softmax recurrence over
# ``token_tile``-row K/V tiles — the flash-style accumulation the Pallas
# kernel (``kernels.blockwise_prefill``) implements tile-for-tile, so the
# two agree and per-tile VMEM is flat in S.
#
# Positions are 1-D (shared across the batch): q_pos [C], k_pos [S].  A
# view row is visible to query q iff ``k_pos <= q_pos`` (and inside the
# sliding window when set) — invalid rows (future positions, another
# slot's ring leftovers, tile padding carrying the POS_SENTINEL) carry
# finite garbage values that are masked to exact +0 probability, so a
# tile of entirely-invalid rows is a bitwise no-op in the recurrence.
# That property is what makes engine-vs-oracle streams bit-equal: both
# sides see identical tiles over the *valid* prefix and arbitrarily many
# masked tails.

POS_SENTINEL = 1 << 30          # k_pos value that is never visible


def blockwise_prefill_ref(q, k, v, q_pos, k_pos, *, window=None,
                          softcap=None, scale, token_tile):
    """q [B,C,H,hd]; k [B,S,KV,hd]; v [B,S,KV,vd]; q_pos [C]; k_pos [S]
    int32, with S a multiple of ``token_tile`` (the dispatch route pads
    with sentinel-position rows).  Returns [B,C,H,vd] f32."""
    b, c, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    assert s % token_tile == 0, (s, token_tile)
    nt = s // token_tile
    rep = h // kv
    qg = q.astype(jnp.float32).reshape(b, c, kv, rep, hd)
    kt = k.reshape(b, nt, token_tile, kv, hd).transpose(1, 0, 2, 3, 4)
    vt = v.reshape(b, nt, token_tile, kv, vd).transpose(1, 0, 2, 3, 4)
    pt = k_pos.reshape(nt, token_tile)

    def tile_step(carry, xs):
        m, l, acc = carry
        ki, vi, kpos = xs
        logits = jnp.einsum("bqkrd,bskd->bkrqs", qg,
                            ki.astype(jnp.float32),
                            preferred_element_type=jnp.float32) * scale
        logits = _softcap(logits, softcap)
        ok = kpos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= (q_pos[:, None] - kpos[None, :]) < window
        ok = ok[None, None, None, :, :]              # [1,1,1,C,T]
        logits = jnp.where(ok, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.where(ok, jnp.exp(logits - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkrqs,bskd->bkrqd", p, vi.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, rep, c), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, rep, c), jnp.float32)
    a0 = jnp.zeros((b, kv, rep, c, vd), jnp.float32)
    (_, l, acc), _ = jax.lax.scan(tile_step, (m0, l0, a0), (kt, vt, pt))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, c, h, vd)


def dequant_view_ref(words, cbs, d: int, bits: int, page_size: int):
    """Dequantize a *pre-gathered* quantized-page view.

    words [B, S, ..., Wd] uint32 (S = n_pages·page_size, rows in logical
    order); cbs [B, n_pages, Gcb, K] per-page codebooks.  Same unpack +
    per-page broadcast + take as :func:`dequant_pages_ref` (which gathers
    from the physical pool itself), so values are bit-identical to the
    decode path's view.
    """
    from repro.core.compression import unpack_rows

    b, s = words.shape[:2]
    npg = cbs.shape[1]
    idx = unpack_rows(words, d, 1 << bits)         # [B, S, ..., d]
    idx = idx.reshape((b, npg, page_size) + idx.shape[2:])
    cb = cbs.reshape(cbs.shape[:2] + (1,) * (idx.ndim - cbs.ndim)
                     + cbs.shape[2:])
    cb_b = jnp.broadcast_to(cb, idx.shape[:-1] + cb.shape[-1:])
    vals = jnp.take_along_axis(cb_b, idx, axis=-1)
    return vals.reshape((b, s) + vals.shape[3:])


def blockwise_prefill_quant_ref(q, k_words, v_words, k_cb, v_cb, q_pos,
                                k_pos, *, page_size, bits, head_dim,
                                window=None, softcap=None, scale,
                                token_tile):
    """Blockwise prefill over a quantized-page K/V view: dequantize the
    gathered words through their per-page codebooks, then the identical
    dense recurrence (the Pallas kernel dequantizes tile-by-tile in VMEM
    instead — a pure gather, so values match)."""
    gk = dequant_view_ref(k_words, k_cb, head_dim, bits, page_size)
    gv = dequant_view_ref(v_words, v_cb, head_dim, bits, page_size)
    return blockwise_prefill_ref(q, gk, gv, q_pos, k_pos, window=window,
                                 softcap=softcap, scale=scale,
                                 token_tile=token_tile)
