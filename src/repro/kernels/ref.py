"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant_ops


def kmeans_assign_ref(w: jax.Array, codebook: jax.Array):
    """Reference for kernels.kmeans_assign: brute-force argmin + segment sums.

    Note: argmin tie-breaking (lowest index) matches the kernel.
    """
    flat = w.reshape(-1).astype(jnp.float32)
    c = codebook.astype(jnp.float32)
    d = (flat[:, None] - c[None, :]) ** 2
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    sums = jax.ops.segment_sum(flat, assign, num_segments=c.shape[0])
    counts = jax.ops.segment_sum(jnp.ones_like(flat), assign,
                                 num_segments=c.shape[0])
    return assign, sums, counts


def codebook_matmul_ref(x: jax.Array, idx: jax.Array, codebook: jax.Array):
    """Reference for kernels.codebook_matmul: dequantize fully, then dot."""
    w = codebook.astype(jnp.float32)[idx.astype(jnp.int32)]
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def packed_codebook_matmul_ref(x: jax.Array, pidx: jax.Array,
                               codebook: jax.Array):
    """Reference for kernels.codebook_matmul_packed: unpack the uint32 word
    operand (``compression.pack_indices_2d`` layout), then gather + dot.
    Also the CPU serving path — the unpack is an in-jit temporary, so the
    HBM-resident operand stays bit-packed here too."""
    from repro.core.compression import unpack_indices_2d

    idx = unpack_indices_2d(pidx, x.shape[-1], codebook.shape[0])
    return codebook_matmul_ref(x, idx, codebook)


def packed_codebook_matmul_t_ref(x: jax.Array, pidx: jax.Array,
                                 codebook: jax.Array, n_out: int,
                                 order: str = "kd"):
    """Reference for kernels.codebook_matmul_packed_t: unpack the word
    operand (either orientation), gather, then the transposed dot."""
    from repro.core.compression import unpack_indices_2d, unpack_rows

    if order == "row":
        idx = unpack_rows(pidx, x.shape[-1], codebook.shape[0])   # [V, D]
    else:
        idx = unpack_indices_2d(pidx, n_out, codebook.shape[0])   # [V, D]
    w = codebook.astype(jnp.float32)[idx]
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)


def quantized_gather_ref(tokens: jax.Array, pidx: jax.Array,
                         codebook: jax.Array, d: int):
    """Reference for kernels.quantized_gather: gather the packed word row,
    unpack its lanes, LUT through the codebook — a pure gather, so it is
    bit-exact vs the kernel and vs the dense-table row gather."""
    from repro.core.compression import unpack_rows

    words = pidx[tokens.astype(jnp.int32)]           # [..., ⌈d/lanes⌉]
    idx = unpack_rows(words, d, codebook.shape[0])
    return codebook[idx]


def fixed_quant_ref(w: jax.Array, mode: str, pow2_c: int = 4,
                    scale: float = 1.0):
    """Reference for kernels.fixed_quant via repro.core.quant_ops."""
    ws = w.astype(jnp.float32) / scale
    if mode == "binary":
        q = quant_ops.binarize(ws)
    elif mode == "ternary":
        q = quant_ops.ternarize(ws)
    else:
        q = quant_ops.pow2_quantize(ws, pow2_c)
    return (q * scale).astype(w.dtype)


# ---------------------------------------------------------------------------
# Paged-attention decode family (engine KV path).
#
# These are verbatim moves of the jnp math that used to live inline in
# ``models.attention`` (``_gather_slots`` / ``_slot_attention`` / the MLA
# absorbed-decode einsums) — models now reaches them through
# ``kernels.dispatch`` so the Pallas route and this CPU route share one
# call site.  The einsum strings / dtypes / op order must not change:
# the engine's bit-exact streams and the golden fixtures pin them.

NEG_INF = -1e30


def _softcap(x, cap):
    # local twin of models.layers.softcap — kernels must not import models
    if cap is None:
        return x
    return (cap * jnp.tanh(x / cap)).astype(x.dtype)


def gather_pages_ref(pool: jax.Array, page_table: jax.Array,
                     alive: jax.Array) -> jax.Array:
    """[P+1, page, ...] pool → per-slot logical view [B, max_pages·page, ...].

    Dead slots' table rows are masked to the trash page (page 0) *before*
    the gather, so a stalled/empty slot contributes one repeated page to
    the gather footprint instead of max_pages arbitrary live pages.
    """
    b, npg = page_table.shape
    table = jnp.where(alive[:, None], page_table, 0)
    g = pool[table]                            # [B, max_pages, page, ...]
    return g.reshape((b, npg * pool.shape[1]) + pool.shape[2:])


def _paged_softmax_gqa(q, ck, cv, valid, *, softcap, scale):
    """q [B,1,H,hd]; ck/cv [B,cap,KV,hd]; valid [B,cap] → [B,1,H·hd]."""
    b, _, h, hd = q.shape
    kv = ck.shape[2]
    rep = h // kv
    qg = q.reshape(b, 1, kv, rep, hd)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, softcap)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bkrqd", attn.astype(cv.dtype), cv)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, h * hd)


def paged_attention_ref(q, k_pool, v_pool, page_table, pos, alive, *,
                        softcap=None, scale):
    """Dense-KV paged GQA decode: gather through the page table, mask,
    softmax-attend.  q [B,1,H,hd]; pools [P+1, page, KV, hd]."""
    gk = gather_pages_ref(k_pool, page_table, alive)
    gv = gather_pages_ref(v_pool, page_table, alive)
    cap = gk.shape[1]
    valid = (jnp.arange(cap)[None, :] <= pos[:, None]) & alive[:, None]
    return _paged_softmax_gqa(q, gk, gv, valid, softcap=softcap, scale=scale)


def dequant_pages_ref(words, cbs, page_table, alive, d: int, bits: int):
    """Gather + dequantize quantized pages to the dense logical view.

    words [P+1, page, ..., Wd] uint32 (pack_rows layout over the trailing
    feature axis); cbs [P+1, Gcb, K] with Gcb ∈ {1, group-axis size};
    returns [B, max_pages·page, ..., d] in the codebook dtype.
    """
    from repro.core.compression import unpack_rows

    b, npg = page_table.shape
    table = jnp.where(alive[:, None], page_table, 0)
    w = words[table]                           # [B, npg, page, ..., Wd]
    idx = unpack_rows(w, d, 1 << bits)         # [B, npg, page, ..., d]
    cb = cbs[table]                            # [B, npg, Gcb, K]
    # broadcast the per-page codebooks over the page axis (and over the
    # group axis when Gcb == 1 — the "page" grouping mode)
    cb = cb.reshape(cb.shape[:2] + (1,) * (idx.ndim - cb.ndim)
                    + cb.shape[2:])
    cb_b = jnp.broadcast_to(cb, idx.shape[:-1] + cb.shape[-1:])
    vals = jnp.take_along_axis(cb_b, idx, axis=-1)
    return vals.reshape((b, npg * words.shape[1]) + vals.shape[3:])


def paged_attention_quant_ref(q, k_words, v_words, k_cb, v_cb, page_table,
                              pos, alive, *, bits, head_dim,
                              softcap=None, scale):
    """Quantized-KV paged GQA decode: the gathered pages dequantize
    through their stored per-page codebooks, then the attention math is
    identical to the dense route (so at matching dequantized values the
    two are bit-exact)."""
    gk = dequant_pages_ref(k_words, k_cb, page_table, alive, head_dim, bits)
    gv = dequant_pages_ref(v_words, v_cb, page_table, alive, head_dim, bits)
    cap = gk.shape[1]
    valid = (jnp.arange(cap)[None, :] <= pos[:, None]) & alive[:, None]
    return _paged_softmax_gqa(q, gk, gv, valid, softcap=softcap, scale=scale)


def _paged_softmax_mla(q_eff, q_rope, gkv, grope, valid, *, scale):
    """q_eff [B,1,H,l]; q_rope [B,1,H,r]; gkv [B,cap,l]; grope [B,cap,r]."""
    logits = (jnp.einsum("bqhl,bsl->bhqs", q_eff, gkv) +
              jnp.einsum("bqhd,bsd->bhqs", q_rope, grope))
    logits = logits.astype(jnp.float32) * scale
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqs,bsl->bqhl", attn.astype(gkv.dtype), gkv)


def mla_paged_attention_ref(q_eff, q_rope, c_pool, r_pool, page_table, pos,
                            alive, *, scale):
    """Dense absorbed-MLA paged decode → latent context [B,1,H,kv_lora]."""
    gkv = gather_pages_ref(c_pool, page_table, alive)
    grope = gather_pages_ref(r_pool, page_table, alive)
    cap = gkv.shape[1]
    valid = (jnp.arange(cap)[None, :] <= pos[:, None]) & alive[:, None]
    return _paged_softmax_mla(q_eff, q_rope, gkv, grope, valid, scale=scale)


def mla_paged_attention_quant_ref(q_eff, q_rope, c_words, r_words, c_cb,
                                  r_cb, page_table, pos, alive, *, bits,
                                  kv_lora, rope_dim, scale):
    """Quantized absorbed-MLA paged decode (per-page codebooks)."""
    gkv = dequant_pages_ref(c_words, c_cb, page_table, alive, kv_lora, bits)
    grope = dequant_pages_ref(r_words, r_cb, page_table, alive, rope_dim,
                              bits)
    cap = gkv.shape[1]
    valid = (jnp.arange(cap)[None, :] <= pos[:, None]) & alive[:, None]
    return _paged_softmax_mla(q_eff, q_rope, gkv, grope, valid, scale=scale)
