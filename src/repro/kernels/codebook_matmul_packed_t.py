"""Pallas TPU kernel: *transposed* codebook matmul over bit-packed indices
— the fused tied-embedding LM-head route (an untied head is stored
[D, V] and already serves through the forward packed kernel).

y[M, V] = x[M, D] · W.T where W [V, D] is stored bit-packed.  Before this
kernel the tied LM head was dequant-then-dot: the full bf16/f32 embedding
matrix was materialized every decode step.  Here the packed words are the
HBM-resident operand end to end — each grid step DMAs one word tile into
VMEM, unpacks it with a shift+mask (``kernels.unpack``), LUT-dequantizes,
and feeds the MXU with a transposed contraction (``dot_general`` over the
D axis) — exactly ``bits_per_index(K)/8`` bytes/weight of index traffic,
same as the forward packed kernel.

Two word layouts are accepted (``order``):

* ``"kd"``  — ``pack_indices_2d`` over the leaf's own (V, D) view:
  ``pidx[⌈V/lanes⌉, D]``; word (w, d) holds rows w·lanes+l of column d.
  V is the *output* axis here, so ``bn`` must be a multiple of ``lanes``.
* ``"row"`` — ``pack_rows``: ``pidx[V, ⌈D/lanes⌉]``; word (v, w) holds
  columns w·lanes+l of row v.  This is the serving layout for embedding
  tables (shared with the fused gather kernel), packing the *reduction*
  axis: ``bk`` must be a multiple of ``lanes``.

Grid: (M/bm, V/bn, D/bk), k innermost; f32 accumulation in the revisited
output block (sequential TPU grid ⇒ safe).  Padding is benign: padded x
columns are zero so garbage weights decoded from padded words contribute
0; padded V rows are sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compression import bits_per_index
from repro.kernels.unpack import (dequant_tile, unpack_words_axis0,
                                  unpack_words_axis1)


def _kernel(x_ref, pidx_ref, cb_ref, o_ref, *, k_entries: int, bits: int,
            order: str, dequant: str):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                    # [bm, bk]
    words = pidx_ref[...]                             # see below
    cb = cb_ref[0, :]                                 # [K]

    if order == "kd":
        # words [bnw, bk]: lanes expand along the V (output) axis.
        idx = unpack_words_axis0(words, bits)         # [bn, bk]
    else:
        # words [bn, bkw]: lanes expand along the D (reduction) axis.
        idx = unpack_words_axis1(words, bits)         # [bn, bk]
    w = dequant_tile(idx, cb, k_entries, dequant)     # [bn, bk]
    # y[bm, bn] += x[bm, bk] · w[bn, bk].T — contract the D axis.
    o_ref[...] += jax.lax.dot_general(
        x.astype(jnp.float32), w.astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def codebook_matmul_packed_t_pallas(
    x: jax.Array,            # [M, D]
    pidx: jax.Array,         # packed indices of W [V, D]; layout per order
    codebook: jax.Array,     # [K] float
    n_out: int,              # V — the true output dim (not derivable from
                             # the padded word rows in the "kd" order)
    *,
    order: str = "kd",
    bm: int = 128, bn: int = 128, bk: int = 512,
    dequant: str = "lut",
    interpret: bool = False,
) -> jax.Array:
    m, d = x.shape
    k_entries = codebook.shape[0]
    bits = bits_per_index(k_entries)
    lanes = 32 // bits
    if dequant not in ("lut", "onehot"):
        raise ValueError(f"dequant={dequant!r}; choose lut|onehot")
    if order not in ("kd", "row"):
        raise ValueError(f"order={order!r}; choose kd|row")

    if order == "kd":
        wv, dcols = pidx.shape
        if (wv, dcols) != (-(-n_out // lanes), d):
            raise ValueError(
                f"pidx {pidx.shape} != (ceil({n_out}/{lanes}), {d}) — "
                f"operand not in pack_indices_2d layout for K={k_entries}")
        if bn % lanes:
            raise ValueError(f"bn={bn} must be a multiple of lanes={lanes} "
                             f"(bits={bits}): V is the word-packed axis")
        # Pad V up to a bn multiple (word rows to bn//lanes), D to bk.
        vp = -(-max(n_out, lanes * wv) // bn) * bn
        dp = -(-d // bk) * bk
        xp = jnp.pad(x, ((0, (-m) % bm), (0, dp - d)))
        pp = jnp.pad(pidx, ((0, vp // lanes - wv), (0, dp - d)))
        pidx_spec = pl.BlockSpec((bn // lanes, bk), lambda i, j, kk: (j, kk))
    else:
        v, wd = pidx.shape
        if (v, wd) != (n_out, -(-d // lanes)):
            raise ValueError(
                f"pidx {pidx.shape} != ({n_out}, ceil({d}/{lanes})) — "
                f"operand not in pack_rows layout for K={k_entries}")
        if bk % lanes:
            raise ValueError(f"bk={bk} must be a multiple of lanes={lanes} "
                             f"(bits={bits}): D is the word-packed axis")
        vp = -(-v // bn) * bn
        dp = -(-max(d, lanes * wd) // bk) * bk
        xp = jnp.pad(x, ((0, (-m) % bm), (0, dp - d)))
        pp = jnp.pad(pidx, ((0, vp - v), (0, dp // lanes - wd)))
        pidx_spec = pl.BlockSpec((bn, bk // lanes), lambda i, j, kk: (j, kk))

    gm, gn, gk = xp.shape[0] // bm, vp // bn, dp // bk
    out = pl.pallas_call(
        functools.partial(_kernel, k_entries=k_entries, bits=bits,
                          order=order, dequant=dequant),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pidx_spec,
            pl.BlockSpec((1, k_entries), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], vp), jnp.float32),
        interpret=interpret,
    )(xp, pp, codebook.reshape(1, -1))
    return out[:m, :n_out].astype(x.dtype)
