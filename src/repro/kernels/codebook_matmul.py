"""Pallas TPU kernel: codebook-dequant matmul for quantized serving.

y[M, N] = x[M, Kd] · W  where W is stored as uint8 codebook indices
idx[Kd, N] plus a K-entry float codebook — the packed format emitted by
``repro.core.compression``.  The quantized weights are **never
materialized in HBM at float width**: each grid step dequantizes one
[bk, bn] index tile inside VMEM and feeds the MXU.

This is the memory-roofline payoff of quantization at serve time: HBM
weight traffic per step drops from 2 bytes/weight (bf16) to 1 byte
(uint8 idx; 4-bit packing halves it again — see ops.py), which directly
scales the decode-shape memory term (§Roofline).

Dequant strategy: a K-entry LUT gather ``cb[idx]`` (``dequant="lut"``, the
default) — O(bk·bn) independent of K, so a K=256 adaptive codebook costs
the same per tile as K=4.  ``dequant="onehot"`` keeps the original
MXU-shaped one-hot contraction ``W_tile = onehot(idx) @ codebook``
(O(bk·bn·K)) as a fallback for Mosaic versions that lower small-table
gathers poorly (flip globally via ``REPRO_DEQUANT=onehot`` — see
dispatch.py).

Grid: (M/bm, N/bn, Kd/bk), k innermost; f32 accumulation directly in the
revisited output block (sequential TPU grid ⇒ safe).

For the bit-packed index operand (the end-to-end serve path — bits/8
bytes/weight instead of this kernel's 1 byte/weight uint8 indices) see
``codebook_matmul_packed.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.unpack import dequant_tile


def _kernel(x_ref, idx_ref, cb_ref, o_ref, *, k_entries: int, bk: int,
            bn: int, dequant: str):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                    # [bm, bk]
    idx = idx_ref[...].astype(jnp.int32)              # [bk, bn] uint8/int32
    cb = cb_ref[0, :]                                 # [K]

    w = dequant_tile(idx, cb, k_entries, dequant)    # [bk, bn]
    o_ref[...] += jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                          preferred_element_type=jnp.float32)


def codebook_matmul_pallas(
    x: jax.Array,            # [M, Kd]
    idx: jax.Array,          # [Kd, N] integer codebook indices
    codebook: jax.Array,     # [K] float
    *,
    bm: int = 128, bn: int = 128, bk: int = 512,
    dequant: str = "lut",
    interpret: bool = False,
) -> jax.Array:
    m, kd = x.shape
    kd2, n = idx.shape
    assert kd == kd2, (kd, kd2)
    k_entries = codebook.shape[0]

    pm, pn, pk = (-m) % bm, (-n) % bn, (-kd) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    ip = jnp.pad(idx, ((0, pk), (0, pn)))
    gm, gn, gk = xp.shape[0] // bm, ip.shape[1] // bn, xp.shape[1] // bk

    if dequant not in ("lut", "onehot"):
        raise ValueError(f"dequant={dequant!r}; choose lut|onehot")
    out = pl.pallas_call(
        functools.partial(_kernel, k_entries=k_entries, bk=bk, bn=bn,
                          dequant=dequant),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, k_entries), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], ip.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, ip, codebook.reshape(1, -1))
    return out[:m, :n].astype(x.dtype)
