"""Pallas kernel layer: compute hot-spots of quantized training/serving.

``ops`` holds the jit'd public wrappers, ``ref`` the pure-jnp oracles,
``dispatch`` the backend router (Mosaic on TPU / reference on CPU) the
serving path calls into.
"""
from repro.kernels import dispatch, ops, ref  # noqa: F401
