"""Pallas TPU kernel: fused 1-D k-means assignment + per-centroid stats.

The C step's hot loop is, for every weight shard, one pass of
  assign_i = argmin_k (w_i - c_k)²;  sums_k = Σ_{i∈k} w_i;  counts_k = |k|.

TPU adaptation (DESIGN §4.1): no scatter/atomics — each grid step loads a
[1, TILE] weight tile into VMEM, forms the [TILE, K] distance matrix
(K ≤ 256 ⇒ ≤ 1 MiB fp32, comfortably VMEM-resident), reduces it to
one-hot partial sums with a VPU reduction, and accumulates into the [1, K]
output block that every grid step maps to (TPU grids are sequential ⇒
deterministic accumulation, initialized at step 0 via ``pl.when``).

Tail handling without scalar plumbing: the wrapper zero-pads P to a TILE
multiple; padded lanes deterministically assign to the centroid nearest 0,
so the wrapper subtracts ``pad`` from that centroid's count (their weight
contribution is exactly 0).  Assignments for padded lanes are sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024        # 8 sublanes × 128 lanes


def _kernel(w_ref, c_ref, assign_ref, sums_ref, counts_ref, *, k: int):
    i = pl.program_id(0)
    w = w_ref[0, :]                                   # [TILE]
    c = c_ref[0, :]                                   # [K]
    d = w[:, None] - c[None, :]
    d = d * d                                         # [TILE, K]
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)  # [TILE]
    assign_ref[0, :] = assign

    onehot = (assign[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (TILE, k), 1)
              ).astype(jnp.float32)                   # [TILE, K]
    part_sums = jnp.sum(onehot * w[:, None].astype(jnp.float32), axis=0)
    part_counts = jnp.sum(onehot, axis=0)

    @pl.when(i == 0)
    def _init():
        sums_ref[0, :] = jnp.zeros((k,), jnp.float32)
        counts_ref[0, :] = jnp.zeros((k,), jnp.float32)

    sums_ref[0, :] += part_sums
    counts_ref[0, :] += part_counts


def kmeans_assign_pallas(w: jax.Array, codebook: jax.Array,
                         interpret: bool = False):
    """w: [P] float; codebook: [K] float (need not be sorted).

    Returns (assign [P] int32, sums [K] f32, counts [K] f32): per-centroid
    Σw and cardinality — exactly the inputs of the k-means centroid step
    (and of the distributed psum variant in repro/dist/cstep.py).
    """
    p = w.shape[0]
    k = codebook.shape[0]
    pad = (-p) % TILE
    wp = jnp.pad(w.astype(jnp.float32), (0, pad)).reshape(-1, TILE)
    tiles = wp.shape[0]
    cb = codebook.astype(jnp.float32)

    assign, sums, counts = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles, TILE), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
        ],
        interpret=interpret,
    )(wp, cb.reshape(1, k))

    sums, counts = sums[0], counts[0]
    if pad:
        # padded zeros land on the centroid nearest 0 — undo their counts
        zero_idx = jnp.argmin(cb * cb)
        counts = counts.at[zero_idx].add(-float(pad))
    return assign.reshape(-1)[:p], sums, counts
