"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the
kernel body executes in Python for correctness validation; on TPU the same
call sites compile to Mosaic.  ``interpret=None`` → auto-detect backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.blockwise_prefill import (
    blockwise_prefill_pallas, blockwise_prefill_quant_pallas)
from repro.kernels.codebook_matmul import codebook_matmul_pallas
from repro.kernels.codebook_matmul_packed import codebook_matmul_packed_pallas
from repro.kernels.codebook_matmul_packed_t import (
    codebook_matmul_packed_t_pallas)
from repro.kernels.fixed_quant import fixed_quant_pallas
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.kernels.paged_attention import (
    mla_paged_attention_pallas, mla_paged_attention_quant_pallas,
    page_gather_pallas, paged_attention_pallas,
    paged_attention_quant_pallas)
from repro.kernels.quantized_gather import quantized_gather_pallas


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("interpret",))
def _kmeans_assign_jit(w, codebook, interpret):
    return kmeans_assign_pallas(w, codebook, interpret=interpret)


def kmeans_assign(w: jax.Array, codebook: jax.Array,
                  interpret: Optional[bool] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused assignment + per-centroid (Σw, count). See kmeans_assign.py."""
    return _kmeans_assign_jit(w.reshape(-1), codebook,
                              _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "dequant", "interpret"))
def _codebook_matmul_jit(x, idx, codebook, bm, bn, bk, dequant, interpret):
    return codebook_matmul_pallas(x, idx, codebook, bm=bm, bn=bn, bk=bk,
                                  dequant=dequant, interpret=interpret)


def codebook_matmul(x: jax.Array, idx: jax.Array, codebook: jax.Array,
                    *, bm: int = 128, bn: int = 128, bk: int = 512,
                    dequant: str = "lut",
                    interpret: Optional[bool] = None) -> jax.Array:
    """y = x · codebook[idx] without materializing float weights in HBM."""
    return _codebook_matmul_jit(x, idx, codebook, bm, bn, bk, dequant,
                                _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "dequant", "interpret"))
def _packed_codebook_matmul_jit(x, pidx, codebook, bm, bn, bk, dequant,
                                interpret):
    return codebook_matmul_packed_pallas(x, pidx, codebook, bm=bm, bn=bn,
                                         bk=bk, dequant=dequant,
                                         interpret=interpret)


def packed_codebook_matmul(x: jax.Array, pidx: jax.Array,
                           codebook: jax.Array, *, bm: int = 128,
                           bn: int = 128, bk: int = 512,
                           dequant: str = "lut",
                           interpret: Optional[bool] = None) -> jax.Array:
    """y = x · codebook[unpack(pidx)] with the ``pack_indices_2d`` uint32
    word operand HBM-resident: bits_per_index(K)/8 bytes/weight of index
    traffic (see codebook_matmul_packed.py)."""
    return _packed_codebook_matmul_jit(x, pidx, codebook, bm, bn, bk,
                                       dequant, _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("n_out", "order", "bm", "bn", "bk",
                                    "dequant", "interpret"))
def _packed_codebook_matmul_t_jit(x, pidx, codebook, n_out, order, bm, bn,
                                  bk, dequant, interpret):
    return codebook_matmul_packed_t_pallas(x, pidx, codebook, n_out,
                                           order=order, bm=bm, bn=bn, bk=bk,
                                           dequant=dequant,
                                           interpret=interpret)


def packed_codebook_matmul_t(x: jax.Array, pidx: jax.Array,
                             codebook: jax.Array, n_out: int, *,
                             order: str = "kd", bm: int = 128,
                             bn: int = 128, bk: int = 512,
                             dequant: str = "lut",
                             interpret: Optional[bool] = None) -> jax.Array:
    """y = x · codebook[unpack(pidx)].T — the fused transposed (LM-head)
    route; the packed word operand stays HBM-resident (see
    codebook_matmul_packed_t.py)."""
    return _packed_codebook_matmul_t_jit(x, pidx, codebook, n_out, order,
                                         bm, bn, bk, dequant,
                                         _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("d", "dequant", "interpret"))
def _quantized_gather_jit(tokens, pidx, codebook, d, dequant, interpret):
    return quantized_gather_pallas(tokens, pidx, codebook, d,
                                   dequant=dequant, interpret=interpret)


def quantized_gather(tokens: jax.Array, pidx: jax.Array,
                     codebook: jax.Array, d: int, *,
                     dequant: str = "lut",
                     interpret: Optional[bool] = None) -> jax.Array:
    """rows = codebook[unpack(pidx[tokens])] — Mosaic dequant-on-gather
    over the pack_rows embedding layout: ``bits_per_index(K)/8`` HBM bytes
    per gathered weight (see quantized_gather.py)."""
    return _quantized_gather_jit(tokens, pidx, codebook, d, dequant,
                                 _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("softcap", "scale", "token_tile",
                                    "interpret"))
def _paged_attention_jit(q, k_pool, v_pool, page_table, pos, alive, softcap,
                         scale, token_tile, interpret):
    return paged_attention_pallas(q, k_pool, v_pool, page_table, pos, alive,
                                  softcap=softcap, scale=scale,
                                  token_tile=token_tile, interpret=interpret)


def paged_attention(q, k_pool, v_pool, page_table, pos, alive, *,
                    softcap=None, scale, token_tile=None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Fused page-gather + online-softmax GQA decode (paged_attention.py)."""
    return _paged_attention_jit(q, k_pool, v_pool, page_table, pos, alive,
                                softcap, scale, token_tile,
                                _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("bits", "head_dim", "softcap", "scale",
                                    "token_tile", "dequant", "interpret"))
def _paged_attention_quant_jit(q, k_words, v_words, k_cb, v_cb, page_table,
                               pos, alive, bits, head_dim, softcap, scale,
                               token_tile, dequant, interpret):
    return paged_attention_quant_pallas(
        q, k_words, v_words, k_cb, v_cb, page_table, pos, alive, bits=bits,
        head_dim=head_dim, softcap=softcap, scale=scale,
        token_tile=token_tile, dequant=dequant, interpret=interpret)


def paged_attention_quant(q, k_words, v_words, k_cb, v_cb, page_table, pos,
                          alive, *, bits, head_dim, softcap=None, scale,
                          token_tile=None, dequant: str = "lut",
                          interpret: Optional[bool] = None) -> jax.Array:
    """GQA decode over codebook-quantized KV pages: kv_bits/8 B per cached
    scalar of HBM traffic, dequant in VMEM (paged_attention.py)."""
    return _paged_attention_quant_jit(q, k_words, v_words, k_cb, v_cb,
                                      page_table, pos, alive, bits, head_dim,
                                      softcap, scale, token_tile, dequant,
                                      _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("scale", "token_tile", "interpret"))
def _mla_paged_attention_jit(q_eff, q_rope, c_pool, r_pool, page_table, pos,
                             alive, scale, token_tile, interpret):
    return mla_paged_attention_pallas(q_eff, q_rope, c_pool, r_pool,
                                      page_table, pos, alive, scale=scale,
                                      token_tile=token_tile,
                                      interpret=interpret)


def mla_paged_attention(q_eff, q_rope, c_pool, r_pool, page_table, pos,
                        alive, *, scale, token_tile=None,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Fused absorbed-MLA paged decode → latent context [B,1,H,kv_lora]."""
    return _mla_paged_attention_jit(q_eff, q_rope, c_pool, r_pool,
                                    page_table, pos, alive, scale,
                                    token_tile, _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("bits", "kv_lora", "rope_dim", "scale",
                                    "token_tile", "dequant", "interpret"))
def _mla_paged_attention_quant_jit(q_eff, q_rope, c_words, r_words, c_cb,
                                   r_cb, page_table, pos, alive, bits,
                                   kv_lora, rope_dim, scale, token_tile,
                                   dequant, interpret):
    return mla_paged_attention_quant_pallas(
        q_eff, q_rope, c_words, r_words, c_cb, r_cb, page_table, pos, alive,
        bits=bits, kv_lora=kv_lora, rope_dim=rope_dim, scale=scale,
        token_tile=token_tile, dequant=dequant, interpret=interpret)


def mla_paged_attention_quant(q_eff, q_rope, c_words, r_words, c_cb, r_cb,
                              page_table, pos, alive, *, bits, kv_lora,
                              rope_dim, scale, token_tile=None,
                              dequant: str = "lut",
                              interpret: Optional[bool] = None) -> jax.Array:
    """Absorbed-MLA decode over codebook-quantized latent pages."""
    return _mla_paged_attention_quant_jit(
        q_eff, q_rope, c_words, r_words, c_cb, r_cb, page_table, pos, alive,
        bits, kv_lora, rope_dim, scale, token_tile,
        dequant, _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("window", "softcap", "scale",
                                    "token_tile", "interpret"))
def _blockwise_prefill_jit(q, k, v, q_pos, k_pos, window, softcap, scale,
                           token_tile, interpret):
    return blockwise_prefill_pallas(q, k, v, q_pos, k_pos, window=window,
                                    softcap=softcap, scale=scale,
                                    token_tile=token_tile,
                                    interpret=interpret)


def blockwise_prefill(q, k, v, q_pos, k_pos, *, window=None, softcap=None,
                      scale, token_tile,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Chunked-prompt prefill attention: C new queries vs. an S-row K/V
    view, online-softmax per K/V tile (blockwise_prefill.py)."""
    return _blockwise_prefill_jit(q, k, v, q_pos, k_pos, window, softcap,
                                  scale, token_tile,
                                  _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("page_size", "bits", "head_dim",
                                    "window", "softcap", "scale",
                                    "token_tile", "dequant", "interpret"))
def _blockwise_prefill_quant_jit(q, k_words, v_words, k_cb, v_cb, q_pos,
                                 k_pos, page_size, bits, head_dim, window,
                                 softcap, scale, token_tile, dequant,
                                 interpret):
    return blockwise_prefill_quant_pallas(
        q, k_words, v_words, k_cb, v_cb, q_pos, k_pos, page_size=page_size,
        bits=bits, head_dim=head_dim, window=window, softcap=softcap,
        scale=scale, token_tile=token_tile, dequant=dequant,
        interpret=interpret)


def blockwise_prefill_quant(q, k_words, v_words, k_cb, v_cb, q_pos, k_pos,
                            *, page_size, bits, head_dim, window=None,
                            softcap=None, scale, token_tile,
                            dequant: str = "lut",
                            interpret: Optional[bool] = None) -> jax.Array:
    """Chunked-prompt prefill over codebook-quantized KV pages: kv_bits/8
    B per cached scalar of HBM traffic (blockwise_prefill.py)."""
    return _blockwise_prefill_quant_jit(
        q, k_words, v_words, k_cb, v_cb, q_pos, k_pos, page_size, bits,
        head_dim, window, softcap, scale, token_tile, dequant,
        _auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _page_gather_jit(pool, page_table, alive, interpret):
    return page_gather_pallas(pool, page_table, alive, interpret=interpret)


def page_gather(pool, page_table, alive,
                interpret: Optional[bool] = None) -> jax.Array:
    """Scalar-prefetch page gather: [P+1, page, ...] pool → per-slot
    logical view [B, max_pages·page, ...] (paged_attention.py)."""
    return _page_gather_jit(pool, page_table, alive,
                            _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("mode", "pow2_c", "scale", "interpret"))
def _fixed_quant_jit(w, mode, pow2_c, scale, interpret):
    return fixed_quant_pallas(w, mode, pow2_c=pow2_c, scale=scale,
                              interpret=interpret)


def fixed_quant(w: jax.Array, mode: str, *, pow2_c: int = 4,
                scale: float = 1.0,
                interpret: Optional[bool] = None) -> jax.Array:
    """Tiled fixed-codebook quantizer (binary | ternary | pow2)."""
    return _fixed_quant_jit(w, mode, pow2_c, float(scale),
                            _auto_interpret(interpret))
