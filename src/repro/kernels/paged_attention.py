"""Pallas TPU kernels: paged-attention decode over the engine's KV pages.

One decode step serves B batch slots, each reading its logical KV
stream through a per-slot page table into a pool of fixed-size pages
(page 0 = trash; see ``models.attention``).  The jnp route materializes
the full gathered view ``[B, max_pages·page, ...]`` in HBM; these
kernels never do:

* the page table / per-slot positions / alive mask ride as
  **scalar-prefetch** operands (``pltpu.PrefetchScalarGridSpec`` — the
  ``quantized_gather`` pattern), so the index maps pick the physical
  page of each KV tile and the pages DMA straight into VMEM
  tile-by-tile;
* softmax is **online** (flash-style running max / normalizer in VMEM
  scratch, the ``chunked_attention`` recurrence), so VMEM holds one
  ``token_tile`` of KV at a time regardless of sequence length;
* dead slots' tiles are redirected to the trash page *in the index
  map* — a stalled slot DMAs one repeated page, not ``max_pages``
  arbitrary live ones — and their outputs are fully masked.

The ``*_quant`` variants read **codebook-quantized** pages: uint32
words in the ``pack_rows`` layout plus per-page codebooks
(``core.kvquant``), unpacked shift+mask and LUT-dequantized in VMEM via
``kernels.unpack`` — KV HBM traffic is ``kv_bits/8`` bytes per cached
scalar, the eq.-14 accounting applied to activations.

Grid: ``(B, max_pages · page_size // token_tile)`` — the token axis is
innermost, so the per-slot accumulator scratch carries across KV tiles
and the output block (revisited each step) is written once on the last
tile.  CPU reference route: ``kernels.ref.paged_attention_ref`` family
behind ``dispatch.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kvquant import kv_entries, words_per
from repro.kernels.unpack import dequant_tile, unpack_words_axis1

NEG_INF = -1e30
_EPS = 1e-30


def _softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _page_select(alv, tbl, b, j, tpp):
    """Physical page of KV tile j for slot b; dead slots → trash page."""
    return jnp.where(alv[b] > 0, tbl[b, j // tpp], 0)


def _tile_valid(pos_ref, alive_ref, b, j, bt):
    """[1, bt] bool: token j·bt+t is a live KV entry of slot b."""
    positions = (jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1) + j * bt)
    return (positions <= pos_ref[b]) & (alive_ref[b] > 0)


# ---------------------------------------------------------------------------
# GQA (dense and quantized KV pages)


def _gqa_body(q_ref, k, v, o_ref, m_ref, l_ref, acc_ref, *, valid, j,
              nj, softcap, scale):
    """Shared GQA tile step.  k/v: [bt, KV, hd] f32 (already dequant).

    Scratch: m/l [KV, rep], acc [KV, rep, hd] — the flash-softmax
    recurrence of ``chunked_attention``, with ``p`` explicitly masked:
    on a fully-dead tile m stays NEG_INF and exp(NEG_INF - NEG_INF) = 1
    would otherwise inflate the normalizer.
    """
    h, hd = q_ref.shape[1], q_ref.shape[2]
    kv = k.shape[1]
    rep = h // kv
    qg = q_ref[0].reshape(kv, rep, hd).astype(jnp.float32)
    # [KV, rep, bt]: contract hd, batch the kv-head group
    logits = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, softcap)
    ok = jnp.broadcast_to(valid, logits.shape)
    logits = jnp.where(ok, logits, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    p = jnp.where(ok, jnp.exp(logits - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    # [KV, rep, hd]: contract bt, batch the kv-head group
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv

    @pl.when(j == nj - 1)
    def _():
        o = acc_ref[...] / jnp.maximum(l_ref[...], _EPS)[..., None]
        o_ref[0] = o.reshape(h, hd)


def _gqa_kernel(tbl_ref, pos_ref, alive_ref, q_ref, k_ref, v_ref, o_ref,
                m_ref, l_ref, acc_ref, *, bt, nj, softcap, scale):
    del tbl_ref
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    valid = _tile_valid(pos_ref, alive_ref, b, j, bt)
    _gqa_body(q_ref, k_ref[0].astype(jnp.float32),
              v_ref[0].astype(jnp.float32), o_ref, m_ref, l_ref, acc_ref,
              valid=valid, j=j, nj=nj, softcap=softcap, scale=scale)


def _dequant_kv_tile(words, cb, *, head_dim, bits, dequant):
    """[bt, KV, Wd] uint32 words + [Gcb, K] codebooks → [bt, KV, hd] f32."""
    bt, kv, wd = words.shape
    k_entries = kv_entries(bits)
    idx = unpack_words_axis1(words.reshape(bt * kv, wd), bits)
    idx = idx[:, :head_dim].reshape(bt, kv, head_dim)
    if cb.shape[0] == 1:          # one codebook per page
        vals = dequant_tile(idx.reshape(bt * kv, head_dim),
                            cb[0].astype(jnp.float32), k_entries, dequant)
        return vals.reshape(bt, kv, head_dim)
    heads = [dequant_tile(idx[:, g, :], cb[g].astype(jnp.float32),
                          k_entries, dequant)
             for g in range(kv)]   # per-kv-head codebooks, KV is static
    return jnp.stack(heads, axis=1)


def _gqa_quant_kernel(tbl_ref, pos_ref, alive_ref, q_ref, kw_ref, vw_ref,
                      kcb_ref, vcb_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      bt, nj, softcap, scale, head_dim, bits, dequant):
    del tbl_ref
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    valid = _tile_valid(pos_ref, alive_ref, b, j, bt)
    k = _dequant_kv_tile(kw_ref[0], kcb_ref[0], head_dim=head_dim,
                         bits=bits, dequant=dequant)
    v = _dequant_kv_tile(vw_ref[0], vcb_ref[0], head_dim=head_dim,
                         bits=bits, dequant=dequant)
    _gqa_body(q_ref, k, v, o_ref, m_ref, l_ref, acc_ref, valid=valid,
              j=j, nj=nj, softcap=softcap, scale=scale)


def _check_tile(page_size: int, token_tile: int) -> int:
    if token_tile is None:
        token_tile = page_size
    if page_size % token_tile:
        raise ValueError(f"token_tile={token_tile} must divide "
                         f"page_size={page_size}")
    return token_tile


def paged_attention_pallas(q, k_pool, v_pool, page_table, pos, alive, *,
                           softcap=None, scale, token_tile=None,
                           interpret=False):
    """q [B,1,H,hd]; pools [P+1, page, KV, hd] → [B, 1, H·hd] f32."""
    b, _, h, hd = q.shape
    _, page, kv, _ = k_pool.shape
    npg = page_table.shape[1]
    bt = _check_tile(page, token_tile)
    tpp = page // bt
    nj = npg * tpp
    rep = h // kv

    kv_spec = pl.BlockSpec(
        (1, bt, kv, hd),
        lambda b, j, tbl, pos, alv: (_page_select(alv, tbl, b, j, tpp),
                                     j % tpp, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, nj),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda b, j, tbl, pos, alv: (b, 0, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=pl.BlockSpec((1, h, hd),
                               lambda b, j, tbl, pos, alv: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, rep), jnp.float32),
            pltpu.VMEM((kv, rep), jnp.float32),
            pltpu.VMEM((kv, rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_gqa_kernel, bt=bt, nj=nj, softcap=softcap,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32),
      alive.astype(jnp.int32), q.reshape(b, h, hd), k_pool, v_pool)
    return out.reshape(b, 1, h * hd)


def paged_attention_quant_pallas(q, k_words, v_words, k_cb, v_cb,
                                 page_table, pos, alive, *, bits, head_dim,
                                 softcap=None, scale, token_tile=None,
                                 dequant="lut", interpret=False):
    """Quantized-KV paged GQA decode: words [P+1, page, KV, Wd] uint32,
    per-page codebooks [P+1, Gcb, K] → [B, 1, H·hd] f32."""
    b, _, h, hd = q.shape
    _, page, kv, wd = k_words.shape
    if wd != words_per(head_dim, bits):
        raise ValueError(f"word operand width {wd} != "
                         f"ceil({head_dim}/lanes) for kv_bits={bits}")
    npg = page_table.shape[1]
    gcb, k_entries = k_cb.shape[1], k_cb.shape[2]
    if k_entries != kv_entries(bits):
        raise ValueError(f"codebook K={k_entries} != 2**{bits}")
    bt = _check_tile(page, token_tile)
    tpp = page // bt
    nj = npg * tpp
    rep = h // kv

    word_spec = pl.BlockSpec(
        (1, bt, kv, wd),
        lambda b, j, tbl, pos, alv: (_page_select(alv, tbl, b, j, tpp),
                                     j % tpp, 0, 0))
    cb_spec = pl.BlockSpec(
        (1, gcb, k_entries),
        lambda b, j, tbl, pos, alv: (_page_select(alv, tbl, b, j, tpp),
                                     0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, nj),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda b, j, tbl, pos, alv: (b, 0, 0)),
            word_spec, word_spec, cb_spec, cb_spec,
        ],
        out_specs=pl.BlockSpec((1, h, hd),
                               lambda b, j, tbl, pos, alv: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, rep), jnp.float32),
            pltpu.VMEM((kv, rep), jnp.float32),
            pltpu.VMEM((kv, rep, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_gqa_quant_kernel, bt=bt, nj=nj, softcap=softcap,
                          scale=scale, head_dim=head_dim, bits=bits,
                          dequant=dequant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32),
      alive.astype(jnp.int32), q.reshape(b, h, hd), k_words, v_words,
      k_cb, v_cb)
    return out.reshape(b, 1, h * hd)


# ---------------------------------------------------------------------------
# MLA (absorbed decode in the latent space; dense and quantized)


def _mla_body(qe_ref, qr_ref, ckv, kr, o_ref, m_ref, l_ref, acc_ref, *,
              valid, j, nj, scale):
    """ckv [bt, L] f32; kr [bt, R] f32.  Accumulates the latent context
    with the same masked flash recurrence as the GQA body (scratch m/l
    [H, 1], acc [H, L])."""
    qe = qe_ref[0].astype(jnp.float32)          # [H, L]
    qr = qr_ref[0].astype(jnp.float32)          # [H, R]
    logits = (jax.lax.dot_general(qe, ckv, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) +
              jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32))
    logits = logits * scale                     # [H, bt]
    ok = jnp.broadcast_to(valid, logits.shape)
    logits = jnp.where(ok, logits, NEG_INF)
    m_prev = m_ref[...]                         # [H, 1]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.where(ok, jnp.exp(logits - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, ckv, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == nj - 1)
    def _():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], _EPS)


def _mla_kernel(tbl_ref, pos_ref, alive_ref, qe_ref, qr_ref, c_ref, r_ref,
                o_ref, m_ref, l_ref, acc_ref, *, bt, nj, scale):
    del tbl_ref
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    valid = _tile_valid(pos_ref, alive_ref, b, j, bt)
    _mla_body(qe_ref, qr_ref, c_ref[0].astype(jnp.float32),
              r_ref[0].astype(jnp.float32), o_ref, m_ref, l_ref, acc_ref,
              valid=valid, j=j, nj=nj, scale=scale)


def _dequant_lat_tile(words, cb, *, d, bits, dequant):
    """[bt, Wd] uint32 + [1, K] codebook → [bt, d] f32."""
    idx = unpack_words_axis1(words, bits)[:, :d]
    return dequant_tile(idx, cb[0].astype(jnp.float32), kv_entries(bits),
                        dequant)


def _mla_quant_kernel(tbl_ref, pos_ref, alive_ref, qe_ref, qr_ref, cw_ref,
                      rw_ref, ccb_ref, rcb_ref, o_ref, m_ref, l_ref,
                      acc_ref, *, bt, nj, scale, kv_lora, rope_dim, bits,
                      dequant):
    del tbl_ref
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    valid = _tile_valid(pos_ref, alive_ref, b, j, bt)
    ckv = _dequant_lat_tile(cw_ref[0], ccb_ref[0], d=kv_lora, bits=bits,
                            dequant=dequant)
    kr = _dequant_lat_tile(rw_ref[0], rcb_ref[0], d=rope_dim, bits=bits,
                           dequant=dequant)
    _mla_body(qe_ref, qr_ref, ckv, kr, o_ref, m_ref, l_ref, acc_ref,
              valid=valid, j=j, nj=nj, scale=scale)


def mla_paged_attention_pallas(q_eff, q_rope, c_pool, r_pool, page_table,
                               pos, alive, *, scale, token_tile=None,
                               interpret=False):
    """q_eff [B,1,H,L]; q_rope [B,1,H,R]; pools [P+1, page, L/R]
    → latent context [B, 1, H, L] f32."""
    b, _, h, lat = q_eff.shape
    rd = q_rope.shape[-1]
    _, page, _ = c_pool.shape
    npg = page_table.shape[1]
    bt = _check_tile(page, token_tile)
    tpp = page // bt
    nj = npg * tpp

    def lat_spec(width):
        return pl.BlockSpec(
            (1, bt, width),
            lambda b, j, tbl, pos, alv: (_page_select(alv, tbl, b, j, tpp),
                                         j % tpp, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, nj),
        in_specs=[
            pl.BlockSpec((1, h, lat),
                         lambda b, j, tbl, pos, alv: (b, 0, 0)),
            pl.BlockSpec((1, h, rd),
                         lambda b, j, tbl, pos, alv: (b, 0, 0)),
            lat_spec(lat), lat_spec(rd),
        ],
        out_specs=pl.BlockSpec((1, h, lat),
                               lambda b, j, tbl, pos, alv: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, lat), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_mla_kernel, bt=bt, nj=nj, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, lat), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32),
      alive.astype(jnp.int32), q_eff.reshape(b, h, lat),
      q_rope.reshape(b, h, rd), c_pool, r_pool)
    return out.reshape(b, 1, h, lat)


def mla_paged_attention_quant_pallas(q_eff, q_rope, c_words, r_words, c_cb,
                                     r_cb, page_table, pos, alive, *, bits,
                                     kv_lora, rope_dim, scale,
                                     token_tile=None, dequant="lut",
                                     interpret=False):
    """Quantized latent pages: words [P+1, page, W*] uint32 + per-page
    codebooks [P+1, 1, K] → latent context [B, 1, H, L] f32."""
    b, _, h, lat = q_eff.shape
    rd = q_rope.shape[-1]
    _, page, cwd = c_words.shape
    rwd = r_words.shape[-1]
    if cwd != words_per(kv_lora, bits) or rwd != words_per(rope_dim, bits):
        raise ValueError(f"latent word widths ({cwd},{rwd}) don't match "
                         f"kv_bits={bits} for dims ({kv_lora},{rope_dim})")
    k_entries = kv_entries(bits)
    npg = page_table.shape[1]
    bt = _check_tile(page, token_tile)
    tpp = page // bt
    nj = npg * tpp

    def word_spec(width):
        return pl.BlockSpec(
            (1, bt, width),
            lambda b, j, tbl, pos, alv: (_page_select(alv, tbl, b, j, tpp),
                                         j % tpp, 0))

    cb_spec = pl.BlockSpec(
        (1, 1, k_entries),
        lambda b, j, tbl, pos, alv: (_page_select(alv, tbl, b, j, tpp),
                                     0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, nj),
        in_specs=[
            pl.BlockSpec((1, h, lat),
                         lambda b, j, tbl, pos, alv: (b, 0, 0)),
            pl.BlockSpec((1, h, rd),
                         lambda b, j, tbl, pos, alv: (b, 0, 0)),
            word_spec(cwd), word_spec(rwd), cb_spec, cb_spec,
        ],
        out_specs=pl.BlockSpec((1, h, lat),
                               lambda b, j, tbl, pos, alv: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, lat), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_mla_quant_kernel, bt=bt, nj=nj, scale=scale,
                          kv_lora=kv_lora, rope_dim=rope_dim, bits=bits,
                          dequant=dequant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, lat), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32),
      alive.astype(jnp.int32), q_eff.reshape(b, h, lat),
      q_rope.reshape(b, h, rd), c_words, r_words, c_cb, r_cb)
    return out.reshape(b, 1, h, lat)


# ---------------------------------------------------------------------------
# Standalone page gather (the fused kernels make this a fallback / debug
# view; it also feeds the bench row that prices the gather alone)


def _page_gather_kernel(tbl_ref, alive_ref, p_ref, o_ref):
    del tbl_ref, alive_ref
    o_ref[...] = p_ref[...]


def page_gather_pallas(pool, page_table, alive, *, interpret=False):
    """[P+1, page, ...] pool → [B, max_pages·page, ...] logical view,
    one page DMA per (slot, logical page); dead slots read the trash
    page (the ``gather_pages_ref`` alive-masking contract)."""
    b, npg = page_table.shape
    page = pool.shape[1]
    feat = pool.shape[2:]
    d = 1
    for f in feat:
        d *= f
    pool2 = pool.reshape(pool.shape[0], page, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, npg),
        in_specs=[
            pl.BlockSpec(
                (1, page, d),
                lambda b, j, tbl, alv: (jnp.where(alv[b] > 0, tbl[b, j], 0),
                                        0, 0)),
        ],
        out_specs=pl.BlockSpec((1, page, d),
                               lambda b, j, tbl, alv: (b, j, 0)),
    )
    out = pl.pallas_call(
        _page_gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, npg * page, d), pool.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), alive.astype(jnp.int32), pool2)
    return out.reshape((b, npg * page) + feat)
