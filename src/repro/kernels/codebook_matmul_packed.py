"""Pallas TPU kernel: codebook matmul over *bit-packed* indices.

y[M, N] = x[M, Kd] · W where W is stored as the ``pack_indices_2d`` word
layout — uint32 ``pidx[⌈Kd/lanes⌉, N]``, each word holding ``lanes =
32//bits`` reduction-axis indices at a fixed ``bits = bits_per_index(K)``
width (little-endian, no straddling).  The packed words are the HBM-
resident operand: each grid step DMAs one [bkw, bn] word tile into VMEM,
unpacks it to a [bkw·lanes, bn] index tile with a shift+mask (pure VPU),
dequantizes, and feeds the MXU.

This closes the serve-path gap of the eq.-14 story: HBM weight traffic per
step is exactly ``bits/8`` bytes/weight — 4 bits at K=16 (8× less than
bf16, 2× less than the uint8-index layout), 2 bits at ternary, 1 bit at
binary — plus one K-entry codebook reread per (i, j) tile.

Dequant strategy: a K-entry LUT gather ``cb[idx]`` (``dequant="lut"``, the
default) — O(bk·bn) independent of K, so a K=256 adaptive codebook serves
at the same cost as K=4.  ``dequant="onehot"`` keeps the MXU-shaped
one-hot contraction (O(bk·bn·K)) as a fallback for Mosaic versions that
lower small-table gathers poorly (see ``REPRO_DEQUANT`` in dispatch.py).

Grid: (M/bm, N/bn, Kd/bk), k innermost; f32 accumulation in the revisited
output block (sequential TPU grid ⇒ safe).  ``bk`` must be a multiple of
``lanes`` so word tiles never straddle a k-block boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compression import bits_per_index
from repro.kernels.unpack import dequant_tile, unpack_words_axis0


def _kernel(x_ref, pidx_ref, cb_ref, o_ref, *, k_entries: int, bits: int,
            dequant: str):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                    # [bm, bk]
    words = pidx_ref[...]                             # [bkw, bn] uint32
    cb = cb_ref[0, :]                                 # [K]

    # In-VMEM unpack: word (w, n) → lanes indices at rows w·lanes+l.
    idx = unpack_words_axis0(words, bits)             # [bk, bn]
    w = dequant_tile(idx, cb, k_entries, dequant)
    o_ref[...] += jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                          preferred_element_type=jnp.float32)


def codebook_matmul_packed_pallas(
    x: jax.Array,            # [M, Kd]
    pidx: jax.Array,         # [⌈Kd/lanes⌉, N] uint32 packed indices
    codebook: jax.Array,     # [K] float
    *,
    bm: int = 128, bn: int = 128, bk: int = 512,
    dequant: str = "lut",
    interpret: bool = False,
) -> jax.Array:
    m, kd = x.shape
    k_entries = codebook.shape[0]
    bits = bits_per_index(k_entries)
    lanes = 32 // bits
    wk, n = pidx.shape
    if wk != -(-kd // lanes):
        raise ValueError(f"pidx rows {wk} != ceil({kd}/{lanes}) — operand "
                         f"not in pack_indices_2d layout for K={k_entries}")
    if bk % lanes:
        raise ValueError(f"bk={bk} must be a multiple of lanes={lanes} "
                         f"(bits={bits}) so word tiles don't straddle")
    if dequant not in ("lut", "onehot"):
        raise ValueError(f"dequant={dequant!r}; choose lut|onehot")
    bkw = bk // lanes

    # Pad M/N with zeros and Kd up to a bk multiple.  Padded x rows are
    # zero, so whatever the zero-padded words decode to contributes 0.
    kdp = -(-max(kd, lanes * wk) // bk) * bk
    xp = jnp.pad(x, ((0, (-m) % bm), (0, kdp - kd)))
    pp = jnp.pad(pidx, ((0, kdp // lanes - wk), (0, (-n) % bn)))
    gm, gn, gk = xp.shape[0] // bm, pp.shape[1] // bn, kdp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, k_entries=k_entries, bits=bits,
                          dequant=dequant),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkw, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, k_entries), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], pp.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, pp, codebook.reshape(1, -1))
    return out[:m, :n].astype(x.dtype)
