"""Backend dispatch for quantized serving — the layer between the
PackedModel artifact and the kernels.

One call site (``models.layers.apply_mlp``, ``launch/serve.py --packed``)
routes every codebook matmul here; this module picks the implementation:

* ``pallas``            — the Mosaic ``codebook_matmul`` kernel
  (dequant-in-VMEM one-hot contraction; TPU only);
* ``pallas_interpret``  — same kernel body, Python interpreter (CPU
  correctness checks; slow);
* ``ref``               — pure-jnp gather-dequant + dot
  (``kernels.ref``) — the CPU serving default, and the allclose oracle.

Default: pallas on TPU, ref elsewhere; override with
``REPRO_KERNEL_BACKEND=pallas|pallas_interpret|ref`` or per call.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

Array = jax.Array

_BACKENDS = ("pallas", "pallas_interpret", "ref")


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in _BACKENDS:
            raise ValueError(f"REPRO_KERNEL_BACKEND={env!r}; "
                             f"choose from {_BACKENDS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def codebook_matmul(x: Array, idx: Array, codebook: Array, *,
                    backend: Optional[str] = None,
                    bm: int = 128, bn: int = 128, bk: int = 512) -> Array:
    """y[M, N] = x[M, Kd] · codebook[idx[Kd, N]] on the chosen backend."""
    b = backend or default_backend()
    if b == "pallas":
        return ops.codebook_matmul(x, idx, codebook, bm=bm, bn=bn, bk=bk,
                                   interpret=False)
    if b == "pallas_interpret":
        return ops.codebook_matmul(x, idx, codebook, bm=bm, bn=bn, bk=bk,
                                   interpret=True)
    return ref.codebook_matmul_ref(x, idx, codebook)


def quantized_matmul(x: Array, idx: Array, codebook: Array, *,
                     backend: Optional[str] = None) -> Array:
    """Batched-x wrapper: x[..., Kd] · codebook[idx[Kd, N]] → [..., N].

    This is the serve-path entry ``apply_mlp`` uses when a param leaf is
    stored quantized (``<name>_idx`` + ``<name>_cb``).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = codebook_matmul(x2, idx, codebook, backend=backend)
    return y.reshape(lead + (idx.shape[-1],)).astype(x.dtype)


def decode_leaf(idx: Array, codebook: Array, dtype=None) -> Array:
    """Materialize a dense weight from (indices, codebook) — the fallback
    for call sites without a fused kernel.  A 2-D codebook is per-group
    ([G, K] against idx [G, ...]): gathered group-wise."""
    idx = idx.astype(jnp.int32)
    if codebook.ndim == 2:
        w = jax.vmap(lambda i, c: c[i])(idx, codebook)
    else:
        w = codebook[idx]
    return w.astype(dtype) if dtype is not None else w


def decode_params(tree: Any) -> Any:
    """In-jit dense reconstruction of a ``serving_params``-layout tree:
    every ``<name>_idx``/``<name>_cb`` pair collapses to a dense ``<name>``
    leaf.  Under jit only the packed arrays are HBM-resident inputs; the
    dense weights are temporaries XLA schedules per use."""
    if isinstance(tree, dict):
        out = {}
        for key, val in tree.items():
            if key.endswith("_idx"):
                name = key[:-4]
                out[name] = decode_leaf(val, tree[f"{name}_cb"])
            elif key.endswith("_cb") and f"{key[:-3]}_idx" in tree:
                continue
            else:
                out[key] = decode_params(val)
        return out
    if isinstance(tree, (tuple, list)):
        return type(tree)(decode_params(v) for v in tree)
    return tree
