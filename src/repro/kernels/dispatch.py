"""Backend dispatch for quantized serving — the layer between the
PackedModel artifact and the kernels.

One call site (``models.qleaf`` — the model-wide quantized-leaf
abstraction every MLP/attention/embedding/MoE/SSM weight fetch goes
through; ``launch/serve.py --packed``) routes every codebook matmul and
embedding gather here; this module picks the implementation:

* ``pallas``            — the Mosaic kernels (dequant-in-VMEM; TPU only):
  ``codebook_matmul`` for uint8 indices, ``codebook_matmul_packed`` for
  the bit-packed uint32 word operand, ``codebook_matmul_packed_t`` for
  the fused transposed LM head, ``quantized_gather`` for the row-packed
  embedding table;
* ``pallas_interpret``  — same kernel bodies, Python interpreter (CPU
  correctness checks; slow);
* ``ref``               — pure-jnp gather-dequant + dot
  (``kernels.ref``) — the CPU serving default, and the allclose oracle.

Default: pallas on TPU, ref elsewhere; override with
``REPRO_KERNEL_BACKEND=pallas|pallas_interpret|ref`` or per call.

Dequant strategy inside the Pallas kernels is a K-entry LUT gather by
default; ``REPRO_DEQUANT=onehot`` falls back to the one-hot contraction
(O(K) per weight — the pre-LUT behaviour) for Mosaic versions that lower
small-table gathers poorly.

Block-size autotune (``packed_block_sizes``): the packed route picks
(bm, bn, bk) from a shape-keyed table — exact (M, Kd, N, bits) entries
first, then a roofline heuristic that separates decode shapes (M small:
one activation tile, stream the packed weights with wide bn·bk tiles)
from prefill shapes (M large: MXU-balanced 128×128×512).  Override with
``REPRO_PACKED_BLOCKS=bm,bn,bk``.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import (PackedLayout, bits_per_index,
                                    unpack_indices_2d, unpack_rows)
from repro.kernels import ops, ref

Array = jax.Array

_BACKENDS = ("pallas", "pallas_interpret", "ref")


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in _BACKENDS:
            raise ValueError(f"REPRO_KERNEL_BACKEND={env!r}; "
                             f"choose from {_BACKENDS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def default_dequant() -> str:
    env = os.environ.get("REPRO_DEQUANT", "lut")
    if env not in ("lut", "onehot"):
        raise ValueError(f"REPRO_DEQUANT={env!r}; choose lut|onehot")
    return env


# ---------------------------------------------------------------------------
# Packed-route block-size autotune
# ---------------------------------------------------------------------------

# Exact-shape entries (M, Kd, N, bits) → (bm, bn, bk), seeded from the
# roofline model for the bench/serve shapes; extend by measuring sweeps
# with REPRO_PACKED_BLOCKS and recording winners here.
_PACKED_BLOCK_TABLE: Dict[Tuple[int, int, int, int],
                          Tuple[int, int, int]] = {
    (256, 2048, 512, 4): (128, 128, 512),   # bench prefill shape
    (64, 1024, 256, 4): (64, 256, 512),     # bench mid shape
    (1, 2048, 512, 4): (8, 512, 1024),      # single-request decode
    # Transposed LM-head route (packed_block_sizes_t keys on (M, D, V)):
    # decode micro-batch against a row-packed 1024-vocab head (bench
    # shape codebook_matmul_packed_t_*).
    (8, 256, 1024, 4): (8, 256, 256),
}


def packed_block_table() -> Dict[Tuple[int, int, int, int],
                                 Tuple[int, int, int]]:
    """The exact-shape autotune entries (copy).  Public so the static
    auditor (``repro.analysis.vmem``) can lint every committed entry —
    a bad one otherwise only fails at Mosaic compile time on a TPU."""
    return dict(_PACKED_BLOCK_TABLE)


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def packed_block_sizes(m: int, kd: int, n: int, bits: int
                       ) -> Tuple[int, int, int]:
    """(bm, bn, bk) for the packed kernel at this shape.

    Priority: ``REPRO_PACKED_BLOCKS=bm,bn,bk`` env override → exact
    (M, Kd, N, bits) table hit → roofline heuristic.  The result always
    has bk a multiple of lanes (= 32 // bits) so word tiles never
    straddle a k-block boundary.
    """
    env = os.environ.get("REPRO_PACKED_BLOCKS")
    if env:
        try:
            bm, bn, bk = (int(v) for v in env.split(","))
        except ValueError as e:
            raise ValueError(
                f"REPRO_PACKED_BLOCKS={env!r}; expected 'bm,bn,bk'") from e
    else:
        hit = _PACKED_BLOCK_TABLE.get((m, kd, n, bits))
        if hit is not None:
            bm, bn, bk = hit
        elif m <= 32:
            # Decode shape: activations fit one tile; widen the weight
            # tiles so the DMA stream of packed words stays long.
            bm, bn, bk = _round_up(min(m, 32), 8), 256, 1024
        elif m <= 128:
            bm, bn, bk = 64, 128, 512
        else:
            # Prefill shape: MXU-balanced tiles.
            bm, bn, bk = 128, 128, 512
        # Don't over-pad small layers past one tile.
        bn = min(bn, _round_up(n, 128))
        bk = min(bk, _round_up(kd, 128))
    lanes = 32 // bits
    bk = max(lanes, bk // lanes * lanes)
    return bm, bn, bk


def packed_block_sizes_t(m: int, d: int, n_out: int, bits: int, order: str
                         ) -> Tuple[int, int, int]:
    """(bm, bn, bk) for the *transposed* packed kernel (LM head: y[M, V] =
    x[M, D]·W.T).  Reuses :func:`packed_block_sizes` keyed on the
    contraction shape (M, D, V), then re-aligns the lane-packed axis:
    ``order="kd"`` packs V (the output axis) → bn must be a lanes
    multiple; ``order="row"`` packs D (the reduction axis) → bk already
    is.  Same ``REPRO_PACKED_BLOCKS`` override."""
    bm, bn, bk = packed_block_sizes(m, d, n_out, bits)
    if order == "kd":
        lanes = 32 // bits
        bn = max(lanes, bn // lanes * lanes)
    return bm, bn, bk


# ---------------------------------------------------------------------------
# Paged-attention route block autotune
# ---------------------------------------------------------------------------

# Exact-shape entries (kind, feat, page_size, kv_bits) → token_tile, the
# number of KV tokens DMA'd per grid step (must divide page_size).
# ``feat`` is the per-token feature count of the tiled operand
# (n_kv·head_dim for gqa; kv_lora + rope_dim for mla; the flattened
# trailing dims for gather).  kv_bits=0 = dense pages.  Seeded from the
# bench/engine shapes; extend by measuring sweeps with
# ``REPRO_PAGED_BLOCK`` and recording winners here (BENCH_kernels.json
# tracks the timings).
_PAGED_BLOCK_TABLE: Dict[Tuple[str, int, int, int], int] = {
    # bench/engine config: n_kv=2 · head_dim=12, page_size=8
    ("gqa", 24, 8, 0): 8,
    ("gqa", 24, 8, 2): 8,
    ("gqa", 24, 8, 4): 8,
    ("gqa", 24, 8, 8): 8,
    ("gather", 24, 8, 0): 8,
    # kernel-bench GQA config: n_kv=2 · head_dim=32, page_size=8 (hd a
    # multiple of every lane count, so word rows pack without a ragged
    # tail and the bench's B/token invariant is exact)
    ("gqa", 64, 8, 0): 8,
    ("gqa", 64, 8, 2): 8,
    ("gqa", 64, 8, 4): 8,
    ("gqa", 64, 8, 8): 8,
    ("gather", 64, 8, 0): 8,
    # MLA bench config: kv_lora=32 + rope_dim=16, page_size=8
    ("mla", 48, 8, 0): 8,
    ("mla", 48, 8, 2): 8,
    ("mla", 48, 8, 4): 8,
    ("mla", 48, 8, 8): 8,
    # production-ish GQA shape: n_kv=8 · head_dim=128, page_size=16 —
    # half-page tiles keep the dense KV tile ≤ 32 KiB so double-buffered
    # DMA fits comfortably beside the accumulator scratch
    ("gqa", 1024, 16, 0): 8,
    ("gqa", 1024, 16, 4): 16,
}


def paged_block_table() -> Dict[Tuple[str, int, int, int], int]:
    """The exact-shape paged-attention autotune entries (copy) — public
    for the same reason as :func:`packed_block_table`: the vmem lint
    checks every committed entry at audit time."""
    return dict(_PAGED_BLOCK_TABLE)


def paged_token_tile(kind: str, feat: int, page_size: int, kv_bits: int
                     ) -> int:
    """Token tile for a paged-attention/page-gather kernel at this shape.

    Priority: ``REPRO_PAGED_BLOCK=<tile>`` env override → exact table
    hit → full page (the pools are built with small pages, so one page
    per grid step is the roofline default).  Always clamped to a
    divisor of ``page_size``.
    """
    env = os.environ.get("REPRO_PAGED_BLOCK")
    if env:
        try:
            tile = int(env)
        except ValueError as e:
            raise ValueError(f"REPRO_PAGED_BLOCK={env!r}; expected an int "
                             f"token tile") from e
    else:
        tile = _PAGED_BLOCK_TABLE.get((kind, feat, page_size, kv_bits),
                                      page_size)
    tile = min(tile, page_size)
    while page_size % tile:
        tile -= 1
    return tile


def paged_attention(q: Array, k_pool: Array, v_pool: Array,
                    page_table: Array, pos: Array, alive: Array, *,
                    softcap: Optional[float] = None, scale: float,
                    backend: Optional[str] = None) -> Array:
    """Paged GQA decode over dense KV pages: q [B,1,H,hd] + pools
    [P+1, page, KV, hd] → [B, 1, H·hd].

    ``ref`` (CPU serving default): the jnp gather + masked-softmax math
    that used to live inline in ``models.attention`` — bit-identical to
    it.  Pallas backends: the fused scalar-prefetch online-softmax
    kernel (``kernels.paged_attention``), allclose vs ref."""
    b = backend or default_backend()
    if b == "ref":
        return ref.paged_attention_ref(q, k_pool, v_pool, page_table, pos,
                                       alive, softcap=softcap, scale=scale)
    page, kv, hd = k_pool.shape[1], k_pool.shape[2], k_pool.shape[3]
    tile = paged_token_tile("gqa", kv * hd, page, 0)
    out = ops.paged_attention(q, k_pool, v_pool, page_table, pos, alive,
                              softcap=softcap, scale=scale, token_tile=tile,
                              interpret=(b == "pallas_interpret"))
    return out.astype(k_pool.dtype)


def paged_attention_quant(q: Array, k_words: Array, v_words: Array,
                          k_cb: Array, v_cb: Array, page_table: Array,
                          pos: Array, alive: Array, *, bits: int,
                          head_dim: int, softcap: Optional[float] = None,
                          scale: float,
                          backend: Optional[str] = None) -> Array:
    """Paged GQA decode over codebook-quantized KV pages (kv_bits/8 B per
    cached scalar): words [P+1, page, KV, Wd] uint32 + per-page codebooks
    [P+1, Gcb, K] → [B, 1, H·hd]."""
    b = backend or default_backend()
    if b == "ref":
        return ref.paged_attention_quant_ref(
            q, k_words, v_words, k_cb, v_cb, page_table, pos, alive,
            bits=bits, head_dim=head_dim, softcap=softcap, scale=scale)
    page, kv = k_words.shape[1], k_words.shape[2]
    tile = paged_token_tile("gqa", kv * head_dim, page, bits)
    out = ops.paged_attention_quant(
        q, k_words, v_words, k_cb, v_cb, page_table, pos, alive, bits=bits,
        head_dim=head_dim, softcap=softcap, scale=scale, token_tile=tile,
        dequant=default_dequant(), interpret=(b == "pallas_interpret"))
    return out.astype(k_cb.dtype)


def mla_paged_attention(q_eff: Array, q_rope: Array, c_pool: Array,
                        r_pool: Array, page_table: Array, pos: Array,
                        alive: Array, *, scale: float,
                        backend: Optional[str] = None) -> Array:
    """Absorbed-MLA paged decode over dense latent pages → latent context
    [B, 1, H, kv_lora]."""
    b = backend or default_backend()
    if b == "ref":
        return ref.mla_paged_attention_ref(q_eff, q_rope, c_pool, r_pool,
                                           page_table, pos, alive,
                                           scale=scale)
    page = c_pool.shape[1]
    feat = c_pool.shape[2] + r_pool.shape[2]
    tile = paged_token_tile("mla", feat, page, 0)
    out = ops.mla_paged_attention(q_eff, q_rope, c_pool, r_pool, page_table,
                                  pos, alive, scale=scale, token_tile=tile,
                                  interpret=(b == "pallas_interpret"))
    return out.astype(c_pool.dtype)


def mla_paged_attention_quant(q_eff: Array, q_rope: Array, c_words: Array,
                              r_words: Array, c_cb: Array, r_cb: Array,
                              page_table: Array, pos: Array, alive: Array,
                              *, bits: int, kv_lora: int, rope_dim: int,
                              scale: float,
                              backend: Optional[str] = None) -> Array:
    """Absorbed-MLA paged decode over quantized latent pages."""
    b = backend or default_backend()
    if b == "ref":
        return ref.mla_paged_attention_quant_ref(
            q_eff, q_rope, c_words, r_words, c_cb, r_cb, page_table, pos,
            alive, bits=bits, kv_lora=kv_lora, rope_dim=rope_dim,
            scale=scale)
    page = c_words.shape[1]
    tile = paged_token_tile("mla", kv_lora + rope_dim, page, bits)
    out = ops.mla_paged_attention_quant(
        q_eff, q_rope, c_words, r_words, c_cb, r_cb, page_table, pos,
        alive, bits=bits, kv_lora=kv_lora, rope_dim=rope_dim, scale=scale,
        token_tile=tile, dequant=default_dequant(),
        interpret=(b == "pallas_interpret"))
    return out.astype(c_cb.dtype)


def page_gather(pool: Array, page_table: Array, alive: Array, *,
                backend: Optional[str] = None) -> Array:
    """Per-slot logical KV view [B, max_pages·page, ...] with dead slots
    masked to the trash page — the standalone gather (the fused decode
    kernels above subsume it on the hot path)."""
    b = backend or default_backend()
    if b == "ref":
        return ref.gather_pages_ref(pool, page_table, alive)
    return ops.page_gather(pool, page_table, alive,
                           interpret=(b == "pallas_interpret"))


# ---------------------------------------------------------------------------
# Blockwise-prefill route block autotune
# ---------------------------------------------------------------------------

# Exact-shape entries (kind, feat) → token_tile, the number of stored KV
# rows DMA'd per grid step of the blockwise-prefill kernel.  ``kind`` is
# "dense" (f32/bf16 view rows) or "quant" (packed uint32 word rows);
# ``feat`` is the per-token, per-kv-head feature count of the tiled
# operand (head_dim for gqa; kv_lora + rope_dim for the expanded-MLA
# latent-derived keys).  The quant route additionally clamps the tile to
# a divisor of ``page_size`` so a tile's codebook is one page's.  Seeded
# from the bench/test shapes; extend by measuring sweeps with
# ``REPRO_PREFILL_BLOCK`` and recording winners here.
_PREFILL_BLOCK_TABLE: Dict[Tuple[str, int], int] = {
    ("dense", 12): 64,        # bench/engine mixed config head_dim
    ("dense", 8): 64,         # bf16 engine config head_dim
    ("dense", 44): 64,        # mla expanded keys: nope 32 + rope 12
    ("quant", 12): 8,         # kv_bits>0 pages, page_size=8 geometry
}

DEFAULT_PREFILL_TILE = 64


def prefill_block_table() -> Dict[Tuple[str, int], int]:
    """The exact-shape blockwise-prefill autotune entries (copy) — public
    so the static auditor's VMEM lint checks every committed entry, same
    contract as :func:`packed_block_table`/:func:`paged_block_table`."""
    return dict(_PREFILL_BLOCK_TABLE)


def prefill_token_tile(kind: str, feat: int,
                       page_size: Optional[int] = None) -> int:
    """KV-row tile for a blockwise-prefill kernel at this shape.

    Priority: ``REPRO_PREFILL_BLOCK=<tile>`` env override → exact
    (kind, feat) table hit → :data:`DEFAULT_PREFILL_TILE`.  When
    ``page_size`` is given (the quantized-page route) the tile is
    clamped to a divisor of it so no tile straddles a codebook
    boundary.
    """
    env = os.environ.get("REPRO_PREFILL_BLOCK")
    if env:
        try:
            tile = int(env)
        except ValueError as e:
            raise ValueError(f"REPRO_PREFILL_BLOCK={env!r}; expected an "
                             f"int token tile") from e
    else:
        tile = _PREFILL_BLOCK_TABLE.get((kind, feat), DEFAULT_PREFILL_TILE)
    tile = max(1, tile)
    if page_size is not None:
        tile = min(tile, page_size)
        while page_size % tile:
            tile -= 1
    return tile


def blockwise_prefill_attention(q: Array, k: Array, v: Array, q_pos: Array,
                                k_pos: Array, *,
                                window: Optional[int] = None,
                                softcap: Optional[float] = None,
                                scale: float,
                                backend: Optional[str] = None) -> Array:
    """Chunked-prompt prefill attention: q [B,C,H,hd] (the C new tokens)
    vs. a stored K/V view k [B,S,KV,hd] / v [B,S,KV,vd] with 1-D int32
    positions q_pos [C] / k_pos [S] → [B,C,H,vd] in the view dtype.

    Visibility is purely position-derived (``k_pos <= q_pos`` and the
    optional sliding ``window``); rows past the valid prefix carry
    ``ref.POS_SENTINEL`` and mask to exact zero probability, so the
    engine's fixed-capacity page view and the oracle's growing buffer
    produce bit-identical chunks.  The view is padded to a tile multiple
    here — identically on every backend — so ref and Pallas reduce over
    the same tile partition."""
    b = backend or default_backend()
    tile = prefill_token_tile("dense", k.shape[-1])
    s = k.shape[1]
    k_pos = k_pos.astype(jnp.int32)
    pad = (-s) % tile
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.concatenate(
            [k_pos, jnp.full((pad,), ref.POS_SENTINEL, jnp.int32)])
    if b == "ref":
        out = ref.blockwise_prefill_ref(q, k, v, q_pos, k_pos,
                                        window=window, softcap=softcap,
                                        scale=scale, token_tile=tile)
    else:
        out = ops.blockwise_prefill(q, k, v, q_pos, k_pos, window=window,
                                    softcap=softcap, scale=scale,
                                    token_tile=tile,
                                    interpret=(b == "pallas_interpret"))
    return out.astype(v.dtype)


def blockwise_prefill_attention_quant(q: Array, k_words: Array,
                                      v_words: Array, k_cb: Array,
                                      v_cb: Array, q_pos: Array,
                                      k_pos: Array, *, page_size: int,
                                      bits: int, head_dim: int,
                                      window: Optional[int] = None,
                                      softcap: Optional[float] = None,
                                      scale: float,
                                      backend: Optional[str] = None
                                      ) -> Array:
    """Chunked-prompt prefill over the slot's codebook-quantized pages:
    word view [B, S, KV, Wd] uint32 (S = n_pages·page_size, logical row
    order) + per-page codebooks [B, n_pages, Gcb, K] → [B, C, H,
    head_dim] in the codebook dtype.  kv_bits/8 B per cached scalar of
    KV traffic on the Pallas backends; same position-derived masking as
    the dense route (stale rows of reused pages carry sentinel
    positions)."""
    b = backend or default_backend()
    tile = prefill_token_tile("quant", head_dim, page_size=page_size)
    s = k_words.shape[1]
    if s % page_size:
        raise ValueError(f"quantized view rows {s} not a multiple of "
                         f"page_size={page_size}")
    k_pos = k_pos.astype(jnp.int32)
    if b == "ref":
        out = ref.blockwise_prefill_quant_ref(
            q, k_words, v_words, k_cb, v_cb, q_pos, k_pos,
            page_size=page_size, bits=bits, head_dim=head_dim,
            window=window, softcap=softcap, scale=scale, token_tile=tile)
    else:
        out = ops.blockwise_prefill_quant(
            q, k_words, v_words, k_cb, v_cb, q_pos, k_pos,
            page_size=page_size, bits=bits, head_dim=head_dim,
            window=window, softcap=softcap, scale=scale, token_tile=tile,
            dequant=default_dequant(),
            interpret=(b == "pallas_interpret"))
    return out.astype(k_cb.dtype)


def codebook_matmul(x: Array, idx: Array, codebook: Array, *,
                    backend: Optional[str] = None,
                    bm: int = 128, bn: int = 128, bk: int = 512) -> Array:
    """y[M, N] = x[M, Kd] · codebook[idx[Kd, N]] on the chosen backend."""
    b = backend or default_backend()
    dq = default_dequant()
    if b == "pallas":
        return ops.codebook_matmul(x, idx, codebook, bm=bm, bn=bn, bk=bk,
                                   dequant=dq, interpret=False)
    if b == "pallas_interpret":
        return ops.codebook_matmul(x, idx, codebook, bm=bm, bn=bn, bk=bk,
                                   dequant=dq, interpret=True)
    return ref.codebook_matmul_ref(x, idx, codebook)


def packed_codebook_matmul(x: Array, pidx: Array, codebook: Array, *,
                           layout: Optional[PackedLayout] = None,
                           backend: Optional[str] = None,
                           blocks: Optional[Tuple[int, int, int]] = None,
                           ) -> Array:
    """y[M, N] = x[M, Kd] · codebook[unpack(pidx)] with the bit-packed
    uint32 word operand (``pack_indices_2d`` layout) HBM-resident end to
    end — bits_per_index(K)/8 bytes/weight of index traffic.

    ``layout`` (the static lane metadata ``serving_params(packed=True)``
    emits) is validated against the operands when given; block sizes come
    from :func:`packed_block_sizes` unless ``blocks`` overrides.
    """
    k = int(codebook.shape[-1])
    bits = bits_per_index(k)
    m, kd = x.shape
    wk, n = pidx.shape
    if layout is not None:
        if (layout.kd, layout.n, layout.k) != (kd, n, k):
            raise ValueError(f"packed layout {layout} does not match "
                             f"operands x[{m},{kd}] pidx[...,{n}] cb[{k}]")
        bits = layout.bits
    # Validate the word count on every backend — the ref route would
    # otherwise silently truncate a mismatched (stale/wrong-leaf) operand.
    lanes = 32 // bits
    if wk != -(-kd // lanes):
        raise ValueError(f"pidx rows {wk} != ceil({kd}/{lanes}) — operand "
                         f"not in pack_indices_2d layout for K={k}")
    b = backend or default_backend()
    if b == "ref":
        return ref.packed_codebook_matmul_ref(x, pidx, codebook)
    bm, bn, bk = blocks or packed_block_sizes(m, kd, n, bits)
    return ops.packed_codebook_matmul(
        x, pidx, codebook, bm=bm, bn=bn, bk=bk, dequant=default_dequant(),
        interpret=(b == "pallas_interpret"))


def quantized_matmul(x: Array, idx: Array, codebook: Array, *,
                     backend: Optional[str] = None) -> Array:
    """Batched-x wrapper: x[..., Kd] · codebook[idx[Kd, N]] → [..., N].

    This is the serve-path entry ``models.qleaf.qmatmul`` uses when a
    param leaf is stored quantized (``<name>_idx`` + ``<name>_cb``).

    On the ``ref`` backend (the CPU serving default) the contraction is
    literally ``x @ codebook[idx]`` — the identical graph as the dense
    layout, so packed-vs-dense serving is bit-exact there.
    """
    b = backend or default_backend()
    if b == "ref" or idx.ndim != 2:
        y = x @ decode_leaf(idx, codebook)
        return y.astype(x.dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = codebook_matmul(x2, idx, codebook, backend=b)
    return y.reshape(lead + (idx.shape[-1],)).astype(x.dtype)


def packed_quantized_matmul(x: Array, pidx: Array, codebook: Array, *,
                            layout: Optional[PackedLayout] = None,
                            backend: Optional[str] = None) -> Array:
    """Batched-x wrapper over :func:`packed_codebook_matmul` — the serve-
    path entry ``models.qleaf.qmatmul`` uses for the ``<name>_pidx``
    layout.  Same bit-exact dense-graph property on ``ref`` as
    :func:`quantized_matmul`; non-matrix layouts (``layout.shape`` set)
    always take the dequant-then-dot route."""
    b = backend or default_backend()
    nd = layout is not None and (layout.shape is not None
                                 or layout.order != "kd")
    if b == "ref" or pidx.ndim != 2 or nd:
        if layout is None:
            raise ValueError("packed_quantized_matmul needs the static "
                             "PackedLayout on the dequant route")
        y = x @ decode_packed_leaf(pidx, codebook, layout)
        return y.astype(x.dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = packed_codebook_matmul(x2, pidx, codebook, layout=layout,
                               backend=b)
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)


def packed_quantized_matmul_t(x: Array, pidx: Array, codebook: Array, *,
                              layout: PackedLayout,
                              backend: Optional[str] = None,
                              blocks: Optional[Tuple[int, int, int]] = None,
                              ) -> Array:
    """y[..., V] = x[..., D] · codebook[unpack(pidx)].T — the fused
    transposed (tied/untied LM-head) route over a packed [V, D] leaf.

    The packed word operand — ``pack_indices_2d`` (``layout.order="kd"``)
    or ``pack_rows`` (``"row"``, the embedding serving layout shared with
    the fused gather) — stays HBM-resident on the Pallas backends:
    ``bits_per_index(K)/8`` bytes/weight, no dense [V, D] temporary.  On
    the ``ref`` backend (CPU serving default) the contraction is literally
    ``x @ decode.T`` — the identical graph as the dense layout, so
    packed-vs-dense logits are bit-exact there.
    """
    b = backend or default_backend()
    if b == "ref" or pidx.ndim != 2 or layout.shape is not None \
            or codebook.ndim != 1:
        w = decode_packed_leaf(pidx, codebook, layout)
        y = x @ jnp.matrix_transpose(w)
        return y.astype(x.dtype)
    if pidx.shape != layout.word_shape:
        raise ValueError(f"pidx {pidx.shape} != layout word shape "
                         f"{layout.word_shape} ({layout})")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    bm, bn, bk = blocks or packed_block_sizes_t(
        x2.shape[0], layout.n, layout.kd, layout.bits, layout.order)
    y = ops.packed_codebook_matmul_t(
        x2, pidx, codebook, layout.kd, order=layout.order, bm=bm, bn=bn,
        bk=bk, dequant=default_dequant(),
        interpret=(b == "pallas_interpret"))
    return y.reshape(lead + (layout.kd,)).astype(x.dtype)


def quantized_gather(tokens: Array, pidx: Array, codebook: Array, *,
                     layout: PackedLayout,
                     backend: Optional[str] = None) -> Array:
    """Embedding dequant-on-gather: rows ``codebook[unpack(pidx)[tokens]]``
    without ever materializing the dense [V, D] table.

    ``layout.order == "row"`` (the serving layout —
    :func:`~repro.core.compression.pack_rows`, [V, ⌈D/lanes⌉] uint32): a
    token's lookup reads its contiguous packed word row — exactly
    ``bits_per_index(K)/8`` bytes per gathered weight.  On TPU this is the
    Mosaic kernel (``kernels.quantized_gather``: scalar-prefetch row DMA →
    shift+mask → K-entry LUT); the jnp route (word-row gather + unpack,
    the same bytes) is the CPU reference — a pure gather, so it is
    bit-exact vs the dense table on every backend.

    ``layout.order == "kd"`` (the pre-row-pack column layout,
    :func:`~repro.core.compression.pack_indices_2d` over the vocab axis):
    retained jnp fallback — gathers one full uint32 word per embedding
    *column* (4 B/weight), shift+masks the token's lane.  A 2-D codebook
    is per-group ([G, K]) — not needed for the root embedding table.
    """
    tokens = tokens.astype(jnp.int32)
    if layout.order == "row":
        b = backend or default_backend()
        if b != "ref" and pidx.ndim == 2 and codebook.ndim == 1:
            lead = tokens.shape
            out = ops.quantized_gather(
                tokens.reshape(-1), pidx, codebook, layout.n,
                dequant=default_dequant(),
                interpret=(b == "pallas_interpret"))
            rows = out.reshape(lead + (layout.n,))
        else:
            words = pidx[tokens]                     # [..., ⌈D/lanes⌉]
            idx = unpack_rows(words, layout.n, layout.k)
            rows = codebook[idx]
    else:
        del backend              # single (jnp reference) backend for "kd"
        mask = jnp.uint32((1 << layout.bits) - 1)
        words = pidx[tokens // layout.lanes]         # [..., D] uint32
        lane = (tokens % layout.lanes).astype(jnp.uint32)
        idx = (words >> (lane[..., None] * jnp.uint32(layout.bits))) & mask
        rows = codebook[idx.astype(jnp.int32)]
    # Cast f32 codebook values back to the table's original dtype so the
    # embedding keeps anchoring the residual-stream dtype (bf16 models).
    return rows if layout.dtype is None else rows.astype(layout.dtype)


def decode_leaf(idx: Array, codebook: Array, dtype=None) -> Array:
    """Materialize a dense weight from (indices, codebook) — the fallback
    for call sites without a fused kernel.  A 2-D codebook is per-group
    ([G, K] against idx [G, ...]): gathered group-wise."""
    idx = idx.astype(jnp.int32)
    if codebook.ndim == 2:
        w = jax.vmap(lambda i, c: c[i])(idx, codebook)
    else:
        w = codebook[idx]
    return w.astype(dtype) if dtype is not None else w


def decode_packed_leaf(pidx: Array, codebook: Array, layout: PackedLayout,
                       dtype=None) -> Array:
    """Materialize a dense weight from the bit-packed word operand
    (``pack_indices_2d`` layout, or ``pack_rows`` when
    ``layout.order == "row"``; grouped leaves carry a leading G axis).
    Non-matrix leaves (``layout.shape`` set — e.g. MoE expert stacks
    [E, D, F] packed as (E·D, F)) are reshaped back to the dense shape."""
    if layout.order == "row":
        idx = unpack_rows(pidx, layout.n, layout.k)
    elif pidx.ndim == 3:
        idx = jax.vmap(lambda w: unpack_indices_2d(w, layout.kd,
                                                   layout.k))(pidx)
    else:
        idx = unpack_indices_2d(pidx, layout.kd, layout.k)
    if dtype is None:
        dtype = layout.dtype      # original leaf dtype (None on old layouts)
    w = decode_leaf(idx, codebook, dtype)
    if layout.shape is not None:
        w = w.reshape(w.shape[:-2] + tuple(layout.shape))
    return w


def decode_params(tree: Any) -> Any:
    """In-jit dense reconstruction of a ``serving_params``-layout tree:
    every ``<name>_idx``/``<name>_cb`` (or ``<name>_pidx``/``<name>_cb``/
    ``<name>_layout``) group collapses to a dense ``<name>`` leaf.  Under
    jit only the packed arrays are HBM-resident inputs; the dense weights
    are temporaries XLA schedules per use."""
    if isinstance(tree, dict):
        out = {}
        for key, val in tree.items():
            if key.endswith("_idx") and not key.endswith("_pidx"):
                name = key[:-4]
                out[name] = decode_leaf(val, tree[f"{name}_cb"])
            elif key.endswith("_pidx"):
                name = key[:-5]
                out[name] = decode_packed_leaf(val, tree[f"{name}_cb"],
                                               tree[f"{name}_layout"])
            elif key.endswith("_cb") and (f"{key[:-3]}_idx" in tree
                                          or f"{key[:-3]}_pidx" in tree):
                continue
            elif key.endswith("_layout") and f"{key[:-7]}_pidx" in tree:
                continue
            else:
                out[key] = decode_params(val)
        return out
    if isinstance(tree, (tuple, list)):
        return type(tree)(decode_params(v) for v in tree)
    return tree
