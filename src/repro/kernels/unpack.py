"""In-VMEM bit-unpack + codebook-dequant micro-library.

The three packed-serving kernels (``codebook_matmul_packed``,
``codebook_matmul_packed_t``, ``quantized_gather``) all do the same two
VPU-friendly steps on a uint32 word tile that just DMA'd into VMEM:

1. **shift+mask unpack** — each word holds ``lanes = 32 // bits``
   little-endian indices at a fixed ``bits = bits_per_index(K)`` width
   (no straddling); a broadcasted-iota shift plus an AND expands the word
   tile to an index tile;
2. **dequant** — a K-entry LUT gather ``cb[idx]`` (O(1) in K), or the
   MXU-shaped one-hot contraction fallback for Mosaic versions that lower
   small-table gathers poorly (``REPRO_DEQUANT=onehot``).

Two unpack orientations cover every packed operand layout:

* :func:`unpack_words_axis0` — words tile the *leading* axis
  (``pack_indices_2d``: word (w, n) holds rows w·lanes+l of column n) —
  the forward-matmul reduction layout;
* :func:`unpack_words_axis1` — words tile the *trailing* axis
  (``pack_rows``: word (r, w) holds columns w·lanes+l of row r) — the
  row-gather / transposed-matmul layout.

Everything here is shape-static jnp, safe both inside a Pallas kernel
body and in plain jit (the CPU reference paths reuse it).

Bit-layout contract: these unpacks must stay bit-compatible with the
host-side packers ``compression.pack_indices_2d`` / ``pack_rows`` (whose
jit-friendly inverses ``unpack_indices_2d`` / ``unpack_rows`` live in
core, deliberately not imported here to keep the kernels layer free of a
core→kernels cycle).  The pack→in-kernel-unpack roundtrips in
tests/test_packed_kernel.py and the differential matrix pin the
compatibility.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def unpack_words_axis0(words: Array, bits: int) -> Array:
    """[W, N] uint32 → [W·lanes, N] int32: lane l of word (w, n) lands at
    row w·lanes + l (the ``pack_indices_2d`` orientation)."""
    lanes = 32 // bits
    w, n = words.shape
    shifts = (jax.lax.broadcasted_iota(jnp.uint32, (w, lanes, n), 1)
              * jnp.uint32(bits))
    mask = jnp.uint32((1 << bits) - 1)
    idx = (words[:, None, :] >> shifts) & mask
    return idx.reshape(w * lanes, n).astype(jnp.int32)


def unpack_words_axis1(words: Array, bits: int) -> Array:
    """[R, W] uint32 → [R, W·lanes] int32: lane l of word (r, w) lands at
    column w·lanes + l (the ``pack_rows`` orientation)."""
    lanes = 32 // bits
    r, w = words.shape
    shifts = (jax.lax.broadcasted_iota(jnp.uint32, (r, w, lanes), 2)
              * jnp.uint32(bits))
    mask = jnp.uint32((1 << bits) - 1)
    idx = (words[:, :, None] >> shifts) & mask
    return idx.reshape(r, w * lanes).astype(jnp.int32)


def dequant_tile(idx: Array, cb: Array, k_entries: int, dequant: str) -> Array:
    """[R, C] int32 indices + [K] codebook → [R, C] float weights.

    ``dequant="lut"``: K-entry gather, O(R·C) independent of K.
    ``dequant="onehot"``: one-hot contraction, O(R·C·K) but MXU-shaped —
    the fallback for Mosaic versions that lower small gathers poorly.
    """
    if dequant == "lut":
        return jnp.take(cb, idx, axis=0)
    r, c = idx.shape
    onehot = (idx[:, :, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (r, c, k_entries), 2))
    return jnp.sum(onehot.astype(cb.dtype) * cb[None, None, :], axis=2)
