"""Pallas TPU kernel: embedding dequant-on-gather over bit-packed rows.

out[T, D] = codebook[unpack(pidx[tokens])] where the embedding table
[V, D] is stored in the ``pack_rows`` layout — uint32 ``pidx[V, ⌈D/lanes⌉]``,
each word holding ``lanes = 32 // bits`` consecutive *feature-axis*
indices of one vocab row.  The token ids are a scalar-prefetch operand
(``pltpu.PrefetchScalarGridSpec``), so each grid step DMAs exactly one
packed word row — ``⌈D/lanes⌉ · 4`` bytes, i.e. ``bits_per_index(K)/8``
bytes per gathered weight — then shift+mask-unpacks it and LUT-gathers
the K-entry codebook in VMEM (``kernels.unpack``).

This replaces the jnp fallback over the PR-3 column-packed layout, which
gathered one full uint32 word per embedding *column* (4 B/weight): the
packed-row layout + fused kernel close the last dense-inflation gap of
the eq.-14 serving story.  The jnp route (``dispatch.quantized_gather``)
is retained as the CPU reference.

The dense [V, D] table is never materialized; the only f32 HBM write is
the [T, D] result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compression import bits_per_index
from repro.kernels.unpack import dequant_tile, unpack_words_axis1


def _kernel(tokens_ref, pidx_ref, cb_ref, o_ref, *, k_entries: int,
            bits: int, dequant: str):
    del tokens_ref                 # consumed by the index maps
    words = pidx_ref[...]                             # [1, Dw] uint32
    idx = unpack_words_axis1(words, bits)             # [1, Dw·lanes]
    o_ref[...] = dequant_tile(idx, cb_ref[0, :], k_entries, dequant)


def quantized_gather_pallas(
    tokens: jax.Array,       # [T] int32 row ids
    pidx: jax.Array,         # [V, ⌈D/lanes⌉] uint32 pack_rows words
    codebook: jax.Array,     # [K] float
    d: int,                  # true feature dim D (≤ ⌈D/lanes⌉·lanes)
    *,
    dequant: str = "lut",
    interpret: bool = False,
) -> jax.Array:
    if tokens.ndim != 1:
        raise ValueError(f"tokens must be flat [T], got {tokens.shape}")
    k_entries = codebook.shape[0]
    bits = bits_per_index(k_entries)
    lanes = 32 // bits
    v, wd = pidx.shape
    if wd != -(-d // lanes):
        raise ValueError(f"pidx cols {wd} != ceil({d}/{lanes}) — operand "
                         f"not in pack_rows layout for K={k_entries}")
    if dequant not in ("lut", "onehot"):
        raise ValueError(f"dequant={dequant!r}; choose lut|onehot")
    dp = wd * lanes

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tokens.shape[0],),
        in_specs=[
            pl.BlockSpec((1, wd), lambda t, toks: (toks[t], 0)),
            pl.BlockSpec((1, k_entries), lambda t, toks: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dp), lambda t, toks: (t, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, k_entries=k_entries, bits=bits,
                          dequant=dequant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((tokens.shape[0], dp), jnp.float32),
        interpret=interpret,
    )(tokens.astype(jnp.int32), pidx, codebook.reshape(1, -1))
    return out[:, :d]
