"""Pallas TPU kernels: blockwise (chunked-prompt) prefill attention.

One prompt chunk of C query tokens attends over a stored K/V view of S
rows — the engine's page-gathered slot view, or the one-shot oracle's
growing prefill buffer.  The jnp route (``kernels.ref.
blockwise_prefill_ref``) materializes full [C, S] score tiles per scan
step in f32; this kernel never does:

* softmax is **online** (flash-style running max / normalizer in VMEM
  scratch — the same recurrence as ``kernels.paged_attention``), so
  VMEM holds one ``token_tile`` of K/V at a time regardless of S: the
  prefill VMEM footprint is flat in prompt length;
* visibility is position-derived: a view row with ``k_pos > q_pos``
  (future tokens, another tenant's stale ring rows, the dispatch
  route's ``POS_SENTINEL`` padding) masks to exact +0 probability, so
  trailing all-masked tiles are bitwise no-ops — the property that
  keeps engine (fixed-capacity view) and oracle (growing view) streams
  bit-equal;
* the ``_quant`` variant reads **codebook-quantized** pages: uint32
  words in the ``pack_rows`` layout plus per-page codebooks
  (``core.kvquant``), unpacked shift+mask and LUT-dequantized in VMEM
  via ``kernels.unpack`` — K/V HBM traffic at ``kv_bits/8`` bytes per
  cached scalar on the one remaining dense-compute path.

Grid: ``(B, S // token_tile)`` — the KV-tile axis is innermost so the
per-chunk accumulator scratch carries across tiles and the output block
is written once on the last tile.  Routed + block-autotuned through
``dispatch.blockwise_prefill_attention[_quant]``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kvquant import kv_entries, words_per
from repro.kernels.paged_attention import _dequant_kv_tile

NEG_INF = -1e30
_EPS = 1e-30


def _softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _prefill_body(q_ref, k, v, qp_ref, kp_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, j, nj, window, softcap, scale):
    """Shared tile step.  k/v: [bt, KV, hd/vd] f32 (already dequant).

    Scratch: m/l [KV, rep, C], acc [KV, rep, C, vd] — the masked flash
    recurrence of ``ref.blockwise_prefill_ref``, tile-for-tile.
    """
    c, h, hd = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    kv, vd = k.shape[1], v.shape[2]
    rep = h // kv
    qg = q_ref[0].astype(jnp.float32).reshape(c, kv, rep, hd)
    qg = qg.transpose(1, 2, 0, 3)                    # [KV, rep, C, hd]
    kt = k.transpose(1, 0, 2)                        # [KV, bt, hd]
    vt = v.transpose(1, 0, 2)                        # [KV, bt, vd]
    # [KV, rep, C, bt]: contract hd, batch the kv-head group
    logits = jax.lax.dot_general(
        qg, kt, (((3,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, softcap)
    qpos = qp_ref[0]                                 # [C]
    kpos = kp_ref[0]                                 # [bt]
    ok = kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    ok = jnp.broadcast_to(ok[None, None, :, :], logits.shape)
    logits = jnp.where(ok, logits, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    p = jnp.where(ok, jnp.exp(logits - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    # [KV, rep, C, vd]: contract bt, batch the kv-head group
    pv = jax.lax.dot_general(
        p, vt, (((3,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv

    @pl.when(j == nj - 1)
    def _():
        o = acc_ref[...] / jnp.maximum(l_ref[...], _EPS)[..., None]
        o_ref[0] = o.transpose(2, 0, 1, 3).reshape(c, h, vd)


def _prefill_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref, m_ref,
                    l_ref, acc_ref, *, nj, window, softcap, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    _prefill_body(q_ref, k_ref[0].astype(jnp.float32),
                  v_ref[0].astype(jnp.float32), qp_ref, kp_ref, o_ref,
                  m_ref, l_ref, acc_ref, j=j, nj=nj, window=window,
                  softcap=softcap, scale=scale)


def _prefill_quant_kernel(q_ref, kw_ref, vw_ref, kcb_ref, vcb_ref, qp_ref,
                          kp_ref, o_ref, m_ref, l_ref, acc_ref, *, nj,
                          window, softcap, scale, head_dim, bits, dequant):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    k = _dequant_kv_tile(kw_ref[0], kcb_ref[0, 0], head_dim=head_dim,
                         bits=bits, dequant=dequant)
    v = _dequant_kv_tile(vw_ref[0], vcb_ref[0, 0], head_dim=head_dim,
                         bits=bits, dequant=dequant)
    _prefill_body(q_ref, k, v, qp_ref, kp_ref, o_ref, m_ref, l_ref,
                  acc_ref, j=j, nj=nj, window=window, softcap=softcap,
                  scale=scale)


def blockwise_prefill_pallas(q, k, v, q_pos, k_pos, *, window=None,
                             softcap=None, scale, token_tile,
                             interpret=False):
    """q [B,C,H,hd]; k [B,S,KV,hd]; v [B,S,KV,vd]; q_pos [C]; k_pos [S]
    int32 (S a multiple of ``token_tile``; padded rows carry the
    sentinel position) → [B, C, H, vd] f32."""
    b, c, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    if s % token_tile:
        raise ValueError(f"view rows {s} not a multiple of "
                         f"token_tile={token_tile}")
    nj = s // token_tile
    rep = h // kv
    bt = token_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(b, nj),
        in_specs=[
            pl.BlockSpec((1, c, h, hd), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, bt, kv, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, bt, kv, vd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, c), lambda b, j: (0, 0)),
            pl.BlockSpec((1, bt), lambda b, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, c, h, vd), lambda b, j: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, rep, c), jnp.float32),
            pltpu.VMEM((kv, rep, c), jnp.float32),
            pltpu.VMEM((kv, rep, c, vd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, nj=nj, window=window,
                          softcap=softcap, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, h, vd), jnp.float32),
        interpret=interpret,
    )(q, k, v, q_pos.astype(jnp.int32)[None, :],
      k_pos.astype(jnp.int32)[None, :])
    return out


def blockwise_prefill_quant_pallas(q, k_words, v_words, k_cb, v_cb, q_pos,
                                   k_pos, *, page_size, bits, head_dim,
                                   window=None, softcap=None, scale,
                                   token_tile, dequant="lut",
                                   interpret=False):
    """Quantized-page view: words [B, S, KV, Wd] uint32 (S = pages·page,
    logical row order) + per-page codebooks [B, npg, Gcb, K]
    → [B, C, H, hd] f32.  ``token_tile`` must divide ``page_size`` so a
    K/V tile's codebook is a single page's."""
    b, c, h, hd = q.shape
    s, kv, wd = k_words.shape[1], k_words.shape[2], k_words.shape[3]
    if wd != words_per(head_dim, bits):
        raise ValueError(f"word operand width {wd} != "
                         f"ceil({head_dim}/lanes) for kv_bits={bits}")
    gcb, k_ent = k_cb.shape[2], k_cb.shape[3]
    if k_ent != kv_entries(bits):
        raise ValueError(f"codebook K={k_ent} != 2**{bits}")
    if page_size % token_tile or s % page_size:
        raise ValueError(f"token_tile={token_tile} must divide "
                         f"page_size={page_size} (view rows {s})")
    nj = s // token_tile
    tpp = page_size // token_tile
    rep = h // kv
    bt = token_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(b, nj),
        in_specs=[
            pl.BlockSpec((1, c, h, hd), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, bt, kv, wd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, bt, kv, wd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, 1, gcb, k_ent),
                         lambda b, j: (b, j // tpp, 0, 0)),
            pl.BlockSpec((1, 1, gcb, k_ent),
                         lambda b, j: (b, j // tpp, 0, 0)),
            pl.BlockSpec((1, c), lambda b, j: (0, 0)),
            pl.BlockSpec((1, bt), lambda b, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, c, h, hd), lambda b, j: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, rep, c), jnp.float32),
            pltpu.VMEM((kv, rep, c), jnp.float32),
            pltpu.VMEM((kv, rep, c, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_prefill_quant_kernel, nj=nj, window=window,
                          softcap=softcap, scale=scale, head_dim=head_dim,
                          bits=bits, dequant=dequant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, h, hd), jnp.float32),
        interpret=interpret,
    )(q, k_words, v_words, k_cb, v_cb,
      q_pos.astype(jnp.int32)[None, :], k_pos.astype(jnp.int32)[None, :])
    return out
