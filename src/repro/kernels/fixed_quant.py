"""Pallas TPU kernel: elementwise fixed-codebook quantization operators.

Tiled VMEM application of the paper's closed-form quantizers (fig. 5 /
Theorems A.1): binary sign, ternary threshold, powers-of-two exponent
rounding.  Scale-solving variants (Thms A.2/A.3) are reductions solved in
repro.core.quant_ops / repro.dist.cstep; given the scale ``a`` this kernel
applies them too (pass ``scale=a``).

Mostly VPU work — included because the C step streams *every* weight in
the model through exactly this op each LC iteration, so on TPU it should
run fused at HBM bandwidth rather than as a chain of XLA elementwise ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R, TILE_C = 8, 1024
MODES = ("binary", "ternary", "pow2")


def _kernel(w_ref, o_ref, *, mode: str, pow2_c: int, scale: float):
    w = w_ref[...].astype(jnp.float32) / scale
    sgn = jnp.where(w >= 0, 1.0, -1.0)
    aw = jnp.abs(w)
    if mode == "binary":
        q = sgn
    elif mode == "ternary":
        q = sgn * (aw >= 0.5).astype(jnp.float32)
    else:  # pow2 (Theorem A.1)
        safe = jnp.where(aw > 0, aw, 1.0)
        f = -jnp.log2(safe)
        f = jnp.where(aw > 0, f, jnp.inf)
        mid = jnp.floor(f + jnp.log2(1.5))
        alpha = jnp.where(
            f > pow2_c + 1, 0.0,
            jnp.where(f <= 0.0, 1.0,
                      jnp.where(f > pow2_c, 2.0 ** (-pow2_c),
                                jnp.exp2(-mid))))
        q = sgn * alpha
    o_ref[...] = (q * scale).astype(o_ref.dtype)


def fixed_quant_pallas(w: jax.Array, mode: str, *, pow2_c: int = 4,
                       scale: float = 1.0, interpret: bool = False
                       ) -> jax.Array:
    """Quantize ``w`` (any shape) with a fixed codebook; returns same shape."""
    assert mode in MODES, mode
    shape = w.shape
    flat = w.reshape(-1)
    p = flat.shape[0]
    cols = TILE_R * TILE_C
    pad = (-p) % cols
    wp = jnp.pad(flat, (0, pad)).reshape(-1, TILE_C)
    rows = wp.shape[0]
    grid = (rows // TILE_R,)

    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode, pow2_c=pow2_c, scale=scale),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_R, TILE_C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_R, TILE_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(wp.shape, w.dtype),
        interpret=interpret,
    )(wp)
    return out.reshape(-1)[:p].reshape(shape)
