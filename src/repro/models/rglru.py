"""Griffin / RecurrentGemma recurrent block: RG-LRU + depthwise conv.

Block (De et al. 2024, arXiv:2402.19427):
  x → [linear → GeLU]  (gate branch)
    → [linear → causal conv(4) → RG-LRU]  (recurrent branch)
  y = gate ⊙ rec → out-proj.

RG-LRU recurrence (per channel):
  r_t = σ(W_a x_t + b_a)           recurrence gate
  i_t = σ(W_x x_t + b_x)           input gate
  a_t = exp(-c · softplus(Λ) · r_t),  c = 8
  h_t = a_t h_{t-1} + √(1 - a_t²) · (i_t ⊙ x_t)

Training uses ``jax.lax.associative_scan`` over the sequence (O(S log S)
depth, sub-quadratic — this is why recurrentgemma runs the ``long_500k``
cell).  Decode is an O(1) state update.

Unquantized leaves: ``a_param`` (Λ), gates' biases, ``conv1d_w``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.qleaf import qmatmul
from repro.models.sharding_ctx import constrain

Array = jax.Array
_C = 8.0


def init_rglru_block(key, d_model, width, conv_w=4, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    sw = width ** -0.5
    return {
        "w_rec_in": (jax.random.normal(ks[0], (d_model, width)) * s).astype(dtype),
        "w_gate_in": (jax.random.normal(ks[1], (d_model, width)) * s).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (width, d_model)) * sw).astype(dtype),
        "conv1d_w": (jax.random.normal(ks[3], (conv_w, width)) * 0.1).astype(dtype),
        "w_a_gate": (jax.random.normal(ks[4], (width, width)) * sw).astype(dtype),
        "w_x_gate": (jax.random.normal(ks[5], (width, width)) * sw).astype(dtype),
        "a_gate_bias": jnp.zeros((width,), dtype),
        "x_gate_bias": jnp.zeros((width,), dtype),
        # Λ init so that a^c = exp(-c softplus Λ) spans ≈ (0.9, 0.999)
        "a_param": jnp.linspace(-4.0, -1.0, width).astype(jnp.float32),
    }


def _rglru_coeffs(p, x):
    """x: [B,S,W] → (a, b) of the recurrence h = a·h_prev + b."""
    r = jax.nn.sigmoid(qmatmul(p, "w_a_gate", x)
                       + p["a_gate_bias"]).astype(jnp.float32)
    i = jax.nn.sigmoid(qmatmul(p, "w_x_gate", x)
                       + p["x_gate_bias"]).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["a_param"]) * r           # [B,S,W]
    a = jnp.exp(log_a)
    # √(1-a²) computed stably: 1-a² = -expm1(2 log a)
    b = jnp.sqrt(-jnp.expm1(2.0 * log_a)) * i * x.astype(jnp.float32)
    return a, b


def _causal_conv(x: Array, w: Array) -> Array:
    wlen = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(wlen):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out


def rglru_forward(p, x, *, width):
    """Training / prefill. x: [B,S,D] → [B,S,D]; returns (y, final_state)."""
    gate = jax.nn.gelu(constrain(qmatmul(p, "w_gate_in", x),
                                 "batch", None, "width"),
                       approximate=True)
    rec = constrain(qmatmul(p, "w_rec_in", x), "batch", None, "width")
    rec = _causal_conv(rec, p["conv1d_w"])
    a, b = _rglru_coeffs(p, rec)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b2 + a2 * b1

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gate.astype(jnp.float32) * h).astype(x.dtype)
    final_state = h[:, -1]
    return qmatmul(p, "w_out", y), final_state


def rglru_block_forward(p, x, cache, *, width):
    """One prompt *block* with carried state — the blockwise-prefill
    step.  x: [B,c,D] + the cache left by the previous blocks →
    (y [B,c,D], new :class:`RGLRUCache`).

    The conv consumes the carried raw tail (zero tail at block 0 =
    bitwise :func:`_causal_conv`'s zero pad); the carried recurrent
    state folds into the first step's additive term — ``b₀ + a₀·h`` —
    before the associative scan, exactly the decode recurrence for that
    step.  Batch-row-decoupled throughout."""
    gate = jax.nn.gelu(qmatmul(p, "w_gate_in", x), approximate=True)
    rec_raw = qmatmul(p, "w_rec_in", x)
    wlen = p["conv1d_w"].shape[0]
    s = rec_raw.shape[1]
    xp = jnp.concatenate([cache.conv.astype(rec_raw.dtype), rec_raw],
                         axis=1)                         # [B,c+W-1,W]
    rec = jnp.zeros_like(rec_raw)
    for i in range(wlen):
        rec = rec + xp[:, i:i + s, :] * p["conv1d_w"][i]
    new_conv = xp[:, s:, :]
    a, b = _rglru_coeffs(p, rec)
    b = b.at[:, 0].add(a[:, 0] * cache.state)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b2 + a2 * b1

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gate.astype(jnp.float32) * h).astype(x.dtype)
    return qmatmul(p, "w_out", y), RGLRUCache(
        state=h[:, -1], conv=new_conv.astype(cache.conv.dtype))


class RGLRUCache(NamedTuple):
    state: Array     # [B, W] fp32
    conv: Array      # [B, conv_w-1, W]


def init_rglru_cache(batch, width, conv_w, dtype):
    return RGLRUCache(state=jnp.zeros((batch, width), jnp.float32),
                      conv=jnp.zeros((batch, conv_w - 1, width), dtype))


def rglru_decode(p, x_t, cache: RGLRUCache, *, width):
    """O(1) decode. x_t: [B,1,D]."""
    xt = x_t[:, 0]
    gate = jax.nn.gelu(qmatmul(p, "w_gate_in", xt), approximate=True)
    rec = qmatmul(p, "w_rec_in", xt)
    conv_in = jnp.concatenate([cache.conv, rec[:, None, :]], axis=1)
    rec = jnp.einsum("bwc,wc->bc", conv_in, p["conv1d_w"])
    a, b = _rglru_coeffs(p, rec[:, None, :])
    h = a[:, 0] * cache.state + b[:, 0]
    y = (gate.astype(jnp.float32) * h).astype(x_t.dtype)
    out = qmatmul(p, "w_out", y)[:, None, :]
    return out, RGLRUCache(state=h, conv=conv_in[:, 1:, :])
