"""The paper's own experiment networks (§5): LeNet300, LeNet5-style conv
net, the 12-layer VGG-style CIFAR net, the single-hidden-layer tradeoff
net (fig. 6), and the super-resolution linear regression (§5.2).

These are deliberately simple (tanh MLPs / small convs, exactly as in the
paper) and are used by the repro benchmarks; the LM zoo lives in
transformer.py.  All params follow the quantization naming convention
(weights ``w``, biases ``*_bias`` — the paper quantizes only the
multiplicative weights).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# MLPs (LeNet300 & fig. 6 tradeoff net)
# ---------------------------------------------------------------------------

def init_mlp_classifier(key: Array, sizes: Sequence[int]) -> dict:
    """sizes = [in, h1, ..., out]; tanh hidden units, softmax output."""
    params = {}
    ks = jax.random.split(key, len(sizes) - 1)
    for i, (din, dout) in enumerate(zip(sizes[:-1], sizes[1:])):
        params[f"fc{i}"] = {
            "w": jax.random.normal(ks[i], (din, dout)) * (1.0 / jnp.sqrt(din)),
            "b_bias": jnp.zeros((dout,)),
        }
    return params


def mlp_logits(params: dict, x: Array) -> Array:
    from repro.models.qleaf import qmatmul
    n = len(params)
    h = x
    for i in range(n):
        p = params[f"fc{i}"]
        h = qmatmul(p, "w", h) + p["b_bias"]
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def lenet300_init(key: Array) -> dict:
    """784-300-100-10 (P1 = 266 200 weights, P0 = 410 biases — paper tbl 1)."""
    return init_mlp_classifier(key, [784, 300, 100, 10])


# ---------------------------------------------------------------------------
# LeNet5-style conv net (paper tbl 1, reduced-friendly)
# ---------------------------------------------------------------------------

def lenet5_init(key: Array, c1: int = 20, c2: int = 50, fc: int = 500,
                num_classes: int = 10) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "conv0": {"w": jax.random.normal(ks[0], (5, 5, 1, c1)) * 0.1,
                  "b_bias": jnp.zeros((c1,))},
        "conv1": {"w": jax.random.normal(ks[1], (5, 5, c1, c2)) * 0.1,
                  "b_bias": jnp.zeros((c2,))},
        "fc0": {"w": jax.random.normal(ks[2], (c2 * 4 * 4, fc)) * 0.02,
                "b_bias": jnp.zeros((fc,))},
        "fc1": {"w": jax.random.normal(ks[3], (fc, num_classes)) * 0.05,
                "b_bias": jnp.zeros((num_classes,))},
    }


def lenet5_logits(params: dict, x: Array) -> Array:
    """x: [B, 28, 28, 1]."""
    from repro.models.qleaf import qmatmul, qweight

    def conv(p, h):
        h = jax.lax.conv_general_dilated(
            h, qweight(p, "w"), window_strides=(1, 1), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu(h + p["b_bias"])

    def pool(h):
        return jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

    h = pool(conv(params["conv0"], x))
    h = pool(conv(params["conv1"], h))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(qmatmul(params["fc0"], "w", h)
                    + params["fc0"]["b_bias"])
    return qmatmul(params["fc1"], "w", h) + params["fc1"]["b_bias"]


def cross_entropy(logits: Array, labels: Array) -> Array:
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def classification_error(logits: Array, labels: Array) -> Array:
    return jnp.mean((jnp.argmax(logits, -1) != labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Super-resolution linear regression (§5.2) — closed-form L step
# ---------------------------------------------------------------------------

def superres_loss(w: Array, b_bias: Array, x: Array, y: Array) -> Array:
    """L(W,b) = (1/N) Σ ||y_n - W x_n - b||²; x:[N,Din], y:[N,Dout]."""
    r = y - x @ w.T - b_bias
    return jnp.mean(jnp.sum(r * r, axis=-1))


def superres_l_step_closed_form(
    x: Array, y: Array, mu: float, wc: Array, lam: Array,
    reg: float = 1e-6) -> Tuple[Array, Array]:
    """Exact argmin_W of L(W,b) + μ/2||W - W_C - λ/μ||² (b solved jointly).

    Normal equations per output row; returns (W [Dout,Din], b [Dout]).
    The μ-penalty adds μ·N/2 to the diagonal in the normalized system.
    """
    n, din = x.shape
    xm = jnp.mean(x, axis=0)
    ym = jnp.mean(y, axis=0)
    xc = x - xm
    yc = y - ym
    # (2/N)·XcᵀXc W + μ(W - Wc - λ/μ) = (2/N)·XcᵀYc   (bias eliminated)
    gram = (2.0 / n) * (xc.T @ xc) + (mu + reg) * jnp.eye(din)
    rhs = (2.0 / n) * (xc.T @ yc) + (mu * wc + lam).T
    w = jnp.linalg.solve(gram, rhs).T
    b = ym - w @ xm
    return w, b
