"""Shared model components: norms, RoPE, MLPs, embeddings, softcaps.

Param-naming conventions matter: the LC quantization policy
(`repro.core.lc.DEFAULT_EXCLUDE`) excludes leaves whose path contains
``bias|scale|norm|router|...`` — so norm gains are called ``norm_scale``,
biases ``*_bias``, etc.  2-D multiplicative weights get quantized.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def softcap(x: Array, cap: Optional[float]) -> Array:
    """Gemma-2 style logit soft-capping: cap·tanh(x/cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                      # [head_dim/2]


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # [hd/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: Array, d_model: int) -> Array:
    """MusicGen-style sinusoidal position embedding [..., S, D]."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "sqrelu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def init_mlp(key: Array, d_model: int, d_ff: int, act: str, gated: bool,
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "w_in": (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[1], (d_ff, d_model)) * s_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * s_in).astype(dtype)
    return p


def apply_mlp(p, x: Array, act: str) -> Array:
    from repro.models.qleaf import has_leaf, qmatmul
    from repro.models.sharding_ctx import constrain
    f = act_fn(act)
    h = qmatmul(p, "w_in", x)
    if has_leaf(p, "w_gate"):
        h = f(qmatmul(p, "w_gate", x)) * h
    else:
        h = f(h)
    h = constrain(h, "batch", None, "ffn")
    return qmatmul(p, "w_out", h)
