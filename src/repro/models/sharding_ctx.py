"""Activation-sharding policy context (GSPMD constraint injection).

Without explicit constraints, XLA's SPMD partitioner free-runs on the
layer loop and (for large per-chip batches) picks the comm-minimal plan:
all-gather the layer weights and **replicate the matmuls across the model
axis** — 16× wasted FLOPs on a 16-way TP mesh (measured on qwen train_4k;
see EXPERIMENTS.md §Perf iteration 0).  Production frameworks pin
activation shardings at block boundaries; this module is that mechanism.

Model code calls ``constrain(x, "batch", None, "heads", None)`` with
*logical* axis names; the active policy maps them to mesh axes and applies
``jax.lax.with_sharding_constraint`` — or no-ops when no policy is set
(single-device tests) or when a dim is not divisible by its mesh axes.

Logical axes: batch, seq, heads, kv_heads, ffn, experts, width, vocab,
embed (all map to "model" except batch → (pod, data)).
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: dict = {"policy": None}

_MODEL_AXES = ("heads", "kv_heads", "ffn", "experts", "width", "vocab",
               "embed", "ssm_heads")


class Policy:
    """mode: "tp" (megatron TP + EP), "dp" (pure data parallel — model
    axis joins the batch axes), or "none"."""

    def __init__(self, mesh: Mesh, mode: str = "tp",
                 seq_axis: Optional[str] = None):
        self.mesh = mesh
        self.mode = mode
        self.daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        self.seq_axis = seq_axis
        sizes = dict(mesh.shape)
        self.model_size = sizes.get("model", 1)
        self.batch_size_div = 1
        for a in self.daxes:
            self.batch_size_div *= sizes[a]

    def axes_for(self, logical: Optional[str], dim: int):
        if logical is None:
            return None, 1
        if logical == "batch":
            if self.mode == "dp":
                axes = self.daxes + ("model",)
                return axes, self.batch_size_div * self.model_size
            if self.mode == "tp2d":
                return None, 1          # decode: tiny activations, replicate
            return self.daxes, self.batch_size_div
        if logical == "seq" and self.seq_axis:
            return self.seq_axis, self.mesh.shape[self.seq_axis]
        if logical in _MODEL_AXES:
            if self.mode == "tp":
                return "model", self.model_size
            if self.mode == "tp2d":
                # weight-stationary decode: channel dims over the full mesh;
                # head dims unconstrained — pinning them forces GSPMD to
                # re-gather the 256-way projection weights to the head
                # grouping (measured 85 MB/layer on nemotron decode);
                # resharding the [B,1,D] activations instead costs ~nothing
                if logical in ("heads", "kv_heads", "ssm_heads"):
                    return None, 1
                axes = ("model",) + self.daxes
                return axes, self.model_size * self.batch_size_div
        return None, 1


@contextlib.contextmanager
def activation_policy(policy: Optional[Policy]):
    prev = _ACTIVE["policy"]
    _ACTIVE["policy"] = policy
    try:
        yield
    finally:
        _ACTIVE["policy"] = prev


def set_policy(policy: Optional[Policy]):
    _ACTIVE["policy"] = policy


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply the active policy's sharding to ``x`` (no-op without one)."""
    pol: Optional[Policy] = _ACTIVE["policy"]
    if pol is None or pol.mode == "none":
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    parts = []
    for dim, name in zip(x.shape, logical):
        axes, size = pol.axes_for(name, dim)
        if axes is None or size <= 1 or dim % size != 0:
            parts.append(None)
        else:
            parts.append(axes)
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pol.mesh, P(*parts)))
