"""Mamba-2 (SSD — state-space duality) block, chunked training + O(1) decode.

Follows the minimal SSD formulation (Dao & Gu 2024, arXiv:2405.21060):
within a chunk the output is a masked quadratic ("attention-like") term;
across chunks a first-order state recurrence carries [H, P, N] states.

Block layout (mamba2 defaults, ngroups=1), with **separate projections**
(z, x, B, C, dt) rather than one fused in_proj: the fused layout would
split unevenly across a tensor-parallel shard of the output dim; separate
projections let z/x shard over the ``model`` axis (heads parallel) while
the tiny B/C/dt projections stay replicated — the SSD scan is then fully
head-parallel with no sequence collectives (DESIGN §6).

Non-quantized leaves (dynamics-sensitive, tiny — see DESIGN §5):
``a_log``, ``dt_*``, ``conv1d_*``, ``norm_scale``, ``d_skip``.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm
from repro.models.qleaf import qmatmul
from repro.models.sharding_ctx import constrain

Array = jax.Array


def init_ssm(key, d_model, *, d_inner, head_p, state_n, conv_w=4,
             dtype=jnp.float32):
    n_heads = d_inner // head_p
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    return {
        "in_z_w": (jax.random.normal(ks[0], (d_model, d_inner)) * s).astype(dtype),
        "in_x_w": (jax.random.normal(ks[1], (d_model, d_inner)) * s).astype(dtype),
        "in_b_w": (jax.random.normal(ks[2], (d_model, state_n)) * s).astype(dtype),
        "in_c_w": (jax.random.normal(ks[3], (d_model, state_n)) * s).astype(dtype),
        "dt_w": (jax.random.normal(ks[4], (d_model, n_heads)) * s).astype(jnp.float32),
        "out_proj_w": (jax.random.normal(ks[5], (d_inner, d_model))
                       * d_inner ** -0.5).astype(dtype),
        "conv1d_x_w": (jnp.zeros((conv_w, d_inner)) .at[-1].set(1.0)).astype(dtype),
        "conv1d_b_w": (jnp.zeros((conv_w, state_n)).at[-1].set(1.0)).astype(dtype),
        "conv1d_c_w": (jnp.zeros((conv_w, state_n)).at[-1].set(1.0)).astype(dtype),
        "a_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype),
    }


def _causal_conv(x: Array, w: Array) -> Array:
    """Depthwise causal conv: x [B,S,C], w [W,C] → [B,S,C]."""
    wlen = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(wlen):                  # W=4: tiny static unroll
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, h0=None):
    """Minimal SSD scan.

    x:[B,L,H,P], dt:[B,L,H] (softplus'd), a:[H] (negative),
    b_mat,c_mat:[B,L,N] (ngroups=1, shared across heads).
    ``h0`` [B,H,P,N] fp32 seeds the cross-chunk recurrence (None →
    zeros, the from-scratch case — bitwise the old behaviour).
    Returns y:[B,L,H,P] and final state [B,H,P,N].
    """
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    nc = l // chunk
    assert nc * chunk == l, (l, chunk)

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    da = dtc * a[None, None, None, :]                   # [B,NC,Q,H]
    da_cs = jnp.cumsum(da, axis=2)                      # within-chunk cumsum
    # intra-chunk (masked quadratic) term
    # L_mat[b,c,h,i,j] = exp(da_cs[i] - da_cs[j]) for i >= j else 0.
    # Mask BEFORE exp: masked diffs are positive and would overflow to inf,
    # poisoning the where-gradient (inf·0 = nan).
    diff = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]   # [B,NC,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    lmat = jnp.exp(diff)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)          # [B,NC,Qi,Qj]
    xdt = xc * dtc[..., None]                           # [B,NC,Q,H,P]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp",
                         cb.astype(jnp.float32), lmat, xdt.astype(jnp.float32))

    # chunk summaries: state contribution of each chunk
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)          # [B,NC,Q,H]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                        bc.astype(jnp.float32), decay_to_end, xdt.astype(jnp.float32))
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))                   # [B,NC,H]

    def scan_body(h_prev, inp):
        st, dec = inp                                   # [B,H,P,N],[B,H]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    h_final, h_before = jax.lax.scan(
        scan_body, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)        # [B,NC,H,P,N]

    # inter-chunk term: contribution of carried state to each position
    decay_from_start = jnp.exp(da_cs)                   # [B,NC,Q,H]
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         cc.astype(jnp.float32), decay_from_start, h_before)

    y = (y_intra + y_inter).reshape(bsz, l, h, p)
    return y.astype(x.dtype), h_final


def ssm_forward(p, x, *, d_inner, head_p, state_n, chunk=256):
    """Training / prefill forward. x: [B,S,D] → [B,S,D] (+ final state)."""
    bsz, s, _ = x.shape
    h = d_inner // head_p
    z = constrain(qmatmul(p, "in_z_w", x), "batch", None, "width")
    xin = constrain(qmatmul(p, "in_x_w", x), "batch", None, "width")
    xin = jax.nn.silu(_causal_conv(xin, p["conv1d_x_w"]))
    b_mat = jax.nn.silu(_causal_conv(qmatmul(p, "in_b_w", x),
                                     p["conv1d_b_w"]))
    c_mat = jax.nn.silu(_causal_conv(qmatmul(p, "in_c_w", x),
                                     p["conv1d_c_w"]))
    dt = jax.nn.softplus((x @ p["dt_w"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = constrain(xin.reshape(bsz, s, h, head_p),
                   "batch", None, "ssm_heads", None)
    y, state = ssd_chunked(xh, dt, a, b_mat, c_mat, chunk)
    y = (y + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
         ).astype(x.dtype)
    y = y.reshape(bsz, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return qmatmul(p, "out_proj_w", y), state


class SSMCache(NamedTuple):
    state: Array       # [B, H, P, N] fp32
    conv_x: Array      # [B, W-1, d_inner]
    conv_b: Array      # [B, W-1, N]
    conv_c: Array      # [B, W-1, N]


def init_ssm_cache(batch, d_inner, head_p, state_n, conv_w, dtype):
    h = d_inner // head_p
    return SSMCache(
        state=jnp.zeros((batch, h, head_p, state_n), jnp.float32),
        conv_x=jnp.zeros((batch, conv_w - 1, d_inner), dtype),
        conv_b=jnp.zeros((batch, conv_w - 1, state_n), dtype),
        conv_c=jnp.zeros((batch, conv_w - 1, state_n), dtype))


def _conv_step(tail: Array, new: Array, w: Array) -> Tuple[Array, Array]:
    """tail [B,W-1,C], new [B,C] → (out [B,C], new tail)."""
    window = jnp.concatenate([tail, new[:, None, :]], axis=1)   # [B,W,C]
    out = jnp.einsum("bwc,wc->bc", window, w)
    return out, window[:, 1:, :]


def _conv_tail_apply(tail: Array, x: Array, w: Array
                     ) -> Tuple[Array, Array]:
    """Depthwise causal conv over a block with a carried left context:
    tail [B,W-1,C] (raw pre-conv values of the previous W-1 positions, or
    zeros at position 0 — then bitwise = :func:`_causal_conv`'s zero
    pad), x [B,S,C] → (out [B,S,C], new tail [B,W-1,C]).  Handles
    S < W-1 streaming: the new tail spans the old tail + block."""
    wlen = w.shape[0]
    s = x.shape[1]
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # [B,S+W-1,C]
    out = jnp.zeros_like(x)
    for i in range(wlen):                  # W=4: tiny static unroll
        out = out + xp[:, i:i + s, :] * w[i]
    return out, xp[:, s:, :]


def ssm_block_forward(p, x, cache: SSMCache, *, d_inner, head_p, state_n,
                      chunk=256):
    """One prompt *block* with carried state — the blockwise-prefill
    step.  x: [B,c,D] (the block's c new tokens) + the cache left by the
    previous blocks → (y [B,c,D], new cache).

    Semantics: the depthwise convs consume the carried raw tails
    (:func:`_conv_tail_apply` — at block 0 the zero tails make this
    bitwise :func:`_causal_conv`), the SSD scan is seeded with the
    carried [B,H,P,N] state, and the block is zero-padded up to an
    ``ssm_chunk`` multiple *after* the convs/gates so pad rows carry
    dt = 0 — zero state contribution, unit decay — and are sliced off.
    Every op is batch-row-decoupled, so the engine's B=1 stream and the
    oracle's batched stream agree bitwise given the same partition."""
    bsz, c, _ = x.shape
    h = d_inner // head_p
    z = qmatmul(p, "in_z_w", x)
    xin, conv_x = _conv_tail_apply(cache.conv_x, qmatmul(p, "in_x_w", x),
                                   p["conv1d_x_w"])
    b_mat, conv_b = _conv_tail_apply(cache.conv_b, qmatmul(p, "in_b_w", x),
                                     p["conv1d_b_w"])
    c_mat, conv_c = _conv_tail_apply(cache.conv_c, qmatmul(p, "in_c_w", x),
                                     p["conv1d_c_w"])
    xin = jax.nn.silu(xin)
    b_mat = jax.nn.silu(b_mat)
    c_mat = jax.nn.silu(c_mat)
    dt = jax.nn.softplus((x @ p["dt_w"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    pad = (-c) % chunk
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xh = xin.reshape(bsz, c + pad, h, head_p)
    y, state = ssd_chunked(xh, dt, a, b_mat, c_mat, chunk, h0=cache.state)
    y = (y[:, :c] + xh[:, :c] * p["d_skip"][None, None, :, None]
         .astype(x.dtype)).astype(x.dtype)
    y = y.reshape(bsz, c, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return qmatmul(p, "out_proj_w", y), SSMCache(
        state=state, conv_x=conv_x.astype(cache.conv_x.dtype),
        conv_b=conv_b.astype(cache.conv_b.dtype),
        conv_c=conv_c.astype(cache.conv_c.dtype))


def ssm_decode(p, x_t, cache: SSMCache, *, d_inner, head_p, state_n):
    """O(1) single-token decode. x_t: [B,1,D]."""
    bsz = x_t.shape[0]
    h = d_inner // head_p
    xt = x_t[:, 0]
    z = qmatmul(p, "in_z_w", xt)
    xin_raw = qmatmul(p, "in_x_w", xt)
    b_raw = qmatmul(p, "in_b_w", xt)
    c_raw = qmatmul(p, "in_c_w", xt)
    xin, conv_x = _conv_step(cache.conv_x, xin_raw, p["conv1d_x_w"])
    b_mat, conv_b = _conv_step(cache.conv_b, b_raw, p["conv1d_b_w"])
    c_mat, conv_c = _conv_step(cache.conv_c, c_raw, p["conv1d_c_w"])
    xin, b_mat, c_mat = (jax.nn.silu(xin), jax.nn.silu(b_mat),
                         jax.nn.silu(c_mat))
    dt = jax.nn.softplus((xt @ p["dt_w"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)                                             # [B,H]
    xh = xin.reshape(bsz, h, head_p)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh.astype(jnp.float32),
                     b_mat.astype(jnp.float32))
    state = cache.state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c_mat.astype(jnp.float32))
    y = y.astype(x_t.dtype) + xh * p["d_skip"][None, :, None].astype(x_t.dtype)
    y = y.reshape(bsz, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    out = qmatmul(p, "out_proj_w", y)[:, None, :]
    return out, SSMCache(state=state, conv_x=conv_x, conv_b=conv_b,
                         conv_c=conv_c)
