"""Attention: chunked (flash-style) training path + KV-cache decode paths.

* ``chunked_attention`` — pure-jnp online-softmax attention over KV chunks
  (memory O(S·chunk) instead of O(S²)); supports GQA head broadcasting,
  causal masking, sliding windows (banded compute: local layers only touch
  the ``window + q_chunk`` KV band ⇒ O(S·W) FLOPs, not O(S²)), and
  Gemma-2-style attention-logit softcap.
* GQA with full or ring-buffer (sliding-window) caches for decode.
* MLA (DeepSeek-V2 multi-head latent attention): trains on the expanded
  K/V; decodes in the *compressed* space via the matrix-absorption trick,
  so the cache is [S, kv_lora + rope_dim] per token regardless of heads.

Causal-waste note (roofline): the global-attention training path scans all
KV chunks per query chunk and masks the upper triangle ⇒ HLO FLOPs ≈ 2×
useful attention FLOPs.  This shows up honestly in the MODEL_FLOPS /
HLO_FLOPs ratio and is one of the §Perf hillclimb levers (banded/triangle
scheduling).  Local (windowed) layers already avoid it.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kvquant
from repro.kernels import dispatch
from repro.models.layers import apply_rope, softcap
from repro.models.qleaf import qmatmul, qweight
from repro.models.sharding_ctx import constrain

Array = jax.Array
NEG_INF = -1e30


def _mask_bias(q_pos: Array, k_pos: Array, window: Optional[int]) -> Array:
    """[Sq, Sk] additive bias: causal (+ sliding window if given)."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)


def chunked_attention(
    q: Array, k: Array, v: Array,
    q_positions: Array, k_positions: Array,
    *,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    scale: Optional[float] = None,
    causal_unroll: bool = False,
) -> Array:
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd]; positions: [Sq],[Sk] (global ids).

    Returns [B,Sq,H,hd].  GQA: H must be a multiple of KV.
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    vd = v.shape[-1]                       # value dim may differ (MLA)
    rep = h // kv
    scale = scale if scale is not None else hd ** -0.5
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    nq, nk = sq // qc, sk // kc
    assert nq * qc == sq and nk * kc == sk, (sq, sk, qc, kc)

    # [nq, B, qc, H, hd]
    qs = q.reshape(b, nq, qc, h, hd).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(nq, qc)

    if window is not None and sk > kc:
        return _banded_attention(qs, qp, k, v, k_positions, window, rep,
                                 scale, attn_softcap, qc, kc, b, h, hd, vd, sq)

    if causal_unroll and sq == sk and nq <= 8:
        return _triangular_attention(qs, qp, k, v, k_positions, rep, scale,
                                     attn_softcap, qc, kc, b, h, hd, vd, sq,
                                     window)

    ks = k.reshape(b, nk, kc, kv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kc, kv, vd).transpose(1, 0, 2, 3, 4)
    kp = k_positions.reshape(nk, kc)

    def q_body(_, qblk):
        qi, qpos = qblk                                   # [B,qc,H,hd], [qc]

        def kv_body(carry, kblk):
            m, l, o = carry
            ki, vi, kpos = kblk
            # logits [B, KV, rep, qc, kc]
            qg = qi.reshape(b, qc, kv, rep, hd)
            logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, ki,
                                preferred_element_type=jnp.float32) * scale
            logits = softcap(logits, attn_softcap)
            logits = logits + _mask_bias(qpos, kpos, window)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkrqs,bskd->bkrqd", p.astype(vi.dtype), vi)
            o = o * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l, o), None

        m0 = jnp.full((b, kv, rep, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, rep, qc), jnp.float32)
        o0 = jnp.zeros((b, kv, rep, qc, vd), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_body, (m0, l0, o0), (ks, vs, kp))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        # [B,KV,rep,qc,vd] -> [B,qc,H,vd]
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, vd)
        return None, o.astype(qi.dtype)

    _, outs = jax.lax.scan(q_body, None, (qs, qp))        # [nq,B,qc,H,vd]
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, vd)


def _triangular_attention(qs, qp, k, v, k_positions, rep, scale,
                          attn_softcap, qc, kc, b, h, hd, vd, sq, window):
    """Causal attention with a statically-unrolled triangular schedule:
    q chunk i attends only k[: (i+1)·qc] — no fully-masked blocks are ever
    computed (the scan path burns ~2× attention FLOPs on them).  Used when
    nq ≤ 8 so the unrolled HLO stays small (§Perf qwen iteration 3)."""
    kv = k.shape[2]
    nq = qs.shape[0]
    outs = []
    for i in range(nq):
        end = (i + 1) * qc
        qi, qpos = qs[i], qp[i]
        ki, vi = k[:, :end], v[:, :end]
        kpos = k_positions[:end]
        qg = qi.reshape(b, qc, kv, rep, hd)
        logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, ki,
                            preferred_element_type=jnp.float32) * scale
        logits = softcap(logits, attn_softcap)
        logits = logits + _mask_bias(qpos, kpos, window)
        m = logits.max(axis=-1, keepdims=True)
        p = jnp.exp(logits - m)
        o = jnp.einsum("bkrqs,bskd->bkrqd", p.astype(vi.dtype), vi)
        o = o / p.sum(axis=-1, keepdims=True).astype(o.dtype)
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, vd))
    return jnp.concatenate(outs, axis=1).astype(qs.dtype)


def _banded_attention(qs, qp, k, v, k_positions, window, rep, scale,
                      attn_softcap, qc, kc, b, h, hd, vd, sq):
    """Sliding-window path: each q chunk reads only its KV band."""
    kv = k.shape[2]
    sk = k.shape[1]
    band = ((window + qc - 1) // kc + 1) * kc             # static band length
    band = min(band + kc, sk)                             # cover chunk offset
    nq = qs.shape[0]

    def q_body(_, xs):
        qi, qpos, idx = xs
        q_start = idx * qc
        start = jnp.clip(q_start + qc - band, 0, sk - band)
        ki = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        kpos = jax.lax.dynamic_slice_in_dim(k_positions, start, band, axis=0)
        qg = qi.reshape(b, qc, kv, rep, hd)
        logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, ki,
                            preferred_element_type=jnp.float32) * scale
        logits = softcap(logits, attn_softcap)
        logits = logits + _mask_bias(qpos, kpos, window)
        m = logits.max(axis=-1, keepdims=True)
        p = jnp.exp(logits - m)
        o = jnp.einsum("bkrqs,bskd->bkrqd", p.astype(vi.dtype), vi)
        o = o / p.sum(axis=-1, keepdims=True).astype(o.dtype)
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, vd)
        return None, o.astype(qi.dtype)

    idxs = jnp.arange(nq)
    _, outs = jax.lax.scan(q_body, None, (qs, qp, idxs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, vd)


# ---------------------------------------------------------------------------
# GQA block (params + train / prefill / decode)
# ---------------------------------------------------------------------------

def init_gqa(key, d_model, n_heads, n_kv, head_dim, qkv_bias=False,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_kv * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_kv * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads * head_dim, d_model))
               * (n_heads * head_dim) ** -0.5).astype(dtype),
    }
    if qkv_bias:
        p["q_bias"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["k_bias"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["v_bias"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _qkv(p, x, n_heads, n_kv, head_dim):
    """q/k/v projections; each weight may be dense or a quantized leaf
    (``serving_params`` layouts) — qleaf routes to the codebook-matmul
    kernels in that case."""
    b, s, _ = x.shape
    q = qmatmul(p, "wq", x)
    k = qmatmul(p, "wk", x)
    v = qmatmul(p, "wv", x)
    if "q_bias" in p:
        q, k, v = q + p["q_bias"], k + p["k_bias"], v + p["v_bias"]
    q = constrain(q.reshape(b, s, n_heads, head_dim),
                  "batch", None, "heads", None)
    k = constrain(k.reshape(b, s, n_kv, head_dim),
                  "batch", None, "kv_heads", None)
    v = constrain(v.reshape(b, s, n_kv, head_dim),
                  "batch", None, "kv_heads", None)
    return q, k, v


def gqa_forward(p, x, positions, *, n_heads, n_kv, head_dim,
                window=None, attn_softcap=None, rope_theta=10000.0,
                q_chunk=1024, kv_chunk=1024, query_scale=None,
                causal_unroll=False):
    """Full-sequence causal forward (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, n_heads, n_kv, head_dim)
    q = apply_rope(q, positions[None, :], rope_theta)
    k = apply_rope(k, positions[None, :], rope_theta)
    o = chunked_attention(q, k, v, positions, positions, window=window,
                          attn_softcap=attn_softcap, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, scale=query_scale,
                          causal_unroll=causal_unroll)
    return qmatmul(p, "wo", o.reshape(b, s, n_heads * head_dim)), (k, v)


class KVCache(NamedTuple):
    k: Array          # [B, C, KV, hd]  (C = max_len or window)
    v: Array


def init_kv_cache(batch, capacity, n_kv, head_dim, dtype):
    z = jnp.zeros((batch, capacity, n_kv, head_dim), dtype)
    return KVCache(k=z, v=z)


def gqa_decode(p, x_t, cache: KVCache, pos, *, n_heads, n_kv, head_dim,
               ring=False, window=None, attn_softcap=None,
               rope_theta=10000.0, query_scale=None):
    """One-token decode. x_t: [B,1,D]; pos: scalar position index.

    ``ring`` (static, from the layer kind) marks a sliding-window ring
    buffer of capacity = window.
    """
    b = x_t.shape[0]
    q, k, v = _qkv(p, x_t, n_heads, n_kv, head_dim)
    pos_arr = jnp.asarray(pos)[None]
    q = apply_rope(q, pos_arr[None, :], rope_theta)
    k = apply_rope(k, pos_arr[None, :], rope_theta)

    cap = cache.k.shape[1]
    slot = (pos % cap) if ring else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, axis=1)

    idx = jnp.arange(cap)
    if ring:
        # slot i holds position p_i = pos - ((pos - i) mod cap)
        slot_pos = pos - jnp.mod(pos - idx, cap)
        valid = (slot_pos >= 0) & (slot_pos > pos - (window or cap))
    else:
        slot_pos = idx
        valid = idx <= pos
        if window is not None:
            valid &= idx > pos - window
    scale = query_scale if query_scale is not None else head_dim ** -0.5
    rep = n_heads // n_kv
    qg = q.reshape(b, 1, n_kv, rep, head_dim)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, attn_softcap)
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bkrqd", attn.astype(cv.dtype), cv)
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, 1, n_heads * head_dim)
    return qmatmul(p, "wo", o), KVCache(k=ck, v=cv)


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention
# ---------------------------------------------------------------------------

def init_mla(key, d_model, n_heads, *, kv_lora, rope_dim, nope_dim, v_dim,
             dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    qdim = n_heads * (nope_dim + rope_dim)
    return {
        "wq": (jax.random.normal(ks[0], (d_model, qdim)) * s).astype(dtype),
        "w_dkv": (jax.random.normal(ks[1], (d_model, kv_lora + rope_dim)) * s).astype(dtype),
        "w_uk": (jax.random.normal(ks[2], (kv_lora, n_heads * nope_dim))
                 * kv_lora ** -0.5).astype(dtype),
        "w_uv": (jax.random.normal(ks[3], (kv_lora, n_heads * v_dim))
                 * kv_lora ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[4], (n_heads * v_dim, d_model))
               * (n_heads * v_dim) ** -0.5).astype(dtype),
        "kv_norm_scale": jnp.zeros((kv_lora,), dtype),
    }


def _mla_q(p, x, n_heads, nope_dim, rope_dim, positions, rope_theta):
    """positions: broadcastable against [B, S] (e.g. [1, S] for the full
    forward, [B, 1] for a per-slot decode step)."""
    b, s, _ = x.shape
    q = qmatmul(p, "wq", x).reshape(b, s, n_heads, nope_dim + rope_dim)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    return q_nope, q_rope


def mla_forward(p, x, positions, *, n_heads, kv_lora, rope_dim, nope_dim,
                v_dim, rope_theta=10000.0, q_chunk=1024, kv_chunk=1024):
    """Training/prefill: expand the latent KV and run standard attention."""
    from repro.models.layers import rms_norm
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, n_heads, nope_dim, rope_dim,
                            positions[None, :], rope_theta)
    q_nope = constrain(q_nope, "batch", None, "heads", None)
    q_rope = constrain(q_rope, "batch", None, "heads", None)
    dkv = qmatmul(p, "w_dkv", x)
    c_kv = rms_norm(dkv[..., :kv_lora], p["kv_norm_scale"])
    k_rope = apply_rope(dkv[..., None, kv_lora:], positions[None, :], rope_theta)
    k_nope = constrain(
        qmatmul(p, "w_uk", c_kv).reshape(b, s, n_heads, nope_dim),
        "batch", None, "heads", None)
    v = constrain(qmatmul(p, "w_uv", c_kv).reshape(b, s, n_heads, v_dim),
                  "batch", None, "heads", None)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, n_heads, rope_dim))],
                        axis=-1)
    scale = (nope_dim + rope_dim) ** -0.5
    o = chunked_attention(q, k, v, positions, positions, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, scale=scale)
    cache = {"c_kv": c_kv, "k_rope": k_rope[..., 0, :]}
    return qmatmul(p, "wo", o.reshape(b, s, n_heads * v_dim)), cache


class MLACache(NamedTuple):
    c_kv: Array      # [B, C, kv_lora]
    k_rope: Array    # [B, C, rope_dim]


def init_mla_cache(batch, capacity, kv_lora, rope_dim, dtype):
    return MLACache(c_kv=jnp.zeros((batch, capacity, kv_lora), dtype),
                    k_rope=jnp.zeros((batch, capacity, rope_dim), dtype))


def mla_decode(p, x_t, cache: MLACache, pos, *, n_heads, kv_lora, rope_dim,
               nope_dim, v_dim, rope_theta=10000.0):
    """Absorbed decode: attention entirely in the [kv_lora] latent space.

    q_eff = q_nope · W_UK   (per head: [nope]·[nope,kv_lora])
    logits = q_eff·c_kv + q_rope·k_rope ;  ctx = attn·c_kv ;
    out_head = ctx · W_UV.  Cache traffic per token: kv_lora + rope_dim.
    """
    from repro.models.layers import rms_norm
    b = x_t.shape[0]
    pos_arr = jnp.asarray(pos)[None]
    q_nope, q_rope = _mla_q(p, x_t, n_heads, nope_dim, rope_dim,
                            pos_arr[None, :], rope_theta)
    dkv = qmatmul(p, "w_dkv", x_t)
    c_kv_t = rms_norm(dkv[..., :kv_lora], p["kv_norm_scale"])
    k_rope_t = apply_rope(dkv[..., None, kv_lora:], pos_arr[None, :], rope_theta)[:, :, 0]

    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_kv_t.astype(cache.c_kv.dtype), pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, k_rope_t.astype(cache.k_rope.dtype), pos, axis=1)

    # Absorbed factors are einsum operands: fetch dense via qweight (an
    # in-jit dequant temporary when the leaf is quantized) and reshape.
    w_uk = qweight(p, "w_uk").reshape(kv_lora, n_heads, nope_dim)
    q_eff = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk)        # [B,1,H,kv_lora]
    logits = (jnp.einsum("bqhl,bsl->bhqs", q_eff, ckv) +
              jnp.einsum("bqhd,bsd->bhqs", q_rope, krope))
    logits = logits.astype(jnp.float32) * (nope_dim + rope_dim) ** -0.5
    cap = ckv.shape[1]
    valid = jnp.arange(cap) <= pos
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhqs,bsl->bqhl", attn.astype(ckv.dtype), ckv)
    w_uv = qweight(p, "w_uv").reshape(kv_lora, n_heads, v_dim)
    o = jnp.einsum("bqhl,lhd->bqhd", ctx, w_uv).reshape(b, 1, n_heads * v_dim)
    return qmatmul(p, "wo", o), MLACache(c_kv=ckv, k_rope=krope)


# ---------------------------------------------------------------------------
# Paged / slot-aware decode (continuous-batching engine)
#
# Global-attention layers store KV in a pool of fixed-size pages shared by
# all batch slots; a per-slot page table maps logical position t to
# physical cell (table[slot, t // page] , t % page).  Physical page 0 is
# reserved as the trash page: dead slots (and unallocated logical pages)
# point at it, so one fused decode step serves any admission/eviction
# state without shape changes or recompiles.  Sliding-window layers keep
# their constant-size per-slot ring buffer instead (capacity == window).
# ---------------------------------------------------------------------------


class PagedKVCache(NamedTuple):
    k: Array          # [n_pages + 1, page, KV, hd]  (page 0 = trash)
    v: Array


class PagedMLACache(NamedTuple):
    c_kv: Array       # [n_pages + 1, page, kv_lora]
    k_rope: Array     # [n_pages + 1, page, rope_dim]


def init_paged_kv_cache(n_pages, page_size, n_kv, head_dim, dtype):
    z = jnp.zeros((n_pages + 1, page_size, n_kv, head_dim), dtype)
    return PagedKVCache(k=z, v=z)


def init_paged_mla_cache(n_pages, page_size, kv_lora, rope_dim, dtype):
    return PagedMLACache(
        c_kv=jnp.zeros((n_pages + 1, page_size, kv_lora), dtype),
        k_rope=jnp.zeros((n_pages + 1, page_size, rope_dim), dtype))


def _write_slot(pool: Array, page_table: Array, pos: Array, alive: Array,
                new: Array, page_size: int) -> Array:
    """Scatter one new entry per slot into its current page.

    pool [P+1, page, ...]; page_table [B, max_pages]; pos/alive [B];
    new [B, ...].  Dead (or page-starved) slots write the trash page.
    """
    b = new.shape[0]
    npg = page_table.shape[1]
    pg = jnp.clip(pos // page_size, 0, npg - 1)
    phys = page_table[jnp.arange(b), pg]
    phys = jnp.where(alive, phys, 0)
    return pool.at[phys, pos % page_size].set(new.astype(pool.dtype),
                                              mode="drop")


def _gather_slots(pool: Array, page_table: Array, alive: Array) -> Array:
    """Logical KV view per slot: [B, max_pages·page, ...].

    Dead slots' table rows are masked to the trash page *before* the
    gather (dispatch routes to ``kernels.ref.gather_pages_ref`` on CPU or
    the scalar-prefetch Pallas gather on TPU), so a stalled/empty slot
    contributes one repeated trash page instead of ``max_pages``
    arbitrary live pages to the gather footprint.
    """
    return dispatch.page_gather(pool, page_table, alive)


def _slot_attention(q, ck, cv, valid, *, n_heads, n_kv, head_dim,
                    attn_softcap, scale):
    """Masked decode attention over per-slot gathered KV.

    q [B,1,H,hd]; ck/cv [B,cap,KV,hd]; valid [B,cap] bool."""
    b = q.shape[0]
    rep = n_heads // n_kv
    qg = q.reshape(b, 1, n_kv, rep, head_dim)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, attn_softcap)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkrqs,bskd->bkrqd", attn.astype(cv.dtype), cv)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, n_heads * head_dim)


def gqa_decode_paged(p, x_t, cache: PagedKVCache, page_table, pos, alive, *,
                     n_heads, n_kv, head_dim, page_size,
                     attn_softcap=None, rope_theta=10000.0,
                     query_scale=None):
    """One-token GQA decode for a batch of engine slots.

    x_t [B,1,D]; page_table [B, max_pages] int32; pos [B] int32 per-slot
    write positions; alive [B] bool (dead slots: reads fully masked,
    writes land on the trash page).
    """
    q, k, v = _qkv(p, x_t, n_heads, n_kv, head_dim)
    posb = pos[:, None]
    q = apply_rope(q, posb, rope_theta)
    k = apply_rope(k, posb, rope_theta)

    ck = _write_slot(cache.k, page_table, pos, alive, k[:, 0], page_size)
    cv = _write_slot(cache.v, page_table, pos, alive, v[:, 0], page_size)
    scale = query_scale if query_scale is not None else head_dim ** -0.5
    # fused page-gather + online-softmax decode; the CPU ref route is the
    # verbatim former _gather_slots/_slot_attention math (bit-identical)
    o = dispatch.paged_attention(q, ck, cv, page_table, pos, alive,
                                 softcap=attn_softcap, scale=scale)
    return qmatmul(p, "wo", o), PagedKVCache(k=ck, v=cv)


# --- codebook-quantized paged KV (kv_bits ∈ {2,4,8}) -----------------------
#
# Pages store bit-packed codebook indices (``core.kvquant`` pack_rows
# layout) plus per-page codebooks fit at write time.  Freeze-on-first-
# write: the codebook of a page is fit exactly once — by the prefill
# commit (over the whole zero-padded page) or by the decode step that
# writes the page's first cell — and every later in-page write assigns
# against the frozen codebook.  Storage is therefore a pure function of
# the written values (replay/restore deterministic), and the stored
# dequantized value equals ``cb[assign(v, cb)]`` exactly.


class QuantPagedKVCache(NamedTuple):
    k_words: Array    # [n_pages + 1, page, KV, Wd] uint32 packed indices
    v_words: Array
    k_cb: Array       # [n_pages + 1, Gcb, K]; Gcb = n_kv ("head") | 1 ("page")
    v_cb: Array


class QuantPagedMLACache(NamedTuple):
    c_words: Array    # [n_pages + 1, page, ⌈kv_lora/lanes⌉] uint32
    r_words: Array    # [n_pages + 1, page, ⌈rope_dim/lanes⌉] uint32
    c_cb: Array       # [n_pages + 1, 1, K]  (latent pages: per-page cbs)
    r_cb: Array


def init_quant_paged_kv_cache(n_pages, page_size, n_kv, head_dim, bits,
                              cb_mode, dtype):
    wd = kvquant.words_per(head_dim, kvquant.check_kv_bits(bits))
    gcb = n_kv if cb_mode == "head" else 1
    zw = jnp.zeros((n_pages + 1, page_size, n_kv, wd), jnp.uint32)
    zc = jnp.zeros((n_pages + 1, gcb, kvquant.kv_entries(bits)), dtype)
    return QuantPagedKVCache(k_words=zw, v_words=zw, k_cb=zc, v_cb=zc)


def init_quant_paged_mla_cache(n_pages, page_size, kv_lora, rope_dim, bits,
                               dtype):
    k = kvquant.kv_entries(kvquant.check_kv_bits(bits))
    return QuantPagedMLACache(
        c_words=jnp.zeros(
            (n_pages + 1, page_size, kvquant.words_per(kv_lora, bits)),
            jnp.uint32),
        r_words=jnp.zeros(
            (n_pages + 1, page_size, kvquant.words_per(rope_dim, bits)),
            jnp.uint32),
        c_cb=jnp.zeros((n_pages + 1, 1, k), dtype),
        r_cb=jnp.zeros((n_pages + 1, 1, k), dtype))


def _quant_groups(new: Array, cb_mode: str) -> Array:
    """Reshape one token's write row to [B, Gcb, N] codebook groups."""
    if new.ndim == 2:                  # MLA latent/rope row: per-page cb
        return new[:, None, :]
    b, kv, hd = new.shape
    if cb_mode == "head":
        return new                     # one cb per kv head
    return new.reshape(b, 1, kv * hd)  # one cb per page


def _write_slot_quant(words: Array, cbs: Array, page_table: Array,
                      pos: Array, alive: Array, new: Array, page_size: int,
                      bits: int, cb_mode: str):
    """Quantizing twin of ``_write_slot``: fit-or-reuse the page codebook,
    assign, bit-pack, scatter.

    words [P+1, page, (KV,) Wd]; cbs [P+1, Gcb, K]; new [B, (KV,) d].
    A slot writing offset 0 of a page fits that page's codebook from its
    token row and freezes it; offsets > 0 assign against the frozen one.
    Dead slots write the trash page (page 0) and never refit its cb.
    """
    b = new.shape[0]
    npg = page_table.shape[1]
    pg = jnp.clip(pos // page_size, 0, npg - 1)
    phys = page_table[jnp.arange(b), pg]
    phys = jnp.where(alive, phys, 0)
    off = pos % page_size
    is_first = (off == 0) & alive

    grp = _quant_groups(new, cb_mode)              # [B, Gcb, N]
    cb_new = kvquant.fit_codebooks(grp, bits).astype(cbs.dtype)
    cb = jnp.where(is_first[:, None, None], cb_new, cbs[phys])
    idx = kvquant.assign_codebook(grp, cb)
    wrow = kvquant.pack_rows_jnp(idx.reshape(new.shape), bits)
    return (words.at[phys, off].set(wrow, mode="drop"),
            cbs.at[phys].set(cb, mode="drop"))


def gqa_decode_paged_quant(p, x_t, cache: QuantPagedKVCache, page_table,
                           pos, alive, *, n_heads, n_kv, head_dim,
                           page_size, kv_bits, kv_cb_mode="page",
                           attn_softcap=None, rope_theta=10000.0,
                           query_scale=None):
    """``gqa_decode_paged`` over codebook-quantized KV pages.

    The written token is quantized *before* it is attended, so what the
    kernel reads is exactly what the cache stores — the differential
    oracle is the dense route over the dequantized pools, bit-exact.
    """
    q, k, v = _qkv(p, x_t, n_heads, n_kv, head_dim)
    posb = pos[:, None]
    q = apply_rope(q, posb, rope_theta)
    k = apply_rope(k, posb, rope_theta)

    kw, kcb = _write_slot_quant(cache.k_words, cache.k_cb, page_table, pos,
                                alive, k[:, 0], page_size, kv_bits,
                                kv_cb_mode)
    vw, vcb = _write_slot_quant(cache.v_words, cache.v_cb, page_table, pos,
                                alive, v[:, 0], page_size, kv_bits,
                                kv_cb_mode)
    scale = query_scale if query_scale is not None else head_dim ** -0.5
    o = dispatch.paged_attention_quant(
        q, kw, vw, kcb, vcb, page_table, pos, alive, bits=kv_bits,
        head_dim=head_dim, softcap=attn_softcap, scale=scale)
    return (qmatmul(p, "wo", o),
            QuantPagedKVCache(k_words=kw, v_words=vw, k_cb=kcb, v_cb=vcb))


def mla_decode_paged_quant(p, x_t, cache: QuantPagedMLACache, page_table,
                           pos, alive, *, n_heads, kv_lora, rope_dim,
                           nope_dim, v_dim, page_size, kv_bits,
                           rope_theta=10000.0):
    """Absorbed MLA decode over codebook-quantized latent pages."""
    from repro.models.layers import rms_norm
    b = x_t.shape[0]
    posb = pos[:, None]
    q_nope, q_rope = _mla_q(p, x_t, n_heads, nope_dim, rope_dim, posb,
                            rope_theta)
    dkv = qmatmul(p, "w_dkv", x_t)
    c_kv_t = rms_norm(dkv[..., :kv_lora], p["kv_norm_scale"])
    k_rope_t = apply_rope(dkv[..., None, kv_lora:], posb, rope_theta)[:, :, 0]

    cw, ccb = _write_slot_quant(cache.c_words, cache.c_cb, page_table, pos,
                                alive, c_kv_t[:, 0], page_size, kv_bits,
                                "page")
    rw, rcb = _write_slot_quant(cache.r_words, cache.r_cb, page_table, pos,
                                alive, k_rope_t[:, 0], page_size, kv_bits,
                                "page")

    w_uk = qweight(p, "w_uk").reshape(kv_lora, n_heads, nope_dim)
    q_eff = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk)
    ctx = dispatch.mla_paged_attention_quant(
        q_eff, q_rope, cw, rw, ccb, rcb, page_table, pos, alive,
        bits=kv_bits, kv_lora=kv_lora, rope_dim=rope_dim,
        scale=(nope_dim + rope_dim) ** -0.5)
    w_uv = qweight(p, "w_uv").reshape(kv_lora, n_heads, v_dim)
    o = jnp.einsum("bqhl,lhd->bqhd", ctx, w_uv).reshape(b, 1, n_heads * v_dim)
    return (qmatmul(p, "wo", o),
            QuantPagedMLACache(c_words=cw, r_words=rw, c_cb=ccb, r_cb=rcb))


# ---------------------------------------------------------------------------
# Blockwise prefill (chunked-prompt path, PR 9)
#
# Each engine prefill step runs ONE block of ≤ prefill_chunk new prompt
# tokens through these functions: project + rope the block, write its
# K/V straight into the slot's pages (quantizing token-by-token when
# kv_bits > 0 — the same freeze-on-first-write protocol as decode, so
# pages are a pure function of the written values, independent of the
# block partition), then attend the block's queries over the slot's
# *stored* K/V view via ``dispatch.blockwise_prefill_attention`` — the
# write-then-attend order makes what is attended exactly what the cache
# holds.  The one-shot oracle runs the same per-block functions over
# growing buffers; because view rows carry their positions and invisible
# rows mask to exact zero probability, the engine's fixed-capacity page
# view and the oracle's growing view are bit-identical per block.
# ---------------------------------------------------------------------------


def _write_block_slot(pool: Array, page_table: Array, start, alive: Array,
                      new: Array, page_size: int) -> Array:
    """Blockwise twin of ``_write_slot``: scatter ``c`` consecutive
    entries per slot starting at logical position ``start``.

    pool [P+1, page, ...]; page_table [B, npg]; start scalar or [B];
    new [B, c, ...].  Dead slots write the trash page."""
    b, c = new.shape[0], new.shape[1]
    npg = page_table.shape[1]
    start = jnp.broadcast_to(jnp.asarray(start), (b,))
    t = start[:, None] + jnp.arange(c)[None, :]            # [B, c]
    pg = jnp.clip(t // page_size, 0, npg - 1)
    phys = page_table[jnp.arange(b)[:, None], pg]
    phys = jnp.where(alive[:, None], phys, 0)
    return pool.at[phys, t % page_size].set(new.astype(pool.dtype),
                                            mode="drop")


def _write_block_slot_quant(words: Array, cbs: Array, page_table: Array,
                            start, alive: Array, new: Array, page_size: int,
                            bits: int, cb_mode: str):
    """Blockwise twin of ``_write_slot_quant``: a per-token ``lax.scan``
    over the block so the freeze-on-first-write codebook protocol is the
    decode path's, token for token — a page's codebook is fit by whoever
    writes its offset 0, whether that token arrives in this block, a
    previous one, or (after restore) a replayed one."""
    b, c = new.shape[0], new.shape[1]
    start = jnp.broadcast_to(jnp.asarray(start), (b,))

    def body(carry, xs):
        w, cb = carry
        tok, off = xs
        w, cb = _write_slot_quant(w, cb, page_table, start + off, alive,
                                  tok, page_size, bits, cb_mode)
        return (w, cb), None

    toks = jnp.moveaxis(new, 1, 0)                         # [c, B, ...]
    (w, cb), _ = jax.lax.scan(body, (words, cbs),
                              (toks, jnp.arange(c)))
    return w, cb


def gqa_prefill_block_paged(p, x, cache: PagedKVCache, page_table, start,
                            alive, *, n_heads, n_kv, head_dim, page_size,
                            attn_softcap=None, rope_theta=10000.0,
                            query_scale=None):
    """One prompt block of a paged (global-attention) GQA layer.

    x [B,c,D]; start: the block's first logical position (traced OK).
    Writes the block's K/V into the slot's pages, then attends the block
    queries over the gathered page view — rows beyond ``start + c`` are
    future/garbage and mask out causally (row index == position)."""
    b, c, _ = x.shape
    q, k, v = _qkv(p, x, n_heads, n_kv, head_dim)
    t = jnp.asarray(start) + jnp.arange(c)                 # [c]
    q = apply_rope(q, t[None, :], rope_theta)
    k = apply_rope(k, t[None, :], rope_theta)

    ck = _write_block_slot(cache.k, page_table, start, alive, k, page_size)
    cv = _write_block_slot(cache.v, page_table, start, alive, v, page_size)
    view_k = _gather_slots(ck, page_table, alive)          # [B,cap,KV,hd]
    view_v = _gather_slots(cv, page_table, alive)
    scale = query_scale if query_scale is not None else head_dim ** -0.5
    o = dispatch.blockwise_prefill_attention(
        q, view_k, view_v, t, jnp.arange(view_k.shape[1]),
        softcap=attn_softcap, scale=scale)
    return (qmatmul(p, "wo", o.reshape(b, c, n_heads * head_dim)),
            PagedKVCache(k=ck, v=cv))


def gqa_prefill_block_paged_quant(p, x, cache: QuantPagedKVCache,
                                  page_table, start, alive, *, n_heads,
                                  n_kv, head_dim, page_size, kv_bits,
                                  kv_cb_mode="page", attn_softcap=None,
                                  rope_theta=10000.0, query_scale=None):
    """``gqa_prefill_block_paged`` over codebook-quantized KV pages: the
    block's tokens quantize one by one at write time, then the block
    attends over the stored packed words — what is read is exactly what
    the cache holds, kv_bits/8 B per cached scalar."""
    b, c, _ = x.shape
    q, k, v = _qkv(p, x, n_heads, n_kv, head_dim)
    t = jnp.asarray(start) + jnp.arange(c)
    q = apply_rope(q, t[None, :], rope_theta)
    k = apply_rope(k, t[None, :], rope_theta)

    kw, kcb = _write_block_slot_quant(cache.k_words, cache.k_cb, page_table,
                                      start, alive, k, page_size, kv_bits,
                                      kv_cb_mode)
    vw, vcb = _write_block_slot_quant(cache.v_words, cache.v_cb, page_table,
                                      start, alive, v, page_size, kv_bits,
                                      kv_cb_mode)
    masked_tbl = jnp.where(alive[:, None], page_table, 0)
    kw_view = dispatch.page_gather(kw, page_table, alive)  # [B,cap,KV,Wd]
    vw_view = dispatch.page_gather(vw, page_table, alive)
    kcb_view = kcb[masked_tbl]                             # [B,npg,Gcb,K]
    vcb_view = vcb[masked_tbl]
    scale = query_scale if query_scale is not None else head_dim ** -0.5
    o = dispatch.blockwise_prefill_attention_quant(
        q, kw_view, vw_view, kcb_view, vcb_view, t,
        jnp.arange(kw_view.shape[1]), page_size=page_size, bits=kv_bits,
        head_dim=head_dim, softcap=attn_softcap, scale=scale)
    return (qmatmul(p, "wo", o.reshape(b, c, n_heads * head_dim)),
            QuantPagedKVCache(k_words=kw, v_words=vw, k_cb=kcb, v_cb=vcb))


def _ring_positions(start, cap: int) -> Array:
    """Position held by each ring slot after ``start`` tokens have been
    written: slot j holds p = (start-1) - ((start-1 - j) mod cap), or the
    sentinel when that is negative (slot not yet written)."""
    j = jnp.arange(cap)
    pm1 = jnp.asarray(start) - 1
    pos = pm1 - jnp.mod(pm1 - j, cap)
    return jnp.where(pos >= 0, pos, dispatch.ref.POS_SENTINEL)


def gqa_prefill_block_ring(p, x, cache: KVCache, start, *, n_heads, n_kv,
                           head_dim, window, attn_softcap=None,
                           rope_theta=10000.0, query_scale=None):
    """One prompt block of a sliding-window (ring-buffer) GQA layer.

    The ring (capacity == window) plus the block's fresh K/V form the
    attended view; ring rows carry their true positions (sentinel when
    unwritten — stale rows older than the window mask out by the window
    predicate).  After attending, the last ``min(c, cap)`` tokens land
    in their ring slots.  Used by both the engine (B=1 slot rows) and
    the oracle (batched) — batch-row-decoupled."""
    b, c, _ = x.shape
    cap = cache.k.shape[1]
    q, k, v = _qkv(p, x, n_heads, n_kv, head_dim)
    t = jnp.asarray(start) + jnp.arange(c)
    q = apply_rope(q, t[None, :], rope_theta)
    k = apply_rope(k, t[None, :], rope_theta)

    view_k = jnp.concatenate([cache.k, k.astype(cache.k.dtype)], axis=1)
    view_v = jnp.concatenate([cache.v, v.astype(cache.v.dtype)], axis=1)
    k_pos = jnp.concatenate([_ring_positions(start, cap), t])
    scale = query_scale if query_scale is not None else head_dim ** -0.5
    o = dispatch.blockwise_prefill_attention(
        q, view_k, view_v, t, k_pos, window=window, softcap=attn_softcap,
        scale=scale)

    j = jnp.arange(cap)
    end = jnp.asarray(start) + c - 1
    pos_j = end - jnp.mod(end - j, cap)                    # position slot j
    take = pos_j >= jnp.asarray(start)                     # written this blk
    idx = jnp.clip(pos_j - jnp.asarray(start), 0, c - 1)
    newk = jnp.where(take[None, :, None, None],
                     k.astype(cache.k.dtype)[:, idx], cache.k)
    newv = jnp.where(take[None, :, None, None],
                     v.astype(cache.v.dtype)[:, idx], cache.v)
    return (qmatmul(p, "wo", o.reshape(b, c, n_heads * head_dim)),
            KVCache(k=newk, v=newv))


def gqa_prefill_block(p, x, buf_k, buf_v, start: int, *, n_heads, n_kv,
                      head_dim, window=None, attn_softcap=None,
                      rope_theta=10000.0, query_scale=None):
    """Oracle-side block step of a global GQA layer: append the block's
    K/V to the growing buffers ([B, start, KV, hd] → [B, start+c, ...])
    and attend over the result.  Same per-row math as the engine's page
    view; extra engine rows are all masked, which is a bitwise no-op."""
    b, c, _ = x.shape
    q, k, v = _qkv(p, x, n_heads, n_kv, head_dim)
    t = start + jnp.arange(c)
    q = apply_rope(q, t[None, :], rope_theta)
    k = apply_rope(k, t[None, :], rope_theta)
    bk = jnp.concatenate([buf_k, k.astype(buf_k.dtype)], axis=1)
    bv = jnp.concatenate([buf_v, v.astype(buf_v.dtype)], axis=1)
    scale = query_scale if query_scale is not None else head_dim ** -0.5
    o = dispatch.blockwise_prefill_attention(
        q, bk, bv, t, jnp.arange(bk.shape[1]), window=window,
        softcap=attn_softcap, scale=scale)
    return qmatmul(p, "wo", o.reshape(b, c, n_heads * head_dim)), bk, bv


def mla_prefill_block_paged(p, x, cache: PagedMLACache, page_table, start,
                            alive, *, n_heads, kv_lora, rope_dim, nope_dim,
                            v_dim, page_size, rope_theta=10000.0):
    """One prompt block of an MLA layer over the paged latent cache.

    Prefill stays in the *expanded* space: the block's latent rows are
    written to the slot's pages, the page view is re-expanded through
    W_UK/W_UV (row-wise matmuls — identical per row regardless of view
    length), and the block runs the dense blockwise-attention op with
    per-head keys of width nope+rope and values of width v_dim."""
    from repro.models.layers import rms_norm
    b, c, _ = x.shape
    t = jnp.asarray(start) + jnp.arange(c)
    q_nope, q_rope = _mla_q(p, x, n_heads, nope_dim, rope_dim, t[None, :],
                            rope_theta)
    dkv = qmatmul(p, "w_dkv", x)
    c_kv = rms_norm(dkv[..., :kv_lora], p["kv_norm_scale"])
    k_rope = apply_rope(dkv[..., None, kv_lora:], t[None, :],
                        rope_theta)[:, :, 0]

    ckv = _write_block_slot(cache.c_kv, page_table, start, alive, c_kv,
                            page_size)
    krope = _write_block_slot(cache.k_rope, page_table, start, alive,
                              k_rope, page_size)
    c_view = _gather_slots(ckv, page_table, alive)         # [B,cap,lora]
    r_view = _gather_slots(krope, page_table, alive)       # [B,cap,rope]
    o = _mla_block_attend(p, q_nope, q_rope, c_view, r_view, t,
                          n_heads=n_heads, nope_dim=nope_dim,
                          rope_dim=rope_dim, v_dim=v_dim)
    return (qmatmul(p, "wo", o.reshape(b, c, n_heads * v_dim)),
            PagedMLACache(c_kv=ckv, k_rope=krope))


def _mla_block_attend(p, q_nope, q_rope, c_view, r_view, t, *, n_heads,
                      nope_dim, rope_dim, v_dim):
    """Expand a latent view and attend one block's queries over it."""
    b, s = c_view.shape[0], c_view.shape[1]
    k_nope = qmatmul(p, "w_uk", c_view).reshape(b, s, n_heads, nope_dim)
    v = qmatmul(p, "w_uv", c_view).reshape(b, s, n_heads, v_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(r_view[:, :, None, :],
                                  (b, s, n_heads, rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    return dispatch.blockwise_prefill_attention(
        q, k, v, t, jnp.arange(s), scale=(nope_dim + rope_dim) ** -0.5)


def mla_prefill_block_paged_quant(p, x, cache: QuantPagedMLACache,
                                  page_table, start, alive, *, n_heads,
                                  kv_lora, rope_dim, nope_dim, v_dim,
                                  page_size, kv_bits, rope_theta=10000.0):
    """MLA block prefill over codebook-quantized latent pages: per-token
    quantizing writes (decode's freeze-on-first-write protocol), then the
    latent view is dequantized (jnp — the expansion matmuls need dense
    latents anyway, so there is no fused-quant MLA prefill kernel
    variant) and re-expanded exactly as the dense path."""
    from repro.kernels.ref import dequant_view_ref
    from repro.models.layers import rms_norm
    b, c, _ = x.shape
    t = jnp.asarray(start) + jnp.arange(c)
    q_nope, q_rope = _mla_q(p, x, n_heads, nope_dim, rope_dim, t[None, :],
                            rope_theta)
    dkv = qmatmul(p, "w_dkv", x)
    c_kv = rms_norm(dkv[..., :kv_lora], p["kv_norm_scale"])
    k_rope = apply_rope(dkv[..., None, kv_lora:], t[None, :],
                        rope_theta)[:, :, 0]

    cw, ccb = _write_block_slot_quant(cache.c_words, cache.c_cb, page_table,
                                      start, alive, c_kv, page_size,
                                      kv_bits, "page")
    rw, rcb = _write_block_slot_quant(cache.r_words, cache.r_cb, page_table,
                                      start, alive, k_rope, page_size,
                                      kv_bits, "page")
    masked_tbl = jnp.where(alive[:, None], page_table, 0)
    c_view = dequant_view_ref(dispatch.page_gather(cw, page_table, alive),
                              ccb[masked_tbl], kv_lora, kv_bits, page_size)
    r_view = dequant_view_ref(dispatch.page_gather(rw, page_table, alive),
                              rcb[masked_tbl], rope_dim, kv_bits, page_size)
    o = _mla_block_attend(p, q_nope, q_rope, c_view.astype(ccb.dtype),
                          r_view.astype(rcb.dtype), t, n_heads=n_heads,
                          nope_dim=nope_dim, rope_dim=rope_dim, v_dim=v_dim)
    return (qmatmul(p, "wo", o.reshape(b, c, n_heads * v_dim)),
            QuantPagedMLACache(c_words=cw, r_words=rw, c_cb=ccb, r_cb=rcb))


def mla_prefill_block(p, x, buf_c, buf_r, start: int, *, n_heads, kv_lora,
                      rope_dim, nope_dim, v_dim, rope_theta=10000.0):
    """Oracle-side MLA block step: append the block's latent rows to the
    growing buffers and attend over the re-expansion of the result."""
    from repro.models.layers import rms_norm
    b, c, _ = x.shape
    t = start + jnp.arange(c)
    q_nope, q_rope = _mla_q(p, x, n_heads, nope_dim, rope_dim, t[None, :],
                            rope_theta)
    dkv = qmatmul(p, "w_dkv", x)
    c_kv = rms_norm(dkv[..., :kv_lora], p["kv_norm_scale"])
    k_rope = apply_rope(dkv[..., None, kv_lora:], t[None, :],
                        rope_theta)[:, :, 0]
    bc = jnp.concatenate([buf_c, c_kv.astype(buf_c.dtype)], axis=1)
    br = jnp.concatenate([buf_r, k_rope.astype(buf_r.dtype)], axis=1)
    o = _mla_block_attend(p, q_nope, q_rope, bc, br, t, n_heads=n_heads,
                          nope_dim=nope_dim, rope_dim=rope_dim, v_dim=v_dim)
    return qmatmul(p, "wo", o.reshape(b, c, n_heads * v_dim)), bc, br


def gqa_decode_ring_slots(p, x_t, cache: KVCache, pos, alive, *, n_heads,
                          n_kv, head_dim, window, attn_softcap=None,
                          rope_theta=10000.0, query_scale=None):
    """Sliding-window decode with a per-slot position vector.

    The ring buffer is per-slot constant size (capacity == cache cap);
    the engine never pages it — it just resets on admission.
    """
    b = x_t.shape[0]
    q, k, v = _qkv(p, x_t, n_heads, n_kv, head_dim)
    posb = pos[:, None]
    q = apply_rope(q, posb, rope_theta)
    k = apply_rope(k, posb, rope_theta)

    cap = cache.k.shape[1]
    slot = pos % cap
    rows = jnp.arange(b)
    ck = cache.k.at[rows, slot].set(k[:, 0].astype(cache.k.dtype))
    cv = cache.v.at[rows, slot].set(v[:, 0].astype(cache.v.dtype))

    idx = jnp.arange(cap)[None, :]
    # ring slot i holds position p_i = pos - ((pos - i) mod cap)
    slot_pos = posb - jnp.mod(posb - idx, cap)
    valid = ((slot_pos >= 0) & (slot_pos > posb - (window or cap))
             & alive[:, None])
    scale = query_scale if query_scale is not None else head_dim ** -0.5
    o = _slot_attention(q, ck, cv, valid, n_heads=n_heads, n_kv=n_kv,
                        head_dim=head_dim, attn_softcap=attn_softcap,
                        scale=scale)
    return qmatmul(p, "wo", o), KVCache(k=ck, v=cv)


def mla_decode_paged(p, x_t, cache: PagedMLACache, page_table, pos, alive, *,
                     n_heads, kv_lora, rope_dim, nope_dim, v_dim, page_size,
                     rope_theta=10000.0):
    """Absorbed MLA decode over the paged latent cache (per-slot pos)."""
    from repro.models.layers import rms_norm
    b = x_t.shape[0]
    posb = pos[:, None]
    q_nope, q_rope = _mla_q(p, x_t, n_heads, nope_dim, rope_dim, posb,
                            rope_theta)
    dkv = qmatmul(p, "w_dkv", x_t)
    c_kv_t = rms_norm(dkv[..., :kv_lora], p["kv_norm_scale"])
    k_rope_t = apply_rope(dkv[..., None, kv_lora:], posb, rope_theta)[:, :, 0]

    ckv = _write_slot(cache.c_kv, page_table, pos, alive, c_kv_t[:, 0],
                      page_size)
    krope = _write_slot(cache.k_rope, page_table, pos, alive, k_rope_t[:, 0],
                        page_size)

    w_uk = qweight(p, "w_uk").reshape(kv_lora, n_heads, nope_dim)
    q_eff = jnp.einsum("bqhd,lhd->bqhl", q_nope, w_uk)
    # fused absorbed-MLA paged decode (the ref route is the verbatim
    # former gather + latent-softmax einsum chain, bit-identical)
    ctx = dispatch.mla_paged_attention(
        q_eff, q_rope, ckv, krope, page_table, pos, alive,
        scale=(nope_dim + rope_dim) ** -0.5)
    w_uv = qweight(p, "w_uv").reshape(kv_lora, n_heads, v_dim)
    o = jnp.einsum("bqhl,lhd->bqhd", ctx, w_uv).reshape(b, 1, n_heads * v_dim)
    return qmatmul(p, "wo", o), PagedMLACache(c_kv=ckv, k_rope=krope)
