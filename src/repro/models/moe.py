"""Mixture-of-Experts with sort-based capacity dispatch (TPU-friendly).

Routing pipeline (per layer, per step):
  1. router logits [T, E] → top-k experts/token (softmax probs over top-k);
  2. flatten (token, slot) pairs, sort by expert id;
  3. place into a [E, C, D] dispatch buffer (capacity C per expert;
     overflow dropped — standard capacity-factor routing);
  4. gated-FFN einsum per expert [E, C, D]×[E, D, F];
  5. combine back with router probabilities.

No [T, E, C] one-hot einsum (that is quadratic in tokens); cost is
sort + two gathers + the expert matmuls (≈ active-param FLOPs × capacity
factor).  Experts are sharded over the ``model`` mesh axis (EP) by the
sharding rules in repro/dist/sharding.py; GSPMD inserts the all-to-all.

Router weights stay un-quantized (see DESIGN §5).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn
from repro.models.qleaf import has_leaf, qmatmul, qweight
from repro.models.sharding_ctx import constrain

Array = jax.Array


def _active_policy():
    from repro.models import sharding_ctx
    return sharding_ctx._ACTIVE["policy"]


def init_moe(key, d_model, d_ff_expert, n_experts, n_shared, act, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    s_in = d_model ** -0.5
    s_out = d_ff_expert ** -0.5
    p = {
        "router_w": (jax.random.normal(ks[0], (d_model, n_experts)) * s_in
                     ).astype(jnp.float32),   # router kept fp32, unquantized
        "experts_w_in": (jax.random.normal(ks[1], (n_experts, d_model, d_ff_expert)) * s_in).astype(dtype),
        "experts_w_gate": (jax.random.normal(ks[2], (n_experts, d_model, d_ff_expert)) * s_in).astype(dtype),
        "experts_w_out": (jax.random.normal(ks[3], (n_experts, d_ff_expert, d_model)) * s_out).astype(dtype),
    }
    if n_shared > 0:
        dsh = n_shared * d_ff_expert
        p["shared_w_in"] = (jax.random.normal(ks[4], (d_model, dsh)) * s_in).astype(dtype)
        p["shared_w_gate"] = (jax.random.normal(ks[5], (d_model, dsh)) * s_in).astype(dtype)
        p["shared_w_out"] = (jax.random.normal(ks[6], (dsh, d_model)) * dsh ** -0.5).astype(dtype)
    return p


def _dispatch_row(xt, eidx, gates, e, c, top_k):
    """Route one batch row's tokens: xt [S,D], eidx/gates [S,k] →
    (ex_in [E,C,D], dst [S·k], keep [S·k], stok [S·k], sgate [S·k])."""
    s, d = xt.shape
    flat_e = eidx.reshape(-1)                                  # [S·k]
    flat_tok = jnp.repeat(jnp.arange(s), top_k)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, stok, sgate = flat_e[order], flat_tok[order], flat_gate[order]
    group_start = jnp.searchsorted(se, jnp.arange(e), side="left")  # [E]
    pos_in_group = jnp.arange(se.size) - group_start[se]
    keep = pos_in_group < c
    dst = jnp.where(keep, se * c + pos_in_group, e * c)        # drop → OOB
    buf = jnp.zeros((e * c, d), xt.dtype)
    buf = buf.at[jnp.minimum(dst, e * c - 1)].add(
        jnp.where(keep[:, None], xt[stok], 0).astype(xt.dtype),
        mode="drop")
    return buf.reshape(e, c, d), dst, keep, stok, sgate


def _combine_row(ex_out, dst, keep, stok, sgate, s):
    e, c, d = ex_out.shape
    gathered = ex_out.reshape(e * c, d)[jnp.minimum(dst, e * c - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered * sgate[:, None].astype(gathered.dtype)
    return jnp.zeros((s, d), contrib.dtype).at[stok].add(contrib)


def apply_moe(p, x: Array, *, top_k: int, act: str = "silu",
              capacity_factor: float = 1.25,
              capacity: Optional[int] = None) -> Array:
    """x: [B, S, D] → [B, S, D].

    Dispatch is **per batch row** (vmapped): routing, capacity and the
    scatter/gather stay inside each row, so under pjit the dispatch
    parallelizes over the data-sharded batch dim with zero communication,
    the expert FFN runs EP-sharded over ``model``, and the combine needs
    exactly one model-axis psum of [B_loc, S, D] — the same collective
    shape as a dense TP layer.

    (The first implementation used one global-token capacity buffer
    [E, T·k·cf/E, D]; GSPMD had to gather every token to every chip —
    measured 50 s of collective time per step on granite train_4k vs
    1.15 s of compute.  See EXPERIMENTS.md §Perf/moe-dispatch.)
    """
    b, s, d = x.shape
    f = act_fn(act)
    # Expert stacks are einsum operands [E, D, F]: fetch dense via qleaf
    # (an in-jit dequant temporary when the leaf serves quantized from the
    # packed [E·D, F] word layout; a no-op on dense params).  The router
    # stays un-quantized by policy and is always a raw leaf.
    w_in = qweight(p, "experts_w_in")
    w_gate = qweight(p, "experts_w_gate")
    w_out = qweight(p, "experts_w_out")
    e = w_in.shape[0]

    logits = (x.astype(jnp.float32) @ p["router_w"])          # [B,S,E]
    gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    c = capacity if capacity is not None else max(
        1, int(s * top_k * capacity_factor / e))

    pol = _active_policy()
    if pol is not None and pol.mode == "tp" and e % pol.model_size == 0:
        out = _apply_moe_ep_shard_map(w_in, w_gate, w_out, x, eidx, gates,
                                      e, c, top_k, act, pol)
    else:
        ex_in, dst, keep, stok, sgate = jax.vmap(
            lambda xt, ei, ga: _dispatch_row(xt, ei, ga, e, c, top_k)
        )(x, eidx, gates)
        ex_in = constrain(ex_in, "batch", "experts", None, None)  # [B,E,C,D]

        h = jnp.einsum("becd,edf->becf", ex_in, w_in)
        g = jnp.einsum("becd,edf->becf", ex_in, w_gate)
        h = constrain(f(g) * h, "batch", "experts", None, None)
        ex_out = jnp.einsum("becf,efd->becd", h, w_out)
        ex_out = constrain(ex_out, "batch", "experts", None, None)

        out = jax.vmap(lambda eo, ds, ke, st, sg: _combine_row(
            eo, ds, ke, st, sg, s))(ex_out, dst, keep, stok, sgate)

    if has_leaf(p, "shared_w_in"):
        hs = constrain(f(qmatmul(p, "shared_w_gate", x))
                       * qmatmul(p, "shared_w_in", x),
                       "batch", None, "ffn")
        out = out + qmatmul(p, "shared_w_out", hs)
    return out.astype(x.dtype)


def _apply_moe_ep_shard_map(w_in_all, w_gate_all, w_out_all, x, eidx, gates,
                            e, c, top_k, act, pol):
    """Expert-parallel dispatch with rank-local routing (shard_map).

    GSPMD cannot prove that per-token scatter/gather indices stay within
    one model rank's expert slice, so the pjit combine all-gathers the
    [B,E,C,D] expert outputs every layer (measured 190 GB/chip/step on
    granite train_4k — §Perf iteration 2).  Under shard_map each model
    rank routes only the (token, slot) pairs belonging to ITS experts,
    runs its expert FFN slice, combines a rank-local partial [B_loc,S,D],
    and one psum over ``model`` finishes the layer — the identical
    collective shape as a dense TP MLP.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    f = act_fn(act)
    b, s, d = x.shape
    daxes = pol.daxes
    e_loc = e // pol.model_size

    def rank_local(x_loc, eidx_loc, gates_loc, w_in, w_gate, w_out):
        m_idx = jax.lax.axis_index("model")
        lo = m_idx * e_loc

        def one_row(xt, ei, ga):
            # mask (token, slot) pairs routed to other ranks' experts
            rel = ei - lo                                       # [S,k]
            mine = (rel >= 0) & (rel < e_loc)
            rel = jnp.where(mine, rel, e_loc)                   # OOB → drop
            ex_in, dst, keep, stok, sgate = _dispatch_row(
                xt, rel, jnp.where(mine, ga, 0.0), e_loc + 1, c, ei.shape[-1])
            ex_in = ex_in[:e_loc]
            h = jnp.einsum("ecd,edf->ecf", ex_in, w_in)
            g = jnp.einsum("ecd,edf->ecf", ex_in, w_gate)
            ex_out = jnp.einsum("ecf,efd->ecd", f(g) * h, w_out)
            ex_out = jnp.concatenate(
                [ex_out, jnp.zeros((1, c, xt.shape[-1]), ex_out.dtype)], 0)
            return _combine_row(ex_out, dst, keep, stok, sgate, xt.shape[0])

        partial = jax.vmap(one_row)(x_loc, eidx_loc, gates_loc)
        return jax.lax.psum(partial, "model")

    return shard_map(
        rank_local, mesh=pol.mesh,
        in_specs=(P(daxes, None, None), P(daxes, None, None),
                  P(daxes, None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P(daxes, None, None),
        check_rep=False,
    )(x, eidx, gates.astype(x.dtype), w_in_all, w_gate_all, w_out_all)
