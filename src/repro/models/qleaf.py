"""Model-wide quantized-leaf ("qleaf") abstraction.

Every multiplicative weight in the model forward — MLP leaves, attention
q/k/v/o projections, the embedding table / LM head, MoE expert tensors,
SSM and RG-LRU projections — is fetched through this module, which
understands the three storage layouts a leaf may arrive in (the
``PackedModel.serving_params`` layouts):

* dense          — ``p[name]``: the training / dense-serve layout;
* uint8 indices  — ``p[f"{name}_idx"]`` + ``p[f"{name}_cb"]``: the
  1 B/weight fallback/oracle layout (``serving_params(packed=False)``);
* bit-packed     — ``p[f"{name}_pidx"]`` uint32 words + ``p[f"{name}_cb"]``
  + the static ``p[f"{name}_layout"]`` lane metadata:
  ``bits_per_index(K)/8`` B/weight (``serving_params(packed=True)``).

Call sites pick the entry point by access pattern, and
``repro.kernels.dispatch`` picks the backend:

* :func:`qmatmul`   — ``x @ W``: the fused codebook-matmul kernels
  (Mosaic dequant-in-VMEM on TPU, jnp gather-dequant reference on CPU);
* :func:`qmatmul_t` — ``x @ W.T``: the tied-embedding LM head — the
  fused transposed packed kernel (``dispatch.packed_quantized_matmul_t``;
  the HBM operand stays packed, the [V, D] table is never inflated; an
  untied ``head_w`` is [D, V] and is already fused via :func:`qmatmul`);
* :func:`qembed`    — row gather: fused unpack + LUT dequant-on-gather
  (``dispatch.quantized_gather``; Mosaic row-gather kernel on the packed
  ``pack_rows`` layout), no dense table is materialized;
* :func:`qweight`   — the dense tensor, for einsum operands and reshaped
  factors (MoE expert stacks, MLA ``w_uk``/``w_uv``) — again an in-jit
  temporary scheduled per use.

(The pre-qleaf MLP-only aliases ``layers.mlp_matmul`` / ``mlp_weight`` /
``_has_mlp_leaf`` were removed after a deprecation PR; this module is
the only weight-fetch API.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def has_leaf(p, name: str) -> bool:
    """True if ``name`` is present in any of the three storage layouts."""
    return name in p or f"{name}_idx" in p or f"{name}_pidx" in p


def qweight(p, name: str, dtype=None) -> Array:
    """Dense tensor fetch in the leaf's original shape (decode if
    quantized).  Under jit the decode is a temporary XLA schedules per
    use; only the packed arrays are HBM-resident inputs."""
    from repro.kernels import dispatch
    if f"{name}_pidx" in p:
        return dispatch.decode_packed_leaf(p[f"{name}_pidx"],
                                           p[f"{name}_cb"],
                                           p[f"{name}_layout"], dtype)
    if f"{name}_idx" in p:
        return dispatch.decode_leaf(p[f"{name}_idx"], p[f"{name}_cb"], dtype)
    w = p[name]
    return w.astype(dtype) if dtype is not None else w


def qmatmul(p, name: str, x: Array) -> Array:
    """``x @ <name>`` where ``<name>`` may be stored dense or quantized.

    Quantized leaves route through ``repro.kernels.dispatch`` — the packed
    uint32-word operand (or the uint8 oracle) feeds the codebook-matmul
    kernel path on TPU; the CPU reference is gather-dequant + the same
    ``x @ w`` contraction as the dense layout (bit-identical logits).
    """
    if f"{name}_pidx" in p:
        from repro.kernels import dispatch
        return dispatch.packed_quantized_matmul(
            x, p[f"{name}_pidx"], p[f"{name}_cb"],
            layout=p[f"{name}_layout"])
    if f"{name}_idx" in p:
        from repro.kernels import dispatch
        return dispatch.quantized_matmul(x, p[f"{name}_idx"],
                                         p[f"{name}_cb"])
    return x @ p[name]


def qmatmul_t(p, name: str, x: Array) -> Array:
    """``x @ <name>.T`` — the tied-embedding LM head over a [V, D] table
    (an untied head ``head_w`` is stored [D, V] and goes through
    :func:`qmatmul`, already fused).

    Packed leaves route through ``dispatch.packed_quantized_matmul_t`` —
    the fused transposed kernel on TPU reads the packed words directly
    (``bits_per_index(K)/8`` B/weight; the dense [V, D] table is never
    inflated); the CPU reference is the identical ``x @ decode.T`` graph
    as the dense layout (bit-exact logits).  uint8-oracle and dense
    leaves take the dequant-then-dot route (in-jit temporary).
    """
    if f"{name}_pidx" in p:
        from repro.kernels import dispatch
        return dispatch.packed_quantized_matmul_t(
            x, p[f"{name}_pidx"], p[f"{name}_cb"],
            layout=p[f"{name}_layout"])
    return x @ qweight(p, name).T


def qembed(p, name: str, tokens: Array) -> Array:
    """Row gather ``<name>[tokens]`` — embedding lookup.

    Packed layout: gather the token's uint32 word row, shift+mask the
    lane, LUT through the codebook (``dispatch.quantized_gather``) — the
    dense [V, D] table is never materialized.
    """
    if f"{name}_pidx" in p:
        from repro.kernels import dispatch
        return dispatch.quantized_gather(tokens, p[f"{name}_pidx"],
                                         p[f"{name}_cb"],
                                         layout=p[f"{name}_layout"])
    if f"{name}_idx" in p:
        idx = p[f"{name}_idx"][tokens].astype(jnp.int32)
        return p[f"{name}_cb"][idx]
    return p[name][tokens]
