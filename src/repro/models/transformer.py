"""Composable decoder stack covering all assigned architectures.

A model is a sequence of *stacks*; each stack is ``groups`` repetitions of
a layer ``pattern`` (tuple of LayerKind).  The forward scans over groups
with stacked parameters ([G, ...] leaves) so the HLO is compact regardless
of depth — 96-layer Nemotron compiles as fast as 2 layers.  Mixed layouts
(Gemma-2 local/global alternation, RecurrentGemma's rec-rec-attn 1:2
pattern, DeepSeek's dense-then-MoE split) are expressed as patterns /
multiple stacks, never as unrolled layers.

Three entry points per model:
  * ``loss_fn``      — training loss (next-token CE), full sequence;
  * ``prefill``      — forward + KV/state cache emission;
  * ``decode_step``  — one token with cache (the ``serve_step``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import qleaf as Q
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.sharding_ctx import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLASpec:
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_inner: int = 0
    head_p: int = 64
    state_n: int = 128
    conv_w: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    width: int = 0
    conv_w: int = 4


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str          # gqa | gqa_local | mla | ssm | rglru
    mlp: str = "dense"  # dense | moe | none


@dataclasses.dataclass(frozen=True)
class StackSpec:
    pattern: Tuple[LayerKind, ...]
    groups: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    stacks: Tuple[StackSpec, ...]
    mlp_act: str = "silu"
    gated_mlp: bool = True
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    ssm: Optional[SSMSpec] = None
    rglru: Optional[RGLRUSpec] = None
    post_norms: bool = False
    emb_scale: Optional[float] = None
    pos_embed: str = "rope"        # rope | sinusoidal
    vlm_patches: int = 0
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # codebook-quantized paged KV cache (serving): 0 = dense pages,
    # else bits ∈ {2,4,8}; kv_cb_mode ∈ {"page","head"} picks one
    # codebook per page or per (page, kv-head) — see core.kvquant.
    kv_bits: int = 0
    kv_cb_mode: str = "page"
    remat: bool = True
    remat_policy: str = "full"     # full (save nothing) | dots (save dot outs)
    attn_unroll: bool = False      # triangular causal schedule (nq ≤ 8)
    # notes for DESIGN/dry-run (e.g. long-context applicability)
    subquadratic: bool = False

    @property
    def n_layers(self) -> int:
        return sum(len(s.pattern) * s.groups for s in self.stacks)

    def param_count(self) -> int:
        """Analytic total param count (for 6·N·D roofline terms)."""
        import numpy as np
        shapes = jax.eval_shape(lambda k: init_params(k, self, jnp.float32),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k experts only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        per_expert = 3 * self.d_model * m.d_ff_expert
        n_moe_layers = sum(
            sum(1 for k in s.pattern if k.mlp == "moe") * s.groups
            for s in self.stacks)
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total - inactive


def uniform_stack(kind: LayerKind, n_layers: int) -> Tuple[StackSpec, ...]:
    return (StackSpec(pattern=(kind,), groups=n_layers),)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key: Array, cfg: ModelConfig, kind: LayerKind, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p: dict = {"ln1_norm_scale": jnp.zeros((cfg.d_model,), dtype)}

    if kind.mixer in ("gqa", "gqa_local"):
        p["mixer"] = attn.init_gqa(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv,
                                   cfg.head_dim, cfg.qkv_bias, dtype)
    elif kind.mixer == "mla":
        m = cfg.mla
        p["mixer"] = attn.init_mla(ks[0], cfg.d_model, cfg.n_heads,
                                   kv_lora=m.kv_lora, rope_dim=m.rope_dim,
                                   nope_dim=m.nope_dim, v_dim=m.v_dim,
                                   dtype=dtype)
    elif kind.mixer == "ssm":
        s = cfg.ssm
        p["mixer"] = ssm_mod.init_ssm(ks[0], cfg.d_model, d_inner=s.d_inner,
                                      head_p=s.head_p, state_n=s.state_n,
                                      conv_w=s.conv_w, dtype=dtype)
    elif kind.mixer == "rglru":
        r = cfg.rglru
        p["mixer"] = rglru_mod.init_rglru_block(ks[0], cfg.d_model, r.width,
                                                r.conv_w, dtype)
    else:
        raise ValueError(kind.mixer)

    if kind.mlp != "none":
        p["ln2_norm_scale"] = jnp.zeros((cfg.d_model,), dtype)
        if kind.mlp == "moe":
            m = cfg.moe
            p["mlp"] = moe_mod.init_moe(ks[1], cfg.d_model, m.d_ff_expert,
                                        m.n_experts, m.n_shared, cfg.mlp_act,
                                        dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act,
                                  cfg.gated_mlp, dtype)
    if cfg.post_norms:
        p["post1_norm_scale"] = jnp.zeros((cfg.d_model,), dtype)
        if kind.mlp != "none":
            p["post2_norm_scale"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_params(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    n_stacks = len(cfg.stacks)
    keys = jax.random.split(key, n_stacks + 2)
    params: dict = {
        "embed_tok": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                      * cfg.d_model ** -0.5).astype(dtype),
        "final_norm_scale": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head_w"] = (jax.random.normal(keys[1], (cfg.d_model, cfg.vocab))
                            * cfg.d_model ** -0.5).astype(dtype)
    stacks = []
    for si, spec in enumerate(cfg.stacks):
        gkeys = jax.random.split(jax.random.fold_in(keys[2 + si], 7), spec.groups)
        stack = {}
        for pi, kind in enumerate(spec.pattern):
            pkeys = jax.vmap(lambda k: jax.random.fold_in(k, pi))(gkeys)
            stack[f"pos{pi}"] = jax.vmap(
                lambda k: _init_layer(k, cfg, kind, dtype))(pkeys)
        stacks.append(stack)
    params["stacks"] = tuple(stacks)
    return params


# ---------------------------------------------------------------------------
# Layer application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _apply_mixer_full(kind, p, x, positions, cfg):
    """Full-sequence mixer; returns (out, prefill_cache_entry)."""
    if kind.mixer in ("gqa", "gqa_local"):
        window = cfg.window if kind.mixer == "gqa_local" else None
        out, (k, v) = attn.gqa_forward(
            p, x, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.head_dim, window=window,
            attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            query_scale=cfg.query_scale, causal_unroll=cfg.attn_unroll)
        return out, {"k": k, "v": v}
    if kind.mixer == "mla":
        m = cfg.mla
        out, cache = attn.mla_forward(
            p, x, positions, n_heads=cfg.n_heads, kv_lora=m.kv_lora,
            rope_dim=m.rope_dim, nope_dim=m.nope_dim, v_dim=m.v_dim,
            rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
            kv_chunk=cfg.kv_chunk)
        return out, cache
    if kind.mixer == "ssm":
        s = cfg.ssm
        out, state = ssm_mod.ssm_forward(p, x, d_inner=s.d_inner,
                                         head_p=s.head_p, state_n=s.state_n,
                                         chunk=s.chunk)
        return out, {"state": state}
    if kind.mixer == "rglru":
        out, state = rglru_mod.rglru_forward(p, x, width=cfg.rglru.width)
        return out, {"state": state}
    raise ValueError(kind.mixer)


def _apply_layer_full(kind, p, x, positions, cfg):
    h = L.rms_norm(x, p["ln1_norm_scale"])
    out, _ = _apply_mixer_full(kind, p["mixer"], h, positions, cfg)
    if cfg.post_norms:
        out = L.rms_norm(out, p["post1_norm_scale"])
    x = constrain(x + out, "batch", None, None)
    if kind.mlp != "none":
        h = L.rms_norm(x, p["ln2_norm_scale"])
        if kind.mlp == "moe":
            out = moe_mod.apply_moe(p["mlp"], h, top_k=cfg.moe.top_k,
                                    act=cfg.mlp_act,
                                    capacity_factor=cfg.moe.capacity_factor)
        else:
            out = L.apply_mlp(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norms:
            out = L.rms_norm(out, p["post2_norm_scale"])
        x = constrain(x + out, "batch", None, None)
    return x


def _apply_stack_full(spec: StackSpec, stack_params, x, positions, cfg):
    def body(carry, group_params):
        h = carry
        for pi, kind in enumerate(spec.pattern):
            h = _apply_layer_full(kind, group_params[f"pos{pi}"], h,
                                  positions, cfg)
        return h, None

    if cfg.remat:
        policy = (jax.checkpoint_policies.checkpoint_dots
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    x, _ = jax.lax.scan(body, x, stack_params)
    return x


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def _embed(params, cfg, tokens, patch_embeds=None, positions=None):
    # Dense gather, or dequant-on-gather when the table serves quantized
    # (packed indices → shift+mask → LUT; dispatch.quantized_gather).
    # ``positions``: global position ids [S] for a mid-prompt block
    # (blockwise prefill); defaults to arange(S).
    x = Q.qembed(params, "embed_tok", tokens)
    if cfg.emb_scale is not None:
        x = x * jnp.asarray(cfg.emb_scale, x.dtype)
    if cfg.pos_embed == "sinusoidal":
        if positions is None:
            positions = jnp.arange(tokens.shape[1])
        x = x + L.sinusoidal_positions(
            positions, cfg.d_model)[None].astype(x.dtype)
    if cfg.vlm_patches and patch_embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, patch_embeds.astype(x.dtype), (0, 0, 0))
    return constrain(x, "batch", None, None)


def _head(params, cfg, x):
    x = L.rms_norm(x, params["final_norm_scale"])
    if cfg.tie_embeddings:
        logits = Q.qmatmul_t(params, "embed_tok", x)
    else:
        logits = Q.qmatmul(params, "head_w", x)
    logits = constrain(logits, "batch", None, "vocab")
    return L.softcap(logits.astype(jnp.float32), cfg.final_softcap)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens: Array,
            patch_embeds: Optional[Array] = None) -> Array:
    """[B, S] tokens → [B, S, V] logits (f32)."""
    s = tokens.shape[1]
    positions = jnp.arange(s)
    x = _embed(params, cfg, tokens, patch_embeds)
    for spec, sp in zip(cfg.stacks, params["stacks"]):
        x = _apply_stack_full(spec, sp, x, positions, cfg)
    return _head(params, cfg, x)


def loss_fn(params, cfg: ModelConfig, batch: dict) -> Array:
    """Mean next-token cross-entropy.  batch: tokens, labels[, patch_embeds]."""
    logits = forward(params, cfg, batch["tokens"],
                     batch.get("patch_embeds"))
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# --- caches -----------------------------------------------------------------

def _init_layer_cache(kind: LayerKind, cfg: ModelConfig, batch: int,
                      capacity: int, dtype):
    if kind.mixer == "gqa":
        return attn.init_kv_cache(batch, capacity, cfg.n_kv, cfg.head_dim,
                                  dtype=dtype)
    if kind.mixer == "gqa_local":
        cap = min(capacity, cfg.window or capacity)
        return attn.init_kv_cache(batch, cap, cfg.n_kv, cfg.head_dim,
                                  dtype=dtype)
    if kind.mixer == "mla":
        m = cfg.mla
        return attn.init_mla_cache(batch, capacity, m.kv_lora, m.rope_dim, dtype)
    if kind.mixer == "ssm":
        s = cfg.ssm
        return ssm_mod.init_ssm_cache(batch, s.d_inner, s.head_p, s.state_n,
                                      s.conv_w, dtype)
    if kind.mixer == "rglru":
        return rglru_mod.init_rglru_cache(batch, cfg.rglru.width,
                                          cfg.rglru.conv_w, dtype)
    raise ValueError(kind.mixer)


def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.float32):
    """Stacked caches mirroring the param stacks: leaves [G, ...]."""
    caches = []
    for spec in cfg.stacks:
        stack = {}
        for pi, kind in enumerate(spec.pattern):
            one = _init_layer_cache(kind, cfg, batch, capacity, dtype)
            stack[f"pos{pi}"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (spec.groups,) + x.shape),
                one)
        caches.append(stack)
    return tuple(caches)


def _apply_mixer_decode(kind, p, x_t, cache, pos, cfg):
    if kind.mixer in ("gqa", "gqa_local"):
        local = kind.mixer == "gqa_local"
        return attn.gqa_decode(p, x_t, cache, pos, n_heads=cfg.n_heads,
                               n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                               ring=local, window=cfg.window if local else None,
                               attn_softcap=cfg.attn_softcap,
                               rope_theta=cfg.rope_theta,
                               query_scale=cfg.query_scale)
    if kind.mixer == "mla":
        m = cfg.mla
        return attn.mla_decode(p, x_t, cache, pos, n_heads=cfg.n_heads,
                               kv_lora=m.kv_lora, rope_dim=m.rope_dim,
                               nope_dim=m.nope_dim, v_dim=m.v_dim,
                               rope_theta=cfg.rope_theta)
    if kind.mixer == "ssm":
        s = cfg.ssm
        return ssm_mod.ssm_decode(p, x_t, cache, d_inner=s.d_inner,
                                  head_p=s.head_p, state_n=s.state_n)
    if kind.mixer == "rglru":
        return rglru_mod.rglru_decode(p, x_t, cache, width=cfg.rglru.width)
    raise ValueError(kind.mixer)


def _apply_layer_decode(kind, p, x_t, cache, pos, cfg):
    h = L.rms_norm(x_t, p["ln1_norm_scale"])
    out, cache = _apply_mixer_decode(kind, p["mixer"], h, cache, pos, cfg)
    if cfg.post_norms:
        out = L.rms_norm(out, p["post1_norm_scale"])
    x_t = x_t + out
    if kind.mlp != "none":
        h = L.rms_norm(x_t, p["ln2_norm_scale"])
        if kind.mlp == "moe":
            out = moe_mod.apply_moe(p["mlp"], h, top_k=cfg.moe.top_k,
                                    act=cfg.mlp_act,
                                    capacity_factor=cfg.moe.capacity_factor)
        else:
            out = L.apply_mlp(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norms:
            out = L.rms_norm(out, p["post2_norm_scale"])
        x_t = x_t + out
    return x_t, cache


def decode_step(params, cfg: ModelConfig, caches, tokens_t: Array, pos):
    """serve_step: one new token per sequence with existing caches.

    tokens_t: [B, 1] int32; pos: scalar int32 (current position).
    Returns (logits [B, 1, V], new caches).
    """
    x = Q.qembed(params, "embed_tok", tokens_t)
    if cfg.emb_scale is not None:
        x = x * jnp.asarray(cfg.emb_scale, x.dtype)
    if cfg.pos_embed == "sinusoidal":
        x = x + L.sinusoidal_positions(
            jnp.asarray(pos)[None], cfg.d_model)[None].astype(x.dtype)

    new_caches = []
    for spec, sp, sc in zip(cfg.stacks, params["stacks"], caches):
        def body(carry, xs):
            h = carry
            gp, gc = xs
            new_gc = {}
            for pi, kind in enumerate(spec.pattern):
                h, c = _apply_layer_decode(kind, gp[f"pos{pi}"], h,
                                           gc[f"pos{pi}"], pos, cfg)
                new_gc[f"pos{pi}"] = c
            return h, new_gc

        x, nc = jax.lax.scan(body, x, (sp, sc))
        new_caches.append(nc)
    return _head(params, cfg, x), tuple(new_caches)


# --- paged caches (continuous-batching engine) ------------------------------
#
# Global-attention layers share one physical page pool per layer position
# ([G, n_pages + 1, page, ...]; page 0 is the reserved trash page) indexed
# by ONE per-slot page table — every layer caches the same logical
# positions, so the table is model-wide, not per-layer.  SSM / RG-LRU /
# sliding-window layers keep constant-size per-slot state ([G, n_slots,
# ...]) that simply resets on admission.  ``decode_step_slots`` is the
# engine's serve step: fixed shapes for any admission/eviction state, so
# admitting a request never recompiles.


def _init_layer_paged_cache(kind: LayerKind, cfg: ModelConfig, n_slots: int,
                            n_pages: int, page_size: int, dtype):
    if kind.mixer == "gqa":
        if cfg.kv_bits:
            return attn.init_quant_paged_kv_cache(
                n_pages, page_size, cfg.n_kv, cfg.head_dim, cfg.kv_bits,
                cfg.kv_cb_mode, dtype)
        return attn.init_paged_kv_cache(n_pages, page_size, cfg.n_kv,
                                        cfg.head_dim, dtype)
    if kind.mixer == "gqa_local":
        # ring buffers stay dense: constant-size per-slot state, no pages
        return attn.init_kv_cache(n_slots, cfg.window or n_pages * page_size,
                                  cfg.n_kv, cfg.head_dim, dtype)
    if kind.mixer == "mla":
        m = cfg.mla
        if cfg.kv_bits:
            return attn.init_quant_paged_mla_cache(
                n_pages, page_size, m.kv_lora, m.rope_dim, cfg.kv_bits,
                dtype)
        return attn.init_paged_mla_cache(n_pages, page_size, m.kv_lora,
                                         m.rope_dim, dtype)
    if kind.mixer == "ssm":
        s = cfg.ssm
        return ssm_mod.init_ssm_cache(n_slots, s.d_inner, s.head_p,
                                      s.state_n, s.conv_w, dtype)
    if kind.mixer == "rglru":
        return rglru_mod.init_rglru_cache(n_slots, cfg.rglru.width,
                                          cfg.rglru.conv_w, dtype)
    raise ValueError(kind.mixer)


def init_paged_cache(cfg: ModelConfig, n_slots: int, n_pages: int,
                     page_size: int, dtype=jnp.float32):
    """Engine decode caches mirroring the param stacks: leaves [G, ...]."""
    caches = []
    for spec in cfg.stacks:
        stack = {}
        for pi, kind in enumerate(spec.pattern):
            one = _init_layer_paged_cache(kind, cfg, n_slots, n_pages,
                                          page_size, dtype)
            stack[f"pos{pi}"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (spec.groups,) + x.shape),
                one)
        caches.append(stack)
    return tuple(caches)


def _gate_slot_cache(new, old, alive: Array):
    """Keep masked slots' per-slot state untouched (page-starved slots
    must resume bit-exactly; leading cache dim is the slot dim)."""
    def sel(n, o):
        m = alive.reshape((alive.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree_util.tree_map(sel, new, old)


def _apply_mixer_decode_slots(kind, p, x_t, cache, page_table, pos, alive,
                              cfg):
    if kind.mixer == "gqa":
        if isinstance(cache, attn.QuantPagedKVCache):
            page_size = cache.k_words.shape[1]
            return attn.gqa_decode_paged_quant(
                p, x_t, cache, page_table, pos, alive, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv, head_dim=cfg.head_dim, page_size=page_size,
                kv_bits=cfg.kv_bits, kv_cb_mode=cfg.kv_cb_mode,
                attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
                query_scale=cfg.query_scale)
        page_size = cache.k.shape[1]
        return attn.gqa_decode_paged(
            p, x_t, cache, page_table, pos, alive, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv, head_dim=cfg.head_dim, page_size=page_size,
            attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            query_scale=cfg.query_scale)
    if kind.mixer == "gqa_local":
        out, c = attn.gqa_decode_ring_slots(
            p, x_t, cache, pos, alive, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.head_dim, window=cfg.window,
            attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            query_scale=cfg.query_scale)
        return out, _gate_slot_cache(c, cache, alive)
    if kind.mixer == "mla":
        m = cfg.mla
        if isinstance(cache, attn.QuantPagedMLACache):
            page_size = cache.c_words.shape[1]
            return attn.mla_decode_paged_quant(
                p, x_t, cache, page_table, pos, alive, n_heads=cfg.n_heads,
                kv_lora=m.kv_lora, rope_dim=m.rope_dim, nope_dim=m.nope_dim,
                v_dim=m.v_dim, page_size=page_size, kv_bits=cfg.kv_bits,
                rope_theta=cfg.rope_theta)
        page_size = cache.c_kv.shape[1]
        return attn.mla_decode_paged(
            p, x_t, cache, page_table, pos, alive, n_heads=cfg.n_heads,
            kv_lora=m.kv_lora, rope_dim=m.rope_dim, nope_dim=m.nope_dim,
            v_dim=m.v_dim, page_size=page_size, rope_theta=cfg.rope_theta)
    if kind.mixer == "ssm":
        s = cfg.ssm
        out, c = ssm_mod.ssm_decode(p, x_t, cache, d_inner=s.d_inner,
                                    head_p=s.head_p, state_n=s.state_n)
        return out, _gate_slot_cache(c, cache, alive)
    if kind.mixer == "rglru":
        out, c = rglru_mod.rglru_decode(p, x_t, cache, width=cfg.rglru.width)
        return out, _gate_slot_cache(c, cache, alive)
    raise ValueError(kind.mixer)


def _apply_layer_decode_slots(kind, p, x_t, cache, page_table, pos, alive,
                              cfg):
    h = L.rms_norm(x_t, p["ln1_norm_scale"])
    out, cache = _apply_mixer_decode_slots(kind, p["mixer"], h, cache,
                                           page_table, pos, alive, cfg)
    if cfg.post_norms:
        out = L.rms_norm(out, p["post1_norm_scale"])
    x_t = x_t + out
    if kind.mlp != "none":
        h = L.rms_norm(x_t, p["ln2_norm_scale"])
        if kind.mlp == "moe":
            out = moe_mod.apply_moe(p["mlp"], h, top_k=cfg.moe.top_k,
                                    act=cfg.mlp_act,
                                    capacity_factor=cfg.moe.capacity_factor)
        else:
            out = L.apply_mlp(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norms:
            out = L.rms_norm(out, p["post2_norm_scale"])
        x_t = x_t + out
    return x_t, cache


def decode_step_slots(params, cfg: ModelConfig, caches, page_table,
                      tokens_t: Array, pos: Array, alive: Array):
    """Slot-aware serve step for the continuous-batching engine.

    tokens_t [B, 1] int32 (B = n_slots); pos [B] int32 per-slot write
    positions; alive [B] bool.  Dead / page-starved slots are masked:
    their attention reads are invalid, their pool writes land on the
    reserved trash page, and their per-slot state (ring / SSM / RG-LRU)
    is left untouched.  Returns (logits [B, 1, V], new caches); shapes
    are independent of which slots are live, so admission never
    recompiles.
    """
    x = Q.qembed(params, "embed_tok", tokens_t)
    if cfg.emb_scale is not None:
        x = x * jnp.asarray(cfg.emb_scale, x.dtype)
    if cfg.pos_embed == "sinusoidal":
        x = x + L.sinusoidal_positions(pos[:, None],
                                       cfg.d_model).astype(x.dtype)

    new_caches = []
    for spec, sp, sc in zip(cfg.stacks, params["stacks"], caches):
        def body(carry, xs):
            h = carry
            gp, gc = xs
            new_gc = {}
            for pi, kind in enumerate(spec.pattern):
                h, c = _apply_layer_decode_slots(
                    kind, gp[f"pos{pi}"], h, gc[f"pos{pi}"], page_table,
                    pos, alive, cfg)
                new_gc[f"pos{pi}"] = c
            return h, new_gc

        x, nc = jax.lax.scan(body, x, (sp, sc))
        new_caches.append(nc)
    return _head(params, cfg, x), tuple(new_caches)


# Default prompt-block length for the one-shot (oracle) blockwise
# prefill.  The engine's block length is its `prefill_chunk`; engine
# differential tests must run the oracle with the engine's effective
# chunk so both sides see the same block partition (the flash recurrence
# is partition-sensitive at the bit level).
DEFAULT_PREFILL_BLOCK = 64


def _init_layer_block_state(kind: LayerKind, cfg: ModelConfig, batch: int,
                            dtype):
    """Initial blockwise-prefill carry for one layer (unstacked).

    gqa/mla carry *growing* K/V (latent) buffers starting at length 0;
    gqa_local carries a ring of capacity ``cfg.window`` (the engine's
    per-slot ring capacity — required so engine and oracle views tile
    identically); ssm/rglru carry their decode caches (state + raw conv
    tails)."""
    if kind.mixer == "gqa":
        e = jnp.zeros((batch, 0, cfg.n_kv, cfg.head_dim), dtype)
        return attn.KVCache(k=e, v=e)
    if kind.mixer == "gqa_local":
        if not cfg.window:
            raise ValueError("blockwise prefill needs a finite cfg.window "
                             "for gqa_local layers (ring capacity)")
        z = jnp.zeros((batch, cfg.window, cfg.n_kv, cfg.head_dim), dtype)
        return attn.KVCache(k=z, v=z)
    if kind.mixer == "mla":
        m = cfg.mla
        return attn.MLACache(
            c_kv=jnp.zeros((batch, 0, m.kv_lora), dtype),
            k_rope=jnp.zeros((batch, 0, m.rope_dim), dtype))
    if kind.mixer == "ssm":
        s = cfg.ssm
        return ssm_mod.init_ssm_cache(batch, s.d_inner, s.head_p,
                                      s.state_n, s.conv_w, dtype)
    if kind.mixer == "rglru":
        return rglru_mod.init_rglru_cache(batch, cfg.rglru.width,
                                          cfg.rglru.conv_w, dtype)
    raise ValueError(kind.mixer)


def _apply_mixer_block(kind, p, x, state, start, cfg):
    """One prompt block through a mixer, carrying its prefill state."""
    if kind.mixer == "gqa":
        out, bk, bv = attn.gqa_prefill_block(
            p, x, state.k, state.v, start, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv, head_dim=cfg.head_dim,
            attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            query_scale=cfg.query_scale)
        return out, attn.KVCache(k=bk, v=bv)
    if kind.mixer == "gqa_local":
        return attn.gqa_prefill_block_ring(
            p, x, state, start, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.head_dim, window=cfg.window,
            attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            query_scale=cfg.query_scale)
    if kind.mixer == "mla":
        m = cfg.mla
        out, bc, br = attn.mla_prefill_block(
            p, x, state.c_kv, state.k_rope, start, n_heads=cfg.n_heads,
            kv_lora=m.kv_lora, rope_dim=m.rope_dim, nope_dim=m.nope_dim,
            v_dim=m.v_dim, rope_theta=cfg.rope_theta)
        return out, attn.MLACache(c_kv=bc, k_rope=br)
    if kind.mixer == "ssm":
        s = cfg.ssm
        return ssm_mod.ssm_block_forward(p, x, state, d_inner=s.d_inner,
                                         head_p=s.head_p,
                                         state_n=s.state_n, chunk=s.chunk)
    if kind.mixer == "rglru":
        return rglru_mod.rglru_block_forward(p, x, state,
                                             width=cfg.rglru.width)
    raise ValueError(kind.mixer)


def _apply_layer_block(kind, p, x, state, start, cfg):
    h = L.rms_norm(x, p["ln1_norm_scale"])
    out, state = _apply_mixer_block(kind, p["mixer"], h, state, start, cfg)
    if cfg.post_norms:
        out = L.rms_norm(out, p["post1_norm_scale"])
    x = x + out
    if kind.mlp != "none":
        h = L.rms_norm(x, p["ln2_norm_scale"])
        if kind.mlp == "moe":
            out = moe_mod.apply_moe(p["mlp"], h, top_k=cfg.moe.top_k,
                                    act=cfg.mlp_act,
                                    capacity_factor=cfg.moe.capacity_factor)
        else:
            out = L.apply_mlp(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norms:
            out = L.rms_norm(out, p["post2_norm_scale"])
        x = x + out
    return x, state


def _block_state_to_cache(kind: LayerKind, state, s: int,
                          cfg: ModelConfig):
    """Final blockwise-prefill carry → decode-cache layout (leaves keep
    their leading [G] group dim).  Same contract the full-sequence
    prefill used to emit — except ssm/rglru conv tails are now the
    *real* trailing raw activations, not zeros, so decode resumes the
    conv streams exactly."""
    if kind.mixer == "gqa_local":
        w = cfg.window
        if s < w:
            # ring never wrapped: natural order, capacity = S (grown by
            # the decode loop); at S ≥ W the ring layout is already
            # positions mod W
            return attn.KVCache(k=state.k[:, :, :s], v=state.v[:, :, :s])
        return state
    return state


def prefill(params, cfg: ModelConfig, tokens: Array,
            patch_embeds: Optional[Array] = None,
            last_logits_only: bool = False,
            block: Optional[int] = None):
    """Blockwise forward over the prompt, emitting logits + decode caches.

    The prompt runs in fixed blocks of ``block`` tokens (default
    :data:`DEFAULT_PREFILL_BLOCK`, remainder last); every block attends
    over the carried K/V written so far via the online-softmax blockwise
    op (``dispatch.blockwise_prefill_attention``), and SSM / RG-LRU /
    ring layers carry their recurrent state across blocks.  Peak
    activation memory is O(block·S) in attention reads but O(block) in
    scores/logits — never O(S²).

    ``last_logits_only=True`` (the serving configuration) heads only the
    final position — full-sequence f32 logits over a 150k-250k vocab are
    a multi-GB/chip buffer that serving never needs.

    ``patch_embeds`` (VLM) forces a single block: patch rows replace the
    leading positions at embed time.

    Emits *full-length* caches for gqa/mla layers (capacity = S);
    ring-buffer layers keep the last ``window`` entries.
    """
    b, s = tokens.shape
    if patch_embeds is not None:
        blk = s
    else:
        blk = max(1, min(block or DEFAULT_PREFILL_BLOCK, s))
    starts = list(range(0, s, blk))
    states = None
    logits_parts = []
    for start in starts:
        end = min(start + blk, s)
        tok_blk = jax.lax.slice_in_dim(tokens, start, end, axis=1)
        x = _embed(params, cfg, tok_blk, patch_embeds,
                   positions=jnp.arange(start, end))
        if states is None:
            states = [
                {f"pos{pi}": jax.tree_util.tree_map(
                    lambda l: jnp.broadcast_to(
                        l[None], (spec.groups,) + l.shape),
                    _init_layer_block_state(kind, cfg, b, x.dtype))
                 for pi, kind in enumerate(spec.pattern)}
                for spec in cfg.stacks]
        new_states = []
        for spec, sp, st in zip(cfg.stacks, params["stacks"], states):
            def body(h, xs):
                gp, gst = xs
                ngst = {}
                for pi, kind in enumerate(spec.pattern):
                    h, c = _apply_layer_block(kind, gp[f"pos{pi}"], h,
                                              gst[f"pos{pi}"], start, cfg)
                    ngst[f"pos{pi}"] = c
                return h, ngst

            x, nst = jax.lax.scan(body, x, (sp, st))
            new_states.append(nst)
        states = new_states
        if not last_logits_only:
            logits_parts.append(_head(params, cfg, x))
        elif start == starts[-1]:
            logits_parts.append(_head(params, cfg, x[:, -1:, :]))
    logits = (logits_parts[0] if len(logits_parts) == 1
              else jnp.concatenate(logits_parts, axis=1))
    caches = tuple(
        {f"pos{pi}": _block_state_to_cache(kind, st[f"pos{pi}"], s, cfg)
         for pi, kind in enumerate(spec.pattern)}
        for spec, st in zip(cfg.stacks, states))
    return logits, caches


# --- engine-side blockwise prefill (one slot, one block) --------------------


def _apply_mixer_prefill_slot(kind, p, x, cache, table_row, sl, start,
                              alive, cfg):
    """One prompt block of one *slot* against the engine's paged /
    per-slot caches.  ``cache`` leaves are unstacked (the group scan
    strips [G]); ``table_row`` [1, npg]; ``sl`` [1] traced slot id."""
    if kind.mixer == "gqa":
        if isinstance(cache, attn.QuantPagedKVCache):
            page_size = cache.k_words.shape[1]
            return attn.gqa_prefill_block_paged_quant(
                p, x, cache, table_row, start, alive, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv, head_dim=cfg.head_dim, page_size=page_size,
                kv_bits=cfg.kv_bits, kv_cb_mode=cfg.kv_cb_mode,
                attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
                query_scale=cfg.query_scale)
        page_size = cache.k.shape[1]
        return attn.gqa_prefill_block_paged(
            p, x, cache, table_row, start, alive, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv, head_dim=cfg.head_dim, page_size=page_size,
            attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            query_scale=cfg.query_scale)
    if kind.mixer == "mla":
        m = cfg.mla
        if isinstance(cache, attn.QuantPagedMLACache):
            page_size = cache.c_words.shape[1]
            return attn.mla_prefill_block_paged_quant(
                p, x, cache, table_row, start, alive, n_heads=cfg.n_heads,
                kv_lora=m.kv_lora, rope_dim=m.rope_dim,
                nope_dim=m.nope_dim, v_dim=m.v_dim, page_size=page_size,
                kv_bits=cfg.kv_bits, rope_theta=cfg.rope_theta)
        page_size = cache.c_kv.shape[1]
        return attn.mla_prefill_block_paged(
            p, x, cache, table_row, start, alive, n_heads=cfg.n_heads,
            kv_lora=m.kv_lora, rope_dim=m.rope_dim, nope_dim=m.nope_dim,
            v_dim=m.v_dim, page_size=page_size, rope_theta=cfg.rope_theta)
    # per-slot state rows (ring / ssm / rglru): pull the slot's row,
    # run the same block function the oracle runs, scatter it back
    row = jax.tree_util.tree_map(lambda l: jnp.take(l, sl, axis=0), cache)
    if kind.mixer in ("ssm", "rglru"):
        # block 0 of a *reused* slot must not consume the previous
        # request's recurrent state: the fresh row is all-zero.  (The
        # ring needs no reset — _ring_positions derives validity from
        # ``start``, so stale rows mask out on their own.)
        row = jax.tree_util.tree_map(
            lambda l: jnp.where(start == 0, jnp.zeros_like(l), l), row)
    if kind.mixer == "gqa_local":
        out, c = attn.gqa_prefill_block_ring(
            p, x, row, start, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            head_dim=cfg.head_dim, window=cfg.window,
            attn_softcap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            query_scale=cfg.query_scale)
    elif kind.mixer == "ssm":
        s = cfg.ssm
        out, c = ssm_mod.ssm_block_forward(p, x, row, d_inner=s.d_inner,
                                           head_p=s.head_p,
                                           state_n=s.state_n, chunk=s.chunk)
    elif kind.mixer == "rglru":
        out, c = rglru_mod.rglru_block_forward(p, x, row,
                                               width=cfg.rglru.width)
    else:
        raise ValueError(kind.mixer)
    new = jax.tree_util.tree_map(
        lambda dst, src: dst.at[sl[0]].set(src[0].astype(dst.dtype)),
        cache, c)
    return out, new


def _apply_layer_prefill_slot(kind, p, x, cache, table_row, sl, start,
                              alive, cfg):
    h = L.rms_norm(x, p["ln1_norm_scale"])
    out, cache = _apply_mixer_prefill_slot(kind, p["mixer"], h, cache,
                                           table_row, sl, start, alive, cfg)
    if cfg.post_norms:
        out = L.rms_norm(out, p["post1_norm_scale"])
    x = x + out
    if kind.mlp != "none":
        h = L.rms_norm(x, p["ln2_norm_scale"])
        if kind.mlp == "moe":
            out = moe_mod.apply_moe(p["mlp"], h, top_k=cfg.moe.top_k,
                                    act=cfg.mlp_act,
                                    capacity_factor=cfg.moe.capacity_factor)
        else:
            out = L.apply_mlp(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norms:
            out = L.rms_norm(out, p["post2_norm_scale"])
        x = x + out
    return x, cache


def prefill_chunk_slots(params, cfg: ModelConfig, caches, page_table,
                        tokens_c: Array, slot, start):
    """Engine blockwise prefill: ONE block of ``c`` prompt tokens for ONE
    slot, against the shared paged caches.

    tokens_c [1, c] int32 (positions [start, start+c)); ``slot`` and
    ``start`` are traced int32 scalars — compiled shapes depend only on
    ``c``, so chunk steps never recompile per slot or offset.  The
    block's K/V (quantized when ``kv_bits > 0``) lands directly in the
    slot's pages; recurrent state (ring / SSM / RG-LRU rows) advances in
    place.  Returns (last-position logits [1, 1, V] f32, new caches) —
    the logits are only meaningful on the prompt's final block, where
    they seed the first sampled token.
    """
    c = tokens_c.shape[1]
    sl = jnp.asarray(slot, jnp.int32).reshape(1)
    start = jnp.asarray(start, jnp.int32)
    alive = jnp.ones((1,), bool)
    table_row = jnp.take(page_table, sl, axis=0)
    x = _embed(params, cfg, tokens_c, positions=start + jnp.arange(c))
    new_caches = []
    for spec, sp, sc in zip(cfg.stacks, params["stacks"], caches):
        def body(h, xs):
            gp, gc = xs
            ngc = {}
            for pi, kind in enumerate(spec.pattern):
                h, cc = _apply_layer_prefill_slot(
                    kind, gp[f"pos{pi}"], h, gc[f"pos{pi}"], table_row,
                    sl, start, alive, cfg)
                ngc[f"pos{pi}"] = cc
            return h, ngc

        x, nc = jax.lax.scan(body, x, (sp, sc))
        new_caches.append(nc)
    return _head(params, cfg, x[:, -1:, :]), tuple(new_caches)
