"""Quickstart: LC-quantize a small classifier to 1 bit/weight in ~1 min.

    PYTHONPATH=src python examples/quickstart.py [--k 2] [--scheme adaptive]

The whole pipeline hangs off two artifacts:

* ``CompressionPlan`` — a declarative spec bundling the quantization
  *scheme* (``adaptive:K``, ``binary``, ``ternary_scale``, ``pow2:C`` …,
  resolved through the scheme registry), the *qspec policy* (which leaves
  quantize — multiplicative weights only, paper §5), and the *LC config*
  (μ schedule, iterations).  Every stage — DC baseline, LC training,
  distributed C steps — consumes the same plan.
* ``PackedModel`` — what ``plan.pack(params, lc_state)`` emits after the
  fit: bit-packed assignment indices + per-leaf codebooks + the paper's
  eq.-14 accounting, with ``save``/``load``/``decode`` and a
  ``serving_params()`` layout the quantized serving path executes
  directly (see examples/serve_quantized.py).

This script walks the paper's comparison (fig. 9): train reference →
DC baseline → LC → pack + accounting.
"""
import argparse

import jax
import numpy as np

from repro.core import CompressionPlan, LCConfig, baselines
from repro.data.synthetic import mnist_like
from repro.models.paper_nets import (classification_error, cross_entropy,
                                     init_mlp_classifier, mlp_logits)
from repro.train.trainer import (LCTrainer, TrainerConfig, init_train_state,
                                 make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=2, help="codebook size")
    ap.add_argument("--scheme", default="adaptive",
                    choices=["adaptive", "binary", "binary_scale",
                             "ternary", "ternary_scale", "pow2"])
    args = ap.parse_args()

    print("1) training reference net (784-8-10 on synthetic MNIST-like)...")
    X, Y = mnist_like(0, 4096, noise=1.0)
    params = init_mlp_classifier(jax.random.PRNGKey(0), [784, 8, 10])

    def loss_fn(p, batch):
        return cross_entropy(mlp_logits(p, batch[0]), batch[1])

    def batches():
        i = 0
        while True:
            k = jax.random.fold_in(jax.random.PRNGKey(1), i)
            idx = jax.random.randint(k, (256,), 0, X.shape[0])
            yield (X[idx], Y[idx])
            i += 1

    tc = TrainerConfig(lr=0.1, steps_per_l=40)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(loss_fn, tc))
    it = batches()
    for _ in range(500):
        state, m = step(state, next(it))
    ref = state.params
    ref_loss = float(loss_fn(ref, (X, Y)))
    print(f"   reference loss = {ref_loss:.5f}, "
          f"err = {float(classification_error(mlp_logits(ref, X), Y)):.3f}")

    spec = (f"adaptive:{args.k}" if args.scheme == "adaptive"
            else args.scheme)
    plan = CompressionPlan.parse(
        spec, lc=LCConfig(mu0=1e-3, mu_growth=1.25, num_lc_iters=30))

    print(f"2) direct compression (DC) baseline with plan={spec}...")
    dc, _ = baselines.direct_compression(jax.random.PRNGKey(0), ref, plan)
    print(f"   DC loss = {float(loss_fn(dc, (X, Y))):.5f}")

    print("3) LC algorithm (augmented Lagrangian, clipped-LR L steps)...")
    tr = LCTrainer.from_plan(loss_fn, plan, ref, tc)
    st = tr.init(jax.random.PRNGKey(0), ref)
    st = tr.run(st, it, log_every=10)
    q = tr.finalize(st)
    lc_loss = float(loss_fn(q, (X, Y)))
    print(f"   LC loss = {lc_loss:.5f}, "
          f"err = {float(classification_error(mlp_logits(q, X), Y)):.3f}")
    print(f"   layer-0 values: {np.unique(np.asarray(q['fc0']['w']))}")

    packed = plan.pack(st.params, st.lc_state, tr.qspec)
    s = packed.summary()
    print(f"4) compression (eq. 14): P1={s['p1']} P0={s['p0']} "
          f"ρ = ×{s['ratio']:.1f}  ({s['bits_per_weight']} bit/weight + "
          f"{s['codebook_entries']} codebook floats; "
          f"{s['ref_bytes']} B → {s['packed_bytes']} B packed)")


if __name__ == "__main__":
    main()
