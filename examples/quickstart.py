"""Quickstart: LC-quantize a small classifier to 1 bit/weight in ~1 min.

    PYTHONPATH=src python examples/quickstart.py [--k 2] [--scheme adaptive]

Walks the full paper pipeline: train reference → DC baseline → LC
(learning-compression) → compression accounting — and prints the same
comparison the paper's fig. 9 makes.
"""
import argparse

import jax
import numpy as np

from repro.core import (LCConfig, baselines, compression, default_qspec,
                        make_scheme, param_counts, codebook_entry_count)
from repro.data.synthetic import mnist_like
from repro.models.paper_nets import (classification_error, cross_entropy,
                                     init_mlp_classifier, mlp_logits)
from repro.train.trainer import (LCTrainer, TrainerConfig, init_train_state,
                                 make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=2, help="codebook size")
    ap.add_argument("--scheme", default="adaptive",
                    choices=["adaptive", "binary", "binary_scale",
                             "ternary", "ternary_scale", "pow2"])
    args = ap.parse_args()

    print("1) training reference net (784-8-10 on synthetic MNIST-like)...")
    X, Y = mnist_like(0, 4096, noise=1.0)
    params = init_mlp_classifier(jax.random.PRNGKey(0), [784, 8, 10])

    def loss_fn(p, batch):
        return cross_entropy(mlp_logits(p, batch[0]), batch[1])

    def batches():
        i = 0
        while True:
            k = jax.random.fold_in(jax.random.PRNGKey(1), i)
            idx = jax.random.randint(k, (256,), 0, X.shape[0])
            yield (X[idx], Y[idx])
            i += 1

    tc = TrainerConfig(lr=0.1, steps_per_l=40)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(loss_fn, tc))
    it = batches()
    for _ in range(500):
        state, m = step(state, next(it))
    ref = state.params
    ref_loss = float(loss_fn(ref, (X, Y)))
    print(f"   reference loss = {ref_loss:.5f}, "
          f"err = {float(classification_error(mlp_logits(ref, X), Y)):.3f}")

    spec = (f"adaptive:{args.k}" if args.scheme == "adaptive"
            else args.scheme)
    scheme = make_scheme(spec)
    qspec = default_qspec(ref)

    print(f"2) direct compression (DC) baseline with scheme={spec}...")
    dc, _ = baselines.direct_compression(jax.random.PRNGKey(0), ref, scheme,
                                         qspec)
    print(f"   DC loss = {float(loss_fn(dc, (X, Y))):.5f}")

    print("3) LC algorithm (augmented Lagrangian, clipped-LR L steps)...")
    tr = LCTrainer(loss_fn, scheme, qspec,
                   LCConfig(mu0=1e-3, mu_growth=1.25, num_lc_iters=30), tc)
    st = tr.init(jax.random.PRNGKey(0), ref)
    st = tr.run(st, it, log_every=10)
    q = tr.finalize(st)
    lc_loss = float(loss_fn(q, (X, Y)))
    print(f"   LC loss = {lc_loss:.5f}, "
          f"err = {float(classification_error(mlp_logits(q, X), Y)):.3f}")
    print(f"   layer-0 values: {np.unique(np.asarray(q['fc0']['w']))}")

    p1, p0 = param_counts(ref, qspec)
    entries = codebook_entry_count(st.lc_state, scheme)
    rho = compression.compression_ratio(p1, p0, max(args.k, 2), entries)
    print(f"4) compression: P1={p1} P0={p0} ρ = ×{rho:.1f}  "
          f"({scheme.bits_per_weight} bit/weight + {entries} codebook floats)")


if __name__ == "__main__":
    main()
