"""Paper §5.2 walkthrough: quantizing linear regression with a clustered,
non-Gaussian weight distribution (the controlled setting with exact
closed-form L steps).

    PYTHONPATH=src python examples/superres_regression.py [--k 2]

Reproduces the fig. 7 findings: DC = iDC (both stall at iteration 1),
LC reaches a much lower loss, and the learned centroids sit where the
loss wants them — not where the reference weight histogram peaks.
"""
import argparse

import numpy as np

from benchmarks.bench_superres import run_case


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=2)
    args = ap.parse_args()

    r = run_case(args.k)
    print(f"K = {args.k}")
    print(f"  reference loss : {r['ref_loss']:.4f}")
    print(f"  DC   loss      : {r['dc_loss']:.4f}")
    print(f"  iDC  loss      : {r['idc_loss']:.4f}  "
          f"(stalled = {r['idc_stalled']} — matches the paper)")
    print(f"  LC   loss      : {r['lc_loss']:.4f}  "
          f"({r['dc_loss'] / r['lc_loss']:.2f}x better than DC)")
    print(f"  LC centroids   : {np.round(r['centroids'], 4)}")
    print(f"  k-means iters  : first C step = {r['kmeans_iters_first']}, "
          f"late C steps = {r['kmeans_iters_late']} (fig. 10 warm start)")


if __name__ == "__main__":
    main()
