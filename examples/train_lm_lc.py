"""End-to-end driver: train an LM with LC quantization as a first-class
training feature — reference phase, then alternating L/C phases, with
checkpoint/restart supervision.

    PYTHONPATH=src python examples/train_lm_lc.py --preset tiny
    PYTHONPATH=src python examples/train_lm_lc.py --preset 100m \
        --ref-steps 300 --lc-iters 20          # ~100M params (CPU: hours)

Presets build a qwen-family config scaled to size; any --arch from the
zoo works with --preset arch (reduced).  The LC state (μ, λ, codebooks)
rides in every checkpoint, so kill/resume continues the same constrained
optimization path.
"""
import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduce_config
from repro.core import (LCConfig, compression, default_qspec, make_scheme,
                        param_counts, codebook_entry_count)
from repro.data.pipeline import LMTokenPipeline
from repro.models.transformer import (LayerKind, init_params,
                                      loss_fn as lm_loss, uniform_stack)
from repro.train import checkpoint as ckpt
from repro.train.trainer import (LCTrainer, TrainerConfig, init_train_state,
                                 make_train_step)


def preset_config(name: str):
    base = get_config("qwen1.5-0.5b")
    if name == "tiny":
        return reduce_config(base)
    if name == "100m":
        return dataclasses.replace(
            base, name="lm-100m", d_model=512, n_heads=8, n_kv=8,
            head_dim=64, d_ff=1408, vocab=32768,
            stacks=uniform_stack(LayerKind("gqa", "dense"), 12),
            q_chunk=256, kv_chunk=256)
    if name in list_archs():
        return reduce_config(get_config(name))
    raise SystemExit(f"unknown preset {name}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ref-steps", type=int, default=60)
    ap.add_argument("--lc-iters", type=int, default=8)
    ap.add_argument("--steps-per-l", type=int, default=10)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_lc")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = preset_config(args.preset)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n/1e6:.1f}M params, {cfg.n_layers} layers")

    pipe = LMTokenPipeline(seed=0, batch=args.batch, seq_len=args.seq,
                           vocab=cfg.vocab)

    def loss(p, batch):
        return lm_loss(p, cfg, batch)

    # --- phase 1: reference ------------------------------------------------
    tc = TrainerConfig(optimizer="adamw", lr=3e-3, steps_per_l=args.steps_per_l)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(loss, tc))
    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        state, extra, start = ckpt.restore_checkpoint(args.ckpt_dir,
                                                      like=state)
        pipe.state.step = int(extra.get("data_step", start))
        print(f"resumed at step {start}")
    for i in range(start, args.ref_steps):
        state, m = step(state, pipe.next())
        if i % 20 == 0:
            print(f"[ref {i:4d}] loss={float(m['loss']):.4f}")
        if (i + 1) % 50 == 0:
            ckpt.save_checkpoint(args.ckpt_dir, i + 1, state,
                                 extra={"data_step": pipe.state.step})
    ref_loss = float(m["loss"])

    # --- phase 2: LC quantization -------------------------------------------
    qspec = default_qspec(state.params)
    scheme = make_scheme(f"adaptive:{args.k}")
    tr = LCTrainer(loss, scheme, qspec,
                   LCConfig(mu0=1e-2, mu_growth=1.4,
                            num_lc_iters=args.lc_iters),
                   TrainerConfig(optimizer="adamw", lr=1e-3,
                                 steps_per_l=args.steps_per_l))
    lc_state = tr.init(jax.random.PRNGKey(1), state.params)
    lc_state = tr.run(lc_state, iter(pipe), log_every=1)
    q = tr.finalize(lc_state)
    q_loss = float(loss(q, pipe.next()))

    p1, p0 = param_counts(state.params, qspec)
    rho = compression.compression_ratio(
        p1, p0, args.k, codebook_entry_count(lc_state.lc_state, scheme))
    print(f"\nreference loss {ref_loss:.4f} → quantized loss {q_loss:.4f} "
          f"at {scheme.bits_per_weight} bits/weight (ρ = ×{rho:.1f})")
    wq = q["stacks"][0]["pos0"]["mlp"]["w_in"]
    print("per-layer codebooks (layer 0 mlp.w_in uniques):",
          np.unique(np.asarray(wq[0]))[:8])


if __name__ == "__main__":
    main()
