"""End-to-end quantized serving through the CompressionPlan → PackedModel
API (the memory-roofline payoff of the paper).

    PYTHONPATH=src python examples/serve_quantized.py [--requests 4]

Pipeline — each arrow is one API call:

    CompressionPlan.parse("adaptive:K")          # scheme+qspec+LC config
      → LCTrainer.from_plan(...).run(...)        # LC fit (train-tiny)
      → plan.pack(params, lc_state)              # PackedModel artifact
      → packed.save(dir) / PackedModel.load(dir) # on-disk round trip
      → packed.serving_params(packed=True)       # bit-packed uint32 words
                                                 #   + codebooks + layout
      → prefill/decode (every quantized leaf — attention q/k/v/o, the
        embedding table / LM head, MLP — serves from the packed layout
        through repro.models.qleaf → repro.kernels.dispatch: codebook
        matmuls + embedding dequant-on-gather, Mosaic on TPU, jnp
        reference on CPU — bits_per_index(K)/8 bytes/weight of HBM index
        traffic for the whole model, not just the MLP sublayer)

The script verifies the acceptance contract: ``load().decode()`` is
bit-exact vs the LC ``finalize`` params, and serving from the bit-packed
layout reproduces both the legacy uint8-index layout (the retained
fallback/oracle, ``packed=False``) and the dense-reference logits within
1e-2.
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import CompressionPlan, LCConfig, PackedModel
from repro.data.pipeline import LMTokenPipeline
from repro.kernels import dispatch
from repro.models.transformer import (decode_step, init_params, loss_fn,
                                      prefill)
from repro.train.trainer import LCTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--k", type=int, default=16)
    args = ap.parse_args()

    cfg = reduce_config(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    pipe = LMTokenPipeline(seed=0, batch=8, seq_len=64, vocab=cfg.vocab)

    def loss(p, batch):
        return loss_fn(p, cfg, batch)

    # --- CompressionPlan → LC fit ------------------------------------------
    plan = CompressionPlan.parse(
        f"adaptive:{args.k}",
        lc=LCConfig(mu0=1e-2, mu_growth=1.5, num_lc_iters=5))
    print(f"training + LC-quantizing a tiny LM (plan: {plan.scheme.spec})...")
    tr = LCTrainer.from_plan(loss, plan, params,
                             TrainerConfig(optimizer="adamw", lr=2e-3,
                                           steps_per_l=15))
    st = tr.init(jax.random.PRNGKey(1), params)
    st = tr.run(st, iter(pipe))
    qparams = tr.finalize(st)                      # dense reference

    # --- pack → save/load → verify -----------------------------------------
    packed = plan.pack(st.params, st.lc_state, tr.qspec)
    with tempfile.TemporaryDirectory() as tmp:
        packed.save(tmp)
        packed = PackedModel.load(tmp)
    dec = packed.decode()
    exact = all(bool(jnp.all(a == b)) for a, b in
                zip(jax.tree_util.tree_leaves(qparams),
                    jax.tree_util.tree_leaves(dec)))
    s = packed.summary()
    print(f"PackedModel: {s['bits_per_weight']} bit/weight, "
          f"{s['ref_bytes']} B f32 → {s['packed_bytes']} B packed "
          f"(×{s['ratio']:.1f}, eq. 14); save/load→decode bit-exact: {exact}")
    assert exact, "packed decode must be bit-exact vs lc.finalize"

    # --- serve from the packed artifact (full-model leaf coverage) ---------
    sparams = packed.serving_params(packed=True)   # bit-packed, all leaves
    uparams = packed.serving_params(packed=False)  # uint8 oracle layout
    cov = packed.leaf_coverage()
    n_q = sum(r["quantized"] for r in cov)
    print(f"serving {args.requests} batched requests from the packed "
          f"artifact ({n_q}/{len(cov)} param paths quantized — attention "
          f"q/k/v/o + embedding/LM-head + MLP; kernel backend: "
          f"{dispatch.default_backend()}, {s['bits_per_weight']/8:g} "
          f"B/weight HBM index traffic)...")
    prompts = pipe.next()["tokens"][:args.requests, :args.prompt_len]

    def serve(p):
        logits0, caches = prefill(p, cfg, prompts, last_logits_only=True)

        def grow(leaf):
            if leaf.ndim >= 3 and leaf.shape[2] == args.prompt_len:
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, args.gen_len)
                return jnp.pad(leaf, pad)
            return leaf

        caches = jax.tree_util.tree_map(grow, caches)
        step = jax.jit(lambda c, t, pos: decode_step(p, cfg, c, t, pos))
        tok = jnp.argmax(logits0[:, -1], -1)[:, None].astype(jnp.int32)
        out, logits = [tok], [logits0]
        for t in range(args.gen_len - 1):
            lg, caches = step(caches, tok,
                              jnp.asarray(args.prompt_len + t, jnp.int32))
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
            out.append(tok)
            logits.append(lg)
        return jnp.concatenate(out, 1), jnp.concatenate(logits, 1)

    gen_q, logits_q = serve(sparams)
    gen_u, logits_u = serve(uparams)
    gen_d, logits_d = serve(qparams)
    err = float(jnp.max(jnp.abs(logits_q - logits_d)))
    err_u = float(jnp.max(jnp.abs(logits_q - logits_u)))
    same = bool(jnp.all(gen_q == gen_d))
    print(f"bit-packed-vs-dense serve: max |Δlogits| = {err:.2e} "
          f"(tokens identical: {same}); vs uint8 oracle layout: "
          f"max |Δlogits| = {err_u:.2e}")
    assert err < 1e-2, "packed serving must match dense logits within 1e-2"
    assert err_u < 1e-4, "bit-packed layout must match the uint8 oracle"

    gen = np.asarray(gen_q)
    for r in range(args.requests):
        print(f"  req{r}: prompt={np.asarray(prompts[r])[:8]}... "
              f"generated={gen[r]}")
    print("done.")


if __name__ == "__main__":
    main()
