"""Serve a quantized LM with batched requests through the packed
codebook representation (the memory-roofline payoff of the paper).

    PYTHONPATH=src python examples/serve_quantized.py [--requests 4]

Pipeline: train-tiny → LC-quantize (K=16 ⇒ 4-bit weights) → pack indices
→ batched prefill + decode loop where the MLP matmuls run through the
codebook-matmul kernel path (interpret mode on CPU; Mosaic on TPU).
Prints per-request generated tokens + the serving byte accounting.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import (LCConfig, compression, default_qspec, make_scheme)
from repro.data.pipeline import LMTokenPipeline
from repro.kernels import ops as kops
from repro.models.transformer import (decode_step, init_params, loss_fn,
                                      prefill)
from repro.train.trainer import LCTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--k", type=int, default=16)
    args = ap.parse_args()

    cfg = reduce_config(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    pipe = LMTokenPipeline(seed=0, batch=8, seq_len=64, vocab=cfg.vocab)

    def loss(p, batch):
        return loss_fn(p, cfg, batch)

    print("training + LC-quantizing a tiny LM (K =", args.k, ")...")
    qspec = default_qspec(params)
    tr = LCTrainer(loss, make_scheme(f"adaptive:{args.k}"), qspec,
                   LCConfig(mu0=1e-2, mu_growth=1.5, num_lc_iters=5),
                   TrainerConfig(optimizer="adamw", lr=2e-3, steps_per_l=15))
    st = tr.init(jax.random.PRNGKey(1), params)
    st = tr.run(st, iter(pipe))
    qparams = tr.finalize(st)

    # --- pack one layer and demonstrate the serving kernel -----------------
    w = np.asarray(qparams["stacks"][0]["pos0"]["mlp"]["w_in"][0])
    cb = np.unique(w)
    assign = np.argmin((w[..., None] - cb) ** 2, axis=-1)
    words, lanes = compression.pack_indices(assign, len(cb))
    idx = compression.unpack_indices(jnp.asarray(words), assign.size,
                                     len(cb)).reshape(assign.shape)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, w.shape[0]))
    y_kernel = kops.codebook_matmul(x, idx.astype(jnp.uint8),
                                    jnp.asarray(cb), bm=32, bn=32, bk=32)
    y_dense = x @ w
    err = float(jnp.max(jnp.abs(y_kernel - y_dense)))
    bits = compression.bits_per_index(len(cb))
    print(f"codebook-matmul kernel |Δ| = {err:.2e}; weight bytes "
          f"{w.size * 4}B f32 → {words.size * 4}B packed "
          f"({bits} bit/weight, ×{w.size * 4 / (words.size * 4):.1f} smaller)")

    # --- batched serving loop ----------------------------------------------
    print(f"serving {args.requests} batched requests...")
    prompts = pipe.next()["tokens"][:args.requests, :args.prompt_len]
    capacity = args.prompt_len + args.gen_len
    logits, caches = prefill(qparams, cfg, prompts, last_logits_only=True)

    def grow(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == args.prompt_len:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, args.gen_len)
            return jnp.pad(leaf, pad)
        return leaf

    caches = jax.tree_util.tree_map(grow, caches)
    step = jax.jit(lambda c, t, p: decode_step(qparams, cfg, c, t, p))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    generated = [tok]
    for t in range(args.gen_len - 1):
        logits, caches = step(caches, tok,
                              jnp.asarray(args.prompt_len + t, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        generated.append(tok)
    gen = np.asarray(jnp.concatenate(generated, axis=1))
    for r in range(args.requests):
        print(f"  req{r}: prompt={np.asarray(prompts[r])[:8]}... "
              f"generated={gen[r]}")
    print("done.")


if __name__ == "__main__":
    main()
