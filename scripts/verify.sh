#!/usr/bin/env bash
# Tier-1 verify: the command CI and local dev both run (ROADMAP.md).
#
#   ./scripts/verify.sh [extra pytest args]
#
# Notes on XLA host-device flags (SNIPPETS.md): the distributed tests
# (tests/test_dist.py) spawn subprocesses that set
#   XLA_FLAGS=--xla_force_host_platform_device_count=8
# themselves — the parent process must stay single-device (the dry-run
# isolation rule: jax locks the device count at first init).  Do NOT
# export that flag here; export it only when running a multi-device
# entry point directly, e.g.:
#   XLA_FLAGS=--xla_force_host_platform_device_count=8 \
#       python -m repro.launch.train --reduced --mesh 2x4 --lc
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# XLA's CPU backend splits LLVM codegen across a thread pool; on
# low-core runners that parallel split races and sporadically SIGSEGVs
# inside backend_compile on long many-compilation runs (observed on a
# 1-vCPU box compiling the kmeans scan, different test each run).
# Serializing codegen removes the crash and costs nothing at CI scale.
# Appended so a caller's XLA_FLAGS still apply; the test_dist.py
# subprocesses overwrite XLA_FLAGS themselves (see note above) and are
# single-compile, short-lived processes.
export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_cpu_parallel_codegen_split_count=1"

# Differential matrices first — the serving-layout invariant ({dense,
# uint8, packed} × {forward, prefill, decode} × K × dtype bit-exact;
# tests/test_differential.py + golden artifacts) and the paged-KV
# invariant ({dense KV, quantized KV} × {gqa, mla} × K: quant refs ==
# dense refs on dequantized pools bit-exactly, engine streams == the
# one-shot oracle at kv_bits=0; tests/test_paged_attention.py) — both
# before any engine smoke below, so a KV-cache regression fails the
# build at the kernel oracle, not in an end-to-end stream diff.  Then
# the rest of tier-1.  With extra pytest args, fall back to one plain
# invocation so -k/--lf/-m filters keep applying to everything.
# Mosaic-only tests carry the `tpu` marker and auto-skip on CPU (run
# them on hardware with: pytest -m tpu).
if [ "$#" -gt 0 ]; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
else
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
        tests/test_differential.py tests/test_golden_fixtures.py \
        tests/test_paged_attention.py
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
        --ignore=tests/test_differential.py \
        --ignore=tests/test_golden_fixtures.py \
        --ignore=tests/test_paged_attention.py
fi

# Full-model packed-serving smoke: the mixed attention+MLP+MoE+SSM stack
# served end to end (prefill + decode) from the bit-packed layout, packed
# vs dense logits allclose (bit-exact on the CPU ref backend).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/smoke_serve_packed.py

# Continuous-batching engine smoke: staggered admission + out-of-order
# completion over the packed mixed stack, every greedy stream equal to
# the one-shot loop's (the full matrix lives in tests/test_engine.py).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/smoke_engine.py

# Fault-tolerance smoke (hard gate): a seeded FaultPlan with every fault
# kind — injected decode failure, NaN-poisoned slot, page-pressure
# spike, kill-and-restore, preemption signal — driven through
# supervised_serve; every FINISHED stream must be bit-exact to the
# one-shot oracle and every other request typed.  CHAOS_report.json is
# uploaded next to the audit artifacts by CI.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/smoke_chaos.py \
    CHAOS_report.json

# Static serving-graph audit (hard gate): compile-time proof of the
# eq.-14 invariants over both committed golden fixtures — dense-inflation
# scan of every serve entry's jaxpr (pallas routes traced on CPU, no
# Mosaic), per-leaf HBM bytes/weight == bits_per_index(K)/8 from compiled
# HLO, the engine recompile gate, and VMEM/lane lint of every reachable
# block config.  Non-allowlisted violations exit 1 and fail the build;
# AUDIT_*.json is uploaded next to the bench artifact by CI.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis.audit \
    --packed tests/fixtures/pr2_mlp_only --out AUDIT_pr2.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis.audit \
    --packed tests/fixtures/pr3_full --out AUDIT_pr3.json

# Kernel + engine bench smoke (serve-path byte accounting, engine
# throughput rows, perf trajectory): the same CSV/JSON CI uploads as an
# artifact (BENCH_kernels.{csv,json}).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run \
    --only kernels,engine --json BENCH_kernels.json | tee BENCH_kernels.csv
