"""CI smoke: the continuous-batching engine serves the FULL packed
mixed stack (attention + MLP + MoE + SSM) with staggered admission and
out-of-order completion, and every request's greedy token stream equals
the one-shot lockstep loop's.  Run by scripts/verify.sh.

    PYTHONPATH=src python scripts/smoke_engine.py
"""
import jax
import numpy as np

from repro.core import CompressionPlan
from repro.engine import Engine, Request, greedy_generate
from repro.models.transformer import (LayerKind, ModelConfig, MoESpec,
                                      SSMSpec, StackSpec, init_params)

K = 16
PROMPT, GEN = 16, 6
N_REQ, SLOTS = 5, 2


def main():
    cfg = ModelConfig(
        name="engine-smoke", family="hybrid", d_model=48, n_heads=4,
        n_kv=2, head_dim=12, d_ff=96, vocab=160,
        stacks=(StackSpec(pattern=(LayerKind("gqa", "dense"),
                                   LayerKind("ssm", "none")), groups=2),
                StackSpec(pattern=(LayerKind("gqa", "moe"),), groups=1)),
        tie_embeddings=True,
        moe=MoESpec(n_experts=4, top_k=2, n_shared=1, d_ff_expert=24,
                    capacity_factor=4.0),
        ssm=SSMSpec(d_inner=96, head_p=16, state_n=12, conv_w=4, chunk=8),
        q_chunk=8, kv_chunk=8, remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    plan = CompressionPlan.parse(f"adaptive:{K}")
    qspec = plan.build_qspec(params)
    state = plan.init(jax.random.PRNGKey(1), params, qspec)
    sp = plan.pack(params, state, qspec).serving_params(packed=True)

    prompts = jax.random.randint(jax.random.PRNGKey(2), (N_REQ, PROMPT), 0,
                                 cfg.vocab)
    oracle = np.asarray(greedy_generate(sp, cfg, prompts, GEN)[0])

    gens = [GEN, 2, GEN - 1, 3, GEN]          # out-of-order completion
    reqs = [Request(rid=r, prompt=np.asarray(prompts[r]),
                    max_new_tokens=gens[r]) for r in range(N_REQ)]
    eng = Engine(sp, cfg, n_slots=SLOTS, page_size=8,
                 max_seq=PROMPT + GEN, token_budget=SLOTS + PROMPT)
    outs = eng.run(reqs)
    for r in range(N_REQ):
        np.testing.assert_array_equal(
            outs[r], oracle[r][:gens[r]],
            err_msg=f"request {r}: engine stream != one-shot stream")
    s = eng.stats.summary()
    print(f"engine smoke: {N_REQ} staggered requests over {SLOTS} slots, "
          f"packed K={K} — all greedy streams == one-shot "
          f"({s['generated_tokens']} tokens, {s['steps']} steps, "
          f"occupancy {s['slot_occupancy']:.2f}, page util peak "
          f"{s['page_utilization_max']:.2f}) — OK")


if __name__ == "__main__":
    main()
