"""CI smoke: serve the FULL packed mixed-stack model (attention + MLP +
MoE + SSM layers) end to end — prefill + greedy decode — from the
bit-packed serving layout, and assert packed-vs-dense logits allclose
(bit-exact on the CPU ref backend).  Run by scripts/verify.sh.

    PYTHONPATH=src python scripts/smoke_serve_packed.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionPlan
from repro.engine import greedy_generate
from repro.models.transformer import (LayerKind, ModelConfig, MoESpec,
                                      SSMSpec, StackSpec, init_params)

K = 16
PROMPT, GEN = 16, 4


def main():
    cfg = ModelConfig(
        name="mixed-smoke", family="hybrid", d_model=48, n_heads=4, n_kv=2,
        head_dim=12, d_ff=96, vocab=160,
        stacks=(StackSpec(pattern=(LayerKind("gqa", "dense"),
                                   LayerKind("ssm", "none")), groups=2),
                StackSpec(pattern=(LayerKind("gqa", "moe"),), groups=1)),
        tie_embeddings=True,
        moe=MoESpec(n_experts=4, top_k=2, n_shared=1, d_ff_expert=24,
                    capacity_factor=4.0),
        ssm=SSMSpec(d_inner=96, head_p=16, state_n=12, conv_w=4, chunk=8),
        q_chunk=8, kv_chunk=8, remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    plan = CompressionPlan.parse(f"adaptive:{K}")
    qspec = plan.build_qspec(params)
    state = plan.init(jax.random.PRNGKey(1), params, qspec)
    packed = plan.pack(params, state, qspec)

    sp = packed.serving_params(packed=True)      # full-model bit-packed
    dense = packed.decode()
    cov = packed.leaf_coverage()
    s = packed.summary()
    print(f"smoke-serving mixed stack (gqa+mlp / ssm / gqa+moe): "
          f"{sum(r['quantized'] for r in cov)}/{len(cov)} param paths "
          f"quantized, {s['bits_per_weight']} bit/weight, "
          f"eq.-14 rho={s['ratio']:.1f}")

    toks = jax.random.randint(jax.random.PRNGKey(2), (2, PROMPT), 0,
                              cfg.vocab)

    # the shared one-shot greedy loop (repro.engine.oneshot) — also the
    # continuous-batching engine's differential oracle
    tp, lp = greedy_generate(sp, cfg, toks, GEN, collect_logits=True)
    td, ld = greedy_generate(dense, cfg, toks, GEN, collect_logits=True)
    err = float(jnp.max(jnp.abs(lp - ld)))
    assert np.allclose(np.asarray(lp), np.asarray(ld), rtol=1e-5,
                       atol=1e-5), f"packed vs dense logits differ: {err}"
    np.testing.assert_array_equal(np.asarray(tp), np.asarray(td))
    print(f"packed vs dense (prefill + {GEN}-step decode): "
          f"max |dlogits| = {err:.2e}, identical greedy tokens — OK")


if __name__ == "__main__":
    main()
