"""Regenerate the committed golden artifacts under tests/fixtures/.

    PYTHONPATH=src:tests python scripts/make_golden_fixtures.py

Two tiny PackedModel artifacts pin the two prior serving generations so
future layout changes can't silently break old saved models
(tests/test_golden_fixtures.py):

* ``pr2_mlp_only/``  — a tied GQA+MLP stack packed at K=4, served with
  the PR-2-era MLP-only coverage (``quant_names=MLP_LEGACY``);
* ``pr3_full/``      — the mixed gqa+moe+ssm stack packed at K=16,
  served with full-model coverage (the PR-3 default).

Each directory holds the artifact (``manifest.json`` + ``arrays.npz``)
plus ``golden.npz`` (input tokens + dense-serve forward logits).  The
test asserts load → decode → serve is (a) allclose to the stored golden
logits (drift guard across refactors) and (b) **bit-exact** across the
dense / uint8 / packed serving layouts (the differential invariant).

Only rerun this script when an intentional format change invalidates the
fixtures — and say so in the commit message.
"""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, os.path.join(REPO, "tests"))

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402

from helpers import mixed_cfg, pack_model, tiny_cfg        # noqa: E402
from repro.models.transformer import forward, init_params  # noqa: E402

FIXTURES = os.path.join(REPO, "tests", "fixtures")


def build(name: str, cfg, k: int) -> None:
    out = os.path.join(FIXTURES, name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    packed = pack_model(params, k)
    packed.save(out)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    logits = forward(packed.decode(), cfg, toks)
    np.savez(os.path.join(out, "golden.npz"),
             tokens=np.asarray(toks), logits=np.asarray(logits))
    size = sum(os.path.getsize(os.path.join(out, f))
               for f in os.listdir(out))
    print(f"{name}: k={k} ratio={packed.ratio():.2f} "
          f"({size / 1024:.0f} KiB)")


def main() -> None:
    build("pr2_mlp_only", tiny_cfg(tie=True), k=4)
    build("pr3_full", mixed_cfg(tie=False), k=16)


if __name__ == "__main__":
    main()
