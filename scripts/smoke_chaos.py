"""CI smoke: the fault-tolerance acceptance gate, end to end.

Drives the full packed mixed stack (attention + MLP + MoE + SSM) through
``supervised_serve`` under a seeded :class:`FaultPlan` containing every
fault kind — injected decode failure, NaN-poisoned slot, page-pressure
spike, kill-and-restore, preemption signal — plus one deadline-bound
request, and asserts the ISSUE acceptance criteria:

* the supervisor never raises;
* every ``FINISHED`` stream is bit-exact to the one-shot oracle;
* every other request carries exactly one typed outcome;
* every planned fault actually fired.

Writes ``CHAOS_report.json`` (plan, outcomes, supervisor counters) for
the CI artifact upload.  Run by scripts/verify.sh.

    PYTHONPATH=src python scripts/smoke_chaos.py [out.json]
"""
import json
import sys
import tempfile

import jax
import numpy as np

from repro.core import CompressionPlan
from repro.engine import (Engine, FaultPlan, Outcome, Request,
                          ServeSupervisorConfig, greedy_generate,
                          supervised_serve, truncate_at_eos)
from repro.models.transformer import (LayerKind, ModelConfig, MoESpec,
                                      SSMSpec, StackSpec, init_params)

K = 16
SEED = 23
PROMPT, GEN = 16, 8
N_REQ, SLOTS = 5, 2


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "CHAOS_report.json"
    cfg = ModelConfig(
        name="chaos-smoke", family="hybrid", d_model=48, n_heads=4,
        n_kv=2, head_dim=12, d_ff=96, vocab=160,
        stacks=(StackSpec(pattern=(LayerKind("gqa", "dense"),
                                   LayerKind("ssm", "none")), groups=2),
                StackSpec(pattern=(LayerKind("gqa", "moe"),), groups=1)),
        tie_embeddings=True,
        moe=MoESpec(n_experts=4, top_k=2, n_shared=1, d_ff_expert=24,
                    capacity_factor=4.0),
        ssm=SSMSpec(d_inner=96, head_p=16, state_n=12, conv_w=4, chunk=8),
        q_chunk=8, kv_chunk=8, remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    plan_c = CompressionPlan.parse(f"adaptive:{K}")
    qspec = plan_c.build_qspec(params)
    state = plan_c.init(jax.random.PRNGKey(1), params, qspec)
    sp = plan_c.pack(params, state, qspec).serving_params(packed=True)

    prompts = jax.random.randint(jax.random.PRNGKey(2), (N_REQ, PROMPT),
                                 0, cfg.vocab)
    oracle = np.asarray(greedy_generate(sp, cfg, prompts, GEN)[0])
    reqs = [Request(rid=r, prompt=np.asarray(prompts[r]),
                    max_new_tokens=GEN,
                    deadline_steps=3 if r == N_REQ - 1 else None)
            for r in range(N_REQ)]

    # horizon well inside the workload's fault-free step count (~25+)
    # so every scheduled event lands while requests are still in flight
    fault_plan = FaultPlan.generate(SEED, horizon=18, n_slots=SLOTS)
    assert all(v >= 1 for v in fault_plan.counts().values()), \
        "generated plan must contain every fault kind"

    with tempfile.TemporaryDirectory() as snap_dir:
        sup = ServeSupervisorConfig(snapshot_dir=snap_dir,
                                    snapshot_every=5, max_restarts=6,
                                    max_steps=800)
        outputs, results, report = supervised_serve(
            lambda: Engine(sp, cfg, n_slots=SLOTS, page_size=8,
                           max_seq=PROMPT + GEN, n_pages=5,
                           token_budget=SLOTS + PROMPT),
            reqs, sup, injector=fault_plan)

    # -- acceptance assertions ----------------------------------------------
    assert sorted(results) == list(range(N_REQ)), \
        f"untracked requests: {sorted(results)}"
    n_finished = 0
    for rid, res in sorted(results.items()):
        if res.outcome is Outcome.FINISHED:
            want = truncate_at_eos(oracle[rid][:GEN], None)
            np.testing.assert_array_equal(
                outputs[rid], want,
                err_msg=f"request {rid}: stream != one-shot oracle "
                        f"under faults")
            n_finished += 1
        else:
            assert res.detail, f"request {rid}: untyped {res.outcome}"
    assert n_finished >= 1, "no request survived the chaos schedule"
    assert not report.aborted, "supervisor exhausted its budget"
    assert len(fault_plan._fired) == len(fault_plan.events), \
        f"unfired events: {len(fault_plan.events) - len(fault_plan._fired)}"

    payload = {
        "seed": SEED,
        "plan": fault_plan.to_json(),
        "fault_counts": fault_plan.counts(),
        "supervisor": report.to_json(),
        "outcomes": {str(rid): results[rid].to_json()
                     for rid in sorted(results)},
        "finished": n_finished,
        "oracle_bit_exact": True,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    kinds = ", ".join(f"{k}x{v}" for k, v in
                      sorted(fault_plan.counts().items()))
    print(f"chaos smoke: {len(fault_plan.events)} injected faults "
          f"({kinds}) over {N_REQ} requests — {n_finished} finished "
          f"bit-exact to one-shot, {N_REQ - n_finished} typed "
          f"({report.restarts} restarts, {report.restores} restores, "
          f"{report.snapshots} snapshots) — wrote {out_path} — OK")


if __name__ == "__main__":
    main()
