"""Unit tests for the closed-form quantization operators (Theorems A.1-A.3,
eq. 11) — each checked against brute force."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant_ops as Q


def _rand(n, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (n,))


class TestFixedNoScale:
    def test_binarize_sign_convention(self):
        t = jnp.asarray([-2.0, -0.0, 0.0, 3.0])
        # sgn(0) = +1 per paper eq. 12
        np.testing.assert_array_equal(np.asarray(Q.binarize(t)),
                                      [-1.0, 1.0, 1.0, 1.0])

    def test_ternarize_threshold(self):
        t = jnp.asarray([-0.51, -0.5, -0.49, 0.0, 0.49, 0.5, 0.51])
        np.testing.assert_array_equal(
            np.asarray(Q.ternarize(t)), [-1, -1, 0, 0, 0, 1, 1])

    @pytest.mark.parametrize("c", [0, 2, 4, 7])
    def test_pow2_matches_bruteforce(self, c):
        codebook = np.array(sorted({s * m for m in
                                    [0.0] + [2.0 ** (-i) for i in range(c + 1)]
                                    for s in (-1.0, 1.0)}))
        t = np.asarray(_rand(500, seed=c, scale=2.0))
        q = np.asarray(Q.pow2_quantize(jnp.asarray(t), c))
        brute = codebook[np.argmin((t[:, None] - codebook[None, :]) ** 2, 1)]
        err_q = (t - q) ** 2
        err_b = (t - brute) ** 2
        # optimal distortion (ties may pick different entries, same error)
        np.testing.assert_allclose(err_q, err_b, rtol=1e-5, atol=1e-7)

    def test_pow2_zero(self):
        assert float(Q.pow2_quantize(jnp.asarray(0.0), 4)) == 0.0

    def test_fixed_codebook_tie_break_larger_index(self):
        cb = jnp.asarray([-1.0, 1.0])
        # midpoint 0 → larger index (eq. 11 left-closed intervals)
        assert int(Q.fixed_codebook_assign(jnp.asarray(0.0), cb)) == 1

    def test_fixed_codebook_quantize_optimal(self):
        cb = jnp.sort(_rand(7, seed=3))
        t = _rand(300, seed=4, scale=2.0)
        q = Q.fixed_codebook_quantize(t, cb)
        d = np.asarray(t)[:, None] - np.asarray(cb)[None, :]
        best = np.min(d * d, axis=1)
        np.testing.assert_allclose(np.asarray((t - q) ** 2), best,
                                   rtol=1e-5, atol=1e-6)


class TestScaled:
    def test_binarize_scale_thm_a2(self):
        w = _rand(1000, seed=5)
        q, a = Q.binarize_scale(w)
        assert np.isclose(float(a), float(jnp.mean(jnp.abs(w))))
        # optimal vs grid search over a
        e_opt = float(jnp.sum((w - q) ** 2))
        for ag in np.linspace(0.01, 2.0, 200):
            e = float(jnp.sum((w - ag * jnp.sign(w)) ** 2))
            assert e_opt <= e + 1e-4

    def test_ternarize_scale_thm_a3_vs_grid(self):
        w = _rand(64, seed=6)
        q, a = Q.ternarize_scale(w)
        e_opt = float(jnp.sum((w - q) ** 2))
        best = 1e18
        for ag in np.linspace(1e-3, 3.0, 4000):
            th = np.sign(w) * (np.abs(w) >= ag / 2)
            best = min(best, float(np.sum((np.asarray(w) - ag * th) ** 2)))
        assert e_opt <= best + 1e-5

    def test_ternarize_scale_consistency(self):
        # Thm A.3 proof invariant: |w_(j*)| > a/2 > |w_(j*+1)|
        w = _rand(200, seed=7)
        q, a = Q.ternarize_scale(w)
        nz = np.asarray(jnp.abs(w))[np.asarray(q) != 0]
        z = np.asarray(jnp.abs(w))[np.asarray(q) == 0]
        if nz.size and z.size:
            assert nz.min() >= float(a) / 2 - 1e-7
            assert z.max() <= float(a) / 2 + 1e-7

    def test_fixed_scale_fit_monotone(self):
        w = _rand(500, seed=8, scale=0.3)
        cb = jnp.asarray([-1.0, -0.25, 0.0, 0.25, 1.0])
        q, a, assign = Q.fixed_scale_fit(w, cb, iters=25)
        e = float(jnp.sum((w - q) ** 2))
        # must beat the un-scaled fixed codebook
        q0 = Q.fixed_codebook_quantize(w, cb)
        assert e <= float(jnp.sum((w - q0) ** 2)) + 1e-5
