"""Cross-route differential matrix: the three storage layouts can never
silently diverge again.

One parametrized suite asserts **bit-exactness** on the CPU ref backend
over {dense, uint8 ``_idx``, bit-packed ``_pidx``} × {forward, prefill,
decode} × K ∈ {2, 3, 16, 256} × dtype ∈ {f32, bf16} for a tiny
tied-embedding GQA stack — which exercises every packed serve route
including the two PR-4 kernels' layouts (row-packed embedding: fused
gather + fused transposed LM head).  Logits AND caches are compared, so
a cache-path divergence is caught even when logits agree.

A second block checks the fused Pallas routes (interpret mode) against
the ref backend at the dispatch level, and the hypothesis fuzz drives
ragged shapes / non-pow2 K through all three layouts at once at the
qleaf level (skips when hypothesis is not installed, like
test_schemes_property.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # dev-only dep: fuzzing skips, matrix still runs
    given = None

from helpers import (assert_routes_agree, packed_tiny, serving_layouts,
                     tiny_cfg)
from repro.core import compression as C
from repro.kernels import dispatch
from repro.models import qleaf as Q

K_MATRIX = [2, 3, 16, 256]          # bits ∈ {1, 2, 4, 8}, pow2 and non-pow2
DTYPES = ["float32", "bfloat16"]
MODES = ["forward", "prefill", "decode"]


def _tokens(cfg, seed=2):
    return jax.random.randint(jax.random.PRNGKey(seed), (2, 16), 0,
                              cfg.vocab)


# ---------------------------------------------------------------------------
# The matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("k", K_MATRIX)
def test_layout_matrix_bit_exact(k, dtype, mode):
    cfg, packed = packed_tiny(k, dtype)
    layouts = serving_layouts(packed)
    # the packed layout must actually be the packed layout (and the tied
    # embedding row-packed for the fused gather/transposed-head kernels)
    assert "embed_tok_pidx" in layouts["packed"]
    assert layouts["packed"]["embed_tok_layout"].order == "row"
    assert "embed_tok_idx" in layouts["uint8"]
    assert_routes_agree(cfg, layouts, _tokens(cfg), modes=(mode,))


@pytest.mark.parametrize("k", [3, 16])
def test_matrix_catches_a_poisoned_layout(k):
    """The harness itself must fail when a layout diverges: perturb the
    packed embedding codebook and assert the matrix trips."""
    cfg, packed = packed_tiny(k, "float32")
    layouts = serving_layouts(packed)
    bad = dict(layouts["packed"])
    bad["embed_tok_cb"] = bad["embed_tok_cb"] + 1.0
    layouts["packed"] = bad
    with pytest.raises(AssertionError):
        assert_routes_agree(cfg, layouts, _tokens(cfg), modes=("forward",))


# ---------------------------------------------------------------------------
# Fused Pallas routes (interpret mode) vs the ref backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 16, 256])
def test_fused_routes_match_ref_backend(k):
    """dispatch-level: the interpret-mode Mosaic kernels agree with the
    CPU ref route on the same packed operands — the gather bitwise (pure
    gather), the transposed matmul to f32 tolerance (f32 accumulation)."""
    rng = np.random.RandomState(k)
    v, d, m = 52, 24, 5
    idx = rng.randint(0, k, size=(v, d))
    cb = jnp.asarray(rng.randn(k), jnp.float32)
    pidx_r = jnp.asarray(C.pack_rows(idx, k))
    layout = C.PackedLayout.make(v, d, k, order="row")
    toks = jnp.asarray(rng.randint(0, v, size=(3, 7)), jnp.int32)
    g_ref = dispatch.quantized_gather(toks, pidx_r, cb, layout=layout,
                                      backend="ref")
    g_pal = dispatch.quantized_gather(toks, pidx_r, cb, layout=layout,
                                      backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(g_ref), np.asarray(g_pal))
    np.testing.assert_array_equal(np.asarray(g_ref),
                                  np.asarray(cb)[idx][np.asarray(toks)])

    x = jnp.asarray(rng.randn(m, d), jnp.float32)
    y_ref = dispatch.packed_quantized_matmul_t(x, pidx_r, cb, layout=layout,
                                               backend="ref")
    y_pal = dispatch.packed_quantized_matmul_t(x, pidx_r, cb, layout=layout,
                                               backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=3e-5, atol=3e-4)
    # the kd-order (pack_indices_2d) orientation also feeds the kernel
    pidx_kd = jnp.asarray(C.pack_indices_2d(idx, k))
    lay_kd = C.PackedLayout.make(v, d, k)
    y_kd = dispatch.packed_quantized_matmul_t(x, pidx_kd, cb, layout=lay_kd,
                                              backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_kd),
                               rtol=3e-5, atol=3e-4)


def test_qmatmul_t_ref_route_is_dense_graph_all_layouts():
    """qleaf.qmatmul_t on the CPU ref backend is literally x @ W.T for
    every storage layout — bitwise equal across dense / uint8 / packed
    (both word orders)."""
    rng = np.random.RandomState(5)
    v, d, k = 40, 16, 16
    idx = rng.randint(0, k, size=(v, d))
    cb = jnp.asarray(rng.randn(k), jnp.float32)
    dense = jnp.asarray(np.asarray(cb)[idx])
    x = jnp.asarray(rng.randn(3, d), jnp.float32)
    want = np.asarray(x @ dense.T)
    trees = {
        "dense": {"w": dense},
        "uint8": {"w_idx": jnp.asarray(idx, jnp.uint8), "w_cb": cb},
        "packed-kd": {"w_pidx": jnp.asarray(C.pack_indices_2d(idx, k)),
                      "w_cb": cb, "w_layout": C.PackedLayout.make(v, d, k)},
        "packed-row": {"w_pidx": jnp.asarray(C.pack_rows(idx, k)),
                       "w_cb": cb,
                       "w_layout": C.PackedLayout.make(v, d, k,
                                                       order="row")},
    }
    for name, p in trees.items():
        np.testing.assert_array_equal(
            np.asarray(Q.qmatmul_t(p, "w", x)), want, err_msg=name)


# ---------------------------------------------------------------------------
# Hypothesis fuzz: ragged shapes + non-pow2 K, all three layouts at once
# ---------------------------------------------------------------------------

def _qleaf_trees(idx, cb, k):
    kd, n = idx.shape
    dense = jnp.asarray(np.asarray(cb)[idx])
    return dense, {
        "dense": {"w": dense},
        "uint8": {"w_idx": jnp.asarray(idx, jnp.uint8), "w_cb": cb},
        "packed": {"w_pidx": jnp.asarray(C.pack_indices_2d(idx, k)),
                   "w_cb": cb, "w_layout": C.PackedLayout.make(kd, n, k)},
        "packed-row": {"w_pidx": jnp.asarray(C.pack_rows(idx, k)),
                       "w_cb": cb,
                       "w_layout": C.PackedLayout.make(kd, n, k,
                                                       order="row")},
    }


if given is not None:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 256), st.integers(1, 120), st.integers(1, 64),
           st.integers(1, 5), st.integers(0, 10 ** 6))
    def test_qleaf_layouts_fuzz(k, kd, n, m, seed):
        """qmatmul / qmatmul_t / qembed agree bitwise across every storage
        layout for ragged (kd, n) and arbitrary K ≤ 256 on the ref
        backend (row-packed leaves take the dequant route for qmatmul)."""
        rng = np.random.RandomState(seed)
        idx = rng.randint(0, k, size=(kd, n))
        cb = jnp.asarray(rng.randn(k), jnp.float32)
        dense, trees = _qleaf_trees(idx, cb, k)

        x = jnp.asarray(rng.randn(m, kd), jnp.float32)
        want = np.asarray(x @ dense)
        for name, p in trees.items():
            np.testing.assert_array_equal(
                np.asarray(Q.qmatmul(p, "w", x)), want, err_msg=name)

        xt = jnp.asarray(rng.randn(m, n), jnp.float32)
        want_t = np.asarray(xt @ dense.T)
        for name, p in trees.items():
            np.testing.assert_array_equal(
                np.asarray(Q.qmatmul_t(p, "w", xt)), want_t, err_msg=name)

        toks = jnp.asarray(rng.randint(0, kd, size=(2, 3)), jnp.int32)
        want_e = np.asarray(dense)[np.asarray(toks)]
        # "packed" (kd order) exercises the retained word-column fallback
        for name in ("dense", "uint8", "packed", "packed-row"):
            np.testing.assert_array_equal(
                np.asarray(Q.qembed(trees[name], "w", toks)), want_e,
                err_msg=name)
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_qleaf_layouts_fuzz():
        pass
