"""1-D k-means: exactness of the sorted assignment, monotone descent,
warm-start behaviour (paper fig. 10), grouped vmap."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans as KM


def test_assignment_is_exact_nearest():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (2000,))
    cb = jnp.sort(jax.random.normal(jax.random.PRNGKey(1), (8,)))
    res = KM.kmeans_fit(w, cb, iters=1)
    # after one iteration, assignments are nearest-centroid of the *input*
    from repro.core.quant_ops import fixed_codebook_assign
    a0 = fixed_codebook_assign(w, cb)
    d = (np.asarray(w)[:, None] - np.asarray(cb)[None, :]) ** 2
    np.testing.assert_array_equal(np.asarray(a0), np.argmin(d, axis=1))


def test_distortion_descends():
    key = jax.random.PRNGKey(0)
    w = jnp.concatenate([jax.random.normal(key, (500,)) * 0.2,
                         3 + jax.random.normal(key, (500,)) * 0.2])
    cb = KM.quantile_init(w, 4)
    prev = None
    for iters in [1, 2, 4, 8, 16]:
        res = KM.kmeans_fit(w, cb, iters=iters)
        d = float(res.distortion)
        if prev is not None:
            assert d <= prev + 1e-6
        prev = d


def test_warm_start_converges_fast():
    """Paper fig. 10: after the first C step, k-means needs ~1 iteration."""
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (5000,))
    res1 = KM.kmeans_fit(w, KM.kmeans_plus_plus_init(key, w, 4), iters=50)
    assert int(res1.iters_run) < 50
    # perturb weights slightly (as an L step would) and warm start
    w2 = w + 0.001 * jax.random.normal(jax.random.PRNGKey(3), w.shape)
    res2 = KM.kmeans_fit(w2, res1.codebook, iters=50)
    assert int(res2.iters_run) <= 3


def test_weighted_equals_replicated():
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (100,))
    nw = jnp.asarray(np.random.RandomState(0).randint(1, 4, 100), jnp.float32)
    rep = jnp.repeat(w, np.asarray(nw, int))
    cb0 = KM.quantile_init(rep, 3)
    r_w = KM.kmeans_fit(w, cb0, iters=30, point_weights=nw)
    r_r = KM.kmeans_fit(rep, cb0, iters=30)
    np.testing.assert_allclose(np.asarray(r_w.codebook),
                               np.asarray(r_r.codebook), rtol=1e-5, atol=1e-6)


def test_grouped_vmap():
    key = jax.random.PRNGKey(5)
    w = jax.random.normal(key, (3, 1000))
    cbs = KM.quantile_init_grouped(w, 4)
    assert cbs.shape == (3, 4)
    res = KM.kmeans_fit_grouped(w, cbs, 10)
    assert res.codebook.shape == (3, 4)
    assert res.assignments.shape == (3, 1000)
    # each group's codebook sorted
    assert bool(jnp.all(jnp.diff(res.codebook, axis=1) >= 0))


def test_empty_cluster_keeps_centroid():
    w = jnp.asarray([0.0, 0.1, 0.2])
    cb = jnp.asarray([0.1, 100.0])          # second centroid acquires nothing
    res = KM.kmeans_fit(w, cb, iters=5)
    assert np.asarray(res.codebook)[1] == 100.0
