"""Unit tests for the static HLO analyzer (the roofline's data source):
trip-count multiplication, collective byte conventions, dot FLOPs via the
symbol table — against a hand-written HLO text fixture."""
from repro.launch import hlo_analysis as H

FIXTURE = """
HloModule jit_fn, entry_computation_layout={()->f32[8,8]{1,0}}

%body.1 (param.0: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %param.0 = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte.0 = s32[] get-tuple-element(%param.0), index=0
  %gte.1 = f32[128,256]{1,0} get-tuple-element(%param.0), index=1
  %w = f32[256,256]{1,0} constant(0)
  %dot.1 = f32[128,256]{1,0} dot(%gte.1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar.1 = f32[128,256]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[4,2]<=[8]
  %one = s32[] constant(1)
  %next = s32[] add(%gte.0, %one)
  ROOT %tup = (s32[], f32[128,256]{1,0}) tuple(%next, %ar.1)
}

%cond.1 (param.1: (s32[], f32[128,256])) -> pred[] {
  %param.1 = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte.2 = s32[] get-tuple-element(%param.1), index=0
  %trip = s32[] constant(12)
  ROOT %cmp = pred[] compare(%gte.2, %trip), direction=LT
}

ENTRY %main.1 (arg: f32[128,256]) -> f32[8,8] {
  %arg = f32[128,256]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,256]{1,0}) tuple(%zero, %arg)
  %while.1 = (s32[], f32[128,256]{1,0}) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  %gte.3 = f32[128,256]{1,0} get-tuple-element(%while.1), index=1
  %w2 = f32[256,8]{1,0} constant(0)
  %dot.2 = f32[128,8]{1,0} dot(%gte.3, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag.1 = f32[64,8]{1,0} all-gather(%dot.2), dimensions={0}, channel_id=2
  %rs.1 = f32[8,8]{1,0} reduce-scatter(%ag.1), dimensions={0}, channel_id=3, to_apply=%add.1
  ROOT %out = f32[8,8]{1,0} copy(%rs.1)
}
"""


def test_collective_bytes_with_trip_counts():
    res = H.analyze(FIXTURE)
    bd = res["collective_breakdown"]
    # all-reduce inside the while: 128·256·4 B × 12 trips
    assert bd["all-reduce"] == 128 * 256 * 4 * 12
    # all-gather: output bytes, once
    assert bd["all-gather"] == 64 * 8 * 4
    # reduce-scatter: OPERAND bytes (the all-gather output)
    assert bd["reduce-scatter"] == 64 * 8 * 4


def test_dot_flops_with_symbol_table():
    res = H.analyze(FIXTURE)
    # dot.1: 2·(128·256)·256 per trip × 12; dot.2: 2·(128·8)·256 once
    expected = 2 * 128 * 256 * 256 * 12 + 2 * 128 * 8 * 256
    assert res["dot_flops"] == expected


def test_trip_count_fallback_from_condition():
    # strip backend_config → the parser must recover trip=12 from %cond.1
    text = FIXTURE.replace(
        ', backend_config={"known_trip_count":{"n":"12"}}', "")
    res = H.analyze(text)
    assert res["collective_breakdown"]["all-reduce"] == 128 * 256 * 4 * 12
