"""Unit tests for the static HLO analyzer (the roofline's data source):
trip-count multiplication, collective byte conventions, dot FLOPs via the
symbol table — against a hand-written HLO text fixture."""
from repro.launch import hlo_analysis as H

FIXTURE = """
HloModule jit_fn, entry_computation_layout={()->f32[8,8]{1,0}}

%body.1 (param.0: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %param.0 = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte.0 = s32[] get-tuple-element(%param.0), index=0
  %gte.1 = f32[128,256]{1,0} get-tuple-element(%param.0), index=1
  %w = f32[256,256]{1,0} constant(0)
  %dot.1 = f32[128,256]{1,0} dot(%gte.1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar.1 = f32[128,256]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[4,2]<=[8]
  %one = s32[] constant(1)
  %next = s32[] add(%gte.0, %one)
  ROOT %tup = (s32[], f32[128,256]{1,0}) tuple(%next, %ar.1)
}

%cond.1 (param.1: (s32[], f32[128,256])) -> pred[] {
  %param.1 = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte.2 = s32[] get-tuple-element(%param.1), index=0
  %trip = s32[] constant(12)
  ROOT %cmp = pred[] compare(%gte.2, %trip), direction=LT
}

ENTRY %main.1 (arg: f32[128,256]) -> f32[8,8] {
  %arg = f32[128,256]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,256]{1,0}) tuple(%zero, %arg)
  %while.1 = (s32[], f32[128,256]{1,0}) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  %gte.3 = f32[128,256]{1,0} get-tuple-element(%while.1), index=1
  %w2 = f32[256,8]{1,0} constant(0)
  %dot.2 = f32[128,8]{1,0} dot(%gte.3, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag.1 = f32[64,8]{1,0} all-gather(%dot.2), dimensions={0}, channel_id=2
  %rs.1 = f32[8,8]{1,0} reduce-scatter(%ag.1), dimensions={0}, channel_id=3, to_apply=%add.1
  ROOT %out = f32[8,8]{1,0} copy(%rs.1)
}
"""


def test_collective_bytes_with_trip_counts():
    res = H.analyze(FIXTURE)
    bd = res["collective_breakdown"]
    # all-reduce inside the while: 128·256·4 B × 12 trips
    assert bd["all-reduce"] == 128 * 256 * 4 * 12
    # all-gather: output bytes, once
    assert bd["all-gather"] == 64 * 8 * 4
    # reduce-scatter: OPERAND bytes (the all-gather output)
    assert bd["reduce-scatter"] == 64 * 8 * 4


def test_dot_flops_with_symbol_table():
    res = H.analyze(FIXTURE)
    # dot.1: 2·(128·256)·256 per trip × 12; dot.2: 2·(128·8)·256 once
    expected = 2 * 128 * 256 * 256 * 12 + 2 * 128 * 8 * 256
    assert res["dot_flops"] == expected


def test_trip_count_fallback_from_condition():
    # strip backend_config → the parser must recover trip=12 from %cond.1
    text = FIXTURE.replace(
        ', backend_config={"known_trip_count":{"n":"12"}}', "")
    res = H.analyze(text)
    assert res["collective_breakdown"]["all-reduce"] == 128 * 256 * 4 * 12


# ---------------------------------------------------------------------------
# dtype table: fp8/sub-byte types must count real bytes, unknown types
# must not silently count as 0 (the pre-fix behaviour under-reported HBM)
# ---------------------------------------------------------------------------

def test_fp8_and_subbyte_dtypes_counted():
    # the old regex parsed "f8e4m3fn[...]" as dtype "fn" → 0 bytes
    assert H._shape_bytes("f8e4m3fn", "128,256") == 128 * 256
    assert H._shape_bytes("bf16", "4,4") == 32
    assert H._shape_bytes("u4", "64") == 32
    assert H._shape_bytes("u32", "2,3") == 24
    # the full-token regex must grab the whole dtype
    assert H._SHAPE_RE.findall("f8e4m3fn[12,8]{1,0}") == [("f8e4m3fn",
                                                           "12,8")]


def test_fp8_collective_counts_bytes():
    text = FIXTURE.replace("f32[64,8]{1,0} all-gather",
                           "f8e4m3fn[64,8]{1,0} all-gather")
    res = H.analyze(text)
    assert res["collective_breakdown"]["all-gather"] == 64 * 8  # 1 B/elem


def test_unknown_dtype_warns_in_analyze_raises_on_request():
    import warnings

    import pytest
    text = FIXTURE.replace("f32[64,8]{1,0} all-gather",
                           "q3x[64,8]{1,0} all-gather")
    with warnings.catch_warnings(record=True) as got:
        warnings.simplefilter("always")
        H.analyze(text)
    assert any("q3x" in str(w.message) for w in got)
    with pytest.raises(ValueError, match="q3x"):
        H.analyze(text, on_unknown="raise")
    H.analyze(text, on_unknown="ignore")      # opt-out still available


# ---------------------------------------------------------------------------
# entry_parameters — the HBM audit's data source
# ---------------------------------------------------------------------------

ENTRY_FIXTURE = """
HloModule jit_g, entry_computation_layout={(f32[4,8],u32[2,8],f32[16])->f32[4,8]}

%fused_computation (p.0: f32[4,8], p.1: u32[2,8]) -> f32[4,8] {
  %p.0 = f32[4,8]{1,0} parameter(0)
  %p.1 = u32[2,8]{1,0} parameter(1)
  %c = f32[4,8]{1,0} convert(%p.1)
  ROOT %a = f32[4,8]{1,0} add(%p.0, %c)
}

ENTRY main.10 {
  Arg_0.1 = f32[4,8]{1,0} parameter(0)
  Arg_1.2 = u32[2,8]{1,0} parameter(1)
  Arg_2.3 = f32[16]{0} parameter(2)
  fusion.4 = f32[4,8]{1,0} fusion(Arg_0.1, Arg_1.2), kind=kLoop, calls=%fused_computation
  ROOT add.5 = f32[4,8]{1,0} add(fusion.4, fusion.4)
}
"""


def test_entry_parameters_parses_entry_only():
    params = H.entry_parameters(ENTRY_FIXTURE)
    # the fused computation's parameter(0/1) must NOT appear
    assert [p["index"] for p in params] == [0, 1, 2]
    assert params[0]["dtype"] == "f32" and params[0]["shape"] == (4, 8)
    assert params[0]["bytes"] == 4 * 8 * 4
    assert params[1]["dtype"] == "u32" and params[1]["bytes"] == 2 * 8 * 4
    # uses: Arg_0/Arg_1 feed the fusion; Arg_2 is dead
    assert params[0]["uses"] == 1
    assert params[1]["uses"] == 1
    assert params[2]["uses"] == 0


def test_entry_parameters_unknown_dtype_raises():
    import pytest
    text = ENTRY_FIXTURE.replace("f32[16]{0} parameter(2)",
                                 "q3x[16]{0} parameter(2)")
    with pytest.raises(ValueError, match="q3x"):
        H.entry_parameters(text)
    assert H.entry_parameters(text, on_unknown="ignore")[2]["bytes"] == 0


def test_entry_parameters_on_real_compiled_module():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda a, b: a @ b)
    text = f.lower(jnp.zeros((4, 8)), jnp.zeros((8, 16))).compile().as_text()
    params = H.entry_parameters(text, on_unknown="raise")
    assert [p["index"] for p in params] == [0, 1]
    assert params[0]["shape"] == (4, 8) and params[1]["shape"] == (8, 16)
    assert all(p["uses"] >= 1 for p in params)
