"""LC algorithm behaviour: DC limit, feasibility convergence, KKT
stationarity with accurate path-following, LC ≥ DC on anisotropic losses,
baselines (DC/iDC/BinaryConnect plumbing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LCConfig, baselines, c_step, codebook_entry_count,
                        default_qspec, feasibility_gap, finalize, lc_init,
                        make_scheme, param_counts, penalty_grad)

KEY = jax.random.PRNGKey(0)
TARGET = jax.random.normal(jax.random.PRNGKey(2), (8, 16))


def _params(w=None):
    return {"layer": {"w": TARGET if w is None else w,
                      "b": jnp.zeros((16,))}}


def _quad_loss(p):
    return jnp.mean((p["layer"]["w"] - TARGET) ** 2)


def test_qspec_excludes_biases():
    qspec = default_qspec(_params())
    assert qspec["layer"]["w"].quantize
    assert not qspec["layer"]["b"].quantize


def test_lc_init_is_direct_compression():
    """μ→0⁺ limit: w_C = Δ(Π(w̄)) — the DC point (paper §3.4)."""
    params = _params()
    qspec = default_qspec(params)
    scheme = make_scheme("adaptive:2")
    state = lc_init(KEY, params, scheme, qspec, LCConfig())
    dc, _ = baselines.direct_compression(KEY, params, scheme, qspec)
    np.testing.assert_allclose(np.asarray(state.w_c["layer"]["w"]),
                               np.asarray(dc["layer"]["w"]), atol=1e-6)


@pytest.mark.parametrize("scheme_spec", ["adaptive:2", "adaptive:4",
                                         "binary", "ternary_scale",
                                         "binary_scale", "pow2:4"])
def test_lc_converges_feasible(scheme_spec):
    """Every scheme: gap → 0 and final weights live in the codebook."""
    params = _params()
    qspec = default_qspec(params)
    scheme = make_scheme(scheme_spec)
    cfg = LCConfig(mu0=1e-2, mu_growth=1.5, num_lc_iters=30)
    state = lc_init(KEY, params, scheme, qspec, cfg)

    p = params
    for _ in range(cfg.num_lc_iters):
        lr = min(0.1, 1.0 / float(state.mu))
        for _ in range(60):
            g = jax.grad(_quad_loss)(p)
            pg = penalty_grad(p, state, qspec)
            p = jax.tree_util.tree_map(lambda x, a, b: x - lr * (a + b),
                                       p, g, pg)
        state = c_step(p, state, scheme, qspec, cfg)
    gap = float(feasibility_gap(p, state, qspec))
    assert gap < 5e-2, (scheme_spec, gap)
    final = finalize(p, state, qspec)
    uniq = np.unique(np.asarray(final["layer"]["w"]))
    k_max = {"adaptive:2": 2, "adaptive:4": 4, "binary": 2,
             "binary_scale": 2, "ternary_scale": 3, "pow2:4": 11}[scheme_spec]
    assert len(uniq) <= k_max


def test_lc_reaches_loss_optimal_quantization_anisotropic():
    """With accurate path-following (slow μ, inner alternations) LC finds
    the loss-optimal K=2 codebook of an anisotropic quadratic — beating
    DC — and satisfies the KKT condition (cluster-mean gradient ≈ 0)."""
    n = 128
    t = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (n,)))
    h = np.asarray([50.0] * 8 + [0.1] * 120)
    hj, tj = jnp.asarray(h)[None, :], jnp.asarray(t)[None, :]

    params = {"w": tj}
    qspec = default_qspec(params)
    scheme = make_scheme("adaptive:2")

    def loss(p):
        d = p["w"].ravel() - t
        return jnp.sum(jnp.asarray(h) * d * d) / n

    # loss-optimal = weighted 2-means (split search over sorted t)
    order = np.argsort(t)
    ts, hs = t[order], h[order]
    best = 1e18
    for split in range(1, n):
        c1 = np.sum(hs[:split] * ts[:split]) / np.sum(hs[:split])
        c2 = np.sum(hs[split:] * ts[split:]) / np.sum(hs[split:])
        e = (np.sum(hs[:split] * (ts[:split] - c1) ** 2)
             + np.sum(hs[split:] * (ts[split:] - c2) ** 2))
        best = min(best, e / n)

    cfg = LCConfig(mu0=1e-3, mu_growth=1.1, num_lc_iters=100,
                   inner_alternations=3)
    state = lc_init(KEY, params, scheme, qspec, cfg)
    p = params
    for j in range(cfg.num_lc_iters):
        for inner in range(cfg.inner_alternations):
            mu = state.mu
            w = (2 * hj / n * tj + mu * state.w_c["w"] + state.lam["w"]) \
                / (2 * hj / n + mu)                      # exact L step
            p = {"w": w}
            state = c_step(p, state, scheme, qspec, cfg,
                           advance_mu=inner == cfg.inner_alternations - 1)

    final = finalize(p, state, qspec)
    lc_loss = float(loss(final))
    dc, _ = baselines.direct_compression(KEY, params, scheme, qspec)
    dc_loss = float(loss(dc))
    assert lc_loss <= dc_loss + 1e-6, (lc_loss, dc_loss)
    assert lc_loss <= best * 1.005, (lc_loss, best)

    # KKT: cluster-mean gradient ~ 0
    g = np.asarray(jax.grad(loss)(final)["w"]).ravel()
    fw = np.asarray(final["w"]).ravel()
    for c in np.unique(fw):
        assert abs(g[fw == c].mean()) < 1e-3


def test_idc_round_requantizes():
    params = _params()
    qspec = default_qspec(params)
    scheme = make_scheme("adaptive:2")
    _, state = baselines.direct_compression(KEY, params, scheme, qspec)
    p2 = _params(TARGET + 0.05)
    q2, state2 = baselines.idc_round(p2, state, scheme, qspec)
    assert len(np.unique(np.asarray(q2["layer"]["w"]))) <= 2


def test_binaryconnect_straight_through():
    params = _params()
    qspec = default_qspec(params)
    vg = baselines.make_binaryconnect_grad(
        lambda p, b: _quad_loss(p), qspec)
    loss, g = vg(params, None)
    # loss evaluated at binarized weights
    bparams = baselines.binaryconnect_forward_params(params, qspec)
    assert np.isclose(float(loss), float(_quad_loss(bparams)))
    clipped = baselines.binaryconnect_clip(
        {"layer": {"w": TARGET * 10, "b": jnp.zeros((16,))}}, qspec)
    assert float(jnp.max(jnp.abs(clipped["layer"]["w"]))) <= 1.0


def test_param_counts_and_codebook_entries():
    params = _params()
    qspec = default_qspec(params)
    p1, p0 = param_counts(params, qspec)
    assert p1 == 128 and p0 == 16
    scheme = make_scheme("adaptive:4")
    state = lc_init(KEY, params, scheme, qspec, LCConfig())
    assert codebook_entry_count(state, scheme) == 4


def test_adaptive_zero_scheme_prunes():
    """Paper §4.2 footnote 2: a zero-pinned centroid gives joint
    pruning + quantization; the zero entry survives every C step."""
    key = jax.random.PRNGKey(0)
    w = jnp.concatenate([0.02 * jax.random.normal(key, (800,)),
                         1.0 + 0.1 * jax.random.normal(key, (200,))])
    s = make_scheme("adaptive_zero:4")
    st = s.init(key, w)
    q, st = s.c_step(w, st, first=True)
    cb = np.asarray(st["codebook"])
    assert 0.0 in cb
    assert float(s.sparsity(w, st)) > 0.3
    q2, st2 = s.c_step(q, st)
    assert 0.0 in np.asarray(st2["codebook"])


def test_quadratic_penalty_variant_converges():
    """use_lagrangian=False (λ≡0) is the paper's quadratic-penalty method;
    it must still reach feasibility under the μ schedule."""
    params = _params()
    qspec = default_qspec(params)
    scheme = make_scheme("adaptive:2")
    cfg = LCConfig(mu0=1e-2, mu_growth=1.5, num_lc_iters=30,
                   use_lagrangian=False)
    state = lc_init(KEY, params, scheme, qspec, cfg)
    p = params
    for _ in range(cfg.num_lc_iters):
        lr = min(0.1, 1.0 / float(state.mu))
        for _ in range(60):
            g = jax.grad(_quad_loss)(p)
            pg = penalty_grad(p, state, qspec)
            p = jax.tree_util.tree_map(lambda x, a, b: x - lr * (a + b),
                                       p, g, pg)
        state = c_step(p, state, scheme, qspec, cfg)
    # λ stays exactly zero in QP mode
    assert float(jnp.max(jnp.abs(state.lam["layer"]["w"]))) == 0.0
    assert float(feasibility_gap(p, state, qspec)) < 5e-2
