"""Fault-tolerance acceptance suite: chaos × snapshot × typed outcomes.

THE invariant (ISSUE acceptance gate): under a seeded
:class:`~repro.engine.chaos.FaultPlan` mixing injected decode failures,
NaN-poisoned slots, page-pressure spikes, kill-and-restore round trips,
and preemption signals, ``supervised_serve`` never raises, every
``FINISHED`` stream is **bit-exact** to the one-shot oracle
(``repro.engine.oneshot``), and every other request carries exactly one
typed outcome.  Across {dense, packed K∈{2,16}} serving layouts on the
mixed gqa+moe+ssm stack.

Plus regressions: snapshot→kill→restore mid-stream equality, corrupt
snapshots rejected typed (and survived), NaN quarantine isolating one
slot, preemption-budget livelock breaking, and an oversized submission
never killing the batch.
"""
import functools
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # dev-only dep: fuzz skips, seeded matrix runs
    given = None

from helpers import mixed_cfg, pack_model
from repro.engine import (Engine, FaultEvent, FaultPlan, Outcome, Request,
                          ServeSupervisorConfig, SnapshotError,
                          greedy_generate, restore_into, save_snapshot,
                          supervised_serve, truncate_at_eos)


@functools.lru_cache(maxsize=None)
def _mixed(k, layout: str):
    cfg = mixed_cfg(tie=True)
    from repro.models.transformer import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    if layout == "dense":
        return cfg, params
    return cfg, pack_model(params, k).serving_params(packed=True)


@functools.lru_cache(maxsize=None)
def _prompts(vocab: int, n: int, length: int):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(7 + length), (n, length), 0, vocab))


def _oracle(params, cfg, reqs, block=None):
    out = {}
    by_len = {}
    for r in reqs:
        by_len.setdefault(r.prompt_len, []).append(r)
    for _, group in by_len.items():
        prompts = np.stack([r.prompt for r in group])
        gen = max(r.max_new_tokens for r in group)
        toks = np.asarray(greedy_generate(params, cfg,
                                          jax.numpy.asarray(prompts),
                                          gen, block=block)[0])
        for i, r in enumerate(group):
            out[r.rid] = truncate_at_eos(toks[i][:r.max_new_tokens],
                                         r.eos_id)
    return out


# shared geometry so every test reuses the same compiled decode step
_GEO = dict(n_slots=2, page_size=8, max_seq=48)


def _workload(cfg, n=5, gen=10, deadline_rid=None):
    prompts = _prompts(cfg.vocab, n, 8)
    reqs = []
    for r in range(n):
        reqs.append(Request(
            rid=r, prompt=prompts[r], max_new_tokens=gen + (r % 3),
            deadline_steps=3 if r == deadline_rid else None))
    return reqs


def _check_outcomes(params, cfg, reqs, outputs, results):
    """Every rid typed exactly once; every FINISHED stream == oracle."""
    assert sorted(results) == sorted(r.rid for r in reqs)
    want = _oracle(params, cfg, reqs)
    for rid, res in results.items():
        assert isinstance(res.outcome, Outcome)
        if res.outcome is Outcome.FINISHED:
            np.testing.assert_array_equal(
                outputs[rid], want[rid],
                err_msg=f"request {rid}: stream != one-shot oracle "
                        f"after faults")
            np.testing.assert_array_equal(res.tokens, want[rid])
        else:
            assert rid not in outputs
            assert res.detail, f"untyped failure for request {rid}"


# ---------------------------------------------------------------------------
# the acceptance gate: full fault mix, every layout


@pytest.mark.parametrize("layout,k", [("dense", None), ("packed", 2),
                                      ("packed", 16)])
def test_supervised_serve_full_fault_mix(tmp_path, layout, k):
    cfg, params = _mixed(k, layout)
    # tight pool (6 of 12 default pages) for organic page-pressure
    reqs = _workload(cfg, n=5, gen=10, deadline_rid=3)
    plan = FaultPlan(events=[
        FaultEvent(step=4, kind="poison"),
        FaultEvent(step=6, kind="pressure", pages=3, duration=3),
        FaultEvent(step=9, kind="decode_fail"),
        FaultEvent(step=13, kind="kill_restore"),
        FaultEvent(step=17, kind="preempt"),
    ])
    sup = ServeSupervisorConfig(snapshot_dir=str(tmp_path / "snaps"),
                                snapshot_every=4, max_restarts=4,
                                max_steps=600)
    outputs, results, report = supervised_serve(
        lambda: Engine(params, cfg, n_pages=6, **_GEO),
        reqs, sup, injector=plan)

    _check_outcomes(params, cfg, reqs, outputs, results)
    assert outputs, "chaos run finished nothing — workload too fragile"
    # every event actually fired, and the supervisor saw each fault class
    assert len(plan._fired) == len(plan.events)
    assert report.restarts >= 1          # decode_fail
    assert report.kill_restores == 1
    assert report.preemptions_signalled == 1
    assert report.snapshots >= 1 and report.restores >= 1
    assert not report.aborted
    # the deadline request is typed (expired, or finished if a rewind
    # raced it under the wire — both are valid typed terminals)
    assert results[3].outcome in (Outcome.DEADLINE_EXCEEDED,
                                  Outcome.FINISHED)


def test_generated_plans_seeded_matrix(tmp_path):
    cfg, params = _mixed(16, "packed")
    reqs = _workload(cfg, n=4, gen=8)
    for seed in (0, 1, 2):
        plan = FaultPlan.generate(seed, horizon=24, n_slots=_GEO["n_slots"])
        # a generated plan covers every fault kind at least once
        assert all(v >= 1 for v in plan.counts().values())
        sup = ServeSupervisorConfig(
            snapshot_dir=str(tmp_path / f"s{seed}"), snapshot_every=5,
            max_restarts=6, max_steps=600)
        outputs, results, report = supervised_serve(
            lambda: Engine(params, cfg, n_pages=8, **_GEO),
            reqs, sup, injector=plan)
        _check_outcomes(params, cfg, reqs, outputs, results)
        assert not report.aborted, f"seed {seed} exhausted the supervisor"


if given is not None:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_generated_plans_fuzz(seed):
        cfg, params = _mixed(16, "packed")
        reqs = _workload(cfg, n=3, gen=6)
        import tempfile
        with tempfile.TemporaryDirectory() as td:
            plan = FaultPlan.generate(seed, horizon=20,
                                      n_slots=_GEO["n_slots"])
            sup = ServeSupervisorConfig(snapshot_dir=td, snapshot_every=4,
                                        max_restarts=6, max_steps=500)
            outputs, results, _ = supervised_serve(
                lambda: Engine(params, cfg, n_pages=8, **_GEO),
                reqs, sup, injector=plan)
            _check_outcomes(params, cfg, reqs, outputs, results)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(requirements-dev.txt)")
    def test_generated_plans_fuzz():
        pass


# ---------------------------------------------------------------------------
# snapshot/restore


def test_snapshot_kill_restore_mid_stream_bit_exact(tmp_path):
    cfg, params = _mixed(16, "packed")
    reqs = _workload(cfg, n=4, gen=10)
    want = Engine(params, cfg, n_pages=8, **_GEO).run(list(reqs))

    eng = Engine(params, cfg, n_pages=8, **_GEO)
    for r in reqs:
        eng.submit(r)
    for _ in range(7):                     # mid-stream: decodes in flight
        eng.step()
    assert eng.sched.has_work()
    path = save_snapshot(eng, str(tmp_path))
    assert os.path.exists(os.path.join(path, "manifest.json"))

    # the original engine is dead; a fresh one restores and finishes
    eng2 = Engine(params, cfg, n_pages=8, **_GEO)
    step = restore_into(eng2, str(tmp_path))
    assert step == 7
    while eng2.sched.has_work():
        eng2.step()
    assert sorted(eng2.outputs) == sorted(want)
    for rid in want:
        np.testing.assert_array_equal(
            eng2.outputs[rid], want[rid],
            err_msg=f"request {rid}: restored stream != uninterrupted")
        assert eng2.results[rid].outcome is Outcome.FINISHED
    # allocator fully drained after restore-and-finish
    assert eng2.pool.used_pages == 0 and eng2.pool.seized == 0


def test_snapshot_kill_restore_quantized_pages_bit_exact(tmp_path):
    """Kill-and-restore with codebook-quantized KV pages live: the word
    pools AND the frozen per-page codebooks round-trip through the
    snapshot, so the restored engine's stream is bit-identical to an
    uninterrupted quantized run (freeze-on-first-write makes storage a
    pure function of the written values — nothing to re-fit)."""
    cfg, params = _mixed(16, "packed")
    reqs = _workload(cfg, n=4, gen=10)
    kvq = dict(kv_bits=4, kv_cb_mode="page")
    want = Engine(params, cfg, n_pages=8, **_GEO, **kvq).run(list(reqs))

    eng = Engine(params, cfg, n_pages=8, **_GEO, **kvq)
    for r in reqs:
        eng.submit(r)
    for _ in range(7):                     # quantized pages in flight
        eng.step()
    assert eng.sched.has_work()
    save_snapshot(eng, str(tmp_path))

    eng2 = Engine(params, cfg, n_pages=8, **_GEO, **kvq)
    step = restore_into(eng2, str(tmp_path))
    assert step == 7
    # the restored cache really is the quantized layout (uint32 words)
    kv_leaves = [x for x in jax.tree_util.tree_leaves(eng2.caches)
                 if hasattr(x, "dtype") and x.dtype == np.uint32
                 and x.ndim >= 3]
    assert kv_leaves, "restored engine lost its quantized KV word pools"
    while eng2.sched.has_work():
        eng2.step()
    assert sorted(eng2.outputs) == sorted(want)
    for rid in want:
        np.testing.assert_array_equal(
            eng2.outputs[rid], want[rid],
            err_msg=f"request {rid}: restored kvq stream != uninterrupted")
        assert eng2.results[rid].outcome is Outcome.FINISHED
    assert eng2.pool.used_pages == 0 and eng2.pool.seized == 0


_LONG_GEO = dict(n_slots=2, page_size=8, max_seq=48, prefill_chunk=8,
                 token_budget=10)


def test_snapshot_kill_restore_mid_prefill_bit_exact(tmp_path):
    """Kill-and-restore while a slot is partway through a *blockwise*
    prefill: the snapshot must round-trip partially-written KV pages and
    the per-layer block-carry rows (SSM state, RG-LRU state, window
    ring), and the restored engine must replay the identical block
    partition — streams bit-equal to an uninterrupted run."""
    cfg, params = _mixed(16, "packed")
    prompts = _prompts(cfg.vocab, 3, 40)       # 40 >> prefill_chunk 8
    reqs = [Request(rid=r, prompt=prompts[r], max_new_tokens=6)
            for r in range(3)]
    want = Engine(params, cfg, **_LONG_GEO).run(list(reqs))

    eng = Engine(params, cfg, **_LONG_GEO)
    for r in reqs:
        eng.submit(r)
    mid = False
    while not mid:
        eng.step()
        mid = any(s is not None and not s.prefilled
                  and 0 < s.prefill_progress for s in eng.sched.slots)
    save_snapshot(eng, str(tmp_path))

    eng2 = Engine(params, cfg, **_LONG_GEO)
    restore_into(eng2, str(tmp_path))
    assert any(s is not None and not s.prefilled
               and 0 < s.prefill_progress for s in eng2.sched.slots), \
        "restore lost the mid-prefill slot state"
    while eng2.sched.has_work():
        eng2.step()
    assert sorted(eng2.outputs) == sorted(want)
    for rid in want:
        np.testing.assert_array_equal(
            eng2.outputs[rid], want[rid],
            err_msg=f"request {rid}: mid-prefill restore diverged")
    assert eng2.pool.used_pages == 0 and eng2.pool.seized == 0


def test_prefill_kill_chaos_fires_mid_prefill(tmp_path):
    """The ``prefill_kill`` fault kind waits until some slot is actually
    mid-prefill, then forces the kill/restore round trip — the harness's
    prefill-phase fault point.  Long prompts guarantee the window
    exists; every FINISHED stream still equals the oracle at the
    engine's block partition."""
    cfg, params = _mixed(16, "packed")
    prompts = _prompts(cfg.vocab, 4, 40)
    reqs = [Request(rid=r, prompt=prompts[r], max_new_tokens=6 + r % 2)
            for r in range(4)]
    plan = FaultPlan(events=[
        FaultEvent(step=1, kind="prefill_kill"),
        FaultEvent(step=8, kind="prefill_kill"),
    ])
    sup = ServeSupervisorConfig(snapshot_dir=str(tmp_path),
                                snapshot_every=4, max_restarts=4,
                                max_steps=600)
    outputs, results, report = supervised_serve(
        lambda: Engine(params, cfg, **_LONG_GEO), reqs, sup,
        injector=plan)
    assert len(plan._fired) == len(plan.events)
    assert report.kill_restores == 2
    assert sorted(results) == [r.rid for r in reqs]
    want = _oracle(params, cfg, reqs, block=8)
    for rid, res in results.items():
        if res.outcome is Outcome.FINISHED:
            np.testing.assert_array_equal(
                outputs[rid], want[rid],
                err_msg=f"request {rid}: stream != oracle after "
                        f"prefill_kill")
    assert len(outputs) == len(reqs), "prefill_kill lost requests"


def test_snapshot_corruption_rejected_and_survived(tmp_path):
    cfg, params = _mixed(16, "packed")
    reqs = _workload(cfg, n=2, gen=6)
    eng = Engine(params, cfg, n_pages=8, **_GEO)
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    save_snapshot(eng, str(tmp_path))

    # geometry mismatch is typed too, not a numpy shape crash (checked
    # against the still-intact snapshot — integrity is verified first)
    small = Engine(params, cfg, n_slots=2, page_size=8, max_seq=32,
                   n_pages=8)
    with pytest.raises(SnapshotError, match="geometry"):
        restore_into(small, str(tmp_path))

    npz = os.path.join(str(tmp_path), "snap_00000004", "arrays.npz")
    blob = bytearray(open(npz, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(npz, "wb").write(bytes(blob))

    fresh = Engine(params, cfg, n_pages=8, **_GEO)
    with pytest.raises(SnapshotError, match="integrity|corrupt"):
        restore_into(fresh, str(tmp_path))

    # the supervisor treats the corrupt snapshot as absent: a failure
    # mid-run falls back to a fresh deterministic replay, never raises
    plan = FaultPlan(events=[FaultEvent(step=5, kind="decode_fail")])
    sup = ServeSupervisorConfig(snapshot_dir=str(tmp_path),
                                snapshot_every=0,   # no new snapshots
                                max_restarts=2, max_steps=400)
    outputs, results, report = supervised_serve(
        lambda: Engine(params, cfg, n_pages=8, **_GEO), reqs, sup,
        injector=plan)
    _check_outcomes(params, cfg, reqs, outputs, results)
    assert report.restarts == 1 and report.restores == 0
    assert report.fresh_starts == 2
    assert len(outputs) == len(reqs)


def test_supervisor_restart_budget_returns_typed(tmp_path):
    cfg, params = _mixed(16, "packed")
    reqs = _workload(cfg, n=2, gen=6)
    # more injected failures than the budget allows — must return typed
    # results (completed + FAILED stragglers), never raise
    plan = FaultPlan(events=[
        FaultEvent(step=s, kind="decode_fail") for s in (2, 3, 4, 5)])
    sup = ServeSupervisorConfig(snapshot_dir=str(tmp_path),
                                snapshot_every=0, max_restarts=2,
                                max_steps=400)
    outputs, results, report = supervised_serve(
        lambda: Engine(params, cfg, n_pages=8, **_GEO), reqs, sup,
        injector=plan)
    assert report.aborted and report.restarts == 3
    assert sorted(results) == [r.rid for r in reqs]
    for res in results.values():
        if res.outcome is Outcome.FAILED:
            assert "restart budget" in res.detail


# ---------------------------------------------------------------------------
# isolation regressions


def test_nan_quarantine_isolates_one_slot():
    cfg, params = _mixed(16, "packed")
    reqs = _workload(cfg, n=3, gen=8)
    eng = Engine(params, cfg, n_pages=8, **_GEO)
    for r in reqs:
        eng.submit(r)
    # let prefills commit, then poison whichever slot serves rid 0
    while eng.sched.slot_of(0) is None or not eng.sched.running_ids():
        eng.step()
    eng.poison_slot(eng.sched.slot_of(0))
    while eng.sched.has_work():
        eng.step()
    res = eng.results[0]
    assert res.outcome is Outcome.FAILED
    assert "non-finite" in res.detail
    assert eng.stats.quarantined == 1
    # neighbors were decoding in the same fused call that step — their
    # streams must still equal the oracle exactly
    want = _oracle(params, cfg, reqs)
    for rid in (1, 2):
        assert eng.results[rid].outcome is Outcome.FINISHED
        np.testing.assert_array_equal(eng.outputs[rid], want[rid])
    assert eng.pool.used_pages == 0


def test_preemption_budget_breaks_livelock():
    cfg, params = _mixed(16, "packed")
    prompts = _prompts(cfg.vocab, 2, 8)
    # two giants on a pool that can't hold both full streams: with a
    # zero budget the first preemption fails typed instead of
    # ping-ponging until max_steps
    reqs = [Request(rid=r, prompt=prompts[r], max_new_tokens=30)
            for r in range(2)]
    eng = Engine(params, cfg, n_slots=2, page_size=8, max_seq=48,
                 n_pages=5, max_preemptions=0)
    outs = eng.run(list(reqs), max_steps=300)
    assert eng.stats.preemptions >= 1
    outcomes = {rid: eng.results[rid].outcome for rid in (0, 1)}
    assert Outcome.FINISHED in outcomes.values()
    assert Outcome.FAILED in outcomes.values()
    failed = next(r for r, o in outcomes.items() if o is Outcome.FAILED)
    assert "preemption budget" in eng.results[failed].detail
    want = _oracle(params, cfg, reqs)
    for rid, o in outcomes.items():
        if o is Outcome.FINISHED:
            np.testing.assert_array_equal(outs[rid], want[rid])
    assert eng.pool.used_pages == 0


def test_oversized_submission_never_kills_the_batch():
    cfg, params = _mixed(16, "packed")
    reqs = _workload(cfg, n=2, gen=8)
    eng = Engine(params, cfg, n_pages=8, **_GEO)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):                     # neighbors mid-flight
        eng.step()
    big = Request(rid=99, prompt=_prompts(cfg.vocab, 1, 8)[0],
                  max_new_tokens=1000)
    assert eng.submit(big) is Outcome.REJECTED_TOO_LARGE
    while eng.sched.has_work():
        eng.step()
    want = _oracle(params, cfg, reqs)
    for r in reqs:
        assert eng.results[r.rid].outcome is Outcome.FINISHED
        np.testing.assert_array_equal(eng.outputs[r.rid], want[r.rid])
    assert eng.results[99].outcome is Outcome.REJECTED_TOO_LARGE


def test_pressure_spike_stalls_without_burning_budget():
    cfg, params = _mixed(16, "packed")
    reqs = _workload(cfg, n=2, gen=8)
    eng = Engine(params, cfg, n_pages=6, **_GEO)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    taken = eng.pool.seize(eng.pool.free_pages)   # total pressure
    for _ in range(6):                 # starved steps: wait, not preempt
        eng.step()
    assert eng.stats.preemptions == 0
    eng.pool.release()
    assert eng.pool.seized == 0
    while eng.sched.has_work():
        eng.step()
    want = _oracle(params, cfg, reqs)
    for r in reqs:
        assert eng.results[r.rid].outcome is Outcome.FINISHED
        np.testing.assert_array_equal(eng.outputs[r.rid], want[r.rid])
    assert taken >= 1
