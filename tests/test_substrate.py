"""Substrate: data determinism, schedules, checkpoint atomicity/integrity,
fault-injection recovery, trainer integration."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.data.pipeline import LMTokenPipeline, prefetch
from repro.models.paper_nets import (cross_entropy, init_mlp_classifier,
                                     mlp_logits)
from repro.optim import schedules
from repro.train import checkpoint as ckpt
from repro.train.fault import (FailureInjector, PreemptionSignal,
                               SimulatedNodeFailure, SupervisorConfig,
                               supervised_run)
from repro.train.trainer import (TrainerConfig, init_train_state,
                                 make_train_step)


def test_lm_batch_deterministic():
    b1 = synthetic.lm_batch(7, 3, 4, 32, 1000)
    b2 = synthetic.lm_batch(7, 3, 4, 32, 1000)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic.lm_batch(7, 4, 4, 32, 1000)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))


def test_lm_structure_learnable():
    """Markov structure: bigram model beats unigram entropy."""
    b = synthetic.lm_batch(0, 0, 16, 256, 64)
    toks = np.asarray(b["tokens"]).ravel()
    # transition counts
    joint = np.ones((64, 64))
    for a, c in zip(toks[:-1], toks[1:]):
        joint[a, c] += 1
    cond = joint / joint.sum(1, keepdims=True)
    marg = joint.sum(0) / joint.sum()
    h_cond = -np.mean(np.log([cond[a, c] for a, c in zip(toks[:-1], toks[1:])]))
    h_marg = -np.mean(np.log([marg[c] for c in toks[1:]]))
    assert h_cond < h_marg - 0.2


def test_pipeline_cursor_resume():
    p1 = LMTokenPipeline(seed=1, batch=2, seq_len=16, vocab=100)
    batches = [p1.next() for _ in range(5)]
    p2 = LMTokenPipeline(seed=1, batch=2, seq_len=16, vocab=100,
                         start_step=3)
    np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                  np.asarray(p2.next()["tokens"]))


def test_prefetch_order():
    it = prefetch(iter(range(20)), depth=3)
    assert list(it) == list(range(20))


def test_superres_weight_distribution_clustered():
    """§5.2 setup: optimal W has a dominant cluster at 0 + positive
    clusters (the paper's non-Gaussian fig. 7 distribution)."""
    x, y = synthetic.superres_data(0, n=400, hi_side=12, factor=2)
    w, *_ = np.linalg.lstsq(np.asarray(x), np.asarray(y), rcond=None)
    w = w.ravel()
    near_zero = np.mean(np.abs(w) < 0.05)
    assert near_zero > 0.35         # large cluster at zero
    assert np.max(w) > 0.15         # plus real positive weights


def test_schedules():
    s = schedules.exponential(0.1, 0.5, 10)
    assert float(s(0)) == pytest.approx(0.1)
    assert float(s(10)) == pytest.approx(0.05)
    clipped = schedules.lc_clip(schedules.constant(1.0))
    assert float(clipped(0, 100.0)) == pytest.approx(0.01)
    assert float(clipped(0, 0.1)) == pytest.approx(1.0)
    w = schedules.wsd(1.0, 100)
    assert float(w(50)) == pytest.approx(1.0)
    assert float(w(99)) < 0.6


def test_checkpoint_roundtrip_and_integrity(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    ckpt.save_checkpoint(str(tmp_path), 5, tree, extra={"note": 1})
    out, extra, step = ckpt.restore_checkpoint(str(tmp_path), like=tree)
    assert step == 5 and extra["note"] == 1
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10.0))
    # corrupt → integrity error
    path = os.path.join(str(tmp_path), "step_00000005", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError):
        ckpt.restore_checkpoint(str(tmp_path), like=tree)


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"x": jnp.zeros(4)}
    for s in [1, 2, 3, 4, 5]:
        ckpt.save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(d for d in os.listdir(str(tmp_path))
                   if d.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]
    assert ckpt.latest_step(str(tmp_path)) == 5


def _mini_problem():
    X, Y = synthetic.mnist_like(0, 512)
    params = init_mlp_classifier(jax.random.PRNGKey(0), [784, 16, 10])

    def loss_fn(p, batch):
        return cross_entropy(mlp_logits(p, batch[0]), batch[1])

    def make_batches(start):
        def gen():
            i = start
            while True:
                k = jax.random.fold_in(jax.random.PRNGKey(9), i)
                idx = jax.random.randint(k, (64,), 0, X.shape[0])
                yield (X[idx], Y[idx])
                i += 1
        return gen()

    return params, loss_fn, make_batches


def test_supervised_run_recovers_from_failures(tmp_path):
    params, loss_fn, make_batches = _mini_problem()
    tc = TrainerConfig(lr=0.05, steps_per_l=10)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(loss_fn, tc))
    inj = FailureInjector(fail_at_steps={17, 42})
    cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=10,
                           max_restarts=4)
    out = supervised_run(state=state, make_batches=make_batches,
                         step_fn=step, num_steps=60, cfg=cfg, injector=inj)
    assert int(out.step) == 60
    # deterministic data cursor ⇒ same result as a failure-free run
    state2 = init_train_state(params, tc)
    it = make_batches(0)
    for _ in range(60):
        state2, _ = step(state2, next(it))
    np.testing.assert_allclose(
        np.asarray(out.params["fc0"]["w"]),
        np.asarray(state2.params["fc0"]["w"]), rtol=2e-4, atol=2e-5)


def test_supervised_run_exhausts_restarts(tmp_path):
    params, loss_fn, make_batches = _mini_problem()
    tc = TrainerConfig(lr=0.05)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(loss_fn, tc))
    inj = FailureInjector(fail_at_steps=set(range(100)))
    inj._fired = set()      # refire every restart

    class AlwaysFail(FailureInjector):
        def check(self, step):
            raise SimulatedNodeFailure("flaky")

    cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=10,
                           max_restarts=2)
    with pytest.raises(SimulatedNodeFailure):
        supervised_run(state=state, make_batches=make_batches, step_fn=step,
                       num_steps=50, cfg=cfg, injector=AlwaysFail())


def test_preemption_saves_checkpoint(tmp_path):
    params, loss_fn, make_batches = _mini_problem()
    tc = TrainerConfig(lr=0.05)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(loss_fn, tc))
    inj = FailureInjector(preempt_at=7)
    cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                           max_restarts=0)
    with pytest.raises(PreemptionSignal):
        supervised_run(state=state, make_batches=make_batches, step_fn=step,
                       num_steps=50, cfg=cfg, injector=inj)
    assert ckpt.latest_step(str(tmp_path)) == 7
