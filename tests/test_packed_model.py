"""CompressionPlan → PackedModel pipeline: non-power-of-two bit-packing,
save/load → decode bit-exactness, serving layout, and the scheme-registry
string shim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CompressionPlan, LCConfig, PackedModel, compression,
                        make_scheme, schemes)


# ---------------------------------------------------------------------------
# pack_indices / unpack_indices at non-power-of-two K
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 5, 17])
@pytest.mark.parametrize("n", [1, 7, 64, 1000])
def test_pack_unpack_roundtrip_non_pow2(k, n):
    rng = np.random.RandomState(k * 1000 + n)
    assign = rng.randint(0, k, size=n)
    words, lanes = compression.pack_indices(assign, k)
    bits = compression.bits_per_index(k)
    assert lanes == 32 // bits
    assert words.dtype == np.uint32
    assert words.size == -(-n // lanes)          # ceil-div: no straddling
    out = np.asarray(compression.unpack_indices(jnp.asarray(words), n, k))
    np.testing.assert_array_equal(out, assign)


@pytest.mark.parametrize("k", [3, 5, 17])
def test_pack_unpack_roundtrip_2d_shapes(k):
    rng = np.random.RandomState(k)
    assign = rng.randint(0, k, size=(13, 9))
    words, _ = compression.pack_indices(assign, k)
    out = np.asarray(compression.unpack_indices(jnp.asarray(words),
                                                assign.size, k))
    np.testing.assert_array_equal(out.reshape(assign.shape), assign)


# ---------------------------------------------------------------------------
# PackedModel: pack → save/load → decode bit-exactness
# ---------------------------------------------------------------------------

def _toy_params(key):
    """Mixed tree: 2-D leaves, a grouped [G, ...] stack, and excluded
    (bias/norm) leaves — the structures default_qspec distinguishes."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "fc": {"w": jax.random.normal(k1, (12, 8)),
               "b_bias": jnp.zeros((8,))},
        "stack": ({"w_in": jax.random.normal(k2, (3, 8, 16)),
                   "norm_scale": jnp.zeros((3, 8))},),
        "head_w": jax.random.normal(k3, (8, 6)),
    }


@pytest.mark.parametrize("spec,k", [("adaptive:5", 5), ("ternary", 3),
                                    ("ternary_scale", 3)])
def test_packed_model_save_load_decode_bit_exact(tmp_path, spec, k):
    params = _toy_params(jax.random.PRNGKey(0))
    plan = CompressionPlan.parse(spec, lc=LCConfig(num_lc_iters=2))
    qspec = plan.build_qspec(params)
    state = plan.init(jax.random.PRNGKey(1), params, qspec)
    state = plan.c_step(params, state, qspec)
    dense = plan.finalize(params, state, qspec)

    packed = plan.pack(params, state, qspec)
    assert packed.k == k
    packed.save(str(tmp_path))
    loaded = PackedModel.load(str(tmp_path))
    assert loaded.scheme_spec == plan.scheme.spec

    decoded = loaded.decode()
    assert (jax.tree_util.tree_structure(decoded)
            == jax.tree_util.tree_structure(dense))
    for a, b in zip(jax.tree_util.tree_leaves(dense),
                    jax.tree_util.tree_leaves(decoded)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # eq. 14 accounting carried through the round trip
    s = loaded.summary()
    assert s["p1"] == 12 * 8 + 3 * 8 * 16 + 8 * 6
    assert s["p0"] == 8 + 3 * 8
    assert s["ratio"] > 1.0


def test_serving_params_layout_and_equivalence():
    params = _toy_params(jax.random.PRNGKey(2))
    plan = CompressionPlan.parse("adaptive:4")
    qspec = plan.build_qspec(params)
    state = plan.init(jax.random.PRNGKey(3), params, qspec)
    packed = plan.pack(params, state, qspec)

    sp = packed.serving_params(quant_names=("w_in",))
    layer = sp["stack"][0]
    assert "w_in_idx" in layer and "w_in_cb" in layer and "w_in" not in layer
    assert layer["w_in_idx"].dtype == jnp.uint8
    assert layer["w_in_cb"].shape == (3, 4)      # grouped: per-layer codebook

    from repro.kernels import dispatch
    dense = plan.finalize(params, state, qspec)
    dp = dispatch.decode_params(sp)
    for a, b in zip(jax.tree_util.tree_leaves(dense),
                    jax.tree_util.tree_leaves(dp)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=0)


# ---------------------------------------------------------------------------
# Scheme registry + string shim
# ---------------------------------------------------------------------------

def test_make_scheme_string_shim_still_resolves():
    assert make_scheme("adaptive:4").k == 4
    assert make_scheme("adaptive_zero:8").k == 8
    assert make_scheme("pow2:3").pow2_c == 3
    assert make_scheme("binary").kind == "binary"
    assert make_scheme("ternary_scale").kind == "ternary_scale"
    assert make_scheme("adaptive").k == 4        # default preserved


def test_registry_validation_errors():
    with pytest.raises(ValueError, match="registered"):
        make_scheme("no_such_scheme")
    with pytest.raises(ValueError, match="not an int"):
        make_scheme("adaptive:four")
    with pytest.raises(ValueError, match="≥"):
        make_scheme("adaptive:1")
    with pytest.raises(ValueError, match="no arg"):
        make_scheme("binary:2")


def test_register_scheme_decorator_extends_registry():
    name = "unit_test_scheme"
    assert name not in schemes.registered_schemes()

    @schemes.register_scheme(name)
    def factory(arg=None, **kw):
        return schemes.FixedScheme(kind="binary")

    try:
        assert name in schemes.registered_schemes()
        assert make_scheme(name).kind == "binary"
        with pytest.raises(ValueError, match="twice"):
            schemes.register_scheme(name)(factory)
    finally:
        schemes._REGISTRY.pop(name, None)


# ---------------------------------------------------------------------------
# artifact integrity: v2 manifest verification + typed ArtifactError
# ---------------------------------------------------------------------------

def _packed_artifact(tmp_path):
    params = _toy_params(jax.random.PRNGKey(4))
    plan = CompressionPlan.parse("adaptive:4")
    qspec = plan.build_qspec(params)
    state = plan.init(jax.random.PRNGKey(5), params, qspec)
    packed = plan.pack(params, state, qspec)
    packed.save(str(tmp_path))
    return packed


def test_artifact_manifest_v2_integrity_records(tmp_path):
    import json
    import os
    _packed_artifact(tmp_path)
    with open(os.path.join(str(tmp_path), "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 2
    data = np.load(os.path.join(str(tmp_path), "arrays.npz"))
    # one integrity record per npz key, with totals
    assert sorted(man["arrays"]) == sorted(data.files)
    assert man["n_arrays"] == len(data.files)
    assert man["total_elements"] == sum(int(data[k].size)
                                        for k in data.files)
    for key, rec in man["arrays"].items():
        assert len(rec["sha256"]) == 64
        assert rec["dtype"] == str(data[key].dtype)
        assert rec["shape"] == list(data[key].shape)
    # clean round trip still verifies
    PackedModel.load(str(tmp_path))


def test_artifact_corruption_names_bad_leaf(tmp_path):
    import json
    import os
    from repro.core import ArtifactError
    _packed_artifact(tmp_path)
    with open(os.path.join(str(tmp_path), "manifest.json")) as f:
        man = json.load(f)
    # flip one element of one array and re-zip: sha mismatch must name
    # the leaf that owns the corrupted key
    data = dict(np.load(os.path.join(str(tmp_path), "arrays.npz")))
    key = sorted(k for k in data if k.startswith("p"))[0]
    arr = data[key].copy()
    arr.view(np.uint8).flat[0] ^= 1    # single flipped bit, any dtype
    data[key] = arr
    np.savez(os.path.join(str(tmp_path), "arrays.npz"), **data)
    owner = man["packed"][0]["path"]
    with pytest.raises(ArtifactError, match="integrity"):
        PackedModel.load(str(tmp_path))
    with pytest.raises(ArtifactError, match=key):
        PackedModel.load(str(tmp_path))
    try:
        PackedModel.load(str(tmp_path))
    except ArtifactError as e:
        assert owner in str(e)


def test_artifact_truncation_and_missing_pieces(tmp_path):
    import os
    from repro.core import ArtifactError
    _packed_artifact(tmp_path)
    # drop an array: typed error naming the missing key
    data = dict(np.load(os.path.join(str(tmp_path), "arrays.npz")))
    dropped = sorted(data)[0]
    data.pop(dropped)
    np.savez(os.path.join(str(tmp_path), "arrays.npz"), **data)
    with pytest.raises(ArtifactError, match="truncated|missing|holds"):
        PackedModel.load(str(tmp_path))
    # unreadable zip
    with open(os.path.join(str(tmp_path), "arrays.npz"), "wb") as f:
        f.write(b"not a zip")
    with pytest.raises(ArtifactError, match="unreadable"):
        PackedModel.load(str(tmp_path))
    # absent files
    os.remove(os.path.join(str(tmp_path), "arrays.npz"))
    with pytest.raises(ArtifactError, match="arrays"):
        PackedModel.load(str(tmp_path))
    os.remove(os.path.join(str(tmp_path), "manifest.json"))
    with pytest.raises(ArtifactError, match="manifest"):
        PackedModel.load(str(tmp_path))


def test_artifact_v1_loads_with_warning(tmp_path):
    import json
    import os
    pm = _packed_artifact(tmp_path)
    man_path = os.path.join(str(tmp_path), "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    # rewrite as a pre-integrity version-1 manifest (the committed
    # golden fixtures have this shape)
    man["version"] = 1
    for k in ("arrays", "n_arrays", "total_elements"):
        man.pop(k)
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.warns(UserWarning, match="version-1"):
        loaded = PackedModel.load(str(tmp_path))
    for path, leaf in pm.packed.items():
        np.testing.assert_array_equal(loaded.packed[path].words, leaf.words)
    # a manifest newer than this reader is refused outright
    man["version"] = 3
    with open(man_path, "w") as f:
        json.dump(man, f)
    from repro.core import ArtifactError
    with pytest.raises(ArtifactError, match="newer"):
        PackedModel.load(str(tmp_path))
