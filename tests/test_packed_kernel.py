"""Bit-packed serve-path correctness: pack_indices_2d / pack_rows →
in-kernel unpack is bit-exact, and the three packed kernels
(packed_codebook_matmul, packed_codebook_matmul_t, quantized_gather —
interpret mode) match the dense-gather oracle for bits ∈ {1, 2, 4, 8},
non-pow2 K, and ragged M/Kd/N tails.  Deterministic sweeps always run;
hypothesis fuzzing skips when hypothesis is not installed (``pip install
-r requirements-dev.txt``), like test_schemes_property.py.  Tests marked
``tpu`` compile the same kernels with Mosaic and only run on a real TPU
backend."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # dev-only dep: fuzzing skips, sweeps still run
    given = None

from repro.core import compression as C
from repro.kernels import dispatch, ops, ref

# K values spanning bits ∈ {1, 2, 3, 4, 8}, pow2 and non-pow2.
K_SWEEP = [2, 3, 4, 5, 16, 200, 256]


def _rand_case(k, kd, n, seed=0):
    rng = np.random.RandomState(seed)
    idx = rng.randint(0, k, size=(kd, n))
    pidx = jnp.asarray(C.pack_indices_2d(idx, k))
    cb = jnp.asarray(rng.randn(k), jnp.float32)
    return idx, pidx, cb


@pytest.mark.parametrize("k", K_SWEEP)
@pytest.mark.parametrize("kd,n", [(32, 16), (300, 77), (1024, 128)])
def test_pack2d_unpack2d_roundtrip(k, kd, n):
    idx, pidx, _ = _rand_case(k, kd, n, seed=kd + k)
    out = np.asarray(C.unpack_indices_2d(pidx, kd, k))
    np.testing.assert_array_equal(out, idx)


@pytest.mark.parametrize("k", K_SWEEP)
def test_in_kernel_unpack_bit_exact(k):
    """pack → in-kernel unpack is bit-exact vs unpack_indices_2d: with
    x = I and cb = [0..K), the kernel output IS the unpacked index tile
    (small ints are exact in f32)."""
    kd, n = 96, 40
    idx, pidx, _ = _rand_case(k, kd, n, seed=k)
    cb = jnp.arange(k, dtype=jnp.float32)
    bits = C.bits_per_index(k)
    lanes = 32 // bits
    y = ops.packed_codebook_matmul(jnp.eye(kd, dtype=jnp.float32), pidx, cb,
                                   bm=32, bn=32, bk=4 * lanes)
    np.testing.assert_array_equal(np.asarray(y).astype(np.int64), idx)
    up = np.asarray(C.unpack_indices_2d(pidx, kd, k))
    np.testing.assert_array_equal(up, idx)


@pytest.mark.parametrize("k", K_SWEEP)
@pytest.mark.parametrize("m,kd,n", [(8, 32, 16), (100, 300, 77),
                                    (1, 2048, 1), (33, 130, 257)])
def test_packed_matmul_matches_ref(m, kd, n, k):
    """interpret-mode packed kernel == ref.codebook_matmul_ref ∘ unpack
    to fp32 tolerance, including ragged M/Kd/N tails."""
    idx, pidx, cb = _rand_case(k, kd, n, seed=m + kd + n + k)
    x = jnp.asarray(np.random.RandomState(m + n).randn(m, kd), jnp.float32)
    bits = C.bits_per_index(k)
    lanes = 32 // bits
    y1 = ops.packed_codebook_matmul(x, pidx, cb, bm=32, bn=64, bk=4 * lanes)
    y2 = ref.codebook_matmul_ref(x, jnp.asarray(idx), cb)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-5, atol=3e-4)


@pytest.mark.parametrize("dequant", ["lut", "onehot"])
def test_dequant_strategies_agree(dequant):
    idx, pidx, cb = _rand_case(16, 256, 64, seed=7)
    x = jnp.asarray(np.random.RandomState(9).randn(16, 256), jnp.float32)
    y = ops.packed_codebook_matmul(x, pidx, cb, bm=16, bn=64, bk=64,
                                   dequant=dequant)
    y2 = ref.codebook_matmul_ref(x, jnp.asarray(idx), cb)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=3e-5, atol=3e-4)


def test_uint8_kernel_lut_matches_onehot():
    """The uint8-index kernel grew the same LUT/one-hot switch."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(24, 128), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 256, size=(128, 48)), jnp.uint8)
    cb = jnp.asarray(rng.randn(256), jnp.float32)
    y_lut = ops.codebook_matmul(x, idx, cb, bm=32, bn=32, bk=64,
                                dequant="lut")
    y_oh = ops.codebook_matmul(x, idx, cb, bm=32, bn=32, bk=64,
                               dequant="onehot")
    np.testing.assert_allclose(np.asarray(y_lut), np.asarray(y_oh),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("k", K_SWEEP)
@pytest.mark.parametrize("kd,n", [(32, 16), (300, 77)])
def test_pack_rows_unpack_rows_roundtrip(k, kd, n):
    idx, _, _ = _rand_case(k, kd, n, seed=kd + k)
    words = C.pack_rows(idx, k)
    assert words.shape == (kd, -(-n // (32 // C.bits_per_index(k))))
    out = np.asarray(C.unpack_rows(jnp.asarray(words), n, k))
    np.testing.assert_array_equal(out, idx)


@pytest.mark.parametrize("k", K_SWEEP)
@pytest.mark.parametrize("order", ["kd", "row"])
@pytest.mark.parametrize("m,v,d", [(8, 32, 16), (5, 77, 50), (1, 257, 33)])
def test_packed_matmul_t_matches_ref(m, v, d, k, order):
    """interpret-mode transposed kernel == dequant-then-dot oracle to fp32
    tolerance, both word orders, including ragged M/V/D tails."""
    idx, _, cb = _rand_case(k, v, d, seed=m + v + d + k)
    x = jnp.asarray(np.random.RandomState(m + d).randn(m, d), jnp.float32)
    lanes = 32 // C.bits_per_index(k)
    if order == "kd":
        pidx = jnp.asarray(C.pack_indices_2d(idx, k))
        bn, bk = 2 * lanes, 16
    else:
        pidx = jnp.asarray(C.pack_rows(idx, k))
        bn, bk = 16, 2 * lanes
    y1 = ops.packed_codebook_matmul_t(x, pidx, cb, v, order=order, bm=8,
                                      bn=bn, bk=bk)
    want = np.asarray(x) @ np.asarray(cb)[idx].T
    np.testing.assert_allclose(np.asarray(y1), want, rtol=3e-5, atol=3e-4)
    y2 = ref.packed_codebook_matmul_t_ref(x, pidx, cb, v, order=order)
    np.testing.assert_allclose(np.asarray(y2), want, rtol=3e-5, atol=3e-4)


@pytest.mark.parametrize("k", K_SWEEP)
def test_gather_kernel_matches_dense_rows_bitwise(k):
    """interpret-mode gather kernel == dense-table row gather, bitwise
    (a pure gather — no arithmetic), ragged D included; lut == onehot."""
    v, d = 50, 13
    idx, _, cb = _rand_case(k, v, d, seed=k)
    pidx = jnp.asarray(C.pack_rows(idx, k))
    toks = jnp.asarray(np.random.RandomState(k).randint(0, v, size=(9,)),
                       jnp.int32)
    dense = np.asarray(cb)[idx]
    for dequant in ("lut", "onehot"):
        g = ops.quantized_gather(toks, pidx, cb, d, dequant=dequant)
        np.testing.assert_array_equal(np.asarray(g),
                                      dense[np.asarray(toks)])


@pytest.mark.tpu
@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="Mosaic compile path needs a real TPU")
def test_packed_kernels_compile_on_tpu():
    """The Mosaic (non-interpret) lowering of all three packed kernels —
    the compiled counterpart of the interpret-mode sweeps above."""
    k, v, d, m = 16, 256, 512, 8
    idx, _, cb = _rand_case(k, v, d, seed=1)
    x = jnp.asarray(np.random.RandomState(0).randn(m, d), jnp.float32)
    dense = np.asarray(cb)[idx]
    pidx_kd = jnp.asarray(C.pack_indices_2d(idx, k))
    y = ops.packed_codebook_matmul(
        jnp.asarray(np.random.RandomState(2).randn(m, v), jnp.float32),
        pidx_kd, cb, bm=8, bn=128, bk=128, interpret=False)
    assert y.shape == (m, d)
    pidx_r = jnp.asarray(C.pack_rows(idx, k))
    y_t = ops.packed_codebook_matmul_t(x, pidx_r, cb, v, order="row", bm=8,
                                       bn=128, bk=128, interpret=False)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(x) @ dense.T,
                               rtol=3e-5, atol=3e-4)
    toks = jnp.asarray([0, 3, 255, 17], jnp.int32)
    g = ops.quantized_gather(toks, pidx_r, cb, d, interpret=False)
    np.testing.assert_array_equal(np.asarray(g), dense[np.asarray(toks)])


def test_dispatch_packed_route_and_layout_validation():
    idx, pidx, cb = _rand_case(16, 128, 64, seed=11)
    x = jnp.asarray(np.random.RandomState(1).randn(8, 128), jnp.float32)
    layout = C.PackedLayout.make(128, 64, 16)
    for backend in ("ref", "pallas_interpret"):
        y = dispatch.packed_codebook_matmul(x, pidx, cb, layout=layout,
                                            backend=backend)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.codebook_matmul_ref(
                x, jnp.asarray(idx), cb)), rtol=3e-5, atol=3e-4)
    with pytest.raises(ValueError, match="layout"):
        dispatch.packed_codebook_matmul(
            x, pidx, cb, layout=C.PackedLayout.make(64, 64, 16),
            backend="ref")


def test_packed_block_sizes_lane_aligned(monkeypatch):
    monkeypatch.delenv("REPRO_PACKED_BLOCKS", raising=False)
    for m, kd, n in [(1, 2048, 512), (64, 1024, 256), (512, 4096, 1024),
                     (33, 100, 100)]:
        for bits in (1, 2, 3, 4, 8):
            bm, bn, bk = dispatch.packed_block_sizes(m, kd, n, bits)
            assert bk % (32 // bits) == 0, (m, kd, n, bits, bk)
            assert bm > 0 and bn > 0
            # transposed route: the lane-packed axis depends on the order
            bm, bn, bk = dispatch.packed_block_sizes_t(m, kd, n, bits, "kd")
            assert bn % (32 // bits) == 0, (m, kd, n, bits, bn)
            bm, bn, bk = dispatch.packed_block_sizes_t(m, kd, n, bits, "row")
            assert bk % (32 // bits) == 0, (m, kd, n, bits, bk)
    monkeypatch.setenv("REPRO_PACKED_BLOCKS", "16,64,128")
    assert dispatch.packed_block_sizes(7, 99, 13, 4) == (16, 64, 128)


def test_serving_params_packed_no_uint8():
    """serving_params(packed=True) must not materialize any index array
    wider than the packed uint32 words, and apply_mlp over the packed
    layout must match the uint8 layout and the dense decode."""
    from repro.models import layers as L

    rng = np.random.RandomState(0)
    k = 16
    d, f = 48, 96
    key = jax.random.PRNGKey(0)
    params = {"mlp": L.init_mlp(key, d, f, "silu", True)}

    from repro.core import CompressionPlan
    plan = CompressionPlan.parse(f"adaptive:{k}")
    qspec = plan.build_qspec(params)
    state = plan.init(key, params, qspec)
    packed = plan.pack(params, state, qspec)

    sp = packed.serving_params(packed=True)
    up = packed.serving_params(packed=False)
    mlp_p, mlp_u = sp["mlp"], up["mlp"]
    for name in ("w_in", "w_gate", "w_out"):
        assert f"{name}_pidx" in mlp_p and f"{name}_idx" not in mlp_p
        assert mlp_p[f"{name}_pidx"].dtype == jnp.uint32
        layout = mlp_p[f"{name}_layout"]
        assert isinstance(layout, C.PackedLayout)
        assert mlp_p[f"{name}_pidx"].shape == (layout.words, layout.n)
        # HBM index bytes per weight == bits/8 (kd here divides lanes).
        nbytes = mlp_p[f"{name}_pidx"].size * 4
        assert nbytes * 8 == layout.bits * layout.kd * layout.n

    x = jnp.asarray(rng.randn(5, d), jnp.float32)
    y_p = L.apply_mlp(mlp_p, x, "silu")
    y_u = L.apply_mlp(mlp_u, x, "silu")
    dense = packed.decode()["mlp"]
    y_d = L.apply_mlp(dense, x, "silu")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_u),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_d),
                               rtol=1e-4, atol=1e-4)
    # decode_params collapses the packed layout to the same dense leaves
    dec = dispatch.decode_params(sp)
    np.testing.assert_allclose(np.asarray(dec["mlp"]["w_in"]),
                               np.asarray(dense["w_in"]), rtol=0, atol=0)


def test_grouped_packed_serving_under_scan():
    """Grouped (stacked-layer) packed leaves: the static PackedLayout node
    rides through jax.lax.scan and each group's slice decodes exactly."""
    from repro.kernels.dispatch import decode_packed_leaf

    rng = np.random.RandomState(4)
    g, kd, n, k = 3, 64, 32, 4
    idx = rng.randint(0, k, size=(g, kd, n))
    words = jnp.asarray(np.stack([C.pack_indices_2d(i, k) for i in idx]))
    cb = jnp.asarray(rng.randn(g, k), jnp.float32)
    layout = C.PackedLayout.make(kd, n, k)

    dense = decode_packed_leaf(words, cb, layout)
    ref_dense = np.stack([np.asarray(cb)[i][idx[i]] for i in range(g)])
    np.testing.assert_allclose(np.asarray(dense), ref_dense, rtol=0, atol=0)

    xs = {"pidx": words, "cb": cb, "layout": layout}
    x = jnp.asarray(rng.randn(2, kd), jnp.float32)

    def body(carry, p):
        y = dispatch.packed_quantized_matmul(x, p["pidx"], p["cb"],
                                             layout=p["layout"])
        return carry + jnp.sum(y), None

    total, _ = jax.lax.scan(body, 0.0, xs)
    expect = sum(float(jnp.sum(x @ jnp.asarray(ref_dense[i])))
                 for i in range(g))
    np.testing.assert_allclose(float(total), expect, rtol=1e-5)


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 256), st.integers(1, 500), st.integers(1, 64),
           st.integers(0, 10 ** 6))
    def test_packed_matmul_fuzz(k, kd, n, seed):
        rng = np.random.RandomState(seed)
        idx = rng.randint(0, k, size=(kd, n))
        pidx = jnp.asarray(C.pack_indices_2d(idx, k))
        cb = jnp.asarray(rng.randn(k), jnp.float32)
        x = jnp.asarray(rng.randn(4, kd), jnp.float32)
        lanes = 32 // C.bits_per_index(k)
        y1 = ops.packed_codebook_matmul(x, pidx, cb, bm=8, bn=32,
                                        bk=2 * lanes)
        y2 = ref.codebook_matmul_ref(x, jnp.asarray(idx), cb)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=3e-5, atol=3e-4)
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_packed_matmul_fuzz():
        pass
