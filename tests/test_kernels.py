"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + hypothesis fuzzing.  The deterministic sweeps always run; only
the fuzz test skips when hypothesis is not installed
(``pip install -r requirements-dev.txt``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # dev-only dep: fuzzing skips, sweeps still run
    given = None

from repro.kernels import ops, ref


@pytest.mark.parametrize("p", [3, 100, 1024, 4096, 70001])
@pytest.mark.parametrize("k", [2, 3, 16, 64])
def test_kmeans_assign_matches_ref(p, k):
    key = jax.random.PRNGKey(p * 131 + k)
    w = jax.random.normal(key, (p,))
    cb = jnp.sort(jax.random.normal(jax.random.fold_in(key, 1), (k,)))
    a1, s1, c1 = ops.kmeans_assign(w, cb)
    a2, s2, c2 = ref.kmeans_assign_ref(w, cb)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=0.5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_dtypes(dtype):
    w = jax.random.normal(jax.random.PRNGKey(0), (2048,)).astype(dtype)
    cb = jnp.asarray([-1.0, 0.0, 1.0])
    a1, s1, c1 = ops.kmeans_assign(w, cb)
    a2, s2, c2 = ref.kmeans_assign_ref(w.astype(jnp.float32), cb)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


@pytest.mark.parametrize("m,kd,n", [(8, 32, 16), (128, 512, 128),
                                    (100, 300, 77), (1, 2048, 1)])
@pytest.mark.parametrize("k", [2, 4, 256])
def test_codebook_matmul_matches_ref(m, kd, n, k):
    key = jax.random.PRNGKey(m + n + k)
    x = jax.random.normal(key, (m, kd), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (kd, n), 0, k
                             ).astype(jnp.uint8 if k <= 256 else jnp.int32)
    cb = jax.random.normal(jax.random.fold_in(key, 2), (k,))
    y1 = ops.codebook_matmul(x, idx, cb, bm=32, bn=32, bk=64)
    y2 = ref.codebook_matmul_ref(x, idx, cb)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-5, atol=3e-4)


def test_codebook_matmul_bf16_activations():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 128), jnp.bfloat16)
    idx = jax.random.randint(jax.random.PRNGKey(1), (128, 64), 0, 4
                             ).astype(jnp.uint8)
    cb = jnp.asarray([-0.5, -0.1, 0.1, 0.5])
    y1 = ops.codebook_matmul(x, idx, cb, bm=32, bn=32, bk=64)
    y2 = ref.codebook_matmul_ref(x, idx, cb)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=2e-2, atol=2e-1)


@pytest.mark.parametrize("mode", ["binary", "ternary", "pow2"])
@pytest.mark.parametrize("shape", [(5,), (100,), (33, 77), (8, 1024)])
def test_fixed_quant_matches_ref(mode, shape):
    w = 2.0 * jax.random.normal(jax.random.PRNGKey(42), shape)
    q1 = ops.fixed_quant(w, mode)
    q2 = ref.fixed_quant_ref(w, mode)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@pytest.mark.parametrize("scale", [0.5, 1.0, 2.3])
def test_fixed_quant_scale(scale):
    w = jax.random.normal(jax.random.PRNGKey(7), (999,))
    q1 = ops.fixed_quant(w, "ternary", scale=scale)
    q2 = ref.fixed_quant_ref(w, "ternary", scale=scale)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)


if given is not None:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 3000), st.integers(2, 32), st.integers(0, 10 ** 6))
    def test_kmeans_assign_fuzz(p, k, seed):
        key = jax.random.PRNGKey(seed)
        w = 3 * jax.random.normal(key, (p,))
        cb = jnp.sort(jax.random.normal(jax.random.fold_in(key, 1), (k,)))
        a1, s1, c1 = ops.kmeans_assign(w, cb)
        a2, s2, c2 = ref.kmeans_assign_ref(w, cb)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=0.5)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=2e-4, atol=2e-3)
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_kmeans_assign_fuzz():
        pass
