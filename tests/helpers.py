"""Shared helpers for the cross-route differential test suites.

The three serving storage layouts a quantized leaf may arrive in —

* ``dense``  — the decoded dense params (``PackedModel.decode()``);
* ``uint8``  — ``<name>_idx`` uint8 + ``<name>_cb``
  (``serving_params(packed=False)``, the 1 B/weight oracle);
* ``packed`` — ``<name>_pidx`` uint32 words + ``<name>_cb`` +
  ``<name>_layout`` (``serving_params(packed=True)``,
  ``bits_per_index(K)/8`` B/weight; embedding tables row-packed) —

must produce **bit-identical** model outputs on the CPU ref backend
across every execution mode (forward / prefill / decode).  These helpers
build the layouts and run the comparison so the matrix in
``tests/test_differential.py`` (and the ad-hoc checks consolidated from
``test_qleaf.py``) all go through one code path:
:func:`assert_routes_agree`.
"""
from __future__ import annotations

import functools
from typing import Dict, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.zoo import mixed_cfg, tiny_cfg  # noqa: F401 — the
# fixture-zoo configs moved to src (the audit CLI reconstructs the model
# an artifact serves); re-exported so every existing test import works.
from repro.core import CompressionPlan, PackedModel
from repro.models.transformer import (ModelConfig, decode_step, forward,
                                      init_params, prefill)

# the PR-2-era MLP-only coverage set (pre-qleaf serving)
MLP_LEGACY = ("w_in", "w_gate", "w_out")

LAYOUTS = ("dense", "uint8", "packed")
MODES = ("forward", "prefill", "decode")


def pack_model(params, k: int) -> PackedModel:
    """Default-policy pack at codebook size K (adaptive scheme)."""
    plan = CompressionPlan.parse(f"adaptive:{k}")
    qspec = plan.build_qspec(params)
    state = plan.init(jax.random.PRNGKey(1), params, qspec)
    return plan.pack(params, state, qspec)


def serving_layouts(packed: PackedModel,
                    which: Iterable[str] = LAYOUTS) -> Dict[str, dict]:
    """The three storage layouts of one artifact, keyed by name."""
    build = {"dense": packed.decode,
             "uint8": lambda: packed.serving_params(packed=False),
             "packed": lambda: packed.serving_params(packed=True)}
    return {name: build[name]() for name in which}


@functools.lru_cache(maxsize=None)
def packed_tiny(k: int, dtype_name: str, tie: bool = True):
    """Cached (cfg, PackedModel) for the differential matrix — packing is
    the expensive step, so each (K, dtype) cell is built once per run."""
    cfg = tiny_cfg(tie)
    params = init_params(jax.random.PRNGKey(0), cfg,
                         dtype=jnp.dtype(dtype_name))
    return cfg, pack_model(params, k)


def assert_trees_equal(a, b, context: str = ""):
    """Bitwise equality over two pytrees (leaf count, then every array)."""
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), (context, len(la), len(lb))
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=context)


def run_mode(params, cfg: ModelConfig, tokens, mode: str,
             decode_steps: int = 3):
    """One serving execution mode → comparable pytree of outputs.

    ``forward``: full-sequence logits.  ``prefill``: (last logits, emitted
    caches).  ``decode``: prefill then ``decode_steps`` greedy steps —
    returns every step's logits AND the final caches, so cache divergence
    is caught even when logits happen to agree.
    """
    if mode == "forward":
        return forward(params, cfg, tokens)
    logits, caches = prefill(params, cfg, tokens, last_logits_only=True)
    if mode == "prefill":
        return logits, caches
    assert mode == "decode", mode
    outs = [logits]
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for t in range(decode_steps):
        pos = jnp.asarray(tokens.shape[1] + t, jnp.int32)
        logits, caches = decode_step(params, cfg, caches, tok, pos)
        outs.append(logits)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return outs, caches


def assert_routes_agree(cfg: ModelConfig, layouts: Dict[str, dict], tokens,
                        modes: Tuple[str, ...] = MODES,
                        reference: str = "dense",
                        decode_steps: int = 3):
    """Every layout serves bit-identically to ``reference`` in every mode.

    This is THE differential invariant of the packed-serving family: on
    the CPU ref backend the quantized routes are literally the dense
    ``x @ cb[idx]`` graph, so logits *and* caches must match bitwise —
    any mismatch means a storage layout decoded differently.
    """
    ref_out = {m: run_mode(layouts[reference], cfg, tokens, m,
                           decode_steps=decode_steps) for m in modes}
    for name, params in layouts.items():
        if name == reference:
            continue
        for m in modes:
            got = run_mode(params, cfg, tokens, m, decode_steps=decode_steps)
            assert_trees_equal(ref_out[m], got,
                               context=f"layout={name} mode={m}")
