"""Hypothesis property tests on the quantization invariants.

Every test here fuzzes through hypothesis, so the whole module skips
when it is not installed (``pip install -r requirements-dev.txt``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compression, quant_ops as Q
from repro.core.kmeans import kmeans_fit, quantile_init

F32 = st.floats(-100.0, 100.0, allow_nan=False, width=32)
ARRAYS = st.lists(F32, min_size=2, max_size=200).map(
    lambda xs: jnp.asarray(xs, jnp.float32))


@settings(max_examples=50, deadline=None)
@given(ARRAYS)
def test_binarize_idempotent(w):
    q = Q.binarize(w)
    np.testing.assert_array_equal(np.asarray(Q.binarize(q)), np.asarray(q))


@settings(max_examples=50, deadline=None)
@given(ARRAYS)
def test_ternarize_idempotent(w):
    q = Q.ternarize(w)
    np.testing.assert_array_equal(np.asarray(Q.ternarize(q)), np.asarray(q))


@settings(max_examples=50, deadline=None)
@given(ARRAYS, st.integers(0, 6))
def test_pow2_idempotent_and_in_codebook(w, c):
    q = np.asarray(Q.pow2_quantize(w, c))
    codebook = sorted({s * m for m in [0.0] + [2.0 ** (-i) for i in range(c + 1)]
                       for s in (-1.0, 1.0)})
    assert set(np.unique(q)).issubset(set(codebook))
    q2 = np.asarray(Q.pow2_quantize(jnp.asarray(q), c))
    np.testing.assert_array_equal(q2, q)


@settings(max_examples=50, deadline=None)
@given(ARRAYS)
def test_binarize_scale_optimal_scale(w):
    """a* = mean|w| is stationary: E(a) is quadratic in a with min there."""
    q, a = Q.binarize_scale(w)
    a = float(a)
    e0 = float(jnp.sum((w - q) ** 2))
    for eps in (1e-3, -1e-3):
        qe = (a + eps) * Q.sgn(w)
        ee = float(jnp.sum((w - qe) ** 2))
        assert e0 <= ee * (1 + 1e-5) + 1e-6      # f32 ULP headroom


@settings(max_examples=30, deadline=None)
@given(ARRAYS)
def test_c_step_assignment_beats_any_shift(w):
    """Voronoi assignment is distortion-optimal vs shifted assignments."""
    cb = jnp.sort(jnp.asarray([-1.0, -0.3, 0.4, 2.0]))
    assign = Q.fixed_codebook_assign(w, cb)
    d_opt = float(jnp.sum((w - cb[assign]) ** 2))
    for shift in (-1, 1):
        alt = jnp.clip(assign + shift, 0, 3)
        d_alt = float(jnp.sum((w - cb[alt]) ** 2))
        assert d_opt <= d_alt + 1e-5


@settings(max_examples=20, deadline=None)
@given(st.lists(F32, min_size=8, max_size=100), st.integers(2, 5))
def test_kmeans_from_grid_beats_fixed_grid(xs, k):
    """Adaptive codebook ≥ fixed codebook (paper §2.1): k-means *started
    from* a uniform grid can only lower the grid's distortion (descent).
    (Note: from an arbitrary init k-means may hit a worse local optimum —
    hypothesis found [0×6,1,2]/K=3 — so the property is stated via the
    descent guarantee, as in the paper's k-means argument.)"""
    w = jnp.asarray(xs, jnp.float32)
    lo, hi = float(jnp.min(w)), float(jnp.max(w))
    grid = jnp.linspace(lo, hi if hi > lo else lo + 1.0, k)
    q_grid = grid[Q.fixed_codebook_assign(w, grid)]
    grid_dist = float(jnp.sum((w - q_grid) ** 2))
    res = kmeans_fit(w, grid, iters=30)
    assert float(res.distortion) <= grid_dist * (1 + 1e-5) + 1e-4


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 256), st.integers(1, 2000))
def test_pack_unpack_roundtrip(k, n):
    rng = np.random.RandomState(n)
    assign = rng.randint(0, k, size=n)
    words, lanes = compression.pack_indices(assign, k)
    out = np.asarray(compression.unpack_indices(jnp.asarray(words), n, k))
    np.testing.assert_array_equal(out, assign)


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 256))
def test_compression_ratio_monotone_in_k(k):
    """ρ(K) decreases (weakly) as K grows — paper eq. 14 sanity."""
    p1, p0 = 266200, 410
    r_small = compression.compression_ratio(p1, p0, k, k)
    r_big = compression.compression_ratio(p1, p0, min(k * 2, 512),
                                          min(k * 2, 512))
    assert r_big <= r_small + 1e-9


def test_compression_ratio_matches_paper_lenet300():
    """Paper fig. 9 table: LeNet300, per-layer codebooks (3 layers)."""
    p1, p0 = 266200, 410
    expected = {2: 30.5, 4: 15.6, 8: 10.5, 16: 7.9, 32: 6.3, 64: 5.3}
    for k, rho in expected.items():
        got = compression.compression_ratio(p1, p0, k, 3 * k)
        assert abs(got - rho) < 0.1, (k, got, rho)
