"""Continuous-batching engine differential + stress suite.

THE invariant: for any admission order, slot count, page-pool size and
completion pattern, every request's greedy token stream from the engine
equals the one-shot lockstep loop's (``repro.engine.oneshot``) — across
{dense, packed} serving layouts and K ∈ {2, 16} on the mixed
gqa+moe+ssm stack.  Plus: page-reuse stress (short/long interleave with
an oversubscribed pool never corrupts a neighbor's KV), no-recompile on
admission, deterministic per-request sampling, and scheduler / page-pool
unit behavior.
"""
import functools

import jax
import numpy as np
import pytest

from helpers import mixed_cfg, pack_model
from repro.engine import (Engine, Outcome, PagePool, Request,
                          SlotScheduler, greedy_generate, truncate_at_eos)


@functools.lru_cache(maxsize=None)
def _mixed(k: int, layout: str):
    """(cfg, serving params) for the mixed gqa+moe+ssm stack — cached:
    packing is the expensive step."""
    cfg = mixed_cfg(tie=True)
    params = jax.random.PRNGKey(0)
    from repro.models.transformer import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    if layout == "dense":
        return cfg, params
    packed = pack_model(params, k)
    return cfg, packed.serving_params(packed=True)


@functools.lru_cache(maxsize=None)
def _prompts(vocab: int, n: int, length: int):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(7 + length), (n, length), 0, vocab))


def _oracle(params, cfg, reqs, block=None):
    """One-shot greedy streams per request (grouped by prompt length —
    the lockstep loop needs a rectangular prompt batch).  ``block`` is
    the prefill block size; pass the engine's ``effective_chunk`` when
    it differs from the default so both sides run the same blockwise
    partition (different partitions are numerically inequivalent)."""
    out = {}
    by_len = {}
    for r in reqs:
        by_len.setdefault(r.prompt_len, []).append(r)
    for length, group in by_len.items():
        prompts = np.stack([r.prompt for r in group])
        gen = max(r.max_new_tokens for r in group)
        toks = np.asarray(greedy_generate(params, cfg,
                                          jax.numpy.asarray(prompts),
                                          gen, block=block)[0])
        for i, r in enumerate(group):
            out[r.rid] = truncate_at_eos(toks[i][:r.max_new_tokens],
                                         r.eos_id)
    return out


def _assert_streams_equal(outs, want):
    assert set(outs) == set(want)
    for rid in want:
        np.testing.assert_array_equal(
            outs[rid], want[rid],
            err_msg=f"request {rid}: engine stream != one-shot stream")


# ---------------------------------------------------------------------------
# The differential matrix: {dense, packed} × K ∈ {2, 16}, staggered
# admission (more requests than slots, mixed prompt lengths) and
# out-of-order completion (mixed max-new-tokens)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout,k", [("dense", 16), ("packed", 2),
                                      ("packed", 16)])
def test_engine_matches_one_shot_staggered(layout, k):
    cfg, params = _mixed(k, layout)
    p16 = _prompts(cfg.vocab, 4, 16)
    p8 = _prompts(cfg.vocab, 2, 8)
    gens = [6, 2, 5, 3, 6, 1]
    reqs = [Request(rid=r, prompt=(p16[r // 2] if r % 2 == 0
                                   else p8[r // 4]),
                    max_new_tokens=gens[r]) for r in range(6)]
    # token_budget 12 < prompt 16: the engine prefills in blocks of 12
    # ({12, 4} for the long prompts, {8} for the short) — the oracle
    # must run the same partition
    want = _oracle(params, cfg, reqs, block=12)

    eng = Engine(params, cfg, n_slots=2, page_size=8, max_seq=24,
                 token_budget=12)
    assert eng.effective_chunk == 12
    # Staggered admission / eviction never retraces: jit-cache growth is
    # bounded by the number of distinct *shapes* (decode: 1 config;
    # prefill chunks: the 3 distinct block widths 12/4/8; sample: 1),
    # never by admission or completion events.
    from repro.analysis import RecompileAuditor
    auditor = RecompileAuditor(eng.trace_counts)
    with auditor.frozen("staggered admission/completion",
                        budget={"decode": 1, "prefill_chunk": 3,
                                "sample": 1}):
        outs = eng.run(reqs)
    _assert_streams_equal(outs, want)
    s = eng.stats.summary()
    assert s["finished"] == 6
    assert 0 < s["slot_occupancy"] <= 1
    assert 0 < s["page_utilization_max"] <= 1


def test_engine_eos_early_exit_out_of_order():
    """EOS stops a request mid-stream; its slot and pages free while
    neighbors keep decoding."""
    cfg, params = _mixed(16, "packed")
    p16 = _prompts(cfg.vocab, 3, 16)
    base = [Request(rid=r, prompt=p16[r], max_new_tokens=8)
            for r in range(3)]
    plain = _oracle(params, cfg, base)
    # make request 1's third token its EOS: it must finish after 3 tokens
    eos = int(plain[1][2])
    reqs = [Request(rid=r, prompt=p16[r], max_new_tokens=8,
                    eos_id=eos if r == 1 else None) for r in range(3)]
    want = _oracle(params, cfg, reqs)
    assert len(want[1]) == 3

    eng = Engine(params, cfg, n_slots=2, page_size=8, max_seq=24)
    outs = eng.run(reqs)
    _assert_streams_equal(outs, want)
    assert len(outs[1]) == 3 and outs[1][-1] == eos


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b",
                                  "recurrentgemma-2b"])
def test_engine_matches_one_shot_mla_rglru_windowed(arch):
    """The mixer kinds the mixed stack doesn't cover: MLA (paged
    absorbed-latent decode) and RG-LRU + sliding-window gqa_local
    (per-slot ring buffers) — engine streams must still equal the
    one-shot loop's under staggered admission."""
    from repro.configs import get_config, reduce_config
    from repro.models.transformer import init_params
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg.vocab, 3, 16)
    reqs = [Request(rid=r, prompt=prompts[r],
                    max_new_tokens=[5, 3, 4][r]) for r in range(3)]
    want = _oracle(params, cfg, reqs)
    eng = Engine(params, cfg, n_slots=2, page_size=8, max_seq=24)
    _assert_streams_equal(eng.run(reqs), want)


# ---------------------------------------------------------------------------
# Blockwise prefill: prompt_len >> prefill_chunk
# ---------------------------------------------------------------------------

_LONG_GEO = dict(page_size=8, max_seq=64, prefill_chunk=8, token_budget=10)


def _long_reqs(cfg, n=3, length=40):
    prompts = _prompts(cfg.vocab, n, length)
    return [Request(rid=r, prompt=prompts[r],
                    max_new_tokens=[6, 3, 5][r % 3]) for r in range(n)]


@pytest.mark.parametrize("layout,k,kv_bits", [
    ("dense", 16, 0), ("packed", 2, 0), ("packed", 16, 0),
    ("dense", 16, 4), ("packed", 16, 4)])
def test_blockwise_prefill_long_prompt(layout, k, kv_bits):
    """prompt_len (40) >> prefill_chunk (8): prefill streams through the
    prompt in 5 real block forwards per request — recurrent/window
    carries cross block boundaries, each block's K/V lands in the slot's
    pages (quantized when kv_bits > 0) — and the final streams still
    equal the oracle.  Plus the stats identities the old commit-style
    prefill lied about."""
    cfg, params = _mixed(k, layout)
    reqs = _long_reqs(cfg)
    kvq = dict(kv_bits=kv_bits, kv_cb_mode="page") if kv_bits else {}
    eng = Engine(params, cfg, n_slots=2, **_LONG_GEO, **kvq)
    assert eng.effective_chunk == 8
    outs = eng.run(list(reqs))
    if kv_bits == 0:
        want = _oracle(params, cfg, reqs, block=8)
        _assert_streams_equal(outs, want)
    else:
        # quantized KV has no dense oracle; the contract (PR 8) is slot
        # -layout invariance: a different slot count means different
        # pages, admission order and preemption pattern — same streams
        outs2 = Engine(params, cfg, n_slots=3, **_LONG_GEO,
                       **kvq).run(list(reqs))
        _assert_streams_equal(outs, outs2)
    st = eng.stats
    assert st.prefill_tokens == 3 * 40          # computed, not charged
    assert st.prefill_calls == 3 * 5            # ceil(40/8) blocks each
    assert st.prefill_samples == 3
    assert st.generated_tokens == st.decode_tokens + st.prefill_samples
    assert st.generated_tokens == sum(len(v) for v in outs.values())


@pytest.mark.parametrize("kv_bits", [0, 4])
def test_blockwise_prefill_long_prompt_mla(kv_bits):
    """Same long-prompt regime on the MLA stack (absorbed-latent paged
    decode + latent-page blockwise prefill)."""
    from repro.configs import get_config, reduce_config
    from repro.models.transformer import init_params
    cfg = reduce_config(get_config("deepseek-v2-lite-16b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    reqs = _long_reqs(cfg)
    kvq = dict(kv_bits=kv_bits, kv_cb_mode="page") if kv_bits else {}
    eng = Engine(params, cfg, n_slots=2, **_LONG_GEO, **kvq)
    outs = eng.run(list(reqs))
    if kv_bits == 0:
        _assert_streams_equal(outs, _oracle(params, cfg, reqs, block=8))
    else:
        outs2 = Engine(params, cfg, n_slots=3, **_LONG_GEO,
                       **kvq).run(list(reqs))
        _assert_streams_equal(outs, outs2)
    assert eng.stats.prefill_calls == 3 * 5


def test_prefill_budget_bounds_compute():
    """THE tentpole claim, asserted on the actual device-call trace: no
    engine step runs a forward over more than ``effective_chunk`` prompt
    tokens — the old engine charged budget per chunk but then ran ONE
    full-prompt forward at commit, so its widest call was prompt_len."""
    cfg, params = _mixed(16, "dense")
    reqs = _long_reqs(cfg)
    eng = Engine(params, cfg, n_slots=2, **_LONG_GEO)
    widths = []
    orig = eng._chunk

    def spy(p, c, caches, table, tok, slot, start):
        widths.append(int(tok.shape[1]))
        return orig(p, c, caches, table, tok, slot, start)

    eng._chunk = spy
    outs = eng.run(list(reqs))
    assert widths, "prefill never ran"
    assert max(widths) <= eng.effective_chunk == 8
    assert sum(widths) == 3 * 40               # every prompt token once
    _assert_streams_equal(outs, _oracle(params, cfg, reqs, block=8))


# ---------------------------------------------------------------------------
# Page reuse stress: oversubscribed pool, short/long interleave
# ---------------------------------------------------------------------------

def test_page_reuse_stress_never_corrupts_neighbor_kv():
    """A long-running request decodes while short requests churn through
    the slots around it, constantly recycling pages.  The pool is
    oversubscribed (stalls + preemptions must occur), yet every stream —
    including the long neighbor's — stays exactly the one-shot stream:
    a page handed to a new request is never still referenced by an old
    page table."""
    cfg, params = _mixed(16, "packed")
    p16 = _prompts(cfg.vocab, 8, 16)
    p8 = _prompts(cfg.vocab, 4, 8)
    reqs = [Request(rid=0, prompt=p16[0], max_new_tokens=8)]  # the long one
    for r in range(1, 8):
        reqs.append(Request(rid=r, prompt=(p8[r % 4] if r % 2
                                           else p16[r]),
                            max_new_tokens=2 + r % 3))
    want = _oracle(params, cfg, reqs)

    # 3 slots but only 7 usable pages (full residency would need 9)
    eng = Engine(params, cfg, n_slots=3, page_size=8, max_seq=24,
                 n_pages=7, token_budget=20)
    outs = eng.run(reqs)
    _assert_streams_equal(outs, want)
    s = eng.stats.summary()
    assert s["page_utilization_max"] > 0.8


def test_preemption_replays_request_exactly():
    """When every runnable slot is page-starved the youngest is
    preempted and replayed from scratch — deterministically, so its
    final stream is still the oracle stream."""
    cfg, params = _mixed(16, "packed")
    p16 = _prompts(cfg.vocab, 6, 16)
    reqs = [Request(rid=r, prompt=p16[r], max_new_tokens=[6, 2, 5, 3, 6,
                                                          4][r])
            for r in range(6)]
    want = _oracle(params, cfg, reqs)
    eng = Engine(params, cfg, n_slots=3, page_size=8, max_seq=22,
                 n_pages=6, token_budget=20)
    outs = eng.run(reqs)
    _assert_streams_equal(outs, want)
    assert eng.stats.preemptions > 0
    assert eng.stats.stall_events > 0


# ---------------------------------------------------------------------------
# Per-slot sampling
# ---------------------------------------------------------------------------

def test_sampled_streams_deterministic_across_batching():
    """temperature/top-k streams depend only on (request, seed), not on
    slot assignment, admission order, or pool shape."""
    cfg, params = _mixed(16, "packed")
    p16 = _prompts(cfg.vocab, 4, 16)

    def mk():
        return [Request(rid=r, prompt=p16[r], max_new_tokens=5,
                        temperature=0.8, top_k=7, seed=100 + r)
                for r in range(4)]

    o1 = Engine(params, cfg, n_slots=2, page_size=8, max_seq=24).run(mk())
    o2 = Engine(params, cfg, n_slots=4, page_size=4, max_seq=24).run(mk())
    for r in o1:
        np.testing.assert_array_equal(o1[r], o2[r])
    # all sampled ids are valid vocab entries
    for r in o1:
        assert (o1[r] >= 0).all() and (o1[r] < cfg.vocab).all()


def test_bf16_model_infers_bf16_pool_and_matches_oracle():
    """The KV-pool dtype is inferred from the embedding leaf: a bf16
    model gets a bf16 pool (an f32 pool would round differently than
    the oracle's bf16 caches and break stream parity)."""
    import jax.numpy as jnp
    from repro.models.transformer import (LayerKind, ModelConfig,
                                          StackSpec, init_params)
    cfg = ModelConfig(
        name="bf16-eng", family="dense", d_model=32, n_heads=4, n_kv=2,
        head_dim=8, d_ff=64, vocab=96,
        stacks=(StackSpec(pattern=(LayerKind("gqa", "dense"),),
                          groups=2),),
        tie_embeddings=True, q_chunk=8, kv_chunk=8, remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.bfloat16)
    prompts = _prompts(cfg.vocab, 2, 16)
    reqs = [Request(rid=r, prompt=prompts[r], max_new_tokens=[5, 3][r])
            for r in range(2)]
    want = _oracle(params, cfg, reqs)
    eng = Engine(params, cfg, n_slots=2, page_size=8, max_seq=24)
    assert eng.caches[0]["pos0"].k.dtype == jnp.bfloat16
    _assert_streams_equal(eng.run(reqs), want)


def test_top_k_ties_keep_exactly_k():
    """Tie-heavy top-k: exactly k candidates survive the cutoff, ties
    breaking toward the lower token id.  The old ``logits >= cutoff``
    mask kept *every* token tied with the k-th — on flat logits top_k=3
    silently became full-vocab sampling."""
    import jax.numpy as jnp
    from repro.engine import sampling

    v = 16
    flat = jnp.zeros((v,), jnp.float32)        # all 16 logits tied
    for k in (1, 3, 7):
        seen = {int(sampling._sample_one(
            flat, jnp.float32(1.0), jnp.int32(k), sampling.slot_key(s, 0)))
            for s in range(100)}
        assert seen <= set(range(k)), (k, sorted(seen))
        if k > 1:
            assert len(seen) > 1               # still samples within top-k
    # partial tie exactly at the cutoff: k=3 over [5, 5, 3, 3, 3, ...]
    # keeps ids {0, 1} and exactly ONE of the tied 3s — id 2
    lg = jnp.asarray([5.0, 5.0, 3.0, 3.0, 3.0, 1.0, 0.0, -1.0])
    seen = {int(sampling._sample_one(
        lg, jnp.float32(0.7), jnp.int32(3), sampling.slot_key(s, 1)))
        for s in range(200)}
    assert seen <= {0, 1, 2}, sorted(seen)
    # batch wrapper agrees (same mask per row)
    toks = sampling.sample_tokens(
        jnp.stack([lg, lg]), jnp.asarray([0.7, 0.7], jnp.float32),
        jnp.asarray([3, 3], jnp.int32),
        jnp.stack([sampling.slot_key(0, 0), sampling.slot_key(0, 0)]))
    assert int(toks[0]) == int(toks[1]) and int(toks[0]) in (0, 1, 2)


def test_greedy_requests_ignore_seed():
    cfg, params = _mixed(16, "packed")
    p16 = _prompts(cfg.vocab, 2, 16)
    a = Engine(params, cfg, n_slots=2, page_size=8, max_seq=24).run(
        [Request(rid=0, prompt=p16[0], max_new_tokens=4, seed=1)])
    b = Engine(params, cfg, n_slots=2, page_size=8, max_seq=24).run(
        [Request(rid=0, prompt=p16[0], max_new_tokens=4, seed=2)])
    np.testing.assert_array_equal(a[0], b[0])


# ---------------------------------------------------------------------------
# Scheduler / page-pool units
# ---------------------------------------------------------------------------

def test_page_pool_alloc_free_accounting():
    pool = PagePool(n_pages=6, page_size=8, n_slots=2,
                    max_pages_per_slot=3)
    assert pool.free_pages == 6 and pool.used_pages == 0
    assert pool.alloc(0, 2)
    assert pool.table[0, 0] != 0 and pool.table[0, 1] != 0
    assert pool.table[0, 2] == 0                 # unallocated → trash
    assert pool.ensure(0, 17)                    # pos 17 → 3rd page
    assert pool.used_pages == 3
    assert not pool.ensure(0, 24)                # beyond max_pages_per_slot
    assert pool.alloc(1, 3)
    assert pool.free_pages == 0
    assert not pool.alloc(0, 1) and not pool.alloc(1, 1)
    freed = pool.free_slot(0)
    assert freed == 3 and pool.free_pages == 3
    assert (pool.table[0] == 0).all()
    # freed pages immediately reusable — and all-or-nothing alloc
    assert not pool.alloc(1, 4)
    p1_before = pool.pages_of(1)
    assert pool.pages_of(1) == p1_before
    pool2 = PagePool(n_pages=3, page_size=8, n_slots=1,
                     max_pages_per_slot=3)
    assert not pool2.alloc(0, 4)
    assert pool2.free_pages == 3


def test_page_pool_seized_pages_not_counted_used():
    """Chaos-seized pages are *withheld*, not owned: they must not
    inflate ``used_pages``/``utilization()`` (the old accounting counted
    a pressure spike as KV residency, so a pool with zero live slots
    could report 100% utilization)."""
    pool = PagePool(n_pages=6, page_size=8, n_slots=2,
                    max_pages_per_slot=3)
    assert pool.alloc(0, 2)
    taken = pool.seize(3)
    assert taken == 3
    assert pool.used_pages == 2                 # live slots only
    assert pool.seized == 3
    assert pool.free_pages == 1
    assert pool.utilization() == pytest.approx(2 / 6)
    # allocator still treats seized pages as unavailable
    assert not pool.alloc(1, 2)
    pool.release()
    assert pool.seized == 0 and pool.free_pages == 4
    assert pool.used_pages == 2
    # seize everything with no live slots: utilization stays 0, not 1
    pool2 = PagePool(n_pages=4, page_size=8, n_slots=1,
                     max_pages_per_slot=4)
    assert pool2.seize(4) == 4
    assert pool2.used_pages == 0
    assert pool2.utilization() == 0.0


def test_slot_scheduler_admit_evict_tracking():
    sched = SlotScheduler(2)
    r = Request(rid=0, prompt=np.arange(5), max_new_tokens=3)
    sched.submit(r)
    assert sched.has_work() and sched.free_ids() == [0, 1]
    st = sched.admit(0, sched.queue.popleft())
    assert sched.free_ids() == [1] and sched.running_ids() == []
    assert sched.prefilling_ids() == [0]
    st.prefilled = True
    st.out.append(42)
    assert sched.running_ids() == [0]
    assert st.write_pos == 5          # prompt_len + n_generated - 1
    assert not st.finished()
    st.out += [43, 44]
    assert st.finished()              # max_new_tokens reached
    sched.evict(0)
    assert not sched.has_work()
    # EOS completion
    r2 = Request(rid=1, prompt=np.arange(4), max_new_tokens=10, eos_id=9)
    st2 = sched.admit(1, r2)
    st2.prefilled = True
    st2.out.append(9)
    assert st2.finished()
    with pytest.raises(ValueError):
        Request(rid=2, prompt=np.array([], np.int32))
    with pytest.raises(ValueError):
        Request(rid=3, prompt=np.arange(3), max_new_tokens=0)


def test_engine_rejects_oversized_request_and_tiny_pool():
    # rejections are *typed outcomes*, not exceptions: submit never
    # raises, never reserves pages, and records the reason
    cfg, params = _mixed(16, "packed")
    p16 = _prompts(cfg.vocab, 1, 16)
    eng = Engine(params, cfg, n_slots=1, page_size=8, max_seq=24)
    out = eng.submit(Request(rid=0, prompt=p16[0], max_new_tokens=100))
    assert out is Outcome.REJECTED_TOO_LARGE
    assert eng.results[0].outcome is Outcome.REJECTED_TOO_LARGE
    assert "max_seq" in eng.results[0].detail
    assert eng.pool.used_pages == 0 and not eng.sched.has_work()
    # a request that fits max_seq but can never fit the pool must be
    # rejected up front (it would otherwise preempt-cycle forever)
    eng2 = Engine(params, cfg, n_slots=1, page_size=8, max_seq=24,
                  n_pages=2)
    out2 = eng2.submit(Request(rid=0, prompt=p16[0], max_new_tokens=8))
    assert out2 is Outcome.REJECTED_TOO_LARGE
    assert "pool" in eng2.results[0].detail
    # pool smaller than one prompt: same typed rejection, not a hang,
    # and run() completes returning no streams
    eng3 = Engine(params, cfg, n_slots=1, page_size=8, max_seq=24,
                  n_pages=1)
    outs = eng3.run([Request(rid=0, prompt=p16[0], max_new_tokens=2)])
    assert outs == {}
    assert eng3.results[0].outcome is Outcome.REJECTED_TOO_LARGE
    assert eng3.stats.rejected == 1


def test_engine_backpressure_and_cancel():
    cfg, params = _mixed(16, "packed")
    prompts = _prompts(cfg.vocab, 5, 8)
    eng = Engine(params, cfg, n_slots=1, page_size=8, max_seq=16,
                 queue_limit=2)
    reqs = [Request(rid=r, prompt=prompts[r], max_new_tokens=4)
            for r in range(5)]
    outcomes = [eng.submit(r) for r in reqs]
    # slot admission happens inside step(), so the limit bounds the
    # whole backlog: 2 queued, 3 shed with a typed outcome
    assert outcomes[:2] == [None, None]
    assert all(o is Outcome.REJECTED_BACKPRESSURE for o in outcomes[2:])
    # cancel one queued request before it ever runs
    assert eng.cancel(1)
    assert eng.results[1].outcome is Outcome.CANCELLED
    assert not eng.cancel(99)          # unknown rid
    outs = eng.run()
    assert sorted(outs) == [0]
    assert eng.results[0].outcome is Outcome.FINISHED
    assert eng.stats.cancelled == 1 and eng.stats.rejected == 3
    # every submitted rid has exactly one typed outcome
    assert sorted(eng.results) == [0, 1, 2, 3, 4]


def test_engine_deadline_exceeded_typed():
    cfg, params = _mixed(16, "packed")
    prompts = _prompts(cfg.vocab, 2, 8)
    eng = Engine(params, cfg, n_slots=2, page_size=8, max_seq=64)
    eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=40,
                       deadline_steps=4))
    eng.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=4))
    outs = eng.run()
    # the tight-deadline request expires mid-stream with partial tokens
    # and freed pages; its neighbor finishes untouched
    assert sorted(outs) == [1]
    res = eng.results[0]
    assert res.outcome is Outcome.DEADLINE_EXCEEDED
    assert 0 < res.tokens.size < 40
    assert eng.results[1].outcome is Outcome.FINISHED
    assert eng.pool.used_pages == 0
    assert eng.stats.deadline_expired == 1


def test_engine_max_steps_returns_partials():
    cfg, params = _mixed(16, "packed")
    prompts = _prompts(cfg.vocab, 2, 8)
    eng = Engine(params, cfg, n_slots=2, page_size=8, max_seq=64)
    reqs = [Request(rid=r, prompt=prompts[r], max_new_tokens=30)
            for r in range(2)]
    outs = eng.run(reqs, max_steps=6)
    # overrun no longer throws away completed work: stragglers fail
    # typed with their partial prefix attached
    assert outs == {}                  # nothing finished in 6 steps
    for r in range(2):
        res = eng.results[r]
        assert res.outcome is Outcome.FAILED
        assert "max_steps" in res.detail
        assert res.tokens.size > 0
    assert not eng.sched.has_work() and eng.pool.used_pages == 0
