"""Golden-artifact regression: the committed fixtures from both prior
artifact generations (tests/fixtures/, scripts/make_golden_fixtures.py)
must keep loading and serving bit-exactly.

* ``pr2_mlp_only`` — PR-2-era serving: MLP-only coverage
  (``quant_names=MLP_LEGACY``) over a tied GQA stack at K=4;
* ``pr3_full``     — PR-3 full-model coverage over the mixed
  gqa+moe+ssm stack at K=16.

Two layers of protection: the stored golden logits are an *allclose*
drift guard (a format change that corrupts decode shows up immediately),
and the dense / uint8 / packed serving layouts of the loaded artifact
must stay **bitwise** identical (the differential invariant — run
through the same helpers as test_differential.py).
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from helpers import (MLP_LEGACY, assert_routes_agree, assert_trees_equal,
                     mixed_cfg, serving_layouts, tiny_cfg)
from repro.core import PackedModel
from repro.models.transformer import forward

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures")


def _load(name):
    d = os.path.join(FIXTURES, name)
    pm = PackedModel.load(d)
    g = np.load(os.path.join(d, "golden.npz"))
    return pm, jnp.asarray(g["tokens"]), g["logits"]


def test_pr2_mlp_only_artifact_load_decode_serve():
    pm, toks, golden = _load("pr2_mlp_only")
    cfg = tiny_cfg(tie=True)
    assert pm.k == 4
    dense = pm.decode()
    ld = forward(dense, cfg, toks)
    np.testing.assert_allclose(np.asarray(ld), golden, rtol=1e-5,
                               atol=1e-5)
    # PR-2-era coverage: MLP leaves quantized, everything else dense —
    # both quantized layouts serve bit-exactly vs the dense decode.
    for packed_flag in (False, True):
        sp = pm.serving_params(quant_names=MLP_LEGACY, packed=packed_flag)
        assert "embed_tok" in sp            # non-MLP leaves decoded dense
        assert_trees_equal(ld, forward(sp, cfg, toks),
                           context=f"packed={packed_flag}")


def test_pr3_full_coverage_artifact_load_decode_serve():
    pm, toks, golden = _load("pr3_full")
    cfg = mixed_cfg(tie=False)
    assert pm.k == 16
    dense = pm.decode()
    ld = forward(dense, cfg, toks)
    np.testing.assert_allclose(np.asarray(ld), golden, rtol=1e-5,
                               atol=1e-5)
    # full-model coverage across all three layouts, forward + prefill +
    # decode — logits and caches bitwise
    layouts = serving_layouts(pm)
    assert "embed_tok_pidx" in layouts["packed"]
    assert layouts["packed"]["embed_tok_layout"].order == "row"
    assert_routes_agree(cfg, layouts, toks, decode_steps=2)


def test_packed_report_runs_on_fixture(capsys):
    """launch/report.py --packed must render the whole coverage table —
    including dense (policy-excluded) leaves, which carry route=None
    (regression: the B/weight+route columns once crashed on them)."""
    from repro.launch.report import packed_report
    packed_report(os.path.join(FIXTURES, "pr3_full"))
    out = capsys.readouterr().out
    assert "Leaf coverage" in out
    assert "qembed+qmatmul_t (pack_rows)" in out
    assert "policy exclude" in out              # dense rows rendered too


def test_fixture_manifests_are_version_1():
    """The on-disk format contract both generations share."""
    import json
    for name in ("pr2_mlp_only", "pr3_full"):
        with open(os.path.join(FIXTURES, name, "manifest.json")) as f:
            m = json.load(f)
        assert m["version"] == 1
        assert m["packed"] and "scheme" in m
