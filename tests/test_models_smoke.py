"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting shapes + no NaNs, plus exact decode-replay consistency
(teacher-forced decode == full forward) for every mixer family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduce_config
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, loss_fn, prefill)

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    if cfg.vlm_patches:
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.vlm_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_loss_grad(arch):
    cfg = reduce_config(get_config(arch))
    params = init_params(KEY, cfg)
    batch = _batch(cfg)
    logits = forward(params, cfg, batch["tokens"], batch.get("patch_embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits)))
    l, g = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(l))
    gn = sum(float(jnp.vdot(x, x)) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", list_archs())
def test_decode_replay_matches_forward(arch):
    """Feed tokens one-by-one through decode_step from an empty cache; the
    final-position logits must match the full forward (exact KV/state
    streaming equivalence — catches cache-layout and masking bugs)."""
    cfg = reduce_config(get_config(arch))
    if cfg.vlm_patches:
        cfg = cfg.__class__(**{**cfg.__dict__, "vlm_patches": 0})
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    ref_logits = forward(params, cfg, tokens)

    caches = init_cache(cfg, B, S)
    step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
    outs = []
    for t in range(S):
        logits_t, caches = step(caches, tokens[:, t:t + 1],
                                jnp.asarray(t, jnp.int32))
        outs.append(logits_t[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_logits),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma2-9b",
                                  "deepseek-v2-lite-16b"])
def test_prefill_then_decode(arch):
    """prefill(S-1 tokens) + decode_step(last) ≈ forward's last logits.
    (Exact for attention caches; SSM/RG-LRU conv tails are zeros after
    chunked prefill — covered exactly by the replay test above.)"""
    cfg = reduce_config(get_config(arch))
    if cfg.vlm_patches:
        return
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    ref_logits = forward(params, cfg, tokens)

    pre_logits, caches = prefill(params, cfg, tokens[:, :S - 1])
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(ref_logits[:, :S - 1]),
                               rtol=2e-2, atol=2e-2)
    # grow attention caches to capacity S
    def pad_to(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == S - 1:   # [G,B,S-1,...]
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, 1)
            return jnp.pad(leaf, pad)
        return leaf
    caches = jax.tree_util.tree_map(pad_to, caches)
    logits_t, _ = decode_step(params, cfg, caches, tokens[:, -1:],
                              jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_t[:, 0]),
                               np.asarray(ref_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)


def test_banded_local_attention_equals_masked_full():
    """The banded sliding-window path == full attention with window mask."""
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(3)
    b, s, h, hd, w = 1, 64, 2, 8, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    pos = jnp.arange(s)
    banded = chunked_attention(q, k, v, pos, pos, window=w,
                               q_chunk=16, kv_chunk=16)
    full = chunked_attention(q, k, v, pos, pos, window=w,
                             q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_moe_routing_conservation():
    """Every kept (token, slot) contributes with its router prob; dropped
    slots contribute zero — output norm bounded by input scale."""
    from repro.models.moe import apply_moe, init_moe
    p = init_moe(KEY, 16, 8, 4, 0, "silu")
    x = jax.random.normal(KEY, (2, 8, 16))
    y = apply_moe(p, x, top_k=2, capacity_factor=2.0)
    assert y.shape == x.shape
    assert not np.any(np.isnan(np.asarray(y)))
    # capacity 0 drop-all edge: capacity_factor tiny → finite output
    y0 = apply_moe(p, x, top_k=2, capacity=1)
    assert not np.any(np.isnan(np.asarray(y0)))


def test_triangular_attention_equals_scan():
    """attn_unroll (the §Perf triangular schedule) == the scan path."""
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(11)
    b, s, h, hd = 2, 128, 4, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, 2, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, hd))
    pos = jnp.arange(s)
    a1 = chunked_attention(q, k, v, pos, pos, q_chunk=32, kv_chunk=32)
    a2 = chunked_attention(q, k, v, pos, pos, q_chunk=32, kv_chunk=32,
                           causal_unroll=True)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=2e-4, atol=2e-5)
