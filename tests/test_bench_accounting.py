"""Serve-path byte-accounting invariant over the kernel-bench output.

Runs ``benchmarks.run --only kernels --json`` end to end (in
``REPRO_BENCH_FAST=1`` mode — one timing iteration; the *derived*
accounting strings are produced exactly as CI's BENCH_kernels.json) and
asserts, for every packed-route row (``*_packed_*``, ``quantized_gather_*``,
``codebook_matmul_packed_t_*``), that the reported HBM index bytes per
weight equal ``bits_per_index(K)/8`` — the eq.-14 serving footprint.

This pins the PR-4 fix: gather rows used to report the *resident word
bytes per table weight* of the column-packed layout (and the jnp route's
gathered traffic was 4 B/weight); the row-packed serving layout reads
``bits/8`` per gathered weight and the bench must account for exactly
that.
"""
import json
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BPW_RE = re.compile(
    r"idx_bytes/weight=([0-9.]+) \(== bits_per_index/8 = ([0-9.]+)")


@pytest.fixture(scope="module")
def bench_json(tmp_path_factory):
    out = tmp_path_factory.mktemp("bench") / "BENCH_kernels.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_BENCH_FAST"] = "1"
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "kernels",
         "--json", str(out)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=1800)
    assert res.returncode == 0, res.stderr[-3000:]
    with open(out) as f:
        return json.load(f)


def test_every_packed_row_reports_bits_over_8(bench_json):
    packed_rows = {n: r for n, r in bench_json.items()
                   if "_packed_" in n or n.startswith("quantized_gather")}
    # the serve-path rows the bench must keep emitting
    for expect in ("codebook_matmul_packed_interp_K2",
                   "codebook_matmul_packed_interp_K16",
                   "codebook_matmul_packed_interp_K256",
                   "codebook_matmul_packed_t_K2",
                   "codebook_matmul_packed_t_K16",
                   "codebook_matmul_packed_t_K256",
                   "quantized_gather_mosaic_K2",
                   "quantized_gather_mosaic_K16",
                   "quantized_gather_mosaic_K256",
                   "quantized_gather_embed_K2",
                   "quantized_gather_embed_K16",
                   "quantized_gather_embed_K256"):
        assert expect in packed_rows, f"bench row {expect} disappeared"
    for name, row in packed_rows.items():
        derived = row["derived"]
        assert "MISMATCH" not in derived, f"{name}: {derived}"
        m = _BPW_RE.search(derived)
        assert m, f"{name}: no idx_bytes/weight accounting in {derived!r}"
        actual, expect = float(m.group(1)), float(m.group(2))
        assert actual == pytest.approx(expect, abs=1e-9), \
            f"{name}: {actual} B/weight != bits/8 = {expect}"
        # bits/8 for K ≤ 256 is one of the serve-path widths
        assert expect in (0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)


def test_uint8_oracle_rows_report_one_byte(bench_json):
    for name, row in bench_json.items():
        if "uint8" in name:
            assert "idx_bytes/weight=1.0" in row["derived"]
