"""Serve-path byte-accounting invariant over the kernel-bench output.

Runs ``benchmarks.run --only kernels --json`` end to end (in
``REPRO_BENCH_FAST=1`` mode — one timing iteration; the *derived*
accounting strings are produced exactly as CI's BENCH_kernels.json) and
asserts, for every packed-route row (``*_packed_*``, ``quantized_gather_*``,
``codebook_matmul_packed_t_*``), that the reported HBM index bytes per
weight equal ``bits_per_index(K)/8`` — the eq.-14 serving footprint.

This pins the PR-4 fix: gather rows used to report the *resident word
bytes per table weight* of the column-packed layout (and the jnp route's
gathered traffic was 4 B/weight); the row-packed serving layout reads
``bits/8`` per gathered weight and the bench must account for exactly
that.
"""
import json
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BPW_RE = re.compile(
    r"idx_bytes/weight=([0-9.]+) \(== bits_per_index/8 = ([0-9.]+)")


@pytest.fixture(scope="module")
def bench_json(tmp_path_factory):
    out = tmp_path_factory.mktemp("bench") / "BENCH_kernels.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_BENCH_FAST"] = "1"
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only",
         "kernels,engine", "--json", str(out)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=1800)
    assert res.returncode == 0, res.stderr[-3000:]
    with open(out) as f:
        return json.load(f)


def test_every_packed_row_reports_bits_over_8(bench_json):
    packed_rows = {n: r for n, r in bench_json.items()
                   if "_packed_" in n or n.startswith("quantized_gather")}
    # the serve-path rows the bench must keep emitting
    for expect in ("codebook_matmul_packed_interp_K2",
                   "codebook_matmul_packed_interp_K16",
                   "codebook_matmul_packed_interp_K256",
                   "codebook_matmul_packed_t_K2",
                   "codebook_matmul_packed_t_K16",
                   "codebook_matmul_packed_t_K256",
                   "quantized_gather_mosaic_K2",
                   "quantized_gather_mosaic_K16",
                   "quantized_gather_mosaic_K256",
                   "quantized_gather_embed_K2",
                   "quantized_gather_embed_K16",
                   "quantized_gather_embed_K256"):
        assert expect in packed_rows, f"bench row {expect} disappeared"
    for name, row in packed_rows.items():
        derived = row["derived"]
        assert "MISMATCH" not in derived, f"{name}: {derived}"
        m = _BPW_RE.search(derived)
        assert m, f"{name}: no idx_bytes/weight accounting in {derived!r}"
        actual, expect = float(m.group(1)), float(m.group(2))
        assert actual == pytest.approx(expect, abs=1e-9), \
            f"{name}: {actual} B/weight != bits/8 = {expect}"
        # bits/8 for K ≤ 256 is one of the serve-path widths
        assert expect in (0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)


def test_uint8_oracle_rows_report_one_byte(bench_json):
    for name, row in bench_json.items():
        if "uint8" in name:
            assert "idx_bytes/weight=1.0" in row["derived"]


_KVT_RE = re.compile(
    r"kv_bytes/token=([0-9.]+) \(== kv_bits/8\*head_dim\*n_kv = "
    r"([0-9.]+)[^;]*; kv_bits=(\d+) head_dim=(\d+) n_kv=(\d+)")


def test_paged_attention_rows_report_kv_bytes_per_token(bench_json):
    """Every ``paged_attention_*`` row's KV traffic accounting (measured
    from the materialized pool arrays) must equal kv_bits/8 · head_dim ·
    n_kv — eq. 14 extended to activation bytes (dense rows state the
    same identity at kv_bits=32)."""
    rows = {n: r for n, r in bench_json.items()
            if n.startswith("paged_attention_")}
    for expect in ("paged_attention_gqa_ref_dense",
                   "paged_attention_gqa_interp_dense",
                   "paged_attention_gqa_ref_kvq4",
                   "paged_attention_gqa_interp_kvq2",
                   "paged_attention_gqa_interp_kvq4",
                   "paged_attention_gqa_interp_kvq8",
                   "paged_attention_mla_interp_dense",
                   "paged_attention_mla_interp_kvq4"):
        assert expect in rows, f"bench row {expect} disappeared"
    for name, row in rows.items():
        derived = row["derived"]
        assert "MISMATCH" not in derived, f"{name}: {derived}"
        m = _KVT_RE.search(derived)
        assert m, f"{name}: no kv_bytes/token accounting in {derived!r}"
        actual, stated = float(m.group(1)), float(m.group(2))
        bits, hd, nkv = (int(m.group(i)) for i in (3, 4, 5))
        assert actual == pytest.approx(stated, abs=1e-9), \
            f"{name}: {actual} != stated {stated}"
        assert actual == pytest.approx(bits / 8 * hd * nkv, abs=1e-9), \
            f"{name}: {actual} B/token != {bits}/8*{hd}*{nkv}"
        assert "tile=" in derived, f"{name}: no committed token tile"
    # the standalone page-gather kernel rides with its own rows
    assert any(n.startswith("page_gather") for n in bench_json)


_TPS_RE = re.compile(
    r"tok/s=([0-9.]+) one_shot=([0-9.]+) \(x([0-9.]+)\); "
    r"occupancy=([0-9.]+) page_util=([0-9.]+) peak=([0-9.]+)")


def test_engine_throughput_rows(bench_json):
    """The continuous-batching bench must emit its dense + packed cells
    with tokens/s, slot occupancy and page-pool utilization, and state
    the equal-HBM budget it compared under."""
    for expect in ("engine_throughput_dense",
                   "engine_throughput_K2_packed",
                   "engine_throughput_K16_packed",
                   "engine_throughput_faulted"):
        assert expect in bench_json, f"bench row {expect} disappeared"
        derived = bench_json[expect]["derived"]
        m = _TPS_RE.search(derived)
        assert m, f"{expect}: no throughput accounting in {derived!r}"
        tps, one_shot, ratio, occ, util, peak = map(float, m.groups())
        assert tps > 0 and one_shot > 0
        assert ratio == pytest.approx(tps / one_shot, rel=0.05)
        assert 0 < occ <= 1 and 0 <= util <= 1 and 0 < peak <= 1
        assert "equal-HBM" in derived
        if "packed" in expect:
            assert "B/weight idx" in derived
        if "faulted" in expect:
            # the fault-tolerance cost row must state its injected rate
            # and what the supervisor did
            assert "faults=" in derived and "restarts" in derived


def test_engine_long_prompt_prefill_flat(bench_json):
    """The blockwise-prefill scaling row: per-chunk latency and the
    analytic per-chunk kernel VMEM must be ~flat in prompt length (the
    pre-fix engine re-ran the whole prompt at commit — per-"chunk" cost
    and peak activation footprint scaled linearly with S)."""
    from repro.analysis.vmem import estimate_prefill_vmem_bytes

    name = "engine_prefill_long_prompt"
    assert name in bench_json, f"bench row {name} disappeared"
    derived = bench_json[name]["derived"]
    cells = re.findall(r"S=(\d+)->(\d+) \((\d+) chunks\)", derived)
    assert len(cells) >= 2, derived
    (s0, us0, c0), (s1, us1, c1) = cells[0], cells[-1]
    assert int(s1) > int(s0) and int(c1) > int(c0)
    # flat-in-S: a full-prompt recompute would scale per-chunk latency
    # ~linearly (x4 at the non-FAST S ratio); allow generous CI noise
    assert float(us1) / max(float(us0), 1.0) < 2.0, derived
    m = re.search(r"vmem/chunk=(\d+) B \(dense tile=(\d+), flat in S\)",
                  derived)
    assert m, derived
    assert int(m.group(1)) == estimate_prefill_vmem_bytes(
        "dense", 12, int(m.group(2)))
    assert "no step forwards more than 16 prompt tokens" in derived


def test_engine_stats_generated_tokens_identity():
    """``generated_tokens`` counts tokens actually *sampled* (decode
    steps + the one token each completed prefill emits) and equals the
    delivered output length in a clean run.  The pre-fix stats added
    full ``prefill_tokens`` to the decode count, so throughput rows
    over-reported generation by ~prompt_len per request."""
    import jax
    import numpy as np
    from helpers import mixed_cfg
    from repro.engine import Engine, Request
    from repro.models.transformer import init_params

    cfg = mixed_cfg(tie=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (3, 20), 0, cfg.vocab))
    reqs = [Request(rid=r, prompt=prompts[r], max_new_tokens=4 + r)
            for r in range(3)]
    eng = Engine(params, cfg, n_slots=2, page_size=8, max_seq=32,
                 prefill_chunk=8, token_budget=10)
    outs = eng.run(reqs)
    st = eng.stats
    delivered = sum(len(v) for v in outs.values())
    assert st.prefill_samples == 3
    assert st.prefill_tokens == 3 * 20          # computed prompt tokens
    assert st.prefill_calls == 3 * 3            # ceil(20/8) blocks each
    assert st.generated_tokens == st.decode_tokens + st.prefill_samples
    assert st.generated_tokens == delivered, \
        (st.generated_tokens, delivered)


_KVQ_RE = re.compile(
    r"tok/s=([0-9.]+) dense=([0-9.]+) \(x([0-9.]+)\); "
    r"occupancy=([0-9.]+) page_util=([0-9.]+) peak=([0-9.]+); "
    r"equal-HBM: kv_bits=(\d+) slots=(\d+)/(\d+) \(x([0-9.]+) capacity"
    r"[^)]*\) page_bytes=(\d+) dense=(\d+)")


def test_engine_kvq_rows(bench_json):
    """The quantized-KV engine cells must state the equal-HBM slot
    capacity at each width, with page bytes matching
    ``engine.kvcache.kv_page_footprint`` — and 4-bit KV must afford
    ≥1.5× the dense baseline's concurrent slots (the PR's acceptance
    bar; kvq8's codebook overhead may honestly show no gain)."""
    from repro.engine.kvcache import kv_page_footprint

    for bits in (2, 4, 8):
        name = f"engine_throughput_kvq{bits}"
        assert name in bench_json, f"bench row {name} disappeared"
        derived = bench_json[name]["derived"]
        m = _KVQ_RE.search(derived)
        assert m, f"{name}: no equal-HBM accounting in {derived!r}"
        (tps, dense_tps, ratio, occ, util, peak) = map(
            float, m.groups()[:6])
        kv_bits, slots, dense_slots = (int(m.group(i)) for i in (7, 8, 9))
        cap_ratio = float(m.group(10))
        page_b, dense_b = int(m.group(11)), int(m.group(12))
        assert kv_bits == bits
        assert tps > 0 and dense_tps > 0
        assert ratio == pytest.approx(tps / dense_tps, rel=0.05)
        assert 0 < occ <= 1 and 0 <= util <= 1 and 0 < peak <= 1
        assert cap_ratio == pytest.approx(slots / dense_slots, abs=0.01)
        # page bytes re-derived independently (bench cfg geometry:
        # page_size=8, n_kv=2, head_dim=12)
        assert page_b == kv_page_footprint(8, 2, 12, bits, "page")
        assert dense_b == kv_page_footprint(8, 2, 12, 0)
        assert slots == max(dense_slots,
                            dense_slots * dense_b // page_b)
        if bits == 4:
            assert slots / dense_slots >= 1.5, \
                f"4-bit KV affords only {slots}/{dense_slots} slots"
