"""Distributed semantics, run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests in this process
keep seeing 1 device, per the dry-run isolation rule).

Covers: shard_map'd k-means == single-device k-means; histogram
ternary-scale == exact sort solution; int8-compressed psum accuracy;
elastic checkpoint reshard (save on 8-dev mesh, load on 4); sharding-rule
divisibility validation."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str) -> dict:
    """Run ``body`` in a subprocess with 8 host devices; it must print a
    JSON dict on the last line."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_kmeans_equals_single_device():
    res = run_sub("""
        from repro.dist.cstep import sharded_kmeans
        from repro.core.kmeans import kmeans_fit, quantile_init
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        w = jax.random.normal(jax.random.PRNGKey(0), (4096,))
        cb0 = quantile_init(w, 4)
        cb_d, assign_d, dist_d = sharded_kmeans(w, cb0, mesh, iters=20,
                                                axis="model")
        res_s = kmeans_fit(w, cb0, iters=20)
        print(json.dumps({
            "cb_close": bool(np.allclose(np.asarray(cb_d),
                                         np.asarray(res_s.codebook),
                                         rtol=1e-5, atol=1e-6)),
            "dist_close": bool(np.isclose(float(dist_d),
                                          float(res_s.distortion),
                                          rtol=1e-5)),
        }))
    """)
    assert res["cb_close"] and res["dist_close"]


def test_histogram_warm_start_first_adaptive_cstep_1dev():
    """sharded_c_step(codebook=None) — the first-C-step histogram-quantile
    warm start (ROADMAP distributed item).  On a 1-device mesh it must
    equal the identical local pipeline (histogram-quantile init + k-means,
    psum over one shard is the identity) bit-for-bit, and land on the
    same solution as the local k-means++-init first C step."""
    res = run_sub("""
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.cstep import histogram_quantiles, sharded_c_step
        from repro.core.kmeans import kmeans_fit, kmeans_plus_plus_init
        from repro.core.schemes import make_scheme
        scheme = make_scheme("adaptive:4")
        mesh = jax.make_mesh((1,), ("model",))
        w = jax.random.normal(jax.random.PRNGKey(0), (8192,))
        @partial(shard_map, mesh=mesh, in_specs=(P("model"),),
                 out_specs=(P("model"), P()), check_rep=False)
        def first_c(ws):
            q, th = sharded_c_step(scheme, ws, "model")   # no codebook
            return q, th["codebook"]
        q_d, cb_d = first_c(w)
        # identical local pipeline (axis_name=None): exact equality
        cb0 = histogram_quantiles(w, 4, None)
        res_l = kmeans_fit(w, cb0, iters=scheme.iters_first)
        q_l = res_l.codebook[res_l.assignments]
        # local k-means++ init first C step: same converged solution
        cbpp = kmeans_plus_plus_init(jax.random.PRNGKey(1), w, 4)
        res_pp = kmeans_fit(w, cbpp, iters=scheme.iters_first)
        print(json.dumps({
            "q_equal": bool(np.array_equal(np.asarray(q_d),
                                           np.asarray(q_l))),
            "cb_equal": bool(np.array_equal(np.asarray(cb_d),
                                            np.asarray(res_l.codebook))),
            "cb_vs_pp": bool(np.allclose(np.asarray(cb_d),
                                         np.asarray(res_pp.codebook),
                                         atol=5e-2)),
            "dist_vs_pp": abs(float(res_l.distortion)
                              - float(res_pp.distortion))
                          / float(res_pp.distortion),
        }))
    """)
    assert res["q_equal"] and res["cb_equal"]
    assert res["cb_vs_pp"]
    assert res["dist_vs_pp"] < 2e-2


def test_histogram_ternary_scale_matches_exact():
    res = run_sub("""
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.cstep import ternary_scale_histogram
        from repro.core.quant_ops import ternarize_scale
        mesh = jax.make_mesh((8,), ("model",))
        w = jax.random.normal(jax.random.PRNGKey(1), (8192,))
        @partial(shard_map, mesh=mesh, in_specs=P("model"),
                 out_specs=P(None), check_rep=False)
        def dist_scale(ws):
            return ternary_scale_histogram(ws, "model")[None]
        a_d = float(dist_scale(w)[0])
        _, a_exact = ternarize_scale(w)
        print(json.dumps({"a_d": a_d, "a_exact": float(a_exact)}))
    """)
    assert res["a_d"] == pytest.approx(res["a_exact"], rel=2e-3)


def test_compressed_psum_accuracy():
    res = run_sub("""
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.cstep import compressed_psum
        mesh = jax.make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(2), (8, 4096)) \
            * jnp.logspace(-2, 0, 8)[:, None]   # heterogeneous scales
        @partial(shard_map, mesh=mesh, in_specs=P("pod", None),
                 out_specs=P("pod", None), check_rep=False)
        def comp(x):
            return compressed_psum(x[0], "pod")[None]
        @partial(shard_map, mesh=mesh, in_specs=P("pod", None),
                 out_specs=P("pod", None), check_rep=False)
        def exact(x):
            return jax.lax.psum(x[0], "pod")[None]
        c = np.asarray(comp(g))[0]
        e = np.asarray(exact(g))[0]
        rel = float(np.linalg.norm(c - e) / np.linalg.norm(e))
        print(json.dumps({"rel_err": rel}))
    """)
    assert res["rel_err"] < 0.02


def test_elastic_checkpoint_reshard():
    res = run_sub("""
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt
        tmp = tempfile.mkdtemp()
        mesh8 = jax.make_mesh((2, 4), ("data", "model"))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh8, P(None, "model")))
        ckpt.save_checkpoint(tmp, 1, {"w": xs})
        mesh4 = jax.make_mesh((2, 2), ("data", "model"))
        sh = {"w": NamedSharding(mesh4, P("model", None))}
        out, _, _ = ckpt.restore_checkpoint(tmp, like={"w": x}, shardings=sh)
        ok = bool(np.allclose(np.asarray(out["w"]), np.asarray(x)))
        nshards = len(out["w"].sharding.device_set)
        print(json.dumps({"ok": ok, "nshards": nshards}))
    """)
    assert res["ok"] and res["nshards"] == 4


def test_param_sharding_rules_divisibility():
    res = run_sub("""
        from repro.dist.sharding import param_shardings
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = {
            "embed_tok": jnp.zeros((50281, 64)),      # 50281 % 4 != 0
            "stacks": ({"pos0": {"mixer": {"wq": jnp.zeros((2, 64, 64))},
                                 "mlp": {"w_in": jnp.zeros((2, 64, 128))}}},),
        }
        sh = param_shardings(params, mesh)
        emb = sh["embed_tok"].spec
        wq = sh["stacks"][0]["pos0"]["mixer"]["wq"].spec
        w_in = sh["stacks"][0]["pos0"]["mlp"]["w_in"].spec
        print(json.dumps({"emb": str(emb), "wq": str(wq),
                          "w_in": str(w_in)}))
    """)
    assert "model" not in res["emb"]               # dropped: not divisible
    assert res["wq"] == "PartitionSpec(None, None, 'model')"
    assert res["w_in"] == "PartitionSpec(None, None, 'model')"


def test_debug_mesh_dryrun_tiny():
    """End-to-end mini dry-run on an 8-device (2,2,2) multi-pod mesh:
    lower+compile the reduced qwen train step with production shardings."""
    res = run_sub("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduce_config
        from repro.dist import sharding as shard_rules
        from repro.launch.mesh import make_debug_mesh
        from repro.models import sharding_ctx
        from repro.models import transformer as tfm
        mesh = make_debug_mesh(2, 2, pods=2)
        cfg = reduce_config(get_config("qwen1.5-0.5b"))
        sharding_ctx.set_policy(sharding_ctx.Policy(mesh, mode="tp"))
        params_sh = jax.eval_shape(
            lambda k: tfm.init_params(k, cfg, jnp.bfloat16),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_shard = shard_rules.param_shardings(params_sh, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        b_shard = shard_rules.batch_shardings(batch, mesh)
        def loss(p, b):
            return tfm.loss_fn(p, cfg, b)
        with mesh:
            compiled = jax.jit(loss, in_shardings=(p_shard, b_shard),
                               out_shardings=NamedSharding(mesh, P())
                               ).lower(params_sh, batch).compile()
        mem = compiled.memory_analysis()
        # older jaxlibs lack peak_memory_in_bytes (dryrun.py guards it too)
        peak = getattr(mem, "peak_memory_in_bytes", None) or (
            mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes)
        print(json.dumps({"ok": True, "peak": int(peak)}))
    """)
    assert res["ok"] and res["peak"] > 0


def test_lc_c_step_sharded_equals_local_8dev():
    """ROADMAP distributed item: the plan-driven shard-local C step
    (repro.dist.cstep.lc_c_step_sharded) must walk the same (w_C, Θ)
    trajectory as repro.core.lc.c_step — adaptive k-means statistics are
    psum-exact, so grouped and flat leaves both match to fp tolerance."""
    res = run_sub("""
        from repro.core import lc as lc_mod
        from repro.core.schemes import make_scheme
        from repro.dist.cstep import lc_c_step_sharded
        mesh = jax.make_mesh((8,), ("model",))
        scheme = make_scheme("adaptive:4")
        key = jax.random.PRNGKey(0)
        params = {
            "w": jax.random.normal(key, (64, 64)),            # flat leaf
            "stack_w": jax.random.normal(key, (2, 32, 64)),   # grouped leaf
            "tail": jax.random.normal(key, (3, 19)),          # 57 % 8 != 0
        }
        qspec = lc_mod.default_qspec(params)
        cfg = lc_mod.LCConfig(mu0=1e-2, mu_growth=1.5)
        state = lc_mod.lc_init(key, params, scheme, qspec, cfg)
        loc = lc_mod.c_step(params, state, scheme, qspec, cfg)
        sh = lc_c_step_sharded(params, state, scheme=scheme, qspec=qspec,
                               config=cfg, mesh=mesh, axis="model")
        flat_ok = all(
            np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
            for a, b in zip(jax.tree_util.tree_leaves(loc.w_c),
                            jax.tree_util.tree_leaves(sh.w_c)))
        cb_ok = all(
            np.allclose(np.asarray(loc.theta[p]["codebook"]),
                        np.asarray(sh.theta[p]["codebook"]),
                        rtol=1e-5, atol=1e-6)
            for p in loc.theta)
        print(json.dumps({"w_c": flat_ok, "cb": cb_ok,
                          "mu": float(sh.mu) == float(loc.mu)}))
    """)
    assert res["w_c"] and res["cb"] and res["mu"]


def test_adaptive_zero_sharded_c_step_8dev():
    """PR-4 distributed item: adaptive_zero's pinned-zero centroid step
    has a sharded primitive (adaptive_zero_kmeans_psum) — the plan-driven
    shard-local C step walks the same (w_C, Θ) trajectory as the local
    solver on an 8-device mesh, the zero centroid stays pinned exactly,
    and the remaining fallback boundary is only divisibility (the 'tail'
    leaf, 57 % 8 != 0, takes the local path and still matches)."""
    res = run_sub("""
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import lc as lc_mod
        from repro.core.schemes import make_scheme
        from repro.dist.cstep import (histogram_quantiles, lc_c_step_sharded,
                                      sharded_c_step)
        mesh = jax.make_mesh((8,), ("model",))
        scheme = make_scheme("adaptive_zero:4")
        key = jax.random.PRNGKey(0)
        params = {
            "w": jax.random.normal(key, (64, 64)),            # divisible
            "tail": jax.random.normal(key, (3, 19)),          # 57 % 8 != 0
        }
        qspec = lc_mod.default_qspec(params)
        cfg = lc_mod.LCConfig(mu0=1e-2, mu_growth=1.5)
        state = lc_mod.lc_init(key, params, scheme, qspec, cfg)
        loc = lc_mod.c_step(params, state, scheme, qspec, cfg)
        sh = lc_c_step_sharded(params, state, scheme=scheme, qspec=qspec,
                               config=cfg, mesh=mesh, axis="model")
        w_ok = all(
            np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
            for a, b in zip(jax.tree_util.tree_leaves(loc.w_c),
                            jax.tree_util.tree_leaves(sh.w_c)))
        cb_ok = all(
            np.allclose(np.asarray(loc.theta[p]["codebook"]),
                        np.asarray(sh.theta[p]["codebook"]),
                        rtol=1e-5, atol=1e-6)
            for p in loc.theta)
        pinned = all(0.0 in np.asarray(sh.theta[p]["codebook"])
                     for p in sh.theta)
        # first-C-step path (codebook=None): histogram warm start + pin,
        # equal to the identical local pipeline on the same mesh
        w8 = jax.random.normal(jax.random.fold_in(key, 9), (8192,))
        @partial(shard_map, mesh=mesh, in_specs=(P("model"),),
                 out_specs=(P("model"), P()), check_rep=False)
        def first_c(ws):
            q, th = sharded_c_step(scheme, ws, "model")
            return q, th["codebook"]
        q_d, cb_d = first_c(w8)
        cb0 = histogram_quantiles(w8, 4, None)
        cb0 = jnp.sort(cb0.at[jnp.argmin(jnp.abs(cb0))].set(0.0))
        from repro.dist.cstep import adaptive_zero_kmeans_psum
        cb_l, q_l = adaptive_zero_kmeans_psum(w8, cb0, 4, None,
                                              scheme.iters_first)
        first_ok = bool(np.allclose(np.asarray(cb_d), np.asarray(cb_l),
                                    rtol=1e-5, atol=1e-6))
        first_pinned = bool((np.asarray(cb_d) == 0.0).any())
        print(json.dumps({"w_c": w_ok, "cb": cb_ok, "pinned": bool(pinned),
                          "first": first_ok,
                          "first_pinned": first_pinned}))
    """)
    assert res["w_c"] and res["cb"]
    assert res["pinned"], "zero centroid must stay exactly pinned"
    assert res["first"] and res["first_pinned"]


def test_lctrainer_sharded_c_step_plan_flag_1dev():
    """Smoke-test the plan flag end to end on a 1-device mesh (in-process:
    jax sees one CPU device here): CompressionPlan(sharded_c_step=True) →
    LCTrainer.from_plan(..., mesh=...) runs, and its LC trajectory matches
    the local-C-step trainer on the same data."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import CompressionPlan, LCConfig
    from repro.train.trainer import LCTrainer, TrainerConfig

    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (8, 8))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (64, 8))
    ys = xs @ w_true

    def loss(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2)

    def batches():
        while True:
            yield (xs, ys)

    params = {"w": jax.random.normal(jax.random.fold_in(key, 2), (8, 8))}
    tc = TrainerConfig(lr=0.05, steps_per_l=5)
    lc = LCConfig(mu0=1e-2, mu_growth=1.5, num_lc_iters=3)
    mesh = jax.make_mesh((1,), ("model",))

    plan_sh = CompressionPlan.parse("adaptive:4", lc=lc,
                                    sharded_c_step=True,
                                    init_method="quantile")
    plan_loc = CompressionPlan.parse("adaptive:4", lc=lc,
                                     init_method="quantile")
    tr_sh = LCTrainer.from_plan(loss, plan_sh, params, tc, mesh=mesh)
    tr_loc = LCTrainer.from_plan(loss, plan_loc, params, tc)
    st_sh = tr_sh.run(tr_sh.init(key, params), batches())
    st_loc = tr_loc.run(tr_loc.init(key, params), batches())

    q_sh = tr_sh.finalize(st_sh)
    q_loc = tr_loc.finalize(st_loc)
    np.testing.assert_allclose(np.asarray(q_sh["w"]), np.asarray(q_loc["w"]),
                               rtol=1e-5, atol=1e-6)
    cb_sh = st_sh.lc_state.theta["['w']"]["codebook"]
    cb_loc = st_loc.lc_state.theta["['w']"]["codebook"]
    np.testing.assert_allclose(np.asarray(cb_sh), np.asarray(cb_loc),
                               rtol=1e-5, atol=1e-6)


def test_engine_paged_cache_sharding_rules():
    """Page pools replicate the page axis over data (any slot's table
    entry may point at any physical page) and shard the kv-head axis
    over ``model``; per-slot state shards the slot axis over data like a
    decode batch.  A fused engine decode step must run under these
    placements on a 2×4 mesh without resharding errors."""
    res = run_sub("""
        from repro.dist.sharding import engine_cache_shardings, param_shardings
        from repro.models.transformer import (LayerKind, ModelConfig,
                                              StackSpec, decode_step_slots,
                                              init_paged_cache, init_params)
        cfg = ModelConfig(
            name="tiny", family="dense", d_model=32, n_heads=8, n_kv=4,
            head_dim=4, d_ff=64, vocab=96,
            stacks=(StackSpec(pattern=(LayerKind("gqa", "dense"),),
                              groups=2),),
            tie_embeddings=True, q_chunk=8, kv_chunk=8, remat=False)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        n_slots, n_pages, page = 4, 8, 4
        caches = init_paged_cache(cfg, n_slots, n_pages, page)
        sh = engine_cache_shardings(caches, mesh, n_slots=n_slots,
                                    n_pages=n_pages)
        pool_sh = sh[0]["pos0"].k        # [G, n_pages+1, page, kv, hd]
        pool_spec = tuple(pool_sh.spec)
        # the ambiguous oversubscribed case (n_pages + 1 == n_slots):
        # pool pages must still replicate, never data-shard
        amb = init_paged_cache(cfg, 4, 3, page)
        amb_sh = engine_cache_shardings(amb, mesh, n_slots=4, n_pages=3)
        amb_spec = tuple(amb_sh[0]["pos0"].k.spec)
        params = init_params(jax.random.PRNGKey(0), cfg)
        params = jax.tree_util.tree_map(jax.device_put, params,
                                        param_shardings(params, mesh))
        caches = jax.tree_util.tree_map(jax.device_put, caches, sh)
        pt = jnp.zeros((n_slots, 2), jnp.int32).at[:, 0].set(
            jnp.arange(1, n_slots + 1))
        toks = jnp.zeros((n_slots, 1), jnp.int32)
        pos = jnp.zeros((n_slots,), jnp.int32)
        alive = jnp.ones((n_slots,), bool)
        with mesh:
            logits, _ = jax.jit(decode_step_slots, static_argnums=1)(
                params, cfg, caches, pt, toks, pos, alive)
        print(json.dumps({
            "pool_spec": [str(s) for s in pool_spec],
            "pool_model_axis": pool_spec[3] == "model",
            "pool_pages_replicated": pool_spec[1] is None,
            "ambiguous_pool_pages_replicated": amb_spec[1] is None,
            "logits_ok": bool(np.isfinite(np.asarray(logits)).all()),
        }))
    """)
    assert res["pool_model_axis"], res
    assert res["pool_pages_replicated"], res
    assert res["ambiguous_pool_pages_replicated"], res
    assert res["logits_ok"], res


def test_moe_ep_shard_map_equals_vmap():
    """Rank-local EP dispatch (shard_map) == the local vmap path."""
    res = run_sub("""
        from repro.models.moe import apply_moe, init_moe
        from repro.models import sharding_ctx
        key = jax.random.PRNGKey(0)
        p = init_moe(key, 16, 8, 8, 1, "silu")
        x = jax.random.normal(key, (4, 16, 16))
        y_ref = apply_moe(p, x, top_k=2, capacity_factor=4.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sharding_ctx.set_policy(sharding_ctx.Policy(mesh, mode="tp"))
        with mesh:
            y_ep = jax.jit(lambda p, x: apply_moe(p, x, top_k=2,
                                                  capacity_factor=4.0))(p, x)
        ok = bool(np.allclose(np.asarray(y_ref), np.asarray(y_ep),
                              rtol=2e-4, atol=2e-5))
        print(json.dumps({"ok": ok}))
    """)
    assert res["ok"]
