"""Differential acceptance suite for the paged-attention kernel family
and the codebook-quantized KV cache.

Layered oracles, each proved against the one below it:

1. ``core.kvquant`` primitives — jit-side ``pack_rows_jnp`` is
   bit-identical to the host packer and round-trips; first-write fits
   with K ≥ N values are lossless;
2. quant refs == dense refs **bit-exactly** when the dense ref runs on
   the dequantized pools ({gqa, mla}, page- and head-grouped codebooks)
   — quantization and attention commute by construction;
3. Pallas kernels (interpret mode; ``-m tpu`` variants compile the
   Mosaic lowering) ≈ the jnp refs for dense and quantized pages;
4. one decode step over quantized pages stays within the codebook
   distortion bound of the dense step on the original values (tighter
   as kv_bits grows);
5. the engine: ``kv_bits=0`` streams are **bit-exact** to the one-shot
   oracle across {gqa-mixed, mla} × weight-packing K ∈ {2, 16} (the
   dispatch rerouting changed no numerics); quantized engines are
   deterministic across reruns *and* slot counts, with every request
   typed FINISHED.

Plus the dead-slot regression: ``_gather_slots``/``page_gather`` mask
the page table with ``alive`` so a freed slot's stale table entries
never gather live pages (pre-PR they materialized whatever the dead
table pointed at).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import mixed_cfg, pack_model
from repro.core import compression, kvquant
from repro.engine import Engine, Request, greedy_generate, truncate_at_eos
from repro.kernels import dispatch, ops, ref
from repro.models import attention as attn

# ---------------------------------------------------------------------------
# shared kernel-level fixture: 3 slots (one dead), 6 usable pages
# ---------------------------------------------------------------------------

B, H, KV, HD, PAGE, NPG = 3, 4, 2, 8, 4, 2
NP_POOL = B * NPG                       # physical pages 1..6; 0 = trash
LAT, RD = 16, 8                         # MLA latent + rope dims
TBL = np.array([[1, 2], [3, 0], [4, 5]], np.int32)
POS = np.array([5, 2, 3], np.int32)
ALIVE = np.array([True, True, False])
SCALE = HD ** -0.5


def _rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape),
                       jnp.float32)


@functools.lru_cache(maxsize=None)
def _gqa_case():
    kp = _rand((NP_POOL + 1, PAGE, KV, HD), 0)
    vp = _rand((NP_POOL + 1, PAGE, KV, HD), 1)
    q = _rand((B, 1, H, HD), 2)
    return q, kp, vp, jnp.asarray(TBL), jnp.asarray(POS), jnp.asarray(ALIVE)


@functools.lru_cache(maxsize=None)
def _mla_case():
    cp = _rand((NP_POOL + 1, PAGE, LAT), 3)
    rp = _rand((NP_POOL + 1, PAGE, RD), 4)
    qe = _rand((B, 1, H, LAT), 5)
    qr = _rand((B, 1, H, RD), 6)
    return qe, qr, cp, rp, jnp.asarray(TBL), jnp.asarray(POS), \
        jnp.asarray(ALIVE)


def _quant_pool(pool, bits, mode="page"):
    """(words, cbs, dequantized_pool) for a dense page pool."""
    if pool.ndim == 4 and mode == "head":
        pp1, page, kvh, hd = pool.shape
        grp = jnp.transpose(pool, (0, 2, 1, 3)).reshape(pp1, kvh,
                                                        page * hd)
        cbs = kvquant.fit_codebooks(grp, bits)
        idx = kvquant.assign_codebook(grp, cbs)
        deq = jnp.transpose(
            kvquant.dequant_codebook(idx, cbs).reshape(pp1, kvh, page, hd),
            (0, 2, 1, 3))
        idx = jnp.transpose(idx.reshape(pp1, kvh, page, hd), (0, 2, 1, 3))
    else:
        grp = pool.reshape(pool.shape[0], 1, -1)
        cbs = kvquant.fit_codebooks(grp, bits)
        idx = kvquant.assign_codebook(grp, cbs)
        deq = kvquant.dequant_codebook(idx, cbs).reshape(pool.shape)
        idx = idx.reshape(pool.shape)
    return kvquant.pack_rows_jnp(idx, bits), cbs, deq


# ---------------------------------------------------------------------------
# 1. kvquant primitives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", kvquant.KV_BITS_CHOICES)
def test_pack_rows_jnp_matches_host_packer_and_roundtrips(bits):
    k = kvquant.kv_entries(bits)
    idx = np.random.RandomState(bits).randint(0, k, size=(7, 13))
    jit_words = np.asarray(kvquant.pack_rows_jnp(jnp.asarray(idx), bits))
    host_words = compression.pack_rows(idx, k)
    np.testing.assert_array_equal(jit_words, host_words)
    back = compression.unpack_rows(host_words, 13, k)
    np.testing.assert_array_equal(back, idx)


@pytest.mark.parametrize("bits", kvquant.KV_BITS_CHOICES)
def test_first_write_fit_is_lossless_when_entries_cover_values(bits):
    """A page's freeze-on-first-write codebook is fit from ≤ K distinct
    values at decode-time first touch — each value becomes its own
    centroid, so the stored dequant is exact."""
    k = kvquant.kv_entries(bits)
    n = min(k, 9)
    vals = jnp.asarray(np.random.RandomState(1).randn(2, 1, n),
                       jnp.float32)
    cbs = kvquant.fit_codebooks(vals, bits)
    idx = kvquant.assign_codebook(vals, cbs)
    np.testing.assert_array_equal(
        np.asarray(kvquant.dequant_codebook(idx, cbs)), np.asarray(vals))


def test_kv_byte_accounting_identities():
    assert kvquant.kv_bytes_per_token(4, 128, 8) == 0.5 * 128 * 8
    dense = kvquant.dense_page_bytes(16, 128)
    for bits in kvquant.KV_BITS_CHOICES:
        q = kvquant.quant_page_bytes(16, 128, bits, 1)
        assert q < dense
    with pytest.raises(ValueError):
        kvquant.check_kv_bits(3)


# ---------------------------------------------------------------------------
# 2. quant refs == dense refs on the dequantized pools (bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", kvquant.KV_BITS_CHOICES)
@pytest.mark.parametrize("mode", ["page", "head"])
def test_gqa_quant_ref_is_dense_ref_on_dequantized_pool(bits, mode):
    q, kp, vp, tbl, pos, alive = _gqa_case()
    kw, kcb, kdeq = _quant_pool(kp, bits, mode)
    vw, vcb, vdeq = _quant_pool(vp, bits, mode)
    out_q = ref.paged_attention_quant_ref(
        q, kw, vw, kcb, vcb, tbl, pos, alive, bits=bits, head_dim=HD,
        softcap=None, scale=SCALE)
    out_d = ref.paged_attention_ref(q, kdeq, vdeq, tbl, pos, alive,
                                    softcap=None, scale=SCALE)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_d))


@pytest.mark.parametrize("bits", kvquant.KV_BITS_CHOICES)
def test_mla_quant_ref_is_dense_ref_on_dequantized_pool(bits):
    qe, qr, cp, rp, tbl, pos, alive = _mla_case()
    cw, ccb, cdeq = _quant_pool(cp, bits)
    rw, rcb, rdeq = _quant_pool(rp, bits)
    out_q = ref.mla_paged_attention_quant_ref(
        qe, qr, cw, rw, ccb, rcb, tbl, pos, alive, bits=bits,
        kv_lora=LAT, rope_dim=RD, scale=(LAT + RD) ** -0.5)
    out_d = ref.mla_paged_attention_ref(qe, qr, cdeq, rdeq, tbl, pos,
                                        alive, scale=(LAT + RD) ** -0.5)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_d))


# ---------------------------------------------------------------------------
# 3. Pallas kernels (interpret mode) vs refs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tile", [1, 2, 4])
def test_gqa_pallas_interpret_matches_ref(tile):
    q, kp, vp, tbl, pos, alive = _gqa_case()
    want = ref.paged_attention_ref(q, kp, vp, tbl, pos, alive,
                                   softcap=None, scale=SCALE)
    got = ops.paged_attention(q, kp, vp, tbl, pos, alive, softcap=None,
                              scale=SCALE, token_tile=tile, interpret=True)
    np.testing.assert_allclose(np.asarray(got)[ALIVE],
                               np.asarray(want)[ALIVE],
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bits", kvquant.KV_BITS_CHOICES)
def test_gqa_quant_pallas_interpret_matches_quant_ref(bits):
    q, kp, vp, tbl, pos, alive = _gqa_case()
    kw, kcb, _ = _quant_pool(kp, bits)
    vw, vcb, _ = _quant_pool(vp, bits)
    want = ref.paged_attention_quant_ref(
        q, kw, vw, kcb, vcb, tbl, pos, alive, bits=bits, head_dim=HD,
        softcap=None, scale=SCALE)
    got = ops.paged_attention_quant(
        q, kw, vw, kcb, vcb, tbl, pos, alive, bits=bits, head_dim=HD,
        softcap=None, scale=SCALE, token_tile=2, interpret=True)
    np.testing.assert_allclose(np.asarray(got)[ALIVE],
                               np.asarray(want)[ALIVE],
                               rtol=2e-5, atol=2e-5)


def test_mla_pallas_interpret_matches_ref_dense_and_quant():
    qe, qr, cp, rp, tbl, pos, alive = _mla_case()
    scale = (LAT + RD) ** -0.5
    want = ref.mla_paged_attention_ref(qe, qr, cp, rp, tbl, pos, alive,
                                       scale=scale)
    got = ops.mla_paged_attention(qe, qr, cp, rp, tbl, pos, alive,
                                  scale=scale, token_tile=2,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(got)[ALIVE],
                               np.asarray(want)[ALIVE],
                               rtol=2e-5, atol=2e-5)
    cw, ccb, _ = _quant_pool(cp, 4)
    rw, rcb, _ = _quant_pool(rp, 4)
    want = ref.mla_paged_attention_quant_ref(
        qe, qr, cw, rw, ccb, rcb, tbl, pos, alive, bits=4, kv_lora=LAT,
        rope_dim=RD, scale=scale)
    got = ops.mla_paged_attention_quant(
        qe, qr, cw, rw, ccb, rcb, tbl, pos, alive, bits=4, kv_lora=LAT,
        rope_dim=RD, scale=scale, token_tile=2, interpret=True)
    np.testing.assert_allclose(np.asarray(got)[ALIVE],
                               np.asarray(want)[ALIVE],
                               rtol=2e-5, atol=2e-5)


def test_page_gather_pallas_interpret_bit_exact():
    _, kp, _, tbl, _, alive = _gqa_case()
    want = ref.gather_pages_ref(kp, tbl, alive)
    got = ops.page_gather(kp, tbl, alive, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# dead-slot regression: stale page tables never gather live pages
# ---------------------------------------------------------------------------

def test_gather_masks_dead_slots_to_trash_page():
    _, kp, _, tbl, _, alive = _gqa_case()
    # pool with a recognizable trash page
    kp = kp.at[0].set(0.0)
    for route in (lambda: dispatch.page_gather(kp, tbl, alive,
                                               backend="ref"),
                  lambda: attn._gather_slots(kp, tbl, alive)):
        out = np.asarray(route()).reshape(B, NPG, PAGE, KV, HD)
        # dead slot 2's table points at live pages 4 and 5, but its
        # gathered view must be the trash page
        np.testing.assert_array_equal(out[2], 0.0)
        # alive slots still see exactly their tables' pages
        np.testing.assert_array_equal(out[0, 0], np.asarray(kp)[1])
        np.testing.assert_array_equal(out[1, 1], np.asarray(kp)[0])


# ---------------------------------------------------------------------------
# freeze-on-first-write storage semantics
# ---------------------------------------------------------------------------

def test_write_slot_quant_freezes_codebook_on_first_write():
    bits = 4
    cache = attn.init_quant_paged_kv_cache(NP_POOL, PAGE, KV, HD, bits,
                                           "page", jnp.float32)
    words, cbs = cache.k_words, cache.k_cb
    tbl = jnp.asarray(TBL)
    alive = jnp.asarray([True, True, True])
    v0 = _rand((B, KV, HD), 10)
    # first write lands at offset 0 → fits and freezes the codebook
    pos0 = jnp.asarray([0, 0, 0], jnp.int32)
    w1, c1 = attn._write_slot_quant(words, cbs, tbl, pos0, alive, v0,
                                    PAGE, bits, "page")
    # a later in-page write must reuse the frozen codebook verbatim
    v1 = _rand((B, KV, HD), 11)
    pos1 = jnp.asarray([1, 1, 1], jnp.int32)
    w2, c2 = attn._write_slot_quant(w1, c1, tbl, pos1, alive, v1,
                                    PAGE, bits, "page")
    phys = np.asarray(TBL)[np.arange(B), 0]
    np.testing.assert_array_equal(np.asarray(c2)[phys],
                                  np.asarray(c1)[phys])
    # storage is a pure function of the written values: replay the same
    # writes and the words/codebooks are bit-identical
    w1b, c1b = attn._write_slot_quant(words, cbs, tbl, pos0, alive, v0,
                                      PAGE, bits, "page")
    w2b, c2b = attn._write_slot_quant(w1b, c1b, tbl, pos1, alive, v1,
                                      PAGE, bits, "page")
    np.testing.assert_array_equal(np.asarray(w2), np.asarray(w2b))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(c2b))
    # and the stored rows dequantize to assign-then-lookup of the
    # written values (storage exactness)
    cb_p = np.asarray(c2)[phys]                      # [B, 1, K]
    for b in range(B):
        for off, v in ((0, v0), (1, v1)):
            row = compression.unpack_rows(
                np.asarray(w2)[phys[b], off], HD, 1 << bits)
            want_idx = np.asarray(kvquant.assign_codebook(
                np.asarray(v)[b].reshape(1, 1, -1),
                jnp.asarray(cb_p[b:b + 1]))).reshape(KV, HD)
            np.testing.assert_array_equal(row, want_idx)


# ---------------------------------------------------------------------------
# 4. decode-step distortion bound
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", kvquant.KV_BITS_CHOICES)
def test_decode_step_within_codebook_distortion_bound(bits):
    """Attention over quantized pages vs over the original dense values:
    the output error is bounded by the measured per-scalar codebook
    distortion ε (softmax weights are a convex combination; the value
    term contributes ≤ ε directly, the key term through the bounded
    logit shift).  The bound tightens as kv_bits grows."""
    q, kp, vp, tbl, pos, alive = _gqa_case()
    kw, kcb, kdeq = _quant_pool(kp, bits)
    vw, vcb, vdeq = _quant_pool(vp, bits)
    eps = max(float(jnp.max(jnp.abs(kdeq - kp))),
              float(jnp.max(jnp.abs(vdeq - vp))))
    out_q = np.asarray(ref.paged_attention_quant_ref(
        q, kw, vw, kcb, vcb, tbl, pos, alive, bits=bits, head_dim=HD,
        softcap=None, scale=SCALE))
    out_d = np.asarray(ref.paged_attention_ref(
        q, kp, vp, tbl, pos, alive, softcap=None, scale=SCALE))
    err = np.max(np.abs(out_q - out_d)[ALIVE])
    # ε + (logit-shift sensitivity): |Δlogit| ≤ scale·|q|₁·ε, and the
    # softmax's value spread is O(max|v|); a generous constant covers it
    qmax = float(jnp.max(jnp.abs(q)))
    vmax = float(jnp.max(jnp.abs(vp)))
    bound = eps + 2.0 * SCALE * qmax * HD * KV * eps * vmax
    assert err <= bound, (bits, err, eps, bound)


# ---------------------------------------------------------------------------
# 5. engine differential: {gqa-mixed, mla} × K ∈ {2, 16}
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _packed_arch(arch: str, k: int):
    if arch == "gqa-mixed":
        cfg = mixed_cfg(tie=True)
    else:
        from repro.configs import get_config, reduce_config
        cfg = reduce_config(get_config("deepseek-v2-lite-16b"))
    from repro.models.transformer import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, pack_model(params, k).serving_params(packed=True)


@functools.lru_cache(maxsize=None)
def _arch_prompts(vocab: int, n: int, length: int):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(7 + length), (n, length), 0, vocab))


def _reqs(cfg, n=3):
    prompts = _arch_prompts(cfg.vocab, n, 16)
    return [Request(rid=r, prompt=prompts[r],
                    max_new_tokens=[5, 3, 4][r % 3]) for r in range(n)]


def _oracle(params, cfg, reqs):
    prompts = np.stack([r.prompt for r in reqs])
    gen = max(r.max_new_tokens for r in reqs)
    toks = np.asarray(greedy_generate(params, cfg,
                                      jnp.asarray(prompts), gen)[0])
    return {r.rid: truncate_at_eos(toks[i][:r.max_new_tokens], r.eos_id)
            for i, r in enumerate(reqs)}


@pytest.mark.parametrize("arch", ["gqa-mixed", "mla"])
@pytest.mark.parametrize("k", [2, 16])
def test_engine_dense_kv_bit_exact_and_quant_kv_deterministic(arch, k):
    cfg, sp = _packed_arch(arch, k)
    reqs = _reqs(cfg)
    want = _oracle(sp, cfg, reqs)

    # kv_bits=0: the dispatch rerouting must not change a single token
    outs = Engine(sp, cfg, n_slots=2, page_size=8,
                  max_seq=24).run([Request(rid=r.rid, prompt=r.prompt,
                                           max_new_tokens=r.max_new_tokens)
                                   for r in reqs])
    assert sorted(outs) == sorted(want)
    for rid in want:
        np.testing.assert_array_equal(
            outs[rid], want[rid],
            err_msg=f"{arch}/K{k}: dense-KV stream != one-shot oracle")

    # kv_bits=4: runs to completion, typed FINISHED, and the streams are
    # a pure function of the requests — identical across reruns and
    # across slot counts (freeze-on-first-write storage determinism)
    runs = []
    for n_slots in (2, 2, 3):
        eng = Engine(sp, cfg, n_slots=n_slots, page_size=8, max_seq=24,
                     kv_bits=4)
        runs.append(eng.run([Request(rid=r.rid, prompt=r.prompt,
                                     max_new_tokens=r.max_new_tokens)
                             for r in reqs]))
        assert all(res.ok for res in eng.results.values())
    for rid in runs[0]:
        np.testing.assert_array_equal(runs[0][rid], runs[1][rid])
        np.testing.assert_array_equal(
            runs[0][rid], runs[2][rid],
            err_msg=f"{arch}/K{k}: quantized-KV stream depends on "
                    f"batching")
        assert len(runs[0][rid]) == reqs[rid].max_new_tokens


def test_engine_quant_kv_head_mode_and_kv8():
    """The remaining kv knobs: per-head codebooks and 8-bit pages both
    serve deterministically on the mixed stack."""
    cfg, sp = _packed_arch("gqa-mixed", 16)
    reqs = _reqs(cfg)
    for kwargs in ({"kv_bits": 4, "kv_cb_mode": "head"}, {"kv_bits": 8}):
        a = Engine(sp, cfg, n_slots=2, page_size=8, max_seq=24,
                   **kwargs).run(list(reqs))
        b = Engine(sp, cfg, n_slots=2, page_size=8, max_seq=24,
                   **kwargs).run(list(reqs))
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])


def test_engine_rejects_bad_kv_knobs():
    cfg, sp = _packed_arch("gqa-mixed", 16)
    with pytest.raises(ValueError, match="kv_bits"):
        Engine(sp, cfg, n_slots=2, page_size=8, max_seq=24, kv_bits=3)
    with pytest.raises(ValueError, match="kv_cb_mode"):
        Engine(sp, cfg, n_slots=2, page_size=8, max_seq=24, kv_bits=4,
               kv_cb_mode="tensor")


# ---------------------------------------------------------------------------
# Mosaic compile variants (need a real TPU; CI runs them allowed-to-skip)
# ---------------------------------------------------------------------------

@pytest.mark.tpu
@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="Mosaic compile path needs a real TPU")
def test_paged_kernels_compile_on_tpu():
    q, kp, vp, tbl, pos, alive = _gqa_case()
    out = ops.paged_attention(q, kp, vp, tbl, pos, alive, softcap=None,
                              scale=SCALE, token_tile=PAGE,
                              interpret=False)
    assert out.shape == (B, 1, H * HD)
    kw, kcb, _ = _quant_pool(kp, 4)
    vw, vcb, _ = _quant_pool(vp, 4)
    out = ops.paged_attention_quant(
        q, kw, vw, kcb, vcb, tbl, pos, alive, bits=4, head_dim=HD,
        softcap=None, scale=SCALE, token_tile=PAGE, interpret=False)
    assert out.shape == (B, 1, H * HD)
    g = ops.page_gather(kp, tbl, alive, interpret=False)
    assert g.shape == (B, NPG * PAGE, KV, HD)


@pytest.mark.tpu
@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="Mosaic compile path needs a real TPU")
def test_mla_paged_kernels_compile_on_tpu():
    qe, qr, cp, rp, tbl, pos, alive = _mla_case()
    scale = (LAT + RD) ** -0.5
    out = ops.mla_paged_attention(qe, qr, cp, rp, tbl, pos, alive,
                                  scale=scale, token_tile=PAGE,
                                  interpret=False)
    assert out.shape == (B, 1, H, LAT)
    cw, ccb, _ = _quant_pool(cp, 4)
    rw, rcb, _ = _quant_pool(rp, 4)
    out = ops.mla_paged_attention_quant(
        qe, qr, cw, rw, ccb, rcb, tbl, pos, alive, bits=4, kv_lora=LAT,
        rope_dim=RD, scale=scale, token_tile=PAGE, interpret=False)
    assert out.shape == (B, 1, H, LAT)
