"""The static serving-graph auditor's own test matrix (ISSUE 6).

Two halves:

* **poisoned self-tests** — each checker must flag a graph/config built
  to violate its invariant (a dequant-then-dot graph, an oversized or
  lane-misaligned block config, a shape-varying jit loop).  A linter
  that never fires is indistinguishable from one that works;
* **clean golden runs** — the full audit over both committed fixtures
  passes with zero active violations and byte-exact eq.-14 accounting
  (``bits_per_index(K)/8`` B/weight from compiled HLO).

Everything runs on CPU: jaxpr tracing is abstract eval (no Mosaic), the
HBM compile uses the ref backend, and the VMEM checks are integer
arithmetic over static shapes.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from helpers import packed_tiny
from repro.analysis import audit as audit_mod
from repro.analysis import (RecompileAuditor, RecompileViolation,
                            find_dense_inflations, protected_leaves,
                            validate_block_config)
from repro.analysis.graph import trace_backend
from repro.analysis.vmem import audit_block_space, estimate_vmem_bytes
from repro.analysis.zoo import CONFIGS, infer_config
from repro.core.compression import PackedModel, bits_per_index
from repro.kernels import dispatch

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures")


# ---------------------------------------------------------------------------
# poisoned: dense-inflation detection
# ---------------------------------------------------------------------------

def _tiny_serving():
    cfg, pm = packed_tiny(16, "float32")
    sp = pm.serving_params(packed=True)
    return cfg, sp, protected_leaves(sp)


def test_poisoned_dequant_then_dot_is_flagged():
    """The exact pre-PR-4 LM-head failure: materialize the dense weight
    from the packed operand, then contract — must be flagged, with the
    dot feed proven."""
    _, sp, prot = _tiny_serving()
    mlp = sp["stacks"][0]["pos0"]["mlp"]
    lay = mlp["w_in_layout"]

    def poisoned(p, x):
        m = p["stacks"][0]["pos0"]["mlp"]
        w = dispatch.decode_packed_leaf(m["w_in_pidx"][0],
                                        m["w_in_cb"][0], lay)
        return x @ w

    x = jnp.zeros((4, lay.kd))
    hits = find_dense_inflations(poisoned, (sp, x), prot)
    leaves = {h.leaf for h in hits}
    assert "['stacks'][0]['pos0']['mlp']['w_in']" in leaves
    assert any(h.feeds_dot for h in hits)


def test_clean_fused_route_not_flagged():
    """The production route (pallas backend pinned while tracing) must
    NOT be flagged: the packed operand feeds an opaque pallas_call."""
    _, sp, prot = _tiny_serving()
    mlp = sp["stacks"][0]["pos0"]["mlp"]
    lay = mlp["w_in_layout"]

    def fused(p, x):
        m = p["stacks"][0]["pos0"]["mlp"]
        return dispatch.packed_quantized_matmul(
            x, m["w_in_pidx"][0], m["w_in_cb"][0], layout=lay,
            backend="pallas")

    x = jnp.zeros((4, lay.kd))
    assert find_dense_inflations(fused, (sp, x), prot) == []


def test_taint_disambiguates_same_shape_leaves():
    """Two leaves can share a dense shape; the detector must charge the
    one whose arrays actually feed the gather, not both."""
    _, sp, prot = _tiny_serving()
    mlp = sp["stacks"][0]["pos0"]["mlp"]
    # w_in [32, 64] and w_gate [32, 64] share a shape in tiny_cfg
    assert prot["['stacks'][0]['pos0']['mlp']['w_in']"]["dense_shapes"] \
        == prot["['stacks'][0]['pos0']['mlp']['w_gate']"]["dense_shapes"]

    def poisoned(p, x):
        m = p["stacks"][0]["pos0"]["mlp"]
        w = dispatch.decode_packed_leaf(m["w_gate_pidx"][0],
                                        m["w_gate_cb"][0],
                                        m["w_gate_layout"])
        return x @ w

    x = jnp.zeros((4, mlp["w_gate_layout"].kd))
    leaves = {h.leaf for h in find_dense_inflations(poisoned, (sp, x),
                                                    prot)}
    assert leaves == {"['stacks'][0]['pos0']['mlp']['w_gate']"}


def test_full_model_pallas_trace_clean_on_tiny():
    """tiny_cfg has no MoE → the whole forward must trace clean on the
    production backend (no allowlist needed)."""
    from repro.models.transformer import forward
    cfg, sp, prot = _tiny_serving()
    toks = jnp.zeros((1, 8), jnp.int32)
    with trace_backend("pallas"):
        hits = find_dense_inflations(lambda p, t: forward(p, cfg, t),
                                     (sp, toks), prot)
    assert hits == []


def test_ref_trace_is_flagged():
    """Sanity that the detector fires on the dequant reference route —
    proving the clean pallas result above is not vacuous."""
    from repro.models.transformer import forward
    cfg, sp, prot = _tiny_serving()
    toks = jnp.zeros((1, 8), jnp.int32)
    with trace_backend("ref"):
        hits = find_dense_inflations(lambda p, t: forward(p, cfg, t),
                                     (sp, toks), prot)
    assert len({h.leaf for h in hits}) >= 3


# ---------------------------------------------------------------------------
# poisoned: VMEM / block-config lint
# ---------------------------------------------------------------------------

def test_oversized_block_config_rejected():
    res = validate_block_config("packed_matmul", 512, 2048, 8192,
                                4, 16)
    assert not res["ok"]
    assert any("VMEM" in e for e in res["errors"])


def test_lane_straddling_block_rejected():
    # bits=4 → lanes=8; bk=100 straddles word boundaries
    res = validate_block_config("packed_matmul", 8, 256, 100, 4, 16)
    assert not res["ok"] and any("lanes" in e for e in res["errors"])
    # transposed kd-order packs the OUTPUT axis: bn must divide
    res = validate_block_config("packed_matmul_t", 8, 100, 256, 4, 16,
                                order="kd")
    assert not res["ok"] and any("bn=100" in e for e in res["errors"])
    # row order packs the reduction axis: same bn is fine, bad bk isn't
    assert validate_block_config("packed_matmul_t", 8, 100, 256, 4, 16,
                                 order="row")["ok"]


def test_committed_block_table_is_clean():
    """Every committed autotune entry and every heuristic pick for both
    fixtures' leaves must lint clean — this is the CPU-side stand-in for
    Mosaic compile coverage (documented tpu-marker interaction: these
    checks run without a TPU)."""
    for fx in ("pr2_mlp_only", "pr3_full"):
        pm = PackedModel.load(os.path.join(FIXTURES, fx))
        prot = protected_leaves(pm.serving_params(packed=True))
        res = audit_block_space(prot)
        assert res["violations"] == [], (fx, res["violations"])
        assert res["rows"], fx


def test_prefill_block_lint_poisoned_and_clean():
    """The blockwise-prefill token-tile lint: an oversized tile must be
    rejected (a lint that never fires proves nothing), and the committed
    dispatch table must sweep clean at every supported kv width."""
    from repro.analysis.vmem import (audit_prefill_block_space,
                                     validate_prefill_block_config)
    bad = validate_prefill_block_config("dense", 128, 4096)
    assert not bad["ok"] and any("VMEM" in e for e in bad["errors"])
    assert not validate_prefill_block_config("nope", 12, 8)["ok"]
    assert not validate_prefill_block_config("quant", 12, 8, bits=3)["ok"]
    # quant footprint grows with dequant mode (onehot carries ×K body)
    lut = validate_prefill_block_config("quant", 12, 8, bits=4)
    onehot = validate_prefill_block_config("quant", 12, 8, bits=4,
                                           dequant="onehot")
    assert lut["ok"] and lut["vmem_bytes"] < onehot["vmem_bytes"]
    swept = audit_prefill_block_space()
    assert swept["rows"] and swept["violations"] == []
    assert {r["kind"] for r in swept["rows"]} == {"dense", "quant"}


def test_vmem_estimate_monotone_in_blocks():
    small = estimate_vmem_bytes("packed_matmul", 8, 128, 512, 4, 16)
    big = estimate_vmem_bytes("packed_matmul", 128, 512, 2048, 4, 16)
    assert 0 < small < big
    # onehot dequant inflates the in-kernel body by ~K
    onehot = estimate_vmem_bytes("packed_matmul", 8, 128, 512, 4, 16,
                                 dequant="onehot")
    assert onehot > small


# ---------------------------------------------------------------------------
# poisoned: recompile gate
# ---------------------------------------------------------------------------

def test_shape_varying_jit_trips_auditor():
    jf = jax.jit(lambda x: x * 2)
    jf(jnp.zeros((4,)))                     # warm one shape
    auditor = RecompileAuditor({"f": jf})
    auditor.snapshot()
    jf(jnp.zeros((4,)))                     # same shape: no growth
    assert auditor.check("same-shape") == {"f": 0}
    jf(jnp.zeros((8,)))                     # new shape: retrace
    with pytest.raises(RecompileViolation, match="f: \\+1"):
        auditor.check("shape-varying loop")
    # an explicit budget documents legitimate first-compiles
    assert auditor.check("budgeted", budget={"f": 1}) == {"f": 1}


def test_frozen_context_raises_on_growth():
    jf = jax.jit(lambda x: x + 1)
    auditor = RecompileAuditor({"f": jf})
    with pytest.raises(RecompileViolation):
        with auditor.frozen("cold jit"):
            jf(jnp.zeros((3,)))


# ---------------------------------------------------------------------------
# allowlist semantics
# ---------------------------------------------------------------------------

def test_allowlist_glob_matches_bracketed_paths():
    allow = [{"check": "dense-inflation", "subject": "*['experts_w_*']",
              "reason": "einsum operand"}]
    v_moe = {"check": "dense-inflation",
             "subject": "['stacks'][1]['pos0']['mlp']['experts_w_out']",
             "detail": "d"}
    v_mlp = {"check": "dense-inflation",
             "subject": "['stacks'][0]['pos0']['mlp']['w_out']",
             "detail": "d"}
    v_hbm = {"check": "hbm-bytes", "subject": v_moe["subject"],
             "detail": "d"}
    active, allowed = audit_mod.split_allowed([v_moe, v_mlp, v_hbm],
                                              allow)
    assert [v["subject"] for v in allowed] == [v_moe["subject"]]
    assert len(active) == 2
    assert allowed[0]["allowed_reason"] == "einsum operand"


def test_allowlist_entry_requires_reason(tmp_path):
    p = tmp_path / "allow.json"
    p.write_text('{"entries": [{"check": "*", "subject": "*"}]}')
    with pytest.raises(ValueError, match="reason"):
        audit_mod.load_allowlist(str(p))


def test_packaged_allowlist_loads():
    entries = audit_mod.load_allowlist()
    assert all(e["reason"] for e in entries)


# ---------------------------------------------------------------------------
# clean golden runs — the CI gate over the committed fixtures
# ---------------------------------------------------------------------------

def test_zoo_infers_fixture_configs():
    for fx, want in (("pr2_mlp_only", "tiny"), ("pr3_full", "mixed")):
        pm = PackedModel.load(os.path.join(FIXTURES, fx))
        key, cfg = infer_config(pm)
        assert key == want
    with pytest.raises(ValueError, match="unknown config"):
        infer_config(pm, "nope")
    assert set(CONFIGS) == {"tiny", "tiny-untied", "mixed", "mixed-tied"}


@pytest.mark.parametrize("fixture,skip", [
    ("pr2_mlp_only", []),
    # recompile scenario is fixture-independent (same engine loop);
    # covered once above to bound suite runtime
    ("pr3_full", ["recompile"]),
])
def test_golden_fixture_audits_clean(fixture, skip):
    report = audit_mod.run_audit(os.path.join(FIXTURES, fixture),
                                 skip=skip)
    assert report["ok"], report["violations"]
    assert report["violations"] == []
    hbm = report["checks"]["hbm"]
    assert set(hbm) == {"forward", "prefill", "decode_step_slots",
                        "engine_decode_sample",
                        "engine_decode_sample_kvq4",
                        "engine_prefill_chunk"}
    for entry, res in hbm.items():
        assert res["rows"], entry
        for row in res["rows"]:
            exact = bits_per_index(row["k"]) / 8
            assert row["bytes_per_weight"] == exact, row
            assert row["uses"] >= 1, row
    # every protected leaf is covered in every entry's byte audit
    n_leaves = len(report["protected_leaves"])
    for entry, res in hbm.items():
        assert len(res["rows"]) == n_leaves, entry
    # the KV-page operand check engaged: the quantized-KV decode reads
    # live uint32 word pools (zero unexplained dense-width KV reads —
    # those would be violations, asserted empty above)
    kvq = hbm["engine_decode_sample_kvq4"]
    assert kvq["kv_rows"] and kvq["kv_word_input_bytes"] > 0
    for row in kvq["kv_rows"]:
        assert row["uses"] >= 1, row
        assert row["hbm_bytes"] < row["dense_bytes"], row
    # the paged + blockwise-prefill autotune tables are swept by the
    # vmem lint
    assert report["checks"]["vmem"]["paged_configs_checked"] >= 1
    assert report["checks"]["vmem"]["prefill_configs_checked"] >= 1
    if "recompile" not in skip:
        ev = report["checks"]["recompile"]["events"]
        assert ev["preemptions"] >= 1 and ev["finished"] >= 3
    # MoE exceptions surface as *allowed*, never silently dropped
    if fixture == "pr3_full":
        assert all("experts_w_" in v["subject"]
                   for v in report["allowed_violations"])
        assert report["allowed_violations"]


# ---------------------------------------------------------------------------
# satellite: bench group validation
# ---------------------------------------------------------------------------

def _run_bench(*argv):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         *argv],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=120)


def test_bench_unknown_group_errors():
    res = _run_bench("--only", "nosuchgroup")
    assert res.returncode == 2
    assert "nosuchgroup" in res.stderr
    assert "kernels" in res.stderr and "engine" in res.stderr


def test_bench_mixed_valid_invalid_tokens_error():
    res = _run_bench("--only", "kernels,typo")
    assert res.returncode == 2 and "typo" in res.stderr


def test_audit_table_renders_recompile_counts():
    """The human audit table must render whatever jit-cache counters the
    engine's ``trace_counts()`` reports — it used to hard-code the
    pre-blockwise key set (``prefill``/``commit``) and KeyError'd on
    real reports after the rename to ``prefill_chunk``."""
    from repro.launch.report import audit_table

    report = {
        "artifact": "x", "config": "mixed", "passed": True,
        "checks": {"recompile": {
            "events": {"steps": 9, "admitted": 3, "finished": 3,
                       "preemptions": 1},
            "counts": {"decode": 1, "prefill_chunk": 2, "sample": 1},
        }},
    }
    table = audit_table(report)
    assert "prefill_chunk=2" in table and "decode=1" in table
